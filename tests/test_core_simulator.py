"""Digital-twin year-simulator invariants (unit + hypothesis properties)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cost import CostModel
from repro.core.simulate import monthly_table, simulate_year, storage_costs
from repro.core.slo import SLO
from repro.core.traffic import HOURS_PER_YEAR, TrafficModel
from repro.core.twin import QuickscalingTwin, SimpleTwin

NOM = TrafficModel.honda_default("nom")
LOADS = NOM.hourly_loads()


def test_conservation():
    tw = SimpleTwin("t", 1.0, 0.01, 0.1)
    sim = simulate_year(tw, LOADS)
    arrived = LOADS.sum()
    processed = sim.processed.sum()
    assert abs(processed + sim.queue[-1] - arrived) / arrived < 1e-5


def test_capacity_cap():
    tw = SimpleTwin("t", 1.0, 0.01, 0.1)
    sim = simulate_year(tw, LOADS)
    assert sim.processed.max() <= 3600.0 * 1.0 + 1e-3


def test_quickscaling_never_queues():
    tw = QuickscalingTwin("q", 1.0, 0.01, 0.1)
    sim = simulate_year(tw, LOADS)
    assert sim.queue.max() == 0.0
    assert np.allclose(sim.processed, LOADS, rtol=1e-6)
    assert sim.backlog_s == 0.0
    # cost >= single-instance baseline
    assert sim.total_cost_usd >= 0.01 * HOURS_PER_YEAR - 1e-6


@settings(max_examples=20, deadline=None)
@given(cap=st.floats(0.2, 20.0), rate=st.floats(0.001, 1.0))
def test_more_capacity_never_worse(cap, rate):
    # tolerances are relative: the fp32 scan carries queues of ~1e7 records
    lo = simulate_year(SimpleTwin("lo", cap, rate, 0.1), LOADS)
    hi = simulate_year(SimpleTwin("hi", cap * 2, rate, 0.1), LOADS)
    assert hi.queue[-1] <= lo.queue[-1] * (1 + 1e-5) + 1.0
    assert hi.mean_latency_s <= lo.mean_latency_s * (1 + 1e-4) + 1e-3
    assert hi.mean_throughput_rph >= lo.mean_throughput_rph * (1 - 1e-5) - 1.0


@settings(max_examples=20, deadline=None)
@given(cap=st.floats(0.2, 10.0))
def test_backlog_cost_formula(cap):
    tw = SimpleTwin("t", cap, 0.01, 0.1)
    sim = simulate_year(tw, LOADS)
    want = sim.queue[-1] / cap / 3600.0 * 0.01
    assert abs(sim.backlog_cost_usd - want) < 1e-6
    assert abs(sim.total_cost_usd
               - (0.01 * HOURS_PER_YEAR + want)) < 1e-3


def test_slo_evaluation_pattern():
    slo = SLO(limit_s=4 * 3600, met_fraction=0.95)
    big = simulate_year(SimpleTwin("big", 10.0, 0.01, 0.1), LOADS, slo=slo)
    tiny = simulate_year(SimpleTwin("tiny", 0.3, 0.01, 0.1), LOADS, slo=slo)
    assert big.slo_met is True and big.pct_latency_met == 100.0
    assert tiny.slo_met is False


# ---------------------------------------------------------------------------
# storage / retention
# ---------------------------------------------------------------------------

def test_storage_retention_monotone():
    cm3 = CostModel(retention_days=91)
    cm6 = CostModel(retention_days=182)
    d3 = storage_costs(LOADS, cm3, record_mb=0.001)
    d6 = storage_costs(LOADS, cm6, record_mb=0.001)
    assert d6["storage_usd"].sum() > d3["storage_usd"].sum()
    # identical until the shorter retention starts expiring (day 91)
    np.testing.assert_allclose(d3["storage_usd"][:91], d6["storage_usd"][:91])
    # network cost independent of retention
    np.testing.assert_allclose(d3["network_usd"], d6["network_usd"])


def test_storage_window_exact():
    cm = CostModel(retention_days=7)
    loads = np.ones(HOURS_PER_YEAR)          # 24 records/day
    d = storage_costs(loads, cm, record_mb=1.0)
    # steady state: exactly 7 days of data retained
    assert np.allclose(d["stored_gb"][10:], 7 * 24 / 1024.0)


def test_monthly_table_sums():
    cm = CostModel()
    tw = SimpleTwin("t", 2.0, 0.01, 0.1)
    sim = simulate_year(tw, LOADS, cost_model=cm, record_mb=0.001)
    rows = monthly_table(sim, cm, 0.001)
    assert len(rows) == 12
    total_cloud = sum(r["cloud_usd"] for r in rows)
    assert abs(total_cloud - sim.cost_usd.sum()) < 1e-6
    total_stor = sum(r["storage_usd"] for r in rows)
    assert abs(total_stor - sim.storage_cost_usd) < 1e-6

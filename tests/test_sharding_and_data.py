"""Sharding rule table, schema->spec mapping, data loader determinism,
telemetry pipeline variants."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import ParallelConfig
from repro.data.loader import TokenBatchLoader
from repro.models.layers import ParamDef, specs_from_schema
from repro.pipelines.telemetry import (TELEMETRY_VARIANTS,
                                       make_telemetry_dataset,
                                       make_telemetry_pipeline)


# ---------------------------------------------------------------------------
# specs_from_schema
# ---------------------------------------------------------------------------

MESH = {"data": 16, "model": 16}
RULES = {"embed": "data", "mlp": "model", "vocab": "model",
         "expert": "data", "batch": ("pod", "data"), "norm": None}


def test_spec_basic_mapping():
    schema = {"w": ParamDef((2048, 8192), ("embed", "mlp"))}
    specs = specs_from_schema(schema, RULES, MESH)
    assert specs["w"] == P("data", "model")


def test_spec_divisibility_fallback():
    schema = {"w": ParamDef((2048, 100), ("embed", "mlp"))}   # 100 % 16 != 0
    specs = specs_from_schema(schema, RULES, MESH)
    assert specs["w"] == P("data", None)


def test_spec_no_double_axis_use():
    schema = {"w": ParamDef((64, 64, 64), ("mlp", "vocab", "norm"))}
    specs = specs_from_schema(schema, RULES, MESH)
    # 'model' may shard only one dim
    assert specs["w"] == P("model", None, None)


def test_spec_tuple_axes():
    schema = {"x": ParamDef((256, 8), ("batch", None))}
    specs = specs_from_schema(schema, RULES, {"pod": 2, "data": 16})
    assert specs["x"] == P(("pod", "data"), None)


def test_spec_tuple_non_divisible():
    schema = {"x": ParamDef((8, 8), ("batch", None))}   # 8 % 32 != 0
    specs = specs_from_schema(schema, RULES, {"pod": 2, "data": 16})
    assert specs["x"] == P(None, None)


# ---------------------------------------------------------------------------
# data loader
# ---------------------------------------------------------------------------

def test_loader_deterministic_and_resumable():
    l1 = TokenBatchLoader(vocab_size=128, seq_len=16, batch=4, seed=5)
    a = [l1.next()["tokens"].copy() for _ in range(4)]
    l1.close()
    l2 = TokenBatchLoader(vocab_size=128, seq_len=16, batch=4, seed=5)
    l2.load_state_dict({"step": 2, "seed": 5})
    b2 = l2.next()["tokens"]
    l2.close()
    np.testing.assert_array_equal(b2, a[2])
    assert not np.array_equal(a[0], a[1])


# ---------------------------------------------------------------------------
# telemetry pipeline variants (paper Sec. VI-A)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def telemetry_ds():
    return make_telemetry_dataset(12, seed=3)


@pytest.mark.parametrize("variant", TELEMETRY_VARIANTS)
def test_variant_processes_all_records(variant, telemetry_ds, tmp_path):
    pipe = make_telemetry_pipeline(variant, blob_dir=str(tmp_path))
    pipe.start()
    for i in range(6):
        pipe.submit(telemetry_ds.record_batch(i, 1), records=1)
    assert pipe.drain(timeout=60)
    pipe.stop()
    assert not pipe.errors
    # 6 zips x 5 subsystems x 12 channels -> db rows
    assert pipe.etl.rows == 6 * 5 * 12
    summary = pipe.collector.summary()
    assert set(summary) == {"unzipper_phase", "v2x_phase", "etl_phase"}


def test_blocking_write_slower_v2x(telemetry_ds, tmp_path):
    """The paper's central engineering finding: the synchronous blob write
    inflates v2x_phase latency vs the non-blocking variant."""
    lat = {}
    for variant in ("blocking-write", "no-blocking-write"):
        pipe = make_telemetry_pipeline(variant, blob_dir=str(tmp_path / variant))
        pipe.start()
        for i in range(8):
            pipe.submit(telemetry_ds.record_batch(i, 1), records=1)
        assert pipe.drain(timeout=60)
        pipe.stop()
        lat[variant] = pipe.collector.summary()["v2x_phase"]["p50_latency_s"]
    # blocking pays >= 5 x 2ms blob RTTs inline per record; use an absolute
    # margin robust to single-core scheduling noise
    assert lat["blocking-write"] > lat["no-blocking-write"] + 0.005, lat


def test_etl_scrubs_bad_data(telemetry_ds, tmp_path):
    pipe = make_telemetry_pipeline("no-blocking-write", blob_dir=str(tmp_path))
    pipe.start()
    pipe.submit(telemetry_ds.record_batch(0, 2), records=2)
    assert pipe.drain(timeout=60)
    pipe.stop()
    assert pipe.etl.scrubbed > 0          # NaNs were injected and removed

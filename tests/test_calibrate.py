"""Differentiable twin calibration: gradients, recovery, one-dispatch fits.

Covers the acceptance criteria of the calibrate subsystem:
- the generalized scan is bit-identical to the hourly kernel at dt=1
- d(loss)/d(params) matches finite differences (fifo, quickscale, shed)
- parameter recovery from noiseless replays within 5% for >= 3 policies
  (fifo, shed, autoscale; batch_window's identifiable subset too)
- all K restarts of a fit run as ONE jitted dispatch (jit cache count)
- trace builders, holdout generalization, calibrated_twin/calibrated_grid
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.calibrate import (ObservedTrace, bin_loadpattern, calibrated_twin,
                             evaluate, fit, fit_spec, fit_with_holdout,
                             trace_loss, z_from_params)
from repro.calibrate.fit import _fit_kernel
from repro.core.experiment import ExperimentResult
from repro.core.loadpattern import LoadPattern, Segment
from repro.core.metrics import MetricStore
from repro.core.simulate import _grid_scan, scan_trace, simulate_year
from repro.core.spans import Span, SpanCollector
from repro.core.traffic import TrafficModel
from repro.core.twin import (SimpleTwin, Twin, make_twin, policy_names,
                             policy_spec, registry_version)
from repro.core.whatif import calibrated_grid

LOADS = TrafficModel.honda_default("nom").hourly_loads()

RAMP = LoadPattern.ramp("ramp", duration_s=6 * 3600, peak_rate=6.0)
STEADY = LoadPattern.steady("steady", duration_s=6 * 3600, rate=3.0)

FIFO_TRUTH = SimpleTwin("t", 2.0, 0.05, 0.2)
SHED_TRUTH = make_twin("t", "shed", max_rps=2.0, usd_per_hour=0.05,
                       base_latency_s=0.2, queue_cap_hours=1.5)


def _relerrs(result, truth):
    tp = truth.padded_params()
    return {n: float(abs(result.params[i] - tp[i]) / max(abs(tp[i]), 1e-9))
            for i, n in enumerate(result.spec.param_names)
            if result.spec.free_mask[i]}


# ---------------------------------------------------------------------------
# generalized scan: dt=1 bit-identity + sub-hour conservation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("twin", [
    FIFO_TRUTH,
    make_twin("q", "quickscale", max_rps=2.0, usd_per_hour=0.05,
              base_latency_s=0.2),
    make_twin("a", "autoscale", max_rps=0.5, usd_per_hour=0.01,
              base_latency_s=0.1, max_instances=8, scale_up_hours=3),
    SHED_TRUTH,
    make_twin("b", "batch_window", max_rps=4.0, usd_per_hour=0.01,
              base_latency_s=0.1, window_hours=6),
], ids=lambda t: t.policy)
def test_scan_trace_dt1_bit_identical_to_year_kernel(twin):
    """simulate_year (the PR 1 hourly path) == scan_trace at dt=1.0."""
    sim = simulate_year(twin, LOADS)
    q_end, (proc, queue, lat, cost, drop) = scan_trace(
        jnp.asarray(LOADS, jnp.float32), jnp.asarray(twin.padded_params()),
        twin.policy_index, 1.0)
    assert np.array_equal(np.asarray(proc, np.float64), sim.processed)
    assert np.array_equal(np.asarray(queue, np.float64), sim.queue)
    assert np.array_equal(np.asarray(lat, np.float64), sim.latency_s)
    assert np.array_equal(np.asarray(cost, np.float64), sim.cost_usd)
    assert np.array_equal(np.asarray(drop, np.float64), sim.dropped)
    assert float(q_end[0]) == sim.queue[-1]


@pytest.mark.parametrize("policy", ["fifo", "shed"])
def test_subhour_bins_conserve_records(policy):
    """processed + queue_end + dropped == arrived at dt=0.25h."""
    twin = FIFO_TRUTH if policy == "fifo" else SHED_TRUTH
    arrivals = bin_loadpattern(RAMP, bin_s=900.0).astype(np.float32)
    _, (proc, queue, _lat, _cost, drop) = scan_trace(
        jnp.asarray(arrivals), jnp.asarray(twin.padded_params()),
        twin.policy_index, 0.25)
    arrived = float(arrivals.astype(np.float64).sum())
    total = (float(np.asarray(proc, np.float64).sum())
             + float(np.asarray(queue)[-1]) +
             float(np.asarray(drop, np.float64).sum()))
    assert abs(total - arrived) / arrived < 1e-5
    # sub-hour capacity really is per-bin: a quarter-hour bin processes at
    # most a quarter-hour of capacity
    assert np.asarray(proc).max() <= 2.0 * 3600.0 * 0.25 * (1 + 1e-6)


def test_grid_scan_rejects_partial_year_without_bin_hours():
    from repro.core.cost import CostModel
    from repro.core.simulate import simulate_grid
    with pytest.raises(ValueError):
        simulate_grid([FIFO_TRUTH], np.ones((1, 100), np.float32))
    sims = simulate_grid([FIFO_TRUTH], np.full((1, 100), 900.0, np.float32),
                         bin_hours=0.25)
    assert sims[0].processed.shape == (100,)
    # throughput stays records-per-HOUR whatever the bin width
    assert sims[0].max_throughput_rph <= 2.0 * 3600.0 * (1 + 1e-6)
    # an explicit bin_hours=1.0 permits short hourly horizons (1.0 is a
    # real value, not an "unset" sentinel)
    sims = simulate_grid([FIFO_TRUTH], np.full((1, 100), 900.0, np.float32),
                         bin_hours=1.0)
    assert sims[0].processed.shape == (100,)
    # Table IV storage accounting is year-only: loud error, not silent zero
    with pytest.raises(ValueError):
        simulate_grid([FIFO_TRUTH], np.full((1, 100), 900.0, np.float32),
                      bin_hours=0.25, cost_model=CostModel(), record_mb=0.5)


# ---------------------------------------------------------------------------
# trace builders
# ---------------------------------------------------------------------------

def test_bin_loadpattern_integrates_exactly():
    bins = bin_loadpattern(RAMP, bin_s=300.0)
    assert bins.shape == (72,)
    assert bins.sum() == pytest.approx(RAMP.total_records, rel=1e-6)
    assert (np.diff(bins) > 0).all()          # a ramp keeps ramping


def test_trace_from_loadpattern_and_noise():
    tr = ObservedTrace.from_loadpattern(RAMP, FIFO_TRUTH, bin_s=300.0)
    assert tr.num_bins == 72 and tr.bin_hours == pytest.approx(1 / 12)
    assert tr.processed.sum() <= tr.arrivals.sum()
    assert (tr.latency_s >= FIFO_TRUTH.base_latency_s - 1e-6).all()
    noisy = tr.with_noise(0.05, seed=1)
    assert not np.array_equal(noisy.processed, tr.processed)
    assert (noisy.processed >= 0).all() and (noisy.latency_s >= 0).all()
    # scales: every series positive, dropped falls back to arrival scale
    sc = tr.scales()
    assert all(v > 0 for v in sc.values())
    assert sc["dropped"] == pytest.approx(float(np.abs(tr.arrivals).mean()))


def _synthetic_result(rate_rps=20.0, duration_s=60.0, svc_s=0.01):
    """A hand-built ExperimentResult: constant arrivals, one stage that
    completes each batch svc_s later, flat $/hr."""
    col = SpanCollector()
    metrics = MetricStore()
    n_ticks = int(duration_s)
    per_tick = rate_rps
    for i in range(n_ticks):
        t = float(i + 1)
        metrics.observe("records_sent", per_tick * (i + 1), t=t)
        col.add(Span("write", start=t, duration=svc_s,
                     records=int(per_tick)))
    sent = int(per_tick * n_ticks)
    return ExperimentResult(
        name="synthetic", pipeline_name="synthetic", started=0.0,
        duration_s=duration_s, records_sent=sent, records_done=sent,
        ingest_mb=1.0,
        stage_summary={"write": {"records": sent, "mean_latency_s": svc_s,
                                 "p50_latency_s": svc_s,
                                 "throughput_rps": rate_rps,
                                 "busy_s": svc_s * n_ticks}},
        cost={"usd_per_hour": 0.1, "total_usd": 0.1 * duration_s / 3600.0},
        collector=col, metrics=metrics, drained=True, time_scale=1.0)


def test_trace_from_experiment():
    res = _synthetic_result()
    tr = ObservedTrace.from_experiment(res, bin_s=10.0)
    assert tr.num_bins == 6
    assert tr.arrivals.sum() == pytest.approx(res.records_sent, rel=1e-6)
    assert tr.processed.sum() == pytest.approx(res.records_done, rel=1e-6)
    assert (tr.latency_s >= 0).all()
    assert tr.cost_usd.sum() == pytest.approx(0.1 * 60.0 / 3600.0, rel=1e-6)


# ---------------------------------------------------------------------------
# gradients through the scan: autodiff == finite differences
# ---------------------------------------------------------------------------

def _loss_fn_for(policy, trace, truth):
    spec = fit_spec(policy, init=truth)
    arrivals = jnp.asarray(trace.arrivals, jnp.float32)
    targets = {k: jnp.asarray(v, jnp.float32)
               for k, v in trace.series().items()}
    scales = {k: jnp.float32(v) for k, v in trace.scales().items()}
    weights = {k: jnp.float32(1.0) for k in targets}
    idx = policy_spec(policy).index

    def loss(z):
        return trace_loss(z, arrivals, targets, scales, weights, idx,
                          trace.bin_hours, jnp.asarray(spec.lo),
                          jnp.asarray(spec.hi), jnp.asarray(spec.log_mask),
                          jnp.asarray(spec.free_mask),
                          jnp.asarray(spec.fixed))

    z0 = z_from_params(truth.padded_params() * 1.17, spec.lo, spec.hi,
                       spec.log_mask)
    return loss, z0, spec


@pytest.mark.parametrize("policy,truth", [
    ("fifo", FIFO_TRUTH),
    ("quickscale", make_twin("q", "quickscale", max_rps=2.0,
                             usd_per_hour=0.05, base_latency_s=0.2)),
    ("shed", SHED_TRUTH),
])
def test_gradient_matches_finite_differences(policy, truth):
    """Central finite differences confirm d(loss)/d(z) through the scan."""
    # steady-rate trace keeps quickscale's ceil() away from integer edges
    pattern = STEADY if policy == "quickscale" else RAMP
    trace = ObservedTrace.from_loadpattern(pattern, truth, bin_s=300.0)
    loss, z0, spec = _loss_fn_for(policy, trace, truth)
    g_ad = np.asarray(jax.grad(loss)(jnp.asarray(z0)), np.float64)
    # h small enough that the scan-accumulated queue's curvature (huge
    # third derivative in the capacity coordinate) drops out of central FD
    h = 1e-3
    for i in np.nonzero(spec.free_mask)[0]:
        zp, zm = z0.copy(), z0.copy()
        zp[i] += h
        zm[i] -= h
        g_fd = (float(loss(jnp.asarray(zp))) - float(loss(jnp.asarray(zm))))\
            / (2 * h)
        assert g_ad[i] == pytest.approx(
            g_fd, rel=0.05, abs=max(5e-3, 1e-2 * abs(g_ad).max())), \
            (policy, spec.param_names[i], g_ad[i], g_fd)


# ---------------------------------------------------------------------------
# parameter recovery: noiseless replays, random restarts, <= 5% error
# ---------------------------------------------------------------------------

def test_recover_fifo_params():
    tr = ObservedTrace.from_loadpattern(RAMP, FIFO_TRUTH, bin_s=300.0)
    res = fit(tr, "fifo", restarts=8, steps=400, seed=0)
    assert max(_relerrs(res, FIFO_TRUTH).values()) < 0.05


def test_recover_shed_params():
    tr = ObservedTrace.from_loadpattern(RAMP, SHED_TRUTH, bin_s=300.0)
    res = fit(tr, "shed", restarts=8, steps=400, seed=0)
    assert max(_relerrs(res, SHED_TRUTH).values()) < 0.05
    assert tr.dropped.sum() > 0       # the trace actually exercised the cap


def test_recover_autoscale_params():
    truth = make_twin("t", "autoscale", max_rps=0.8, usd_per_hour=0.02,
                      base_latency_s=0.3, min_instances=1, max_instances=4,
                      scale_up_hours=2.0)
    segs = []
    for _ in range(4):       # drainable burst cycles: the boot-delay signal
        segs += [Segment(3 * 3600, 2.0, 2.0), Segment(6 * 3600, 0.1, 0.1)]
    tr = ObservedTrace.from_loadpattern(LoadPattern("cycles", tuple(segs)),
                                        truth, bin_s=300.0)
    res = fit(tr, "autoscale", restarts=16, steps=800, seed=0,
              fixed_values={"min_instances": 1.0, "max_instances": 4.0})
    errs = _relerrs(res, truth)
    assert max(errs.values()) < 0.05, errs
    # instance bounds were frozen, not fit
    assert "min_instances" not in errs and "max_instances" not in errs
    assert res.params[3] == 1.0 and res.params[4] == 4.0


def test_recover_batch_window_identifiable_params():
    """batch_window recovers its identifiable parameters; base_latency_s
    is additively degenerate with the half-window term (0.25 s against
    hours of batching latency) and is excluded by construction."""
    truth = make_twin("b", "batch_window", max_rps=3.0, usd_per_hour=0.04,
                      base_latency_s=0.25, window_hours=4.0,
                      idle_cost_fraction=0.15)
    pat = LoadPattern("ramp24", (Segment(24 * 3600, 0.5, 4.0),))
    tr = ObservedTrace.from_loadpattern(pat, truth, bin_s=600.0)
    res = fit(tr, "batch_window", restarts=16, steps=800, seed=0)
    errs = _relerrs(res, truth)
    errs.pop("base_latency_s")
    assert max(errs.values()) < 0.05, errs


def test_recover_fifo_params_under_noise():
    tr = ObservedTrace.from_loadpattern(RAMP, FIFO_TRUTH, bin_s=300.0)
    res = fit(tr.with_noise(0.02, seed=3), "fifo", restarts=8, steps=400,
              seed=0)
    assert max(_relerrs(res, FIFO_TRUTH).values()) < 0.10


# ---------------------------------------------------------------------------
# one vmapped dispatch for all restarts, shared across policies
# ---------------------------------------------------------------------------

def test_multi_start_fit_is_single_jit_dispatch():
    """K restarts x 3 policies on one trace shape = exactly one trace of
    the fit kernel (policy index and restart stack are operands)."""
    _fit_kernel.clear_cache()
    tr_f = ObservedTrace.from_loadpattern(RAMP, FIFO_TRUTH, bin_s=300.0)
    tr_s = ObservedTrace.from_loadpattern(RAMP, SHED_TRUTH, bin_s=300.0)
    quick = make_twin("q", "quickscale", max_rps=2.0, usd_per_hour=0.05,
                      base_latency_s=0.2)
    tr_q = ObservedTrace.from_loadpattern(STEADY, quick, bin_s=300.0)
    for trace, policy in [(tr_f, "fifo"), (tr_s, "shed"),
                          (tr_q, "quickscale")]:
        fit(trace, policy, restarts=8, steps=120, seed=0)
    assert _fit_kernel._cache_size() == 1


def test_fit_result_reporting():
    tr = ObservedTrace.from_loadpattern(RAMP, SHED_TRUTH, bin_s=300.0)
    res = fit(tr, "shed", restarts=4, steps=150, seed=0)
    assert res.loss_history.shape == (150, 4)
    assert res.start_losses.shape == (4,)
    assert res.loss == pytest.approx(res.start_losses.min())
    rows = res.restart_table()
    assert len(rows) == 4
    assert sum(r["best"] for r in rows) == 1
    assert all(set(res.spec.free_names) <= set(r) for r in rows)
    assert res.twin.kind == "calibrated" and res.twin.policy == "shed"


# ---------------------------------------------------------------------------
# holdout + entry points
# ---------------------------------------------------------------------------

def test_holdout_fit_on_ramp_validates_on_steady():
    train = ObservedTrace.from_loadpattern(RAMP, SHED_TRUTH, bin_s=300.0)
    hold = ObservedTrace.from_loadpattern(STEADY, SHED_TRUTH, bin_s=300.0)
    res = fit_with_holdout(train, hold, "shed", restarts=8, steps=400,
                           seed=0)
    assert res.holdout_loss is not None and res.holdout_name == hold.name
    # a noiseless, well-identified fit generalizes: holdout loss stays tiny
    assert res.holdout_loss < 0.05
    assert res.generalization_gap == pytest.approx(
        res.holdout_loss / res.loss, rel=1e-6)
    # evaluate() agrees with the stored holdout number
    assert evaluate(res.twin, hold) == pytest.approx(res.holdout_loss)


def test_calibrated_twin_from_trace_and_experiment():
    tr = ObservedTrace.from_loadpattern(RAMP, FIFO_TRUTH, bin_s=300.0)
    tw = calibrated_twin(tr, "fifo", restarts=8, steps=400, seed=0)
    assert isinstance(tw, Twin) and tw.policy == "fifo"
    assert abs(tw.max_rps - 2.0) / 2.0 < 0.05

    res = _synthetic_result(rate_rps=20.0)
    tw2 = calibrated_twin(res, "fifo", bin_s=10.0, restarts=4, steps=200)
    assert tw2.policy == "fifo" and np.isfinite(tw2.max_rps)
    # the synthetic pipeline kept up at 20 rec/s, so fitted capacity >= that
    assert tw2.max_rps > 5.0


def test_calibrated_grid_end_to_end():
    res = _synthetic_result(rate_rps=20.0)
    traffics = [TrafficModel.honda_default("nom", R=3.5)]
    sims = calibrated_grid(res, ["fifo", "quickscale"], traffics,
                           bin_s=10.0, restarts=4, steps=200)
    assert len(sims) == 2
    assert {s.twin.policy for s in sims} == {"fifo", "quickscale"}
    for s in sims:
        # run_grid is aggregate-mode by default now: scalars, no series
        assert np.isfinite(s.total_cost_usd) and s.processed_records > 0.0


# ---------------------------------------------------------------------------
# satellite regressions (this module always runs — the windtunnel module
# skips without hypothesis)
# ---------------------------------------------------------------------------

def test_datagen_seed_is_process_stable():
    """The rng seed must not depend on PYTHONHASHSEED: zlib.crc32 of the
    (schema, seed) pair replaces the salted str hash. Pinned values guard
    against silent reseeding."""
    from repro.core.datagen import DataGenerator
    from repro.core.schema import telemetry_schema

    ds = DataGenerator(seed=1).generate(telemetry_schema(), 8)
    np.testing.assert_allclose(
        ds.columns["speed_kph"][:4],
        np.array([84.70726, 184.9455, 9.820917, 49.265144], np.float32),
        rtol=1e-6)
    ds2 = DataGenerator(seed=1).generate(telemetry_schema(), 8)
    np.testing.assert_array_equal(ds.columns["speed_kph"],
                                  ds2.columns["speed_kph"])


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_loadpattern_trapezoid_fallback():
    """records_between works through the numpy<2.0 np.trapz fallback."""
    import repro.core.loadpattern as lp_mod

    lp = LoadPattern.ramp("r", duration_s=120, peak_rate=40)
    want = lp.records_between(0.0, 120.0)
    assert want == pytest.approx(2400.0, rel=1e-6)
    calls = []
    orig = lp_mod._trapezoid

    def counting(ys, xs):
        calls.append(1)
        return orig(ys, xs)

    lp_mod._trapezoid = counting
    try:
        assert lp.records_between(0.0, 120.0) == pytest.approx(want)
        assert calls        # the shim really is the integration path
        # np.trapz (the <2.0 spelling) gives the same integral
        if hasattr(np, "trapz"):
            lp_mod._trapezoid = np.trapz
            assert lp.records_between(0.0, 120.0) == pytest.approx(
                want, rel=1e-9)
    finally:
        lp_mod._trapezoid = orig


# ---------------------------------------------------------------------------
# registry metadata
# ---------------------------------------------------------------------------

def test_policies_declare_calibration_metadata():
    for name in policy_names():
        spec = policy_spec(name)
        for pname in spec.param_names:
            lo, hi = spec.bound(pname)
            assert lo < hi
        assert set(spec.frozen) <= set(spec.param_names)
        assert set(spec.log_params) <= set(spec.param_names)


def test_fit_warns_when_warm_start_outside_bounds():
    """A measured pipeline faster than the calibration box must not be
    clamped silently."""
    tr = ObservedTrace.from_loadpattern(
        LoadPattern.steady("s", 1800.0, 3.0), FIFO_TRUTH, bin_s=300.0)
    giant = SimpleTwin("g", 2000.0, 0.05, 0.2)   # max_rps box tops at 1e3
    with pytest.warns(UserWarning) as warned:
        fit(tr, "fifo", restarts=2, steps=5, seed=0, init=giant)
    messages = [str(w.message) for w in warned]
    assert any("outside the calibration bounds" in m for m in messages)
    # ...and the resulting edge-pinned fit is flagged, not silent
    assert any("pinned" in m for m in messages)


def test_fit_spec_freeze_and_fixed_values():
    spec = fit_spec("autoscale",
                    fixed_values={"min_instances": 2.0,
                                  "max_instances": 8.0})
    assert spec.free_names == ("max_rps", "usd_per_hour", "base_latency_s",
                               "scale_up_hours")
    assert spec.fixed[3] == 2.0 and spec.fixed[4] == 8.0
    spec2 = fit_spec("autoscale", unfreeze=("max_instances",),
                     fixed_values={"min_instances": 1.0})
    assert "max_instances" in spec2.free_names
    spec3 = fit_spec("fifo", freeze=("usd_per_hour",),
                     fixed_values={"usd_per_hour": 0.01})
    assert "usd_per_hour" not in spec3.free_names
    with pytest.raises(KeyError):
        fit_spec("fifo", freeze=("bogus",))
    with pytest.raises(ValueError):
        fit_spec("fifo", init=SHED_TRUTH)


# ---------------------------------------------------------------------------
# OTel span importer (ROADMAP "Trace importers": smallest useful slice)
# ---------------------------------------------------------------------------

def test_from_otel_spans_bins_arrivals_completions_and_errors():
    spans = [
        {"start": 1000.0, "end": 1002.0},                    # bin 0 -> 0
        {"start": 1001.0, "end": 1065.0, "records": 3},      # bin 0 -> 1
        {"start": 1070.0, "end": 1075.0, "status": "ERROR"},  # bin 1, drop
        {"start": 1130.0, "end": 1150.0, "records": 2},      # bin 2 -> 2
    ]
    tr = ObservedTrace.from_otel_spans(spans, bin_seconds=60.0, name="t",
                                       usd_per_hour=0.36)
    assert tr.num_bins == 3 and tr.bin_hours == pytest.approx(1 / 60.0)
    np.testing.assert_allclose(tr.arrivals, [4.0, 1.0, 2.0])
    np.testing.assert_allclose(tr.processed, [1.0, 3.0, 2.0])
    np.testing.assert_allclose(tr.dropped, [0.0, 1.0, 0.0])
    # record-weighted: bin1 = 64s (3 records), bin2 = 20s (2 records)
    np.testing.assert_allclose(tr.latency_s, [2.0, 64.0, 20.0])
    np.testing.assert_allclose(tr.cost_usd, 0.36 / 60.0)


def test_from_otel_spans_otlp_field_names_and_status_codes():
    ns = 1e9
    # every OTLP status encoding an export can produce: numeric code,
    # protobuf-JSON enum NAME in the dict, and bare strings
    for status in ({"code": 2}, {"code": "STATUS_CODE_ERROR"}, "ERROR",
                   "STATUS_CODE_ERROR", 2, "2"):
        spans = [
            {"start_time_unix_nano": 5_000 * ns,
             "end_time_unix_nano": 5_010 * ns},
            {"start_time_unix_nano": 5_020 * ns,
             "end_time_unix_nano": 5_030 * ns, "status": status},
        ]
        tr = ObservedTrace.from_otel_spans(spans, bin_seconds=30.0)
        np.testing.assert_allclose(tr.arrivals, [2.0])
        np.testing.assert_allclose(tr.processed, [1.0], err_msg=str(status))
        np.testing.assert_allclose(tr.dropped, [1.0], err_msg=str(status))
    # and OK forms stay processed
    for status in ("OK", {"code": 0}, {"code": "STATUS_CODE_OK"}, 0):
        tr = ObservedTrace.from_otel_spans(
            [{"start": 0.0, "end": 1.0, "status": status}], bin_seconds=30.0)
        np.testing.assert_allclose(tr.processed, [1.0], err_msg=str(status))
        np.testing.assert_allclose(tr.dropped, [0.0], err_msg=str(status))


def test_from_otel_spans_feeds_calibration():
    """The importer's trace drops straight into repro.calibrate.fit."""
    rng = np.random.default_rng(3)
    spans = []
    t = 0.0
    for _ in range(400):
        t += float(rng.exponential(2.0))
        spans.append({"start": t, "end": t + float(rng.uniform(0.2, 1.0))})
    tr = ObservedTrace.from_otel_spans(spans, bin_seconds=120.0)
    res = fit(tr, "fifo", restarts=4, steps=60, seed=0,
              weights={"cost": 0.0})      # no cost telemetry in the spans
    assert np.isfinite(res.loss)
    assert res.twin.policy == "fifo"


def test_from_otel_spans_rejects_bad_input():
    with pytest.raises(ValueError):
        ObservedTrace.from_otel_spans([])
    with pytest.raises(KeyError):
        ObservedTrace.from_otel_spans([{"end": 1.0}])
    with pytest.raises(ValueError):
        ObservedTrace.from_otel_spans([{"start": 2.0, "end": 1.0}])

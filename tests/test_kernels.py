"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_kernel import rwkv6
from repro.kernels.ssm_scan import ssm

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kh,d", [
    (2, 256, 4, 2, 64),       # GQA
    (1, 512, 8, 8, 64),       # MHA
    (2, 128, 4, 1, 32),       # MQA
    (1, 384, 6, 2, 128),      # non-pow2 blocks (384 = 3*128)
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, s, h, kh, d, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.sdpa(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_sdpa_blocked_matches_exact():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 512, 4, 64))
    k = jax.random.normal(ks[1], (2, 512, 2, 64))
    v = jax.random.normal(ks[2], (2, 512, 2, 64))
    for causal in (True, False):
        out = ref.sdpa_blocked(q, k, v, causal=causal, chunk=128)
        want = ref.sdpa(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)


def test_sdpa_kv_len_masking():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 4, 32))
    v = jax.random.normal(ks[2], (2, 64, 4, 32))
    kv_len = jnp.array([5, 64], jnp.int32)
    out = ref.sdpa(q, k, v, causal=False, kv_len=kv_len)
    # element 0 must equal attention over the first 5 kv entries only
    want0 = ref.sdpa(q[:1], k[:1, :5], v[:1, :5], causal=False)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want0[0]),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,n,chunk", [
    (2, 64, 2, 16, 16), (1, 128, 4, 32, 32), (2, 96, 2, 64, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_kernel_matches_ref(b, s, h, n, chunk, dtype):
    ks = jax.random.split(KEY, 6)
    r = (jax.random.normal(ks[0], (b, s, h, n)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, s, h, n)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (b, s, h, n)) * 0.5).astype(dtype)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, n)) * 0.5 - 0.5)
                ).astype(dtype)
    u = (jax.random.normal(ks[4], (h, n)) * 0.3).astype(jnp.float32)
    st = jax.random.normal(ks[5], (b, h, n, n)) * 0.1
    out, sT = rwkv6(r, k, v, w, u, st, chunk=chunk, interpret=True)
    want, wantS = ref.rwkv6_scan(r, k, v, w, u, st)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=0.1)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(wantS),
                               atol=tol, rtol=0.1)


def test_rwkv6_extreme_decay_stays_finite():
    ks = jax.random.split(KEY, 2)
    shape = (1, 128, 4, 32)
    r = jax.random.normal(ks[0], shape) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[1], shape)))   # adversarial
    out, sT = rwkv6(r, r + 0.1, r - 0.2, w, jnp.zeros((4, 32)), None,
                    interpret=True)
    want, _ = ref.rwkv6_scan(r, r + 0.1, r - 0.2, w, jnp.zeros((4, 32)), None)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=5e-3, rtol=0.05)


def test_rwkv6_chunk_equals_statefeed():
    """Processing two halves with state carry == one pass (associativity)."""
    ks = jax.random.split(KEY, 5)
    shape = (1, 64, 2, 16)
    r, k, v = (jax.random.normal(ks[i], shape) * 0.5 for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], shape) * 0.5))
    u = jax.random.normal(ks[4], (2, 16)) * 0.3
    full, sF = rwkv6(r, k, v, w, u, None, interpret=True)
    h1, s1 = rwkv6(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u, None,
                   interpret=True)
    h2, s2 = rwkv6(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u, s1,
                   interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=1e-4, rtol=0.05)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sF), atol=1e-4,
                               rtol=0.05)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,di,n", [(2, 128, 256, 16), (1, 64, 128, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_kernel_matches_ref(b, s, di, n, dtype):
    ks = jax.random.split(KEY, 6)
    x = (jax.random.normal(ks[0], (b, s, di)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) - 1).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.5)
    B = (jax.random.normal(ks[3], (b, s, n)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[4], (b, s, n)) * 0.5).astype(dtype)
    D = jax.random.normal(ks[5], (di,))
    st = jnp.zeros((b, di, n))
    y, sT = ssm(x, dt, A, B, C, D, st, chunk=32, d_block=64, interpret=True)
    want, wantS = ref.ssm_scan(x, dt, A, B, C, D, st)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=0.1)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(wantS), atol=tol,
                               rtol=0.1)


def test_ssm_state_carry_associativity():
    ks = jax.random.split(KEY, 6)
    b, s, di, n = 1, 64, 64, 8
    x = jax.random.normal(ks[0], (b, s, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, n)) * 0.5
    D = jax.random.normal(ks[5], (di,))
    full, sF = ssm(x, dt, A, B, C, D, None, chunk=16, d_block=32, interpret=True)
    h1, s1 = ssm(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32], D, None,
                 chunk=16, d_block=32, interpret=True)
    h2, s2 = ssm(x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:], D, s1,
                 chunk=16, d_block=32, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sF), atol=1e-5,
                               rtol=1e-4)

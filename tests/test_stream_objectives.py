"""Streamed gradient objectives vs their materialized references.

Acceptance contract of the streaming-objective rework (search/calibrate
losses folded into the scan carry, ``kernels.ops.policy_scan_fold``):

* ``lane_objective(stream=True)`` is BIT-IDENTICAL to ``stream=False``
  — objective, annual cost, and met-fraction — for all five registered
  policies, both SLO modes, benign and fault (chance-constrained) lanes;
* its ``jax.grad`` matches grad of the materialized path within the
  repo's guarded 1e-5 relative contract (``tests/test_policy_vjp.py``);
* the streamed gradient jaxpr holds NO [L, T] intermediate — neither
  the forward value nor the checkpointed backward stages a full series;
* ``calibrate.lane_series_loss`` obeys the same bitwise + gradient
  contract against its materialized reference;
* the raw fold dispatch covers both selector forms (mixed one-hot grid
  and uniform traced index) and the fault layer, with operand
  cotangents (``ops_lane``) included;
* ``search(devices=D)`` is bit-identical to the unsharded dispatch and
  ``fit(devices=D)`` matches to a few ulps (CPU SPMD FMA contraction —
  see ``calibrate.fit._sharded_fit_fn``); a restart count that doesn't
  divide D falls back to replication with the shared warn-once
  RuntimeWarning; invalid ``devices=`` values raise;
* the search kernel's aux diagnostics ride the optimizer scan's carry
  (``per_restart == history[-1]`` — no redundant full-horizon forward).

Multi-device cases need
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import faults  # noqa: E402
from repro.calibrate.objective import lane_series_loss  # noqa: E402
from repro.calibrate.trace import ObservedTrace, SERIES_KEYS  # noqa: E402
from repro.core.loadpattern import LoadPattern  # noqa: E402
from repro.core.slo import SLO  # noqa: E402
from repro.core.traffic import TrafficModel  # noqa: E402
from repro.core.twin import (AGG_SLO_DROP_RATE, AGG_SLO_LATENCY,  # noqa: E402
                             QuickscalingTwin, SimpleTwin, make_twin,
                             policy_onehot)
from repro.distributed import sharding  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.search.objective import lane_objective, lane_objective_t  # noqa: E402
from repro.search.optimize import search  # noqa: E402
from repro.search.space import search_space  # noqa: E402

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "before the first jax import")

ALL_POLICY_TWINS = [
    SimpleTwin("fifo", 1.9512, 0.0082, 0.15),
    QuickscalingTwin("quick", 1.9512, 0.0082, 0.15),
    make_twin("auto", "autoscale", max_rps=0.5, usd_per_hour=0.002,
              base_latency_s=0.1, max_instances=32, scale_up_hours=3),
    make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.0082,
              base_latency_s=0.15, queue_cap_hours=2),
    make_twin("batch", "batch_window", max_rps=6.15, usd_per_hour=0.0703,
              base_latency_s=0.06, window_hours=6),
]


def _assert_grads_close(a, b, rtol=1e-5, floor=1e-6, what=""):
    """The repo's guarded 1e-5 relative contract, plus an absolute floor
    at ``floor`` of the gradient scale: a slot whose reference gradient
    is an exact 0 (saturated hinge gates) may carry f32
    accumulation-order noise in the other path — noise, not
    disagreement. Fault-path callers raise ``floor``: an outage
    reconnect flood amplifies some gradient slots to ~1e8, and the
    O(sqrt(T)) backward's segment replays recompute carries that differ
    from the taped ones at f32 ulp level, so those slots wobble at the
    scale's noise floor rather than their own."""
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    scale = max(np.abs(b).max(), 1.0)
    rel = np.abs(a - b) / np.maximum(np.abs(b), floor * scale)
    ok = (rel <= rtol) | (np.abs(a - b) <= floor * scale)
    assert ok.all(), (what, rel.max())


def _lanes(twin, n=3, t_bins=97, seed=0):
    rng = np.random.default_rng(seed)
    hl = TrafficModel.honda_default("nom").hourly_loads()[:t_bins]
    loads = np.stack([hl * (1.0 + 0.2 * i) for i in range(n)]) \
        .astype(np.float32)
    params = jnp.asarray(
        np.tile(twin.padded_params().astype(np.float32), (n, 1))
        * rng.uniform(0.9, 1.1, (n, 6)).astype(np.float32))
    return params, loads


def _obj_args(twin, slo_mode, n=3):
    limit = 2 * 3600.0 if slo_mode == AGG_SLO_LATENCY else 0.05
    slo_lane = np.full((n,), limit, np.float32)
    return (1.0, jnp.int32(twin.policy_index), slo_lane, slo_mode,
            0.95, 100.0, 50.0, 1.2)


# ---------------------------------------------------------------------------
# search objective: streamed == materialized, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("slo_mode", [AGG_SLO_LATENCY, AGG_SLO_DROP_RATE])
@pytest.mark.parametrize("twin", ALL_POLICY_TWINS, ids=lambda tw: tw.policy)
def test_lane_objective_stream_bitwise(twin, slo_mode):
    params, loads = _lanes(twin)
    dt, pidx, slo_lane, mode, met, pw, ps, hs = _obj_args(twin, slo_mode)
    o_s, (c_s, f_s) = lane_objective(params, loads, dt, pidx, slo_lane,
                                     mode, met, pw, ps, hs, stream=True)
    o_m, (c_m, f_m) = lane_objective(params, loads, dt, pidx, slo_lane,
                                     mode, met, pw, ps, hs, stream=False)
    np.testing.assert_array_equal(np.asarray(o_s), np.asarray(o_m))
    np.testing.assert_array_equal(np.asarray(c_s), np.asarray(c_m))
    np.testing.assert_array_equal(np.asarray(f_s), np.asarray(f_m))


@pytest.mark.parametrize("slo_mode", [AGG_SLO_LATENCY, AGG_SLO_DROP_RATE])
@pytest.mark.parametrize("twin", ALL_POLICY_TWINS, ids=lambda tw: tw.policy)
def test_lane_objective_stream_grads(twin, slo_mode):
    params, loads = _lanes(twin)
    rest = _obj_args(twin, slo_mode)

    def loss(p, stream):
        return lane_objective(p, loads, *rest, stream=stream)[0].sum()

    g_s = jax.grad(lambda p: loss(p, True))(params)
    g_m = jax.grad(lambda p: loss(p, False))(params)
    _assert_grads_close(g_s, g_m, what=f"{twin.policy}/mode{slo_mode}")


def test_fault_lanes_stream_bitwise_and_grads():
    """Chance-constrained lanes (caps riding the scan) stream too — the
    fault path's first O(sqrt(T)) backward."""
    twin = ALL_POLICY_TWINS[2]          # autoscale: every series active
    t_bins, n_fut = 97, 4
    sched = faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=40),
               faults.disconnect(disconnect_frac=(0.2, 0.5))),
        n_futures=n_fut, seed=3)
    sampled = faults.sample_futures(sched, t_bins, 1.0)
    caps = np.asarray(sampled.cap, np.float32)          # [F, T]
    params, loads = _lanes(twin, n=n_fut)
    loads = np.broadcast_to(loads[:1], (n_fut, t_bins)).copy()
    rest = _obj_args(twin, AGG_SLO_LATENCY, n=n_fut)

    o_s, (c_s, f_s) = lane_objective(params, loads, *rest,
                                     caps_block=caps, stream=True)
    o_m, (c_m, f_m) = lane_objective(params, loads, *rest,
                                     caps_block=caps, stream=False)
    np.testing.assert_array_equal(np.asarray(o_s), np.asarray(o_m))
    np.testing.assert_array_equal(np.asarray(c_s), np.asarray(c_m))
    np.testing.assert_array_equal(np.asarray(f_s), np.asarray(f_m))

    def loss(p, stream):
        return lane_objective(p, loads, *rest, caps_block=caps,
                              stream=stream)[0].sum()

    # floor=1e-5: the reconnect flood drives slots to ~1e8, and segment
    # replay vs full tape puts ulp-level carry wobble under them
    _assert_grads_close(jax.grad(lambda p: loss(p, True))(params),
                        jax.grad(lambda p: loss(p, False))(params),
                        floor=1e-5, what="fault lanes")


# ---------------------------------------------------------------------------
# no [L, T] intermediate anywhere in the streamed gradient program
# ---------------------------------------------------------------------------

def _collect_shapes(jaxpr, out):
    """Every intermediate/output aval shape in the jaxpr, recursively."""
    from jax._src import core as jcore
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v.aval, "shape"):
                out.add(tuple(v.aval.shape))
        for p in eqn.params.values():
            cj = getattr(p, "jaxpr", None)
            if isinstance(p, jcore.ClosedJaxpr):
                _collect_shapes(p.jaxpr, out)
            elif cj is not None:
                _collect_shapes(cj, out)
    return out


def test_streamed_grad_jaxpr_has_no_lane_major_series():
    """The whole grad program is scenario-minor: no [L, T] array exists
    in either direction (the [T, L] inputs are the only full-horizon
    operands, and the checkpointed backward stages O(sqrt(T)) segments)."""
    twin = ALL_POLICY_TWINS[2]
    n, t_bins = 3, 256
    params, loads = _lanes(twin, n=n, t_bins=t_bins)
    loads_t = jnp.asarray(np.ascontiguousarray(loads.T))
    dt, pidx, slo_lane, mode, met, pw, ps, hs = _obj_args(
        twin, AGG_SLO_LATENCY, n=n)

    def loss(p):
        return lane_objective_t(p, loads_t, dt, pidx, slo_lane, mode,
                                met, pw, ps, hs)[0].sum()

    jaxpr = jax.make_jaxpr(jax.grad(loss))(params)
    shapes = _collect_shapes(jaxpr.jaxpr, set())
    assert (n, t_bins) not in shapes, "a lane-major [L, T] series is staged"


# ---------------------------------------------------------------------------
# the raw fold dispatch: both selector forms, operand cotangents
# ---------------------------------------------------------------------------

def _sum_fold_init(n):
    return (jnp.zeros((n,), jnp.float32),)


def _sum_fold(acc, arrive, outs, ops_lane, xs_row):
    proc, _queue, lat, cost, drop = outs
    (w,) = ops_lane
    (s,) = acc
    return (s + w * proc + 0.3 * lat + 1.1 * cost + 0.7 * drop
            + 0.1 * arrive + xs_row[0],)


@pytest.mark.parametrize("use_caps", [False, True])
def test_fold_mixed_onehot_matches_materialized(use_caps):
    """policy_scan_fold with the mixed one-hot selector (and the fault
    layer riding along): value bitwise vs folding the materialized
    series, gradients within the guard — params, onehot, AND the
    per-lane ``ops_lane`` operand."""
    n, t_bins = 5, 97
    rng = np.random.default_rng(2)
    loads = rng.uniform(0.2, 3.0, (n, t_bins)).astype(np.float32)
    params = jnp.asarray(np.stack(
        [tw.padded_params() for tw in ALL_POLICY_TWINS]).astype(np.float32))
    onehot = jnp.asarray(np.asarray(policy_onehot(
        np.asarray([tw.policy_index for tw in ALL_POLICY_TWINS],
                   np.int32)), np.float32))
    w_lane = jnp.asarray(rng.uniform(0.5, 1.5, (n,)).astype(np.float32))
    xs = (jnp.asarray(rng.uniform(0, 1, (t_bins,)).astype(np.float32)),)
    caps = (jnp.asarray(rng.choice([0.0, 1.0], (n, t_bins), p=[0.1, 0.9])
                        .astype(np.float32)) if use_caps else None)

    def streamed(p, oh, w):
        carry, (acc,) = ops.policy_scan_fold(
            loads, p, oh, 1.0, caps=caps, fold_init=_sum_fold_init,
            fold_step=_sum_fold, ops_lane=(w,), xs=xs)
        return carry, acc

    def materialized(p, oh, w):
        carry, outs = ops.policy_scan(loads, p, oh, 1.0,
                                      differentiable=True, caps=caps)
        outs_t = tuple(s.T for s in outs)

        def fold(a, row):
            loads_row, outs_row, xs_row = row
            return _sum_fold(a, loads_row, outs_row, (w,), xs_row), None

        (acc,), _ = jax.lax.scan(fold, _sum_fold_init(n),
                                 (jnp.asarray(loads.T), outs_t, xs))
        return carry, acc

    c_s, a_s = streamed(params, onehot, w_lane)
    c_m, a_m = materialized(params, onehot, w_lane)
    if use_caps:
        # the fault layer under the masked one-hot blend is a mul+add
        # chain whose FMA contraction varies with fusion context on CPU,
        # so the fused fold and the materialize-then-fold programs may
        # differ by a few ulps per bin. The uniform-index form — what
        # search/calibrate actually dispatch — has no blend and is
        # pinned bitwise in test_fault_lanes_stream_bitwise_and_grads.
        np.testing.assert_allclose(np.asarray(c_s), np.asarray(c_m),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(a_s), np.asarray(a_m),
                                   rtol=1e-6)
    else:
        np.testing.assert_array_equal(np.asarray(c_s), np.asarray(c_m))
        np.testing.assert_array_equal(np.asarray(a_s), np.asarray(a_m))

    def loss(fn):
        return lambda p, oh, w: fn(p, oh, w)[1].sum() + fn(p, oh, w)[0].sum()

    g_s = jax.grad(loss(streamed), argnums=(0, 1, 2))(params, onehot, w_lane)
    g_m = jax.grad(loss(materialized), argnums=(0, 1, 2))(
        params, onehot, w_lane)
    for got, want, what in zip(g_s, g_m, ("params", "onehot", "ops_lane")):
        _assert_grads_close(got, want, what=f"{what} caps={use_caps}")


# ---------------------------------------------------------------------------
# calibrate loss: streamed == materialized
# ---------------------------------------------------------------------------

def _cal_problem(policy_twin, seed=0):
    ramp = LoadPattern.ramp("ramp", duration_s=6 * 3600, peak_rate=6.0)
    tr = ObservedTrace.from_loadpattern(ramp, policy_twin, bin_s=300.0)
    arrivals = jnp.asarray(np.asarray(tr.arrivals, np.float32))
    targets = {k: jnp.asarray(np.asarray(v, np.float32))
               for k, v in tr.series().items()}
    scales = {k: jnp.float32(v) for k, v in tr.scales().items()}
    w = {k: jnp.float32(1.0) for k in SERIES_KEYS}
    rng = np.random.default_rng(seed)
    pb = jnp.asarray(
        np.tile(policy_twin.padded_params().astype(np.float32), (4, 1))
        * rng.uniform(0.8, 1.2, (4, 6)).astype(np.float32))
    return (tr, pb, arrivals, targets, scales, w,
            jnp.int32(policy_twin.policy_index), float(tr.bin_hours))


@pytest.mark.parametrize("twin", [ALL_POLICY_TWINS[0], ALL_POLICY_TWINS[2],
                                  ALL_POLICY_TWINS[3]],
                         ids=lambda tw: tw.policy)
def test_lane_series_loss_stream_bitwise_and_grads(twin):
    _, pb, arrivals, targets, scales, w, pidx, dt = _cal_problem(twin)
    l_s = lane_series_loss(pb, arrivals, targets, scales, w, pidx, dt,
                           stream=True)
    l_m = lane_series_loss(pb, arrivals, targets, scales, w, pidx, dt,
                           stream=False)
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_m))

    def loss(p, stream):
        return lane_series_loss(p, arrivals, targets, scales, w, pidx, dt,
                                stream=stream).sum()

    _assert_grads_close(jax.grad(lambda p: loss(p, True))(pb),
                        jax.grad(lambda p: loss(p, False))(pb),
                        what=twin.policy)


# ---------------------------------------------------------------------------
# device-mesh sharding: bit parity, fallback, validation
# ---------------------------------------------------------------------------

def _small_search(devices=None, restarts=4):
    base = make_twin("auto", "autoscale", max_rps=1.9512,
                     usd_per_hour=0.0082, base_latency_s=0.15,
                     max_instances=8, scale_up_hours=2)
    tm = TrafficModel.honda_default("high(+40%)", R=3.5, G=1.4)
    slo = SLO(limit_s=2 * 3600, met_fraction=0.95)
    space = search_space(base, ("max_instances", "scale_up_hours"))
    return search(space, [tm], slo, restarts=restarts, steps=8, seed=0,
                  coarsen=8, devices=devices)


@needs4
def test_search_devices_bit_parity():
    r1 = _small_search(devices=None)
    r4 = _small_search(devices=4)
    np.testing.assert_array_equal(r1.restart_params, r4.restart_params)
    np.testing.assert_array_equal(r1.history, r4.history)
    assert r1.cost_usd == r4.cost_usd
    assert r1.best_restart == r4.best_restart


@needs4
def test_fit_devices_parity():
    """Sharded fit == unsharded fit to a few ulps. Not pinned bitwise:
    with the replicated trace operands passed as shard_map arguments,
    XLA CPU's SPMD recompilation contracts the fused log-residual
    mul+add chains differently at width-1 shards (the same
    FMA-contraction wobble the mixed one-hot fold documents — baking
    the operands in as constants restores bitwise equality, at the cost
    of a recompile per trace). The lanes' arithmetic is identical by
    construction; AdamW amplifies the ulps across steps, hence rtol
    rather than equality on the histories."""
    from repro.calibrate.fit import fit
    twin = ALL_POLICY_TWINS[3]
    tr, *_ = _cal_problem(twin)
    r1 = fit(tr, twin.policy, restarts=4, steps=20, seed=0)
    r4 = fit(tr, twin.policy, restarts=4, steps=20, seed=0, devices=4)
    np.testing.assert_allclose(r1.loss_history, r4.loss_history,
                               rtol=2e-6)
    np.testing.assert_allclose(r1.start_losses, r4.start_losses,
                               rtol=2e-6)
    np.testing.assert_allclose(r1.start_params, r4.start_params,
                               rtol=2e-5)
    assert r1.best_start == r4.best_start
    np.testing.assert_allclose(r1.loss, r4.loss, rtol=2e-6)


@needs4
def test_search_devices_replication_fallback_warns_once():
    sharding._REPLICATION_WARNED.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        r3 = _small_search(devices=3, restarts=4)   # 4 % 3 != 0
    msgs = [w for w in rec if issubclass(w.category, RuntimeWarning)
            and "replication" in str(w.message)]
    assert len(msgs) == 1
    r1 = _small_search(devices=None, restarts=4)
    np.testing.assert_array_equal(r1.restart_params, r3.restart_params)
    np.testing.assert_array_equal(r1.history, r3.history)


def test_devices_validation_raises():
    with pytest.raises(ValueError, match="positive"):
        _small_search(devices=-2)
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        _small_search(devices=jax.device_count() + 1)
    from repro.calibrate.fit import fit
    twin = ALL_POLICY_TWINS[3]
    tr, *_ = _cal_problem(twin)
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        fit(tr, twin.policy, restarts=4, steps=2, seed=0,
            devices=jax.device_count() + 1)


# ---------------------------------------------------------------------------
# the aux-carry satellite: diagnostics ride the scan, no extra forward
# ---------------------------------------------------------------------------

def test_search_kernel_aux_rides_the_scan_carry():
    """The kernel's per-restart objective diagnostics are the LAST
    in-loop gradient evaluation — history[-1] — not a separate
    full-horizon forward on z_fin."""
    import dataclasses

    from repro.config import OptimizerConfig
    from repro.core.twin import registry_version
    from repro.search.objective import annual_scale
    from repro.search.optimize import (DEFAULT_SEARCH_OPT, _norm_weights,
                                       _search_kernel)

    base = make_twin("auto", "autoscale", max_rps=1.9512,
                     usd_per_hour=0.0082, base_latency_s=0.15,
                     max_instances=8, scale_up_hours=2)
    space = search_space(base, ("max_instances", "scale_up_hours"))
    loads = TrafficModel.honda_default("nom").hourly_loads()[:97] \
        .astype(np.float32)[None]
    steps, k = 6, 3
    ocfg = dataclasses.replace(DEFAULT_SEARCH_OPT, total_steps=steps)
    # stream=True: pin the aux-carry contract on the streamed objective
    # path (the size-adaptive _run_kernel would vectorize a problem this
    # small, but the carry plumbing is shared by both paths)
    statics = (steps, 1, 1, 1.0, int(AGG_SLO_LATENCY),
               bool(space.needs_surrogate), registry_version(), ocfg,
               True)
    z0 = space.z0(k, seed=0)
    operands = (jnp.asarray(z0),
                jnp.asarray(np.ascontiguousarray(loads.T)),
                jnp.asarray(_norm_weights(None, 1)),
                jnp.asarray(space.lo), jnp.asarray(space.hi),
                jnp.asarray(space.log_mask), jnp.asarray(space.free_mask),
                jnp.asarray(space.fixed), jnp.asarray(space.tie_src),
                jnp.asarray(space.tie_coeff), jnp.int32(space.policy_index),
                jnp.asarray(np.full((k,), 2 * 3600.0, np.float32)),
                jnp.float32(0.95), jnp.float32(100.0), jnp.float32(50.0),
                jnp.float32(annual_scale(97, 1.0)))
    (_, _, per_restart, _, _, history) = _search_kernel(
        *statics, *operands, None, jnp.float32(1.0))
    np.testing.assert_array_equal(np.asarray(per_restart),
                                  np.asarray(history)[-1])

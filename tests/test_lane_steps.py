"""Property test: the two step forms of every policy are interchangeable.

The lane-vectorized refactor's safety net — for every registered policy,
random ``(carry, arrive, params, dt)`` blocks must give identical outputs
from the scalar ``lax.switch`` step and the branchless lane-vectorized
step (the registry asserts a fixed random block at registration; this
sweeps the space). Follows the repo's importorskip guard pattern:
hypothesis is optional, the module skips cleanly without it.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.twin import (CARRY_DIM, PARAM_DIM,  # noqa: E402
                             lane_policy_step, policy_branches,
                             policy_names, policy_onehot, policy_spec)

LANES = 4

finite = dict(allow_nan=False, allow_infinity=False, width=32)
carry_vals = st.floats(0.0, 1e5, **finite)
arrive_vals = st.floats(0.0, 1e5, **finite)
param_vals = st.floats(1e-3, 1e3, **finite)
dts = st.sampled_from([1.0, 0.25, 1.0 / 60.0, 1.0 / 3600.0])


def _block(draw_list, shape):
    return np.asarray(draw_list, np.float32).reshape(shape)


@st.composite
def lane_blocks(draw):
    carry = _block(draw(st.lists(carry_vals, min_size=LANES * CARRY_DIM,
                                 max_size=LANES * CARRY_DIM)),
                   (LANES, CARRY_DIM))
    arrive = _block(draw(st.lists(arrive_vals, min_size=LANES,
                                  max_size=LANES)), (LANES,))
    params = _block(draw(st.lists(param_vals, min_size=LANES * PARAM_DIM,
                                  max_size=LANES * PARAM_DIM)),
                    (LANES, PARAM_DIM))
    dt = draw(dts)
    return carry, arrive, params, dt


@pytest.mark.parametrize("policy", policy_names())
@given(block=lane_blocks())
@settings(max_examples=25, deadline=None)
def test_scalar_and_lane_steps_agree(policy, block):
    carry, arrive, params, dt = block
    spec = policy_spec(policy)
    dt = jnp.float32(dt)
    c_lane, o_lane = spec.lane_step(jnp.asarray(carry), jnp.asarray(arrive),
                                    jnp.asarray(params), dt)
    for lane in range(LANES):
        # the scalar form exactly as the XLA kernel dispatches it
        c_s, o_s = jax.lax.switch(spec.index, policy_branches(),
                                  jnp.asarray(carry[lane]),
                                  jnp.asarray(arrive[lane]),
                                  jnp.asarray(params[lane]), dt)
        np.testing.assert_allclose(np.asarray(c_lane[lane]),
                                   np.asarray(c_s), rtol=1e-6, atol=1e-6)
        for a, b in zip(o_lane, o_s):
            np.testing.assert_allclose(np.asarray(a[lane]), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)


@given(block=lane_blocks(),
       idx=st.lists(st.integers(0, len(policy_names()) - 1),
                    min_size=LANES, max_size=LANES))
@settings(max_examples=25, deadline=None)
def test_masked_blend_matches_switch(block, idx):
    """lane_policy_step (the Pallas kernel's bin-step) == per-lane switch."""
    carry, arrive, params, dt = block
    idx = np.asarray(idx, np.int32)
    dt = jnp.float32(dt)
    c_lane, o_lane = lane_policy_step(
        jnp.asarray(carry), jnp.asarray(arrive), jnp.asarray(params),
        jnp.asarray(policy_onehot(idx)), dt)
    for lane in range(LANES):
        c_s, o_s = jax.lax.switch(int(idx[lane]), policy_branches(),
                                  jnp.asarray(carry[lane]),
                                  jnp.asarray(arrive[lane]),
                                  jnp.asarray(params[lane]), dt)
        np.testing.assert_allclose(np.asarray(c_lane[lane]),
                                   np.asarray(c_s), rtol=1e-6, atol=1e-6)
        for a, b in zip(o_lane, o_s):
            np.testing.assert_allclose(np.asarray(a[lane]), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

"""Multi-pod features that need >1 device, exercised in a subprocess with 8
forced host devices so the main test session keeps 1 device:

* int8-compressed cross-pod gradient psum: numerics vs the f32 all-reduce
  (the full-train-step integration of `_pod_compressed_grads` mixes manual
  'pod' with auto in-pod axes, which the current XLA SPMD partitioner only
  supports with involuntary remat — it is wired behind
  ParallelConfig.grad_compression and documented as experimental until
  Shardy lands; the payload math is what this test pins down).
* the (pod, data, model) production mesh slicing a train step.
"""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.sharding import shard_map
from repro.optim.compression import quantize_int8

mesh = jax.make_mesh((8,), ("pod",))

def compressed_psum(g):
    from repro.optim.compression import block_absmax, quantize_int8_with_scale
    absmax = block_absmax(g.astype(jnp.float32), 64)
    scale = jax.lax.pmax(absmax, "pod") / 127.0
    q = quantize_int8_with_scale(g.astype(jnp.float32), scale, 64)
    qsum = jax.lax.psum(q.astype(jnp.int32), "pod")
    npods = jax.lax.psum(jnp.ones((), jnp.float32), "pod")
    deq = (qsum.astype(jnp.float32) * scale[:, None]).reshape(-1)
    return (deq[: g.size].reshape(g.shape) / npods)

def exact_psum(g):
    return jax.lax.pmean(g, "pod")

rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32))
run_c = jax.jit(shard_map(compressed_psum, mesh=mesh,
                          in_specs=P("pod"), out_specs=P("pod")))
run_e = jax.jit(shard_map(exact_psum, mesh=mesh,
                          in_specs=P("pod"), out_specs=P("pod")))
got, want = np.asarray(run_c(g)), np.asarray(run_e(g))
# error bounded by one int8 step of the max per-block scale
bound = np.abs(g).max() / 127.0 + 1e-6
err = np.abs(got - want).max()
assert err < bound, (err, bound)
# compressed payload is 4x smaller than f32 (int8 + scales)
payload_f32 = g.size * 4
payload_int8 = g.size * 1 + (g.size // 64) * 4
assert payload_int8 < 0.3 * payload_f32
print("COMPRESS_ERR", float(err), "BOUND", float(bound))

# (pod, data, model) mesh slices a real train step
from repro.config import OptimizerConfig, ParallelConfig
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.optim.adamw import abstract_opt_state, init_opt_state
from repro.train.steps import make_train_step

cfg = get_smoke_config("llama3.2-1b")
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
batch_abs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "loss_mask": jax.ShapeDtypeStruct((8, 32), jnp.float32)}
ocfg = OptimizerConfig(total_steps=4, warmup_steps=1)
step, _ = make_train_step(cfg, ocfg, ParallelConfig(), mesh3, batch_abs,
                          donate=False)
params = M.init_params(cfg, jax.random.PRNGKey(0))
opt = init_opt_state(params, ocfg)
batch = {"tokens": jnp.zeros((8, 32), jnp.int32) + 5,
         "loss_mask": jnp.ones((8, 32), jnp.float32)}
with mesh3:
    p2, o2, m = step(params, opt, batch)
assert np.isfinite(float(m["loss"]))
print("MULTIPOD_OK")
"""


def test_multipod_compression_and_mesh():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MULTIPOD_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]

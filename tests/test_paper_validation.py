"""Faithful-reproduction checks against the paper's published numbers.

Table II (six simulations), the cpu-limited backlogs (406 / 611 days), the
cost formula (rate x 8736h + backlog), and the traffic-model anchors. The
month/hour factors are synthesized to the published constraints (the raw
168-entry table is unpublished), so value tolerances are documented per
check; the SLO *pattern* must match exactly.
"""
import numpy as np
import pytest

from repro.core.slo import SLO
from repro.core.traffic import HOURS_PER_YEAR, TrafficModel
from repro.core.twin import SimpleTwin
from repro.core.whatif import run_grid

# paper Table I twin parameters (cents/hr -> USD/hr; max rec/s refined from
# Table II's published max throughput: 7024.39 rec/h = 1.9512 rec/s etc.)
TWINS = [
    SimpleTwin("block", 1.9512, 0.0082, 0.15),
    SimpleTwin("non-block", 6.15, 0.0703, 0.06),
    SimpleTwin("cpu-lim", 0.6612, 0.0027, 0.29),
]
SLO_4H = SLO(limit_s=4 * 3600, met_fraction=0.95)

PAPER_TABLE2 = {  # run -> (cost_usd, slo_met, backlog_s)
    "nom block": (71.87, True, 6690.64),
    "nom non-block": (614.19, True, 0.0),
    "nom cpu-lim": (50.56, False, 35130437.72),
    "high block": (74.71, False, 1247902.13),
    "high non-block": (614.19, True, 0.0),
    "high cpu-lim": (63.98, False, 52813607.51),
}


@pytest.fixture(scope="module")
def sims():
    nom = TrafficModel.honda_default("nom", R=3.5, G=1.0)
    high = TrafficModel.honda_default("high", R=3.5, G=1.5)
    return {s.name: s for s in run_grid(TWINS, [nom, high], slo=SLO_4H)}


def test_traffic_mean_anchor():
    loads = TrafficModel.honda_default("nom").hourly_loads()
    assert abs(loads.mean() - 5035.8) / 5035.8 < 1e-3      # Table II mean


def test_traffic_peak_anchor():
    loads = TrafficModel.honda_default("nom").hourly_loads()
    # Table II: peak nominal load = 13191.79 rec/h (max non-block thruput);
    # synthesized factors land within 10%
    assert abs(loads.max() - 13191.79) / 13191.79 < 0.10


def test_growth_multiplier():
    nom = TrafficModel.honda_default("nom", G=1.0).hourly_loads()
    high = TrafficModel.honda_default("high", G=1.5).hourly_loads()
    ratio = high[-168:].sum() / nom[-168:].sum()
    assert abs(ratio - 1.5) < 0.01          # +50% by year end
    assert abs(high[:168].sum() / nom[:168].sum() - 1.0) < 0.01


def test_slo_pattern_matches_paper_exactly(sims):
    for run, (_, want_met, _) in PAPER_TABLE2.items():
        assert sims[run].slo_met == want_met, run


def test_costs_within_tolerance(sims):
    for run, (want_cost, _, _) in PAPER_TABLE2.items():
        got = sims[run].total_cost_usd
        assert abs(got - want_cost) / want_cost < 0.05, (run, got, want_cost)


def test_cpu_limited_backlogs(sims):
    # 406 days nominal / 611 days high (paper Sec. VII-B)
    nom_days = sims["nom cpu-lim"].backlog_s / 86400
    high_days = sims["high cpu-lim"].backlog_s / 86400
    assert abs(nom_days - 406) < 8, nom_days
    assert abs(high_days - 611) < 15, high_days


def test_throughput_caps_match_table2(sims):
    # saturated pipelines peak at capacity; unsaturated at peak load
    assert abs(sims["nom block"].max_throughput_rph - 7024.39) < 1.0
    assert abs(sims["nom cpu-lim"].max_throughput_rph - 2380.17) < 1.0
    assert sims["nom non-block"].max_throughput_rph < 6.15 * 3600


def test_cost_formula_rate_times_hours(sims):
    # paper-implied: cost = rate x 8736h + backlog_hours x rate
    s = sims["nom non-block"]
    assert abs(s.total_cost_usd - 0.0703 * HOURS_PER_YEAR) < 0.5


def test_mean_throughput_nominal(sims):
    # Table II: ~5035.8 rec/h mean for non-saturating pipelines
    assert abs(sims["nom non-block"].mean_throughput_rph - 5037.29) < 15
    assert abs(sims["nom block"].mean_throughput_rph - 5035.8) < 15

"""``ObservedTrace.from_prometheus``: range-query matrices -> fit traces.

Canned ``/api/v1/query_range`` JSON responses (the envelope a real
Prometheus returns, string-quoted sample values included) must bin into
the same ObservedTrace shape the other importers produce, so the metrics
side of a deployment feeds ``repro.calibrate`` without a client library.
"""
import numpy as np
import pytest

from repro.calibrate import ObservedTrace

STEP = 30.0        # query step of the canned responses (seconds)
T0 = 1.7e9         # an arbitrary unix epoch — times must rebase


def _matrix(entries):
    """Wrap result entries in the full Prometheus response envelope."""
    return {"status": "success",
            "data": {"resultType": "matrix", "result": entries}}


def _entry(rate_fn, n=21, metric=None):
    """One result entry: samples every STEP seconds, values as strings
    (Prometheus JSON quotes numbers)."""
    return {"metric": metric or {"job": "pipeline"},
            "values": [[T0 + i * STEP, str(rate_fn(i * STEP))]
                       for i in range(n)]}


def _flat_responses(rate=10.0, latency=2.5):
    return {
        "arrivals": _matrix([_entry(lambda t: rate)]),
        "processed": _matrix([_entry(lambda t: rate)]),
        "latency": _matrix([_entry(lambda t: latency)]),
    }


def test_flat_rates_bin_to_counts():
    tr = ObservedTrace.from_prometheus(_flat_responses(rate=10.0),
                                       bin_seconds=60.0, name="prom")
    # 21 samples x 30 s span 600 s -> 10 one-minute bins
    assert tr.num_bins == 10
    assert tr.bin_hours == pytest.approx(60.0 / 3600.0)
    # 10 rec/s x 60 s bins
    np.testing.assert_allclose(tr.arrivals, 600.0)
    np.testing.assert_allclose(tr.processed, 600.0)
    np.testing.assert_allclose(tr.latency_s, 2.5)
    np.testing.assert_allclose(tr.dropped, 0.0)      # omitted -> zeros
    assert tr.name == "prom"


def test_multiple_label_sets_sum_rates_average_latency():
    resp = {
        "arrivals": _matrix([_entry(lambda t: 4.0, metric={"pod": "a"}),
                             _entry(lambda t: 6.0, metric={"pod": "b"})]),
        "processed": _matrix([_entry(lambda t: 10.0)]),
        "latency": _matrix([_entry(lambda t: 1.0, metric={"pod": "a"}),
                            _entry(lambda t: 3.0, metric={"pod": "b"})]),
    }
    tr = ObservedTrace.from_prometheus(resp, bin_seconds=60.0)
    np.testing.assert_allclose(tr.arrivals, 600.0)   # 4 + 6 rec/s summed
    np.testing.assert_allclose(tr.latency_s, 2.0)    # gauge averaged


def test_ramp_rate_interpolates_onto_bin_centers():
    # rate ramps 0 -> 20 rec/s over 600 s; bin-center sampling of the
    # linear ramp integrates it exactly per bin
    resp = {"arrivals": _matrix([_entry(lambda t: t / 30.0)]),
            "processed": _matrix([_entry(lambda t: t / 30.0)])}
    tr = ObservedTrace.from_prometheus(resp, bin_seconds=60.0)
    centers = (np.arange(10) + 0.5) * 60.0
    np.testing.assert_allclose(tr.arrivals, centers / 30.0 * 60.0)
    assert tr.arrivals.sum() == pytest.approx(20.0 / 2 * 600.0)


def test_accepts_data_object_and_bare_result_list():
    full = _flat_responses()
    tr_full = ObservedTrace.from_prometheus(full)
    tr_data = ObservedTrace.from_prometheus(
        {k: v["data"] for k, v in full.items()})
    tr_bare = ObservedTrace.from_prometheus(
        {k: v["data"]["result"] for k, v in full.items()})
    for tr in (tr_data, tr_bare):
        np.testing.assert_array_equal(tr.arrivals, tr_full.arrivals)
        np.testing.assert_array_equal(tr.latency_s, tr_full.latency_s)


def test_cost_series_rate_or_flat_fallback():
    resp = _flat_responses()
    tr = ObservedTrace.from_prometheus(resp, bin_seconds=60.0,
                                       usd_per_hour=0.6)
    np.testing.assert_allclose(tr.cost_usd, 0.6 / 60.0)   # flat rate
    resp["cost"] = _matrix([_entry(lambda t: 1.2)])        # USD/hour rate
    tr = ObservedTrace.from_prometheus(resp, bin_seconds=60.0)
    np.testing.assert_allclose(tr.cost_usd, 1.2 / 60.0)


def test_feeds_the_fit_objective_shapes():
    tr = ObservedTrace.from_prometheus(_flat_responses(), bin_seconds=60.0)
    series = tr.series()
    assert set(series) == {"processed", "latency", "dropped", "cost"}
    scales = tr.scales()
    assert all(s > 0.0 for s in scales.values())
    assert tr.duration_hours == pytest.approx(10 * 60.0 / 3600.0)


def test_rejects_bad_inputs():
    with pytest.raises(ValueError, match="arrivals"):
        ObservedTrace.from_prometheus(
            {"processed": _matrix([_entry(lambda t: 1.0)])})
    with pytest.raises(ValueError, match="unknown series keys"):
        ObservedTrace.from_prometheus(
            {**_flat_responses(), "qps": _matrix([])})
    failed = {"status": "error", "error": "query timed out", "data": {}}
    with pytest.raises(ValueError, match="timed out"):
        ObservedTrace.from_prometheus({**_flat_responses(),
                                       "arrivals": failed})
    # real Prometheus error envelopes carry no 'data' key at all
    failed_no_data = {"status": "error", "errorType": "timeout",
                      "error": "query timed out"}
    with pytest.raises(ValueError, match="timed out"):
        ObservedTrace.from_prometheus({**_flat_responses(),
                                       "arrivals": failed_no_data})
    vector = {"status": "success",
              "data": {"resultType": "vector", "result": []}}
    with pytest.raises(ValueError, match="matrix"):
        ObservedTrace.from_prometheus({**_flat_responses(),
                                       "arrivals": vector})
    with pytest.raises(ValueError, match="no samples"):
        ObservedTrace.from_prometheus({"arrivals": _matrix([]),
                                       "processed": _matrix([])})
    # ANY provided-but-empty series is an error, not silent zeros (an
    # empty 'cost' would also silently shadow the usd_per_hour fallback)
    with pytest.raises(ValueError, match="processed.*no samples"):
        ObservedTrace.from_prometheus(
            {**_flat_responses(), "processed": _matrix([])})
    with pytest.raises(ValueError, match="cost.*no samples"):
        ObservedTrace.from_prometheus(
            {**_flat_responses(), "cost": _matrix([])}, usd_per_hour=3.0)

"""Calibrated policy search: optimizer correctness, one-dispatch shape,
grid-beating acceptance, Pareto monotonicity, and the p95/p99 plumbing.

Covers (ISSUE 5):
- analytic sanity: a registered toy policy with a closed-form convex
  optimum is recovered to ~1%;
- recovered-optimum-beats-grid-best across all five registered policies
  (short horizon, 256-point exhaustive baselines);
- the acceptance bar: ``whatif.optimize_scenario`` beats the best
  feasible row of a 4096-point ``run_grid`` sweep on the same space, for
  two policies, on the full hourly year — feasibility re-checked through
  the bit-exact aggregate path;
- all K restarts x S scenarios run as ONE ``_search_kernel`` dispatch
  (jit cache count — no Python-level restart loop), and the whole
  cross-policy tournament reuses that compile;
- Pareto frontier cost is non-increasing as the SLO loosens, from one
  lane-packed dispatch;
- infeasible searches warn with the policy and the pinned parameters;
- p95/p99 ride the aggregate histogram CDF and match the series-path
  percentiles to one quarter-octave bucket.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.slo import SLO
from repro.core.traffic import HOURS_PER_YEAR, TrafficModel
from repro.core.twin import (SimpleTwin, make_twin, policy_spec,
                             register_policy)
from repro.core.whatif import optimize_scenario, run_grid, table2_rows
from repro.search import (SearchInfeasibleWarning, evaluate_exact,
                          pareto_frontier, search, search_policies,
                          search_space)
from repro.search.optimize import _search_kernel

RPS, USD, LAT = 1.2, 0.01, 0.2


def weekly_load(seed=0, mean=4000.0, t_bins=336):
    rng = np.random.default_rng(seed)
    t = np.arange(t_bins)
    load = (mean + 0.75 * mean * np.sin(2 * np.pi * t / 24.0)
            + rng.uniform(0, 0.2 * mean, t_bins))
    return np.maximum(load, 50.0).astype(np.float32)[None]


# ---------------------------------------------------------------------------
# analytic sanity: closed-form convex optimum
# ---------------------------------------------------------------------------

def _ensure_toy_policy():
    """A policy whose cost is a parabola in its extra: cost/bin =
    usd * (1 + (knob - 3)^2) * dt — optimum knob* = 3 exactly, annual
    cost* = usd * 8736, independent of traffic."""
    try:
        return policy_spec("toyquad")
    except KeyError:
        pass

    @register_policy("toyquad",
                     ("max_rps", "usd_per_hour", "base_latency_s", "knob"),
                     defaults={"knob": 1.0},
                     bounds={"knob": (0.5, 10.0)})
    def _toy_step(carry, arrive, p, dt):
        cost = p[1] * (1.0 + (p[3] - 3.0) ** 2) * dt
        return carry, (arrive, jnp.zeros(()), p[2], cost, jnp.zeros(()))

    return policy_spec("toyquad")


def test_toy_convex_optimum_recovered_closed_form():
    _ensure_toy_policy()
    base = make_twin("toy", "toyquad", max_rps=RPS, usd_per_hour=USD,
                     base_latency_s=LAT, knob=1.0)
    res = search(base, loads=weekly_load(), bin_hours=1.0, slo=None,
                 restarts=4, steps=80, seed=0)
    assert res.feasible
    knob = res.twin.param("knob")
    assert abs(knob - 3.0) < 0.05, knob
    expected = USD * HOURS_PER_YEAR          # cost at the exact optimum
    assert res.cost_usd == pytest.approx(expected, rel=5e-3)


# ---------------------------------------------------------------------------
# recovered optimum beats an exhaustive grid, every registered policy
# ---------------------------------------------------------------------------

def _base_for(policy):
    extras = {"autoscale": {"max_instances": 8.0, "scale_up_hours": 2.0},
              "shed": {"queue_cap_hours": 4.0},
              "batch_window": {"window_hours": 2.0}}
    return make_twin(policy, policy, max_rps=2.5, usd_per_hour=USD,
                     base_latency_s=LAT, **extras.get(policy, {}))


@pytest.mark.parametrize("policy", ["fifo", "quickscale", "autoscale",
                                    "shed", "batch_window"])
def test_search_beats_256_point_grid(policy):
    loads = weekly_load(seed=3, mean=4000.0)
    slo = SLO(limit_s=4 * 3600, met_fraction=0.95)
    res = search(_base_for(policy), loads=loads, bin_hours=1.0, slo=slo,
                 restarts=6, steps=80, seed=0)
    grid_twins = res.space.grid(256)
    scen_w = np.array([1.0])
    horizon = HOURS_PER_YEAR / loads.shape[1]
    gcost, gfeas, _, _ = evaluate_exact(grid_twins, loads, 1.0, slo,
                                        scen_w, horizon)
    grid_best = np.where(gfeas, gcost, np.inf).min()
    assert np.isfinite(grid_best), f"{policy}: no feasible grid point"
    assert res.feasible, f"{policy}: search found no feasible config"
    # beats, or matches to the z-clip resolution at a box-edge optimum
    assert res.cost_usd <= grid_best * (1.0 + 1e-3), \
        (policy, res.cost_usd, grid_best, res.config())


# ---------------------------------------------------------------------------
# the acceptance bar: optimize_scenario vs a 4096-point run_grid sweep on
# the full hourly year, two policies, bit-exact feasibility
# ---------------------------------------------------------------------------

def _grid_best_feasible(space, traffic, slo):
    rows = run_grid(space.grid(4096), [traffic], slo=slo)
    assert len(rows) == 4096
    feas = [r for r in rows if r.slo_met]
    assert feas, "sweep found no feasible row — test problem is broken"
    return min(r.total_cost_usd for r in feas)


def test_optimize_scenario_beats_4096_grid_autoscale():
    traffic = TrafficModel.honda_default("high", R=3.5, G=1.4)
    slo = SLO(limit_s=2 * 3600, met_fraction=0.95)
    base = make_twin("auto", "autoscale", max_rps=1.9512,
                     usd_per_hour=0.0082, base_latency_s=0.15,
                     max_instances=8, scale_up_hours=2)
    res = optimize_scenario(base, [traffic], slo,
                            search=("max_instances", "scale_up_hours"),
                            restarts=6, steps=80, seed=0)
    assert res.feasible
    # the winner's evidence went through the aggregate path per scenario
    assert all(r.slo_met for r in res.scenario_rows)
    grid_best = _grid_best_feasible(res.space, traffic, slo)
    assert res.cost_usd <= grid_best, (res.cost_usd, grid_best)


def test_optimize_scenario_beats_4096_grid_shed():
    traffic = TrafficModel.honda_default("high", R=3.5, G=1.4)
    slo = SLO.for_drop_rate(0.01, met_fraction=0.95)
    base = make_twin("shed", "shed", max_rps=1.9512, usd_per_hour=0.0082,
                     base_latency_s=0.15, queue_cap_hours=4.0)
    res = optimize_scenario(base, [traffic], slo,
                            search=("queue_cap_hours", "max_rps"),
                            tie={"usd_per_hour": ("max_rps",
                                                  0.0082 / 1.9512)},
                            restarts=6, steps=80, seed=0)
    assert res.feasible
    grid_best = _grid_best_feasible(res.space, traffic, slo)
    assert res.cost_usd <= grid_best, (res.cost_usd, grid_best)


# ---------------------------------------------------------------------------
# one vmapped grad-of-scan dispatch — no Python loop over restarts
# ---------------------------------------------------------------------------

def test_search_is_single_kernel_dispatch():
    _search_kernel.clear_cache()
    res = search(_base_for("shed"), loads=weekly_load(), bin_hours=1.0,
                 slo=SLO(limit_s=4 * 3600, met_fraction=0.95),
                 restarts=5, steps=20, seed=0)
    assert _search_kernel._cache_size() == 1
    assert res.restart_costs.shape == (5,)


def test_tournament_shares_the_compiled_kernel():
    loads = weekly_load(seed=3)
    slo = SLO(limit_s=4 * 3600, met_fraction=0.95)
    _search_kernel.clear_cache()
    tour = search_policies([_base_for("fifo"), _base_for("autoscale"),
                            _base_for("shed")],
                           loads=loads, bin_hours=1.0, slo=slo,
                           restarts=4, steps=20, seed=0)
    # one compile per surrogate flavor at most (policy index is traced):
    # fifo's priced-capacity space needs the surrogate, the others don't
    assert _search_kernel._cache_size() <= 2
    ranked = tour.leaderboard_rows()
    assert len(ranked) == 3
    costs = [r["cost_usd"] for r in ranked if r["feasible"]]
    assert costs == sorted(costs)
    assert {"policy", "cost_usd", "config"} <= set(ranked[0])


# ---------------------------------------------------------------------------
# Pareto frontier: lane-packed dispatch, monotone by construction
# ---------------------------------------------------------------------------

def test_pareto_frontier_monotone_and_single_dispatch():
    loads = weekly_load(seed=5)
    limits = [1800.0, 3600.0, 4 * 3600.0, 12 * 3600.0]
    _search_kernel.clear_cache()
    fr = pareto_frontier(_base_for("autoscale"), loads=loads,
                         bin_hours=1.0, slo_limits=limits,
                         restarts=4, steps=30, seed=0)
    assert _search_kernel._cache_size() == 1       # all targets, one scan
    assert [p.limit_s for p in fr.points] == sorted(limits)
    feasible_costs = [p.cost_usd for p in fr.points if p.feasible]
    assert len(feasible_costs) >= 2
    for tighter, looser in zip(feasible_costs, feasible_costs[1:]):
        assert looser <= tighter + 1e-9
    rows = fr.rows()
    assert len(rows) == len(limits)
    assert "tightening_premium_usd" in rows[0]


# ---------------------------------------------------------------------------
# actionable diagnostics
# ---------------------------------------------------------------------------

def test_infeasible_search_warns_with_policy_and_bounds():
    # 1 rps max capacity against ~4000 records/hour and a 1-second SLO:
    # unreachable in the box
    base = make_twin("tiny", "shed", max_rps=0.5, usd_per_hour=USD,
                     base_latency_s=0.9, queue_cap_hours=1.0)
    sp = search_space(base, ("queue_cap_hours",))
    slo = SLO(limit_s=1.0, met_fraction=0.99)
    with pytest.warns(SearchInfeasibleWarning) as warned:
        res = search(sp, loads=weekly_load(), bin_hours=1.0, slo=slo,
                     restarts=4, steps=30, seed=0)
    assert not res.feasible
    msg = str(warned[0].message)
    assert "shed" in msg
    assert "NO feasible configuration" in msg
    assert "compliance" in msg
    # either a pinned parameter is named or the policy is called out as
    # unable to meet the SLO anywhere in the space
    assert ("bound" in msg) or ("cannot meet the SLO" in msg)


def test_space_rejects_base_outside_box_naming_param_and_policy():
    base = make_twin("b", "shed", max_rps=RPS, usd_per_hour=USD,
                     base_latency_s=LAT, queue_cap_hours=4.0)
    with pytest.raises(ValueError, match=r"shed\.queue_cap_hours"):
        search_space(base, ("queue_cap_hours",),
                     bounds={"queue_cap_hours": (8.0, 16.0)})


def test_calibrate_pinned_warning_names_param_and_trace():
    from repro.calibrate import ObservedTrace, fit
    from repro.core.loadpattern import LoadPattern
    truth = SimpleTwin("t", 2.0, 0.05, 0.2)
    tr = ObservedTrace.from_loadpattern(
        LoadPattern.steady("steady-trace", 1800.0, 3.0), truth, bin_s=300.0)
    giant = SimpleTwin("g", 2000.0, 0.05, 0.2)    # box tops at 1e3
    with pytest.warns(UserWarning) as warned:
        fit(tr, "fifo", restarts=2, steps=5, seed=0, init=giant)
    messages = [str(w.message) for w in warned]
    outside = [m for m in messages if "outside the calibration bounds" in m]
    pinned = [m for m in messages if "pinned" in m]
    assert outside and pinned
    # the offending parameter, its box, and the trace are all named
    assert "max_rps" in outside[0] and "steady-trace" in outside[0]
    assert "max_rps" in pinned[0] and "steady-trace" in pinned[0]
    assert "edge" in pinned[0]


# ---------------------------------------------------------------------------
# satellites: registry audit + p95/p99 plumbing
# ---------------------------------------------------------------------------

def test_registry_surrogate_audit():
    from repro.core.twin import policy_names
    for name in policy_names():
        spec = policy_spec(name)
        assert set(spec.nondiff_params) <= set(spec.param_names)
        assert spec.surrogate_lane_step is not None
        if spec.nondiff_params:
            assert spec.surrogate_lane_step is not spec.lane_step


def test_surrogate_carries_gradients_for_hard_gated_params():
    import jax
    from repro.kernels import ops
    loads = jnp.asarray(weekly_load(seed=7))
    spec = policy_spec("batch_window")
    base = make_twin("b", "batch_window", max_rps=2.5, usd_per_hour=USD,
                     base_latency_s=LAT, window_hours=6.0)
    widx = spec.param_names.index("window_hours")

    def total(p, surrogate):
        _, (proc, _q, lat, cost, _d) = ops.policy_scan(
            loads, p[None], dt_hours=1.0, policy_index=jnp.int32(spec.index),
            differentiable=True, surrogate=surrogate)
        return cost.sum() + 1e-6 * lat.sum()

    p0 = jnp.asarray(base.padded_params())
    g_soft = np.asarray(jax.grad(total)(p0, True))
    assert np.all(np.isfinite(g_soft))
    assert g_soft[widx] != 0.0, "surrogate lost the window gradient"


def test_p95_p99_series_vs_aggregate_and_table_columns():
    from repro.core.simulate import simulate_grid
    loads = np.tile(weekly_load(seed=11), (2, 1))
    twins = [SimpleTwin("f", 1.0, USD, LAT),
             make_twin("s", "shed", max_rps=1.0, usd_per_hour=USD,
                       base_latency_s=LAT, queue_cap_hours=3.0)]
    slo = SLO(limit_s=4 * 3600, met_fraction=0.95)
    series = simulate_grid(twins, loads, slo=slo, bin_hours=1.0)
    agg = simulate_grid(twins, loads, slo=slo, bin_hours=1.0,
                        return_series=False)
    for s, a in zip(series, agg):
        assert s.median_latency_s <= s.p95_latency_s <= s.p99_latency_s
        assert a.median_latency_s <= a.p95_latency_s <= a.p99_latency_s
        for key in ("p95_latency_s", "p99_latency_s"):
            exact, hist = getattr(s, key), getattr(a, key)
            # histogram CDF read-off is exact to one quarter-octave bucket
            assert abs(np.log2(hist / exact)) <= 0.26, (key, exact, hist)
    rows = table2_rows(agg)
    assert "latency_p95_s" in rows[0] and "latency_p99_s" in rows[0]
    assert rows[0]["latency_p95_s"] == pytest.approx(
        agg[0].p95_latency_s, rel=0.02, abs=0.01)


def test_search_result_reports_p_latency_evidence():
    slo = SLO(limit_s=4 * 3600, met_fraction=0.95)
    res = search(_base_for("autoscale"), loads=weekly_load(), bin_hours=1.0,
                 slo=slo, restarts=4, steps=30, seed=0)
    assert res.feasible
    # p95 off the bit-exact histogram: must respect the latency SLO the
    # exact counters certified at met_fraction=0.95
    assert res.p95_latency_s <= slo.limit_s * (2 ** 0.25)
    row = res.leaderboard_row()
    assert "latency_p95_s" in row

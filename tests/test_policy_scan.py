"""The fused Pallas scenario-grid backend vs the XLA ``lax.switch`` anchor.

Acceptance contract of the lane-vectorized refactor:

* the pure-jnp lane oracle (``kernels.ref.policy_grid_scan``) and the
  Pallas kernel (interpret mode on CPU) match the XLA backend within
  1e-5 relative on ALL FIVE output series for a mixed-policy 64-scenario
  year grid;
* ``simulate_grid`` routes through whichever backend the ``pallas_mode``
  switch selects, end to end, with identical summaries;
* the default XLA hourly full-year path stays bit-identical (the seed
  parity tests in test_twin_policies.py cover that side untouched).
"""
import contextlib

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.simulate import (_grid_scan, _grid_scan_xla,  # noqa: E402
                                 SimulationResult, simulate_grid,
                                 simulate_year)
from repro.core.traffic import HOURS_PER_YEAR, TrafficModel  # noqa: E402
from repro.core.twin import (QuickscalingTwin, SimpleTwin,  # noqa: E402
                             lane_branches, make_twin, policy_branches,
                             policy_names, policy_onehot, registry_version)
from repro.core.whatif import run_grid  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.kernels.policy_scan import policy_grid_scan  # noqa: E402

SERIES = ("processed", "queue", "latency", "cost", "dropped")


def _mixed_grid(n: int):
    """n scenarios cycling through every registered policy x traffic."""
    base = [
        SimpleTwin("fifo", 1.9512, 0.0082, 0.15),
        QuickscalingTwin("quick", 1.9512, 0.0082, 0.15),
        make_twin("auto", "autoscale", max_rps=0.5, usd_per_hour=0.002,
                  base_latency_s=0.1, max_instances=32, scale_up_hours=3),
        make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.0082,
                  base_latency_s=0.15, queue_cap_hours=2),
        make_twin("batch", "batch_window", max_rps=6.15, usd_per_hour=0.0703,
                  base_latency_s=0.06, window_hours=6),
    ]
    gs = np.linspace(1.0, 1.7, -(-n // len(base)))
    twins, loads = [], []
    for g in gs:
        hl = TrafficModel.honda_default(f"g{g:.2f}", R=3.5,
                                        G=float(g)).hourly_loads()
        for tw in base:
            twins.append(tw)
            loads.append(hl)
    twins, loads = twins[:n], loads[:n]
    params = np.stack([tw.padded_params() for tw in twins])
    idx = np.asarray([tw.policy_index for tw in twins], np.int32)
    return twins, np.stack(loads).astype(np.float32), params, idx


def _xla(loads, params, idx, dt=1.0):
    return _grid_scan_xla(jnp.asarray(loads), jnp.asarray(params),
                          jnp.asarray(idx), registry_version(), dt)


def _assert_series_close(outs_a, outs_b, rtol=1e-5):
    for name, a, b in zip(SERIES, outs_a, outs_b):
        a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
        denom = np.maximum(np.abs(b), 1e-6 * max(np.abs(b).max(), 1.0))
        rel = np.abs(a - b) / denom
        assert rel.max() <= rtol, (name, rel.max())


# ---------------------------------------------------------------------------
# the acceptance grid: 64 mixed-policy scenarios over the full year
# ---------------------------------------------------------------------------

def test_ref_lane_oracle_matches_xla_switch_64():
    _, loads, params, idx = _mixed_grid(64)
    q_end, outs_x = _xla(loads, params, idx)
    carry_end, outs_r = ref.policy_grid_scan(
        jnp.asarray(loads), jnp.asarray(params),
        jnp.asarray(policy_onehot(idx)), 1.0)
    _assert_series_close(outs_r, outs_x)
    np.testing.assert_allclose(np.asarray(carry_end[:, 0]),
                               np.asarray(q_end), rtol=1e-5)


def test_pallas_kernel_matches_xla_switch_64():
    _, loads, params, idx = _mixed_grid(64)
    q_end, outs_x = _xla(loads, params, idx)
    carry_end, outs_p = policy_grid_scan(
        jnp.asarray(loads), jnp.asarray(params),
        jnp.asarray(policy_onehot(idx)), 1.0, interpret=True)
    _assert_series_close(outs_p, outs_x)
    np.testing.assert_allclose(np.asarray(carry_end[:, 0]),
                               np.asarray(q_end), rtol=1e-5)


def test_pallas_kernel_scenario_padding_and_lane_blocking():
    """N not a lane multiple + lanes < N both hit the padding/grid paths."""
    _, loads, params, idx = _mixed_grid(13)
    q_end, outs_x = _xla(loads, params, idx)
    for lanes in (8, 128):
        carry_end, outs_p = policy_grid_scan(
            jnp.asarray(loads), jnp.asarray(params),
            jnp.asarray(policy_onehot(idx)), 1.0, lanes=lanes,
            interpret=True)
        _assert_series_close(outs_p, outs_x)
        np.testing.assert_allclose(np.asarray(carry_end[:, 0]),
                                   np.asarray(q_end), rtol=1e-5)


def test_pallas_kernel_short_horizon_subhour_bins():
    """A horizon the default chunk doesn't divide falls back cleanly, at a
    calibration-style sub-hour bin width."""
    rng = np.random.default_rng(0)
    loads = rng.uniform(0.0, 500.0, (5, 97)).astype(np.float32)
    twins = _mixed_grid(5)[0]
    params = np.stack([tw.padded_params() for tw in twins])
    idx = np.asarray([tw.policy_index for tw in twins], np.int32)
    q_end, outs_x = _xla(loads, params, idx, dt=1.0 / 60.0)
    carry_end, outs_p = policy_grid_scan(
        jnp.asarray(loads), jnp.asarray(params),
        jnp.asarray(policy_onehot(idx)), 1.0 / 60.0, interpret=True)
    _assert_series_close(outs_p, outs_x)


# ---------------------------------------------------------------------------
# backend selection end to end
# ---------------------------------------------------------------------------

def test_grid_scan_selects_pallas_backend():
    _, loads, params, idx = _mixed_grid(10)
    args = (jnp.asarray(loads), jnp.asarray(params), jnp.asarray(idx),
            registry_version(), 1.0)
    q_x, outs_x = _grid_scan(*args)
    assert not ops.pallas_enabled()
    with ops.pallas_mode():
        q_p, outs_p = _grid_scan(*args)
    _assert_series_close(outs_p, outs_x)
    np.testing.assert_allclose(np.asarray(q_p), np.asarray(q_x), rtol=1e-5)


def test_simulate_grid_end_to_end_under_pallas_mode():
    twins, loads, _, _ = _mixed_grid(5)
    sims_x = simulate_grid(twins, loads)
    with ops.pallas_mode():
        sims_p = simulate_grid(twins, loads)
    for sx, sp in zip(sims_x, sims_p):
        assert sp.total_cost_usd == pytest.approx(sx.total_cost_usd,
                                                  rel=1e-5)
        assert sp.mean_latency_s == pytest.approx(sx.mean_latency_s,
                                                  rel=1e-5)
        assert sp.dropped_records == pytest.approx(sx.dropped_records,
                                                   rel=1e-5, abs=1e-3)
        np.testing.assert_allclose(sp.processed, sx.processed, rtol=1e-5)


def test_run_grid_under_pallas_mode_mixed_policies():
    twins = _mixed_grid(5)[0]
    traffics = [TrafficModel.honda_default("nom"),
                TrafficModel.honda_default("high", G=1.5)]
    rows_x = [(s.name, s.total_cost_usd) for s in run_grid(twins, traffics)]
    with ops.pallas_mode():
        rows_p = [(s.name, s.total_cost_usd)
                  for s in run_grid(twins, traffics)]
    for (nx, cx), (np_, cp) in zip(rows_x, rows_p):
        assert nx == np_
        assert cp == pytest.approx(cx, rel=1e-5)


def test_uniform_policy_index_lane_path_matches_blend():
    """The calibration route: a uniform-policy lane block selected by a
    (traced) scalar index runs one lax.switch branch and matches both the
    masked blend and the XLA anchor."""
    twins, loads, params, _ = _mixed_grid(5)
    for tw in twins:
        n = loads.shape[0]
        p_block = np.tile(tw.padded_params(), (n, 1))
        idx = np.full(n, tw.policy_index, np.int32)
        q_end, outs_x = _xla(loads, p_block, idx)
        ce_u, outs_u = ops.policy_scan(
            jnp.asarray(loads), jnp.asarray(p_block),
            policy_index=jnp.int32(tw.policy_index), differentiable=True)
        ce_b, outs_b = ops.policy_scan(
            jnp.asarray(loads), jnp.asarray(p_block),
            jnp.asarray(policy_onehot(idx)), differentiable=True)
        _assert_series_close(outs_u, outs_x)
        _assert_series_close(outs_u, outs_b, rtol=1e-6)
    # the ambiguity is rejected before backend dispatch — identically on
    # the ref path and under the Pallas switch (no silent zero grids)
    for enable_pallas in (False, True):
        ctx = ops.pallas_mode() if enable_pallas else \
            contextlib.nullcontext()
        with ctx:
            with pytest.raises(ValueError, match="exactly one"):
                ops.policy_scan(jnp.asarray(loads), jnp.asarray(p_block))
            with pytest.raises(ValueError, match="exactly one"):
                ops.policy_scan(jnp.asarray(loads), jnp.asarray(p_block),
                                jnp.asarray(policy_onehot(idx)),
                                policy_index=jnp.int32(0))
    with pytest.raises(ValueError, match="exactly one"):
        ref.policy_grid_scan(jnp.asarray(loads), jnp.asarray(p_block))


# ---------------------------------------------------------------------------
# registry: both step forms exist and the onehot selector is sound
# ---------------------------------------------------------------------------

def test_every_policy_has_both_step_forms():
    assert len(lane_branches()) == len(policy_branches()) \
        == len(policy_names())
    assert all(callable(f) for f in lane_branches())


def test_policy_onehot_rows():
    idx = np.asarray([0, 3, 1], np.int32)
    oh = policy_onehot(idx)
    assert oh.shape == (3, len(policy_names()))
    np.testing.assert_array_equal(oh.sum(axis=1), 1.0)
    np.testing.assert_array_equal(np.argmax(oh, axis=1), idx)


# ---------------------------------------------------------------------------
# satellites: input validation survives ``python -O``; dropped default
# matches the horizon
# ---------------------------------------------------------------------------

def test_simulate_grid_input_checks_raise_value_error():
    tw = SimpleTwin("s", 1.0, 0.01, 0.1)
    year = np.ones(HOURS_PER_YEAR, np.float32)
    with pytest.raises(ValueError, match=r"\[N, T\]"):
        simulate_grid([tw], year)                       # 1-D, not a grid
    with pytest.raises(ValueError, match="twins"):
        simulate_grid([tw, tw], year[None])             # count mismatch
    with pytest.raises(ValueError, match="year"):
        simulate_year(tw, np.ones(100, np.float32))     # short horizon
    # the checks are real raises, not ``assert`` statements stripped by -O
    import inspect

    from repro.core import simulate as S
    src = inspect.getsource(S.simulate_grid) + inspect.getsource(
        S.simulate_year)
    assert "assert " not in src.replace("assert_", "")


def test_simulation_result_dropped_defaults_to_horizon():
    h = np.zeros(HOURS_PER_YEAR)
    sim = SimulationResult(
        name="x", twin=SimpleTwin("s", 1.0, 0.01, 0.1), load=h,
        processed=h, queue=h, latency_s=h, cost_usd=h, total_cost_usd=0.0,
        backlog_s=0.0, backlog_cost_usd=0.0, mean_throughput_rph=0.0,
        max_throughput_rph=0.0, median_latency_s=0.0, mean_latency_s=0.0,
        pct_latency_met=100.0, pct_hours_met=100.0, slo_met=None)
    assert sim.dropped.shape == h.shape
    # elementwise use against the other hourly series must be well-formed
    assert (sim.processed + sim.dropped).shape == h.shape
    with pytest.raises(ValueError, match="dropped"):
        SimulationResult(
            name="x", twin=SimpleTwin("s", 1.0, 0.01, 0.1), load=h,
            processed=h, queue=h, latency_s=h, cost_usd=h,
            total_cost_usd=0.0, backlog_s=0.0, backlog_cost_usd=0.0,
            mean_throughput_rph=0.0, max_throughput_rph=0.0,
            median_latency_s=0.0, mean_latency_s=0.0, pct_latency_met=100.0,
            pct_hours_met=100.0, slo_met=None, dropped=np.zeros(7))

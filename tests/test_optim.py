"""Optimizer: AdamW math vs numpy reference; quantized states; compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.config import OptimizerConfig
from repro.optim.adamw import adamw_update, init_opt_state, lr_at
from repro.optim.compression import dequantize_int8, quantize_int8


def _numpy_adamw(p, g, m, v, step, ocfg):
    b1, b2 = ocfg.betas
    gnorm = np.sqrt((g ** 2).sum())
    g = g * min(1.0, ocfg.grad_clip / (gnorm + 1e-9))
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g ** 2
    mhat = m / (1 - b1 ** step)
    vhat = v / (1 - b2 ** step)
    lr = float(lr_at(jnp.asarray(step), ocfg))
    return p - lr * (mhat / (np.sqrt(vhat) + ocfg.eps)
                     + ocfg.weight_decay * p), m, v


def test_adamw_matches_numpy_reference():
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                           weight_decay=0.1)
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(5, 7)), jnp.float32)}
    state = init_opt_state(p, ocfg)
    pn = np.asarray(p["w"])
    mn = np.zeros_like(pn)
    vn = np.zeros_like(pn)
    for step in range(1, 4):
        g = {"w": jnp.asarray(rng.normal(size=(5, 7)), jnp.float32)}
        p, state = adamw_update(p, g, state, ocfg)
        pn, mn, vn = _numpy_adamw(pn, np.asarray(g["w"]), mn, vn, step, ocfg)
        np.testing.assert_allclose(np.asarray(p["w"]), pn, atol=1e-5,
                                   rtol=1e-5)


@pytest.mark.parametrize("state_dtype", ["bfloat16", "int8"])
def test_quantized_states_track_fp32(state_dtype):
    """Optimizing a quadratic: quantized-moment Adam must still converge."""
    ocfg32 = OptimizerConfig(lr=5e-2, warmup_steps=0, total_steps=50)
    ocfgq = OptimizerConfig(lr=5e-2, warmup_steps=0, total_steps=50,
                            state_dtype=state_dtype, state_block=32)
    target = jnp.asarray(np.random.default_rng(1).normal(size=(64,)),
                         jnp.float32)

    def run(ocfg):
        p = {"w": jnp.zeros((64,), jnp.float32)}
        st = init_opt_state(p, ocfg)
        for _ in range(30):
            g = {"w": p["w"] - target}
            p, st = adamw_update(p, g, st, ocfg)
        return float(jnp.mean(jnp.square(p["w"] - target)))

    err32, errq = run(ocfg32), run(ocfgq)
    assert errq < 4 * err32 + 1e-3, (errq, err32)


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.float32, st.integers(1, 600),
                  elements=st.floats(-100, 100, width=32)))
def test_int8_quantization_error_bound(x):
    xj = jnp.asarray(x)
    q, scale = quantize_int8(xj, block=64)
    back = dequantize_int8(q, scale, xj.shape)
    # per-block error bounded by half a quantization step
    nblk = -(-x.size // 64)
    flat = np.pad(x, (0, nblk * 64 - x.size)).reshape(nblk, 64)
    bound = np.abs(flat).max(1) / 127.0 * 0.5 + 1e-6
    err = np.abs(np.asarray(back) - x).reshape(-1)
    errb = np.pad(err, (0, nblk * 64 - x.size)).reshape(nblk, 64)
    assert (errb.max(1) <= bound + 1e-7).all()


def test_lr_schedule_shape():
    ocfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_at(jnp.asarray(0), ocfg)) == 0.0
    assert abs(float(lr_at(jnp.asarray(10), ocfg)) - 1.0) < 1e-6
    assert float(lr_at(jnp.asarray(100), ocfg)) == pytest.approx(0.1, abs=1e-6)
    # monotone decay after warmup
    vals = [float(lr_at(jnp.asarray(s), ocfg)) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))

"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs; plus
prefill+decode consistency against the full forward (teacher forcing)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig, OptimizerConfig
from repro.configs import all_archs, get_config, get_smoke_config
from repro.models import model as M
from repro.optim.adamw import adamw_update, init_opt_state

ARCHS = all_archs()


def _batch(cfg, b=2, s=16, key=jax.random.PRNGKey(7)):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["embeds"] = jax.random.normal(key, (b, 4, cfg.d_model),
                                            jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s + 4)[None], (b, s + 4))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, b, s + 4))
    if cfg.encdec:
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_seq,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, _, aux = M.forward(params, cfg, batch)
    b, s = batch["tokens"].shape
    s_total = s + (4 if cfg.frontend == "vision" else 0)
    assert logits.shape == (b, s_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    ocfg = OptimizerConfig(total_steps=10, warmup_steps=1)
    opt = init_opt_state(params, ocfg)

    def loss_fn(p):
        return M.loss_fn(p, cfg, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    new_params, _ = adamw_update(params, grads, opt, ocfg)
    loss2 = loss_fn(new_params)
    assert bool(jnp.isfinite(loss2))
    # one step on the same batch should reduce loss
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_consistent_with_forward(arch):
    """Teacher-forced decode logits must match the parallel forward —
    exercises KV caches, recurrent states, conv buffers and positions."""
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    if cfg.moe is not None:   # disable capacity drops (grouping-dependent)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s_pre, n_dec = 2, 8, 4
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (b, s_pre + n_dec), 0, cfg.vocab_size)

    full_batch = _batch(cfg, b, s_pre + n_dec)
    full_batch["tokens"] = tokens
    logits_full, _, _ = M.forward(params, cfg, full_batch)
    logits_full = logits_full[:, -(s_pre + n_dec):]    # drop patch positions

    pre_batch = _batch(cfg, b, s_pre)
    pre_batch["tokens"] = tokens[:, :s_pre]
    if cfg.frontend == "vision":
        pre_batch["embeds"] = full_batch["embeds"]
        pos = full_batch["positions"][:, :, :s_pre + 4]
        pre_batch["positions"] = pos
    cache = M.init_cache(cfg, b, max_len=64)
    last, cache = M.prefill(params, cfg, pre_batch, cache)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits_full[:, s_pre - 1]),
                               atol=2e-3, rtol=2e-3)
    for i in range(n_dec - 1):
        step_logits, cache = M.decode_step(
            params, cfg, cache, {"token": tokens[:, s_pre + i:s_pre + i + 1]})
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]),
            np.asarray(logits_full[:, s_pre + i]),
            atol=2e-3, rtol=2e-3,
            err_msg=f"{arch} decode step {i}")


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_schema_sane(arch):
    """Full (dry-run) configs build schemas with the exact assigned dims."""
    cfg = get_config(arch)
    schema = M.full_schema(cfg)
    assert len(schema) > 0
    n = M.param_count(cfg)
    assert n > 100e6, f"{arch}: implausibly small full config ({n})"
    # spot-check assigned dimensions survived
    table = schema["embed.table"]
    assert table.shape[0] == cfg.vocab_size and table.shape[1] == cfg.d_model

"""Run-telemetry layer (``repro.obs``): the wind tunnel observing itself.

Acceptance contract of the observability PR:

* **Off by default, invisible when off.** The disabled path records
  nothing — no spans, no counters — and ``obs.span`` returns a shared
  null context manager (no per-call allocation). Enabling telemetry
  changes no computed number: grid results are bit-identical with obs
  on and off.
* **Spans nest and carry attributes.** ``parent_id`` links children to
  the enclosing span while it is still open; ``obs.timed`` records
  unconditionally (the explicit call is the opt-in) and exposes the
  measured wall time.
* **Bounded retention.** The ring drops oldest beyond ``capacity``;
  ``retention_s`` ages spans out by time against an injectable clock,
  and the JSONL collect file prunes itself the same way.
* **The engines emit.** ``simulate_grid`` aggregate runs produce
  ``grid.simulate``/``grid.block`` spans plus dedup counters;
  ``devices=4`` sharded runs produce per-round ``grid.round`` spans
  with device/block attrs; ``search()``/``fit()`` produce kernel spans;
  warn-once messages double as counters (visible even after Python's
  warning dedup silences the repeat).
* **The golden round-trip.** An instrumented experiment's stage spans
  export as OTel-style dicts (``to_otel_spans``) that feed straight
  back into ``ObservedTrace.from_otel_spans`` and support a refit —
  the twin calibrates from the tool's own telemetry.

Multi-device cases need ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
exported before the first jax import (the CI obs-suite job does);
without it they skip rather than sharding a 1-device mesh.
"""
import json
import time
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import obs  # noqa: E402
from repro.core.simulate import simulate_grid  # noqa: E402
from repro.core.slo import SLO  # noqa: E402
from repro.core.traffic import TrafficModel  # noqa: E402
from repro.core.twin import SimpleTwin, make_twin  # noqa: E402
from repro.obs.export import (append_jsonl, prometheus_exposition,  # noqa: E402
                              read_jsonl, to_otel_spans)
from repro.obs.record import _NULL, Recorder  # noqa: E402

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "before the first jax import")

SLO_4H = SLO(limit_s=4 * 3600, met_fraction=0.95)


@pytest.fixture(autouse=True)
def _obs_clean_slate():
    """Every test starts disabled with an empty global recorder and
    leaves the module state the way it found it."""
    was_enabled = obs.enabled()
    obs.disable()
    obs.get_recorder().clear()
    yield
    if was_enabled:
        obs.enable()
    else:
        obs.disable()
    obs.get_recorder().clear()


def _small_grid(n=16, distinct=4, t_bins=168):
    """n scenarios over `distinct` twin configs x 2 traffics — the
    dedup pass collapses the grid `n / distinct`-fold. ``t_bins`` trims
    the year to a week, so pass ``bin_hours=1.0`` to ``simulate_grid``."""
    twins = [SimpleTwin(f"tw{i % distinct}", 1.5 + 0.3 * (i % distinct),
                        0.01, 0.15) for i in range(n)]
    matrix = np.stack(
        [TrafficModel.honda_default("a", G=1.2).hourly_loads()[:t_bins],
         TrafficModel.honda_default("b", G=1.5).hourly_loads()[:t_bins]],
    ).astype(np.float32)
    index = (np.arange(n, dtype=np.int32) % distinct) % 2
    return twins, matrix, index


# ---------------------------------------------------------------------------
# off by default: no recording, no allocation, no numeric effect
# ---------------------------------------------------------------------------

def test_disabled_by_default_records_nothing():
    rec = obs.get_recorder()
    assert not obs.enabled()

    with obs.span("should.not.record", n=1):
        pass
    obs.count("should.not.count", 5)
    obs.gauge("should.not.gauge", 1.0)
    obs.event("should.not.event")

    twins, matrix, index = _small_grid()
    simulate_grid(twins, slo=SLO_4H, bin_hours=1.0, return_series=False,
                  load_matrix=matrix, load_index=index)

    assert len(rec.spans) == 0
    assert rec.counters == {} and rec.gauges == {}


def test_disabled_span_is_shared_null():
    # the disabled fast path hands every call site the SAME null span —
    # no per-call allocation — and its attrs dict accepts writes
    s1 = obs.span("a", x=1)
    s2 = obs.span("b")
    assert s1 is _NULL and s2 is _NULL
    with obs.span("c") as sp:
        sp.attrs["compiled"] = 1.0     # the block-engine write pattern


def test_enabling_does_not_change_grid_numbers():
    twins, matrix, index = _small_grid()
    base = simulate_grid(twins, slo=SLO_4H, bin_hours=1.0, return_series=False,
                         load_matrix=matrix, load_index=index)
    with obs.capture():
        instrumented = simulate_grid(twins, slo=SLO_4H, bin_hours=1.0,
                                     return_series=False,
                                     load_matrix=matrix,
                                     load_index=index)
    for a, b in zip(base, instrumented):
        assert a.mean_latency_s == b.mean_latency_s
        assert a.grand_total_usd == b.grand_total_usd
        assert a.pct_latency_met == b.pct_latency_met


# ---------------------------------------------------------------------------
# spans: nesting, attrs, decorator, timed
# ---------------------------------------------------------------------------

def test_span_nesting_links_parent_ids():
    with obs.capture() as rec:
        with obs.span("outer", layer=0) as outer:
            with obs.span("inner", layer=1):
                time.sleep(0.002)
    outer_sp, = rec.find(name="outer")
    inner_sp, = rec.find(name="inner")
    assert outer_sp.parent_id is None
    assert inner_sp.parent_id == outer_sp.span_id
    assert inner_sp.attrs["layer"] == 1
    assert outer_sp.duration >= inner_sp.duration > 0


def test_span_attrs_mutable_until_exit():
    with obs.capture() as rec:
        with obs.span("block", size=8) as sp:
            sp.attrs["compiled"] = 1.0
    sp, = rec.find(name="block")
    assert sp.attrs == {"size": 8, "compiled": 1.0}


def test_instrument_decorator_names_and_gates():
    @obs.instrument(name="custom.op", kind="unit")
    def work(x):
        return x + 1

    assert work.__obs_name__ == "custom.op"
    assert work(1) == 2                      # disabled: plain call
    assert len(obs.get_recorder().spans) == 0
    with obs.capture() as rec:
        assert work(2) == 3
    sp, = rec.find(name="custom.op")
    assert sp.attrs["kind"] == "unit"


def test_timed_always_records_and_exposes_elapsed():
    assert not obs.enabled()
    with obs.timed("bench.thing", n=4) as tm:
        time.sleep(0.002)
    assert tm.elapsed >= 0.002
    sp, = obs.get_recorder().find(name="bench.thing")
    assert sp.attrs["n"] == 4
    assert sp.duration == pytest.approx(tm.elapsed)


def test_capture_restores_state_and_injected_recorder():
    global_rec = obs.get_recorder()
    mine = Recorder()
    with obs.capture(recorder=mine) as rec:
        assert rec is mine
        assert obs.enabled()
        with obs.span("inside"):
            pass
    assert not obs.enabled()
    assert obs.get_recorder() is global_rec
    assert len(mine.find(name="inside")) == 1
    assert len(global_rec.spans) == 0


# ---------------------------------------------------------------------------
# counters / gauges
# ---------------------------------------------------------------------------

def test_labeled_counters_accumulate_and_flatten():
    with obs.capture() as rec:
        obs.count("grid.blocks", 3, backend="xla", devices=1)
        obs.count("grid.blocks", 2, backend="xla", devices=1)
        obs.count("grid.blocks", 5, backend="pallas", devices=1)
        obs.gauge("grid.block_size", 4480)
        flat = obs.counters()
    assert rec.counter_total("grid.blocks") == 10
    assert flat["grid.blocks{backend=xla,devices=1}"] == 5
    assert flat["grid.blocks{backend=pallas,devices=1}"] == 5


# ---------------------------------------------------------------------------
# bounded retention: capacity ring + time window
# ---------------------------------------------------------------------------

def test_ring_capacity_drops_oldest():
    rec = Recorder(capacity=4)
    for i in range(10):
        rec.add_span(f"s{i}", float(i), float(i) + 0.5)
    names = [s.name for s in rec.find()]
    assert names == ["s6", "s7", "s8", "s9"]


def test_retention_prunes_by_injected_clock():
    t = {"now": 100.0}
    rec = Recorder(retention_s=10.0, clock=lambda: t["now"])
    rec.add_span("old", 80.0, 85.0)
    rec.add_span("fresh", 95.0, 99.0)
    # the next add prunes lazily: cutoff = 100 - 10 = 90 drops "old"
    rec.add_span("new", 99.0, 100.0)
    assert [s.name for s in rec.find()] == ["fresh", "new"]
    t["now"] = 120.0
    assert rec.prune() == 2
    assert rec.find() == []


# ---------------------------------------------------------------------------
# the engines emit: grid spans + dedup counters, sharded per-round spans
# ---------------------------------------------------------------------------

def test_grid_emits_spans_and_dedup_counters():
    twins, matrix, index = _small_grid(n=16, distinct=4)
    with obs.capture() as rec:
        # scenario_block=2 forces the blocked engine on the 4 kept
        # (deduped) scenarios: 2 blocks, each its own span
        rows = simulate_grid(twins, slo=SLO_4H, bin_hours=1.0,
                             return_series=False, scenario_block=2,
                             load_matrix=matrix, load_index=index)
    assert len(rows) == 16
    top, = rec.find(name="grid.simulate")
    assert top.attrs["n"] == 16 and top.attrs["mode"] == "agg"
    blocks = rec.find(name="grid.block")
    assert len(blocks) == 2, "blocked run must emit per-block spans"
    for sp in blocks:
        assert sp.parent_id == top.span_id
        assert sp.attrs["backend"] in ("xla", "pallas")
        assert sp.attrs["compiled"] in (0.0, 1.0)
        assert sp.attrs["size"] == 2
    # 16 scenarios over 4 distinct configs: dedup collapses 4x
    assert rec.counter_total("grid.dedup.total") == 16
    assert rec.counter_total("grid.dedup.kept") == 4
    assert rec.counter_total("grid.scenarios") == 16
    assert rec.counter_total("grid.blocks") == 2
    assert ("grid.block_size", ()) in rec.gauges


def test_series_mode_emits_simulate_span():
    twins, matrix, index = _small_grid(n=4, distinct=4)
    with obs.capture() as rec:
        sims = simulate_grid(twins, slo=SLO_4H, bin_hours=1.0, return_series=True,
                             load_matrix=matrix, load_index=index)
    assert len(sims) == 4
    top, = rec.find(name="grid.simulate")
    assert top.attrs["mode"] == "series"
    assert top.attrs["faulted"] is False


@needs4
def test_sharded_grid_emits_per_round_spans():
    d, block, n = 4, 8, 64                  # 2 rounds of d*block = 32
    twins, matrix, index = _small_grid(n=n, distinct=n)
    with obs.capture() as rec:
        rows = simulate_grid(twins, slo=SLO_4H, bin_hours=1.0, return_series=False,
                             load_matrix=matrix, load_index=index,
                             scenario_block=block, devices=d)
    assert len(rows) == n
    rounds = rec.find(name="grid.round")
    assert len(rounds) == 2
    for i, sp in enumerate(rounds):
        assert sp.attrs["round"] == i
        assert sp.attrs["devices"] == d
        assert sp.attrs["block"] == block
        assert sp.attrs["scenarios"] == d * block
        assert sp.attrs["compiled"] in (0.0, 1.0)
    # the first dispatch of a fresh shape traces; later rounds reuse it
    assert rounds[1].attrs["compiled"] == 0.0
    flat = obs.counters()                    # capture() left the spans +
    key = f"grid.blocks{{backend=xla,devices={d}}}"   # counters in place
    assert flat[key] == n // block


# ---------------------------------------------------------------------------
# search / fit spans + warn events as counters
# ---------------------------------------------------------------------------

def test_search_emits_kernel_span_and_infeasible_event():
    from repro.search import SearchInfeasibleWarning, search, search_space

    base = make_twin("tiny", "shed", max_rps=0.5, usd_per_hour=0.0082,
                     base_latency_s=0.9, queue_cap_hours=1.0)
    sp = search_space(base, ("queue_cap_hours",))
    loads = TrafficModel.honda_default("w").hourly_loads()[:168]
    slo = SLO(limit_s=1.0, met_fraction=0.99)
    with obs.capture() as rec:
        with pytest.warns(SearchInfeasibleWarning):
            res = search(sp, loads=loads, bin_hours=1.0, slo=slo,
                         restarts=4, steps=30, seed=0)
    assert not res.feasible
    kernel = rec.find(name="search.kernel")
    assert kernel and kernel[0].attrs["restarts"] == 4
    assert rec.counter_total("warn.search_infeasible") == 1
    assert rec.counter_total("search.restarts") >= 4
    flat = obs.counters()
    assert flat["search.objective_choice{policy=shed,stream=False}"] >= 1


def test_fit_emits_span_and_pinned_warn_events():
    from repro.calibrate import ObservedTrace, fit
    from repro.core.loadpattern import LoadPattern

    truth = SimpleTwin("t", 2.0, 0.05, 0.2)
    tr = ObservedTrace.from_loadpattern(
        LoadPattern.steady("steady", 1800.0, 3.0), truth, bin_s=300.0)
    giant = SimpleTwin("g", 2000.0, 0.05, 0.2)    # box tops out at 1e3
    with obs.capture() as rec:
        with pytest.warns(UserWarning):
            fit(tr, "fifo", restarts=2, steps=5, seed=0, init=giant)
    span_, = rec.find(name="calibrate.fit")
    assert span_.attrs["policy"] == "fifo"
    assert span_.attrs["restarts"] == 2
    assert rec.counter_total("warn.fit_warm_start_outside") == 1
    assert rec.counter_total("warn.fit_pinned") == 1
    assert rec.counter_total("calibrate.fits") == 1


def test_replication_fallback_counts_every_event():
    from repro.distributed import sharding

    with obs.capture() as rec:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            # same site twice: Python's warn-once dedup fires the
            # RuntimeWarning only the first time, but the obs counter
            # must see BOTH fallbacks
            sharding._warn_replicated("obs-test(x)", "scenario", 23, 4)
            sharding._warn_replicated("obs-test(x)", "scenario", 23, 4)
    flat = obs.counters()
    key = "warn.replication_fallback{axis=scenario,where=obs-test(x)}"
    assert flat[key] == 2


def test_faults_expand_grid_counts():
    from repro import faults

    sched = faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=6, duration_hours=(1, 4)),),
        n_futures=2, seed=0)
    twins, matrix, index = _small_grid(n=4, distinct=4)
    sampled = faults.sample_futures(sched, matrix.shape[1])
    with obs.capture() as rec:
        grid = faults.expand_grid(sampled, matrix, index)
    assert grid.load_index.shape[0] == 8
    assert rec.counter_total("faults.futures") == 2
    assert rec.counter_total("faults.rows") == 8
    assert rec.find(name="faults.expand_grid")


# ---------------------------------------------------------------------------
# exporters: Prometheus exposition, JSONL retention, dispatch profiles
# ---------------------------------------------------------------------------

def test_prometheus_exposition_shape():
    twins, matrix, index = _small_grid(n=4, distinct=4)
    with obs.capture() as rec:
        rows = simulate_grid(twins, slo=SLO_4H, bin_hours=1.0, return_series=False,
                             load_matrix=matrix, load_index=index)
        text = prometheus_exposition(rows, recorder=rec)
    lines = text.strip().split("\n")
    families = [ln.split()[2] for ln in lines if ln.startswith("# TYPE")]
    for fam in ("plantd_latency_seconds", "plantd_latency_mean_seconds",
                "plantd_message_count",
                "plantd_target_compliance_percent", "plantd_cost_usd",
                "plantd_throughput_rph", "plantd_obs_events_total",
                "plantd_obs_span_count"):
        assert fam in families, fam
    # every sample line parses: name{labels} float
    samples = [ln for ln in lines if not ln.startswith("#")]
    assert len(samples) > 20
    for ln in samples:
        metric, val = ln.rsplit(" ", 1)
        float(val)
        assert metric[0].isalpha()
    # each scenario appears at 3 quantiles
    q_lines = [ln for ln in samples
               if ln.startswith("plantd_latency_seconds{")]
    assert len(q_lines) == 3 * len(rows)
    assert 'quantile="0.95"' in text
    # the engine's own counters ride along as obs events
    assert 'event="grid.scenarios"' in text


def test_jsonl_append_prunes_by_retention(tmp_path):
    path = str(tmp_path / "collect.jsonl")
    rec = Recorder()
    t0 = rec.mono0
    rec.add_span("tick.a", t0 + 0.0, t0 + 1.0)
    rec.count("events", 2)
    n = append_jsonl(path, rec, retention_s=3600.0,
                     now=rec.wall0 + 10.0)
    assert n == 2                            # one span + one snapshot
    assert len(rec.spans) == 0               # clear=True drained the ring

    # a second tick an hour later: the first span ages out of the window
    rec.add_span("tick.b", t0 + 3599.0, t0 + 3600.0)
    rec.count("events", 3)
    append_jsonl(path, rec, retention_s=1800.0,
                 now=rec.wall0 + 3601.0)
    data = read_jsonl(path)
    assert [d["name"] for d in data["spans"]] == ["tick.b"]
    # counters are cumulative; the latest snapshot wins
    assert data["counters"][-1]["values"]["events"] == 5.0
    # every line is valid JSON with a type tag
    with open(path) as f:
        for ln in f:
            assert json.loads(ln)["type"] in ("span", "counters")


def test_profile_dispatch_splits_compile_and_execute():
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        return (x @ x.T).sum()

    x = jnp.ones((256, 256), jnp.float32)
    out, prof = obs.profile_dispatch("unit.matmul", f, x, reps=2,
                                     size=256)
    assert float(out) == pytest.approx(256.0 ** 3)
    assert prof.compile_s > 0 and prof.execute_s > 0
    assert prof.reps == 2
    row = prof.row()
    assert row["name"] == "unit.matmul" and row["size"] == 256.0
    assert "compile_s" in row and "execute_s" in row
    rec = obs.get_recorder()
    sp, = rec.find(name="dispatch.unit.matmul")
    assert sp.attrs["compile_s"] == prof.compile_s
    assert rec.profiles[-1] is prof
    # CPU XLA exposes the compiled program's memory analysis
    if prof.peak_temp_bytes is not None:
        assert prof.peak_temp_bytes >= 0
        assert "peak_temp_mb" in row


def test_jit_cache_growth_detection():
    import jax.numpy as jnp

    @jax.jit
    def g(x):
        return x * 2

    g._clear_cache() if hasattr(g, "_clear_cache") else None
    before = obs.jit_cache_size(g)
    g(jnp.ones((3,)))
    assert obs.jit_cache_grew(g, before)
    mid = obs.jit_cache_size(g)
    g(jnp.ones((3,)))                        # cache hit: no growth
    assert not obs.jit_cache_grew(g, mid)


# ---------------------------------------------------------------------------
# the golden round-trip: instrumented experiment -> OTel export ->
# from_otel_spans -> refit
# ---------------------------------------------------------------------------

def test_otel_export_roundtrip_refits_twin():
    from repro.calibrate import ObservedTrace, fit
    from repro.core.datagen import DataGenerator
    from repro.core.experiment import Experiment
    from repro.core.loadpattern import LoadPattern
    from repro.core.pipeline import Pipeline, PipelineStage, Resources
    from repro.core.schema import FieldSpec, Schema

    def work(batch):
        time.sleep(0.004)
        return batch

    pipe = Pipeline("rt", [PipelineStage("only_stage", work)],
                    resources=Resources(vcpus=1, ram_gb=1))
    schema = Schema("one", (FieldSpec("x", "float"),))
    ds = DataGenerator(0).generate(schema, 100)
    load = LoadPattern.steady("rt-load", duration_s=1.2, rate=60)

    with obs.capture() as rec:
        res = Experiment("rt", pipe, load, ds, drain_timeout_s=30).run()
    assert res.drained

    # the pipeline's stage spans were mirrored into obs with records
    spans = to_otel_spans(rec, prefix="stage.")
    assert spans, "instrumented experiment produced no stage spans"
    for d in spans:
        assert d["status"] == "OK"
        assert d["records"] >= 1
        assert d["end"] >= d["start"]
        # unix epoch, not monotonic: the wall anchor placed them
        assert d["start"] > 1e9

    trace = ObservedTrace.from_otel_spans(spans, bin_seconds=0.25,
                                          name="obs-roundtrip")
    assert trace.num_bins >= 2
    assert float(np.sum(trace.arrivals)) == pytest.approx(
        sum(d["records"] for d in spans))

    result = fit(trace, "fifo", restarts=2, steps=30, seed=0)
    assert np.isfinite(result.loss)
    assert result.twin.max_rps > 0


def test_report_renders_spans_counters_and_profiles():
    from repro.obs.report import render, summarize

    with obs.capture() as rec:
        with obs.span("demo.outer", records=8):
            time.sleep(0.002)
        obs.count("demo.events", 3, kind="x")
        obs.gauge("demo.level", 7.0)
        stats = summarize(rec)
        text = render(rec)
    assert stats["demo.outer"]["count"] == 1
    assert stats["demo.outer"]["records"] == 8.0
    assert "demo.outer" in text
    assert "demo.events{kind=x}" in text
    assert "demo.level" in text


def test_report_from_jsonl_file(tmp_path):
    from repro.obs.report import _report_file

    path = str(tmp_path / "obs.jsonl")
    rec = Recorder()
    rec.add_span("tick", rec.mono0, rec.mono0 + 0.5, {"records": 4})
    rec.count("ticks", 2)
    append_jsonl(path, rec)
    text = _report_file(path)
    assert "tick" in text and "ticks" in text

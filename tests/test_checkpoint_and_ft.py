"""Checkpointing (atomic/async/gc), restore, elastic reshard, fault paths,
train-loop recovery and straggler detection."""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.config import OptimizerConfig, ParallelConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core.spans import Span, SpanCollector
from repro.distributed.fault import (FaultInjector, NodeLoss,
                                     StragglerWatchdog, TransientFault,
                                     retry_step)
from repro.launch.mesh import make_host_mesh
from repro.train.loop import train


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_ckpt_dir):
    t = _tree()
    save_checkpoint(tmp_ckpt_dir, 3, t, extra={"k": 1})
    assert latest_step(tmp_ckpt_dir) == 3
    like = jax.tree.map(jnp.zeros_like, t)
    got, step, extra = restore_checkpoint(tmp_ckpt_dir, None, like)
    assert step == 3 and extra == {"k": 1}
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomic_no_tmp_visible(tmp_ckpt_dir):
    save_checkpoint(tmp_ckpt_dir, 1, _tree())
    names = os.listdir(tmp_ckpt_dir)
    assert all(not n.endswith(".tmp") for n in names)


def test_async_checkpointer_gc(tmp_ckpt_dir):
    ck = AsyncCheckpointer(tmp_ckpt_dir, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree())
    ck.close()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_ckpt_dir))
    assert steps == [3, 4]
    assert not ck.errors


def test_restore_missing_raises(tmp_ckpt_dir):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(tmp_ckpt_dir, None, _tree())


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, jax, jax.numpy as jnp, numpy as np
sys.path.insert(0, "src")
from repro.checkpoint.ckpt import save_checkpoint
from repro.config import OptimizerConfig, ParallelConfig
from repro.configs import get_smoke_config
from repro.distributed.elastic import elastic_restore, state_shardings
from repro.models import model as M
from repro.optim.adamw import init_opt_state

ckpt = sys.argv[1]
cfg = get_smoke_config("llama3.2-1b")
ocfg = OptimizerConfig()
params = M.init_params(cfg, jax.random.PRNGKey(0))
opt = init_opt_state(params, ocfg)

# save from a 4x2 mesh placement
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
par = ParallelConfig(batch_axes=("data",))
ps1, os1 = state_shardings(cfg, ocfg, par, mesh1)
params1 = jax.tree.map(jax.device_put, params, ps1)
save_checkpoint(ckpt, 7, (params1, opt))

# restore onto a 2x1 mesh (elastic shrink: 8 -> 2 devices)
mesh2 = jax.make_mesh((2, 1), ("data", "model"))
p2, o2, step, extra = elastic_restore(ckpt, cfg, ocfg, par, mesh2)
assert step == 7
for k in params:
    np.testing.assert_array_equal(np.asarray(p2[k]), np.asarray(params[k]))
    nshard = len(p2[k].sharding.device_set)
    assert nshard <= 2, (k, nshard)
print("ELASTIC_OK")
"""


def test_elastic_reshard_across_device_counts(tmp_ckpt_dir):
    """Subprocess with 8 forced host devices: save on 4x2, restore on 2x1."""
    r = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT, tmp_ckpt_dir],
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_retry_step_recovers_transient():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientFault("flaky")
        return "ok"

    assert retry_step(flaky, retries=5, backoff_s=0.001) == "ok"
    assert calls["n"] == 3


def test_retry_step_exhausts():
    def always():
        raise TransientFault("nope")

    with pytest.raises(TransientFault):
        retry_step(always, retries=2, backoff_s=0.001)


def test_straggler_watchdog():
    col = SpanCollector()
    t = 0.0
    for i in range(12):
        col.add(Span("stage", t, 0.01))
        t += 0.02
    col.add(Span("stage", t, 0.5))        # 50x the median
    wd = StragglerWatchdog(col, factor=3.0)
    flagged = wd.stragglers()
    assert "stage" in flagged
    assert flagged["stage"]["ratio"] > 10


def test_train_loop_survives_faults(tmp_ckpt_dir, host_mesh):
    cfg = get_smoke_config("llama3.2-1b")
    tcfg = TrainConfig(steps=10, seq_len=32, global_batch=4,
                       checkpoint_every=3, checkpoint_dir=tmp_ckpt_dir,
                       log_every=100)
    ocfg = OptimizerConfig(total_steps=10, warmup_steps=2)
    inj = FaultInjector(transient_at=(2,), node_loss_at=(6,))
    res = train(cfg, tcfg, ocfg, ParallelConfig(batch_axes=("data",)),
                host_mesh, injector=inj, verbose=False)
    assert res.steps_done == 10
    assert res.restarts == 1
    assert "transient@2" in inj.fired and "node_loss@6" in inj.fired
    assert res.final_loss < res.losses[0]          # still learning
    assert latest_step(tmp_ckpt_dir) == 10


def test_train_loop_resume_from_checkpoint(tmp_ckpt_dir, host_mesh):
    cfg = get_smoke_config("llama3.2-1b")
    ocfg = OptimizerConfig(total_steps=8, warmup_steps=1)
    par = ParallelConfig(batch_axes=("data",))
    t1 = TrainConfig(steps=4, seq_len=32, global_batch=4, checkpoint_every=2,
                     checkpoint_dir=tmp_ckpt_dir, log_every=100)
    r1 = train(cfg, t1, ocfg, par, host_mesh, verbose=False)
    t2 = TrainConfig(steps=8, seq_len=32, global_batch=4, checkpoint_every=2,
                     checkpoint_dir=tmp_ckpt_dir, log_every=100)
    r2 = train(cfg, t2, ocfg, par, host_mesh, verbose=False)
    # resumed run continues from step 4, not from scratch
    assert r2.steps_done == 8
    assert len(r2.losses) == 4

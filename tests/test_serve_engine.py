"""Serving engine: grouped batching, greedy consistency, TTFT accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ParallelConfig
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine


def _engine(slots=2, max_len=64):
    cfg = get_smoke_config("llama3.2-1b")
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, mesh, ParallelConfig(batch_axes=("data",)), params,
                      slots=slots, max_len=max_len)
    return cfg, params, eng


def test_greedy_consistency_with_forward():
    """Engine's greedy continuation == argmax chain from the raw model."""
    cfg, params, eng = _engine()
    prompt = [3, 17, 5, 9, 2, 11, 7, 4]
    req = Request(rid=0, prompt=list(prompt), max_new=4)
    eng.process_group([req])

    toks = list(prompt)
    plen = eng._prefill_len
    padded = np.zeros((1, plen), np.int32)
    padded[0, :len(toks)] = toks
    # engine pads to prefill_len; replicate exactly (padded greedy chain)
    want = []
    cur = jnp.asarray(padded)
    logits, _, _ = M.forward(params, cfg, {"tokens": cur})
    nxt = int(jnp.argmax(logits[0, -1]))
    want.append(nxt)
    for _ in range(3):
        cur = jnp.concatenate([cur, jnp.asarray([[nxt]], jnp.int32)], 1)
        logits, _, _ = M.forward(params, cfg, {"tokens": cur})
        nxt = int(jnp.argmax(logits[0, -1]))
        want.append(nxt)
    assert req.output == want, (req.output, want)


def test_requests_complete_and_timed():
    _, _, eng = _engine(slots=2)
    reqs = [Request(rid=i, prompt=[1, 2, 3 + i], max_new=3,
                    submitted=0.005 * i) for i in range(5)]
    done = eng.serve(reqs)
    assert len(done) == 5
    for r in done:
        assert len(r.output) == 3
        assert r.ttft_s is not None and r.ttft_s >= 0
        assert r.latency_s >= r.ttft_s
    names = set(eng.collector.stage_names())
    assert {"prefill", "decode"} <= names

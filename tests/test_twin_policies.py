"""TwinPolicy engine: registry, new policies, vmapped grid, seed parity.

No hypothesis dependency — these are the deterministic property checks for
the policy registry (conservation, monotonicity, backward compatibility)
plus the single-trace guarantee of the vmapped ``run_grid``.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.simulate import _grid_scan, simulate_grid, simulate_year
from repro.core.slo import SLO
from repro.core.traffic import HOURS_PER_YEAR, TrafficModel
from repro.core.twin import (PARAM_DIM, QuickscalingTwin, SimpleTwin, Twin,
                             fit_twin, make_twin, policy_names, policy_spec,
                             roofline_twin)
from repro.core.whatif import run_grid, table2_rows

NOM = TrafficModel.honda_default("nom")
LOADS = NOM.hourly_loads()
# the scan runs in f32; compare against what it actually saw
ARRIVED = LOADS.astype(np.float32).astype(np.float64)

ALL_POLICY_TWINS = [
    SimpleTwin("fifo", 1.0, 0.01, 0.1),
    QuickscalingTwin("quick", 1.0, 0.01, 0.1),
    make_twin("auto", "autoscale", max_rps=0.5, usd_per_hour=0.01,
              base_latency_s=0.1, min_instances=1, max_instances=8,
              scale_up_hours=3),
    make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.01,
              base_latency_s=0.1, queue_cap_hours=2),
    make_twin("batch", "batch_window", max_rps=4.0, usd_per_hour=0.01,
              base_latency_s=0.1, window_hours=6),
]


# ---------------------------------------------------------------------------
# seed parity: legacy twins bit-identical to the seed's hard-coded scan
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(2,))
def _seed_fifo_scan(load, params, quickscale):
    """The seed repo's simulate kernel, verbatim, as the parity oracle."""
    max_rps, usd_hr, base_lat = params
    cap_h = max_rps * 3600.0

    def hour(queue, arrive):
        if quickscale:
            instances = jnp.maximum(
                jnp.ceil(arrive / jnp.maximum(cap_h, 1e-9)), 1.0)
            processed = arrive
            new_q = queue * 0.0
            latency = base_lat
            cost = usd_hr * instances
        else:
            avail = queue + arrive
            processed = jnp.minimum(avail, cap_h)
            new_q = avail - processed
            avg_q = 0.5 * (queue + new_q)
            latency = base_lat + avg_q / jnp.maximum(max_rps, 1e-9)
            cost = usd_hr
        return new_q, (processed, new_q, latency, cost)

    q_end, outs = jax.lax.scan(hour, jnp.zeros(()), load)
    return (q_end,) + outs


@pytest.mark.parametrize("twin,quick", [
    (SimpleTwin("block", 1.9512, 0.0082, 0.15), False),
    (SimpleTwin("cpu-lim", 0.6612, 0.0027, 0.29), False),
    (QuickscalingTwin("q", 1.9512, 0.0082, 0.15), True),
])
def test_legacy_twins_bit_identical_to_seed_scan(twin, quick):
    load32 = jnp.asarray(LOADS, jnp.float32)
    params = jnp.array([twin.max_rps, twin.usd_per_hour,
                        twin.base_latency_s], jnp.float32)
    q_end, proc, queue, lat, cost = _seed_fifo_scan(load32, params, quick)
    sim = simulate_year(twin, LOADS)
    assert np.array_equal(np.asarray(proc, np.float64), sim.processed)
    assert np.array_equal(np.asarray(queue, np.float64), sim.queue)
    assert np.array_equal(np.asarray(lat, np.float64), sim.latency_s)
    assert np.array_equal(np.asarray(cost, np.float64), sim.cost_usd)
    assert float(q_end) == sim.queue[-1]


# ---------------------------------------------------------------------------
# registry / Twin record
# ---------------------------------------------------------------------------

def test_builtin_policies_registered():
    assert policy_names()[:5] == ["fifo", "quickscale", "autoscale", "shed",
                                  "batch_window"]
    for name in policy_names():
        spec = policy_spec(name)
        assert spec.param_names[:3] == ("max_rps", "usd_per_hour",
                                        "base_latency_s")
        assert len(spec.param_names) <= PARAM_DIM


def test_legacy_aliases_build_twins():
    tw = SimpleTwin("s", 2.0, 0.05, 0.1)
    assert isinstance(tw, Twin) and tw.policy == "fifo"
    assert (tw.max_rps, tw.usd_per_hour, tw.base_latency_s) == (2.0, 0.05, 0.1)
    assert tw.kind == "simple"
    qw = QuickscalingTwin("q", 2.0, 0.05, 0.1)
    assert qw.policy == "quickscale" and qw.kind == "quickscaling"
    rf = roofline_twin("r", step_seconds=0.5, records_per_step=8, chips=4)
    assert rf.policy == "fifo" and rf.kind == "roofline"
    assert rf.max_rps == 16.0 and rf.usd_per_hour == 4 * 1.20


def test_make_twin_defaults_and_named_access():
    tw = make_twin("a", "autoscale", max_rps=1.0, usd_per_hour=0.01,
                   base_latency_s=0.1)
    assert tw.param("min_instances") == 1.0
    assert tw.param("max_instances") == 64.0
    tw2 = tw.with_params(scale_up_hours=4.0)
    assert tw2.param("scale_up_hours") == 4.0
    assert tw2.param("max_rps") == 1.0
    with pytest.raises(KeyError):
        make_twin("a", "autoscale", max_rps=1.0, usd_per_hour=0.01,
                  base_latency_s=0.1, bogus=1.0)
    with pytest.raises(KeyError):
        policy_spec("no-such-policy")
    padded = tw.padded_params()
    assert padded.shape == (PARAM_DIM,) and padded.dtype == np.float32


# ---------------------------------------------------------------------------
# conservation: processed + queued + dropped == arrived, per hour and total
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("twin", ALL_POLICY_TWINS,
                         ids=[t.policy for t in ALL_POLICY_TWINS])
def test_record_conservation(twin):
    sim = simulate_year(twin, LOADS)
    dq = np.diff(np.concatenate([[0.0], sim.queue]))
    resid = np.abs(sim.processed + dq + sim.dropped - ARRIVED)
    # hourly, to f32 roundoff of the largest quantity in flight
    scale = max(ARRIVED.max(), sim.queue.max(), 1.0)
    assert resid.max() <= 1e-5 * scale + 1e-2
    # and cumulatively over the year
    arrived = ARRIVED.sum()
    total = sim.processed.sum() + sim.queue[-1] + sim.dropped.sum()
    assert abs(total - arrived) / arrived < 1e-5


def test_dropped_zero_for_unbounded_policies():
    for twin in ALL_POLICY_TWINS:
        if twin.policy == "shed":
            continue
        sim = simulate_year(twin, LOADS)
        assert sim.dropped_records == 0.0
        assert sim.dropped.shape == (HOURS_PER_YEAR,)


# ---------------------------------------------------------------------------
# shed: bounded queue, drops only under overload, drop-rate SLO
# ---------------------------------------------------------------------------

def test_shed_bounds_queue_and_drops_overflow():
    cap_h = 1.0 * 3600.0
    tw = make_twin("s", "shed", max_rps=1.0, usd_per_hour=0.01,
                   base_latency_s=0.1, queue_cap_hours=2.0)
    sim = simulate_year(tw, LOADS)
    assert sim.queue.max() <= 2.0 * cap_h * (1 + 1e-6)
    assert sim.dropped_records > 0          # this load overruns 1 rps
    # a big enough pipeline never sheds
    big = make_twin("big", "shed", max_rps=10.0, usd_per_hour=0.01,
                    base_latency_s=0.1, queue_cap_hours=2.0)
    assert simulate_year(big, LOADS).dropped_records == 0.0


def test_drop_rate_slo():
    slo = SLO.for_drop_rate(max_fraction=0.01, met_fraction=0.95)
    small = make_twin("s", "shed", max_rps=0.5, usd_per_hour=0.01,
                      base_latency_s=0.1, queue_cap_hours=1.0)
    big = make_twin("b", "shed", max_rps=10.0, usd_per_hour=0.01,
                    base_latency_s=0.1, queue_cap_hours=1.0)
    assert simulate_year(small, LOADS, slo=slo).slo_met is False
    assert simulate_year(big, LOADS, slo=slo).slo_met is True


# ---------------------------------------------------------------------------
# autoscale: delay tradeoff + quickscale equivalence at zero delay
# ---------------------------------------------------------------------------

def test_autoscale_delay_cost_latency_tradeoff():
    """Slower scale-up -> fewer paid instance-hours but worse latency."""
    clouds, lats = [], []
    for d in [1.0, 2.0, 4.0, 8.0]:
        tw = make_twin("a", "autoscale", max_rps=0.35, usd_per_hour=0.01,
                       base_latency_s=0.1, min_instances=1,
                       max_instances=32, scale_up_hours=d)
        sim = simulate_year(tw, LOADS)
        clouds.append(sim.cost_usd.sum())
        lats.append(sim.mean_latency_s)
    assert all(a >= b for a, b in zip(clouds, clouds[1:])), clouds
    assert all(a <= b for a, b in zip(lats, lats[1:])), lats


def test_autoscale_instant_unbounded_matches_quickscale_cost():
    a = make_twin("a", "autoscale", max_rps=0.35, usd_per_hour=0.01,
                  base_latency_s=0.1, min_instances=1, max_instances=1e6,
                  scale_up_hours=1.0)
    sa = simulate_year(a, LOADS)
    sq = simulate_year(QuickscalingTwin("q", 0.35, 0.01, 0.1), LOADS)
    assert sa.queue.max() == 0.0
    assert np.isclose(sa.total_cost_usd, sq.total_cost_usd, rtol=1e-9)


def test_autoscale_min_instances_floor_cost():
    lo = make_twin("lo", "autoscale", max_rps=1.0, usd_per_hour=0.01,
                   base_latency_s=0.1, min_instances=1, max_instances=16)
    hi = lo.with_params(min_instances=4)
    s_lo, s_hi = simulate_year(lo, LOADS), simulate_year(hi, LOADS)
    assert s_hi.cost_usd.min() >= 4 * 0.01 - 1e-9
    assert s_hi.total_cost_usd >= s_lo.total_cost_usd
    assert s_hi.mean_latency_s <= s_lo.mean_latency_s + 1e-9


# ---------------------------------------------------------------------------
# batch_window: latency/cost tradeoff
# ---------------------------------------------------------------------------

def test_batch_window_latency_grows_cost_amortised():
    sims = []
    for w in [1.0, 4.0, 12.0]:
        tw = make_twin("b", "batch_window", max_rps=4.0, usd_per_hour=0.01,
                       base_latency_s=0.1, window_hours=w,
                       idle_cost_fraction=0.1)
        sims.append(simulate_year(tw, LOADS))
    lats = [s.mean_latency_s for s in sims]
    assert lats[0] < lats[1] < lats[2]
    # every record still gets processed across flushes
    for s in sims:
        assert s.processed.sum() + s.queue[-1] == pytest.approx(
            ARRIVED.sum(), rel=1e-5)
    # pay-per-use + keep-warm stays below the always-on fifo bill
    fifo = simulate_year(SimpleTwin("f", 4.0, 0.01, 0.1), LOADS)
    assert sims[1].total_cost_usd < fifo.total_cost_usd


# ---------------------------------------------------------------------------
# the vmapped grid: one trace, same numbers as batch-of-one
# ---------------------------------------------------------------------------

def test_run_grid_single_trace_all_policies():
    traffics = [TrafficModel.honda_default("nom"),
                TrafficModel.honda_default("high", G=1.5)]
    _grid_scan.clear_cache()
    sims = run_grid(ALL_POLICY_TWINS, traffics,
                    slo=SLO(limit_s=4 * 3600, met_fraction=0.95))
    assert len(sims) == len(ALL_POLICY_TWINS) * 2
    # the whole mixed-policy grid compiled exactly once
    assert _grid_scan._cache_size() == 1
    rows = table2_rows(sims)
    assert {r["run"] for r in rows} == {f"{tr} {tw.name}"
                                        for tr in ("nom", "high")
                                        for tw in ALL_POLICY_TWINS}
    for r in rows:
        assert np.isfinite(r["cost_usd"])


def test_grid_matches_batch_of_one():
    traffics = [TrafficModel.honda_default("nom"),
                TrafficModel.honda_default("high", G=1.5)]
    # per-bin series equality needs series mode (the aggregate default
    # returns scalars only; its parity is tests/test_grid_aggregate.py)
    sims = run_grid(ALL_POLICY_TWINS, traffics, return_series=True)
    k = 0
    for tr in traffics:
        loads = tr.hourly_loads()
        for tw in ALL_POLICY_TWINS:
            solo = simulate_year(tw, loads)
            assert np.array_equal(solo.processed, sims[k].processed)
            assert np.array_equal(solo.cost_usd, sims[k].cost_usd)
            assert np.array_equal(solo.dropped, sims[k].dropped)
            k += 1


def test_register_policy_extends_and_overrides():
    import repro.core.twin as T

    saved_registry = dict(T._REGISTRY)
    saved_version = T._VERSION
    try:
        @T.register_policy("null", ("max_rps", "usd_per_hour",
                                    "base_latency_s"))
        def _null_step(carry, arrive, p):
            """Processes nothing, pays nothing."""
            z = jnp.zeros(())
            return carry, (z, carry[0], p[2], z, z)

        tw = make_twin("n", "null", max_rps=1.0, usd_per_hour=0.01,
                       base_latency_s=0.1)
        sim = simulate_year(tw, LOADS)      # new branch reached via switch
        assert sim.processed.sum() == 0.0 and sim.cost_usd.sum() == 0.0

        # overriding keeps the switch index, so other policies still
        # dispatch to their own branch slots
        old_index = policy_spec("shed").index

        @T.register_policy("shed", ("max_rps", "usd_per_hour",
                                    "base_latency_s", "queue_cap_hours"),
                           defaults={"queue_cap_hours": 4.0})
        def _shed_v2(carry, arrive, p):
            """Drops everything immediately."""
            z = jnp.zeros(())
            return carry, (z, carry[0], p[2], p[1], arrive)

        assert policy_spec("shed").index == old_index
        batch = make_twin("b", "batch_window", max_rps=4.0,
                          usd_per_hour=0.01, base_latency_s=0.1)
        assert simulate_year(batch, LOADS).dropped_records == 0.0
        shed = make_twin("s", "shed", max_rps=1.0, usd_per_hour=0.01,
                         base_latency_s=0.1)
        sim = simulate_year(shed, LOADS)
        assert sim.dropped_records == pytest.approx(ARRIVED.sum(), rel=1e-6)
    finally:
        T._REGISTRY.clear()
        T._REGISTRY.update(saved_registry)
        T._VERSION = saved_version
        # drop traces that captured the overridden branch table — later
        # registrations would otherwise reuse them at a colliding version
        _grid_scan.clear_cache()


def test_fit_twin_policies(tmp_path):
    class R:  # minimal ExperimentResult stand-in
        pipeline_name = "p"
        sustained_rps = 3.0
        cost = {"usd_per_hour": 0.5}
        base_latency_s = 0.2

    tw = fit_twin(R(), "autoscale", max_instances=8)
    assert tw.policy == "autoscale" and tw.max_rps == 3.0
    assert tw.param("max_instances") == 8.0
    assert fit_twin(R(), "fifo").policy == "fifo"

"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests and
benches must see the real single CPU device (only launch/dryrun.py forces
512 placeholder devices, in its own process)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def host_mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(1, 1)


@pytest.fixture()
def tmp_ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")

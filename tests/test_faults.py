"""Chaos suite: the fault & outage scenario library end to end.

Acceptance contract of the fault subsystem (ISSUE 7):

* an EMPTY fault schedule is bit-identical to the pre-fault grid engine
  — aggregate mode, both backends (XLA and Pallas interpret), and under
  a ``devices=4`` scenario mesh;
* ``sample_futures`` is deterministic in (seed, spec names, horizon)
  and in nothing else — pinned across PYTHONHASHSEED values by running
  the sampler in subprocesses with different hash seeds;
* disconnect windows conserve records exactly: the reconnect flood
  replays precisely the mass the window removed, and the simulated
  grid's ``arrived == processed + dropped + queue_end`` ledger holds
  through outage + flood futures;
* chance-constrained search (``search(faults=..., quantile=q)``) on a
  closed-form toy schedule is feasible at ``achieved_quantile >= q``
  and STRICTLY cheaper than the worst-case (``quantile=1.0``) solution;
* fault-attribution columns (``fault_hours``, SLO-met split inside vs
  outside fault windows) come off the in-carry counters, and
  ``table2_rows`` only grows them on chaos grids;
* bad sampled series (negative / NaN multipliers) raise ``ValueError``
  naming the fault spec and bin index before any device work.
"""
import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import faults  # noqa: E402
from repro.core.simulate import simulate_grid  # noqa: E402
from repro.core.slo import SLO  # noqa: E402
from repro.core.traffic import TrafficModel  # noqa: E402
from repro.core.twin import SimpleTwin, make_twin  # noqa: E402
from repro.core.whatif import run_grid, table2_rows  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.search import achieved_quantile, search, search_space  # noqa: E402

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "before the first jax import")

T_WEEK = 168
SLO_4H = SLO(limit_s=4 * 3600, met_fraction=0.9)

TWINS = [
    SimpleTwin("fifo", 1.9512, 0.0082, 0.15),
    make_twin("auto", "autoscale", max_rps=0.5, usd_per_hour=0.002,
              base_latency_s=0.1, max_instances=32, scale_up_hours=3),
    make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.0082,
              base_latency_s=0.15, queue_cap_hours=2),
]
TRAFFICS = [TrafficModel.honda_default("nom"),
            TrafficModel.honda_default("high", G=1.4)]


def _grid_inputs(t_bins=T_WEEK):
    matrix = np.stack([tr.hourly_loads()[:t_bins] for tr in TRAFFICS]) \
        .astype(np.float32)
    index = np.repeat(np.arange(len(TRAFFICS), dtype=np.int32), len(TWINS))
    twins = [tw for _ in TRAFFICS for tw in TWINS]
    return twins, matrix, index


def _agg(twins, matrix, index, **kw):
    return simulate_grid(twins, slo=SLO_4H, return_series=False,
                         load_matrix=matrix, load_index=index,
                         bin_hours=1.0, **kw)


FIELDS = ("total_cost_usd", "queue_end", "pct_hours_met", "pct_latency_met",
          "dropped_records", "processed_records", "arrived_records",
          "median_latency_s", "p95_latency_s", "max_throughput_rph",
          "backlog_s")


def _assert_rows_equal(got, want, fields=FIELDS):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        for f in fields:
            assert getattr(g, f) == getattr(w, f), \
                f"{f} mismatch on {g.name}: {getattr(g, f)!r} " \
                f"!= {getattr(w, f)!r}"


# ---------------------------------------------------------------------------
# empty schedule == pre-fault engine, bit for bit
# ---------------------------------------------------------------------------

def test_empty_schedule_bit_parity_xla():
    twins, matrix, index = _grid_inputs()
    plain = _agg(twins, matrix, index)
    empty = faults.FaultSchedule(specs=(), n_futures=3, seed=0)
    chaos = _agg(twins, matrix, index, faults=empty)
    assert len(chaos) == 3 * len(plain)
    for i, p in enumerate(plain):
        for f in range(3):
            row = chaos[i * 3 + f]
            assert row.name == f"{p.name}/f{f}"
            assert row.fault_hours == 0.0
            assert row.pct_hours_met_in_fault == 100.0
            _assert_rows_equal([row], [p])


def test_empty_schedule_bit_parity_pallas():
    twins, matrix, index = _grid_inputs()
    empty = faults.FaultSchedule(specs=(), n_futures=2, seed=0)
    plain = _agg(twins, matrix, index)
    with ops.pallas_mode():
        chaos = _agg(twins, matrix, index, faults=empty)
    for i, p in enumerate(plain):
        for f in range(2):
            _assert_rows_equal([chaos[i * 2 + f]], [p])


def test_chaos_grid_pallas_matches_xla_and_blocked():
    twins, matrix, index = _grid_inputs()
    sched = faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=200, duration_hours=(2, 6)),
               faults.disconnect(rate_per_year=150),
               faults.brownout(rate_per_year=150)),
        n_futures=3, seed=7)
    anchor = _agg(twins, matrix, index, faults=sched)
    blocked = _agg(twins, matrix, index, faults=sched, scenario_block=4)
    _assert_rows_equal(blocked, anchor)
    with ops.pallas_mode():
        pallas = _agg(twins, matrix, index, faults=sched, scenario_block=4)
    _assert_rows_equal(pallas, anchor)


@needs4
def test_empty_schedule_bit_parity_devices4():
    twins, matrix, index = _grid_inputs()
    plain = _agg(twins, matrix, index, scenario_block=4, devices=4)
    empty = faults.FaultSchedule(specs=(), n_futures=2, seed=0)
    chaos = _agg(twins, matrix, index, faults=empty, scenario_block=4,
                 devices=4)
    for i, p in enumerate(plain):
        for f in range(2):
            _assert_rows_equal([chaos[i * 2 + f]], [p])


@needs4
def test_chaos_grid_devices4_matches_single():
    twins, matrix, index = _grid_inputs()
    sched = faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=200, duration_hours=(2, 6)),
               faults.disconnect(rate_per_year=150)),
        n_futures=3, seed=11)
    single = _agg(twins, matrix, index, faults=sched)
    sharded = _agg(twins, matrix, index, faults=sched, scenario_block=4,
                   devices=4)
    _assert_rows_equal(sharded, single)


def test_series_mode_cross_checks_aggregate():
    twins, matrix, index = _grid_inputs()
    sched = faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=120, duration_hours=(3, 8)),),
        n_futures=2, seed=3)
    agg = _agg(twins, matrix, index, faults=sched)
    series = simulate_grid(twins, slo=SLO_4H, return_series=True,
                           load_matrix=matrix, load_index=index,
                           bin_hours=1.0, faults=sched)
    assert len(series) == len(agg)
    for s, a in zip(series, agg):
        assert s.name == a.name
        assert s.total_cost_usd == pytest.approx(a.total_cost_usd,
                                                 rel=1e-5)
        assert float(s.queue[-1]) == pytest.approx(a.queue_end, rel=1e-4,
                                                   abs=1e-3)


# ---------------------------------------------------------------------------
# seeded sampler: deterministic, PYTHONHASHSEED-independent
# ---------------------------------------------------------------------------

_SAMPLER_SNIPPET = """
import sys, zlib
import numpy as np
sys.path.insert(0, {src!r})
from repro import faults
s = faults.sample_futures(faults.FaultSchedule(
    specs=(faults.outage(rate_per_year=30),
           faults.disconnect(rate_per_year=40),
           faults.brownout(rate_per_year=30),
           faults.burst(rate_per_year=30)),
    n_futures=4, seed=123), 720, 1.0)
digest = zlib.crc32(s.cap.tobytes()
                    + s.mask.tobytes()
                    + s.load_mult.tobytes()
                    + repr(s.events).encode())
print(digest)
"""


def test_sampler_deterministic_across_hashseed():
    import os
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    outs = []
    for hashseed in ("0", "4242"):
        env = dict(os.environ, PYTHONHASHSEED=hashseed)
        r = subprocess.run(
            [sys.executable, "-c", _SAMPLER_SNIPPET.format(src=src)],
            capture_output=True, text=True, env=env)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout.strip())
    assert outs[0] == outs[1]


def test_sampler_in_process_repeatable_and_seed_sensitive():
    sched = faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=30),), n_futures=4, seed=5)
    a = faults.sample_futures(sched, 720, 1.0)
    b = faults.sample_futures(sched, 720, 1.0)
    np.testing.assert_array_equal(a.cap, b.cap)
    np.testing.assert_array_equal(a.load_mult, b.load_mult)
    assert a.events == b.events
    c = faults.sample_futures(
        faults.FaultSchedule(specs=sched.specs, n_futures=4, seed=6),
        720, 1.0)
    assert not (np.array_equal(a.cap, c.cap) and a.events == c.events)


def test_sampler_per_spec_streams_independent():
    """Adding a second spec must not move the first spec's events."""
    one = faults.sample_futures(faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=30),), n_futures=3, seed=9),
        720, 1.0)
    two = faults.sample_futures(faults.FaultSchedule(
        specs=(faults.burst(rate_per_year=50),
               faults.outage(rate_per_year=30)), n_futures=3, seed=9),
        720, 1.0)
    for f in range(3):
        a = [e for e in one.events[f] if e["spec"] == "outage"]
        b = [e for e in two.events[f] if e["spec"] == "outage"]
        assert a == b


# ---------------------------------------------------------------------------
# reconnect floods conserve records
# ---------------------------------------------------------------------------

def test_disconnect_replay_conserves_mass():
    sched = faults.FaultSchedule(
        specs=(faults.disconnect(rate_per_year=400, flood_hours=2.0),),
        n_futures=6, seed=1)
    s = faults.sample_futures(sched, T_WEEK, 1.0)
    assert s.has_load_faults.any(), "toy schedule sampled no disconnects"
    row = TRAFFICS[0].hourly_loads()[:T_WEEK]
    pert = s.apply_loads(row)
    for f in range(s.n_futures):
        assert pert[f].sum() == pytest.approx(row.sum(), rel=1e-12)
        if s.replay[f]:   # flood future: mass moved, not lost
            assert np.any(pert[f] != row)


def test_chaos_grid_record_ledger_balances():
    twins, matrix, index = _grid_inputs()
    sched = faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=150, duration_hours=(2, 8)),
               faults.disconnect(rate_per_year=200)),
        n_futures=4, seed=2)
    for row in _agg(twins, matrix, index, faults=sched):
        ledger = (row.processed_records + row.dropped_records
                  + row.queue_end)
        assert ledger == pytest.approx(row.arrived_records, rel=1e-6)


# ---------------------------------------------------------------------------
# chance-constrained search: closed-form toy, chance beats worst case
# ---------------------------------------------------------------------------

def _toy_faults(t_bins=336):
    """5 handcrafted futures over a flat load: benign, 3x short outage,
    1x long outage. With a latency SLO allowing 15% violating hours,
    the short-outage futures recover cheaply but the long-outage future
    needs ~4x the capacity — so quantile=0.8 (4 of 5 futures) is
    strictly cheaper than the worst-case quantile=1.0 solution."""
    F = 5
    cap = np.ones((F, t_bins), np.float32)
    mask = np.zeros((F, t_bins), np.float32)
    for f, (start, dur) in enumerate([(60, 10), (140, 10), (230, 10)],
                                     start=1):
        cap[f, start:start + dur] = 0.0
        mask[f, start:start + dur] = 1.0
    cap[4, 100:140] = 0.0
    mask[4, 100:140] = 1.0
    windows = [(), ((60, 70),), ((140, 150),), ((230, 240),),
               ((100, 140),)]
    events = tuple(
        tuple({"spec": "toy-outage", "kind": "outage", "start": a,
               "end": b} for a, b in wins)
        for wins in windows)
    return faults.SampledFaults(
        cap=cap, mask=mask, load_mult=np.ones((F, t_bins), np.float64),
        replay=((),) * F, events=events, n_futures=F, t_bins=t_bins,
        bin_hours=1.0, seed=0)


def test_chance_constrained_beats_worst_case():
    t_bins = 336
    loads = np.full((1, t_bins), 300.0, np.float32)
    slo = SLO(limit_s=5.0, met_fraction=0.85)
    base = make_twin("base", "fifo", max_rps=1.0, usd_per_hour=4.0,
                     base_latency_s=0.05)
    space = search_space(base, ("max_rps",),
                         bounds={"max_rps": (0.05, 1.5)},
                         tie={"usd_per_hour": ("max_rps", 4.0)})
    toy = _toy_faults(t_bins)
    worst = search(space, loads=loads, bin_hours=1.0, slo=slo,
                   faults=toy, quantile=1.0, restarts=6, steps=80, seed=0)
    chance = search(space, loads=loads, bin_hours=1.0, slo=slo,
                    faults=toy, quantile=0.8, restarts=6, steps=80, seed=0)
    assert worst.feasible and chance.feasible
    assert worst.achieved_quantile == pytest.approx(1.0)
    assert chance.achieved_quantile >= 0.8 - 1e-9
    assert chance.cost_usd < worst.cost_usd, \
        (chance.cost_usd, worst.cost_usd)
    assert chance.quantile == 0.8 and chance.n_futures == 5
    # the 80% config really does sacrifice the long-outage future: its
    # exact quantile sits below 1 (else worst-case would cost the same)
    assert chance.achieved_quantile < 1.0


def test_achieved_quantile_shape():
    rows = [type("R", (), {"slo_met": m})()
            for m in (True, True, False, True,   # scen 0: 3/4
                      True, True, True, True)]   # scen 1: 4/4
    assert achieved_quantile(rows, 2, 4) == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# fault attribution columns
# ---------------------------------------------------------------------------

def test_fault_attribution_counters_and_table2():
    sched = faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=300, duration_hours=(4, 12)),),
        n_futures=3, seed=4)
    sims = run_grid(TWINS[:1], TRAFFICS[:1], slo=SLO_4H, faults=sched)
    s = faults.sample_futures(
        sched, TRAFFICS[0].hourly_loads().shape[0], 1.0)
    assert any(r.fault_hours > 0 for r in sims)
    for f, row in enumerate(sims):
        assert row.fault_hours == pytest.approx(float(s.mask[f].sum()))
    rows = table2_rows(sims)
    for r in rows:
        assert {"fault_hours", "pct_hours_met_in_fault",
                "pct_hours_met_outside_fault"} <= set(r)
    # benign tables keep the seed's exact column set
    benign = table2_rows(run_grid(TWINS[:1], TRAFFICS[:1], slo=SLO_4H))
    assert "fault_hours" not in benign[0]


# ---------------------------------------------------------------------------
# input validation: bad series raise with spec name + bin index
# ---------------------------------------------------------------------------

def _hand_sampled(cap=None, load_mult=None, t_bins=24):
    F = 1
    c = np.ones((F, t_bins), np.float32) if cap is None else cap
    lm = (np.ones((F, t_bins), np.float64) if load_mult is None
          else load_mult)
    events = (({"spec": "bad-spec", "kind": "outage", "start": 0,
                "end": t_bins},),)
    return faults.SampledFaults(
        cap=c, mask=np.zeros((F, t_bins), np.float32), load_mult=lm,
        replay=((),), events=events, n_futures=F, t_bins=t_bins,
        bin_hours=1.0, seed=0)


def test_negative_capacity_raises_named():
    cap = np.ones((1, 24), np.float32)
    cap[0, 7] = -0.25
    with pytest.raises(ValueError, match=r"bin 7.*bad-spec"):
        simulate_grid([TWINS[0]], slo=SLO_4H, return_series=False,
                      load_matrix=np.full((1, 24), 100.0, np.float32),
                      load_index=np.zeros(1, np.int32), bin_hours=1.0,
                      faults=_hand_sampled(cap=cap))


def test_nan_load_multiplier_raises_named():
    lm = np.ones((1, 24), np.float64)
    lm[0, 3] = np.nan
    with pytest.raises(ValueError, match=r"non-finite at bin 3.*bad-spec"):
        faults.validate_sampled(_hand_sampled(load_mult=lm))


def test_tbins_mismatch_and_bad_type_raise():
    twins, matrix, index = _grid_inputs()
    with pytest.raises(ValueError, match="covers 12 bins"):
        _agg(twins, matrix, index, faults=_hand_sampled(t_bins=12))
    with pytest.raises(TypeError, match="FaultSchedule"):
        _agg(twins, matrix, index, faults={"not": "a schedule"})
    with pytest.raises(TypeError):
        search(TWINS[0], loads=matrix[:1], bin_hours=1.0, slo=SLO_4H,
               faults=object())

"""The O(√T) checkpointed custom VJP vs plain autodiff-through-scan.

Acceptance contract of ``kernels.policy_vjp``:

* the primal is BIT-IDENTICAL to ``ref.policy_grid_scan`` — carry and
  all five series, both selector forms, surrogate included (the custom
  rule changes nothing unless a gradient is requested);
* ``jax.grad`` cotangents (params, loads, onehot) match plain autodiff
  of the reference scan within the repo's guarded 1e-5 relative
  contract, for all five policies, on horizons the segment plan splits
  evenly AND ones with a tail segment, at hourly and sub-hour bins,
  surrogate on and off, under jit;
* ``kernels.ops.policy_scan`` routes differentiable scans through the
  checkpointed VJP when the bin width is static, and falls back to the
  plain reference scan when it is traced — same numbers either way.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.traffic import TrafficModel  # noqa: E402
from repro.core.twin import (QuickscalingTwin, SimpleTwin,  # noqa: E402
                             make_twin, policy_names, policy_onehot)
from repro.kernels import ops, policy_vjp, ref  # noqa: E402
from repro.kernels.policy_vjp import (_segment_plan,  # noqa: E402
                                      policy_grid_scan_ckpt)

ALL_POLICY_TWINS = [
    SimpleTwin("fifo", 1.9512, 0.0082, 0.15),
    QuickscalingTwin("quick", 1.9512, 0.0082, 0.15),
    make_twin("auto", "autoscale", max_rps=0.5, usd_per_hour=0.002,
              base_latency_s=0.1, max_instances=32, scale_up_hours=3),
    make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.0082,
              base_latency_s=0.15, queue_cap_hours=2),
    make_twin("batch", "batch_window", max_rps=6.15, usd_per_hour=0.0703,
              base_latency_s=0.06, window_hours=6),
]

#: mixed weights keep the scalar loss sensitive to every output series
W = (1.0, 0.7, 1.3, -0.5, 0.9)


def _mixed(n, t_bins):
    twins = [ALL_POLICY_TWINS[i % len(ALL_POLICY_TWINS)] for i in range(n)]
    hl = TrafficModel.honda_default("nom").hourly_loads()[:t_bins]
    loads = np.stack([hl * (1.0 + 0.1 * i) for i in range(n)]) \
        .astype(np.float32)
    params = np.stack([tw.padded_params() for tw in twins]) \
        .astype(np.float32)
    idx = np.asarray([tw.policy_index for tw in twins], np.int32)
    return loads, params, idx


def _loss(fn, dt=1.0, surrogate=False, **sel):
    def f(loads, params, *extra):
        kw = dict(sel)
        if "onehot" in kw and kw["onehot"] is None:
            kw["onehot"] = extra[0]
        carry, outs = fn(loads, params, kw.pop("onehot", None), dt,
                         surrogate=surrogate, **kw)
        return (sum(w * jnp.sum(o) for w, o in zip(W, outs))
                + jnp.sum(carry))
    return f


def _assert_grads_close(a, b, rtol=1e-5, what=""):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    denom = np.maximum(np.abs(b), 1e-6 * max(np.abs(b).max(), 1.0))
    rel = np.abs(a - b) / denom
    assert rel.max() <= rtol, (what, rel.max())


# ---------------------------------------------------------------------------
# primal parity: the custom rule must change nothing forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("surrogate", [False, True])
def test_forward_bit_identical_to_ref_mixed_grid(surrogate):
    loads, params, idx = _mixed(5, 257)
    onehot = policy_onehot(idx)
    c_r, outs_r = ref.policy_grid_scan(loads, params, onehot, 1.0,
                                       surrogate=surrogate)
    c_k, outs_k = policy_grid_scan_ckpt(loads, params, onehot, 1.0,
                                        surrogate=surrogate)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_forward_bit_identical_uniform_index_subhour():
    loads, params, _ = _mixed(4, 97)
    for j, tw in enumerate(ALL_POLICY_TWINS):
        p = np.tile(tw.padded_params(), (4, 1)).astype(np.float32)
        c_r, outs_r = ref.policy_grid_scan(loads, p, None, 1.0 / 60.0,
                                           policy_index=jnp.int32(j))
        c_k, outs_k = policy_grid_scan_ckpt(loads, p, None, 1.0 / 60.0,
                                            policy_index=jnp.int32(j))
        np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
        for a, b in zip(outs_k, outs_r):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# gradient parity vs plain autodiff-through-scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t_bins", [97, 256])
def test_grad_parity_mixed_onehot(t_bins):
    # 97 leaves a tail segment (9*10+7); 256 splits evenly (16*16)
    seg, nseg, tail = _segment_plan(t_bins)
    assert (tail > 0) == (t_bins == 97)
    loads, params, idx = _mixed(5, t_bins)
    onehot = policy_onehot(idx).astype(np.float32)
    args = (jnp.asarray(loads), jnp.asarray(params), jnp.asarray(onehot))
    g_ref = jax.grad(_loss(ref.policy_grid_scan, onehot=None),
                     argnums=(0, 1, 2))(*args)
    g_ckpt = jax.grad(_loss(policy_grid_scan_ckpt, onehot=None),
                      argnums=(0, 1, 2))(*args)
    for name, a, b in zip(("loads", "params", "onehot"), g_ckpt, g_ref):
        _assert_grads_close(a, b, what=(name, t_bins))


@pytest.mark.parametrize("surrogate", [False, True])
def test_grad_parity_uniform_index_all_policies_jit(surrogate):
    loads, _, _ = _mixed(4, 97)
    for j, tw in enumerate(ALL_POLICY_TWINS):
        p = np.tile(tw.padded_params(), (4, 1)).astype(np.float32)
        sel = dict(policy_index=jnp.int32(j))
        g_ref = jax.jit(jax.grad(
            _loss(ref.policy_grid_scan, dt=0.25, surrogate=surrogate,
                  **sel), argnums=(0, 1)))(jnp.asarray(loads),
                                           jnp.asarray(p))
        g_ckpt = jax.jit(jax.grad(
            _loss(policy_grid_scan_ckpt, dt=0.25, surrogate=surrogate,
                  **sel), argnums=(0, 1)))(jnp.asarray(loads),
                                           jnp.asarray(p))
        for name, a, b in zip(("loads", "params"), g_ckpt, g_ref):
            _assert_grads_close(a, b, what=(policy_names()[j], name,
                                            surrogate))


def test_segment_plan_shapes():
    for t in (1, 2, 97, 100, 256, 8736):
        seg, nseg, tail = _segment_plan(t)
        assert seg * nseg + tail == t
        assert seg >= 1 and nseg >= 1 and 0 <= tail < seg


def test_selector_ambiguity_rejected():
    loads, params, idx = _mixed(3, 10)
    with pytest.raises(ValueError, match="exactly one"):
        policy_grid_scan_ckpt(loads, params)
    with pytest.raises(ValueError, match="exactly one"):
        policy_grid_scan_ckpt(loads, params, policy_onehot(idx),
                              policy_index=jnp.int32(0))


# ---------------------------------------------------------------------------
# ops.policy_scan routing: ckpt when dt is static, ref when traced
# ---------------------------------------------------------------------------

def test_ops_routes_differentiable_scan_through_ckpt(monkeypatch):
    loads, params, idx = _mixed(5, 97)
    onehot = policy_onehot(idx)
    calls = []
    orig = policy_vjp.policy_grid_scan_ckpt

    def spy(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(policy_vjp, "policy_grid_scan_ckpt", spy)
    c, outs = ops.policy_scan(loads, params, onehot, 1.0,
                              differentiable=True)
    assert calls, "static-dt differentiable scan must use the ckpt VJP"
    c_r, outs_r = ref.policy_grid_scan(loads, params, onehot, 1.0)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_r))
    for a, b in zip(outs, outs_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ops_traced_dt_falls_back_to_ref(monkeypatch):
    loads, params, idx = _mixed(3, 50)
    onehot = policy_onehot(idx)

    def boom(*a, **k):                      # must never be reached
        raise AssertionError("ckpt VJP called with a traced bin width")

    monkeypatch.setattr(policy_vjp, "policy_grid_scan_ckpt", boom)

    @jax.jit
    def total(dt):
        _, outs = ops.policy_scan(loads, params, onehot, dt,
                                  differentiable=True)
        return sum(jnp.sum(o) for o in outs)

    traced = total(jnp.float32(1.0))
    monkeypatch.setattr(policy_vjp, "policy_grid_scan_ckpt",
                        policy_grid_scan_ckpt)
    _, outs = ops.policy_scan(loads, params, onehot, 1.0,
                              differentiable=True)
    np.testing.assert_allclose(float(traced),
                               float(sum(jnp.sum(o) for o in outs)),
                               rtol=1e-6)

"""Device-resident histogram parity: the grid engines vs the host oracle.

The streaming-aggregate grid accumulates its quarter-octave latency
histogram ON DEVICE — an exact f64 ``segment_sum`` per time chunk on the
XLA path, compensated in-kernel triples on Pallas — and
``np_latency_histogram`` survives only as the parity oracle. These tests
pin the acceptance contract of that change:

* the histogram block of every engine's aggregate rows is BIT-IDENTICAL
  to ``np_latency_histogram`` over the series path's latency panel, for
  all five registered policies, on XLA and Pallas (interpret), through
  the chunked block driver, on a ``devices=4`` mesh, and on a chaos grid
  (``faults=``);
* no [B, T]-shaped intermediate exists anywhere in the XLA driver's
  computation (checked on the traced jaxpr, not just the output pytree)
  and the sharded round step returns O(B) aggregates only;
* bitwise-duplicate scenario rows — benign fault futures, tiled
  tournament grids — are simulated ONCE and their summary rows
  replicated (the dispatch-level ``_dedup_rows`` pass).

Mesh cases need ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
before the first jax import (the CI multi-device job exports it);
without it they skip.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
from jax.experimental import enable_x64  # noqa: E402

from repro import faults  # noqa: E402
from repro.core import simulate  # noqa: E402
from repro.core.simulate import (_agg_scan_uniform,  # noqa: E402
                                 _agg_scan_uniform_fault, _grid_agg_dispatch,
                                 _grid_scan, _grid_scan_fault_xla,
                                 _sharded_agg_fn, simulate_grid)
from repro.core.traffic import TrafficModel  # noqa: E402
from repro.core.twin import (AGG_DIM, AGG_SCALARS,  # noqa: E402
                             CARRY_DIM, QuickscalingTwin, SimpleTwin,
                             make_twin, np_latency_histogram,
                             registry_version)
from repro.kernels import ops  # noqa: E402

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "before the first jax import")

ALL_POLICY_TWINS = [
    SimpleTwin("fifo", 1.9512, 0.0082, 0.15),
    QuickscalingTwin("quick", 1.9512, 0.0082, 0.15),
    make_twin("auto", "autoscale", max_rps=0.5, usd_per_hour=0.002,
              base_latency_s=0.1, max_instances=32, scale_up_hours=3),
    make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.0082,
              base_latency_s=0.15, queue_cap_hours=2),
    make_twin("batch", "batch_window", max_rps=6.15, usd_per_hour=0.0703,
              base_latency_s=0.06, window_hours=6),
]
TRAFFICS = [TrafficModel.honda_default("nom"),
            TrafficModel.honda_default("high", G=1.5)]

#: one-month horizon keeps the matrix fast; the engine treats the horizon
#: as opaque, so parity here is parity on the year
T_MONTH = 744

CHAOS = faults.FaultSchedule(
    specs=(faults.outage(rate_per_year=40),
           faults.disconnect(disconnect_frac=(0.2, 0.5))),
    n_futures=5, seed=3)


def _grid_arrays(n, t_bins=T_MONTH):
    twins = [ALL_POLICY_TWINS[i % len(ALL_POLICY_TWINS)] for i in range(n)]
    matrix = np.stack([tr.hourly_loads()[:t_bins] for tr in TRAFFICS]) \
        .astype(np.float32)
    index = np.arange(n, dtype=np.int32) % len(TRAFFICS)
    params = np.stack([tw.padded_params() for tw in twins])
    idx = np.asarray([tw.policy_index for tw in twins], np.int32)
    return twins, matrix, index, params, idx


def _oracle_hist(matrix, index, params, idx):
    """Host-oracle histogram: bin the SERIES path's latency panel with
    ``np_latency_histogram`` — exactly what the old engine shipped."""
    loads = matrix[index]
    _, (_, _, lat, _, _) = _grid_scan(
        jnp.asarray(loads), jnp.asarray(params), jnp.asarray(idx),
        registry_version(), 1.0)
    return np_latency_histogram(np.asarray(lat), loads)


# ---------------------------------------------------------------------------
# bit-parity vs the host oracle: all five policies, every engine
# ---------------------------------------------------------------------------

def test_device_hist_bit_identical_xla_all_policies():
    n = 10      # two scenarios per registered policy
    _, matrix, index, params, idx = _grid_arrays(n)
    oracle = _oracle_hist(matrix, index, params, idx)
    # unchunked and chunked drivers — the chunked one exercises the
    # donated block engine and the O(B·BINS) accumulator scatter
    for block in (None, 4):
        _, agg = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                    float("inf"), 0, block)
        np.testing.assert_array_equal(
            agg[:, AGG_SCALARS:].astype(np.float32), oracle)


def test_device_hist_bit_identical_pallas_all_policies():
    n = 10
    _, matrix, index, params, idx = _grid_arrays(n)
    oracle = _oracle_hist(matrix, index, params, idx)
    with ops.pallas_mode(interpret=True):
        for block in (None, 4):
            _, agg = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                        float("inf"), 0, block)
            np.testing.assert_array_equal(
                agg[:, AGG_SCALARS:].astype(np.float32), oracle)


@needs4
def test_device_hist_bit_identical_devices_4():
    n = 10
    _, matrix, index, params, idx = _grid_arrays(n)
    oracle = _oracle_hist(matrix, index, params, idx)
    _, agg = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                float("inf"), 0, 4, devices=4)
    np.testing.assert_array_equal(
        agg[:, AGG_SCALARS:].astype(np.float32), oracle)
    with ops.pallas_mode(interpret=True):
        _, agg_p = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                      float("inf"), 0, 4, devices=4)
    np.testing.assert_array_equal(
        agg_p[:, AGG_SCALARS:].astype(np.float32), oracle)


def test_device_hist_bit_identical_chaos_grid():
    n = 6
    _, matrix, index, params, idx = _grid_arrays(n)
    sampled = faults.sample_futures(CHAOS, T_MONTH, 1.0)
    fg = faults.expand_grid(sampled, matrix, index)
    nf = fg.n_futures
    params_f = np.repeat(params, nf, axis=0)
    idx_f = np.repeat(idx, nf)
    fault = (fg.cap, fg.fmask, fg.fault_index)

    # chaos oracle: the fault SERIES path's latency panel, host-binned
    # weighted by the (load-fault-perturbed) arrive series
    loads = fg.load_matrix[fg.load_index]
    caps = fg.cap[fg.fault_index]
    _, (_, _, lat, _, _) = _grid_scan_fault_xla(
        jnp.asarray(loads), jnp.asarray(caps), jnp.asarray(params_f),
        jnp.asarray(idx_f), registry_version(), 1.0)
    oracle = np_latency_histogram(np.asarray(lat), loads)

    for block in (None, 4):
        _, agg = _grid_agg_dispatch(fg.load_matrix, fg.load_index, params_f,
                                    idx_f, 1.0, float("inf"), 0, block,
                                    fault=fault)
        np.testing.assert_array_equal(
            agg[:, AGG_SCALARS:].astype(np.float32), oracle)
    with ops.pallas_mode(interpret=True):
        _, agg_p = _grid_agg_dispatch(fg.load_matrix, fg.load_index,
                                      params_f, idx_f, 1.0, float("inf"),
                                      0, 4, fault=fault)
    np.testing.assert_array_equal(
        agg_p[:, AGG_SCALARS:].astype(np.float32), oracle)


# ---------------------------------------------------------------------------
# no [B, T] intermediate anywhere in the device-resident XLA driver
# ---------------------------------------------------------------------------

def _collect_shapes(jaxpr, out):
    """Every intermediate/output aval shape in the jaxpr, recursively."""
    from jax._src import core as jcore
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v.aval, "shape"):
                out.add(tuple(v.aval.shape))
        for p in eqn.params.values():
            cj = getattr(p, "jaxpr", None)
            if isinstance(p, jcore.ClosedJaxpr):
                _collect_shapes(p.jaxpr, out)
            elif cj is not None:
                _collect_shapes(cj, out)
    return out


def test_no_bt_intermediate_in_xla_driver():
    # T must exceed the 1024-bin time-chunk cap, else one chunk IS the
    # horizon; 2048 gives two 1024-bin chunks
    t_bins, k, b = 2048, 3, 7
    matrix = jnp.ones((k, t_bins), jnp.float32)
    lidx = jnp.zeros((b,), jnp.int32)
    params = jnp.ones((b, 6), jnp.float32)
    with enable_x64():
        jaxpr = jax.make_jaxpr(
            lambda m, li, p: _agg_scan_uniform(m, li, p, 0, 1.0,
                                               float("inf"), 0))(
            matrix, lidx, params)
        shapes = _collect_shapes(jaxpr.jaxpr, set())
        assert (b, t_bins) not in shapes, "a [B, T] panel is staged"
        jaxpr_f = jax.make_jaxpr(
            lambda m, li, c, f, fi, p: _agg_scan_uniform_fault(
                m, li, c, f, fi, p, 0, 1.0, float("inf"), 0))(
            matrix, lidx, jnp.ones((4, t_bins), jnp.float32),
            jnp.zeros((4, t_bins), jnp.float32), jnp.zeros((b,), jnp.int32),
            params)
        shapes_f = _collect_shapes(jaxpr_f.jaxpr, set())
        assert (b, t_bins) not in shapes_f, "a [B, T] fault panel is staged"


def test_sharded_round_step_outputs_are_o_n():
    block = 8
    _, matrix, index, params, _ = _grid_arrays(block)
    p_block = np.tile(ALL_POLICY_TWINS[0].padded_params(),
                      (block, 1)).astype(np.float32)
    fn = _sharded_agg_fn(1, registry_version(), 1.0, float("inf"), 0,
                         "xla", True, block)
    with enable_x64():
        out = fn(jnp.asarray(matrix), jnp.asarray(index[None]),
                 jnp.asarray(p_block[None]), jnp.asarray([0], np.int32))
    shapes = [tuple(leaf.shape) for leaf in jax.tree_util.tree_leaves(out)]
    assert shapes == [(1, block, CARRY_DIM), (1, block, AGG_DIM)]
    assert all(T_MONTH not in s for s in shapes)


# ---------------------------------------------------------------------------
# duplicate-scenario dedup: one scan per distinct scenario, replicated rows
# ---------------------------------------------------------------------------

def test_benign_futures_simulated_once(monkeypatch):
    n = 4
    twins, matrix, index, params, idx = _grid_arrays(n)
    # a sparse schedule leaves several futures event-free (benign); their
    # (cap, fmask) rows are bitwise identical, so _dedup_rows inside the
    # dispatch collapses them to one simulated row per base scenario
    sparse = faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=2),), n_futures=8, seed=1)
    sampled = faults.sample_futures(sparse, T_MONTH, 1.0)
    benign = faults.benign_futures(sampled)
    assert benign.sum() > 1, "seed must produce >1 benign futures"

    calls = []
    real_scan = simulate._grid_scan_agg

    def spy(loads, *args, **kw):
        calls.append(int(loads.shape[0]))
        return real_scan(loads, *args, **kw)

    monkeypatch.setattr(simulate, "_grid_scan_agg", spy)
    rows = simulate_grid(twins, load_matrix=matrix, load_index=index,
                         return_series=False, bin_hours=1.0, faults=sampled)
    nf = sampled.n_futures
    expected = n * (nf - int(benign.sum()) + 1)
    assert calls == [expected]          # one scan over the deduped rows
    assert len(rows) == n * nf          # ...but every row reported

    # replicated rows are bit-identical to a dedup-disabled dispatch
    monkeypatch.setattr(simulate, "_grid_scan_agg", real_scan)
    monkeypatch.setattr(simulate, "_dedup_rows", lambda *a, **kw: None)
    fg = faults.expand_grid(sampled, matrix, index)
    carry_full, agg_full = _grid_agg_dispatch(
        fg.load_matrix, fg.load_index, np.repeat(params, nf, axis=0),
        np.repeat(idx, nf), 1.0, float("inf"), 0, None,
        fault=(fg.cap, fg.fmask, fg.fault_index))
    from repro.core.simulate import _summarise_aggregates
    full = _summarise_aggregates(
        [f"{tw.name}/f{f}" for tw in twins for f in range(nf)],
        [tw for tw in twins for _ in range(nf)], carry_full[:, 0],
        agg_full, None, None, 0.0, 1.0, T_MONTH, fg.load_matrix,
        fg.load_index)
    for got, want in zip(rows, full):
        for k, u in vars(got).items():
            v = vars(want)[k]
            if isinstance(u, np.ndarray):
                np.testing.assert_array_equal(u, v)
            elif isinstance(u, float) and np.isnan(u):
                assert np.isnan(v)
            else:
                assert u == v, (k, u, v)


def test_tiled_tournament_deduped_and_replicated(monkeypatch):
    """A grid that re-runs identical (load, params, policy) rows — the
    tournament-baseline shape — is simulated once per distinct scenario
    and replicated bit-identically, with no fault grid in play."""
    n = 6
    _, matrix, index, params, idx = _grid_arrays(n)
    reps = 4
    index_t = np.tile(index, reps)
    params_t = np.tile(params, (reps, 1))
    idx_t = np.tile(idx, reps)

    calls = []
    real_scan = simulate._grid_scan_agg

    def spy(loads, *args, **kw):
        calls.append(int(loads.shape[0]))
        return real_scan(loads, *args, **kw)

    monkeypatch.setattr(simulate, "_grid_scan_agg", spy)
    carry, agg = _grid_agg_dispatch(matrix, index_t, params_t, idx_t,
                                    1.0, float("inf"), 0, None)
    assert calls == [n]                 # 4x-tiled grid -> n distinct scans
    assert carry.shape[0] == n * reps and agg.shape[0] == n * reps
    for r in range(1, reps):
        np.testing.assert_array_equal(agg[r * n:(r + 1) * n], agg[:n])
        np.testing.assert_array_equal(carry[r * n:(r + 1) * n], carry[:n])

    # and the replica block equals a dedup-disabled run of the base grid
    monkeypatch.setattr(simulate, "_grid_scan_agg", real_scan)
    monkeypatch.setattr(simulate, "_dedup_rows", lambda *a, **kw: None)
    carry_base, agg_base = _grid_agg_dispatch(matrix, index, params, idx,
                                              1.0, float("inf"), 0, None)
    np.testing.assert_array_equal(agg[:n], agg_base)
    np.testing.assert_array_equal(carry[:n], carry_base)

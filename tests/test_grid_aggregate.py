"""The streaming-aggregate grid backend vs the full-series ``_summarise``.

Acceptance contract of the O(N)-memory refactor:

* aggregate-mode sums (cost / processed / dropped), the per-bin max, the
  end-of-scan queue and the SLO percentages are BIT-IDENTICAL to the
  series-path summaries (the twice-compensated carry triples recombine to
  numpy's f64 sums exactly), across all five registered policies and both
  backends (XLA switch-scan and Pallas interpret);
* the histogram median and drop-rate SLO stats agree within histogram-bin
  / boundary tolerance of the numpy sort/cumsum path;
* aggregate mode never materializes a [N, T] series — asserted on the
  returned pytree shapes for every backend, chunked dispatch included;
* chunked megabatch dispatch (``lax.map`` over scenario blocks, load
  matrix + index map) returns the same numbers as the unchunked call.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.simulate import (GridSummary, _grid_agg_dispatch,  # noqa: E402
                                 _grid_scan_agg, simulate_grid)
from repro.core.slo import SLO  # noqa: E402
from repro.core.traffic import HOURS_PER_YEAR, TrafficModel  # noqa: E402
from repro.core.twin import (AGG_DIM, AGG_HIST_W, CARRY_DIM,  # noqa: E402
                             QuickscalingTwin, SimpleTwin, make_twin,
                             policy_onehot, registry_version)
from repro.core.whatif import run_grid, run_scenarios, Scenario  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.kernels.policy_scan import policy_grid_agg  # noqa: E402

SLO_4H = SLO(limit_s=4 * 3600, met_fraction=0.95)

#: one twin per registered policy — parity must hold for every branch
ALL_POLICY_TWINS = [
    SimpleTwin("fifo", 1.9512, 0.0082, 0.15),
    QuickscalingTwin("quick", 1.9512, 0.0082, 0.15),
    make_twin("auto", "autoscale", max_rps=0.5, usd_per_hour=0.002,
              base_latency_s=0.1, max_instances=32, scale_up_hours=3),
    make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.0082,
              base_latency_s=0.15, queue_cap_hours=2),
    make_twin("batch", "batch_window", max_rps=6.15, usd_per_hour=0.0703,
              base_latency_s=0.06, window_hours=6),
]
TRAFFICS = [TrafficModel.honda_default("nom"),
            TrafficModel.honda_default("high", G=1.5)]

#: the histogram median's representative is a bucket center, so it sits
#: within one log-spaced bucket of the true (sort/cumsum) median
MEDIAN_RATIO_TOL = 10.0 ** AGG_HIST_W * (1 + 1e-6)


def _series_vs_aggregate(series, aggs):
    assert len(series) == len(aggs)
    for s, a in zip(series, aggs):
        assert isinstance(a, GridSummary)
        assert s.name == a.name and s.twin == a.twin
        yield s, a


def _assert_scalar_parity(series, aggs, exact=True):
    close = (np.testing.assert_array_equal if exact else
             lambda x, y: np.testing.assert_allclose(x, y, rtol=1e-6))
    for s, a in _series_vs_aggregate(series, aggs):
        close(a.total_cost_usd, s.total_cost_usd)
        close(a.backlog_s, s.backlog_s)
        close(a.backlog_cost_usd, s.backlog_cost_usd)
        close(a.max_throughput_rph, s.max_throughput_rph)
        close(a.mean_throughput_rph, s.mean_throughput_rph)
        close(a.dropped_records, s.dropped_records)
        close(a.processed_records, np.float64(s.processed).sum())
        close(a.arrived_records, np.float64(s.load).sum())
        close(a.queue_end, s.queue[-1])
        np.testing.assert_allclose(a.mean_latency_s, s.mean_latency_s,
                                   rtol=1e-5)
        ratio = a.median_latency_s / max(s.median_latency_s, 1e-12)
        assert 1.0 / MEDIAN_RATIO_TOL <= ratio <= MEDIAN_RATIO_TOL, \
            (s.name, a.median_latency_s, s.median_latency_s)


# ---------------------------------------------------------------------------
# aggregate-vs-series parity: all five policies, both backends
# ---------------------------------------------------------------------------

def test_aggregate_bit_identical_to_series_xla_all_policies():
    series = run_grid(ALL_POLICY_TWINS, TRAFFICS, slo=SLO_4H,
                      return_series=True)
    aggs = run_grid(ALL_POLICY_TWINS, TRAFFICS, slo=SLO_4H)
    _assert_scalar_parity(series, aggs, exact=True)
    for s, a in _series_vs_aggregate(series, aggs):
        # pct_* go through the identical f64 ratio, so exact too
        assert a.pct_latency_met == s.pct_latency_met
        assert a.pct_hours_met == s.pct_hours_met
        assert a.slo_met == s.slo_met


def test_aggregate_matches_series_under_pallas_mode():
    series = run_grid(ALL_POLICY_TWINS, TRAFFICS, slo=SLO_4H,
                      return_series=True)
    with ops.pallas_mode():
        aggs = run_grid(ALL_POLICY_TWINS, TRAFFICS, slo=SLO_4H)
    # the Pallas lane blend performs the same additions; empirically it
    # matches the switch-scan bit for bit, but only 1e-6 is contractual
    _assert_scalar_parity(series, aggs, exact=False)
    for s, a in _series_vs_aggregate(series, aggs):
        assert a.slo_met == s.slo_met


def test_aggregate_without_slo_defaults_like_series():
    series = run_grid(ALL_POLICY_TWINS, TRAFFICS[:1], return_series=True)
    aggs = run_grid(ALL_POLICY_TWINS, TRAFFICS[:1])
    for s, a in _series_vs_aggregate(series, aggs):
        assert a.slo_met is None and s.slo_met is None
        assert a.pct_latency_met == 100.0 and a.pct_hours_met == 100.0


def test_drop_rate_slo_aggregate_parity():
    slo = SLO.for_drop_rate(max_fraction=0.01, met_fraction=0.9)
    twins = [make_twin(f"shed{h}", "shed", max_rps=0.8, usd_per_hour=0.008,
                       base_latency_s=0.15, queue_cap_hours=h)
             for h in (0.5, 2.0, 8.0)]
    series = run_grid(twins, TRAFFICS, slo=slo, return_series=True)
    aggs = run_grid(twins, TRAFFICS, slo=slo)
    for s, a in _series_vs_aggregate(series, aggs):
        # the ok-mass is summed exactly, but the f32 in-carry drop
        # fraction can flip bins sitting exactly on the limit — allow a
        # whisker while requiring the decision pattern to match
        np.testing.assert_allclose(a.pct_latency_met, s.pct_latency_met,
                                   atol=0.05)
        np.testing.assert_allclose(a.pct_hours_met, s.pct_hours_met,
                                   atol=0.05)
        assert a.slo_met == s.slo_met
        np.testing.assert_array_equal(a.dropped_records, s.dropped_records)


def test_storage_costs_via_load_matrix_index_map():
    cm_twins = [SimpleTwin("a", 2.0, 0.01, 0.1),
                SimpleTwin("b", 4.0, 0.02, 0.1)]
    from repro.core.cost import CostModel
    cm = CostModel()
    series = run_grid(cm_twins, TRAFFICS, cost_model=cm, record_mb=0.001,
                      return_series=True)
    aggs = run_grid(cm_twins, TRAFFICS, cost_model=cm, record_mb=0.001)
    for s, a in _series_vs_aggregate(series, aggs):
        np.testing.assert_allclose(a.network_cost_usd, s.network_cost_usd,
                                   rtol=1e-12)
        np.testing.assert_allclose(a.storage_cost_usd, s.storage_cost_usd,
                                   rtol=1e-12)
        np.testing.assert_allclose(a.grand_total_usd, s.grand_total_usd,
                                   rtol=1e-12)


# ---------------------------------------------------------------------------
# O(N) memory: no [N, T] output ever exists in aggregate mode
# ---------------------------------------------------------------------------

def _grid_arrays(n):
    twins = [ALL_POLICY_TWINS[i % len(ALL_POLICY_TWINS)] for i in range(n)]
    matrix = np.stack([tr.hourly_loads() for tr in TRAFFICS]).astype(
        np.float32)
    index = np.arange(n, dtype=np.int32) % len(TRAFFICS)
    params = np.stack([tw.padded_params() for tw in twins])
    idx = np.asarray([tw.policy_index for tw in twins], np.int32)
    return twins, matrix, index, params, idx


def test_aggregate_pytree_has_no_series_axis():
    n = 12
    _, matrix, index, params, idx = _grid_arrays(n)
    loads = jnp.asarray(matrix[index])
    t_bins = loads.shape[1]

    def assert_o_n(outs):
        for leaf in jax.tree_util.tree_leaves(outs):
            assert leaf.shape[0] in (n, -(-n // 8) * 8)   # N or lane pad
            assert t_bins not in leaf.shape, leaf.shape
            assert leaf.ndim <= 2 and leaf.size <= n * 8 * AGG_DIM

    # every aggregate backend's result contract is O(N): the XLA path
    # (device-resident histogram), the jnp lane oracle, the Pallas
    # kernel, and the chunked lax.map dispatch
    assert_o_n(_grid_scan_agg(loads, jnp.asarray(params),
                              jnp.asarray(idx), registry_version(),
                              1.0, float("inf"), 0))
    assert_o_n(ref.policy_grid_agg(loads, jnp.asarray(params),
                                   jnp.asarray(policy_onehot(idx)), 1.0))
    assert_o_n(policy_grid_agg(loads, jnp.asarray(params),
                               jnp.asarray(policy_onehot(idx)), 1.0,
                               interpret=True))
    carry_end, agg = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                        float("inf"), 0, scenario_block=5)
    assert carry_end.shape == (n, CARRY_DIM) and agg.shape == (n, AGG_DIM)


def test_grid_summary_rows_carry_no_series():
    aggs = run_grid(ALL_POLICY_TWINS, TRAFFICS, slo=SLO_4H)
    for a in aggs:
        arrays = [v for v in vars(a).values() if isinstance(v, np.ndarray)]
        assert all(v.size <= AGG_DIM for v in arrays)   # histogram only


# ---------------------------------------------------------------------------
# chunked megabatch dispatch == unchunked
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block", [5, 8, 24])
def test_chunked_dispatch_matches_unchunked(block):
    # n=24: block 5 exercises tail padding, 8 even blocks, 24 one block
    n = 24
    twins, matrix, index, params, idx = _grid_arrays(n)
    base_c, base_a = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                        float(SLO_4H.limit_s), 0, None)
    chunk_c, chunk_a = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                          float(SLO_4H.limit_s), 0, block)
    np.testing.assert_array_equal(chunk_c, base_c)
    np.testing.assert_array_equal(chunk_a, base_a)


def test_chunked_dispatch_matches_under_pallas():
    n = 13
    twins, matrix, index, params, idx = _grid_arrays(n)
    with ops.pallas_mode():
        base_c, base_a = _grid_agg_dispatch(matrix, index, params, idx,
                                            1.0, float("inf"), 0, None)
        chunk_c, chunk_a = _grid_agg_dispatch(matrix, index, params, idx,
                                              1.0, float("inf"), 0, 4)
    np.testing.assert_allclose(chunk_c, base_c, rtol=1e-6)
    np.testing.assert_allclose(chunk_a, base_a, rtol=1e-6)


def test_simulate_grid_chunked_end_to_end():
    n = 10
    twins, matrix, index, _, _ = _grid_arrays(n)
    base = simulate_grid(twins, load_matrix=matrix, load_index=index,
                         slo=SLO_4H, return_series=False)
    chunked = simulate_grid(twins, load_matrix=matrix, load_index=index,
                            slo=SLO_4H, return_series=False,
                            scenario_block=3)
    for b, c in zip(base, chunked):
        assert b.total_cost_usd == c.total_cost_usd
        assert b.median_latency_s == c.median_latency_s
        assert b.slo_met == c.slo_met


# ---------------------------------------------------------------------------
# load matrix + index map plumbing
# ---------------------------------------------------------------------------

def test_run_grid_series_and_matrix_paths_agree():
    # the matrix/index grid must equal the old stacked-loads grid
    series = run_grid(ALL_POLICY_TWINS, TRAFFICS, slo=SLO_4H,
                      return_series=True)
    k = 0
    for tr in TRAFFICS:
        loads = tr.hourly_loads().astype(np.float32)[None]
        for tw in ALL_POLICY_TWINS:
            solo = simulate_grid([tw], loads, slo=SLO_4H)[0]
            assert series[k].total_cost_usd == solo.total_cost_usd
            np.testing.assert_array_equal(series[k].processed,
                                          solo.processed)
            k += 1


def test_run_scenarios_aggregate_default_and_dedup():
    tr = TRAFFICS[0]
    scens = [Scenario("s1", ALL_POLICY_TWINS[0], tr),
             Scenario("s2", ALL_POLICY_TWINS[1], tr),
             Scenario("s3", ALL_POLICY_TWINS[0], TRAFFICS[1])]
    aggs = run_scenarios(scens, slo=SLO_4H)
    assert [a.name for a in aggs] == ["s1", "s2", "s3"]
    assert all(isinstance(a, GridSummary) for a in aggs)
    series = run_scenarios(scens, slo=SLO_4H, return_series=True)
    for s, a in zip(series, aggs):
        assert s.total_cost_usd == a.total_cost_usd


def test_simulate_grid_matrix_input_validation():
    tw = SimpleTwin("s", 1.0, 0.01, 0.1)
    year = np.ones((1, HOURS_PER_YEAR), np.float32)
    with pytest.raises(ValueError, match="exactly one"):
        simulate_grid([tw], year, load_matrix=year,
                      load_index=np.zeros(1, np.int32))
    with pytest.raises(ValueError, match="exactly one"):
        simulate_grid([tw])
    with pytest.raises(ValueError, match="load_index"):
        simulate_grid([tw], load_matrix=year)
    with pytest.raises(ValueError, match="out of range"):
        simulate_grid([tw], load_matrix=year,
                      load_index=np.asarray([3], np.int32))
    with pytest.raises(ValueError, match="twins"):
        simulate_grid([tw, tw], load_matrix=year,
                      load_index=np.zeros(1, np.int32))
    for bad_block in (0, -4096):
        with pytest.raises(ValueError, match="scenario_block"):
            simulate_grid([tw], year, return_series=False,
                          scenario_block=bad_block)
    # series mode can't honor a chunked memory bound — loud, not silent
    with pytest.raises(ValueError, match="scenario_block"):
        simulate_grid([tw], year, return_series=True, scenario_block=8)

"""The sharded million-scenario grid engine vs the one-device anchors.

Acceptance contract of the scenario-axis sharding refactor:

* ``devices=D`` dispatch is BIT-IDENTICAL to both the single-device
  chunked engine and the unchunked anchor — including a tail where N is
  divisible by neither the block size nor the device count, across all
  five registered policies and both backends (XLA and Pallas interpret);
* the ``shard_map`` round step runs the same policy-uniform aggregate
  scan per shard that the one-device engine runs (unit-checked on a
  1-device mesh, so this holds in every environment);
* ``_agg_block_plan`` produces policy-uniform blocks that cover each
  scenario exactly once, in stable per-policy order;
* ``agg_auto_block`` derives the streamed block size from the horizon
  length, dtype, and staged-panel count against the ~150 MB budget —
  the device-resident XLA path (``panels=0``) budgets its [B, chunk]
  transients + aggregate rows, not a [B, T] panel it no longer stages;
* replication fall-backs in ``distributed.sharding`` warn once, loudly.

Multi-device cases need ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
exported before the first jax import (the CI multi-device job does);
without it they skip rather than sharding a 1-device mesh.
"""
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.simulate import (AGG_AUTO_BLOCK,  # noqa: E402
                                 AGG_BLOCK_BUDGET_BYTES, _agg_block_plan,
                                 _agg_scan_uniform, _grid_agg_dispatch,
                                 _sharded_agg_fn, agg_auto_block,
                                 simulate_grid)
from repro.core.slo import SLO  # noqa: E402
from repro.core.traffic import HOURS_PER_YEAR, TrafficModel  # noqa: E402
from repro.core.twin import (AGG_DIM, CARRY_DIM,  # noqa: E402
                             QuickscalingTwin, SimpleTwin, make_twin,
                             registry_version)
from repro.core.whatif import run_grid  # noqa: E402
from repro.distributed import sharding  # noqa: E402
from repro.kernels import ops  # noqa: E402

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "before the first jax import")

SLO_4H = SLO(limit_s=4 * 3600, met_fraction=0.95)

ALL_POLICY_TWINS = [
    SimpleTwin("fifo", 1.9512, 0.0082, 0.15),
    QuickscalingTwin("quick", 1.9512, 0.0082, 0.15),
    make_twin("auto", "autoscale", max_rps=0.5, usd_per_hour=0.002,
              base_latency_s=0.1, max_instances=32, scale_up_hours=3),
    make_twin("shed", "shed", max_rps=1.0, usd_per_hour=0.0082,
              base_latency_s=0.15, queue_cap_hours=2),
    make_twin("batch", "batch_window", max_rps=6.15, usd_per_hour=0.0703,
              base_latency_s=0.06, window_hours=6),
]
TRAFFICS = [TrafficModel.honda_default("nom"),
            TrafficModel.honda_default("high", G=1.5)]

#: one-month horizon keeps the parity matrix fast; the engine treats the
#: horizon as opaque, so parity here is parity on the year
T_MONTH = 744


def _grid_arrays(n, t_bins=T_MONTH):
    twins = [ALL_POLICY_TWINS[i % len(ALL_POLICY_TWINS)] for i in range(n)]
    matrix = np.stack([tr.hourly_loads()[:t_bins] for tr in TRAFFICS]) \
        .astype(np.float32)
    index = np.arange(n, dtype=np.int32) % len(TRAFFICS)
    params = np.stack([tw.padded_params() for tw in twins])
    idx = np.asarray([tw.policy_index for tw in twins], np.int32)
    return twins, matrix, index, params, idx


# ---------------------------------------------------------------------------
# block-size budget: derived from horizon length + dtype
# ---------------------------------------------------------------------------

def test_agg_auto_block_derives_from_horizon_and_budget():
    from repro.core.simulate import _agg_time_chunk

    # device-resident default (panels=0): the per-row working set is the
    # scan pipeline's [B, chunk] transients (6 buffers) + the AGG_DIM
    # aggregate row, NOT a [B, T] panel — year blocks grow past the old
    # panel-bound 4480
    block = agg_auto_block(HOURS_PER_YEAR)
    assert block == AGG_AUTO_BLOCK
    assert block % 128 == 0
    per_row = (6 * _agg_time_chunk(HOURS_PER_YEAR) + 4 * AGG_DIM) * 4
    assert block * per_row <= AGG_BLOCK_BUDGET_BYTES
    assert (block + 128) * per_row > AGG_BLOCK_BUDGET_BYTES
    assert block > agg_auto_block(HOURS_PER_YEAR, panels=1)

    # panel-staging backends (Pallas) declare their panel count; one
    # benign [B, T] panel fits the budget tight, and a chaos grid's
    # three panels (loads_t + caps_t + fmask_t) shrink the block ~3x —
    # the historical under-budgeting bug was counting only one
    p1 = agg_auto_block(HOURS_PER_YEAR, panels=1)
    assert p1 % 128 == 0
    assert p1 * HOURS_PER_YEAR * 4 <= AGG_BLOCK_BUDGET_BYTES
    assert (p1 + 128) * HOURS_PER_YEAR * 4 > AGG_BLOCK_BUDGET_BYTES
    p3 = agg_auto_block(HOURS_PER_YEAR, panels=3)
    assert p3 * HOURS_PER_YEAR * 4 * 3 <= AGG_BLOCK_BUDGET_BYTES
    assert (p3 + 128) * HOURS_PER_YEAR * 4 * 3 > AGG_BLOCK_BUDGET_BYTES

    # wider dtypes halve the panel block; shorter horizons grow it
    assert agg_auto_block(HOURS_PER_YEAR, dtype_bytes=8,
                          panels=1) <= p1 // 2 + 128
    assert agg_auto_block(HOURS_PER_YEAR // 4, panels=1) >= 4 * p1 - 512
    # clamps: calibration-length horizons cap at 65536 lanes, pathological
    # horizons never chunk below one lane group
    assert agg_auto_block(1, panels=1) == 65536
    assert agg_auto_block(10 ** 9, panels=1) == 128
    # panel-free blocks stop scaling with the horizon once the time
    # chunking caps the transient width — a pathological horizon still
    # streams thousands of scenarios per block instead of 128
    assert agg_auto_block(10 ** 9) == agg_auto_block(10 ** 6)
    assert 128 <= agg_auto_block(1) <= 65536


# ---------------------------------------------------------------------------
# policy-uniform block plan
# ---------------------------------------------------------------------------

def test_agg_block_plan_covers_each_scenario_once_policy_uniform():
    rng = np.random.default_rng(7)
    idx = rng.integers(0, 5, size=23).astype(np.int32)
    positions, block_policy = _agg_block_plan(idx, block=5)
    assert positions.shape[1] == 5
    assert positions.shape[0] == len(block_policy)
    flat = positions.reshape(-1)
    valid = flat[flat >= 0]
    # exactly-once cover
    np.testing.assert_array_equal(np.sort(valid), np.arange(23))
    for b in range(positions.shape[0]):
        row = positions[b][positions[b] >= 0]
        # every block is single-policy and matches its label
        assert row.size > 0
        np.testing.assert_array_equal(idx[row], block_policy[b])
    # stable: within one policy, scenarios keep grid order
    for p in np.unique(idx):
        mine = valid[idx[valid] == p]
        np.testing.assert_array_equal(mine, np.where(idx == p)[0])


def test_agg_block_plan_empty_grid():
    positions, block_policy = _agg_block_plan(np.zeros(0, np.int32), 4)
    assert positions.shape == (0, 4) and block_policy.size == 0


# ---------------------------------------------------------------------------
# the shard_map round step: unit parity on a 1-device mesh (any env)
# ---------------------------------------------------------------------------

def test_sharded_round_step_matches_uniform_scan_one_device():
    from jax.experimental import enable_x64

    block = 8
    _, matrix, index, params, _ = _grid_arrays(block)
    lidx = index.astype(np.int32)
    p_block = np.tile(ALL_POLICY_TWINS[0].padded_params(),
                      (block, 1)).astype(np.float32)
    fn = _sharded_agg_fn(1, registry_version(), 1.0, float("inf"), 0,
                         "xla", True, block)
    # the round step keeps the histogram in-body and traces f64; every
    # call site enters under enable_x64 (see _run_blocks_sharded)
    with enable_x64():
        carry, agg = fn(jnp.asarray(matrix), jnp.asarray(lidx[None]),
                        jnp.asarray(p_block[None]),
                        jnp.asarray([0], np.int32))
        ref_c, ref_a = _agg_scan_uniform(
            jnp.asarray(matrix), jnp.asarray(lidx), jnp.asarray(p_block),
            0, 1.0, float("inf"), 0)
    assert np.asarray(agg).shape == (1, block, AGG_DIM)  # no [B, T] output
    np.testing.assert_array_equal(np.asarray(carry[0]), np.asarray(ref_c))
    np.testing.assert_array_equal(np.asarray(agg[0]), np.asarray(ref_a))


# ---------------------------------------------------------------------------
# sharded dispatch == chunked == unchunked, bit for bit (4-device mesh)
# ---------------------------------------------------------------------------

@needs4
def test_sharded_dispatch_bit_identical_xla_all_policies():
    # n=23 is divisible by neither block=5 nor devices=4: per-policy tail
    # pads AND a dummy-block round both execute
    n = 23
    _, matrix, index, params, idx = _grid_arrays(n)
    base_c, base_a = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                        float(SLO_4H.limit_s), 0, None)
    chunk_c, chunk_a = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                          float(SLO_4H.limit_s), 0, 5)
    shard_c, shard_a = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                          float(SLO_4H.limit_s), 0, 5,
                                          devices=4)
    np.testing.assert_array_equal(chunk_c, base_c)
    np.testing.assert_array_equal(chunk_a, base_a)
    np.testing.assert_array_equal(shard_c, base_c)
    np.testing.assert_array_equal(shard_a, base_a)
    assert shard_c.shape == (n, CARRY_DIM) and shard_a.shape == (n, AGG_DIM)


@needs4
def test_sharded_dispatch_bit_identical_pallas():
    n = 23
    _, matrix, index, params, idx = _grid_arrays(n)
    with ops.pallas_mode():
        chunk_c, chunk_a = _grid_agg_dispatch(matrix, index, params, idx,
                                              1.0, float("inf"), 0, 5)
        shard_c, shard_a = _grid_agg_dispatch(matrix, index, params, idx,
                                              1.0, float("inf"), 0, 5,
                                              devices=4)
    np.testing.assert_array_equal(shard_c, chunk_c)
    np.testing.assert_array_equal(shard_a, chunk_a)


@needs4
def test_sharded_dispatch_uneven_rounds_devices_2():
    # 3 policy blocks over 2 devices: one dummy pad block, two rounds
    n = 11
    _, matrix, index, params, idx = _grid_arrays(n)
    base_c, base_a = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                        float("inf"), 0, None)
    shard_c, shard_a = _grid_agg_dispatch(matrix, index, params, idx, 1.0,
                                          float("inf"), 0, 4, devices=2)
    np.testing.assert_array_equal(shard_c, base_c)
    np.testing.assert_array_equal(shard_a, base_a)


@needs4
def test_simulate_grid_devices_end_to_end():
    n = 10
    twins, matrix, index, _, _ = _grid_arrays(n, t_bins=HOURS_PER_YEAR)
    base = simulate_grid(twins, load_matrix=matrix, load_index=index,
                         slo=SLO_4H, return_series=False)
    shard = simulate_grid(twins, load_matrix=matrix, load_index=index,
                          slo=SLO_4H, return_series=False,
                          scenario_block=4, devices=4)
    for b, s in zip(base, shard):
        assert b.total_cost_usd == s.total_cost_usd
        assert b.median_latency_s == s.median_latency_s
        assert b.pct_latency_met == s.pct_latency_met
        assert b.slo_met == s.slo_met


@needs4
def test_run_grid_devices_passthrough():
    base = run_grid(ALL_POLICY_TWINS, TRAFFICS, slo=SLO_4H)
    shard = run_grid(ALL_POLICY_TWINS, TRAFFICS, slo=SLO_4H,
                     scenario_block=4, devices=4)
    for b, s in zip(base, shard):
        assert b.name == s.name
        assert b.total_cost_usd == s.total_cost_usd


# ---------------------------------------------------------------------------
# devices= validation: loud, before any dispatch
# ---------------------------------------------------------------------------

def test_simulate_grid_devices_validation():
    tw = SimpleTwin("s", 1.0, 0.01, 0.1)
    year = np.ones((1, HOURS_PER_YEAR), np.float32)
    with pytest.raises(ValueError, match="streaming-aggregate"):
        simulate_grid([tw], year, return_series=True, devices=1)
    with pytest.raises(ValueError, match="devices"):
        simulate_grid([tw], year, return_series=False, devices=0)
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        simulate_grid([tw], year, return_series=False,
                      devices=jax.device_count() + 1)


# ---------------------------------------------------------------------------
# replication fall-backs warn once, naming axis and sizes
# ---------------------------------------------------------------------------

def test_replication_fallback_warns_once_per_site():
    sharding._REPLICATION_WARNED.clear()
    with pytest.warns(RuntimeWarning, match="replication"):
        sharding._warn_replicated("test(x)", "scenario", 23, 4)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        sharding._warn_replicated("test(x)", "scenario", 23, 4)
    assert not caught                      # identical fall-back: silent
    with pytest.warns(RuntimeWarning, match="mesh axis 'scenario'"):
        sharding._warn_replicated("test(x)", "scenario", 25, 4)


@needs4
def test_constrain_indivisible_dim_warns_and_replicates():
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:4]), ("d",))
    sharding._REPLICATION_WARNED.clear()
    sharding.set_activation_mesh(mesh, {"batch": "d"})
    try:
        x = jnp.zeros((6, 3))              # 6 % 4 != 0 -> replicate + warn
        with pytest.warns(RuntimeWarning, match="NO parallelism"):
            y = sharding.constrain(x, "batch", None)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    finally:
        sharding.set_activation_mesh(None)


# ---------------------------------------------------------------------------
# scenario-minor staging: loads_t= operands equal loads= on both kernels
# ---------------------------------------------------------------------------

def test_kernel_loads_t_staging_matches_loads():
    from repro.core.twin import policy_onehot
    from repro.kernels.policy_scan import policy_grid_agg, policy_grid_scan
    n = 13
    _, matrix, index, params, idx = _grid_arrays(n, t_bins=97)
    loads = matrix[index]
    onehot = policy_onehot(idx)
    a = policy_grid_agg(loads, params, onehot, 1.0, interpret=True)
    b = policy_grid_agg(None, params, onehot, 1.0, interpret=True,
                        loads_t=np.ascontiguousarray(loads.T))
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    sa = policy_grid_scan(loads, params, onehot, 1.0, interpret=True)
    sb = policy_grid_scan(None, params, onehot, 1.0, interpret=True,
                          loads_t=np.ascontiguousarray(loads.T))
    for x, y in zip(jax.tree_util.tree_leaves(sa),
                    jax.tree_util.tree_leaves(sb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for f in (policy_grid_scan, policy_grid_agg):
        with pytest.raises(ValueError, match="exactly one"):
            f(None, params, onehot, 1.0, interpret=True)
        with pytest.raises(ValueError, match="exactly one"):
            f(loads, params, onehot, 1.0, interpret=True,
              loads_t=loads.T)

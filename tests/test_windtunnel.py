"""Wind-tunnel harness: spans, metrics, load patterns, experiments, twins."""
import time

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.datagen import DataGenerator
from repro.core.experiment import Experiment
from repro.core.loadpattern import LoadPattern, Segment
from repro.core.metrics import MetricStore
from repro.core.pipeline import Pipeline, PipelineStage, Resources
from repro.core.schema import Schema, FieldSpec, telemetry_schema, token_stream_schema
from repro.core.spans import SpanCollector, span
from repro.core.twin import fit_simple_twin


# ---------------------------------------------------------------------------
# load patterns
# ---------------------------------------------------------------------------

def test_ramp_total_records():
    lp = LoadPattern.ramp("r", duration_s=120, peak_rate=40)
    assert abs(lp.total_records - 2400) < 1e-6      # paper's 120s 0->40 ramp


@settings(max_examples=30, deadline=None)
@given(d=st.floats(1.0, 50.0), r0=st.floats(0.0, 100.0),
       r1=st.floats(0.0, 100.0), split=st.floats(0.1, 0.9))
def test_records_between_additive(d, r0, r1, split):
    lp = LoadPattern("x", (Segment(d, r0, r1),))
    t = d * split
    a = lp.records_between(0, t, n=200) + lp.records_between(t, d, n=200)
    b = lp.records_between(0, d, n=400)
    assert abs(a - b) < max(0.02 * b, 0.5)


def test_rate_interpolation():
    lp = LoadPattern("x", (Segment(10, 0, 100), Segment(10, 50, 50)))
    assert abs(lp.rate_at(5) - 50) < 1e-9
    assert abs(lp.rate_at(15) - 50) < 1e-9
    assert lp.rate_at(25) == 0.0


# ---------------------------------------------------------------------------
# schema / datagen
# ---------------------------------------------------------------------------

def test_datagen_deterministic_and_constrained():
    schema = telemetry_schema()
    g = DataGenerator(seed=1)
    ds1 = g.generate(schema, 50)
    ds2 = DataGenerator(seed=1).generate(schema, 50)
    np.testing.assert_array_equal(ds1.columns["speed_kph"],
                                  ds2.columns["speed_kph"])
    assert (ds1.columns["speed_kph"] >= 0).all()
    assert (ds1.columns["speed_kph"] <= 200).all()
    lat = ds1.columns["location"][:, 0]
    assert (lat > 35).all() and (lat < 45).all()   # land box, not mid-ocean


def test_token_stream_zipfian():
    schema = token_stream_schema(vocab_size=1000, seq_len=64)
    ds = DataGenerator(seed=0).generate(schema, 100)
    toks = ds.columns["tokens"]
    assert toks.shape == (100, 64)
    assert toks.min() >= 0 and toks.max() < 1000
    # Zipf: token 0 must dominate a uniform share by far
    freq0 = (toks == 0).mean()
    assert freq0 > 10 / 1000


# ---------------------------------------------------------------------------
# spans / metrics
# ---------------------------------------------------------------------------

def test_span_nesting_and_summary():
    col = SpanCollector()
    with span("outer", col, records=10):
        with span("inner", col, records=10):
            time.sleep(0.01)
    s = col.summary()
    assert s["outer"]["records"] == 10
    assert s["inner"]["mean_latency_s"] >= 0.001 / 10
    assert s["outer"]["busy_s"] >= s["inner"]["busy_s"]


def test_metric_store_rate_and_quantile():
    ms = MetricStore()
    for i in range(10):
        ms.inc("count", 5, t=float(i))
        ms.observe("lat", float(i), t=float(i))
    assert abs(ms.rate("count", window_s=100) - 5.0) < 1e-6
    assert ms.quantile("lat", 0.5) == 5.0
    assert ms.mean("lat") == 4.5


def test_metric_store_jsonl_roundtrip(tmp_path):
    ms = MetricStore()
    ms.observe("a", 1.0, t=0.0)
    ms.observe("a", 2.0, t=1.0)
    p = str(tmp_path / "m.jsonl")
    ms.dump_jsonl(p)
    ms2 = MetricStore.load_jsonl(p)
    assert ms2.values("a") == [1.0, 2.0]


# ---------------------------------------------------------------------------
# pipeline + experiment end-to-end with a KNOWN capacity
# ---------------------------------------------------------------------------

def _rate_limited_pipeline(service_s: float) -> Pipeline:
    def work(batch):
        time.sleep(service_s)
        return batch

    return Pipeline("calibrated", [PipelineStage("only_stage", work)],
                    resources=Resources(vcpus=1, ram_gb=1))


def test_experiment_measures_known_capacity():
    service = 0.01                       # 100 rec/s capacity
    pipe = _rate_limited_pipeline(service)
    schema = Schema("one", (FieldSpec("x", "float"),))
    ds = DataGenerator(0).generate(schema, 100)
    # drive well over capacity so the bottleneck shows
    load = LoadPattern.steady("over", duration_s=1.5, rate=300)
    exp = Experiment("cal", pipe, load, ds, drain_timeout_s=30)
    res = exp.run()
    assert res.drained
    tw = fit_simple_twin(res)
    # sustained throughput within 40% of the known 100 rec/s (sleep jitter)
    assert 55 < tw.max_rps < 145, tw.max_rps
    assert res.records_sent == pytest.approx(450, abs=2)
    assert tw.usd_per_hour > 0


def test_experiment_engaged_serially():
    pipe = _rate_limited_pipeline(0.001)
    schema = Schema("one", (FieldSpec("x", "float"),))
    ds = DataGenerator(0).generate(schema, 10)
    load = LoadPattern.steady("s", 0.2, 50)
    e = Experiment("a", pipe, load, ds)
    r = e.run()
    assert e.status == "completed"
    assert r.cost["total_usd"] > 0


def test_pipeline_queue_backlog_visible():
    pipe = _rate_limited_pipeline(0.05)   # 20 rec/s
    pipe.start()
    for i in range(20):
        pipe.submit({"x": i}, records=1)
    depth = pipe.inflight
    assert depth > 5                      # backlog forms
    assert pipe.drain(timeout=10)
    pipe.stop()

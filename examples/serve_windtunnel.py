"""Serve a small model with batched requests under a shaped LoadPattern and
measure it with the wind tunnel; then forecast a year of request traffic
against the fitted twin (the paper's business loop, for an LLM serving
pipeline instead of a telemetry pipeline).

Run:  PYTHONPATH=src python examples/serve_windtunnel.py
"""
import numpy as np
import jax

from repro.config import ParallelConfig
from repro.configs import get_smoke_config
from repro.core.slo import SLO
from repro.core.simulate import simulate_year
from repro.core.traffic import TrafficModel
from repro.core.twin import SimpleTwin
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine

cfg = get_smoke_config("llama3.2-1b")
mesh = make_host_mesh(1, 1)
params = M.init_params(cfg, jax.random.PRNGKey(0))
engine = ServeEngine(cfg, mesh, ParallelConfig(batch_axes=("data",)), params,
                     slots=4, max_len=128, chips=0)

# request trace shaped like a poisson-ish ramp, 6 req/s peak
rng = np.random.default_rng(0)
n = 24
arrivals = np.cumsum(rng.exponential(1 / 6.0, n))
requests = [Request(rid=i, prompt=list(rng.integers(0, cfg.vocab_size, 8)),
                    max_new=6, submitted=float(t))
            for i, t in enumerate(arrivals)]
done = engine.serve(requests)

ttft = np.array([r.ttft_s for r in done])
lat = np.array([r.latency_s for r in done])
print(f"served {len(done)} requests")
print(f"TTFT  p50 {np.median(ttft)*1e3:7.1f} ms   p95 {np.percentile(ttft,95)*1e3:7.1f} ms")
print(f"E2E   p50 {np.median(lat)*1e3:7.1f} ms   p95 {np.percentile(lat,95)*1e3:7.1f} ms")
for name, v in engine.collector.summary().items():
    print(f"  {name:10s} {v['records']:4.0f} recs  "
          f"{v['mean_latency_s']*1e3:8.2f} ms/rec  {v['throughput_rps']:7.1f}/s")

# business view: a serving twin from the measured decode throughput
decode = engine.collector.summary()["decode"]
twin = SimpleTwin("llm-serve", max_rps=decode["throughput_rps"],
                  usd_per_hour=1.20 * 8,     # e.g. a v5e-8 slice
                  base_latency_s=float(np.median(lat)))
traffic = TrafficModel.honda_default("requests", R=twin.max_rps * 0.4, G=1.3)
sim = simulate_year(twin, traffic.hourly_loads(),
                    slo=SLO(limit_s=30.0, met_fraction=0.99))
print(f"\nyear-of-traffic forecast for this serving pipeline:")
print(f"  annual cost ${sim.total_cost_usd:,.0f}   latency met "
      f"{sim.pct_latency_met:.2f}%   SLO met: {sim.slo_met}")

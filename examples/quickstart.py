"""Quickstart: the data-pipeline wind tunnel end to end in ~a minute.

1. Build the paper's telemetry pipeline-under-test (blocking-write variant).
2. Generate synthetic vehicle transmissions, drive a ramp LoadPattern at it.
3. Read the per-stage measurements the spans collected.
4. Fit a digital twin and simulate a full year of projected Honda-like
   traffic, with SLO + cost results — the paper's Fig. 4 loop, in one file.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.core.experiment import Experiment
from repro.core.loadpattern import LoadPattern
from repro.core.report import render_table
from repro.core.slo import SLO
from repro.core.simulate import simulate_year
from repro.core.traffic import TrafficModel
from repro.core.twin import fit_simple_twin
from repro.pipelines.telemetry import (make_telemetry_dataset,
                                       make_telemetry_pipeline)

# 1-2: measure the pipeline under a ramp that exceeds its capacity
pipe = make_telemetry_pipeline("blocking-write", blob_dir=tempfile.mkdtemp())
dataset = make_telemetry_dataset(num_records=40, seed=0)
load = LoadPattern.ramp("0->120rps", duration_s=3.0, peak_rate=120.0)
result = Experiment("quickstart", pipe, load, dataset).run()

print(f"sent {result.records_sent} records in {result.duration_s:.1f}s; "
      f"drained={result.drained}")
rows = [dict(stage=k, **{kk: round(vv, 4) for kk, vv in v.items()})
        for k, v in result.stage_summary.items()]
print(render_table(rows, "per-stage measurements (the wind tunnel view)"))

# 3: fit the digital twin from the experiment
twin = fit_simple_twin(result)
print(f"twin: capacity={twin.max_rps:.1f} rec/s, ${twin.usd_per_hour:.4f}/hr,"
      f" base latency {twin.base_latency_s * 1e3:.2f} ms")

# 4: business analysis — a year of projected traffic vs this pipeline
traffic = TrafficModel.honda_default("nominal", R=30.0, G=1.0)
slo = SLO(limit_s=4 * 3600, met_fraction=0.95)
sim = simulate_year(twin, traffic.hourly_loads(), slo=slo)
print(f"\nyear simulation under nominal traffic (R=30 rec/s):")
print(f"  annual cost       ${sim.total_cost_usd:,.2f}")
print(f"  mean throughput   {sim.mean_throughput_rph:,.0f} rec/h")
print(f"  latency met       {sim.pct_latency_met:.2f}%  -> SLO met: {sim.slo_met}")
print(f"  end-of-year backlog {sim.backlog_s / 3600:.1f} h")

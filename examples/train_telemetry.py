"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on CPU, with the training pipeline instrumented as a
pipeline-under-test (datagen / h2d / train_step / checkpoint spans), fault
injection mid-run, and a fitted twin at the end.

Run:  PYTHONPATH=src python examples/train_telemetry.py [--steps 200]
(~100M params; a few hundred steps takes a while on 1 CPU core — use
--steps 30 for a quick look.)
"""
import argparse
import dataclasses
import tempfile

from repro.config import (AttentionConfig, ModelConfig, OptimizerConfig,
                          ParallelConfig, TrainConfig)
from repro.core.report import render_table
from repro.distributed.fault import FaultInjector
from repro.launch.mesh import make_host_mesh
from repro.models.model import param_count
from repro.train.loop import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

# ~100M-parameter llama-style config
cfg = ModelConfig(
    name="llama-100m", family="dense", num_layers=8, d_model=512,
    d_ff=2048, vocab_size=32768,
    attention=AttentionConfig(kind="gqa", num_heads=8, num_kv_heads=4,
                              head_dim=64, rope="standard"),
    mlp_kind="swiglu", norm="rmsnorm", tie_embeddings=True,
    max_seq_len=args.seq)
print(f"model: {param_count(cfg) / 1e6:.1f}M params")

mesh = make_host_mesh(1, 1)
ckpt = tempfile.mkdtemp(prefix="train_telemetry_")
tcfg = TrainConfig(steps=args.steps, seq_len=args.seq,
                   global_batch=args.batch, checkpoint_every=50,
                   checkpoint_dir=ckpt, log_every=10)
ocfg = OptimizerConfig(lr=6e-4, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 1))
# inject a node loss a third of the way in: the loop must restart from the
# latest checkpoint and still finish
injector = FaultInjector(node_loss_at=(args.steps // 3,))
res = train(cfg, tcfg, ocfg, ParallelConfig(batch_axes=("data",)), mesh,
            injector=injector)

print(f"\nfinished {res.steps_done} steps "
      f"(restarts={res.restarts}, injected={injector.fired})")
print(f"loss {res.losses[0]:.3f} -> {res.final_loss:.3f}")
rows = [dict(stage=k, **{kk: round(vv, 5) for kk, vv in v.items()})
        for k, v in res.collector.summary().items()]
print(render_table(rows, "training pipeline stages (wind tunnel spans)"))
if res.stragglers_seen:
    print("stragglers flagged:", res.stragglers_seen)

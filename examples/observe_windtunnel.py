"""Observing the wind tunnel: the tool's own telemetry, end to end.

The measurement framework measures pipelines — ``repro.obs`` measures
the framework. This walkthrough turns the off-by-default run-telemetry
layer on and closes every loop it offers:

1. Capture an instrumented workload: a wind-tunnel experiment plus an
   aggregate what-if grid, a calibration fit and a policy search — the
   engines emit ``stage.*`` / ``grid.*`` / ``calibrate.*`` /
   ``search.*`` spans and counters while they run.
2. Render the consolidated console report (``make obs-report`` runs the
   same renderer): per-span stats, dispatch profiles, counters.
3. Export three ways — OTel-style span dicts, Prometheus text
   exposition (the Table II rows as a scrape page), and a JSONL collect
   file with time-based retention.
4. The golden round-trip: feed the exported spans of the experiment the
   tool just ran straight back into ``ObservedTrace.from_otel_spans``
   and REFIT a twin from them — the wind tunnel calibrates itself from
   its own telemetry.

Run:  PYTHONPATH=src python examples/observe_windtunnel.py
"""
import tempfile
import time

import numpy as np

from repro import obs
from repro.calibrate import ObservedTrace, fit
from repro.core.datagen import DataGenerator
from repro.core.experiment import Experiment
from repro.core.loadpattern import LoadPattern
from repro.core.pipeline import Pipeline, PipelineStage, Resources
from repro.core.schema import FieldSpec, Schema
from repro.core.simulate import simulate_grid
from repro.core.slo import SLO
from repro.core.traffic import TrafficModel
from repro.core.twin import make_twin
from repro.obs.export import append_jsonl, prometheus_exposition, \
    read_jsonl, to_otel_spans
from repro.obs.report import render

# ---------------------------------------------------------------------------
# 1. capture an instrumented workload
# ---------------------------------------------------------------------------
# obs is OFF by default (zero overhead in the engines); obs.capture()
# switches it on for the block and hands back the recorder.


def _stage(batch):                       # ~8ms of "work" per batch
    time.sleep(0.008)
    return batch


pipe = Pipeline("observed", [PipelineStage("ingest", _stage)],
                resources=Resources(vcpus=1, ram_gb=1))
schema = Schema("rec", (FieldSpec("x", "float"),))
dataset = DataGenerator(seed=0).generate(schema, 200)
load = LoadPattern.steady("obs-load", duration_s=2.0, rate=40)

with obs.capture() as recorder:
    # a real wind-tunnel run: every pipeline-stage span the collector
    # records is mirrored into obs as a ``stage.ingest`` span
    result = Experiment("observed", pipe, load, dataset,
                        drain_timeout_s=30).run()

    # an aggregate what-if grid: emits a ``grid.simulate`` span, per-
    # block ``grid.block`` spans tagged compiled=0/1, dedup counters
    traffic = TrafficModel.honda_default("demo", R=3.0, G=1.3)
    week = traffic.hourly_loads()[:168].astype(np.float32)
    twins = [make_twin(f"fifo{i}", "fifo", max_rps=1.6 + 0.2 * i,
                       usd_per_hour=0.01, base_latency_s=0.2)
             for i in range(8)]
    grid_rows = simulate_grid(
        twins, slo=SLO(limit_s=2 * 3600, met_fraction=0.95),
        bin_hours=1.0, return_series=False, scenario_block=4,
        load_matrix=np.tile(week, (8, 1)),
        load_index=np.arange(8, dtype=np.int32))

print(f"experiment drained: {result.drained}, "
      f"records sent: {result.records_sent}")
print(f"grid rows: {len(grid_rows)}, "
      f"stage spans captured: {len(recorder.find(prefix='stage.'))}, "
      f"grid spans captured: {len(recorder.find(prefix='grid.'))}")

# ---------------------------------------------------------------------------
# 2. the consolidated console report
# ---------------------------------------------------------------------------
print()
print(render(recorder))

# ---------------------------------------------------------------------------
# 3. export: Prometheus exposition + JSONL collect file
# ---------------------------------------------------------------------------
# Table II rows become a scrape page (the Snippet-2 monitor vocabulary:
# latency quantiles, message count, target compliance, cost) and the
# recorder's own counters/spans ride along.
exposition = prometheus_exposition(grid_rows, recorder=recorder)
print()
print("--- prometheus exposition (first 12 lines) ---")
print("\n".join(exposition.splitlines()[:12]))

# export the experiment's stage spans BEFORE the collect append below —
# append_jsonl(clear=True) drains the span ring after writing it out
otel = to_otel_spans(recorder, prefix="stage.")

# the continuous-collect shape: append spans + a counter snapshot as
# JSON lines, pruning anything older than the retention window
with tempfile.NamedTemporaryFile(suffix=".jsonl", delete=False) as tmp:
    collect_path = tmp.name
n_lines = append_jsonl(collect_path, recorder, retention_s=3600.0)
data = read_jsonl(collect_path)
print(f"\ncollect file: {n_lines} lines "
      f"({len(data['spans'])} spans, {len(data['counters'])} snapshots)")

# ---------------------------------------------------------------------------
# 4. the golden round-trip: re-import the tool's own spans and refit
# ---------------------------------------------------------------------------
# The experiment's stage spans export in EXACTLY the dict shape
# ObservedTrace.from_otel_spans consumes (unix-seconds start/end,
# records, status) — so the telemetry the tool emitted about itself is
# a calibration trace.
trace = ObservedTrace.from_otel_spans(otel, bin_seconds=0.25,
                                      name="self-observed")
refit = fit(trace, "fifo", restarts=4, steps=80, seed=0)
print(f"\nround-trip: {len(otel)} exported spans -> "
      f"{trace.num_bins}-bin trace -> refit "
      f"max_rps={refit.twin.max_rps:.2f} loss={refit.loss:.4f}")

"""Twin calibration walkthrough: measure -> fit -> what-if grid.

The paper eyeballs twin parameters off wind-tunnel charts; here the twin
is *fit* by gradient descent through the simulation scan itself
(repro.calibrate), so a measured pipeline flows straight into Table II:

1. Ground-truth recovery: replay a ramp LoadPattern through a known twin
   at 5-minute resolution, fit from random restarts (all K restarts run
   as ONE vmapped dispatch), and check the parameters come back.
2. Holdout validation: fit on the ramp trace, score on a steady trace the
   optimizer never saw — the generalization number that says whether the
   twin is a model or a memorization.
3. Measure a real (in-process) telemetry pipeline in the wind tunnel and
   send it through ``calibrated_grid``: experiment in, Table II out.

Run:  PYTHONPATH=src python examples/calibrate_twin.py
"""
import tempfile

from repro.calibrate import ObservedTrace, fit, fit_with_holdout
from repro.core.experiment import Experiment
from repro.core.loadpattern import LoadPattern
from repro.core.report import render_table
from repro.core.slo import SLO
from repro.core.traffic import TrafficModel
from repro.core.twin import make_twin
from repro.core.whatif import calibrated_grid, table2_rows
from repro.pipelines.telemetry import (make_telemetry_dataset,
                                       make_telemetry_pipeline)

# ---------------------------------------------------------------------------
# 1. ground-truth recovery: can the fit find known parameters?
# ---------------------------------------------------------------------------
truth = make_twin("ground-truth", "shed", max_rps=2.0, usd_per_hour=0.05,
                  base_latency_s=0.2, queue_cap_hours=1.5)
ramp = LoadPattern.ramp("ramp-0-6rps", duration_s=6 * 3600, peak_rate=6.0)
trace = ObservedTrace.from_loadpattern(ramp, truth, bin_s=300.0)

result = fit(trace, "shed", restarts=8, steps=400, seed=0)
rows = []
for i, pname in enumerate(result.spec.param_names):
    if result.spec.free_mask[i]:
        rows.append({"param": pname, "truth": truth.padded_params()[i],
                     "fitted": round(float(result.params[i]), 4)})
print(render_table(rows, f"shed-policy recovery (loss {result.loss:.2e})"))
print(render_table(result.restart_table(),
                   "per-restart convergence (one vmapped dispatch)"))

# ---------------------------------------------------------------------------
# 2. holdout: fit on the ramp, validate on a steady pattern
# ---------------------------------------------------------------------------
steady = LoadPattern.steady("steady-3rps", duration_s=6 * 3600, rate=3.0)
holdout = ObservedTrace.from_loadpattern(steady, truth, bin_s=300.0)
hres = fit_with_holdout(trace, holdout, "shed", restarts=8, steps=400)
print(f"train loss {hres.loss:.2e}  holdout loss {hres.holdout_loss:.2e}  "
      f"generalization gap {hres.generalization_gap:.2f}x\n")

# ---------------------------------------------------------------------------
# 3. the full loop: wind-tunnel experiment -> calibrated twins -> Table II
# ---------------------------------------------------------------------------
pipe = make_telemetry_pipeline("blocking-write", blob_dir=tempfile.mkdtemp())
dataset = make_telemetry_dataset(num_records=40, seed=0)
load = LoadPattern.ramp("0->120rps", duration_s=3.0, peak_rate=120.0)
measured = Experiment("calibrate-demo", pipe, load, dataset).run()
print(f"measured: {measured.records_sent} records in "
      f"{measured.duration_s:.1f}s, sustained {measured.sustained_rps:.1f} "
      f"rec/s, ${measured.cost['usd_per_hour']:.4f}/hr")

nominal = TrafficModel.honda_default("nominal", R=30.0, G=1.0)
high = TrafficModel.honda_default("high(+50%)", R=30.0, G=1.5)
slo = SLO(limit_s=4 * 3600, met_fraction=0.95)
sims = calibrated_grid(measured, ["fifo", "quickscale"], [nominal, high],
                       slo=slo, restarts=8, steps=300)
print(render_table(table2_rows(sims),
                   "Table II grid from gradient-calibrated twins"))
print("the fifo twin's capacity/cost/latency were fit to the measured "
      "trace by\ndifferentiating through the year-simulation scan — no "
      "manual eyeballing.")

"""The paper's two what-if studies, reproduced end to end:

  1. "What if increased car sales put 50% more cars on the road by the end
     of the year?"  (Table II: six twin x forecast simulations)
  2. "What would be the cost impact of doubling data retention from 3 to 6
     months?"       (Table IV: monthly cloud/network/storage costs)

Run:  PYTHONPATH=src python examples/whatif_analysis.py
"""
from repro.core.cost import CostModel
from repro.core.report import render_table
from repro.core.slo import SLO
from repro.core.traffic import TrafficModel
from repro.core.twin import SimpleTwin
from repro.core.whatif import retention_whatif, run_grid, table2_rows

# the paper's Table I twins (cents/hr -> USD/hr)
twins = [SimpleTwin("blocking-write", 1.9512, 0.0082, 0.15),
         SimpleTwin("no-blocking-write", 6.15, 0.0703, 0.06),
         SimpleTwin("cpu-limited", 0.6612, 0.0027, 0.29)]
nominal = TrafficModel.honda_default("nominal", R=3.5, G=1.0)
high = TrafficModel.honda_default("high(+50%)", R=3.5, G=1.5)
slo = SLO(limit_s=4 * 3600, met_fraction=0.95)

sims = run_grid(twins, [nominal, high], slo=slo)
print(render_table(table2_rows(sims),
                   "What-if #1: +50% car sales (paper Table II)"))
print("paper: SLO met only for {nom block, nom non-block, high non-block}\n")

tables = retention_whatif(twins[1], nominal, record_mb=0.0141,
                          retentions_days=(91, 182),
                          cost_model=CostModel())
for ret, rows in tables.items():
    total = sum(r["total_usd"] for r in rows)
    print(render_table(rows, f"What-if #2: {ret}-day retention "
                             f"(year total ${total:,.2f})"))

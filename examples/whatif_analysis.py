"""The paper's two what-if studies plus a beyond-paper policy sweep, all
driven by the unified TwinPolicy engine (one vmapped scan per grid):

  1. "What if increased car sales put 50% more cars on the road by the end
     of the year?"  (Table II: six twin x forecast simulations)
  2. "What would be the cost impact of doubling data retention from 3 to 6
     months?"       (Table IV: monthly cloud/network/storage costs)
  3. "Which scaling policy should the blocking-write pipeline run?" —
     fifo vs quickscale vs autoscale (slow/fast) vs shed vs batch_window,
     on the same traffic, priced per instance.
  4. The same policy sweep re-run on the fused Pallas grid backend
     (``kernels.ops.pallas_mode()``): scenarios ride the vector lanes of
     one kernel instead of the XLA vmapped lax.switch scan — interpret
     mode on CPU, the TPU layout on real hardware — and the Table II
     numbers agree to 1e-5.
  5. A 4096-scenario cost-lever sweep (autoscaling delay x instance cap x
     queue cap x batch window x growth — the levers Jablonski & Heltweg
     catalogue for cloud pipelines) through the STREAMING-AGGREGATE grid:
     ``run_grid`` holds each traffic's load row once (load matrix + index
     map), folds the Table II statistics into the scan carry, and returns
     O(N) ``GridSummary`` rows — no [N, 8736] series ever exists, so the
     same engine scales to 100k+ scenarios (see ``make
     grid-bench-stream``).
  6. The INVERSE question — "cheapest autoscale config that keeps p95
     under 2h at +40% traffic?" — answered directly by
     ``whatif.optimize_scenario`` (repro.search): multi-start projected
     AdamW on a differentiable annual-cost + SLO-hinge objective, all
     restarts as lanes of one grad-of-scan dispatch, feasibility
     re-checked bit-exactly, plus the cost-vs-SLO Pareto frontier
     ("what does tightening the SLO cost?").
  7. CHAOS: "what do outages and reconnect floods do to the Table II
     picture, and what is the cheapest config that survives 95% of
     them?" — a ``repro.faults`` schedule crosses the grid with F
     sampled fault futures per scenario (``run_grid(faults=...)``,
     fault-attribution columns in Table II), and
     ``optimize_scenario(faults=..., quantile=0.95)`` runs the
     chance-constrained search: cheapest configuration meeting the SLO
     in >= 95% of futures, achieved quantile re-checked bit-exactly.

Registered twin policies (see repro/core/twin.py):

  policy        extra params                         behaviour
  ------------  -----------------------------------  -------------------------
  fifo          -                                    fixed capacity, infinite
                                                     FIFO queue (paper)
  quickscale    -                                    ideal scaling, pay
                                                     ceil(load/cap) instances
  autoscale     min/max_instances, scale_up_hours    bounded scaling with
                                                     boot delay
  shed          queue_cap_hours                      bounded queue, overflow
                                                     dropped
  batch_window  window_hours, idle_cost_fraction     accumulate-then-flush
                                                     batching

Any new policy registered with ``register_policy`` joins ``run_grid``
automatically — the grid kernel dispatches per scenario via lax.switch.

The twins below are hand-entered from the paper's Table I; to *fit* a
twin to a measured trace by gradient descent through the simulation scan
(measure -> fit -> grid, with holdout validation), see
``examples/calibrate_twin.py`` and ``repro.calibrate``.

Run:  PYTHONPATH=src python examples/whatif_analysis.py
"""
from repro.core.cost import CostModel
from repro.core.report import render_table
from repro.core.slo import SLO
from repro.core.traffic import TrafficModel
from repro.core.twin import SimpleTwin, make_twin, policy_table_rows
from repro.core.whatif import retention_whatif, run_grid, table2_rows

# the paper's Table I twins (cents/hr -> USD/hr)
twins = [SimpleTwin("blocking-write", 1.9512, 0.0082, 0.15),
         SimpleTwin("no-blocking-write", 6.15, 0.0703, 0.06),
         SimpleTwin("cpu-limited", 0.6612, 0.0027, 0.29)]
nominal = TrafficModel.honda_default("nominal", R=3.5, G=1.0)
high = TrafficModel.honda_default("high(+50%)", R=3.5, G=1.5)
slo = SLO(limit_s=4 * 3600, met_fraction=0.95)

sims = run_grid(twins, [nominal, high], slo=slo)
print(render_table(table2_rows(sims),
                   "What-if #1: +50% car sales (paper Table II)"))
print("paper: SLO met only for {nom block, nom non-block, high non-block}\n")

tables = retention_whatif(twins[1], nominal, record_mb=0.0141,
                          retentions_days=(91, 182),
                          cost_model=CostModel())
for ret, rows in tables.items():
    total = sum(r["total_usd"] for r in rows)
    print(render_table(rows, f"What-if #2: {ret}-day retention "
                             f"(year total ${total:,.2f})"))

# ---------------------------------------------------------------------------
# What-if #3 (beyond paper): policy choice for the blocking-write pipeline.
# Price one instance at the measured blocking-write rate/cost and sweep the
# scaling policy; the whole (6 policies x 2 forecasts) grid is one dispatch.
# ---------------------------------------------------------------------------
print(render_table(policy_table_rows(), "Registered twin policies"))

RPS, USD_HR, LAT = 1.9512, 0.0082, 0.15
policy_twins = [
    SimpleTwin("fifo", RPS, USD_HR, LAT),
    make_twin("quickscale", "quickscale", max_rps=RPS, usd_per_hour=USD_HR,
              base_latency_s=LAT),
    make_twin("autoscale-1h", "autoscale", max_rps=RPS, usd_per_hour=USD_HR,
              base_latency_s=LAT, max_instances=8, scale_up_hours=1),
    make_twin("autoscale-6h", "autoscale", max_rps=RPS, usd_per_hour=USD_HR,
              base_latency_s=LAT, max_instances=8, scale_up_hours=6),
    make_twin("shed-4h", "shed", max_rps=RPS, usd_per_hour=USD_HR,
              base_latency_s=LAT, queue_cap_hours=4),
    make_twin("batch-6h", "batch_window", max_rps=RPS, usd_per_hour=USD_HR,
              base_latency_s=LAT, window_hours=6),
]
psims = run_grid(policy_twins, [nominal, high], slo=slo)
print(render_table(table2_rows(psims),
                   "What-if #3: scaling-policy sweep (blocking-write rates)"))
print("a slow autoscaler (6h boot) clears the fifo backlog for less than "
      "quickscale's\nbill while still meeting the SLO; shed trades dropped "
      "records for bounded\nlatency; batch_window is cheapest when latency "
      "may reach half a window.")

# ---------------------------------------------------------------------------
# What-if #4: the same grid on the fused Pallas backend. ``pallas_mode()``
# flips ``core.simulate._grid_scan`` from the XLA vmapped lax.switch scan
# to the one-pallas_call scenario-grid kernel (kernels/policy_scan.py);
# scenarios sit on the vector lanes and every policy runs branchless.
# ---------------------------------------------------------------------------
from repro.kernels.ops import pallas_mode  # noqa: E402

with pallas_mode():         # interpret=True: CPU-safe, same TPU structure
    psims_pallas = run_grid(policy_twins, [nominal, high], slo=slo)
print(render_table(table2_rows(psims_pallas),
                   "What-if #4: same sweep, Pallas grid backend"))
worst = max(abs(p.total_cost_usd - x.total_cost_usd)
            / max(abs(x.total_cost_usd), 1e-9)
            for p, x in zip(psims_pallas, psims))
assert worst <= 1e-5, f"backend drift: {worst:.2e} exceeds 1e-5 vs XLA"
print(f"backends agree: worst relative cost difference vs XLA = "
      f"{worst:.2e} (tolerance 1e-5)")

# ---------------------------------------------------------------------------
# What-if #5: a 4096-scenario cost-lever sweep on the streaming-aggregate
# grid. 256 twins (64 autoscale delay x cap combos, 64 shed queue caps,
# 64 batch windows x idle fractions, 64 fifo/quickscale capacity points)
# x 16 growth forecasts = 4096 full-year scenarios; run_grid keeps ONE
# copy of each forecast's 8736-hour load row and returns scalar
# GridSummary rows straight off the in-carry aggregates. table2_rows
# consumes only scalars, so nothing about the report changes — only the
# memory (O(N) instead of O(N*8736)) and the scale ceiling.
# ---------------------------------------------------------------------------
import numpy as np  # noqa: E402

sweep_twins = []
for d, (cap, delay) in enumerate((c, dl) for c in (2, 4, 8, 16, 24, 32,
                                                   48, 64)
                                 for dl in (0.5, 1, 2, 3, 4, 6, 9, 12)):
    sweep_twins.append(make_twin(f"auto-c{cap}-d{delay:g}", "autoscale",
                                 max_rps=RPS, usd_per_hour=USD_HR,
                                 base_latency_s=LAT, max_instances=cap,
                                 scale_up_hours=delay))
for q in np.geomspace(0.25, 96.0, 64):
    sweep_twins.append(make_twin(f"shed-q{q:.2f}", "shed", max_rps=RPS,
                                 usd_per_hour=USD_HR, base_latency_s=LAT,
                                 queue_cap_hours=float(q)))
for w, f in ((w, f) for w in np.geomspace(0.5, 24.0, 16)
             for f in (0.05, 0.1, 0.2, 0.4)):
    sweep_twins.append(make_twin(f"batch-w{w:.1f}-f{f}", "batch_window",
                                 max_rps=RPS, usd_per_hour=USD_HR,
                                 base_latency_s=LAT, window_hours=float(w),
                                 idle_cost_fraction=f))
for i, r in enumerate(np.geomspace(0.5, 16.0, 64)):
    policy = "fifo" if i % 2 else "quickscale"
    sweep_twins.append(make_twin(f"{policy}-r{r:.2f}", policy,
                                 max_rps=RPS * float(r),
                                 usd_per_hour=USD_HR * float(r),
                                 base_latency_s=LAT))
growths = [TrafficModel.honda_default(f"g{g:.2f}", R=3.5, G=float(g))
           for g in np.linspace(1.0, 1.75, 16)]
sweep = run_grid(sweep_twins, growths, slo=slo)     # aggregate mode
met = [s for s in sweep if s.slo_met]
met.sort(key=lambda s: s.grand_total_usd)
print(render_table(table2_rows(met[:8]),
                   f"What-if #5: 4096-scenario cost-lever sweep — "
                   f"cheapest 8 of {len(met)} SLO-met scenarios"))
print(f"{len(sweep)} scenarios, {len(met)} meet the 4h/95% SLO; the "
      f"whole sweep held {len(growths)} load rows and O(N) aggregates — "
      f"no per-scenario hourly series were ever materialized.")

# ---------------------------------------------------------------------------
# Scaling the grid past this sweep — the same ``run_grid`` call, bigger N.
# Three levers (all bit-identical to the defaults; see the "Scaling the
# grid" section of ``simulate_grid``'s docstring and ``make
# grid-bench-shard``):
#
#  * do nothing: grids past ``agg_auto_block(t_bins)`` scenarios stream
#    through the device automatically in policy-uniform blocks sized so
#    one block's [B, T] staging panel fits a ~150 MB budget, with the
#    host's histogram binning overlapped against the device's next block
#    scan. A 1,048,576-scenario full-year sweep completes on a laptop
#    -class CPU this way (BENCH_grid_shard.json records it).
#  * ``scenario_block=``: override the block size when device memory is
#    tighter (or roomier) than the default budget assumes.
#  * ``devices=D``: shard the blocked grid over a 1-D scenario mesh —
#    one block per device per round, load matrix replicated. On real
#    accelerators each device is one shard; to try it on CPU, export
#      XLA_FLAGS=--xla_force_host_platform_device_count=4
#    BEFORE the first jax import and pass ``devices=4``. Results are
#    bit-identical to devices=None.
#
# e.g.:  run_grid(sweep_twins, growths, slo=slo,
#                 scenario_block=4096, devices=4)
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# What-if #6: INVERT the simulator — "what is the cheapest autoscaler
# configuration that keeps p95 latency under 2 hours at +40% traffic?"
# ``whatif.optimize_scenario`` (repro.search) descends a differentiable
# annual-cost objective with a smooth SLO hinge: all restarts run as
# lanes of ONE grad-of-scan dispatch through the same backend selection
# the grids use, every candidate is re-checked through the bit-exact
# streaming-aggregate path, and the p95 evidence comes off the
# aggregate histogram CDF (the new Table II tail columns above).
# ---------------------------------------------------------------------------
from repro.core.twin import make_twin  # noqa: E402
from repro.core.whatif import optimize_scenario  # noqa: E402
from repro.search import pareto_frontier  # noqa: E402

surge = TrafficModel.honda_default("surge(+40%)", R=3.5, G=1.4)
p95_slo = SLO(limit_s=2 * 3600, met_fraction=0.95)
auto_base = make_twin("autoscale-base", "autoscale", max_rps=RPS,
                      usd_per_hour=USD_HR, base_latency_s=LAT,
                      max_instances=8, scale_up_hours=2)
opt = optimize_scenario(auto_base, [surge], p95_slo,
                        search=("max_instances", "scale_up_hours"),
                        restarts=6, steps=60, coarsen=4, seed=0)
print(render_table(opt.restart_table(),
                   "What-if #6: cheapest autoscale config, p95 < 2h at "
                   "+40% traffic (per-restart convergence)"))
print(f"cheapest feasible config: {opt.config()} — "
      f"${opt.cost_usd:,.2f}/yr vs ${opt.base_cost_usd:,.2f} for the "
      f"base config (p95 = {opt.p95_latency_s:.2f}s, SLO-checked "
      f"through the bit-exact aggregate path)")

# ...and the price of tightening that SLO: a cost-vs-p95 Pareto sweep,
# every target another lane of the same single search dispatch
frontier = pareto_frontier(opt.space, [surge],
                           slo_limits=[1800, 3600, 2 * 3600, 8 * 3600],
                           restarts=4, steps=60, coarsen=4, seed=0)
print(render_table(frontier.rows(),
                   "What-if #6b: the price of tightening the p95 SLO"))

# ---------------------------------------------------------------------------
# What-if #7: CHAOS. "What if the pipeline loses capacity for hours at a
# time — and what is the cheapest configuration that still meets the SLO
# in 95% of those fault futures?" A ``repro.faults`` schedule (outages,
# device disconnects with reconnect floods, brownouts) crosses every
# grid scenario with F sampled futures: ``run_grid(faults=...)`` shows
# the damage with fault-attribution columns (hours in fault windows,
# SLO-met split inside vs outside), and
# ``optimize_scenario(faults=..., quantile=0.95)`` answers the inverse —
# the CHANCE-CONSTRAINED resilience search. quantile=1.0 insures every
# sampled future (worst case); 0.95 buys the config that sacrifices the
# rarest, most expensive futures, and is strictly cheaper whenever
# insuring them costs real capacity. Feasibility and the achieved
# quantile are re-checked through the bit-exact aggregate path.
# ---------------------------------------------------------------------------
from repro import faults  # noqa: E402

chaos = faults.FaultSchedule(
    specs=(faults.outage(rate_per_year=6, duration_hours=(1, 4)),
           faults.disconnect(rate_per_year=12, disconnect_frac=(0.2, 0.5),
                             flood_hours=1.0),
           faults.brownout(rate_per_year=8, capacity_mult=(0.3, 0.7))),
    n_futures=4, seed=0)
chaos_sims = run_grid(twins[:2], [nominal], slo=slo, faults=chaos)
print(render_table(table2_rows(chaos_sims),
                   "What-if #7: chaos suite — 4 fault futures per "
                   "scenario (fault-attribution columns)"))

resilient = optimize_scenario(auto_base, [surge], p95_slo,
                              search=("max_instances", "scale_up_hours"),
                              faults=chaos, quantile=0.95,
                              restarts=4, steps=60, coarsen=4, seed=0)
print(f"chance-constrained (q=0.95): {resilient.config()} — "
      f"${resilient.cost_usd:,.2f}/yr, meets the SLO in "
      f"{resilient.achieved_quantile:.0%} of {resilient.n_futures} fault "
      f"futures (vs ${opt.cost_usd:,.2f}/yr benign-optimal)")

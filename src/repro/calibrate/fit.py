"""Multi-start gradient calibration of digital twins.

``fit`` matches a registered TwinPolicy's parameter vector to an
``ObservedTrace`` by differentiating through the simulation scan. All K
random restarts run as ONE dispatch — and as K *lanes* of the same
scenario-grid backend the what-if engine uses: the jitted ``_fit_kernel``
takes the [K, PARAM_DIM] stack of unconstrained starts and runs

    lax.scan over steps of  grad(lane-block loss-of-scan)  +  vmap(AdamW)

where the lane-block loss STREAMS: the running flow sums and compensated
residual accumulators ride the simulation scan's carry
(``objective.lane_series_loss`` -> ``kernels.ops.policy_scan_fold``), so
neither direction of the gradient ever materializes a [K, T] series —
the backward is the checkpointed O(sqrt(T)) custom VJP. A 32-restart
fit costs one compile and one device program, the same grid trick
``core.simulate`` plays for what-if scenarios. The optimizer is the
existing ``repro.optim`` AdamW (warmup + cosine, global-norm clip),
vmapped so each restart clips and schedules independently.
``fit(devices=D)`` shards the restart axis over a D-device mesh,
matching the single-device dispatch to a few ulps (see
``_sharded_fit_fn``).

The public surface:

* ``fit(trace, policy, ...) -> FitResult`` — best twin + per-start
  convergence table + loss history.
* ``fit_with_holdout(train, holdout, ...)`` — fit on one trace (say a
  ramp pattern), score the fitted twin on another (steady), report the
  generalization gap.
* ``calibrated_twin(result, policy=...) -> Twin`` — the measure -> fit
  entry point: an ``ExperimentResult`` (or a prebuilt trace) straight to
  a simulation-ready Twin for Table II grids.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.calibrate.objective import (DEFAULT_WEIGHTS, FitSpec, fit_spec,
                                       lane_trace_loss, params_from_z,
                                       series_loss, twin_from_z,
                                       z_from_params)
from repro.calibrate.trace import ObservedTrace, SERIES_KEYS
from repro.config import OptimizerConfig
from repro.core.twin import (PARAM_DIM, Twin, fit_twin, policy_spec,
                             registry_version)
from repro.distributed.sharding import resolve_mesh_axis
from repro.optim.adamw import adamw_update, init_opt_state

#: AdamW settings tuned for the z-space objective: no weight decay (z=0 is
#: mid-box, not a prior), generous clip, short warmup; total_steps is
#: overwritten with the fit's step count so the cosine tail anneals the
#: last iterations for tight parameter recovery.
DEFAULT_FIT_OPT = OptimizerConfig(lr=0.08, betas=(0.9, 0.95), eps=1e-8,
                                  weight_decay=0.0, grad_clip=10.0,
                                  warmup_steps=25, total_steps=400)


@dataclass
class FitResult:
    """Best fit plus the evidence: per-start convergence + loss history."""
    twin: Twin
    policy: str
    loss: float
    params: np.ndarray            # [PARAM_DIM] best-fit full vector
    spec: FitSpec
    best_start: int
    start_losses: np.ndarray      # [K] final loss per restart
    start_params: np.ndarray      # [K, PARAM_DIM] fitted params per restart
    loss_history: np.ndarray      # [steps, K]
    trace_name: str
    holdout_loss: Optional[float] = None
    holdout_name: Optional[str] = None

    @property
    def generalization_gap(self) -> Optional[float]:
        """holdout loss / train loss (1.0 = generalizes perfectly)."""
        if self.holdout_loss is None:
            return None
        return float(self.holdout_loss / max(self.loss, 1e-12))

    def restart_table(self) -> List[Dict]:
        """Per-start convergence rows for report.render_table."""
        first = self.loss_history[0] if len(self.loss_history) else \
            self.start_losses
        rows = []
        for k in range(len(self.start_losses)):
            row = {"start": k,
                   "loss0": float(first[k]),
                   "loss": float(self.start_losses[k]),
                   "converged": bool(self.start_losses[k]
                                     <= 2.0 * self.loss + 1e-9),
                   "best": k == self.best_start}
            for i, pname in enumerate(self.spec.param_names):
                if self.spec.free_mask[i]:
                    row[pname] = round(float(self.start_params[k, i]), 6)
            rows.append(row)
        return rows


def _fit_kernel_body(steps: int, dt_hours: float, version: int,
                     ocfg: OptimizerConfig, z0, arrivals, targets, scales,
                     weights, lo, hi, log_mask, free_mask, fixed,
                     policy_index):
    """K restarts, one dispatch: scan(grad(lane-block loss) + vmap(AdamW)).

    The restarts are K lanes of the shared grid backend: the loss plays
    the whole [K, PARAM_DIM] stack through ONE lane-vectorized streaming
    scan (``objective.lane_trace_loss`` -> ``kernels.ops.
    policy_scan_fold``; the traced ``policy_index`` switches in a single
    lane branch, so one jit trace serves every policy without paying the
    P-way blend), and grad of the summed per-lane losses recovers each
    restart's gradient exactly (the lanes are independent). AdamW stays
    vmapped so every restart clips and schedules on its own.

    ``steps``/``dt_hours``/``ocfg`` are static; ``version`` is the policy
    registry version so late registrations retrace (same contract as the
    grid kernel). Returns (z_best [K,D], best_loss [K], history
    [steps,K]) — the BEST-SEEN iterate per restart, not the endpoint:
    each step's in-loop loss evaluation (which the gradient needs
    anyway, so tracking it is free — the kernel never pays a separate
    full-horizon forward) updates a running per-restart argmin in the
    scan carry. Descent through the near-degenerate valleys these
    objectives develop (a fast-``max_rps``/slow-``scale_up_hours`` twin
    imitates its transpose) is not monotone, so the lowest-loss z along
    the trajectory beats wherever the cosine tail happened to freeze.
    """
    def losses(z):
        return lane_trace_loss(z, arrivals, targets, scales, weights,
                               policy_index, dt_hours, lo, hi, log_mask,
                               free_mask, fixed)

    def summed(z):
        per_lane = losses(z)
        return per_lane.sum(), per_lane

    vgrad = jax.value_and_grad(summed, has_aux=True)
    opt0 = jax.vmap(lambda z: init_opt_state({"z": z}, ocfg))(z0)
    best0 = jnp.full((z0.shape[0],), jnp.inf, jnp.float32)

    def one_step(carry, _):
        z, opt, z_best, best = carry
        (_, loss), g = vgrad(z)
        better = loss < best
        z_best = jnp.where(better[:, None], z, z_best)
        best = jnp.where(better, loss, best)

        def upd(zk, gk, ok):
            new_p, new_o = adamw_update({"z": zk}, {"z": gk}, ok, ocfg)
            return new_p["z"], new_o

        z2, opt2 = jax.vmap(upd)(z, g, opt)
        return (z2, opt2, z_best, best), loss

    (_, _, z_best, best_loss), history = jax.lax.scan(
        one_step, (z0, opt0, z0, best0), None, length=steps)
    return z_best, best_loss, history


_fit_kernel = functools.partial(jax.jit, static_argnums=(0, 1, 2, 3))(
    _fit_kernel_body)


@functools.lru_cache(maxsize=16)
def _sharded_fit_fn(devices: int, steps: int, dt_hours: float, version: int,
                    ocfg: OptimizerConfig):
    """Build (and cache) the restart-sharded fit kernel for a D-device
    mesh. Restarts are fully independent lanes — per-restart losses,
    per-restart AdamW — so sharding the leading axis changes nothing
    about any lane's arithmetic. On CPU the results may still drift a
    few ulps from the unsharded kernel: XLA's SPMD recompilation can
    contract the loss backward's fused log-residual mul+add chains
    differently at narrow shards (baking the replicated trace operands
    in as jaxpr constants restores bitwise equality, but would force a
    recompile per trace). Parity is pinned at rtol=2e-6 in
    tests/test_stream_objectives.py."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.distributed.sharding import shard_map

    mesh = Mesh(np.asarray(jax.devices()[:devices]), ("restart",))
    shard, rep = P("restart"), P()

    def body(z0, arrivals, targets, scales, weights, lo, hi, log_mask,
             free_mask, fixed, policy_index):
        return _fit_kernel_body(steps, dt_hours, version, ocfg, z0,
                                arrivals, targets, scales, weights, lo, hi,
                                log_mask, free_mask, fixed, policy_index)

    in_specs = (shard,) + (rep,) * 10
    out_specs = (shard, shard, P(None, "restart"))
    return jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False))


def _as_operands(trace: ObservedTrace, weights: Optional[Dict[str, float]]):
    arrivals = jnp.asarray(np.asarray(trace.arrivals, np.float32))
    targets = {k: jnp.asarray(np.asarray(v, np.float32))
               for k, v in trace.series().items()}
    scales = {k: jnp.float32(v) for k, v in trace.scales().items()}
    w = dict(DEFAULT_WEIGHTS)
    w.update(weights or {})
    w_j = {k: jnp.float32(w[k]) for k in SERIES_KEYS}
    return arrivals, targets, scales, w_j


def fit(trace: ObservedTrace, policy: str = "fifo", *,
        restarts: int = 16, steps: int = 400, seed: int = 0,
        init: Optional[Twin] = None,
        freeze: Sequence[str] = (), unfreeze: Sequence[str] = (),
        fixed_values: Optional[Dict[str, float]] = None,
        weights: Optional[Dict[str, float]] = None,
        opt: Optional[OptimizerConfig] = None,
        name: Optional[str] = None,
        devices: Optional[int] = None) -> FitResult:
    """Fit ``policy``'s parameter vector to ``trace`` by gradient descent
    through the simulation scan, from ``restarts`` random starts at once.

    Start 0 is deterministic: the ``init`` twin's parameters if given,
    else the middle of every parameter box; the rest are Gaussian in
    z-space (i.e. spread across the boxes through the sigmoid bijection).

    Scaling the fit
    ---------------
    The loss streams: its flow cumsums and residual accumulators ride the
    simulation scan's carry and the backward pass is the checkpointed
    O(sqrt(T)) VJP, so fitting a long trace holds O(K * sqrt(T)) live
    values instead of O(K * T) series. ``devices=D`` additionally shards
    the K restarts over a D-device mesh (restarts are independent lanes;
    the sharded fit matches the single-device one to a few ulps — see
    ``_sharded_fit_fn`` on why CPU SPMD recompilation keeps it from
    being bitwise); when
    K doesn't divide D the fit warns once and falls back to replication.
    On CPU, export ``XLA_FLAGS=--xla_force_host_platform_device_count=D``
    before the first jax import to get D devices.

    **Observing the wind tunnel** (``repro.obs``). With telemetry on
    the gradient dispatch records a ``calibrate.fit`` span (attrs:
    policy, restarts, steps, t_bins, devices) and counters
    ``calibrate.fits{policy}`` / ``calibrate.restarts``; the two warn
    sites stay countable as ``warn.fit_warm_start_outside{policy}`` and
    ``warn.fit_pinned{policy}`` (the Python warnings still fire). The
    round-trip the exporters close lands here: an instrumented
    windtunnel experiment's ``stage.*`` spans export via
    ``obs.to_otel_spans`` and re-import through
    ``ObservedTrace.from_otel_spans`` as the very trace this function
    fits — the tool calibrating from its own telemetry.
    """
    spec = fit_spec(policy, freeze=freeze, unfreeze=unfreeze,
                    fixed_values=fixed_values, init=init)
    arrivals, targets, scales, w = _as_operands(trace, weights)

    rng = np.random.default_rng(seed)
    z0 = rng.normal(0.0, 1.5, (restarts, PARAM_DIM)).astype(np.float32)
    if init is not None:
        ip = init.padded_params()
        outside = [
            f"{n}={ip[i]:g} vs box ({spec.lo[i]:g}, {spec.hi[i]:g})"
            for i, n in enumerate(spec.param_names)
            if spec.free_mask[i] and not spec.lo[i] <= ip[i] <= spec.hi[i]]
        if outside:
            obs.event("warn.fit_warm_start_outside", policy=policy)
            warnings.warn(
                f"{policy} fit on trace {trace.name!r}: warm start lies "
                f"outside the calibration bounds — {'; '.join(outside)}. "
                f"The sigmoid bijection cannot reach it; widen that "
                f"parameter's bounds (register_policy(bounds=...)) or "
                f"freeze it", stacklevel=2)
        z0[0] = z_from_params(ip, spec.lo, spec.hi, spec.log_mask)
    else:
        z0[0] = 0.0          # mid-box start

    ocfg = dataclasses.replace(opt or DEFAULT_FIT_OPT, total_steps=steps)
    statics = (int(steps), float(trace.bin_hours), registry_version(), ocfg)
    operands = (jnp.asarray(z0), arrivals, targets, scales, w,
                jnp.asarray(spec.lo), jnp.asarray(spec.hi),
                jnp.asarray(spec.log_mask), jnp.asarray(spec.free_mask),
                jnp.asarray(spec.fixed),
                jnp.int32(policy_spec(policy).index))
    d = resolve_mesh_axis(devices, int(restarts),
                          "fit(devices=) restart mesh")
    obs.count("calibrate.fits", policy=policy)
    obs.count("calibrate.restarts", restarts)
    with obs.span("calibrate.fit", policy=policy, restarts=restarts,
                  steps=int(steps), t_bins=int(arrivals.shape[0]),
                  devices=int(d or 1)):
        if d is None:
            z_fin, final_loss, history = _fit_kernel(*statics, *operands)
        else:
            z_fin, final_loss, history = _sharded_fit_fn(
                d, *statics)(*operands)
        jax.block_until_ready(final_loss)

    z_fin = np.asarray(z_fin)
    final_loss = np.asarray(final_loss, np.float64)
    best = int(np.nanargmin(final_loss))
    start_params = np.stack([
        np.asarray(params_from_z(jnp.asarray(z_fin[k]), spec.lo, spec.hi,
                                 spec.log_mask, spec.free_mask, spec.fixed))
        for k in range(restarts)])
    pinned = [
        f"{n}={start_params[best, i]:g} at the "
        f"{'upper' if z_fin[best, i] > 0 else 'lower'} edge of "
        f"({spec.lo[i]:g}, {spec.hi[i]:g})"
        for i, n in enumerate(spec.param_names)
        if spec.free_mask[i] and np.isfinite(spec.hi[i])
        and abs(z_fin[best, i]) > 7.0]    # sigmoid(7) ~ 0.999
    if pinned:
        obs.event("warn.fit_pinned", policy=policy)
        warnings.warn(
            f"{policy} fit on trace {trace.name!r} pinned "
            f"{'; '.join(pinned)} — the measured pipeline likely lies "
            f"outside that parameter's box; widen the policy's bounds "
            f"(register_policy(bounds=...)) or treat the fit as a "
            f"lower/upper bound", stacklevel=2)
    twin = twin_from_z(z_fin[best], spec,
                       name or f"{trace.name}-{policy}-cal")
    return FitResult(twin=twin, policy=policy,
                     loss=float(final_loss[best]),
                     params=start_params[best], spec=spec, best_start=best,
                     start_losses=final_loss, start_params=start_params,
                     loss_history=np.asarray(history, np.float64),
                     trace_name=trace.name)


def evaluate(twin: Twin, trace: ObservedTrace,
             weights: Optional[Dict[str, float]] = None) -> float:
    """Score an existing twin against a trace with the calibration loss
    (no fitting) — the holdout metric."""
    arrivals, targets, scales, w = _as_operands(trace, weights)
    loss = series_loss(jnp.asarray(twin.padded_params()), arrivals, targets,
                       scales, w, jnp.int32(twin.policy_index),
                       float(trace.bin_hours))
    return float(loss)


def fit_with_holdout(train: ObservedTrace, holdout: ObservedTrace,
                     policy: str = "fifo", **fit_kwargs) -> FitResult:
    """Fit on one trace, validate on another (the measure-on-ramp /
    validate-on-steady workflow): the returned FitResult carries the
    holdout loss and the generalization gap. Extra kwargs — ``devices=D``
    included — forward to ``fit``."""
    result = fit(train, policy, **fit_kwargs)
    result.holdout_loss = evaluate(
        result.twin, holdout, weights=fit_kwargs.get("weights"))
    result.holdout_name = holdout.name
    return result


def calibrated_twin(source: Union[ObservedTrace, "ExperimentResult"],
                    policy: str = "fifo", *, bin_s: float = 1.0,
                    name: Optional[str] = None,
                    **fit_kwargs) -> Twin:
    """Measured pipeline -> simulation-ready Twin, in one call.

    ``source`` is an ``ExperimentResult`` (binned into a trace at
    ``bin_s``-second resolution, with the paper's closed-form fit as the
    warm start) or a prebuilt ``ObservedTrace``. Extra kwargs forward to
    ``fit`` (``devices=D`` shards the restarts over a device mesh). Use
    ``fit()`` directly when you want the convergence table.
    """
    if isinstance(source, ObservedTrace):
        trace = source
    else:
        trace = ObservedTrace.from_experiment(source, bin_s=bin_s)
        if "init" not in fit_kwargs:
            try:
                fit_kwargs["init"] = fit_twin(source, policy)
            except (KeyError, AttributeError):
                fit_kwargs["init"] = None
    result = fit(trace, policy, name=name, **fit_kwargs)
    return result.twin

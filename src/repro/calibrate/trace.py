"""Observed pipeline traces — the measurement side of twin calibration.

An ``ObservedTrace`` is what the fit in ``repro.calibrate.fit`` matches a
simulated twin against: per-bin arrivals, processed records, end-to-end
latency, dropped records and cost over a uniform time grid of
``bin_hours``-wide bins. Three ways to build one:

* ``ObservedTrace.from_experiment`` — from a wind-tunnel
  ``ExperimentResult`` (paper Sec. V-F): arrivals come from the
  ``records_sent`` counter the experiment records in virtual time,
  completions from the final stage's spans, and per-record latency from
  FIFO-matching the cumulative arrival and completion curves (completion
  time of the k-th finished record minus arrival time of the k-th sent
  record — queueing delay included, which per-stage service spans alone
  would miss).
* ``ObservedTrace.from_loadpattern`` — replay a ``LoadPattern`` through a
  ground-truth twin at sub-hour resolution via the generalized simulation
  scan (``core.simulate.scan_trace``). This is the synthetic-benchmark
  path: simulate with known parameters, optionally ``with_noise``, then
  check the fit recovers them.
* ``ObservedTrace.from_simulation`` — same, from an arrivals array you
  already have.
* ``ObservedTrace.from_otel_spans`` — from exported OpenTelemetry-style
  spans (plain list of dicts with start/end/status; no OTel SDK
  dependency), so a real PlantD deployment's trace export feeds
  ``repro.calibrate`` directly (ROADMAP "Trace importers").
* ``ObservedTrace.from_prometheus`` — from Prometheus range-query
  result matrices (``/api/v1/query_range`` JSON, no client dependency):
  rate queries become per-bin record counts, a latency gauge rides
  along, multiple label sets are summed — the metrics-side sibling of
  the span importer.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, Optional

import numpy as np

from repro.core.loadpattern import LoadPattern

#: the series a calibration loss may match, in canonical order
SERIES_KEYS = ("processed", "latency", "dropped", "cost")


def bin_loadpattern(pattern: LoadPattern, bin_s: float = 60.0) -> np.ndarray:
    """Integrate a piecewise-linear LoadPattern into records-per-bin counts."""
    total = pattern.total_duration
    nbins = max(1, int(math.ceil(total / bin_s)))
    edges = np.minimum(np.arange(nbins + 1) * bin_s, total)
    return np.array([pattern.records_between(float(t0), float(t1))
                     for t0, t1 in zip(edges[:-1], edges[1:])], np.float64)


@dataclass(frozen=True)
class ObservedTrace:
    """Per-bin series measured (or synthesized) from a pipeline run."""
    name: str
    bin_hours: float
    arrivals: np.ndarray       # records arriving per bin [T]
    processed: np.ndarray      # records completed per bin [T]
    latency_s: np.ndarray      # mean end-to-end latency of the bin [T]
    dropped: np.ndarray        # records shed per bin [T]
    cost_usd: np.ndarray       # cost accrued per bin [T]

    def __post_init__(self):
        T = len(self.arrivals)
        for key in ("processed", "latency_s", "dropped", "cost_usd"):
            arr = getattr(self, key)
            if arr.shape != (T,):
                raise ValueError(f"{key} has shape {arr.shape}, want ({T},)")

    @property
    def num_bins(self) -> int:
        return len(self.arrivals)

    @property
    def duration_hours(self) -> float:
        return self.num_bins * self.bin_hours

    def series(self) -> Dict[str, np.ndarray]:
        """The fit targets keyed by SERIES_KEYS."""
        return {"processed": self.processed, "latency": self.latency_s,
                "dropped": self.dropped, "cost": self.cost_usd}

    def scales(self) -> Dict[str, float]:
        """Per-series normalization so the loss mixes unlike units: the
        mean magnitude of the observed series, falling back to the arrival
        scale (dropped) or 1.0 when a series is identically zero."""
        arr_scale = float(np.mean(np.abs(self.arrivals))) or 1.0
        out = {}
        for key, vals in self.series().items():
            s = float(np.mean(np.abs(vals)))
            if s <= 0.0:
                s = arr_scale if key == "dropped" else 1.0
            out[key] = s
        return out

    def with_noise(self, frac: float, seed: int = 0) -> "ObservedTrace":
        """Element-wise multiplicative Gaussian measurement noise on every
        series (drop noise scales with arrivals so zero-drop bins still
        jitter) — for fit-robustness tests."""
        rng = np.random.default_rng(seed)

        def jitter(x, rel_to=None):
            scale = np.abs(x) if rel_to is None else np.mean(np.abs(rel_to))
            return np.maximum(x + rng.normal(0.0, frac, x.shape) * scale, 0.0)

        return replace(self,
                       name=f"{self.name}+noise{frac:g}",
                       processed=jitter(self.processed),
                       latency_s=jitter(self.latency_s),
                       dropped=jitter(self.dropped, rel_to=self.arrivals),
                       cost_usd=jitter(self.cost_usd))

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_simulation(cls, twin, arrivals: np.ndarray, bin_hours: float,
                        name: Optional[str] = None) -> "ObservedTrace":
        """Ground-truth replay: run ``twin`` over ``arrivals`` (records per
        bin) through the generalized scan and package the outputs."""
        import jax.numpy as jnp

        from repro.core.simulate import scan_trace

        load = jnp.asarray(np.asarray(arrivals, np.float32))
        _, (proc, _queue, lat, cost, drop) = scan_trace(
            load, jnp.asarray(twin.padded_params()), twin.policy_index,
            float(bin_hours))
        return cls(name=name or f"{twin.name}-replay",
                   bin_hours=float(bin_hours),
                   arrivals=np.asarray(arrivals, np.float64),
                   processed=np.asarray(proc, np.float64),
                   latency_s=np.asarray(lat, np.float64),
                   dropped=np.asarray(drop, np.float64),
                   cost_usd=np.asarray(cost, np.float64))

    @classmethod
    def from_loadpattern(cls, pattern: LoadPattern, twin,
                         bin_s: float = 60.0,
                         name: Optional[str] = None) -> "ObservedTrace":
        """Replay a LoadPattern through a ground-truth twin at sub-hour
        resolution (the paper's ramp/steady patterns become fit traces)."""
        arrivals = bin_loadpattern(pattern, bin_s)
        return cls.from_simulation(twin, arrivals, bin_s / 3600.0,
                                   name=name or f"{pattern.name}-replay")

    @classmethod
    def from_otel_spans(cls, spans, bin_seconds: float = 60.0,
                        name: str = "otel",
                        usd_per_hour: float = 0.0) -> "ObservedTrace":
        """Bin exported OpenTelemetry-style spans into a calibration trace.

        ``spans`` is a plain list of dicts — no OTel SDK dependency, just
        the shape an OTLP/JSON export (or a hand-rolled span log) already
        has. Recognized keys per span:

        * ``start`` / ``end`` — unix seconds, or the OTLP field names
          ``start_time_unix_nano`` / ``end_time_unix_nano`` (nanoseconds);
        * ``status`` — optional; ``"ERROR"`` (or OTLP status code 2)
          counts the span's records as dropped instead of processed;
        * ``records`` — optional batch size, default 1 record per span.

        Arrivals bin by span start, completions (and their end-to-end
        latency, record-weighted per bin) by span end; error spans feed
        the dropped series at their end bin. The cost series is flat at
        ``usd_per_hour`` (pass the deployment's known rate, or leave 0 and
        down-weight cost in the fit). Times are rebased to the earliest
        span start, so any epoch works.
        """
        if not spans:
            raise ValueError("from_otel_spans needs at least one span")

        def _time(sp, key):
            if key in sp:
                return float(sp[key])
            nano = sp.get(f"{key}_time_unix_nano")
            if nano is None:
                raise KeyError(f"span missing {key!r} / "
                               f"'{key}_time_unix_nano': {sp!r}")
            return float(nano) * 1e-9

        def _is_error(sp):
            status = sp.get("status", "OK")
            if isinstance(status, dict):      # OTLP: {"code": 2} — or the
                status = status.get("code", 0)   # protobuf-JSON enum NAME
            if isinstance(status, (int, float)):
                return int(status) == 2
            # "ERROR" / "STATUS_CODE_ERROR" / "2" string forms
            return str(status).upper() in ("ERROR", "STATUS_CODE_ERROR",
                                           "2")

        starts = np.array([_time(sp, "start") for sp in spans])
        ends = np.array([_time(sp, "end") for sp in spans])
        if (ends < starts).any():
            raise ValueError("span end precedes its start")
        recs = np.array([float(sp.get("records", 1.0)) for sp in spans])
        errors = np.array([_is_error(sp) for sp in spans])

        t0 = starts.min()
        dur = max(float(ends.max() - t0), bin_seconds)
        nbins = max(1, int(math.ceil(dur / bin_seconds)))

        def _binned(times, weights):
            out = np.zeros(nbins)
            which = np.clip((times - t0) / bin_seconds, 0,
                            nbins - 1).astype(int)
            np.add.at(out, which, weights)
            return out

        arrivals = _binned(starts, recs)
        ok = ~errors
        processed = _binned(ends[ok], recs[ok])
        dropped = _binned(ends[errors], recs[errors])
        # record-weighted mean end-to-end latency of the bin a span ends in
        lat_w = _binned(ends[ok], (recs * (ends - starts))[ok])
        latency = np.zeros(nbins)
        seen = processed > 0
        latency[seen] = lat_w[seen] / processed[seen]
        if seen.any():
            latency[~seen] = float(lat_w.sum() / processed.sum())

        bin_hours = bin_seconds / 3600.0
        return cls(name=name, bin_hours=bin_hours, arrivals=arrivals,
                   processed=processed, latency_s=latency, dropped=dropped,
                   cost_usd=np.full(nbins, usd_per_hour * bin_hours))

    @classmethod
    def from_prometheus(cls, responses: Dict, bin_seconds: float = 60.0,
                        name: str = "prometheus",
                        usd_per_hour: float = 0.0) -> "ObservedTrace":
        """Bin Prometheus range-query responses into a calibration trace.

        ``responses`` maps series keys to parsed range-query JSON
        (``/api/v1/query_range``) — either the full ``{"status", "data"}``
        envelope, the ``data`` object (``{"resultType": "matrix",
        "result": [...]}``), or the bare ``result`` list. Keys:

        * ``"arrivals"`` (required) and ``"processed"`` (required) —
          rates in records/second (the usual ``rate(counter[...])``
          query); per-bin records = rate at the bin center x bin width;
        * ``"dropped"`` — optional records/second rate (default zeros);
        * ``"latency"`` — optional gauge in seconds (e.g. a summary/
          histogram mean), default zeros;
        * ``"cost"`` — optional rate in USD/hour; omitted, the cost
          series is flat at ``usd_per_hour``.

        Sample values may be strings (Prometheus returns them quoted).
        Each response may hold several result entries (one per label
        set, e.g. per instance): rate-like series are SUMMED across
        entries, the latency gauge is averaged. Samples are linearly
        interpolated onto the common bin-center grid (clamped at the
        ends), so differing query steps and ranges line up; times are
        rebased to the earliest sample. This closes the ROADMAP
        "trace importers" item next to ``from_otel_spans``.
        """
        rate_keys = ("arrivals", "processed", "dropped", "cost")
        known = set(rate_keys) | {"latency"}
        unknown = set(responses) - known
        if unknown:
            raise ValueError(f"unknown series keys {sorted(unknown)}; "
                             f"expected a subset of {sorted(known)}")
        for req in ("arrivals", "processed"):
            if req not in responses:
                raise ValueError(f"from_prometheus needs an {req!r} "
                                 f"range-query response")

        def _entries(resp, key):
            if isinstance(resp, dict) and "status" in resp:
                # real Prometheus error envelopes carry NO 'data' key,
                # so check the status before unwrapping anything
                if resp.get("status") != "success":
                    raise ValueError(
                        f"{key}: Prometheus query failed: "
                        f"{resp.get('error', resp.get('status'))!r}")
                resp = resp.get("data", {})
            elif isinstance(resp, dict) and "data" in resp:
                resp = resp["data"]
            if isinstance(resp, dict) and "result" in resp:
                rtype = resp.get("resultType", "matrix")
                if rtype != "matrix":
                    raise ValueError(
                        f"{key}: need a range-query matrix result, got "
                        f"resultType {rtype!r} (instant queries have no "
                        f"time axis to bin)")
                resp = resp["result"]
            if not isinstance(resp, (list, tuple)):
                raise ValueError(f"{key}: unrecognized response shape "
                                 f"{type(resp).__name__}")
            series = []
            for entry in resp:
                values = entry.get("values") if isinstance(entry, dict) \
                    else None
                if not values:
                    continue
                ts = np.array([float(t) for t, _ in values])
                vs = np.array([float(v) for _, v in values])
                order = np.argsort(ts, kind="stable")
                series.append((ts[order], vs[order]))
            return series

        parsed = {k: _entries(r, k) for k, r in responses.items()}
        for key, series in parsed.items():
            # a PROVIDED series with zero samples would silently bin to
            # zeros (and, for cost, shadow the usd_per_hour fallback) —
            # fitting a twin to a pipeline that apparently did nothing
            if not series:
                raise ValueError(f"{key} response holds no samples")
        all_ts = np.concatenate([ts for ser in parsed.values()
                                 for ts, _ in ser])
        t0, t1 = float(all_ts.min()), float(all_ts.max())
        nbins = max(1, int(math.ceil((t1 - t0) / bin_seconds)))
        centers = t0 + (np.arange(nbins) + 0.5) * bin_seconds

        def _sampled(key, combine_mean=False):
            series = parsed.get(key) or []
            if not series:
                return np.zeros(nbins)
            interped = [np.interp(centers, ts, vs) for ts, vs in series]
            out = np.sum(interped, axis=0)
            return out / len(interped) if combine_mean else out

        bin_hours = bin_seconds / 3600.0
        cost = (_sampled("cost") * bin_hours if "cost" in parsed
                else np.full(nbins, usd_per_hour * bin_hours))
        return cls(name=name, bin_hours=bin_hours,
                   arrivals=_sampled("arrivals") * bin_seconds,
                   processed=_sampled("processed") * bin_seconds,
                   latency_s=_sampled("latency", combine_mean=True),
                   dropped=_sampled("dropped") * bin_seconds,
                   cost_usd=cost)

    @classmethod
    def from_experiment(cls, result, bin_s: float = 1.0,
                        stage: Optional[str] = None) -> "ObservedTrace":
        """Bin a measured ``ExperimentResult`` into a calibration trace.

        Times are virtual (undilated) seconds from experiment start, so
        ``time_scale``-accelerated test runs calibrate the same as real
        ones. ``stage`` selects which stage's completions count as
        "processed" (default: the last stage observed).
        """
        ts = getattr(result, "time_scale", 1.0)
        dur = max(result.duration_s, bin_s)
        nbins = max(1, int(math.ceil(dur / bin_s)))
        edges = np.arange(nbins + 1) * bin_s

        # arrivals: the cumulative records_sent counter, virtual-time stamped
        sent = result.metrics.series("records_sent")
        if sent:
            t = np.array([s.t for s in sent])
            v = np.array([s.value for s in sent])
            cum_arr = np.interp(edges, t, v, left=0.0, right=v[-1])
        else:   # pre-calibration results: spread the total uniformly
            cum_arr = np.linspace(0.0, result.records_sent, nbins + 1)
        arrivals = np.diff(cum_arr)

        # completions: spans of the final stage, converted to virtual time
        stage = stage or (list(result.stage_summary)[-1]
                          if result.stage_summary else None)
        spans = sorted(result.collector.spans(stage),
                       key=lambda s: s.end) if stage else []
        ends = np.array([(s.end - result.started) * ts for s in spans])
        recs = np.array([float(s.records) for s in spans])
        processed = np.zeros(nbins)
        latency = np.zeros(nbins)
        if len(spans):
            which = np.clip(np.searchsorted(edges, ends, side="right") - 1,
                            0, nbins - 1)
            np.add.at(processed, which, recs)
            # FIFO matching: the k-th completed record arrived at the time
            # the cumulative arrival curve crossed k, so its latency is the
            # span end minus that crossing — queueing delay included
            done_before = np.concatenate([[0.0], np.cumsum(recs)[:-1]])
            mid = done_before + 0.5 * recs
            t_arrive = np.interp(mid, cum_arr, edges)
            lat_span = np.maximum(ends - t_arrive, 0.0)
            wsum = np.zeros(nbins)
            np.add.at(wsum, which, recs)
            np.add.at(latency, which, recs * lat_span)
            seen = wsum > 0
            latency[seen] /= wsum[seen]
            if seen.any():
                latency[~seen] = float(
                    (latency[seen] * wsum[seen]).sum() / wsum[seen].sum())

        bin_hours = bin_s / 3600.0
        usd_hr = float(result.cost.get("usd_per_hour", 0.0))
        return cls(name=f"{result.name}-trace", bin_hours=bin_hours,
                   arrivals=arrivals, processed=processed,
                   latency_s=latency, dropped=np.zeros(nbins),
                   cost_usd=np.full(nbins, usd_hr * bin_hours))

"""Calibration objective: bounded reparameterization + multi-series loss.

The fit never optimizes twin parameters directly — it optimizes an
unconstrained vector ``z`` mapped onto each policy's declared parameter
box (``PolicySpec.bounds``) by a smooth bijection:

* finite box, linear param:   p = lo + (hi - lo) * sigmoid(z)
* finite box, log-scale param: p = exp(log lo + (log hi - log lo) * sigmoid(z))
  (scale parameters like max_rps span decades; fitting their exponent
  conditions the problem)
* half-open box (hi = inf):    p = lo + softplus(z)

Frozen parameters (``PolicySpec.frozen`` plus anything the caller freezes)
and the zero-padding slots of the flat vector bypass ``z`` entirely and
take fixed values, so the gradient never touches them.

``trace_loss`` plays the candidate parameters through the *same*
``lax.scan`` the what-if simulator uses (``core.simulate.scan_trace``)
and scores the simulated throughput / latency / drop / cost series
against an ``ObservedTrace`` with a weighted, per-series-normalized MSE.
The lane-block form the optimizer compiles (``lane_series_loss``)
streams that score: running flow sums and compensated residual
accumulators fold into the simulation scan's carry
(``kernels.ops.policy_scan_fold``), so neither the forward nor the
checkpointed O(sqrt(T)) backward materializes a [K, T] series.
Everything here is pure JAX: ``repro.calibrate.fit`` takes grad of the
summed per-lane losses and jits once for all restarts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.calibrate.trace import SERIES_KEYS
from repro.core.simulate import scan_trace
from repro.core.twin import (PARAM_DIM, Twin, fold_triple_add,
                             fold_triple_finalize, fold_triple_init,
                             policy_spec)

#: default loss mix: throughput and latency curves carry most signal; the
#: drop curve pins bounded-queue policies; cost identifies $/hr parameters
DEFAULT_WEIGHTS: Dict[str, float] = {
    "processed": 1.0, "latency": 1.0, "dropped": 1.0, "cost": 1.0,
}


@dataclass(frozen=True)
class FitSpec:
    """Per-policy fit layout: which PARAM_DIM slots are free, their boxes,
    their transform, and the fixed values of everything else."""
    policy: str
    param_names: Tuple[str, ...]
    lo: np.ndarray          # [PARAM_DIM] f32
    hi: np.ndarray          # [PARAM_DIM] f32 (may be +inf -> softplus)
    log_mask: np.ndarray    # [PARAM_DIM] bool — fit exponent, not value
    free_mask: np.ndarray   # [PARAM_DIM] bool — optimized slots
    fixed: np.ndarray       # [PARAM_DIM] f32 — value wherever not free

    @property
    def free_names(self) -> Tuple[str, ...]:
        return tuple(n for i, n in enumerate(self.param_names)
                     if self.free_mask[i])


def fit_spec(policy: str, freeze: Sequence[str] = (),
             unfreeze: Sequence[str] = (),
             fixed_values: Optional[Dict[str, float]] = None,
             init: Optional[Twin] = None) -> FitSpec:
    """Build the fit layout for ``policy`` from the registry metadata.

    ``freeze``/``unfreeze`` adjust the policy's default frozen set; fixed
    values come from ``fixed_values``, then the ``init`` twin, then the
    registered defaults — a frozen parameter with none of the three is an
    error.
    """
    spec = policy_spec(policy)
    names = spec.param_names
    frozen = (set(spec.frozen) | set(freeze)) - set(unfreeze)
    unknown = (set(freeze) | set(unfreeze)) - set(names)
    if unknown:
        raise KeyError(f"{policy} has no params {sorted(unknown)}")

    values: Dict[str, float] = dict(spec.defaults)
    if init is not None:
        if init.policy != policy:
            raise ValueError(f"init twin is {init.policy!r}, want {policy!r}")
        values.update(zip(names, init.padded_params()))
    values.update(fixed_values or {})

    lo = np.zeros(PARAM_DIM, np.float32)
    hi = np.ones(PARAM_DIM, np.float32)
    log_mask = np.zeros(PARAM_DIM, bool)
    free_mask = np.zeros(PARAM_DIM, bool)
    fixed = np.zeros(PARAM_DIM, np.float32)
    for i, pname in enumerate(names):
        b_lo, b_hi = spec.bound(pname)
        lo[i], hi[i] = b_lo, b_hi
        log_mask[i] = pname in spec.log_params
        if pname in frozen:
            if pname not in values:
                raise KeyError(f"frozen param {pname!r} needs a value "
                               f"(fixed_values=, init=, or a default)")
            fixed[i] = float(values[pname])
        else:
            free_mask[i] = True
    return FitSpec(policy=policy, param_names=names, lo=lo, hi=hi,
                   log_mask=log_mask, free_mask=free_mask, fixed=fixed)


# ---------------------------------------------------------------------------
# the z <-> params bijection
# ---------------------------------------------------------------------------

def params_from_z(z, lo, hi, log_mask, free_mask, fixed):
    """Map unconstrained ``z`` [PARAM_DIM] onto the parameter box."""
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    finite = jnp.isfinite(hi)
    lo_pos = jnp.maximum(lo, 1e-12)          # log path needs lo > 0
    hi_safe = jnp.where(finite, hi, lo_pos * 2.0)   # keep logs/NaNs out of
    s = jax.nn.sigmoid(z)                           # the untaken branch
    lin = lo + (hi_safe - lo) * s
    logp = jnp.exp(jnp.log(lo_pos)
                   + (jnp.log(jnp.maximum(hi_safe, lo_pos)) - jnp.log(lo_pos)) * s)
    boxed = jnp.where(log_mask, logp, lin)
    soft = lo + jax.nn.softplus(z)
    p = jnp.where(finite, boxed, soft)
    return jnp.where(free_mask, p, jnp.asarray(fixed, jnp.float32))


def z_from_params(params, lo, hi, log_mask) -> np.ndarray:
    """Inverse bijection (numpy): a warm-start z for a known twin."""
    params = np.asarray(params, np.float64)
    lo = np.asarray(lo, np.float64)
    hi = np.asarray(hi, np.float64)
    lo_pos = np.maximum(lo, 1e-12)
    finite = np.isfinite(hi)
    hi_safe = np.where(finite, hi, lo_pos * 2.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        frac_lin = (params - lo) / np.maximum(hi_safe - lo, 1e-12)
        frac_log = ((np.log(np.maximum(params, 1e-12)) - np.log(lo_pos))
                    / np.maximum(np.log(np.maximum(hi_safe, lo_pos))
                                 - np.log(lo_pos), 1e-12))
    frac = np.clip(np.where(log_mask, frac_log, frac_lin), 1e-4, 1.0 - 1e-4)
    z = np.log(frac / (1.0 - frac))
    # softplus inverse for half-open boxes: z = log(exp(p - lo) - 1),
    # which is ~identity past gap 30 (expm1 would overflow there)
    gap = np.maximum(params - lo, 1e-6)
    z_soft = np.where(gap > 30.0, gap,
                      np.log(np.expm1(np.minimum(gap, 30.0))))
    return np.where(finite, z, z_soft).astype(np.float32)


def twin_from_z(z: np.ndarray, spec: FitSpec, name: str) -> Twin:
    """Materialize the fitted Twin from an optimized z vector."""
    p = np.asarray(params_from_z(jnp.asarray(z, jnp.float32), spec.lo,
                                 spec.hi, spec.log_mask, spec.free_mask,
                                 spec.fixed))
    return Twin(name=name, policy=spec.policy, kind="calibrated",
                params=tuple(float(v) for v in p[:len(spec.param_names)]))


# ---------------------------------------------------------------------------
# the loss
# ---------------------------------------------------------------------------

def series_loss(params_vec, arrivals, targets, scales, weights, policy_index,
                dt_hours):
    """Weighted MSE of log-ratio residuals, simulated vs observed, given a
    concrete parameter vector (no reparameterization).

    Residuals are multiplicative — ``log((sim + eps) / (obs + eps))`` —
    because the series span decades within one trace (a 0.2 s service
    latency next to hour-long queueing delays, near-zero ramp-up arrivals
    next to peak load): a linear MSE would let the large-magnitude bins
    swamp the small ones and lose e.g. ``base_latency_s`` entirely. The
    floor ``eps`` is six decades below each series' magnitude, so exact
    zeros (no drops, idle bins) stay well-defined without muting genuine
    mismatches.

    Flow series (processed / dropped / cost) are matched *cumulatively*:
    bursty policies emit spikes (batch_window's flushes, quickscale's
    per-bin instance counts) whose per-bin alignment is a step function
    of the parameters — a plateaued, ungradientable landscape — while
    the distance between cumulative staircases varies smoothly with
    flush timing and capacity. The state series (latency) stays per-bin.
    """
    _, (proc, _queue, lat, cost, drop) = scan_trace(
        arrivals, params_vec, policy_index, dt_hours)
    sim = {"processed": proc, "latency": lat, "dropped": drop, "cost": cost}
    total = jnp.zeros(())
    for key in SERIES_KEYS:
        s, t = sim[key], targets[key]
        if key != "latency":            # flow series: match the running sum
            s, t = jnp.cumsum(s), jnp.cumsum(t)
            eps = t[-1] * 1e-6 + 1e-12
        else:
            eps = scales[key] * 1e-6 + 1e-12
        r = jnp.log((s + eps) / (t + eps))
        total = total + weights[key] * jnp.mean(r * r)
    return total


def trace_loss(z, arrivals, targets, scales, weights, policy_index, dt_hours,
               lo, hi, log_mask, free_mask, fixed):
    """The calibration objective: reparameterize, simulate, score."""
    p = params_from_z(z, lo, hi, log_mask, free_mask, fixed)
    return series_loss(p, arrivals, targets, scales, weights, policy_index,
                       dt_hours)


# ---------------------------------------------------------------------------
# the lane-block loss — K restarts as K lanes of the shared grid backend
# ---------------------------------------------------------------------------
#
# The streamed form folds the loss INTO the simulation scan: the fold
# carries each lane's running flow sums (the cumulative-staircase match
# needs exactly the prefix up to the current bin, nothing older) and one
# twice-compensated residual triple per series. Both the streamed and the
# materialized paths run the SAME module-level fold functions below over
# the same per-bin rows, so the loss is bit-identical between them by
# construction — the fold functions are module-level because they key the
# kernel's trace caches (``kernels.ops.policy_scan_fold``).

def _cal_fold_init(n):
    """Per-lane accumulator: 3 running flow sums (processed / dropped /
    cost — plain f32 adds, shared verbatim by both paths) + 4 compensated
    squared-log-ratio triples, one per SERIES_KEYS entry."""
    z = jnp.zeros((n,), jnp.float32)
    return (z, z, z, fold_triple_init((n,)), fold_triple_init((n,)),
            fold_triple_init((n,)), fold_triple_init((n,)))


def _cal_fold(acc, arrive, outs, ops_lane, xs_row):
    """One bin of the calibration loss: advance the flow cumsums, score
    this bin's log-ratio residual per series against the precomputed
    target row (``xs_row``), accumulate r^2 into the triples. ``ops_lane``
    carries the per-series eps floors (six decades below each series'
    magnitude — see ``series_loss``)."""
    del arrive
    cum_p, cum_d, cum_c, t_p, t_l, t_d, t_c = acc
    proc, _queue, lat, cost, drop = outs
    eps_p, eps_l, eps_d, eps_c = ops_lane
    tgt_p, tgt_l, tgt_d, tgt_c = xs_row
    cum_p = cum_p + proc
    cum_d = cum_d + drop
    cum_c = cum_c + cost
    r_p = jnp.log((cum_p + eps_p) / (tgt_p + eps_p))
    r_l = jnp.log((lat + eps_l) / (tgt_l + eps_l))
    r_d = jnp.log((cum_d + eps_d) / (tgt_d + eps_d))
    r_c = jnp.log((cum_c + eps_c) / (tgt_c + eps_c))
    return (cum_p, cum_d, cum_c,
            fold_triple_add(t_p, r_p * r_p), fold_triple_add(t_l, r_l * r_l),
            fold_triple_add(t_d, r_d * r_d), fold_triple_add(t_c, r_c * r_c))


def _cal_operands(targets, scales):
    """Target-side per-bin rows (flow cumsums + per-bin latency) and the
    per-series eps floors — computed ONCE outside the scan and fed to
    both the streamed and the materialized path, so how the target
    staircase was built can never split them."""
    tgt_p = jnp.cumsum(targets["processed"])
    tgt_d = jnp.cumsum(targets["dropped"])
    tgt_c = jnp.cumsum(targets["cost"])
    xs = (tgt_p, targets["latency"], tgt_d, tgt_c)
    eps = (tgt_p[-1] * 1e-6 + 1e-12, scales["latency"] * 1e-6 + 1e-12,
           tgt_d[-1] * 1e-6 + 1e-12, tgt_c[-1] * 1e-6 + 1e-12)
    return xs, eps


def _cal_combine(acc, weights, t_bins):
    """Finalize the 4 triples -> per-series means -> weighted total, in
    SERIES_KEYS order (both paths share this code)."""
    _cum_p, _cum_d, _cum_c, t_p, t_l, t_d, t_c = acc
    total = jnp.zeros(())
    for key, triple in zip(SERIES_KEYS, (t_p, t_l, t_d, t_c)):
        total = total + weights[key] * (fold_triple_finalize(triple) / t_bins)
    return total


def lane_series_loss(params_block, arrivals, targets, scales, weights,
                     policy_index, dt_hours, stream: bool = True):
    """[K] per-restart losses for a [K, PARAM_DIM] block of candidates.

    The K restarts are just K more lanes of the scenario-grid scan: the
    trace's arrivals broadcast across the lane block and the whole stack
    runs through the shared gradient backend. All restarts share one
    policy, so ``policy_index`` (a traced scalar; one jit trace serves
    every policy) selects a single lane branch via ``lax.switch`` — no
    P-way masked blend in the optimizer hot loop. Same log-ratio /
    cumulative-flow scoring as ``series_loss``, vectorized over lanes.

    ``stream=True`` (the default, and what ``fit`` compiles) folds the
    running flow sums and the residual accumulators into the scan carry
    via ``kernels.ops.policy_scan_fold``, so neither the forward value
    nor the checkpointed O(sqrt(T)) backward ever holds a [K, T] series.
    ``stream=False`` materializes the five series through
    ``kernels.ops.policy_scan`` and replays the SAME fold over them —
    the O(T)-memory reference the parity tests pin the stream against,
    bit for bit.
    """
    from repro.kernels import ops    # late: keep calibrate importable
    k = params_block.shape[0]        # without the kernels package loaded
    arrivals = jnp.asarray(arrivals, jnp.float32)
    t_bins = arrivals.shape[-1]
    xs, eps = _cal_operands(targets, scales)
    if stream:
        loads_t = jnp.broadcast_to(arrivals[:, None], (t_bins, k))
        _, acc = ops.policy_scan_fold(
            params=params_block, dt_hours=dt_hours,
            policy_index=policy_index, loads_t=loads_t,
            fold_init=_cal_fold_init, fold_step=_cal_fold,
            ops_lane=eps, xs=xs)
        return _cal_combine(acc, weights, t_bins)
    loads = jnp.broadcast_to(arrivals, (k,) + arrivals.shape)
    _, outs = ops.policy_scan(
        loads, params_block, dt_hours=dt_hours, policy_index=policy_index,
        differentiable=True)
    outs_t = tuple(s.T for s in outs)      # [T, K] rows for the shared fold

    def scan_fold(acc, row):
        loads_row, outs_row, xs_row = row
        return _cal_fold(acc, loads_row, outs_row, eps, xs_row), None

    acc, _ = jax.lax.scan(scan_fold, _cal_fold_init(k),
                          (arrivals, outs_t, xs))
    return _cal_combine(acc, weights, t_bins)


def lane_trace_loss(z_block, arrivals, targets, scales, weights,
                    policy_index, dt_hours, lo, hi, log_mask, free_mask,
                    fixed, stream: bool = True):
    """``trace_loss`` over a [K, PARAM_DIM] restart block: reparameterize
    every lane, then score the block through the shared lane backend."""
    p = jax.vmap(
        lambda z: params_from_z(z, lo, hi, log_mask, free_mask, fixed)
    )(z_block)
    return lane_series_loss(p, arrivals, targets, scales, weights,
                            policy_index, dt_hours, stream=stream)

"""Differentiable twin calibration: fit digital twins to measured traces.

Closes the paper's measure -> model -> simulate loop (Secs. V-F/V-G):
``ObservedTrace`` packages what a wind-tunnel experiment measured,
``fit`` recovers any registered TwinPolicy's parameter vector from it by
differentiating through the simulation scan (all restarts in one vmapped
dispatch), and ``calibrated_twin`` hands the result straight to the
what-if grids.
"""
from repro.calibrate.fit import (DEFAULT_FIT_OPT, FitResult, calibrated_twin,
                                 evaluate, fit, fit_with_holdout)
from repro.calibrate.objective import (DEFAULT_WEIGHTS, FitSpec, fit_spec,
                                       lane_series_loss, lane_trace_loss,
                                       params_from_z, series_loss,
                                       trace_loss, twin_from_z,
                                       z_from_params)
from repro.calibrate.trace import ObservedTrace, SERIES_KEYS, bin_loadpattern

__all__ = [
    "DEFAULT_FIT_OPT", "DEFAULT_WEIGHTS", "FitResult", "FitSpec",
    "ObservedTrace", "SERIES_KEYS", "bin_loadpattern", "calibrated_twin",
    "evaluate", "fit", "fit_spec", "fit_with_holdout", "lane_series_loss",
    "lane_trace_loss", "params_from_z", "series_loss", "trace_loss",
    "twin_from_z", "z_from_params",
]

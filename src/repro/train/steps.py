"""Train-step factory: loss -> grads -> AdamW under pjit.

Features toggled by ParallelConfig:
  * remat policy on the layer-scan body (none/full/dots)
  * microbatch gradient accumulation (lax.scan over microbatches)
  * int8 cross-pod gradient compression with error feedback: per-pod
    gradients are block-quantized and summed across the 'pod' axis via a
    shard_map'd psum, cutting DCN all-reduce bytes 4x (the dry-run's
    collective term shows it).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, OptimizerConfig, ParallelConfig
from repro.distributed.sharding import (build_rules, input_batch_specs,
                                        mesh_shape_dict, set_activation_mesh)
from repro.models import model as M
from repro.models import transformer as tf
from repro.optim.adamw import (OptState, adamw_update, init_opt_state,
                               opt_state_specs)


def _tree_ns(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def train_shardings(cfg: ModelConfig, ocfg: OptimizerConfig,
                    parallel: ParallelConfig, mesh: Mesh, batch_abstract: Dict):
    rules = build_rules(parallel, mesh)
    mshape = mesh_shape_dict(mesh)
    pspecs = M.partition_specs(cfg, rules, mshape)
    params_abs = M.abstract_params(cfg)
    ospecs = opt_state_specs(pspecs, ocfg, params_abs,
                             parallel.fsdp_axis or "data", mshape)
    bspecs = input_batch_specs(batch_abstract, parallel, mesh)
    return pspecs, ospecs, bspecs


def _microbatch(batch: Dict, k: int) -> Dict:
    def split(x):
        return x.reshape((k, x.shape[0] // k) + x.shape[1:])
    out = {}
    for key, v in batch.items():
        if key == "positions" and v.ndim == 3:        # [3, b, s]
            out[key] = jnp.moveaxis(
                v.reshape(v.shape[0], k, v.shape[1] // k, v.shape[2]), 1, 0)
        else:
            out[key] = split(v)
    return out


def make_train_step(cfg: ModelConfig, ocfg: OptimizerConfig,
                    parallel: ParallelConfig, mesh: Mesh,
                    batch_abstract: Dict, donate: bool = True):
    """Returns (jitted_step, (pspecs, ospecs, bspecs)).

    step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    pspecs, ospecs, bspecs = train_shardings(cfg, ocfg, parallel, mesh,
                                             batch_abstract)
    tf.set_remat(parallel.remat)
    set_activation_mesh(mesh, build_rules(parallel, mesh))

    compute_dt = jnp.dtype(cfg.dtype)

    def loss_of(params, batch):
        # cast to the compute dtype at the shard (pre-gather): FSDP
        # all-gathers then move bf16, not fp32 — halves gather bytes. The
        # fp32 master copy only feeds the optimizer.
        if parallel.fsdp_axis and compute_dt != jnp.dtype(cfg.param_dtype):
            params = jax.tree.map(lambda p: p.astype(compute_dt)
                                  if p.dtype == jnp.float32 else p, params)
        loss, metrics = M.loss_fn(params, cfg, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def compute_grads(params, batch):
        if parallel.microbatches > 1:
            mb = _microbatch(batch, parallel.microbatches)

            def acc(carry, mbatch):
                gsum, msum = carry
                (loss, metrics), g = grad_fn(params, mbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                msum = jax.tree.map(lambda a, b: a + b, msum, metrics)
                return (gsum, msum), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "nll": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32),
                  "tokens": jnp.zeros((), jnp.float32)}
            (gsum, msum), _ = jax.lax.scan(acc, (g0, m0), mb)
            k = float(parallel.microbatches)
            grads = jax.tree.map(lambda g: g / k, gsum)
            metrics = jax.tree.map(lambda m: m / k, msum)
        else:
            (_, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    compress = (parallel.grad_compression == "int8"
                and "pod" in mesh.axis_names)

    def step(params, opt_state, batch):
        if compress:
            grads, metrics = _pod_compressed_grads(
                compute_grads, params, batch, mesh, bspecs)
        else:
            grads, metrics = compute_grads(params, batch)
        new_params, new_state = adamw_update(params, grads, opt_state, ocfg)
        metrics = dict(metrics)
        return new_params, new_state, metrics

    ns = functools.partial(_tree_ns, mesh)
    jitted = jax.jit(
        step,
        in_shardings=(ns(pspecs), ns(ospecs), ns(bspecs)),
        out_shardings=(ns(pspecs), ns(ospecs), None),
        donate_argnums=(0, 1) if donate else ())
    return jitted, (pspecs, ospecs, bspecs)


# ---------------------------------------------------------------------------
# int8 cross-pod gradient compression
# ---------------------------------------------------------------------------

def _pod_compressed_grads(compute_grads, params: Dict, batch: Dict,
                          mesh: Mesh, bspecs: Dict):
    """Run the whole grad computation under a shard_map that makes 'pod' a
    *manual* axis (in-pod data/model stay auto under GSPMD). Each pod then
    produces a pod-local gradient; the cross-pod (DCN) reduction is done
    explicitly on an int8 block-quantized payload + f32 scales — 4x fewer
    DCN bytes than the f32 all-reduce GSPMD would insert.
    """
    from repro.optim.compression import (block_absmax,
                                         quantize_int8_with_scale)

    def body(params, batch):
        # inside the pod-manual region, activation constraints must not
        # reference 'pod' (it is Manual here); strip it for this trace.
        from repro.distributed.sharding import _ACT, set_activation_mesh

        def _strip(v):
            if isinstance(v, tuple):
                t = tuple(a for a in v if a != "pod")
                return t or None
            return None if v == "pod" else v

        prev = (_ACT["mesh"], _ACT["rules"])
        if prev[1] is not None:
            set_activation_mesh(prev[0], {k: _strip(v)
                                          for k, v in prev[1].items()})
        try:
            grads, metrics = compute_grads(params, batch)   # pod-local mean
        finally:
            set_activation_mesh(*prev)
        npods = jax.lax.psum(jnp.ones((), jnp.float32), "pod")

        def one(g):
            # agree on a shared per-block scale first (one tiny f32 pmax),
            # then quantize against it so the int8 sum is exact to rounding
            absmax = block_absmax(g.astype(jnp.float32), 256)
            scale = jax.lax.pmax(absmax, "pod") / 127.0
            q = quantize_int8_with_scale(g.astype(jnp.float32), scale, 256)
            qsum = jax.lax.psum(q.astype(jnp.int32), "pod")
            deq = (qsum.astype(jnp.float32) * scale[:, None]).reshape(-1)
            deq = deq[: g.size].reshape(g.shape)
            return (deq / npods).astype(g.dtype)

        grads = jax.tree.map(one, grads)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
        return grads, metrics

    # params replicated across pods -> P(); batch dim0 split across pods.
    param_in = jax.tree.map(lambda _: P(), params)
    batch_in = {}
    for k, v in batch.items():
        if k == "positions" and getattr(v, "ndim", 0) == 3:
            batch_in[k] = P(None, "pod")
        elif getattr(v, "ndim", 0) >= 1 and v.shape[0] % 2 == 0:
            batch_in[k] = P("pod")
        else:
            batch_in[k] = P()
    # 'pod' is the only manual axis; in-pod data/model stay under GSPMD
    from repro.distributed.sharding import shard_map
    return shard_map(body, mesh=mesh, in_specs=(param_in, batch_in),
                     out_specs=(param_in, P()), check_vma=False,
                     axis_names={"pod"})(params, batch)

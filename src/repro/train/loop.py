"""Training loop: the canonical pipeline-under-test.

Every stage is a wind-tunnel span (datagen / h2d / train_step / checkpoint),
so an Experiment can measure a *training* pipeline exactly like the paper
measures a telemetry pipeline. Fault tolerance: transient faults retry in
place; NodeLoss restarts from the latest checkpoint (state, optimizer and
data-stream position all restore); the straggler watchdog reports stages
that fall behind.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step,
                                   restore_checkpoint)
from repro.config import (ModelConfig, OptimizerConfig, ParallelConfig,
                          TrainConfig)
from repro.core.metrics import MetricStore
from repro.core.spans import SpanCollector, span
from repro.data.loader import TokenBatchLoader
from repro.distributed.fault import (FaultInjector, NodeLoss,
                                     StragglerWatchdog, TransientFault,
                                     retry_step)
from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.train.steps import make_train_step


@dataclass
class TrainResult:
    steps_done: int
    final_loss: float
    losses: list
    restarts: int
    retries: int
    stragglers_seen: Dict[str, int]
    collector: SpanCollector
    metrics: MetricStore


def train(cfg: ModelConfig, tcfg: TrainConfig, ocfg: OptimizerConfig,
          parallel: ParallelConfig, mesh,
          injector: Optional[FaultInjector] = None,
          collector: Optional[SpanCollector] = None,
          verbose: bool = True) -> TrainResult:
    collector = collector or SpanCollector()
    metrics = MetricStore()
    loader = TokenBatchLoader(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                              seed=tcfg.seed, collector=collector)
    watchdog = StragglerWatchdog(collector)
    ckpt_dir = tcfg.checkpoint_dir
    ckptr = AsyncCheckpointer(ckpt_dir) if tcfg.async_checkpoint else None

    from repro.launch.specs import SDS
    import jax.numpy as jnp
    batch_abs = {"tokens": SDS((tcfg.global_batch, tcfg.seq_len), jnp.int32),
                 "loss_mask": SDS((tcfg.global_batch, tcfg.seq_len), jnp.float32)}
    step_fn, (pspecs, ospecs, _) = make_train_step(
        cfg, ocfg, parallel, mesh, batch_abs, donate=False)

    def fresh_state():
        params = M.init_params(cfg, jax.random.PRNGKey(tcfg.seed))
        return params, init_opt_state(params, ocfg)

    def try_restore():
        ls = latest_step(ckpt_dir)
        if ls is None:
            return None
        params, opt_state = fresh_state()
        (params, opt_state), step0, extra = restore_checkpoint(
            ckpt_dir, ls, (params, opt_state))
        loader.load_state_dict(extra.get("loader", {"step": step0,
                                                    "seed": tcfg.seed}))
        return params, opt_state, step0

    restored = try_restore()
    if restored is not None:
        params, opt_state, step0 = restored
    else:
        params, opt_state = fresh_state()
        step0 = 0

    losses = []
    restarts = retries = 0
    stragglers_seen: Dict[str, int] = {}
    step = step0
    while step < tcfg.steps:
        try:
            with span("datagen+next", collector, records=tcfg.global_batch):
                host_batch = loader.next()
            with span("h2d", collector, records=tcfg.global_batch):
                batch = {k: jax.device_put(v) for k, v in host_batch.items()}

            def do_step():
                with span("train_step", collector, records=tcfg.global_batch):
                    out = step_fn(params, opt_state, batch)
                    jax.block_until_ready(out[2]["loss"])
                    return out

            try:
                new_params, new_opt, m = retry_step(do_step, injector=injector)
            except TransientFault:
                retries += 1
                continue
            params, opt_state = new_params, new_opt
            loss = float(m["loss"])
            losses.append(loss)
            metrics.observe("loss", loss)
            metrics.inc("steps")
            step += 1

            for name, info in watchdog.stragglers().items():
                stragglers_seen[name] = stragglers_seen.get(name, 0) + 1
                metrics.observe(f"straggler.{name}", info["ratio"])

            if step % tcfg.checkpoint_every == 0 or step == tcfg.steps:
                with span("checkpoint", collector, records=1):
                    extra = {"loader": loader.state_dict()}
                    if ckptr is not None:
                        ckptr.save(step, (params, opt_state), extra)
                    else:
                        from repro.checkpoint.ckpt import save_checkpoint
                        save_checkpoint(ckpt_dir, step, (params, opt_state),
                                        extra)
            if verbose and step % tcfg.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f}")
        except NodeLoss:
            # restart-from-checkpoint: the real cluster would re-mesh here
            restarts += 1
            if ckptr is not None:
                ckptr.wait()
            restored = try_restore()
            if restored is not None:
                params, opt_state, step = restored
            else:
                params, opt_state = fresh_state()
                step = 0
    if ckptr is not None:
        ckptr.close()
    loader.close()
    return TrainResult(step, losses[-1] if losses else float("nan"), losses,
                       restarts, retries, stragglers_seen, collector, metrics)

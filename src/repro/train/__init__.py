from repro.train.steps import make_train_step, train_shardings  # noqa: F401

"""Host-side training data pipeline.

Synthesizes token batches from a core Schema (the LM pipeline's ingest is
itself a PlantD pipeline-under-test: datagen -> pack -> h2d are the spans
the wind tunnel measures). Background prefetch keeps the device from
waiting on the host; ``state_dict``/``load_state_dict`` make the stream
restart-exactly (checkpointed alongside model state).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.datagen import DataGenerator
from repro.core.schema import Schema, token_stream_schema
from repro.core.spans import SpanCollector, span


class TokenBatchLoader:
    def __init__(self, vocab_size: int, seq_len: int, batch: int,
                 seed: int = 0, prefetch: int = 2,
                 collector: Optional[SpanCollector] = None,
                 zipf_a: float = 1.2):
        self.schema = token_stream_schema(vocab_size, seq_len)
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.step = 0
        self.collector = collector
        self.zipf_a = zipf_a
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._want = 0
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._produce_step = 0
        self._stop = threading.Event()
        self._thread.start()

    # -- deterministic per-step batch ----------------------------------------
    def _make(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed * 1_000_003 + step) % 2 ** 31)
        z = rng.zipf(self.zipf_a, size=(self.batch, self.seq_len))
        tokens = ((z - 1) % self.vocab_size).astype(np.int32)
        return {"tokens": tokens,
                "loss_mask": np.ones_like(tokens, np.float32)}

    def _producer(self):
        while not self._stop.is_set():
            with self._lock:
                step = self._produce_step
            with span("datagen", self.collector, records=self.batch):
                batch = self._make(step)
            placed = False
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    placed = True
                    break
                except queue.Full:
                    with self._lock:
                        if self._produce_step != step:   # rewound mid-flight
                            break
            if placed:
                with self._lock:
                    # only advance if no rewind raced with this iteration
                    if self._produce_step == step:
                        self._produce_step = step + 1

    def next(self) -> Dict[str, np.ndarray]:
        while True:
            step, batch = self._q.get()
            if step == self.step:            # drop stale prefetches on resume
                self.step += 1
                return batch
            if step > self.step:             # producer ahead of a rewind
                with self._lock:
                    self._produce_step = self.step
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()

    # -- restart-exact state --------------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, state: Dict):
        self.step = int(state["step"])
        with self._lock:
            self._produce_step = self.step
        # drain stale queue
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    def close(self):
        self._stop.set()

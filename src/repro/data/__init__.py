from repro.data.loader import TokenBatchLoader  # noqa: F401

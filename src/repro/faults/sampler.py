"""Seeded deterministic sampler: FaultSchedule -> concrete fault futures.

``sample_futures`` expands a ``FaultSchedule`` into F concrete futures
over a T-bin horizon. Each future is three per-bin series plus a sparse
replay structure:

* ``cap``  [F, T] f32 — capacity multiplier (0 during outages, in (0,1)
  during brownouts, 1 benign); overlapping events compose
  multiplicatively;
* ``mask`` [F, T] f32 — 1.0 where ANY fault event (of any kind) covers
  the bin; feeds the in-carry fault-attribution counters;
* ``load_mult`` [F, T] f64 — multiplicative load perturbation (bursts,
  and the removed fraction during disconnect windows);
* rank-1 replay terms per future: a disconnect event that removes
  weight vector ``w`` from a base load row replays the removed mass
  ``row . w`` as a uniform reconnect flood over the bins right after
  the window (``flood_hours`` wide) — mass-conserving by construction.

Seeding follows the ``core/datagen.py`` idiom: a fresh
``np.random.default_rng`` keyed by ``crc32(f"{spec.name}:{seed}:{f}")``
per (spec, future), so results are independent of spec iteration
details, process hash randomization (PYTHONHASHSEED), platform, and the
number of other specs in the schedule. Event counts are Poisson with
mean ``rate_per_year * horizon_hours / 8736`` (the repo's 52-week
year, ``core/traffic.HOURS_PER_YEAR``).

Sampled series are validated here: a capacity or load multiplier that
is negative or non-finite raises ``ValueError`` naming the fault spec
and bin index (satellite requirement) rather than flowing garbage into
the aggregates.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .spec import FaultSchedule, FaultSpec

HOURS_PER_YEAR = 8736.0  # mirrors core/traffic.HOURS_PER_YEAR


def _spec_rng(spec_name: str, seed: int, future: int) -> np.random.Generator:
    """PYTHONHASHSEED-stable rng for one (spec, future) pair."""
    key = zlib.crc32(f"fault:{spec_name}:{seed}:{future}".encode())
    return np.random.default_rng(key % (2 ** 31))


@dataclass(frozen=True)
class ReplayTerm:
    """One rank-1 reconnect-flood term: row' += (row . removed) * profile.

    ``removed`` [T] is the per-bin weight stripped from the base row
    during the disconnect window; ``profile`` [T] spreads exactly that
    mass (it sums to 1 over the flood bins), so total records are
    conserved bin-for-bin in expectation and exactly in sum.
    """
    removed: np.ndarray    # [T] f64, nonzero only inside the window
    profile: np.ndarray    # [T] f64, nonzero only on flood bins, sums to 1


@dataclass(frozen=True)
class SampledFaults:
    """F concrete fault futures over a T-bin horizon (see module doc)."""
    cap: np.ndarray                       # [F, T] f32 capacity multiplier
    mask: np.ndarray                      # [F, T] f32 in-fault indicator
    load_mult: np.ndarray                 # [F, T] f64 load multiplier
    replay: Tuple[Tuple[ReplayTerm, ...], ...]   # per-future replay terms
    events: Tuple[Tuple[Dict, ...], ...]  # per-future event records
    n_futures: int
    t_bins: int
    bin_hours: float
    seed: int

    @property
    def has_load_faults(self) -> np.ndarray:
        """[F] bool — does future f perturb the load series at all?"""
        out = np.zeros(self.n_futures, dtype=bool)
        for f in range(self.n_futures):
            out[f] = (bool(self.replay[f])
                      or bool(np.any(self.load_mult[f] != 1.0)))
        return out

    @property
    def has_capacity_faults(self) -> np.ndarray:
        """[F] bool — does future f perturb the capacity series?"""
        return np.any(self.cap != 1.0, axis=1)

    def apply_loads(self, row: np.ndarray) -> np.ndarray:
        """Perturb one base load row [T] into its F faulted rows [F, T].

        Computed in f64 (rank-1 replay terms on top of the elementwise
        multiplier), cast to the row's dtype at the end. A benign future
        (mult == 1, no replay) reproduces the row bit-for-bit.
        """
        row64 = np.asarray(row, dtype=np.float64)
        out = row64[None, :] * self.load_mult
        for f in range(self.n_futures):
            for term in self.replay[f]:
                mass = float(row64 @ term.removed)
                if mass != 0.0:
                    out[f] = out[f] + mass * term.profile
        return out.astype(np.asarray(row).dtype, copy=False)


def _window_bins(rng: np.random.Generator, spec: FaultSpec, t_bins: int,
                 bin_hours: float) -> Tuple[int, int]:
    """Sample one event window as [start_bin, end_bin) clipped to grid."""
    horizon_h = t_bins * bin_hours
    start_h = rng.uniform(0.0, horizon_h)
    dur_h = rng.uniform(*spec.duration_hours)
    start = min(int(start_h // bin_hours), t_bins - 1)
    end = min(t_bins, start + max(1, int(math.ceil(dur_h / bin_hours))))
    return start, end


def _named_bad_bin(arr: np.ndarray, events: Tuple[Dict, ...], what: str,
                   future: int):
    """Raise ValueError naming the responsible spec + bin, if any bad."""
    bad = ~np.isfinite(arr) | (arr < 0)
    if not bad.any():
        return
    bin_ix = int(np.argmax(bad))
    culprit = "unknown fault"
    for ev in events:
        if ev["start"] <= bin_ix < max(ev["end"], ev.get("flood_end", 0)):
            culprit = f"fault spec {ev['spec']!r} ({ev['kind']})"
            break
    raise ValueError(
        f"sampled {what} is "
        f"{'negative' if np.isfinite(arr[bin_ix]) else 'non-finite'} at "
        f"bin {bin_ix} of future {future}: {culprit} produced "
        f"{arr[bin_ix]!r}")


def validate_sampled(sampled: SampledFaults) -> SampledFaults:
    """Re-check a SampledFaults (possibly hand-built) for bad bins.

    Raises ``ValueError`` naming the responsible fault spec and bin
    index when any capacity or load multiplier is negative or
    non-finite — the simulate-layer input-validation hook
    (``core.simulate.simulate_grid(faults=...)`` calls this before any
    device work). Returns the input unchanged when clean.
    """
    cap = np.asarray(sampled.cap)
    lm = np.asarray(sampled.load_mult)
    if cap.shape != (sampled.n_futures, sampled.t_bins):
        raise ValueError(f"SampledFaults.cap shape {cap.shape} != "
                         f"({sampled.n_futures}, {sampled.t_bins})")
    if lm.shape != (sampled.n_futures, sampled.t_bins):
        raise ValueError(f"SampledFaults.load_mult shape {lm.shape} != "
                         f"({sampled.n_futures}, {sampled.t_bins})")
    for f in range(sampled.n_futures):
        evs = sampled.events[f] if f < len(sampled.events) else ()
        _named_bad_bin(cap[f], evs, "capacity multiplier", f)
        _named_bad_bin(lm[f], evs, "load multiplier", f)
    return sampled


def sample_futures(schedule: FaultSchedule, t_bins: int,
                   bin_hours: float = 1.0) -> SampledFaults:
    """Expand a FaultSchedule into F concrete futures over t_bins bins.

    Deterministic in (schedule.seed, spec names, t_bins, bin_hours) —
    and in nothing else. Specs compose in declaration order; capacity
    multipliers compose multiplicatively, disconnects strip a fraction
    of whatever load multiplier is in force when they fire.
    """
    if t_bins < 1:
        raise ValueError(f"t_bins must be >= 1, got {t_bins}")
    if bin_hours <= 0:
        raise ValueError(f"bin_hours must be > 0, got {bin_hours}")
    F = schedule.n_futures
    horizon_years = (t_bins * bin_hours) / HOURS_PER_YEAR

    cap = np.ones((F, t_bins), dtype=np.float64)
    mask = np.zeros((F, t_bins), dtype=np.float32)
    load_mult = np.ones((F, t_bins), dtype=np.float64)
    replay: List[Tuple[ReplayTerm, ...]] = []
    events: List[Tuple[Dict, ...]] = []

    for f in range(F):
        f_terms: List[ReplayTerm] = []
        f_events: List[Dict] = []
        for spec in schedule.specs:
            rng = _spec_rng(spec.name, schedule.seed, f)
            n_events = int(rng.poisson(spec.rate_per_year * horizon_years))
            for _ in range(n_events):
                start, end = _window_bins(rng, spec, t_bins, bin_hours)
                ev = {"spec": spec.name, "kind": spec.kind,
                      "start": start, "end": end}
                mask[f, start:end] = 1.0
                if spec.kind == "outage":
                    cap[f, start:end] = 0.0
                elif spec.kind == "brownout":
                    m = rng.uniform(*spec.capacity_mult)
                    cap[f, start:end] *= m
                    ev["capacity_mult"] = m
                elif spec.kind == "burst":
                    m = rng.uniform(*spec.load_mult)
                    load_mult[f, start:end] *= m
                    ev["load_mult"] = m
                elif spec.kind == "disconnect":
                    frac = rng.uniform(*spec.disconnect_frac)
                    # strip `frac` of the load in force over the window…
                    removed = np.zeros(t_bins, dtype=np.float64)
                    removed[start:end] = load_mult[f, start:end] * frac
                    load_mult[f, start:end] *= (1.0 - frac)
                    # …and replay it over the flood bins after the window
                    n_flood = max(1, int(math.ceil(spec.flood_hours
                                                   / bin_hours)))
                    fl_start = min(end, t_bins - 1)
                    fl_end = min(t_bins, fl_start + n_flood)
                    profile = np.zeros(t_bins, dtype=np.float64)
                    profile[fl_start:fl_end] = 1.0 / (fl_end - fl_start)
                    mask[f, fl_start:fl_end] = 1.0
                    f_terms.append(ReplayTerm(removed=removed,
                                              profile=profile))
                    ev["disconnect_frac"] = frac
                    ev["flood_end"] = fl_end
                f_events.append(ev)
        replay.append(tuple(f_terms))
        events.append(tuple(f_events))
        _named_bad_bin(cap[f], events[-1], "capacity multiplier", f)
        _named_bad_bin(load_mult[f], events[-1], "load multiplier", f)

    return SampledFaults(cap=cap.astype(np.float32), mask=mask,
                         load_mult=load_mult, replay=tuple(replay),
                         events=tuple(events), n_futures=F, t_bins=t_bins,
                         bin_hours=float(bin_hours), seed=schedule.seed)

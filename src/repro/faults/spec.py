"""Declarative fault & outage scenario specs (ROADMAP fault library).

A ``FaultSpec`` describes one *family* of stochastic fault events as the
chaos-engineering literature frames them (ESPBench's degraded-operation
modes; the broker disconnect drills of real streaming testbeds:
"disconnect 20-50% of devices for 5-30 min, measure queue flush time").
Specs are declarative and policy-agnostic: they perturb the *load* and
*capacity* series a scenario plays, never the policy step itself, so any
fault composes with any registered policy on any grid backend.

Four built-in kinds:

* ``outage``     — capacity -> 0 for the event window (the pipeline is
                   down; arrivals back up in a fault-layer queue and
                   flood back in when capacity returns);
* ``brownout``   — degraded capacity: a multiplier in (0, 1] scales the
                   twin's ``max_rps`` for the window;
* ``disconnect`` — a fraction of upstream devices drops for the window;
                   their records are NOT lost — the missed mass replays
                   as a reconnect flood spread over ``flood_hours``
                   after the window closes (conservation is a test
                   invariant: no record lost or duplicated);
* ``burst``      — anomalous load: arrivals scale by a multiplier for
                   the window (retry storms, replay attacks, flash
                   crowds).

A ``FaultSchedule`` bundles specs with a seed and a future count F: the
seeded sampler (``repro.faults.sampler``) expands it into F concrete
*fault futures* — per-bin capacity-multiplier / load-perturbation /
in-fault-mask series — deterministically (crc32 seeding like
``core/datagen.py``, stable under PYTHONHASHSEED). The grid engine then
runs every (base scenario x future) pair as one more row of the same
matrix+index grid representation (``repro.faults.grid``).

Event counts are Poisson with mean ``rate_per_year`` scaled to the
simulated horizon; windows start uniformly over the horizon and last
``duration_hours`` drawn uniformly from the declared range.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

#: the four built-in fault kinds (see module docstring)
FAULT_KINDS = ("outage", "brownout", "disconnect", "burst")


def _as_range(value, name: str, kind: str) -> Tuple[float, float]:
    """Normalize a scalar or (lo, hi) pair into an ordered float range."""
    if isinstance(value, (int, float)):
        lo = hi = float(value)
    else:
        try:
            lo, hi = (float(v) for v in value)
        except (TypeError, ValueError):
            raise ValueError(
                f"{kind} fault: {name} must be a number or a (lo, hi) "
                f"pair, got {value!r}") from None
    if hi < lo:
        raise ValueError(f"{kind} fault: {name} range ({lo:g}, {hi:g}) "
                         f"is inverted")
    return lo, hi


@dataclass(frozen=True)
class FaultSpec:
    """One stochastic fault family (build via the kind constructors)."""
    kind: str                          # one of FAULT_KINDS
    name: str                          # names this spec in errors/reports
    rate_per_year: float               # Poisson mean event count per year
    duration_hours: Tuple[float, float]    # uniform window length range
    # kind-specific parameter ranges (sampled uniformly per event):
    capacity_mult: Tuple[float, float] = (1.0, 1.0)   # brownout
    disconnect_frac: Tuple[float, float] = (0.0, 0.0)  # disconnect
    flood_hours: float = 1.0                           # disconnect replay
    load_mult: Tuple[float, float] = (1.0, 1.0)        # burst

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {FAULT_KINDS}")
        if self.rate_per_year < 0:
            raise ValueError(f"{self.name}: rate_per_year must be >= 0, "
                             f"got {self.rate_per_year:g}")
        if self.duration_hours[0] <= 0:
            raise ValueError(f"{self.name}: duration_hours must be "
                             f"positive, got {self.duration_hours}")
        if self.flood_hours <= 0:
            raise ValueError(f"{self.name}: flood_hours must be positive, "
                             f"got {self.flood_hours:g}")


def outage(name: str = "outage", *, rate_per_year: float = 4.0,
           duration_hours=(1.0, 8.0)) -> FaultSpec:
    """Hard outage: capacity -> 0 for the window. Arrivals during the
    window back up in the fault layer and flood back at reconnect."""
    return FaultSpec(kind="outage", name=name,
                     rate_per_year=float(rate_per_year),
                     duration_hours=_as_range(duration_hours,
                                              "duration_hours", "outage"))


def brownout(name: str = "brownout", *, rate_per_year: float = 6.0,
             duration_hours=(2.0, 24.0),
             capacity_mult=(0.3, 0.8)) -> FaultSpec:
    """Degraded capacity: ``max_rps`` scales by a multiplier drawn from
    ``capacity_mult`` for the window (overlapping events compose
    multiplicatively)."""
    mult = _as_range(capacity_mult, "capacity_mult", "brownout")
    if mult[0] < 0:
        raise ValueError(f"{name}: capacity_mult must be >= 0, got {mult}")
    return FaultSpec(kind="brownout", name=name,
                     rate_per_year=float(rate_per_year),
                     duration_hours=_as_range(duration_hours,
                                              "duration_hours", "brownout"),
                     capacity_mult=mult)


def disconnect(name: str = "disconnect", *, rate_per_year: float = 12.0,
               duration_hours=(0.5, 2.0), disconnect_frac=(0.2, 0.5),
               flood_hours: float = 1.0) -> FaultSpec:
    """Correlated device disconnect: a fraction ``disconnect_frac`` of the
    load vanishes for the window, then replays as a reconnect flood
    spread uniformly over ``flood_hours`` after the window closes. Mass
    is conserved exactly: no record is lost or duplicated."""
    frac = _as_range(disconnect_frac, "disconnect_frac", "disconnect")
    if not (0.0 <= frac[0] and frac[1] <= 1.0):
        raise ValueError(f"{name}: disconnect_frac must lie in [0, 1], "
                         f"got {frac}")
    return FaultSpec(kind="disconnect", name=name,
                     rate_per_year=float(rate_per_year),
                     duration_hours=_as_range(duration_hours,
                                              "duration_hours",
                                              "disconnect"),
                     disconnect_frac=frac, flood_hours=float(flood_hours))


def burst(name: str = "burst", *, rate_per_year: float = 8.0,
          duration_hours=(0.5, 3.0), load_mult=(1.5, 4.0)) -> FaultSpec:
    """Anomalous load burst: arrivals scale by ``load_mult`` for the
    window (retry storms, flash crowds). Multipliers below 1 model
    anomalous lulls; negative multipliers are rejected at sampling with
    the spec name and bin index."""
    return FaultSpec(kind="burst", name=name,
                     rate_per_year=float(rate_per_year),
                     duration_hours=_as_range(duration_hours,
                                              "duration_hours", "burst"),
                     load_mult=_as_range(load_mult, "load_mult", "burst"))


@dataclass(frozen=True)
class FaultSchedule:
    """A set of fault specs plus the sampling contract (seed, futures).

    ``n_futures`` is F: how many independent Monte-Carlo fault futures
    the sampler draws. Every base scenario of a faulted grid expands into
    F rows (one per future), so a chance-constrained search can ask for
    "meets the SLO in >= 95% of futures". An empty ``specs`` tuple is
    legal and yields benign futures (capacity multiplier 1, no load
    perturbation) — the bit-parity anchor the tests pin.
    """
    specs: Tuple[FaultSpec, ...] = ()
    n_futures: int = 4
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))
        if self.n_futures < 1:
            raise ValueError(f"n_futures must be >= 1, got "
                            f"{self.n_futures}")
        names = [s.name for s in self.specs]
        dupes = {n for n in names if names.count(n) > 1}
        if dupes:
            raise ValueError(f"duplicate fault spec names {sorted(dupes)}; "
                             f"names key the deterministic per-spec seeds")

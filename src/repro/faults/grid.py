"""Expand sampled fault futures into the matrix+index grid representation.

The aggregate grid engine (``core/simulate.py``) runs N scenarios as a
[K, T] load matrix plus an [N] row index. ``expand_grid`` lifts that
representation to faults: N base scenarios x F futures become N*F grid
rows ordered **scenario-major, future-minor** (row ``i*F + f`` plays
base scenario i under future f — the ordering the chance-constrained
search relies on to reshape result lanes to [..., S, F]).

Load perturbations are baked into new matrix rows; capacity and
in-fault-mask series stay as separate small [F, T] matrices indexed by
a per-row ``fault_index`` so a 65k-row chaos grid carries F extra rows
of fault state, not 65k. Futures that do not touch the load (outage /
brownout only) alias the *original* matrix rows — the benign-future
path literally reads the same memory as the pre-fault grid, which is
how empty-schedule bit-parity is guaranteed structurally rather than
numerically.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro import obs

from .sampler import SampledFaults


def _named_bad_load(row: np.ndarray, sampled: SampledFaults, future: int,
                    base_row: int):
    bad = ~np.isfinite(row) | (row < 0)
    if not bad.any():
        return
    bin_ix = int(np.argmax(bad))
    culprit = "unknown fault"
    for ev in sampled.events[future]:
        if ev["start"] <= bin_ix < max(ev["end"], ev.get("flood_end", 0)):
            culprit = f"fault spec {ev['spec']!r} ({ev['kind']})"
            break
    val = row[bin_ix]
    raise ValueError(
        f"perturbed load series for base row {base_row}, future {future} "
        f"is {'negative' if np.isfinite(val) else 'non-finite'} at bin "
        f"{bin_ix}: {culprit} produced {val!r}")


@dataclass(frozen=True)
class FaultGrid:
    """A faulted grid: expanded load rows + per-row fault series indices.

    ``load_matrix`` [K', T] / ``load_index`` [N*F] drive the same grid
    engines as before; ``cap`` / ``fmask`` [F, T] are gathered per row
    through ``fault_index`` [N*F] exactly like load rows are gathered
    through ``load_index``.
    """
    load_matrix: np.ndarray     # [K', T] — base rows first, then faulted
    load_index: np.ndarray      # [N*F] int32 row index into load_matrix
    cap: np.ndarray             # [F, T] f32 capacity multipliers
    fmask: np.ndarray           # [F, T] f32 in-fault indicators
    fault_index: np.ndarray     # [N*F] int32 row index into cap/fmask
    n_futures: int
    n_base: int                 # N: base scenario count before expansion
    sampled: SampledFaults

    @property
    def n_rows(self) -> int:
        return self.load_index.shape[0]


def benign_futures(sampled: SampledFaults) -> np.ndarray:
    """[F] bool — futures that perturb NOTHING: no load fault, no
    capacity fault, and no fault-window mask (a masked-but-harmless
    window still changes the A_FLTH/A_FOKH attribution counters, so it
    is not benign). Every benign future plays a base scenario through
    the identical fault-free dynamics, so the grid dispatcher simulates
    ONE benign representative per scenario and replicates its summary
    row instead of re-scanning the same year F-benign times."""
    return (~sampled.has_load_faults
            & ~sampled.has_capacity_faults
            & ~np.any(np.asarray(sampled.mask) != 0.0, axis=1))


@obs.instrument(name="faults.expand_grid")
def expand_grid(sampled: SampledFaults, load_matrix: np.ndarray,
                load_index: np.ndarray) -> FaultGrid:
    """Expand (load_matrix [K,T], load_index [N]) by F fault futures.

    Perturbed load rows are deduplicated per (base row, future): two
    scenarios sharing a base matrix row also share its faulted variants.
    Rows whose future leaves loads untouched reuse the base row
    untouched. Perturbed series that come out negative or NaN raise
    ``ValueError`` naming the fault spec and bin index.

    With run-telemetry on (``repro.obs``) the expansion records a
    ``faults.expand_grid`` span and counters ``faults.futures`` /
    ``faults.rows`` / ``faults.load_rows_added`` — how much grid the
    chaos suite actually created.
    """
    load_matrix = np.asarray(load_matrix)
    load_index = np.asarray(load_index)
    k, t = load_matrix.shape
    if t != sampled.t_bins:
        raise ValueError(f"fault futures were sampled over "
                         f"{sampled.t_bins} bins but the load matrix has "
                         f"{t} bins")
    F = sampled.n_futures
    n = load_index.shape[0]
    touches_load = sampled.has_load_faults      # [F] bool

    rows = [load_matrix]                        # base rows keep indices 0..K-1
    next_row = k
    # row_of[k_base, f] -> row index in the expanded matrix
    row_of = np.tile(np.arange(k, dtype=np.int64)[:, None], (1, F))
    used_base = np.unique(load_index)
    for kb in used_base:
        base_row = load_matrix[kb]
        faulted = None
        for f in range(F):
            if not touches_load[f]:
                continue
            if faulted is None:                 # lazy: one apply per row
                faulted = sampled.apply_loads(base_row)
            _named_bad_load(faulted[f], sampled, f, int(kb))
            rows.append(faulted[f][None, :])
            row_of[kb, f] = next_row
            next_row += 1

    expanded = np.concatenate(rows, axis=0) if len(rows) > 1 else load_matrix
    new_index = row_of[load_index].reshape(-1).astype(np.int32)   # [N*F]
    fault_index = np.tile(np.arange(F, dtype=np.int32), n)        # [N*F]
    obs.count("faults.futures", F)
    obs.count("faults.rows", n * F)
    obs.count("faults.load_rows_added", next_row - k)
    return FaultGrid(load_matrix=expanded, load_index=new_index,
                     cap=np.asarray(sampled.cap, dtype=np.float32),
                     fmask=np.asarray(sampled.mask, dtype=np.float32),
                     fault_index=fault_index, n_futures=F, n_base=n,
                     sampled=sampled)

"""Fault & outage scenario library (ROADMAP: chaos suites).

Declarative fault specs -> seeded deterministic futures -> grid rows:

    from repro import faults
    schedule = faults.FaultSchedule(
        specs=(faults.outage(rate_per_year=6),
               faults.disconnect(disconnect_frac=(0.2, 0.5))),
        n_futures=8, seed=0)
    summaries = simulate_grid(twins, traffics, slo, cost,
                              return_series=False, faults=schedule)

and chance-constrained resilience search:

    result = optimize_scenario(base, [surge], slo, search=(...),
                               faults=schedule, quantile=0.95)
"""
from .spec import (FAULT_KINDS, FaultSchedule, FaultSpec, brownout, burst,
                   disconnect, outage)
from .sampler import (ReplayTerm, SampledFaults, sample_futures,
                      validate_sampled)
from .grid import FaultGrid, benign_futures, expand_grid

__all__ = [
    "FAULT_KINDS", "FaultSpec", "FaultSchedule",
    "outage", "brownout", "disconnect", "burst",
    "SampledFaults", "ReplayTerm", "sample_futures", "validate_sampled",
    "FaultGrid", "expand_grid", "benign_futures",
]

"""Run-telemetry recorder — the wind tunnel observing itself.

``repro.obs`` is the off-by-default telemetry layer for the *tool's own*
runtime: monotonic-clock spans around every dispatch boundary (the block
engine, the search/fit kernels, fault expansion, the serve engine),
counters and gauges for the load-bearing decisions that used to vanish
into warn-once messages (dedup hit rates, replication fallbacks,
stream-vs-vectorized objective choice), and a bounded ring buffer with
time-based retention so a long-running collect loop never grows without
bound (the collect → prune-by-retention → report cycle of the
Realtime-Datastreaming monitor).

Design rules:

* **Off by default, trivially cheap when off.** The gate is one module
  attribute; ``obs.span(...)`` returns a shared null context manager
  without allocating, ``obs.count`` returns immediately. Set
  ``REPRO_OBS=1`` in the environment, or call ``obs.enable()`` /
  ``obs.capture()``, to record.
* **Strictly at dispatch boundaries.** Instrumentation wraps host-side
  calls into jitted programs — never code inside a trace — so enabling
  it cannot change any computed number or force a retrace.
* **Monotonic durations, wall-clock export.** Spans are timed with
  ``time.perf_counter``; the recorder anchors one (wall, monotonic)
  pair at construction so exporters can place every span on the unix
  epoch — which is what lets ``ObservedTrace.from_otel_spans`` re-import
  the tool's own telemetry (see ``repro.obs.export``).
"""
from __future__ import annotations

import collections
import contextlib
import functools
import itertools
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "ObsSpan", "Recorder", "capture", "count", "counters", "disable",
    "enable", "enabled", "event", "gauge", "get_recorder", "instrument",
    "set_recorder", "span", "timed",
]


@dataclass
class ObsSpan:
    """One finished span: monotonic start/end plus free-form attributes.

    ``records`` rides in ``attrs`` (the OTel-export batch size);
    ``parent_id`` links nested spans (``None`` for roots).
    """
    name: str
    start: float                      # monotonic seconds (recorder clock)
    end: float
    attrs: Dict[str, float] = field(default_factory=dict)
    span_id: int = 0
    parent_id: Optional[int] = None

    @property
    def duration(self) -> float:
        return self.end - self.start


#: labeled counter/gauge key: (name, sorted (label, value) pairs)
_Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Dict[str, object]) -> _Key:
    if not labels:
        return (name, ())
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


class Recorder:
    """Bounded span ring + counters/gauges, thread-safe.

    ``capacity`` bounds the ring absolutely; ``retention_s`` additionally
    ages spans out by time (pruned lazily on add and explicitly via
    ``prune``), so a continuous collector holds a rolling window instead
    of an ever-growing log. ``clock`` is injectable for tests.
    """

    def __init__(self, capacity: int = 65536,
                 retention_s: Optional[float] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.capacity = int(capacity)
        self.retention_s = retention_s
        self.clock = clock
        self.spans: collections.deque = collections.deque(maxlen=capacity)
        self.counters: Dict[_Key, float] = {}
        self.gauges: Dict[_Key, float] = {}
        self.profiles: List = []      # DispatchProfile rows (obs.profile)
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._stack = threading.local()
        # wall/monotonic anchor pair for epoch placement of spans
        self.wall0 = time.time()
        self.mono0 = self.clock()

    # -- spans ----------------------------------------------------------

    def _parents(self) -> List[int]:
        st = getattr(self._stack, "ids", None)
        if st is None:
            st = self._stack.ids = []
        return st

    def add_span(self, name: str, start: float, end: float,
                 attrs: Optional[Dict] = None,
                 parent_id: Optional[int] = None) -> ObsSpan:
        sp = ObsSpan(name, start, end, dict(attrs or {}),
                     next(self._ids), parent_id)
        with self._lock:
            self.spans.append(sp)
        if self.retention_s is not None:
            self.prune()
        return sp

    def prune(self, retention_s: Optional[float] = None,
              now: Optional[float] = None) -> int:
        """Drop spans older than the retention window (by END time);
        returns how many were dropped."""
        ret = self.retention_s if retention_s is None else retention_s
        if ret is None:
            return 0
        cutoff = (self.clock() if now is None else now) - ret
        dropped = 0
        with self._lock:
            while self.spans and self.spans[0].end < cutoff:
                self.spans.popleft()
                dropped += 1
        return dropped

    def wall_time(self, mono: float) -> float:
        """Place a monotonic timestamp on the unix epoch."""
        return self.wall0 + (mono - self.mono0)

    def find(self, name: Optional[str] = None,
             prefix: Optional[str] = None) -> List[ObsSpan]:
        with self._lock:
            out = list(self.spans)
        if name is not None:
            out = [s for s in out if s.name == name]
        if prefix is not None:
            out = [s for s in out if s.name.startswith(prefix)]
        return out

    # -- counters / gauges ----------------------------------------------

    def count(self, name: str, n: float = 1.0,
              labels: Optional[Dict] = None):
        k = _key(name, labels or {})
        with self._lock:
            self.counters[k] = self.counters.get(k, 0.0) + float(n)

    def gauge(self, name: str, value: float,
              labels: Optional[Dict] = None):
        with self._lock:
            self.gauges[_key(name, labels or {})] = float(value)

    def counter_total(self, name: str) -> float:
        """Sum of one counter across all label sets."""
        with self._lock:
            return sum(v for (n, _), v in self.counters.items()
                       if n == name)

    def clear(self):
        with self._lock:
            self.spans.clear()
            self.counters.clear()
            self.gauges.clear()
            self.profiles.clear()


# -- module state (the fast path) ---------------------------------------

_ENABLED = os.environ.get("REPRO_OBS", "0") not in ("", "0", "false",
                                                    "False", "no")
_RECORDER = Recorder()


def enabled() -> bool:
    """Is run-telemetry recording on? (the one check hot paths pay)"""
    return _ENABLED


def enable() -> Recorder:
    global _ENABLED
    _ENABLED = True
    return _RECORDER


def disable():
    global _ENABLED
    _ENABLED = False


def get_recorder() -> Recorder:
    return _RECORDER


def set_recorder(rec: Recorder) -> Recorder:
    """Swap the global recorder (tests inject clocks/retention); returns
    the previous one."""
    global _RECORDER
    prev, _RECORDER = _RECORDER, rec
    return prev


@contextlib.contextmanager
def capture(clear: bool = True, recorder: Optional[Recorder] = None):
    """Enable telemetry for a block and yield the active recorder::

        with obs.capture() as rec:
            simulate_grid(..., return_series=False)
        print(rec.find(prefix="grid."))

    Restores the previous enabled state (and recorder, if one was
    injected) on exit; ``clear=True`` starts the block from an empty
    recorder.
    """
    global _ENABLED
    prev_state = _ENABLED
    prev_rec = set_recorder(recorder) if recorder is not None else None
    rec = _RECORDER
    if clear:
        rec.clear()
    _ENABLED = True
    try:
        yield rec
    finally:
        _ENABLED = prev_state
        if prev_rec is not None:
            set_recorder(prev_rec)


# -- recording primitives -----------------------------------------------

class _NullSpan:
    """Shared do-nothing span for the disabled path: no allocation, a
    writable class-level ``attrs`` dict call sites may set keys on
    (bounded — the same few keys are overwritten forever)."""
    __slots__ = ()
    attrs: Dict[str, float] = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _OpenSpan:
    """Context manager recording one span. The span id is allocated
    eagerly on enter so nested children link to this span as parent
    while it is still open; ``attrs`` stays mutable inside the block
    (for results known only at exit, e.g. a compile flag)."""
    __slots__ = ("name", "attrs", "_rec", "_t0", "span")

    def __init__(self, rec: Recorder, name: str, attrs: Dict):
        self._rec = rec
        self.name = name
        self.attrs = attrs
        self.span = None

    def __enter__(self):
        self.span = ObsSpan(self.name, 0.0, 0.0, self.attrs,
                            next(self._rec._ids))
        stack = self._rec._parents()
        self.span.parent_id = stack[-1] if stack else None
        stack.append(self.span.span_id)
        self._t0 = self._rec.clock()
        return self

    def __exit__(self, *exc):
        t1 = self._rec.clock()
        self._rec._parents().pop()
        self.span.start, self.span.end = self._t0, t1
        with self._rec._lock:
            self._rec.spans.append(self.span)
        if self._rec.retention_s is not None:
            self._rec.prune()
        return False


def span(name: str, **attrs):
    """Record a span around a block (when telemetry is on)::

        with obs.span("grid.block", block=3, size=4480) as sp:
            ...
            sp.attrs["compiled"] = 1.0

    Disabled, this returns a shared null context manager — the cost is
    the enabled check plus assembling the kwargs dict.
    """
    if not _ENABLED:
        return _NULL
    return _OpenSpan(_RECORDER, name, attrs)


class timed:
    """Like ``span`` but ALWAYS records (benchmarks call it explicitly —
    intent is the opt-in) and exposes the measured wall time::

        with obs.timed("bench.grid", n=1024) as t:
            run()
        print(t.elapsed)
    """

    def __init__(self, name: str, **attrs):
        self.name = name
        self.attrs = attrs
        self.elapsed = float("nan")

    def __enter__(self):
        self._t0 = _RECORDER.clock()
        return self

    def __exit__(self, *exc):
        t1 = _RECORDER.clock()
        self.elapsed = t1 - self._t0
        self.span = _RECORDER.add_span(self.name, self._t0, t1, self.attrs)
        return False


def instrument(fn=None, *, name: Optional[str] = None, **attrs):
    """Decorator form of ``span``: wrap a function in a span named after
    it (or ``name=``). Works bare (``@obs.instrument``) or called
    (``@obs.instrument(name="faults.expand_grid")``). Disabled, the
    wrapper is one check then the plain call."""
    def deco(f):
        label = name or f"{f.__module__.rsplit('.', 1)[-1]}.{f.__name__}"

        @functools.wraps(f)
        def wrapped(*a, **kw):
            if not _ENABLED:
                return f(*a, **kw)
            with span(label, **attrs):
                return f(*a, **kw)
        wrapped.__obs_name__ = label
        return wrapped
    if fn is not None:
        return deco(fn)
    return deco


def count(name: str, n: float = 1.0, **labels):
    """Bump a (optionally labeled) counter — no-op when disabled."""
    if _ENABLED:
        _RECORDER.count(name, n, labels)


def gauge(name: str, value: float, **labels):
    """Set a gauge to its latest value — no-op when disabled."""
    if _ENABLED:
        _RECORDER.gauge(name, value, labels)


def event(name: str, **labels):
    """A structured countable event (warn-once messages route through
    here so they stay visible in exports even after Python's warning
    dedup silences the repeat)."""
    if _ENABLED:
        _RECORDER.count(name, 1.0, labels)


def counters() -> Dict[str, float]:
    """Flattened counter snapshot: ``name{k=v,...}`` -> value."""
    out = {}
    with _RECORDER._lock:
        items = list(_RECORDER.counters.items())
    for (nm, labels), v in items:
        if labels:
            nm = nm + "{" + ",".join(f"{k}={val}" for k, val in labels) \
                + "}"
        out[nm] = v
    return out

"""Per-dispatch profiling: compile vs execute split + compiled memory.

``profile_dispatch`` is the number the benchmark scripts used to derive
by hand (a first timed call for "compile", ``lower().compile().
memory_analysis()`` for peak temp bytes): it AOT-lowers a jitted
callable, times the compile explicitly, reads the compiled program's
memory/cost analyses (``jax.stages``), then times steady-state execution
best-of-``reps``. The result is recorded as a ``dispatch.{name}`` span
(attrs = the split + peak temp bytes) in the global recorder, so
BENCH_*.json rows come out of obs spans instead of private
``perf_counter`` pairs.

``jit_cache_grew`` is the lightweight sibling for hot paths that cannot
afford an AOT round: did this call trigger a compile? — read off the
jitted function's trace-cache size around the call (every jit wrapper in
this repo exposes ``_cache_size``). The block engine uses it to tag each
block/round span with ``compiled=1`` exactly when the step was traced.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.obs.record import get_recorder

__all__ = ["DispatchProfile", "jit_cache_grew", "profile_dispatch"]


@dataclass
class DispatchProfile:
    """One profiled dispatch: the compile/execute split plus whatever
    the backend's ``memory_analysis``/``cost_analysis`` expose (None
    where a backend has no such stat — e.g. older CPU plugins)."""
    name: str
    compile_s: float
    execute_s: float                  # steady state, best of reps
    reps: int
    peak_temp_bytes: Optional[int] = None
    generated_code_bytes: Optional[int] = None
    argument_bytes: Optional[int] = None
    output_bytes: Optional[int] = None
    flops: Optional[float] = None
    attrs: Dict[str, float] = field(default_factory=dict)

    def row(self) -> Dict:
        """JSON-ready row (the BENCH_*.json shape)."""
        out = {"name": self.name,
               "compile_s": round(self.compile_s, 4),
               "execute_s": round(self.execute_s, 4),
               "reps": self.reps}
        if self.peak_temp_bytes is not None:
            out["peak_temp_mb"] = round(self.peak_temp_bytes / 2**20, 1)
        if self.generated_code_bytes is not None:
            out["generated_code_mb"] = round(
                self.generated_code_bytes / 2**20, 2)
        if self.flops is not None:
            out["flops"] = self.flops
        out.update(self.attrs)
        return out


def _mem_stat(obj, attr):
    try:
        v = getattr(obj, attr)
        return int(v) if v is not None and int(v) >= 0 else None
    except Exception:       # noqa: BLE001 — a missing stat is not a fail
        return None


def profile_dispatch(name: str, jitted, *args, reps: int = 3,
                     **attrs) -> "tuple":
    """Profile one jitted dispatch; returns ``(last_output, profile)``.

    AOT path: ``jitted.lower(*args)`` -> timed ``.compile()`` ->
    ``memory_analysis()`` / ``cost_analysis()`` -> one warmup execute ->
    ``reps`` timed executes (best-of). Like ``obs.timed`` this records
    UNCONDITIONALLY (an explicit profile call is its own opt-in): a
    ``dispatch.{name}`` span lands in the global recorder with the
    split and memory numbers as attrs, and the ``DispatchProfile`` is
    appended to ``recorder.profiles``.

    Positional args only (``jax.stages`` lowering is positional); pass
    static extras through the jit wrapper's closure instead.
    """
    import jax

    rec = get_recorder()
    t0 = time.perf_counter()
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    peak = code = arg_b = out_b = None
    flops = None
    try:
        mem = compiled.memory_analysis()
    except Exception:       # noqa: BLE001
        mem = None
    if mem is not None:
        peak = _mem_stat(mem, "temp_size_in_bytes")
        code = _mem_stat(mem, "generated_code_size_in_bytes")
        arg_b = _mem_stat(mem, "argument_size_in_bytes")
        out_b = _mem_stat(mem, "output_size_in_bytes")
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        f = cost.get("flops") if hasattr(cost, "get") else None
        flops = float(f) if f is not None and f >= 0 else None
    except Exception:       # noqa: BLE001
        pass

    out = jax.block_until_ready(compiled(*args))        # warmup
    best = float("inf")
    for _ in range(max(int(reps), 1)):
        t0 = time.perf_counter()
        out = jax.block_until_ready(compiled(*args))
        best = min(best, time.perf_counter() - t0)

    prof = DispatchProfile(
        name=name, compile_s=compile_s, execute_s=best,
        reps=max(int(reps), 1), peak_temp_bytes=peak,
        generated_code_bytes=code, argument_bytes=arg_b,
        output_bytes=out_b, flops=flops,
        attrs={k: float(v) for k, v in attrs.items()})
    span_attrs = dict(prof.attrs, compile_s=compile_s, execute_s=best)
    if peak is not None:
        span_attrs["peak_temp_bytes"] = float(peak)
    now = rec.clock()
    rec.add_span(f"dispatch.{name}", now - compile_s - best, now,
                 span_attrs)
    rec.profiles.append(prof)
    return out, prof


def jit_cache_grew(jitted, before: int) -> bool:
    """Did the jit trace cache grow past ``before`` entries? — the
    cheap "this call compiled" signal for per-block spans. ``before``
    comes from ``jit_cache_size(jitted)`` taken before the call."""
    return jit_cache_size(jitted) > before


def jit_cache_size(jitted) -> int:
    """Trace-cache entry count of a jit wrapper, 0 where unavailable."""
    try:
        return int(jitted._cache_size())
    except Exception:       # noqa: BLE001
        return 0

"""Exporters: OTel-style span dicts, Prometheus text exposition, JSONL.

Three ways telemetry leaves the process, each closing a loop the repo
already has the other half of:

* ``to_otel_spans`` — plain span dicts with unix-seconds ``start`` /
  ``end``, ``records`` and ``status`` keys: EXACTLY the shape
  ``repro.calibrate.ObservedTrace.from_otel_spans`` consumes. The golden
  round-trip — run an instrumented experiment, export its spans,
  re-import, refit — means the twin calibrates from the tool's own
  telemetry (pinned in tests/test_obs.py).
* ``prometheus_exposition`` — the text exposition format, serving the
  Realtime-Datastreaming monitor's metric family (p50/p95/p99, mean,
  max, message count, target compliance) from ``GridSummary`` rows,
  plus the recorder's own counters/gauges/span stats. The output parses
  back through ``ObservedTrace.from_prometheus``-adjacent tooling and
  any scrape endpoint can serve it verbatim.
* ``append_jsonl`` / ``read_jsonl`` — the collect-continuously shape:
  every append writes the new spans (+ a counter snapshot) as JSON
  lines and prunes lines older than the retention window, so the file
  is a rolling window, not a log that grows forever.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs.record import Recorder, get_recorder

__all__ = ["append_jsonl", "prometheus_exposition", "read_jsonl",
           "to_otel_spans"]


def to_otel_spans(recorder: Optional[Recorder] = None, *,
                  name: Optional[str] = None,
                  prefix: Optional[str] = None) -> List[Dict]:
    """Export recorded spans as OTel-style dicts.

    Keys per span: ``name``, ``start`` / ``end`` (unix seconds — the
    recorder's wall/monotonic anchor places the monotonic timestamps on
    the epoch), ``records`` (from the span attr, default 1), ``status``
    ``"OK"``, and the remaining attrs under ``attributes``. Filter with
    ``name=`` (exact) or ``prefix=``. The list feeds
    ``ObservedTrace.from_otel_spans`` directly.
    """
    rec = recorder or get_recorder()
    out = []
    for sp in rec.find(name=name, prefix=prefix):
        attrs = dict(sp.attrs)
        records = attrs.pop("records", 1.0)
        out.append({
            "name": sp.name,
            "start": rec.wall_time(sp.start),
            "end": rec.wall_time(sp.end),
            "records": float(records),
            "status": "OK",
            "attributes": attrs,
        })
    return out


def _esc(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"') \
        .replace("\n", r"\n")


def _line(name: str, labels: Dict, value) -> str:
    lab = ",".join(f'{k}="{_esc(v)}"' for k, v in labels.items())
    v = float(value)
    sval = ("+Inf" if np.isposinf(v) else "-Inf" if np.isneginf(v)
            else repr(v))
    return f"{name}{{{lab}}} {sval}" if lab else f"{name} {sval}"


def prometheus_exposition(rows: Optional[Sequence] = None, *,
                          recorder: Optional[Recorder] = None,
                          namespace: str = "plantd") -> str:
    """Render Prometheus text exposition from grid rows + the recorder.

    ``rows`` are ``GridSummary`` (or ``SimulationResult``) rows — duck-
    typed: anything with ``name``, ``median_latency_s``/``p95``/``p99``,
    ``mean_latency_s``, ``pct_latency_met``, throughput and cost fields.
    Emitted families (the Snippet-2 monitor's vocabulary):

    * ``{ns}_latency_seconds{scenario,quantile=0.5|0.95|0.99}`` — the
      histogram-CDF quantiles;
    * ``{ns}_latency_mean_seconds`` / ``{ns}_latency_max_seconds``;
    * ``{ns}_message_count`` — records processed;
    * ``{ns}_target_compliance_percent`` — pct of records meeting the
      SLO (load-weighted), the monitor's "target compliance";
    * ``{ns}_cost_usd`` / ``{ns}_throughput_rph``.

    The recorder's own telemetry rides along: every counter as
    ``{ns}_obs_{name}_total``, gauges as ``{ns}_obs_{name}``, and
    per-span-name count/total-seconds summaries.
    """
    rec = recorder or get_recorder()
    lines: List[str] = []

    def family(name, ftype, help_text):
        lines.append(f"# HELP {name} {help_text}")
        lines.append(f"# TYPE {name} {ftype}")

    if rows:
        ns = namespace
        family(f"{ns}_latency_seconds", "gauge",
               "Record-weighted latency quantiles per scenario")
        for r in rows:
            for q, v in (("0.5", r.median_latency_s),
                         ("0.95", getattr(r, "p95_latency_s", 0.0)),
                         ("0.99", getattr(r, "p99_latency_s", 0.0))):
                lines.append(_line(f"{ns}_latency_seconds",
                                   {"scenario": r.name, "quantile": q},
                                   v))
        family(f"{ns}_latency_mean_seconds", "gauge",
               "Record-weighted mean latency per scenario")
        for r in rows:
            lines.append(_line(f"{ns}_latency_mean_seconds",
                               {"scenario": r.name}, r.mean_latency_s))
        family(f"{ns}_message_count", "gauge",
               "Records processed over the horizon")
        for r in rows:
            processed = getattr(r, "processed_records", None)
            if processed is None:       # series rows: integrate
                processed = float(np.sum(r.processed))
            lines.append(_line(f"{ns}_message_count",
                               {"scenario": r.name}, processed))
        family(f"{ns}_target_compliance_percent", "gauge",
               "Percent of records meeting the SLO target")
        for r in rows:
            lines.append(_line(f"{ns}_target_compliance_percent",
                               {"scenario": r.name}, r.pct_latency_met))
        family(f"{ns}_cost_usd", "gauge",
               "Total cost of the scenario (incl. backlog)")
        for r in rows:
            lines.append(_line(f"{ns}_cost_usd", {"scenario": r.name},
                               r.grand_total_usd))
        family(f"{ns}_throughput_rph", "gauge",
               "Mean records per hour processed")
        for r in rows:
            lines.append(_line(f"{ns}_throughput_rph",
                               {"scenario": r.name},
                               r.mean_throughput_rph))

    with rec._lock:
        counters = list(rec.counters.items())
        gauges = list(rec.gauges.items())
    if counters:
        family(f"{namespace}_obs_events_total", "counter",
               "repro.obs counters (runtime decisions + warn events)")
        for (nm, labels), v in sorted(counters):
            lab = dict(labels)
            lab["event"] = nm
            lines.append(_line(f"{namespace}_obs_events_total", lab, v))
    if gauges:
        family(f"{namespace}_obs_gauge", "gauge",
               "repro.obs gauges (latest value)")
        for (nm, labels), v in sorted(gauges):
            lab = dict(labels)
            lab["name"] = nm
            lines.append(_line(f"{namespace}_obs_gauge", lab, v))

    by_name: Dict[str, List[float]] = {}
    for sp in rec.find():
        by_name.setdefault(sp.name, []).append(sp.duration)
    if by_name:
        family(f"{namespace}_obs_span_count", "gauge",
               "Recorded spans per name (current retention window)")
        for nm in sorted(by_name):
            lines.append(_line(f"{namespace}_obs_span_count",
                               {"name": nm}, len(by_name[nm])))
        family(f"{namespace}_obs_span_seconds_total", "gauge",
               "Total recorded span seconds per name")
        for nm in sorted(by_name):
            lines.append(_line(f"{namespace}_obs_span_seconds_total",
                               {"name": nm}, sum(by_name[nm])))
    return "\n".join(lines) + "\n"


def append_jsonl(path: str, recorder: Optional[Recorder] = None, *,
                 retention_s: Optional[float] = None,
                 now: Optional[float] = None,
                 clear: bool = True) -> int:
    """Append the recorder's spans (+ one counter snapshot) to a JSONL
    file, then prune lines older than ``retention_s`` — the continuous
    collect loop's storage step. Returns the number of lines now in the
    file. ``clear=True`` empties the recorder's span ring after writing
    (each collect tick appends only what it saw); counters are
    cumulative and re-snapshotted each tick. ``now`` (unix seconds)
    overrides the wall clock for the retention cut, which is how tests
    pin the pruning.
    """
    rec = recorder or get_recorder()
    t_now = time.time() if now is None else float(now)
    new_lines = []
    for d in to_otel_spans(rec):
        d["type"] = "span"
        new_lines.append(json.dumps(d, sort_keys=True))
    with rec._lock:
        snap = dict(rec.counters)
    if snap:
        flat = {}
        for (nm, labels), v in snap.items():
            key = nm if not labels else nm + "{" + ",".join(
                f"{k}={val}" for k, val in labels) + "}"
            flat[key] = v
        new_lines.append(json.dumps(
            {"type": "counters", "t": t_now, "values": flat},
            sort_keys=True))

    old_lines: List[str] = []
    if os.path.exists(path):
        with open(path) as f:
            old_lines = [ln.rstrip("\n") for ln in f if ln.strip()]
    lines = old_lines + new_lines
    if retention_s is not None:
        cutoff = t_now - float(retention_s)

        def ts(ln: str) -> float:
            try:
                d = json.loads(ln)
                return float(d.get("end", d.get("t", t_now)))
            except (ValueError, TypeError):
                return t_now
        lines = [ln for ln in lines if ts(ln) >= cutoff]
    with open(path, "w") as f:
        for ln in lines:
            f.write(ln + "\n")
    if clear:
        with rec._lock:
            rec.spans.clear()
    return len(lines)


def read_jsonl(path: str) -> Dict[str, list]:
    """Read a collect file back: ``{"spans": [...], "counters": [...]}``
    — span dicts in the ``from_otel_spans`` shape, counter snapshots in
    append order (latest last)."""
    spans, counters = [], []
    with open(path) as f:
        for ln in f:
            if not ln.strip():
                continue
            d = json.loads(ln)
            if d.get("type") == "counters":
                counters.append(d)
            else:
                spans.append(d)
    return {"spans": spans, "counters": counters}

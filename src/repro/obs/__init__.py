"""``repro.obs`` — run-telemetry for the wind tunnel itself.

PlantD's pitch is instrumenting pipelines; this package instruments the
*reproduction*: spans at every dispatch boundary, counters for the
runtime decisions that used to vanish into warn-once messages, per-
dispatch compile/execute/peak-memory profiling, and exporters that
round-trip straight back into the tool (OTel span dicts ->
``ObservedTrace.from_otel_spans`` -> refit).

Off by default: export ``REPRO_OBS=1``, or::

    from repro import obs
    with obs.capture() as rec:
        simulate_grid(..., return_series=False)
    print(obs.render(rec))                       # console report
    spans = obs.to_otel_spans(rec)               # feeds from_otel_spans
    text = obs.prometheus_exposition(rows)       # scrape-able exposition
    obs.append_jsonl("obs.jsonl", retention_s=600)   # rolling collect

Disabled overhead is one module-attribute check per call site — the
instrumentation never sits inside jitted code, so the simulated numbers
are bit-identical either way. See ``record`` (spans/counters/ring
buffer), ``profile`` (compile-vs-execute dispatch profiling via
``jax.stages``), ``export`` (OTel / Prometheus / JSONL) and ``report``
(the ``make obs-report`` console summary).
"""
from repro.obs.export import (append_jsonl, prometheus_exposition,
                              read_jsonl, to_otel_spans)
from repro.obs.profile import (DispatchProfile, jit_cache_grew,
                               jit_cache_size, profile_dispatch)
from repro.obs.record import (ObsSpan, Recorder, capture, count, counters,
                              disable, enable, enabled, event, gauge,
                              get_recorder, instrument, set_recorder,
                              span, timed)
from repro.obs.report import render, summarize

__all__ = [
    "DispatchProfile", "ObsSpan", "Recorder", "append_jsonl", "capture",
    "count", "counters", "disable", "enable", "enabled", "event",
    "gauge", "get_recorder", "instrument", "jit_cache_grew",
    "jit_cache_size", "profile_dispatch", "prometheus_exposition",
    "read_jsonl", "render", "set_recorder", "span", "summarize", "timed",
    "to_otel_spans",
]

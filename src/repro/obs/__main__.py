"""``python -m repro.obs`` — the console run-telemetry report."""
from repro.obs.report import main

if __name__ == "__main__":
    raise SystemExit(main())

"""Console run-telemetry report — ``python -m repro.obs.report``.

One consolidated table replaces the per-bench copy-pasted timing
blocks: per span name — count, total seconds, mean / p50 / p95 / max,
records; then dispatch profiles (compile vs execute split, peak temp
memory); then counters and gauges.

Usage::

    python -m repro.obs.report              # instrumented demo run
    python -m repro.obs.report obs.jsonl    # report a collect file

With no argument the module runs a small instrumented workload (an
aggregate grid, a calibration fit and a policy search — the three hot
paths) and reports what it observed; ``make obs-report`` wraps this.
"""
from __future__ import annotations

import sys
from typing import Dict, List, Optional

import numpy as np

from repro.obs.record import Recorder, get_recorder

__all__ = ["render", "summarize", "main"]


def summarize(recorder: Optional[Recorder] = None) -> Dict[str, Dict]:
    """Per-span-name stats over the recorder's current window."""
    rec = recorder or get_recorder()
    by: Dict[str, List] = {}
    for sp in rec.find():
        by.setdefault(sp.name, []).append(sp)
    out = {}
    for name, sps in by.items():
        durs = np.array([s.duration for s in sps])
        out[name] = {
            "count": len(sps),
            "total_s": float(durs.sum()),
            "mean_s": float(durs.mean()),
            "p50_s": float(np.percentile(durs, 50)),
            "p95_s": float(np.percentile(durs, 95)),
            "max_s": float(durs.max()),
            "records": float(sum(s.attrs.get("records", 0.0)
                                 for s in sps)),
        }
    return out


def _fmt_s(v: float) -> str:
    if v >= 1.0:
        return f"{v:8.3f}s"
    if v >= 1e-3:
        return f"{v * 1e3:7.2f}ms"
    return f"{v * 1e6:7.1f}us"


def render(recorder: Optional[Recorder] = None) -> str:
    """The console report: spans, dispatch profiles, counters, gauges."""
    rec = recorder or get_recorder()
    stats = summarize(rec)
    lines = []
    if stats:
        lines.append(f"{'span':<34} {'count':>6} {'total':>9} "
                     f"{'mean':>9} {'p50':>9} {'p95':>9} {'max':>9}")
        lines.append("-" * 92)
        for name in sorted(stats, key=lambda n: -stats[n]["total_s"]):
            s = stats[name]
            lines.append(
                f"{name:<34} {s['count']:>6d} {_fmt_s(s['total_s'])} "
                f"{_fmt_s(s['mean_s'])} {_fmt_s(s['p50_s'])} "
                f"{_fmt_s(s['p95_s'])} {_fmt_s(s['max_s'])}")
    else:
        lines.append("(no spans recorded — is obs enabled?)")
    if rec.profiles:
        lines.append("")
        lines.append(f"{'dispatch':<34} {'compile':>9} {'execute':>9} "
                     f"{'peak temp':>10}")
        lines.append("-" * 66)
        for p in rec.profiles:
            peak = (f"{p.peak_temp_bytes / 2**20:8.1f}MB"
                    if p.peak_temp_bytes is not None else "       n/a")
            lines.append(f"{p.name:<34} {_fmt_s(p.compile_s)} "
                         f"{_fmt_s(p.execute_s)} {peak}")
    with rec._lock:
        counters = list(rec.counters.items())
    if counters:
        cnt = {}
        for (nm, labels), v in counters:
            key = nm if not labels else nm + "{" + ",".join(
                f"{k}={val}" for k, val in labels) + "}"
            cnt[key] = v
        lines.append("")
        lines.append("counters:")
        for k in sorted(cnt):
            lines.append(f"  {k:<50} {cnt[k]:>12g}")
    with rec._lock:
        gauges = dict(rec.gauges)
    if gauges:
        lines.append("")
        lines.append("gauges:")
        for (nm, labels), v in sorted(gauges.items()):
            key = nm if not labels else nm + "{" + ",".join(
                f"{k}={val}" for k, val in labels) + "}"
            lines.append(f"  {key:<50} {v:>12g}")
    return "\n".join(lines)


def _report_file(path: str) -> str:
    """Rebuild a report from a collect JSONL file (span dicts only —
    counters show the latest snapshot)."""
    from repro.obs.export import read_jsonl
    data = read_jsonl(path)
    rec = Recorder()
    for d in data["spans"]:
        attrs = dict(d.get("attributes", {}))
        attrs["records"] = d.get("records", 1.0)
        rec.add_span(d["name"], float(d["start"]), float(d["end"]), attrs)
    if data["counters"]:
        for k, v in data["counters"][-1].get("values", {}).items():
            rec.count(k, v)
    return render(rec)


def _demo() -> str:
    """The instrumented demo workload: one aggregate grid, one fit, one
    search — the consolidated timing table the per-bench scripts used
    to print piecemeal."""
    import numpy as _np

    from repro import obs
    from repro.calibrate import ObservedTrace, fit
    from repro.core.simulate import simulate_grid
    from repro.core.slo import SLO
    from repro.core.traffic import TrafficModel
    from repro.core.twin import make_twin
    from repro.search import search, search_space

    with obs.capture() as rec:
        traffic = TrafficModel.honda_default("demo", R=3.0, G=1.3)
        hl = traffic.hourly_loads().astype(_np.float32)
        twins = [make_twin(f"fifo{i}", "fifo", max_rps=2.0 + 0.2 * i,
                           usd_per_hour=0.01, base_latency_s=0.2)
                 for i in range(8)]
        simulate_grid(twins, _np.tile(hl, (8, 1)),
                      slo=SLO(limit_s=2 * 3600, met_fraction=0.95),
                      return_series=False)

        truth = make_twin("truth", "fifo", max_rps=2.4, usd_per_hour=0.01,
                          base_latency_s=0.3)
        arr = _np.clip(hl[:512] * 0.4, 0, None)
        trace = ObservedTrace.from_simulation(truth, arr, 1.0)
        fit(trace, "fifo", restarts=4, steps=40)

        base = make_twin("auto", "autoscale", max_rps=1.95,
                         usd_per_hour=0.0082, base_latency_s=0.15,
                         max_instances=8, scale_up_hours=2)
        space = search_space(base, ("max_instances", "scale_up_hours"))
        search(space, [traffic], SLO(limit_s=2 * 3600, met_fraction=0.95),
               restarts=4, steps=30, coarsen=8, polish_rounds=0)
        return render(rec)


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if argv:
        print(_report_file(argv[0]))
    else:
        print(_demo())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

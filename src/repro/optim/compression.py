"""Block-wise int8 quantization — used for (a) optimizer-state compression
(8-bit Adam moments; the memory trick that lets jamba-398B train states fit
16 GB/chip) and (b) gradient compression with error feedback for cross-pod
all-reduce (4x collective-byte reduction; see train/steps.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _pad_to(x: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, int]:
    n = x.size
    pad = (-n) % block
    flat = x.reshape(-1)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    return flat, pad


def quantize_int8(x: jnp.ndarray, block: int = 256):
    """Symmetric per-block int8. Returns (q int8 [n/block, block],
    scale f32 [n/block], meta) — reshape-agnostic; dequantize restores."""
    flat, _ = _pad_to(x.astype(jnp.float32), block)
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype=jnp.float32):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def block_absmax(x: jnp.ndarray, block: int = 256) -> jnp.ndarray:
    """Per-block absmax (the scale numerator) without quantizing."""
    flat, _ = _pad_to(x.astype(jnp.float32), block)
    return jnp.max(jnp.abs(flat.reshape(-1, block)), axis=1)


def quantize_int8_with_scale(x: jnp.ndarray, scale: jnp.ndarray,
                             block: int = 256) -> jnp.ndarray:
    """Quantize against an externally agreed per-block scale — required
    when int8 payloads from different devices are SUMMED (a shared scale
    makes the sum exact up to rounding; per-device scales would not)."""
    flat, _ = _pad_to(x.astype(jnp.float32), block)
    blocks = flat.reshape(-1, block)
    safe = jnp.where(scale > 0, scale, 1.0)
    return jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127
                    ).astype(jnp.int8)

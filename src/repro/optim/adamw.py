"""AdamW with global-norm clipping, warmup+cosine schedule, and optional
int8/bf16 moment storage (block-quantized, per optim/compression.py).

Pure pytree functions — no optax dependency; state layouts are declared so
the checkpointing and sharding layers treat optimizer state like any other
schema'd tree (m/v inherit the parameter's PartitionSpec).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.optim.compression import dequantize_int8, quantize_int8


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Dict[str, jnp.ndarray]
    v: Dict[str, jnp.ndarray]
    # int8 mode keeps per-block scales alongside each moment
    m_scale: Dict[str, jnp.ndarray]
    v_scale: Dict[str, jnp.ndarray]


def _moment_like(p, ocfg: OptimizerConfig):
    if ocfg.state_dtype == "int8":
        nblk = -(-p.size // ocfg.state_block)
        return (jnp.zeros((nblk, ocfg.state_block), jnp.int8),
                jnp.zeros((nblk,), jnp.float32))
    dt = jnp.bfloat16 if ocfg.state_dtype == "bfloat16" else jnp.float32
    return jnp.zeros(p.shape, dt), None


def init_opt_state(params: Dict[str, jnp.ndarray], ocfg: OptimizerConfig) -> OptState:
    m, v, ms, vs = {}, {}, {}, {}
    for k, p in params.items():
        mm, sc = _moment_like(p, ocfg)
        m[k] = mm
        v[k] = jnp.zeros_like(mm) if ocfg.state_dtype == "int8" else mm
        if sc is not None:
            ms[k], vs[k] = sc, jnp.zeros_like(sc)
    return OptState(jnp.zeros((), jnp.int32), m, v, ms, vs)


def abstract_opt_state(params, ocfg: OptimizerConfig) -> OptState:
    def absify(x):
        return jax.ShapeDtypeStruct(x.shape, x.dtype)
    return jax.tree.map(absify, jax.eval_shape(
        lambda p: init_opt_state(p, ocfg), params))


def opt_state_specs(param_specs: Dict, ocfg: OptimizerConfig,
                    params_abstract: Dict = None, fsdp_axis: str = "data",
                    mesh_shape: Dict[str, int] = None) -> OptState:
    """fp32/bf16 moments inherit the parameter spec; int8 block layouts
    shard their block dim over the FSDP axis when divisible."""
    from jax.sharding import PartitionSpec as P
    if ocfg.state_dtype == "int8":
        size = (mesh_shape or {}).get(fsdp_axis, 1)

        def blk_spec(k):
            if params_abstract is None or size <= 1:
                return P(), P()
            nblk = -(-_nelem(params_abstract[k].shape) // ocfg.state_block)
            if nblk % size == 0:
                return P(fsdp_axis, None), P(fsdp_axis)
            return P(), P()
        m, scales = {}, {}
        for k in param_specs:
            m[k], scales[k] = blk_spec(k)
        return OptState(P(), m, dict(m), scales, dict(scales))
    m = {k: v for k, v in param_specs.items()}
    return OptState(P(), m, dict(m), {}, {})


def _nelem(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def lr_at(step, ocfg: OptimizerConfig):
    warm = jnp.minimum(step / max(ocfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - ocfg.warmup_steps)
                 / max(ocfg.total_steps - ocfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return ocfg.lr * warm * (0.1 + 0.9 * cos)


def _load_moment(mm, sc, shape, ocfg, second: bool = False):
    if ocfg.state_dtype == "int8":
        x = dequantize_int8(mm, sc, shape)
        # second moment is stored as sqrt(v): halves the dynamic range the
        # int8 grid must cover (Dettmers-style 8-bit Adam)
        return jnp.square(x) if second else x
    return mm.astype(jnp.float32)


def _store_moment(x, ocfg, second: bool = False):
    if ocfg.state_dtype == "int8":
        return quantize_int8(jnp.sqrt(x) if second else x, ocfg.state_block)
    dt = jnp.bfloat16 if ocfg.state_dtype == "bfloat16" else jnp.float32
    return x.astype(dt), None


def adamw_update(params: Dict[str, jnp.ndarray], grads: Dict[str, jnp.ndarray],
                 state: OptState, ocfg: OptimizerConfig
                 ) -> Tuple[Dict[str, jnp.ndarray], OptState]:
    step = state.step + 1
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in grads.values()))
    clip = jnp.minimum(1.0, ocfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(step, ocfg)
    b1, b2 = ocfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_p, new_m, new_v, new_ms, new_vs = {}, {}, {}, {}, {}
    for k, p in params.items():
        g = grads[k].astype(jnp.float32) * clip
        m = _load_moment(state.m[k], state.m_scale.get(k), p.shape, ocfg)
        v = _load_moment(state.v[k], state.v_scale.get(k), p.shape, ocfg,
                         second=True)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        upd = mhat / (jnp.sqrt(vhat) + ocfg.eps) + ocfg.weight_decay * p.astype(jnp.float32)
        new_p[k] = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        mm, msc = _store_moment(m, ocfg)
        vv, vsc = _store_moment(v, ocfg, second=True)
        new_m[k], new_v[k] = mm, vv
        if msc is not None:
            new_ms[k], new_vs[k] = msc, vsc
    return new_p, OptState(step, new_m, new_v, new_ms, new_vs)

from repro.optim.adamw import (  # noqa: F401
    OptState, init_opt_state, abstract_opt_state, opt_state_specs,
    adamw_update, lr_at,
)
from repro.optim.compression import (  # noqa: F401
    quantize_int8, dequantize_int8,
)

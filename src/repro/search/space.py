"""Declarative search spaces over twin-policy parameters.

A ``SearchSpace`` names, for one registered policy, which parameters the
optimizer may move (policy extras like queue caps, batch windows,
autoscale thresholds — or capacity itself), the box each one lives in,
and how the remaining parameters are pinned to a *base* twin. The
optimizer never touches parameters directly: free slots ride the same
sigmoid/softplus bound bijection twin calibration declared in the
registry (``repro.calibrate.objective.params_from_z`` over
``PolicySpec.bounds``), so every gradient step stays inside the box by
construction — the "projection" of the projected-AdamW search is the
reparameterization itself.

Beyond calibration's layout, a space supports *tied* parameters:
``tie={"usd_per_hour": ("max_rps", ratio)}`` computes a parameter as a
fixed multiple of another (differentiably), which is how capacity
sizing stays priced — doubling ``max_rps`` doubles the hourly rate at
the base twin's price per unit capacity. ``default_space`` uses exactly
that for policies with no extras (fifo / quickscale), so every
registered policy gets a sensible search space in the cross-policy
tournament (``repro.search.optimize.search_policies``).

``SearchSpace.grid(n)`` materializes an ~n-point exhaustive sweep over
the free parameters (full factorial, log-spaced where the registry fits
in log space) — the brute-force baseline the optimizer is benchmarked
against (tests, ``benchmarks/search_bench.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.calibrate.objective import params_from_z, z_from_params
from repro.core.twin import PARAM_DIM, Twin, policy_spec

#: z kept inside +-Z_CLIP after every optimizer step: sigmoid(10) is
#: within 5e-5 of the box edge, while gradients still flow (the true
#: asymptote is a dead zone)
Z_CLIP = 10.0


@dataclass(frozen=True)
class SearchSpace:
    """One policy's searchable configuration box (see module docstring).

    lo/hi/log_mask follow the calibration reparam layout; ``free_mask``
    marks the searched slots, ``fixed`` pins everything else to the base
    twin, and ``tie_src``/``tie_coeff`` compute tied slots as
    ``coeff * params[src]`` after the bijection (src must itself be free
    or fixed, not tied).
    """
    policy: str
    base: Twin
    param_names: Tuple[str, ...]
    lo: np.ndarray            # [PARAM_DIM] f32
    hi: np.ndarray            # [PARAM_DIM] f32
    log_mask: np.ndarray      # [PARAM_DIM] bool
    free_mask: np.ndarray     # [PARAM_DIM] bool
    fixed: np.ndarray         # [PARAM_DIM] f32
    tie_src: np.ndarray       # [PARAM_DIM] int32, -1 = untied
    tie_coeff: np.ndarray     # [PARAM_DIM] f32

    @property
    def free_names(self) -> Tuple[str, ...]:
        return tuple(n for i, n in enumerate(self.param_names)
                     if self.free_mask[i])

    @property
    def num_free(self) -> int:
        return int(self.free_mask.sum())

    @property
    def policy_index(self) -> int:
        return policy_spec(self.policy).index

    @property
    def needs_surrogate(self) -> bool:
        """True when any parameter this space VARIES (free or tied) is
        hard-gated in the exact step (``PolicySpec.nondiff_params``).
        Only then does the optimizer scan the smooth-surrogate branch —
        otherwise the exact lane step is its own best gradient model and
        the search descends the true landscape."""
        spec = policy_spec(self.policy)
        varying = {n for i, n in enumerate(self.param_names)
                   if self.free_mask[i] or self.tie_src[i] >= 0}
        return bool(varying & set(spec.nondiff_params))

    # -- the z <-> params mapping (jnp, differentiable) --------------------

    def params_of_z(self, z):
        """[PARAM_DIM] unconstrained z -> boxed parameter vector with
        ties applied (pure jnp; the optimizer differentiates this)."""
        p = params_from_z(z, self.lo, self.hi,
                          jnp.asarray(self.log_mask),
                          jnp.asarray(self.free_mask),
                          jnp.asarray(self.fixed))
        return apply_ties(p, self.tie_src, self.tie_coeff)

    def z0(self, restarts: int, seed: int = 0) -> np.ndarray:
        """[K, PARAM_DIM] starts: start 0 is the base twin (clipped into
        the box), the rest Gaussian in z — spread across the box through
        the bijection, exactly like calibration restarts."""
        rng = np.random.default_rng(seed)
        z = rng.normal(0.0, 1.5, (restarts, PARAM_DIM)).astype(np.float32)
        base_p = np.clip(self.base.padded_params(),
                         self.lo * (1 + 1e-6) + 1e-12, self.hi)
        z[0] = z_from_params(base_p, self.lo, self.hi, self.log_mask)
        return np.clip(z, -Z_CLIP, Z_CLIP)

    def twin(self, params: np.ndarray, name: str) -> Twin:
        """Materialize a candidate parameter vector as a Twin."""
        p = np.asarray(params, np.float64)
        return Twin(name=name, policy=self.policy, kind="searched",
                    params=tuple(float(v)
                                 for v in p[:len(self.param_names)]))

    # -- the exhaustive baseline ------------------------------------------

    def grid(self, n: int, name_prefix: str = "grid") -> List[Twin]:
        """~n-point full-factorial sweep over the free parameters: each
        free dim gets ``round(n ** (1/d))`` points across its box
        (geometric where the registry fits the exponent), ties applied —
        the brute-force baseline ``search`` is measured against."""
        free = [i for i in range(PARAM_DIM) if self.free_mask[i]]
        if not free:
            return [self.twin(self._resolve(self.base.padded_params()),
                              f"{name_prefix}-0")]
        m = max(2, int(round(n ** (1.0 / len(free)))))
        axes = []
        for i in free:
            lo, hi = float(self.lo[i]), float(self.hi[i])
            if not np.isfinite(hi):
                raise ValueError(
                    f"{self.policy}.{self.param_names[i]}: cannot grid a "
                    f"half-open box ({lo:g}, inf) — give the parameter a "
                    f"finite upper bound (bounds=) for the exhaustive "
                    f"baseline")
            if self.log_mask[i]:
                axes.append(np.geomspace(max(lo, 1e-12), hi, m))
            else:
                axes.append(np.linspace(lo, hi, m))
        mesh = np.meshgrid(*axes, indexing="ij")
        pts = np.stack([ax.ravel() for ax in mesh], axis=1)
        twins = []
        for k, row in enumerate(pts):
            p = self.base.padded_params().astype(np.float64)
            p[free] = row
            twins.append(self.twin(self._resolve(p), f"{name_prefix}-{k}"))
        return twins

    def _resolve(self, p: np.ndarray) -> np.ndarray:
        """Apply ties host-side (numpy twin of ``apply_ties``)."""
        p = np.asarray(p, np.float64).copy()
        tied = self.tie_src >= 0
        p[tied] = self.tie_coeff[tied] * p[self.tie_src[tied]]
        return p


def apply_ties(p, tie_src, tie_coeff):
    """Overwrite tied slots with ``coeff * p[src]`` (jnp, differentiable;
    gather over a clipped index so untied slots read slot 0 harmlessly
    and are then masked back to their own value)."""
    src = jnp.asarray(tie_src)
    tied = src >= 0
    gathered = jnp.asarray(tie_coeff) * p[jnp.maximum(src, 0)]
    return jnp.where(tied, gathered, p)


def search_space(base: Twin, search: Optional[Sequence[str]] = None, *,
                 bounds: Optional[Dict[str, Tuple[float, float]]] = None,
                 tie: Optional[Dict[str, Tuple[str, float]]] = None
                 ) -> SearchSpace:
    """Build a ``SearchSpace`` for ``base``'s policy.

    ``search`` names the free parameters (default: the policy's extras —
    everything past the shared triple; for extra-less policies, capacity
    itself with the hourly rate tied to it, see ``default_space``).
    ``bounds`` overrides the registry boxes per parameter; ``tie`` maps
    ``name -> (source_name, coeff)`` so a parameter is computed, not
    searched. A base value outside a searched box is an error naming the
    parameter and policy — a silently clamped warm start is how searches
    return "optima" the operator never asked about.
    """
    spec = policy_spec(base.policy)
    names = spec.param_names
    if search is None:
        space = default_space(base, bounds=bounds, tie=tie)
        return space
    unknown = set(search) - set(names)
    if unknown:
        raise KeyError(f"{spec.name} has no params {sorted(unknown)}; "
                       f"expects {names}")
    tie = dict(tie or {})
    unknown_tie = (set(tie) | {src for src, _ in tie.values()}) - set(names)
    if unknown_tie:
        raise KeyError(f"{spec.name} has no params {sorted(unknown_tie)} "
                       f"(tie=)")
    overlap = set(tie) & set(search)
    if overlap:
        raise ValueError(f"{spec.name}: {sorted(overlap)} cannot be both "
                         f"searched and tied")
    for tname, (src, _coeff) in tie.items():
        if src in tie:
            raise ValueError(f"{spec.name}: tie source {src!r} is itself "
                             f"tied — chained ties are not supported")

    lo = np.zeros(PARAM_DIM, np.float32)
    hi = np.ones(PARAM_DIM, np.float32)
    log_mask = np.zeros(PARAM_DIM, bool)
    free_mask = np.zeros(PARAM_DIM, bool)
    fixed = np.zeros(PARAM_DIM, np.float32)
    tie_src = np.full(PARAM_DIM, -1, np.int32)
    tie_coeff = np.zeros(PARAM_DIM, np.float32)
    base_p = base.padded_params()
    for i, pname in enumerate(names):
        b_lo, b_hi = (bounds or {}).get(pname) or spec.bound(pname)
        if not b_lo < b_hi:
            raise ValueError(f"{spec.name}.{pname}: empty box "
                             f"({b_lo}, {b_hi})")
        lo[i], hi[i] = b_lo, b_hi
        # log-scale geometry: registry-declared log params, plus any box
        # spanning >= 2 decades (instance counts, queue caps): a linear
        # sigmoid over (1, 4096) puts the economical 1-10 region in the
        # bottom 0.2% of z-space and starves both restarts and grids
        log_mask[i] = (pname in spec.log_params
                       or (b_lo > 0 and np.isfinite(b_hi)
                           and b_hi / b_lo >= 100.0))
        if pname in tie:
            src_name, coeff = tie[pname]
            tie_src[i] = names.index(src_name)
            tie_coeff[i] = float(coeff)
        elif pname in search:
            free_mask[i] = True
            if not np.isfinite(b_hi):
                # the optimizer's z-clip caps a softplus half-open box at
                # lo + ~10, silently — demand the finite box grid()
                # already requires instead of returning a capped "optimum"
                raise ValueError(
                    f"{spec.name}.{pname}: searched parameters need a "
                    f"finite box, got ({b_lo:g}, inf) — pass bounds= with "
                    f"a finite upper bound")
            if not b_lo <= base_p[i] <= b_hi:
                raise ValueError(
                    f"{spec.name}.{pname}: base value {base_p[i]:g} lies "
                    f"outside the search box ({b_lo:g}, {b_hi:g}) — widen "
                    f"bounds= or fix the base twin")
        else:
            fixed[i] = base_p[i]
    return SearchSpace(policy=spec.name, base=base, param_names=names,
                       lo=lo, hi=hi, log_mask=log_mask,
                       free_mask=free_mask, fixed=fixed,
                       tie_src=tie_src, tie_coeff=tie_coeff)


def default_space(base: Twin, *,
                  bounds: Optional[Dict[str, Tuple[float, float]]] = None,
                  tie: Optional[Dict[str, Tuple[str, float]]] = None
                  ) -> SearchSpace:
    """The policy's natural knobs: its extras when it has any (queue
    caps, windows, instance bounds, boot delays); otherwise capacity
    sizing — ``max_rps`` free with ``usd_per_hour`` tied at the base
    twin's price per unit capacity, so fifo/quickscale searches answer
    "how big an instance should we buy", not "what if compute were
    free"."""
    spec = policy_spec(base.policy)
    extras = tuple(spec.param_names[3:])
    if extras:
        return search_space(base, extras, bounds=bounds, tie=tie)
    if tie is None:
        ratio = base.usd_per_hour / max(base.max_rps, 1e-12)
        tie = {"usd_per_hour": ("max_rps", ratio)}
    return search_space(base, ("max_rps",), bounds=bounds, tie=tie)

"""Multi-start projected-AdamW policy search, one dispatch per search.

``search`` inverts the what-if simulator for one policy: instead of
enumerating configurations and eyeballing the Table II grid, it descends
the differentiable annual-cost-plus-SLO-hinge objective
(``repro.search.objective``) over a declarative ``SearchSpace`` and
returns the cheapest configuration that *provably* meets the SLO — every
candidate is re-checked through the bit-exact streaming-aggregate grid
path before any number is reported.

The optimizer is structured exactly like twin calibration's multi-start
fit (``repro.calibrate.fit._fit_kernel``): all K restarts x S traffic
scenarios run as K*S *lanes* of the shared scenario-grid backend, and the
jitted ``_search_kernel`` scans

    steps  of  grad(lane-block objective)  +  vmap(AdamW)  +  z-clip

so a whole search is ONE device program — no Python loop over restarts,
ever. ``policy_index`` (and the SLO target, penalty weights, boxes and
ties) are traced operands, so one compiled kernel serves every policy of
a tournament at equal shapes; the z-space sigmoid/softplus
reparameterization (reused from ``calibrate``) is the projection of the
"projected" AdamW, plus a +-Z_CLIP clamp that keeps restarts out of the
sigmoid's dead zones.

``search_policies`` is the cross-policy tournament: every requested
policy's search in one call, ranked into a leaderboard (feasible first,
then by exact annual cost).

Feasibility failures are never silent: a search whose candidates all
miss the SLO warns with the policy, the achieved vs required compliance,
and any parameters pinned against their search box — the actionable
third of diagnosing "the SLO is simply unreachable in this box".
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.config import OptimizerConfig
from repro.core.simulate import GridSummary, simulate_grid
from repro.core.slo import SLO
from repro.core.traffic import TrafficModel
from repro.core.twin import (AGG_SLO_DROP_RATE, AGG_SLO_LATENCY, PARAM_DIM,
                             Twin, registry_version)
from repro.calibrate.objective import params_from_z
from repro.optim.adamw import adamw_update, init_opt_state
from repro.search.objective import (CHANCE_W, HINGE_S, annual_scale,
                                    lane_objective_t,
                                    lane_objective_vectorized)
from repro.search.space import (Z_CLIP, SearchSpace, apply_ties,
                                default_space, search_space)

#: AdamW settings for the z-space search: no decay (z=0 is mid-box, not a
#: prior), generous clip, short warmup; total_steps is overwritten with
#: the search's step count so the cosine tail anneals the final approach
#: to the SLO boundary.
DEFAULT_SEARCH_OPT = OptimizerConfig(lr=0.12, betas=(0.9, 0.95), eps=1e-8,
                                     weight_decay=0.0, grad_clip=10.0,
                                     warmup_steps=10, total_steps=200)

#: dollars of penalty per unit of hinged SLO shortfall, in multiples of
#: the base configuration's annual cost: a 1% compliance shortfall costs
#: one full base-year of spend, so feasibility dominates until met
DEFAULT_PENALTY_WEIGHT = 100.0

#: stand-in SLO operands when no SLO constrains the search (sigmoid
#: compliance saturates at 1, the hinge at met_fraction=0 is exactly 0)
_NO_SLO_LIMIT = 1e30


class SearchInfeasibleWarning(UserWarning):
    """No candidate configuration met the SLO (details in the message)."""


#: lane-bins (global K*S*F*T) above which the search kernel streams its
#: objective reductions through the scan carry instead of materializing
#: the [L, T] series. Below the threshold the vectorized hinge is faster
#: (the streamed fold pays per-bin sigmoid/softplus inside a sequential
#: scan, replayed by the checkpointed backward); above it the [L, T]
#: residuals dominate live memory and the streamed path wins wall clock
#: AND peak temp bytes (BENCH_search.json "stream" rows). Sized so the
#: multi-start bench (8 x 2184) vectorizes and the chance-constrained
#: frontier (1024 x 8736) streams.
_STREAM_MIN_ELEMS = 1 << 21


def _search_kernel_body(steps: int, n_scen: int, n_fut: int,
                        dt_hours: float, slo_mode: int, surrogate: bool,
                        version: int, ocfg: OptimizerConfig, stream: bool,
                        z0, loads_t, scen_w, lo, hi, log_mask, free_mask,
                        fixed, tie_src, tie_coeff, policy_index,
                        slo_limit_k, met_fraction, penalty_weight,
                        penalty_scale, horizon_scale, caps_t=None,
                        quantile=1.0):
    """K restarts x S scenarios (x F fault futures), one dispatch.

    z0 [K, PARAM_DIM]; loads_t [T, S*F] scenario-MINOR (columns
    scenario-major / future-minor) — with ``stream=True`` the whole
    gradient path stays scenario-minor so no [L, T] array ever exists in
    the jaxpr, forward or backward (the streamed ``lane_objective_t``
    folds its reductions into the scan carry); ``stream=False`` takes
    ``lane_objective_vectorized``'s materialized fast path, which wins
    below ``_STREAM_MIN_ELEMS`` lane-bins. The caller decides ``stream``
    from GLOBAL problem size (``_run_kernel``), never from local shapes,
    so sharded and unsharded dispatches always pick the same path and
    ``devices=D`` stays bit-identical to unsharded.
    scen_w [S] (normalized); slo_limit_k [K]
    per-restart SLO limits (a plain search broadcasts one limit; the
    Pareto frontier packs its whole target vector here). ``steps``/
    ``n_scen``/``n_fut``/``dt_hours``/``slo_mode``/``ocfg`` are static;
    ``version`` is the policy-registry version so late registrations
    retrace (same contract as the grid and fit kernels). Everything else
    — including ``policy_index`` and the box/tie arrays — is traced, so
    one compile serves a whole tournament at equal shapes.

    ``n_fut == 1`` (no faults) keeps the pre-chaos objective exactly:
    per-restart scenario-weighted sum of the per-lane cost+hinge. With
    ``n_fut > 1`` (``caps_t`` [T, S*F] riding along) the objective turns
    chance-constrained: expected cost over futures plus a penalty on the
    smoothed probability of meeting the SLO falling below ``quantile`` —
    each future votes sigmoid((frac - met)/CHANCE_W), the per-scenario
    mean of the votes is the smooth chance, its shortfall below the
    target quantile is hinged exactly like the plain path's met-fraction
    shortfall (plus the same small gated violation-depth term so deeply
    infeasible futures still pull).

    Returns (z_fin [K, D], params_fin [K, D], objective [K],
    cost_ann [K, S*F], met_frac [K, S*F], history [steps, K]); the aux
    triple rides the optimizer scan's carry from the LAST gradient
    evaluation (z at step ``steps - 1``) — diagnostics only, which saves
    the full-horizon forward the kernel used to re-dispatch at the end.
    """
    k = z0.shape[0]
    n_lanes = n_scen * n_fut
    loads_t_block = jnp.tile(loads_t, (1, k))
    caps_t_block = None if caps_t is None else jnp.tile(caps_t, (1, k))
    slo_lane = jnp.repeat(slo_limit_k, n_lanes)

    def params_of(z):
        p = jax.vmap(lambda zz: params_from_z(zz, lo, hi, log_mask,
                                              free_mask, fixed))(z)
        return jax.vmap(lambda row: apply_ties(row, tie_src, tie_coeff))(p)

    def objective(z):
        p = params_of(z)
        pb = jnp.repeat(p, n_lanes, axis=0)
        if stream:
            per_lane, (cost_ann, frac) = lane_objective_t(
                pb, loads_t_block, dt_hours, policy_index, slo_lane,
                slo_mode, met_fraction, penalty_weight, penalty_scale,
                horizon_scale, surrogate=surrogate,
                caps_t_block=caps_t_block)
        else:
            per_lane, (cost_ann, frac) = lane_objective_vectorized(
                pb, loads_t_block.T, dt_hours, policy_index, slo_lane,
                slo_mode, met_fraction, penalty_weight, penalty_scale,
                horizon_scale, surrogate=surrogate,
                caps_block=(None if caps_t_block is None
                            else caps_t_block.T))
        if n_fut == 1:
            per_restart = (per_lane.reshape(k, n_scen) * scen_w) \
                .sum(axis=1)
        else:
            cost_sf = cost_ann.reshape(k, n_scen, n_fut)
            frac_sf = frac.reshape(k, n_scen, n_fut)
            exp_cost = (cost_sf.mean(axis=2) * scen_w).sum(axis=1)
            chance = jax.nn.sigmoid((frac_sf - met_fraction)
                                    / CHANCE_W).mean(axis=2)
            # a future sitting exactly ON the met boundary votes 0.5, so
            # the reachable smooth chance tops out half a vote short of
            # the exact count — aim the hinge at that grid (a quantile
            # of 1.0 over F futures means "the worst future at the
            # boundary", i.e. chance ~ 1 - 0.5/F, not 1.0)
            q_eff = quantile - 0.5 / n_fut
            short = jax.nn.softplus((q_eff - chance) / HINGE_S) * HINGE_S
            # chance carries NO usable gradient once futures are deeply
            # infeasible (every per-bin compliance sigmoid saturates, so
            # autodiff sees only the cost slope and dives for the
            # cheapest corner) — the rescue slope is the per-lane
            # penalty lane_objective already computed, whose violation-
            # magnitude softplus stays LINEAR in the violation depth.
            # Gate its future-mean by the chance shortfall: full
            # restoring force while the quantile is missed, released
            # the moment it is met so the allowed (1 - quantile) worst
            # futures stop pulling capacity up at the boundary.
            pen_lane = (per_lane - cost_ann).reshape(k, n_scen, n_fut)
            gate = jax.nn.sigmoid((q_eff - chance) / HINGE_S)
            pen = (penalty_weight * penalty_scale * short
                   + gate * pen_lane.mean(axis=2))
            per_restart = exp_cost + (pen * scen_w).sum(axis=1)
        return per_restart.sum(), (per_restart,
                                   cost_ann.reshape(k, n_lanes),
                                   frac.reshape(k, n_lanes))

    vgrad = jax.value_and_grad(objective, has_aux=True)
    opt0 = jax.vmap(lambda z: init_opt_state({"z": z}, ocfg))(z0)
    aux0 = (jnp.zeros((k,), jnp.float32),
            jnp.zeros((k, n_lanes), jnp.float32),
            jnp.zeros((k, n_lanes), jnp.float32))

    def one_step(carry, _):
        z, opt, _ = carry
        (_, aux), g = vgrad(z)

        def upd(zk, gk, ok):
            new_p, new_o = adamw_update({"z": zk}, {"z": gk}, ok, ocfg)
            # the projection: stay on the live part of the bijection
            return jnp.clip(new_p["z"], -Z_CLIP, Z_CLIP), new_o

        z2, opt2 = jax.vmap(upd)(z, g, opt)
        # carry the aux out instead of re-running a full-horizon forward
        # on z_fin after the scan — these are diagnostics, one AdamW step
        # behind z_fin, and the exact re-check re-scores the candidates
        # anyway
        return (z2, opt2, aux), aux[0]

    (z_fin, _, (per_restart, cost_ann, frac)), history = jax.lax.scan(
        one_step, (z0, opt0, aux0), None, length=steps)
    return (z_fin, params_of(z_fin), per_restart, cost_ann, frac, history)


_search_kernel = functools.partial(
    jax.jit,
    static_argnums=(0, 1, 2, 3, 4, 5, 6, 7, 8))(_search_kernel_body)


@functools.lru_cache(maxsize=16)
def _sharded_search_fn(devices: int, steps: int, n_scen: int, n_fut: int,
                       dt_hours: float, slo_mode: int, surrogate: bool,
                       version: int, ocfg: OptimizerConfig, stream: bool,
                       has_caps: bool):
    """Build (and cache) the jitted ``shard_map`` search kernel for a
    ``devices``-wide 1-D restart mesh: z0 and slo_limit_k shard over
    their restart axis, every other operand is replicated, and each
    device runs ``_search_kernel_body`` on its K/D restarts. Restarts
    are completely independent in the kernel (per-restart reductions,
    vmapped AdamW; the grad-convenience ``per_restart.sum()`` splits
    exactly), so the sharded run is bit-identical to ``devices=None`` —
    the mesh only divides wall clock and per-device live memory."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.distributed.sharding import shard_map
    mesh = Mesh(np.asarray(jax.devices()[:devices]), ("restart",))
    shard, rep = P("restart"), P()

    def body(z0, loads_t, scen_w, lo, hi, log_mask, free_mask, fixed,
             tie_src, tie_coeff, policy_index, slo_limit_k, met_fraction,
             penalty_weight, penalty_scale, horizon_scale, caps_t,
             quantile):
        return _search_kernel_body(
            steps, n_scen, n_fut, dt_hours, slo_mode, surrogate, version,
            ocfg, stream,
            z0, loads_t, scen_w, lo, hi, log_mask, free_mask, fixed,
            tie_src, tie_coeff, policy_index, slo_limit_k, met_fraction,
            penalty_weight, penalty_scale, horizon_scale,
            caps_t if has_caps else None, quantile)

    # shard_map wants a spec per operand, so the benign path threads a
    # [T, 0] caps placeholder — one body signature serves both modes
    in_specs = (shard,) + (rep,) * 10 + (shard,) + (rep,) * 6
    out_specs = (shard, shard, shard, shard, shard, P(None, "restart"))
    return jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False))


@dataclass
class SearchResult:
    """Cheapest SLO-feasible configuration plus the evidence trail."""
    policy: str
    space: SearchSpace
    twin: Twin                     # best candidate (feasible when any is)
    cost_usd: float                # exact annual cost of ``twin``
    feasible: bool                 # SLO met on EVERY traffic scenario
    scenario_rows: List[GridSummary]      # twin's bit-exact rows, per scen
    base_cost_usd: float
    base_feasible: bool
    best_restart: int
    restart_params: np.ndarray     # [K, PARAM_DIM]
    restart_costs: np.ndarray      # [K] exact annual cost per restart
    restart_feasible: np.ndarray   # [K] bool
    restart_pct: np.ndarray        # [K] worst-scenario exact SLO pct
    history: np.ndarray            # [steps, K] smooth objective
    slo: Optional[SLO] = None
    # chance-constrained runs (search(faults=..., quantile=q)) only:
    # the target quantile and the winner's exact empirical quantile —
    # worst-scenario fraction of fault futures meeting the SLO on the
    # bit-exact aggregate re-check. Benign searches report 1.0 / 1.0.
    quantile: float = 1.0
    achieved_quantile: float = 1.0
    n_futures: int = 1

    @property
    def saving_vs_base(self) -> float:
        """Annual dollars saved against the base configuration."""
        return self.base_cost_usd - self.cost_usd

    @property
    def p95_latency_s(self) -> float:
        """Worst-scenario p95 latency of the chosen configuration (off
        the bit-exact aggregate histogram — the p-latency SLO evidence)."""
        return max((r.p95_latency_s for r in self.scenario_rows),
                   default=0.0)

    @property
    def p99_latency_s(self) -> float:
        return max((r.p99_latency_s for r in self.scenario_rows),
                   default=0.0)

    def config(self) -> Dict[str, float]:
        """The searched parameters of the winning configuration."""
        return {n: float(self.twin.param(n))
                for n in self.space.free_names}

    def restart_table(self) -> List[Dict]:
        rows = []
        for i in range(len(self.restart_costs)):
            row = {"restart": i,
                   "cost_usd": round(float(self.restart_costs[i]), 2),
                   "feasible": bool(self.restart_feasible[i]),
                   "pct_met": round(float(self.restart_pct[i]), 3),
                   "best": i == self.best_restart}
            for j, n in enumerate(self.space.param_names):
                if self.space.free_mask[j]:
                    row[n] = round(float(self.restart_params[i, j]), 5)
            rows.append(row)
        return rows

    def leaderboard_row(self) -> Dict:
        row = {"policy": self.policy,
               "feasible": self.feasible,
               "cost_usd": round(self.cost_usd, 2),
               "saving_vs_base": round(self.saving_vs_base, 2),
               "latency_p95_s": round(self.p95_latency_s, 2),
               "config": ", ".join(f"{k}={v:g}"
                                   for k, v in self.config().items())}
        return row


def _norm_weights(scenario_weights, n_scen: int) -> np.ndarray:
    w = np.asarray(scenario_weights if scenario_weights is not None
                   else np.full(n_scen, 1.0 / n_scen), np.float32)
    if w.shape != (n_scen,):
        raise ValueError(f"scenario_weights has shape {w.shape} for "
                         f"{n_scen} traffic scenarios — one weight per "
                         f"scenario")
    return w / max(w.sum(), 1e-12)


def _run_kernel(space: SearchSpace, g_loads: np.ndarray, g_bin: float,
                scen_w: np.ndarray, z0: np.ndarray, slo_limit_k: np.ndarray,
                slo_mode: int, met: float, penalty_weight: float,
                penalty_scale: float, g_horizon: float, steps: int,
                ocfg: OptimizerConfig, *, caps: Optional[np.ndarray] = None,
                n_fut: int = 1, quantile: float = 1.0,
                devices: Optional[int] = None):
    """Marshal one ``_search_kernel`` dispatch for a space and return
    ([K, PARAM_DIM] finite candidate vectors, [steps, K] history) —
    diverged restarts fall back to the base configuration's vector.
    Shared by ``search`` (one SLO limit broadcast over K) and
    ``pareto_frontier`` (M*K lane-packed limits). The keyword-only fault
    operands (``caps`` [S*F, T] + ``n_fut``/``quantile``) switch the
    kernel to its chance-constrained objective; ``g_loads`` then has
    S*F rows, scenario-major / future-minor. ``devices=D`` shards the
    restart axis over a D-device mesh (``_sharded_search_fn``),
    bit-identical to unsharded; a restart count that doesn't divide D
    falls back with the shared warn-once replication RuntimeWarning.

    The kernel's ``stream`` static (fold reductions into the scan carry
    vs materialize the [L, T] series) is decided HERE, from the global
    K*S*F*T lane-bin count against ``_STREAM_MIN_ELEMS`` — never from
    per-device shapes — so a sharded dispatch and its unsharded twin
    always run the same objective path and stay bit-identical."""
    from repro.distributed.sharding import resolve_mesh_axis
    stream = (z0.shape[0] * g_loads.shape[0] * g_loads.shape[1]
              >= _STREAM_MIN_ELEMS)
    statics = (int(steps), g_loads.shape[0] // int(n_fut), int(n_fut),
               float(g_bin), int(slo_mode), bool(space.needs_surrogate),
               registry_version(), ocfg, stream)
    loads_t = jnp.asarray(np.ascontiguousarray(g_loads.T))
    caps_t = (None if caps is None
              else jnp.asarray(np.ascontiguousarray(caps.T), jnp.float32))
    operands = (jnp.asarray(z0), loads_t, jnp.asarray(scen_w),
                jnp.asarray(space.lo), jnp.asarray(space.hi),
                jnp.asarray(space.log_mask), jnp.asarray(space.free_mask),
                jnp.asarray(space.fixed), jnp.asarray(space.tie_src),
                jnp.asarray(space.tie_coeff),
                jnp.int32(space.policy_index),
                jnp.asarray(slo_limit_k, jnp.float32), jnp.float32(met),
                jnp.float32(penalty_weight), jnp.float32(penalty_scale),
                jnp.float32(g_horizon))
    d = resolve_mesh_axis(devices, z0.shape[0],
                          "search(devices=) restart mesh")
    obs.count("search.objective_choice",
              stream=stream, policy=space.policy)
    with obs.span("search.kernel", restarts=z0.shape[0],
                  scenarios=g_loads.shape[0] // int(n_fut),
                  futures=int(n_fut), t_bins=g_loads.shape[1],
                  steps=int(steps), stream=stream,
                  devices=int(d or 1), policy=space.policy):
        if d is None:
            (_, p_fin, _, _, _, history) = _search_kernel(
                *statics, *operands, caps_t, jnp.float32(quantile))
        else:
            fn = _sharded_search_fn(d, *statics, caps_t is not None)
            caps_in = (caps_t if caps_t is not None
                       else jnp.zeros((loads_t.shape[0], 0), jnp.float32))
            (_, p_fin, _, _, _, history) = fn(
                *operands, caps_in, jnp.float32(quantile))
        jax.block_until_ready(p_fin)
    p_fin = np.asarray(p_fin, np.float64)
    bad = ~np.isfinite(p_fin).all(axis=1)
    obs.count("search.restarts", z0.shape[0], policy=space.policy)
    if bad.any():
        obs.count("search.restarts.diverged", int(bad.sum()),
                  policy=space.policy)
        p_fin[bad] = space._resolve(space.base.padded_params())
    return p_fin, np.asarray(history, np.float64)


def _as_loads(traffics, loads, bin_hours):
    if (traffics is None) == (loads is None):
        raise ValueError("pass exactly one of traffics= (TrafficModels) "
                         "or loads= [S, T] with bin_hours=")
    if traffics is not None:
        if isinstance(traffics, TrafficModel):
            traffics = [traffics]
        loads_np = np.stack([tr.hourly_loads() for tr in traffics]) \
            .astype(np.float32)
        return loads_np, 1.0, [tr.name for tr in traffics]
    loads_np = np.asarray(loads, np.float32)
    if loads_np.ndim == 1:
        loads_np = loads_np[None]
    if bin_hours is None:
        raise ValueError("raw loads= need bin_hours=")
    return loads_np, float(bin_hours), \
        [f"scenario{i}" for i in range(len(loads_np))]


def achieved_quantile(rows: Sequence[GridSummary], n_scen: int,
                      n_fut: int) -> float:
    """Worst-scenario fraction of fault futures whose exact re-check met
    the SLO — the empirical quantile a chance-constrained candidate
    actually achieves. ``rows`` is one candidate's [S*F] GridSummary
    list, scenario-major / future-minor."""
    met = np.array([bool(r.slo_met) for r in rows], bool) \
        .reshape(n_scen, n_fut)
    return float(met.mean(axis=1).min())


def evaluate_exact(twins: Sequence[Twin], loads_np: np.ndarray,
                   bin_hours: float, slo: Optional[SLO],
                   scen_w: np.ndarray, horizon_scale: float, *,
                   faults=None, quantile: float = 1.0
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                              List[List[GridSummary]]]:
    """Bit-exact candidate scoring through the streaming-aggregate grid.

    Every (candidate x scenario) pair runs as one aggregate-mode
    ``simulate_grid`` dispatch; a candidate is feasible only when the SLO
    holds on EVERY scenario (``GridSummary.slo_met`` — the exact counters,
    with the histogram p95/p99 columns riding along as the p-latency
    evidence ``SearchResult`` reports). Returns (annual_cost [C],
    feasible [C], worst_pct [C], rows [C][S]).

    ``faults=`` (keyword-only: a ``repro.faults.SampledFaults`` or
    ``FaultSchedule``) re-checks every candidate across the F fault
    futures instead: rows come back per (candidate, scenario, future) —
    [C][S*F], scenario-major / future-minor — cost becomes the
    scenario-weighted EXPECTED cost over futures, and feasibility
    becomes ``achieved_quantile(rows) >= quantile`` (the SLO must hold
    in at least that fraction of futures on every scenario).
    """
    c, s = len(twins), loads_np.shape[0]
    grid_twins = [tw for tw in twins for _ in range(s)]
    load_index = np.tile(np.arange(s, dtype=np.int32), c)
    names = [f"{tw.name}@s{j}" for tw in twins for j in range(s)]
    rows = simulate_grid(grid_twins, names=names, slo=slo,
                         bin_hours=bin_hours, return_series=False,
                         load_matrix=loads_np, load_index=load_index,
                         faults=faults)
    f = len(rows) // (c * s) if c and s else 1
    per = s * f
    rows_by_cand = [rows[i * per:(i + 1) * per] for i in range(c)]
    w_sf = np.repeat(np.asarray(scen_w, np.float64), f) / f
    cost = np.array([sum(w * r.total_cost_usd
                         for w, r in zip(w_sf, rr)) * horizon_scale
                     for rr in rows_by_cand])
    if slo is None:
        feas = np.ones(c, bool)
        pct = np.full(c, 100.0)
    elif faults is not None and f > 1:
        aq = np.array([achieved_quantile(rr, s, f) for rr in rows_by_cand])
        feas = aq >= float(quantile) - 1e-9
        pct = np.array([min(r.pct_latency_met for r in rr)
                        for rr in rows_by_cand])
    else:
        feas = np.array([all(r.slo_met for r in rr)
                         for rr in rows_by_cand])
        pct = np.array([min(r.pct_latency_met for r in rr)
                        for rr in rows_by_cand])
    return cost, feas, pct, rows_by_cand


def _coarsen(loads_np: np.ndarray, bin_hours: float, factor: int):
    """Sum groups of ``factor`` bins for the gradient loop (the policy
    steps are bin-width aware, so dt simply grows); the exact re-check
    always runs on the original bins."""
    if factor <= 1:
        return loads_np, bin_hours
    t = loads_np.shape[1] // factor * factor
    coarse = loads_np[:, :t].reshape(loads_np.shape[0], -1, factor) \
        .sum(axis=2)
    return np.ascontiguousarray(coarse, np.float32), bin_hours * factor


def _coarsen_caps(caps: np.ndarray, factor: int) -> np.ndarray:
    """Mean-coarsen a capacity-multiplier series (loads SUM per coarse
    bin, multipliers AVERAGE — an outage covering half a coarse bin is a
    50% brownout at that scale). Gradient-guide approximation only; the
    exact re-check replays the original bins."""
    if factor <= 1:
        return caps
    t = caps.shape[1] // factor * factor
    coarse = caps[:, :t].reshape(caps.shape[0], -1, factor).mean(axis=2)
    return np.ascontiguousarray(coarse, np.float32)


def _bounds_diagnosis(space: SearchSpace, params: np.ndarray) -> List[str]:
    """Names of free parameters pinned against their search box (within
    0.5% of an edge, measured in the parameter's own fit scale — log for
    log-fitted parameters) — the actionable half of an infeasibility
    report."""
    pinned = []
    for i, n in enumerate(space.param_names):
        if not space.free_mask[i]:
            continue
        lo, hi = float(space.lo[i]), float(space.hi[i])
        if not (np.isfinite(hi) and hi > lo):
            continue
        v = float(params[i])
        if space.log_mask[i] and lo > 0:
            frac = (np.log(max(v, 1e-300)) - np.log(lo)) \
                / max(np.log(hi) - np.log(lo), 1e-12)
        else:
            frac = (v - lo) / (hi - lo)
        if frac <= 0.005:
            pinned.append(f"{n}={v:g} at lower bound {lo:g}")
        elif frac >= 0.995:
            pinned.append(f"{n}={v:g} at upper bound {hi:g}")
    return pinned


def _box_pos(space: SearchSpace, p: np.ndarray) -> np.ndarray:
    """Free coords of ``p`` as normalized positions in their boxes
    (log scale where the space fits the exponent)."""
    u = np.zeros(PARAM_DIM)
    for i in np.where(space.free_mask)[0]:
        lo, hi = float(space.lo[i]), float(space.hi[i])
        if space.log_mask[i] and lo > 0:
            u[i] = (np.log(max(p[i], lo)) - np.log(lo)) \
                / max(np.log(hi) - np.log(lo), 1e-12)
        else:
            u[i] = (p[i] - lo) / max(hi - lo, 1e-12)
    return np.clip(u, 0.0, 1.0)


def _box_params(space: SearchSpace, p_base: np.ndarray,
                u: np.ndarray) -> np.ndarray:
    """Inverse of ``_box_pos``: rebuild a full parameter vector from
    normalized free coords (ties re-applied). Positions 0/1 land on the
    box edges EXACTLY — the one thing the sigmoid reparam cannot do."""
    p = p_base.astype(np.float64).copy()
    for i in np.where(space.free_mask)[0]:
        lo, hi = float(space.lo[i]), float(space.hi[i])
        if space.log_mask[i] and lo > 0:
            p[i] = lo * (hi / lo) ** u[i]
        else:
            p[i] = lo + u[i] * (hi - lo)
    return space._resolve(p)


def _polish_ladder(space: SearchSpace, p_best: np.ndarray,
                   span: float) -> np.ndarray:
    """[C, PARAM_DIM] polish candidates around the incumbent: per free
    coordinate, +-span * (1, 1/2, 1/4, 1/8) steps in normalized box
    position plus the two exact box edges; incumbent first."""
    u0 = _box_pos(space, p_best)
    offs = np.array([span, -span, span / 2, -span / 2,
                     span / 4, -span / 4, span / 8, -span / 8])
    cands = [space._resolve(p_best)]
    for j in np.where(space.free_mask)[0]:
        for target in list(np.clip(u0[j] + offs, 0.0, 1.0)) + [0.0, 1.0]:
            u = u0.copy()
            u[j] = target
            cands.append(_box_params(space, p_best, u))
    return np.stack(cands)


def search(space_or_base: Union[SearchSpace, Twin],
           traffics=None, slo: Optional[SLO] = None, *,
           loads: Optional[np.ndarray] = None,
           bin_hours: Optional[float] = None,
           restarts: int = 8, steps: int = 120, seed: int = 0,
           scenario_weights: Optional[Sequence[float]] = None,
           opt: Optional[OptimizerConfig] = None,
           penalty_weight: float = DEFAULT_PENALTY_WEIGHT,
           met_margin: float = 0.002,
           coarsen: int = 1,
           polish_rounds: int = 3,
           search_params: Optional[Sequence[str]] = None,
           faults=None, quantile: float = 1.0,
           devices: Optional[int] = None) -> SearchResult:
    """Find the cheapest configuration of one policy that meets ``slo``.

    ``space_or_base`` is a ``SearchSpace`` (full control) or a base
    ``Twin`` (the policy's ``default_space`` — or ``search_params`` —
    around it). Traffic comes as ``traffics=`` TrafficModels (hourly
    year rows) or raw ``loads=`` [S, T] with ``bin_hours=``. All K
    ``restarts`` x S scenarios run as one ``_search_kernel`` dispatch;
    ``coarsen`` sums that many bins per gradient-loop step (the exact
    re-check always uses the original bins). ``met_margin`` tightens the
    smooth objective's compliance target slightly so candidates land on
    the feasible side of the boundary the exact re-check draws;
    ``polish_rounds`` batched coordinate-ladder refinements around the
    winner (each one exact aggregate dispatch, span quartering per
    round) then walk it onto that exact boundary — the last fraction of
    a percent no smooth penalty can locate.

    ``faults=`` (a ``repro.faults.FaultSchedule`` or ``SampledFaults``)
    makes the search **chance-constrained**: every (restart x scenario)
    lane fans out over the schedule's F fault futures, the objective
    becomes expected annual cost over futures plus a smooth-quantile
    hinge (see ``_search_kernel``), and a candidate is feasible when the
    bit-exact aggregate re-check meets the SLO in at least ``quantile``
    of the futures on every scenario. ``quantile=1.0`` (the default) is
    the worst-case search — the SLO must hold in EVERY sampled future;
    ``quantile=0.95`` buys the 95%-of-futures configuration, strictly
    cheaper whenever the worst futures are expensive to insure against.
    The result's ``achieved_quantile`` reports the winner's exact
    empirical quantile.

    **Scaling the search.** At scale the gradient loop is a
    streaming-aggregate scan: every reduction the objective needs folds
    into the scan carry as compensated triples (``search.objective``),
    and the checkpointed O(√T) VJP replays √T-bin segments on the
    backward pass — live memory is O(L·√T) for L = restarts × scenarios
    × fault futures lanes, NOT O(L·T), so a chance-constrained
    year-horizon search (K=8 × S=4 × F=32, T=8736) no longer stages
    ~150 MB of series per AdamW step. Small problems (under
    ``_STREAM_MIN_ELEMS`` global lane-bins, where the fold's per-bin
    transcendentals cost more than the series they avoid) keep the
    vectorized materialized objective — the choice is a compile-time
    static made from global sizes, invisible to results.
    ``devices=D`` additionally shards the restart axis over a D-device
    mesh through the ``distributed/sharding.py`` shim — restarts are
    independent, so results are **bit-identical** to ``devices=None``
    and the mesh only divides wall clock and per-device memory. On a
    multi-core CPU host export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=D`` *before the
    first jax import*; when ``restarts`` doesn't divide D the search
    warns once (RuntimeWarning) and runs unsharded. Tournaments
    (``search_policies(devices=...)``), ``pareto_frontier(devices=...)``
    and ``whatif.optimize_scenario(devices=...)`` forward here.

    **Observing the wind tunnel** (``repro.obs``). With telemetry on
    every gradient-loop dispatch records a ``search.kernel`` span
    (attrs: restarts, scenarios, futures, t_bins, steps, the
    ``stream`` objective choice, devices, policy) and the runtime
    decisions that used to be invisible become counters:
    ``search.objective_choice{stream,policy}`` (streamed fold vs
    vectorized hinge — the ``_STREAM_MIN_ELEMS`` static),
    ``search.restarts`` / ``search.restarts.diverged`` /
    ``search.restarts.feasible`` per policy, and an infeasible search
    additionally bumps ``warn.search_infeasible{policy,pinned}`` so the
    warning stays countable after Python's warn-once dedup silences the
    repeat (the UserWarning still fires). All of it sits outside jitted
    code — enabling telemetry changes no searched number.
    """
    if isinstance(space_or_base, SearchSpace):
        space = space_or_base
    elif search_params is not None:
        space = search_space(space_or_base, search_params)
    else:
        space = default_space(space_or_base)
    loads_np, bin_hours, scen_names = _as_loads(traffics, loads, bin_hours)
    s = loads_np.shape[0]
    scen_w = _norm_weights(scenario_weights, s)
    horizon = annual_scale(loads_np.shape[1], bin_hours)

    sampled = None
    n_fut = 1
    if faults is not None:
        from repro.faults import (FaultSchedule, SampledFaults,
                                  sample_futures)
        if isinstance(faults, FaultSchedule):
            sampled = sample_futures(faults, loads_np.shape[1], bin_hours)
        elif isinstance(faults, SampledFaults):
            sampled = faults
        else:
            raise TypeError(f"faults= must be a repro.faults.FaultSchedule "
                            f"or SampledFaults, got {type(faults).__name__}")
        n_fut = sampled.n_futures
        if not 0.0 < quantile <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {quantile}")

    # the base configuration's exact cost anchors the penalty scale and
    # the "what did the search buy us" delta
    base_cost, base_feas, _, _ = evaluate_exact(
        [space.base], loads_np, bin_hours, slo, scen_w, horizon,
        faults=sampled, quantile=quantile)

    if slo is None:
        slo_mode, slo_limit, met = AGG_SLO_LATENCY, _NO_SLO_LIMIT, 0.0
    else:
        slo_mode = (AGG_SLO_DROP_RATE if slo.metric == "drop_rate"
                    else AGG_SLO_LATENCY)
        slo_limit = float(slo.limit_s)
        met = min(float(slo.met_fraction) + met_margin, 1.0)

    if sampled is not None:
        # fan the gradient loop's lanes out over the futures: loads get
        # each scenario's F perturbed rows (reconnect floods baked in),
        # caps ride along as the matching capacity series
        grad_loads = np.stack([sampled.apply_loads(r) for r in loads_np]) \
            .reshape(s * n_fut, -1).astype(np.float32)
        grad_caps = np.tile(np.asarray(sampled.cap, np.float32), (s, 1))
    else:
        grad_loads, grad_caps = loads_np, None

    g_loads, g_bin = _coarsen(grad_loads, bin_hours, int(coarsen))
    g_caps = (None if grad_caps is None
              else _coarsen_caps(grad_caps, int(coarsen)))
    g_horizon = annual_scale(g_loads.shape[1], g_bin)
    ocfg = dataclasses.replace(opt or DEFAULT_SEARCH_OPT, total_steps=steps)
    p_fin, history = _run_kernel(
        space, g_loads, g_bin, scen_w, space.z0(restarts, seed),
        np.full((restarts,), slo_limit), slo_mode, met, penalty_weight,
        max(base_cost[0], 1.0), g_horizon, steps, ocfg,
        caps=g_caps, n_fut=n_fut, quantile=quantile, devices=devices)
    cand_twins = [space.twin(p_fin[i], f"{space.policy}-cand{i}")
                  for i in range(restarts)]
    cost, feas, pct, rows = evaluate_exact(cand_twins, loads_np, bin_hours,
                                           slo, scen_w, horizon,
                                           faults=sampled,
                                           quantile=quantile)
    cost = np.where(np.isfinite(cost), cost, np.inf)
    pct = np.nan_to_num(pct, nan=0.0)
    obs.count("search.restarts.feasible", int(feas.sum()),
              policy=space.policy)

    if feas.any():
        best = int(np.where(feas, cost, np.inf).argmin())
        feasible = True
        # polish: batched coordinate ladders around the winner (including
        # the exact box edges), scored through the SAME exact aggregate
        # path — one dispatch per round, span halving. This walks the
        # config onto the exact SLO boundary the smooth hinge can only
        # approach, and onto box-edge optima the sigmoid reparam can
        # only asymptote toward.
        p_best = p_fin[best].copy()
        best_cost = float(cost[best])
        best_twin, best_rows = cand_twins[best], rows[best]
        span = 0.5
        rounds = int(polish_rounds) if space.num_free else 0
        for _ in range(rounds):
            p_c = _polish_ladder(space, p_best, span)
            twins_c = [space.twin(p_c[i], f"{space.policy}-pol{i}")
                       for i in range(len(p_c))]
            c_c, f_c, _, r_c = evaluate_exact(
                twins_c, loads_np, bin_hours, slo, scen_w, horizon,
                faults=sampled, quantile=quantile)
            c_c = np.where(f_c & np.isfinite(c_c), c_c, np.inf)
            i_c = int(c_c.argmin())
            if c_c[i_c] < best_cost:
                best_cost = float(c_c[i_c])
                best_twin, best_rows = twins_c[i_c], r_c[i_c]
                p_best = p_c[i_c]
            span /= 4.0
        cand_twins = list(cand_twins)
        cand_twins[best] = best_twin
        rows = list(rows)
        rows[best] = best_rows
        cost = cost.copy()
        cost[best] = best_cost
        p_fin[best] = best_twin.padded_params()
    else:
        best = int(pct.argmax())       # closest to compliance
        feasible = False
        desc = (f"{slo.metric} <= {slo.limit_s:g} in "
                f"{slo.met_fraction:.0%} of records" if slo is not None
                else "unconstrained")
        if sampled is not None:
            desc += (f", in >= {quantile:.0%} of {n_fut} fault futures "
                     f"per scenario")
        pins = _bounds_diagnosis(space, p_fin[best])
        obs.event("warn.search_infeasible", policy=space.policy,
                  pinned=bool(pins))
        warnings.warn(
            f"{space.policy} search found NO feasible configuration for "
            f"SLO ({desc}): best candidate reaches "
            f"{pct[best]:.2f}% compliance (needs "
            f"{(slo.met_fraction if slo else 0) * 100:.2f}%)"
            + (f"; pinned against the search box: {'; '.join(pins)} — "
               f"widen bounds= on those parameters or relax the SLO"
               if pins else
               "; no parameter is pinned at its bound — this policy "
               "likely cannot meet the SLO on this traffic at any "
               "configuration in the space"),
            SearchInfeasibleWarning, stacklevel=2)

    aq = 1.0
    if sampled is not None and slo is not None:
        aq = achieved_quantile(rows[best], s, n_fut)
    return SearchResult(
        policy=space.policy, space=space,
        twin=dataclasses.replace(cand_twins[best],
                                 name=f"{space.policy}-opt"),
        cost_usd=float(cost[best]), feasible=feasible,
        scenario_rows=rows[best],
        base_cost_usd=float(base_cost[0]), base_feasible=bool(base_feas[0]),
        best_restart=best, restart_params=p_fin,
        restart_costs=cost, restart_feasible=feas, restart_pct=pct,
        history=np.asarray(history, np.float64), slo=slo,
        quantile=float(quantile) if sampled is not None else 1.0,
        achieved_quantile=float(aq), n_futures=n_fut)


@dataclass
class TournamentResult:
    """Ranked cross-policy search results (feasible first, then cost)."""
    results: List[SearchResult] = field(default_factory=list)

    @property
    def best(self) -> SearchResult:
        return self.ranked()[0]

    def ranked(self) -> List[SearchResult]:
        return sorted(self.results,
                      key=lambda r: (not r.feasible, r.cost_usd))

    def leaderboard_rows(self) -> List[Dict]:
        rows = []
        best_cost = self.best.cost_usd
        for i, r in enumerate(self.ranked()):
            row = {"rank": i + 1}
            row.update(r.leaderboard_row())
            row["vs_winner_usd"] = round(r.cost_usd - best_cost, 2)
            rows.append(row)
        return rows


def search_policies(bases: Sequence[Twin], traffics=None,
                    slo: Optional[SLO] = None, *,
                    search_params: Optional[Dict[str, Sequence[str]]] = None,
                    spaces: Optional[Sequence[SearchSpace]] = None,
                    **kwargs) -> TournamentResult:
    """The cross-policy tournament: one search per base twin (its
    policy's default space, a ``search_params[policy]`` override, or a
    prebuilt entry of ``spaces``), every search one kernel dispatch — and
    all of them ONE compile when shapes agree, since the policy index and
    boxes are traced operands. Returns the ranked leaderboard."""
    if spaces is None:
        spaces = []
        for base in bases:
            override = (search_params or {}).get(base.policy)
            spaces.append(search_space(base, override)
                          if override is not None else default_space(base))
    results = [search(sp, traffics, slo, **kwargs) for sp in spaces]
    return TournamentResult(results=results)

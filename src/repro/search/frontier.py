"""Cost-vs-SLO Pareto frontier: the price of tightening an SLO.

``pareto_frontier`` runs one policy search per SLO target — but NOT one
dispatch per target: all M targets x K restarts x S scenarios ride the
same ``_search_kernel`` as M*K lanes (the per-restart ``slo_limit_k``
vector is exactly the hook the kernel exposes for this), so the whole
sweep is still a single grad-of-scan device program. Each target's
candidates are then re-checked through the bit-exact aggregate path and
the frontier is assembled tightest-target-first, carrying the best
feasible configuration forward: a config feasible at a tight SLO is
feasible at every looser one, so the quoted cost is non-increasing as
the SLO loosens *by construction* — the frontier a business user reads
("loosening p95 from 1h to 4h saves $X/yr") can never zig-zag on
optimizer noise.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.config import OptimizerConfig
from repro.core.slo import SLO
from repro.core.twin import AGG_SLO_DROP_RATE, AGG_SLO_LATENCY, Twin
from repro.search.objective import annual_scale
from repro.search.optimize import (DEFAULT_PENALTY_WEIGHT,
                                   DEFAULT_SEARCH_OPT, SearchSpace,
                                   _as_loads, _coarsen, _norm_weights,
                                   _run_kernel, evaluate_exact)
from repro.search.space import default_space


@dataclass
class FrontierPoint:
    """One SLO target on the frontier."""
    limit_s: float
    cost_usd: float                # exact annual cost (inf if infeasible)
    feasible: bool
    twin: Optional[Twin]
    pct_met: float                 # worst-scenario exact compliance
    p95_latency_s: float = 0.0
    p99_latency_s: float = 0.0
    config: Dict[str, float] = None     # the searched parameters


@dataclass
class Frontier:
    """The assembled cost-vs-SLO curve (tightest target first)."""
    policy: str
    metric: str
    met_fraction: float
    points: List[FrontierPoint]

    def rows(self) -> List[Dict]:
        """Table rows: the price of each SLO tightening step."""
        rows = []
        prev_cost = None
        for p in self.points:
            rows.append({
                "slo_limit": p.limit_s,
                "feasible": p.feasible,
                "cost_usd": round(p.cost_usd, 2) if p.feasible else None,
                "tightening_premium_usd":
                    None if (prev_cost is None or not p.feasible)
                    else round(prev_cost - p.cost_usd, 2),
                "latency_p95_s": round(p.p95_latency_s, 2),
                "config": ", ".join(
                    f"{k}={v:g}" for k, v in (p.config or {}).items())
                    or "-",
            })
            if p.feasible:
                prev_cost = p.cost_usd
        return rows


def pareto_frontier(space_or_base: Union[SearchSpace, Twin],
                    traffics=None,
                    slo_limits: Sequence[float] = (),
                    *, metric: str = "latency",
                    met_fraction: float = 0.95,
                    loads: Optional[np.ndarray] = None,
                    bin_hours: Optional[float] = None,
                    restarts: int = 6, steps: int = 120, seed: int = 0,
                    scenario_weights: Optional[Sequence[float]] = None,
                    opt: Optional[OptimizerConfig] = None,
                    penalty_weight: float = DEFAULT_PENALTY_WEIGHT,
                    met_margin: float = 0.005,
                    coarsen: int = 1,
                    devices: Optional[int] = None) -> Frontier:
    """Sweep the SLO limit and return cost-to-serve at each target.

    All ``len(slo_limits) * restarts`` searches run as lanes of ONE
    ``_search_kernel`` dispatch (the SLO limit is a per-restart operand);
    per-target exact re-checks and the monotone assembly happen host-side
    (see module docstring). Targets are processed tightest first
    regardless of input order; the returned points follow that order.
    The gradient loop streams its reductions (O(lanes·√T) memory — see
    "Scaling the search" in ``search()``); ``devices=D`` shards the M*K
    packed restart axis over a D-device mesh, bit-identical to
    unsharded, with the same warn-once replication fallback when M*K
    doesn't divide D.
    """
    if len(slo_limits) == 0:
        raise ValueError("pareto_frontier needs at least one SLO limit")
    space = space_or_base if isinstance(space_or_base, SearchSpace) \
        else default_space(space_or_base)
    loads_np, bin_hours, _ = _as_loads(traffics, loads, bin_hours)
    scen_w = _norm_weights(scenario_weights, loads_np.shape[0])
    horizon = annual_scale(loads_np.shape[1], bin_hours)
    slo_mode = (AGG_SLO_DROP_RATE if metric == "drop_rate"
                else AGG_SLO_LATENCY)

    limits = np.sort(np.asarray(slo_limits, np.float64))   # tightest first
    m, k = len(limits), restarts

    base_cost, _, _, _ = evaluate_exact(
        [space.base], loads_np, bin_hours, None, scen_w, horizon)

    g_loads, g_bin = _coarsen(loads_np, bin_hours, int(coarsen))
    g_horizon = annual_scale(g_loads.shape[1], g_bin)
    ocfg = dataclasses.replace(opt or DEFAULT_SEARCH_OPT, total_steps=steps)
    # M targets x K restarts = M*K kernel "restarts": same starts per
    # target, each block penalized against its own limit
    p_fin, _ = _run_kernel(
        space, g_loads, g_bin, scen_w, np.tile(space.z0(k, seed), (m, 1)),
        np.repeat(limits, k), slo_mode,
        min(met_fraction + met_margin, 1.0), penalty_weight,
        max(base_cost[0], 1.0), g_horizon, steps, ocfg, devices=devices)
    p_fin = p_fin.reshape(m, k, -1)

    points: List[FrontierPoint] = []
    carry_twin: Optional[Twin] = None
    for j, limit in enumerate(limits):
        slo = SLO(metric=metric, limit_s=float(limit),
                  met_fraction=met_fraction)
        cands = [space.twin(p_fin[j, i], f"{space.policy}-L{j}-c{i}")
                 for i in range(k)]
        # monotone assembly: a config feasible at a TIGHTER limit is
        # feasible here too, so the tighter winner competes in THIS
        # target's exact re-check (its compliance is re-measured against
        # this limit — no stale numbers) and the quoted cost can only
        # fall as the SLO loosens
        if carry_twin is not None:
            cands.append(carry_twin)
        cost, feas, pct, rows = evaluate_exact(
            cands, loads_np, bin_hours, slo, scen_w, horizon)
        cost = np.where(np.isfinite(cost), cost, np.inf)
        pct = np.nan_to_num(pct, nan=0.0)
        if feas.any():
            best = int(np.where(feas, cost, np.inf).argmin())
            pt = FrontierPoint(
                limit_s=float(limit), cost_usd=float(cost[best]),
                feasible=True, twin=cands[best], pct_met=float(pct[best]),
                p95_latency_s=max(r.p95_latency_s for r in rows[best]),
                p99_latency_s=max(r.p99_latency_s for r in rows[best]),
                config={n: float(cands[best].param(n))
                        for n in space.free_names})
            carry_twin = cands[best]
        else:
            best = int(pct.argmax())
            pt = FrontierPoint(
                limit_s=float(limit), cost_usd=float("inf"),
                feasible=False, twin=None, pct_met=float(pct[best]))
        points.append(pt)
    return Frontier(policy=space.policy, metric=metric,
                    met_fraction=met_fraction, points=points)

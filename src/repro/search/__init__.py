"""Calibrated policy search: SLO-constrained differentiable optimization.

This package *inverts* the what-if simulator. Where ``whatif.run_grid``
enumerates (twin x traffic) scenarios and leaves a human to scan Table II
for the cheapest row that still meets the SLO, ``repro.search`` descends
a differentiable annual-cost objective (smooth softplus SLO hinge,
evaluated through the same lane-vectorized scan backend calibration
uses, with registry-declared smooth surrogates for hard-gated policy
extras) and returns that configuration directly — with every reported
number re-checked through the bit-exact streaming-aggregate grid path.

Layers:

* ``space``     — declarative search spaces over policy parameters
                  (registry bounds + sigmoid/softplus reparam reused from
                  ``repro.calibrate``, tied parameters for priced
                  capacity, exhaustive ``grid(n)`` baselines);
* ``objective`` — the smooth annual-cost + SLO-hinge lane objective;
* ``optimize``  — multi-start projected AdamW (K restarts x S traffic
                  scenarios as lanes of ONE grad-of-scan dispatch),
                  ``search_policies`` cross-policy tournament;
* ``frontier``  — cost-vs-SLO Pareto sweep (all targets as lanes of the
                  same single dispatch; monotone by construction).

``search(faults=schedule, quantile=q)`` makes the whole thing
**chance-constrained** (``repro.faults``): lanes fan out over F sampled
fault futures per scenario, the objective becomes expected cost plus a
smooth differentiable quantile hinge, and the winner is the cheapest
configuration whose bit-exact re-check meets the SLO in at least ``q``
of the futures on every scenario (``SearchResult.achieved_quantile``).

Entry points: ``search`` / ``search_policies`` / ``pareto_frontier``
here, or ``repro.core.whatif.optimize_scenario`` for the
measure -> calibrate -> optimize loop the paper's business questions
want ("cheapest config that keeps p95 under 2h at +40% traffic" —
examples/whatif_analysis.py, What-if #6).
"""
from repro.search.frontier import Frontier, FrontierPoint, pareto_frontier
from repro.search.objective import lane_objective, smooth_met_fraction
from repro.search.optimize import (SearchInfeasibleWarning, SearchResult,
                                   TournamentResult, achieved_quantile,
                                   evaluate_exact, search, search_policies)
from repro.search.space import (SearchSpace, default_space, search_space)

__all__ = [
    "Frontier", "FrontierPoint", "pareto_frontier",
    "lane_objective", "smooth_met_fraction",
    "SearchInfeasibleWarning", "SearchResult", "TournamentResult",
    "achieved_quantile", "evaluate_exact", "search", "search_policies",
    "SearchSpace", "default_space", "search_space",
]

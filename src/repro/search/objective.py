"""The search objective: differentiable annual cost under a smooth SLO.

``lane_objective`` scores a [L, PARAM_DIM] block of candidate
configurations against a [L, T] block of traffic scenarios in ONE
lane-vectorized scan — the same dispatch shape (and the same
``kernels.ops.policy_scan`` backend selection) twin calibration uses for
its restarts, with ``surrogate=True`` so hard-gated policy extras
(quickscale/autoscale's ceil, batch_window's flush comparison) carry
gradients. Per lane it returns

    annual cost  +  penalty_weight * penalty_scale * hinge(SLO shortfall)

where annual cost is the simulated cost series total plus the paper's
end-of-horizon backlog pricing (queue / capacity hours at the twin's
hourly rate), scaled from the simulated horizon to the 8736-hour year;
and the SLO term is a *fixed-weight softplus hinge* on the smoothed
met-fraction: each bin's compliance is a sigmoid of its distance to the
limit (width ``tau`` of the limit), load-weighted into a fraction, and
any shortfall below ``met_fraction`` is hinged through softplus. The
hinge is scaled by a caller-supplied reference cost (``penalty_scale``,
normally the base configuration's exact annual cost) so the penalty is
meaningful in dollars regardless of problem size.

This objective is a *gradient guide only*: nothing it computes is ever
reported. ``repro.search.optimize`` re-checks every candidate through
the bit-exact streaming-aggregate path (``simulate_grid(
return_series=False)``) before declaring it feasible or quoting a cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.traffic import HOURS_PER_YEAR
from repro.core.twin import AGG_SLO_DROP_RATE

#: softplus hinge softness, in met-fraction units: a razor hinge — the
#: tail must be ~zero a few tenths of a percent INSIDE feasibility, or
#: its slope (times the dollar-denominated penalty weight) out-muscles
#: the real cost gradient and parks the optimum well short of the
#: boundary the SLO actually draws
HINGE_S = 0.001
#: compliance-sigmoid width as a fraction of the SLO limit: narrow, so a
#: comfortably feasible configuration scores frac ~= 1 and feels pure
#: cost gradients (a wide sigmoid would make feasible configs look
#: half-infeasible and chase latency instead of dollars)
DEFAULT_TAU = 0.02
#: weight of the violation-*magnitude* term relative to penalty_weight.
#: The met-fraction hinge saturates once every violating bin's sigmoid
#: does (deeply infeasible configs would feel NO restoring gradient and
#: race down the cost slope instead); the magnitude term keeps growing
#: with violation depth, so feasibility pressure exists everywhere. It is
#: GATED by infeasibility (sigmoid of the met shortfall) and kept small:
#: the SLO budget explicitly allows (1 - met_fraction) of the load to
#: violate, so charging feasible configs for allowed violations would
#: bias the optimum conservative — and AdamW's per-coordinate
#: normalization follows even a tiny consistent gradient, so small is
#: enough to escape the infeasible region.
EXCESS_WEIGHT = 0.002
#: width of the per-future met/miss sigmoid in met-fraction units for the
#: chance constraint (``repro.search.search(faults=..., quantile=...)``):
#: each fault future contributes sigmoid((frac - met)/CHANCE_W) to the
#: smoothed probability of meeting the SLO, wide enough that a future
#: hovering at the boundary passes usable gradient to the quantile hinge,
#: narrow enough that clearly-met/clearly-missed futures count ~0/1 like
#: the exact re-check's indicator
CHANCE_W = 0.01


def annual_scale(t_bins: int, bin_hours: float) -> float:
    """Scale factor from a simulated horizon to the 8736-hour year."""
    return HOURS_PER_YEAR / (t_bins * bin_hours)


def smooth_met_fraction(values, loads, slo_limit_lane, width):
    """[L] smoothed load-weighted fraction of bins within the SLO limit.

    values [L, T] (latency seconds or drop fractions); loads [L, T];
    slo_limit_lane [L] per-lane limits (the Pareto frontier runs many
    limits as lanes of one dispatch); ``width`` [L, 1] or scalar sigmoid
    width. Each bin contributes a sigmoid of its margin — the
    differentiable stand-in for the aggregate path's exact ``<=``
    counters.
    """
    ok = jax.nn.sigmoid((slo_limit_lane[:, None] - values) / width)
    return (ok * loads).sum(axis=1) / jnp.maximum(loads.sum(axis=1), 1e-9)


def lane_objective(params_block, loads_block, dt_hours, policy_index,
                   slo_limit_lane, slo_mode: int, met_fraction,
                   penalty_weight, penalty_scale, horizon_scale,
                   tau=DEFAULT_TAU, surrogate: bool = True,
                   caps_block=None):
    """[L] smooth objective values for a lane block (see module docstring).

    params_block [L, PARAM_DIM]; loads_block [L, T]; ``policy_index``,
    ``slo_limit_lane`` [L], ``met_fraction``, ``penalty_*``,
    ``horizon_scale`` and ``tau`` may all be traced — one compiled kernel
    serves every policy, SLO target and penalty setting of a tournament.
    ``slo_mode``, ``dt_hours`` and ``surrogate`` are static; pass
    ``surrogate=False`` (``SearchSpace.needs_surrogate``) when no
    searched parameter is hard-gated, so the optimizer descends the TRUE
    landscape instead of the smoothed one. ``caps_block`` [L, T]
    (optional) threads a fault schedule's capacity multipliers through
    the scan (chance-constrained resilience search — each lane is then
    one (candidate, scenario, fault future) triple).
    Returns (objective [L], (annual_cost [L], met_frac [L])).
    """
    from repro.kernels import ops     # late: keep repro.search importable
    carry_end, (_proc, _q, lat, cost, drop) = ops.policy_scan(
        loads_block, params_block, dt_hours=dt_hours,
        policy_index=policy_index, differentiable=True,
        surrogate=surrogate, caps=caps_block)
    total = cost.sum(axis=1)
    backlog_cost = (carry_end[:, 0]
                    / jnp.maximum(params_block[:, 0], 1e-9) / 3600.0
                    * params_block[:, 1])
    cost_ann = (total + backlog_cost) * horizon_scale
    if slo_mode == AGG_SLO_DROP_RATE:
        values = drop / jnp.maximum(loads_block, 1e-9)
        width = tau * slo_limit_lane[:, None] + 1e-4   # rate floor
        # small absolute allowance: a zero-tolerance limit (drop_rate
        # <= 0) would otherwise park every compliant bin at sigmoid(0)
        # = 0.5 and the penalty could never release; the shift keeps
        # v == limit counting as met (the exact counters' <=) at the
        # price of a ~3-width optimism the exact re-check absorbs
        limits = slo_limit_lane + 3e-4
    else:
        values = lat
        width = tau * slo_limit_lane[:, None] + 1e-6
        limits = slo_limit_lane
    frac = smooth_met_fraction(values, loads_block, limits, width)
    shortfall = met_fraction - frac
    hinge = jax.nn.softplus(shortfall / HINGE_S) * HINGE_S
    # violation magnitude in widths, rescaled by tau so it reads as
    # "per unit of the limit", and gated off in the feasible region —
    # see EXCESS_WEIGHT
    rel = (values - limits[:, None]) / width
    w = loads_block
    excess = tau * (jax.nn.softplus(rel) * w).sum(axis=1) \
        / jnp.maximum(w.sum(axis=1), 1e-9)
    gate = jax.nn.sigmoid(shortfall / HINGE_S)
    penalty = penalty_weight * penalty_scale * (
        hinge + EXCESS_WEIGHT * gate * excess)
    return cost_ann + penalty, (cost_ann, frac)

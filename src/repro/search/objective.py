"""The search objective: differentiable annual cost under a smooth SLO.

``lane_objective`` scores a [L, PARAM_DIM] block of candidate
configurations against a [L, T] block of traffic scenarios in ONE
lane-vectorized scan — the same dispatch shape (and the same
``kernels.ops`` backend selection) twin calibration uses for its
restarts, with ``surrogate=True`` so hard-gated policy extras
(quickscale/autoscale's ceil, batch_window's flush comparison) carry
gradients. Per lane it returns

    annual cost  +  penalty_weight * penalty_scale * hinge(SLO shortfall)

where annual cost is the simulated cost series total plus the paper's
end-of-horizon backlog pricing (queue / capacity hours at the twin's
hourly rate), scaled from the simulated horizon to the 8736-hour year;
and the SLO term is a *fixed-weight softplus hinge* on the smoothed
met-fraction: each bin's compliance is a sigmoid of its distance to the
limit (width ``tau`` of the limit), load-weighted into a fraction, and
any shortfall below ``met_fraction`` is hinged through softplus. The
hinge is scaled by a caller-supplied reference cost (``penalty_scale``,
normally the base configuration's exact annual cost) so the penalty is
meaningful in dollars regardless of problem size.

**The reductions stream.** By default (``stream=True``, and always on
the ``lane_objective_t`` kernel entry) nothing [L, T]-shaped is ever
materialized: the four per-lane sums the objective needs — cost, the
load-weighted compliance-sigmoid numerator/denominator behind
``smooth_met_fraction``, and the violation-magnitude softplus mass —
ride the policy scan's carry as twice-compensated f32 triples
(``core.twin.fold_triple_*``, the PR 4 trick) through
``kernels.ops.policy_scan_fold``, whose checkpointed O(√T) VJP replays
√T-bin segments on the backward pass instead of taping the horizon.
``stream=False`` keeps the series-materializing reference path; both
run the IDENTICAL per-bin fold code (``_obj_fold_*``) and finalize, so
their values agree bit for bit — pinned in tests/test_stream_objectives.
``lane_objective_vectorized`` is the third form: the same math as one
vectorized [L, T] hinge with plain f32 sums — the fast gradient guide
the search kernel uses below its streaming size threshold.

This objective is a *gradient guide only*: nothing it computes is ever
reported. ``repro.search.optimize`` re-checks every candidate through
the bit-exact streaming-aggregate path (``simulate_grid(
return_series=False)``) before declaring it feasible or quoting a cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.traffic import HOURS_PER_YEAR
from repro.core.twin import (AGG_SLO_DROP_RATE, fold_triple_add,
                             fold_triple_finalize, fold_triple_init)

#: softplus hinge softness, in met-fraction units: a razor hinge — the
#: tail must be ~zero a few tenths of a percent INSIDE feasibility, or
#: its slope (times the dollar-denominated penalty weight) out-muscles
#: the real cost gradient and parks the optimum well short of the
#: boundary the SLO actually draws
HINGE_S = 0.001
#: compliance-sigmoid width as a fraction of the SLO limit: narrow, so a
#: comfortably feasible configuration scores frac ~= 1 and feels pure
#: cost gradients (a wide sigmoid would make feasible configs look
#: half-infeasible and chase latency instead of dollars)
DEFAULT_TAU = 0.02
#: weight of the violation-*magnitude* term relative to penalty_weight.
#: The met-fraction hinge saturates once every violating bin's sigmoid
#: does (deeply infeasible configs would feel NO restoring gradient and
#: race down the cost slope instead); the magnitude term keeps growing
#: with violation depth, so feasibility pressure exists everywhere. It is
#: GATED by infeasibility (sigmoid of the met shortfall) and kept small:
#: the SLO budget explicitly allows (1 - met_fraction) of the load to
#: violate, so charging feasible configs for allowed violations would
#: bias the optimum conservative — and AdamW's per-coordinate
#: normalization follows even a tiny consistent gradient, so small is
#: enough to escape the infeasible region.
EXCESS_WEIGHT = 0.002
#: width of the per-future met/miss sigmoid in met-fraction units for the
#: chance constraint (``repro.search.search(faults=..., quantile=...)``):
#: each fault future contributes sigmoid((frac - met)/CHANCE_W) to the
#: smoothed probability of meeting the SLO, wide enough that a future
#: hovering at the boundary passes usable gradient to the quantile hinge,
#: narrow enough that clearly-met/clearly-missed futures count ~0/1 like
#: the exact re-check's indicator
CHANCE_W = 0.01


def annual_scale(t_bins: int, bin_hours: float) -> float:
    """Scale factor from a simulated horizon to the 8736-hour year."""
    return HOURS_PER_YEAR / (t_bins * bin_hours)


def smooth_met_fraction(values, loads, slo_limit_lane, width):
    """[L] smoothed load-weighted fraction of bins within the SLO limit.

    values [L, T] (latency seconds or drop fractions); loads [L, T];
    slo_limit_lane [L] per-lane limits (the Pareto frontier runs many
    limits as lanes of one dispatch); ``width`` [L, 1] or scalar sigmoid
    width. Each bin contributes a sigmoid of its margin — the
    differentiable stand-in for the aggregate path's exact ``<=``
    counters. (The streamed objective folds this same numerator /
    denominator pair into the scan carry instead of calling this.)
    """
    ok = jax.nn.sigmoid((slo_limit_lane[:, None] - values) / width)
    return (ok * loads).sum(axis=1) / jnp.maximum(loads.sum(axis=1), 1e-9)


# ---------------------------------------------------------------------------
# The shared per-bin fold — ONE implementation for both dispatch shapes.
# The streamed path runs these inside the policy scan's carry; the
# materialized path scans the same functions over its [L, T] series.
# Sharing the code (not just the math) is what makes the two paths
# bit-identical by construction.
# ---------------------------------------------------------------------------

def _obj_fold_init(n):
    """(cost, ok·load, load, softplus-excess·load) compensated triples."""
    return (fold_triple_init(n), fold_triple_init(n),
            fold_triple_init(n), fold_triple_init(n))


def _obj_fold(acc, w, v, cost, limits, width):
    c_t, o_t, l_t, e_t = acc
    ok = jax.nn.sigmoid((limits - v) / width)
    sp = jax.nn.softplus((v - limits) / width)
    return (fold_triple_add(c_t, cost),
            fold_triple_add(o_t, ok * w),
            fold_triple_add(l_t, w),
            fold_triple_add(e_t, sp * w))


def _obj_fold_latency(acc, arrive, outs, ops_lane, xs_row):
    del xs_row
    _proc, _q, lat, cost, _drop = outs
    limits, width = ops_lane
    return _obj_fold(acc, arrive, lat, cost, limits, width)


def _obj_fold_droprate(acc, arrive, outs, ops_lane, xs_row):
    del xs_row
    _proc, _q, _lat, cost, drop = outs
    limits, width = ops_lane
    v = drop / jnp.maximum(arrive, 1e-9)
    return _obj_fold(acc, arrive, v, cost, limits, width)


def _obj_ops_lane(slo_limit_lane, slo_mode: int, tau):
    """Per-lane (limits, width) operands of the fold, by SLO mode."""
    if slo_mode == AGG_SLO_DROP_RATE:
        width = tau * slo_limit_lane + 1e-4   # rate floor
        # small absolute allowance: a zero-tolerance limit (drop_rate
        # <= 0) would otherwise park every compliant bin at sigmoid(0)
        # = 0.5 and the penalty could never release; the shift keeps
        # v == limit counting as met (the exact counters' <=) at the
        # price of a ~3-width optimism the exact re-check absorbs
        limits = slo_limit_lane + 3e-4
    else:
        width = tau * slo_limit_lane + 1e-6
        limits = slo_limit_lane
    return limits, width


def _obj_combine(acc, carry_end, params_block, met_fraction,
                 penalty_weight, penalty_scale, horizon_scale, tau):
    """Folded sums -> (objective [L], (annual_cost [L], met_frac [L]))."""
    c_t, o_t, l_t, e_t = acc
    total = fold_triple_finalize(c_t)
    okl = fold_triple_finalize(o_t)
    load = fold_triple_finalize(l_t)
    excess_sum = fold_triple_finalize(e_t)
    backlog_cost = (carry_end[:, 0]
                    / jnp.maximum(params_block[:, 0], 1e-9) / 3600.0
                    * params_block[:, 1])
    cost_ann = (total + backlog_cost) * horizon_scale
    frac = okl / jnp.maximum(load, 1e-9)
    shortfall = met_fraction - frac
    hinge = jax.nn.softplus(shortfall / HINGE_S) * HINGE_S
    # violation magnitude in widths, rescaled by tau so it reads as
    # "per unit of the limit", and gated off in the feasible region —
    # see EXCESS_WEIGHT
    excess = tau * excess_sum / jnp.maximum(load, 1e-9)
    gate = jax.nn.sigmoid(shortfall / HINGE_S)
    penalty = penalty_weight * penalty_scale * (
        hinge + EXCESS_WEIGHT * gate * excess)
    return cost_ann + penalty, (cost_ann, frac)


def lane_objective_t(params_block, loads_t_block, dt_hours, policy_index,
                     slo_limit_lane, slo_mode: int, met_fraction,
                     penalty_weight, penalty_scale, horizon_scale,
                     tau=DEFAULT_TAU, surrogate: bool = True,
                     caps_t_block=None):
    """Streaming ``lane_objective`` over scenario-minor operands.

    ``loads_t_block`` / ``caps_t_block`` come [T, L] so the search
    kernel's whole gradient path stays scenario-minor — no [L, T] array
    exists anywhere in its jaxpr (asserted in tests). Reductions fold
    into the scan carry via ``kernels.ops.policy_scan_fold``; O(L·√T)
    live memory in both directions. Same return contract as
    ``lane_objective``, bit-identical values.
    """
    from repro.kernels import ops     # late: keep repro.search importable
    ops_lane = _obj_ops_lane(slo_limit_lane, slo_mode, tau)
    step = (_obj_fold_droprate if slo_mode == AGG_SLO_DROP_RATE
            else _obj_fold_latency)
    carry_end, acc = ops.policy_scan_fold(
        params=params_block, dt_hours=dt_hours, policy_index=policy_index,
        surrogate=surrogate, loads_t=loads_t_block, caps_t=caps_t_block,
        fold_init=_obj_fold_init, fold_step=step, ops_lane=ops_lane)
    return _obj_combine(acc, carry_end, params_block, met_fraction,
                        penalty_weight, penalty_scale, horizon_scale, tau)


def lane_objective(params_block, loads_block, dt_hours, policy_index,
                   slo_limit_lane, slo_mode: int, met_fraction,
                   penalty_weight, penalty_scale, horizon_scale,
                   tau=DEFAULT_TAU, surrogate: bool = True,
                   caps_block=None, stream: bool = True):
    """[L] smooth objective values for a lane block (see module docstring).

    params_block [L, PARAM_DIM]; loads_block [L, T]; ``policy_index``,
    ``slo_limit_lane`` [L], ``met_fraction``, ``penalty_*``,
    ``horizon_scale`` and ``tau`` may all be traced — one compiled kernel
    serves every policy, SLO target and penalty setting of a tournament.
    ``slo_mode``, ``dt_hours`` and ``surrogate`` are static; pass
    ``surrogate=False`` (``SearchSpace.needs_surrogate``) when no
    searched parameter is hard-gated, so the optimizer descends the TRUE
    landscape instead of the smoothed one. ``caps_block`` [L, T]
    (optional) threads a fault schedule's capacity multipliers through
    the scan (chance-constrained resilience search — each lane is then
    one (candidate, scenario, fault future) triple).

    ``stream=True`` (default) folds the reductions into the scan carry
    (O(L·√T) memory, forward and backward); ``stream=False`` is the
    series-materializing reference the parity tests compare against —
    identical fold code either way, so values match bitwise.
    Returns (objective [L], (annual_cost [L], met_frac [L])).
    """
    if stream:
        caps_t = (None if caps_block is None
                  else jnp.asarray(caps_block, jnp.float32).T)
        return lane_objective_t(
            params_block, jnp.asarray(loads_block, jnp.float32).T,
            dt_hours, policy_index, slo_limit_lane, slo_mode,
            met_fraction, penalty_weight, penalty_scale, horizon_scale,
            tau=tau, surrogate=surrogate, caps_t_block=caps_t)
    from repro.kernels import ops     # late: keep repro.search importable
    carry_end, outs = ops.policy_scan(
        loads_block, params_block, dt_hours=dt_hours,
        policy_index=policy_index, differentiable=True,
        surrogate=surrogate, caps=caps_block)
    ops_lane = _obj_ops_lane(slo_limit_lane, slo_mode, tau)
    step = (_obj_fold_droprate if slo_mode == AGG_SLO_DROP_RATE
            else _obj_fold_latency)
    loads_t = jnp.asarray(loads_block, jnp.float32).T
    outs_t = tuple(o.T for o in outs)
    acc0 = _obj_fold_init(loads_t.shape[1])

    def fold(acc, row):
        arrive, outs_row = row
        return step(acc, arrive, outs_row, ops_lane, ()), None

    acc, _ = jax.lax.scan(fold, acc0, (loads_t, outs_t))
    return _obj_combine(acc, carry_end, params_block, met_fraction,
                        penalty_weight, penalty_scale, horizon_scale, tau)


def lane_objective_vectorized(params_block, loads_block, dt_hours,
                              policy_index, slo_limit_lane, slo_mode: int,
                              met_fraction, penalty_weight, penalty_scale,
                              horizon_scale, tau=DEFAULT_TAU,
                              surrogate: bool = True, caps_block=None):
    """Small-problem fast path: materialize the [L, T] series and take
    the hinge reductions as plain vectorized sums.

    Same arguments and return contract as ``lane_objective``, same math
    — but the compliance sigmoid / violation softplus run ONCE over the
    whole [L, T] block instead of per bin inside a sequential fold, and
    the sums are plain f32 ``sum(axis=1)`` instead of compensated
    triples. Below a couple million lane-bins the transcendentals
    dominate the streamed path's scan (they get replayed by the
    checkpointed backward and vectorize poorly at kernel-width lanes),
    so this form is measurably faster there; above it the [L, T]
    residuals dominate memory and the streamed path wins both ways.
    ``repro.search.optimize._run_kernel`` picks between them on GLOBAL
    problem size. Values differ from the streamed path only by f32
    summation order — a gradient-guide difference the exact re-check
    absorbs.
    """
    from repro.kernels import ops     # late: keep repro.search importable
    carry_end, (_proc, _q, lat, cost, drop) = ops.policy_scan(
        loads_block, params_block, dt_hours=dt_hours,
        policy_index=policy_index, differentiable=True,
        surrogate=surrogate, caps=caps_block)
    limits, width = _obj_ops_lane(slo_limit_lane, slo_mode, tau)
    w = jnp.asarray(loads_block, jnp.float32)
    values = (drop / jnp.maximum(w, 1e-9)
              if slo_mode == AGG_SLO_DROP_RATE else lat)
    total = cost.sum(axis=1)
    backlog_cost = (carry_end[:, 0]
                    / jnp.maximum(params_block[:, 0], 1e-9) / 3600.0
                    * params_block[:, 1])
    cost_ann = (total + backlog_cost) * horizon_scale
    frac = smooth_met_fraction(values, w, limits, width[:, None])
    shortfall = met_fraction - frac
    hinge = jax.nn.softplus(shortfall / HINGE_S) * HINGE_S
    rel = (values - limits[:, None]) / width[:, None]
    excess = (tau * (jax.nn.softplus(rel) * w).sum(axis=1)
              / jnp.maximum(w.sum(axis=1), 1e-9))
    gate = jax.nn.sigmoid(shortfall / HINGE_S)
    penalty = penalty_weight * penalty_scale * (
        hinge + EXCESS_WEIGHT * gate * excess)
    return cost_ann + penalty, (cost_ann, frac)

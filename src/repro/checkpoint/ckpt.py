"""Fault-tolerant sharded checkpointing.

Layout: <dir>/step_<N>/  with one .npz per host-shard plus a manifest.
Writes go to a temp directory and are atomically renamed — a crash mid-write
can never corrupt the latest checkpoint (restart-safe). AsyncCheckpointer
snapshots to host memory synchronously (cheap) and writes on a background
thread so the train loop never blocks on storage.

Elastic restore: checkpoints store the *global* array layout, so a
checkpoint written on one mesh restores onto any other mesh/device-count
(``reshard_tree`` re-places global values under new shardings). This is the
mechanism behind elastic scaling: lose a pod, restart on half the mesh,
keep training.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree) -> Dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = leaf
    return out


def save_checkpoint(directory: str, step: int, tree, extra: Optional[Dict] = None):
    """Synchronous atomic checkpoint write."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {}
    manifest = {"step": step, "keys": [], "extra": extra or {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        name = f"a{i}"
        dtype = str(arr.dtype)
        if arr.dtype.kind not in "fiub?" or dtype == "bfloat16":
            # numpy's npz can't round-trip ml_dtypes (bf16 etc.) — store
            # widened; the manifest dtype restores the original.
            arrays[name] = arr.astype(np.float32)
        else:
            arrays[name] = arr
        manifest["keys"].append({"key": key, "name": name,
                                 "dtype": dtype,
                                 "shape": list(arr.shape)})
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):          # idempotent re-save of the same step
        shutil.rmtree(final)
    os.replace(tmp, final)             # atomic publish
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: Optional[int], like,
                       shardings=None):
    """Restore into the structure of ``like``; place under ``shardings``
    (a matching tree of NamedSharding) if given — this is the elastic
    reshard path when the mesh changed since the save."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    by_key = {e["key"]: data[e["name"]] for e in manifest["keys"]}

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings,
                                                is_leaf=lambda x: hasattr(x, "spec"))[0]
    leaves = []
    for i, (p, leaf) in enumerate(flat_like):
        key = jax.tree_util.keystr(p)
        arr = by_key[key]
        want_dtype = leaf.dtype
        val = jnp.asarray(arr, dtype=want_dtype)
        if shard_flat is not None:
            val = jax.device_put(val, shard_flat[i])
        leaves.append(val)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, step, manifest.get("extra", {})


def reshard_tree(tree, shardings):
    """Re-place a (restored or live) tree under new shardings — elastic
    mesh change without touching disk."""
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


class AsyncCheckpointer:
    """Snapshot-then-write-async. ``save`` returns once the host snapshot
    exists; the (slow) serialization happens on a worker thread. ``wait``
    drains pending writes (call before exit / before restore)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self.errors: list = []

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, extra = item
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except Exception as e:   # noqa: BLE001
                self.errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory,
                                       f"step_{s:08d}"), ignore_errors=True)

    def save(self, step: int, tree, extra: Optional[Dict] = None):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host_tree, extra))

    def wait(self):
        self._q.join()

    def close(self):
        self.wait()
        self._q.put(None)
        self._worker.join(timeout=5)

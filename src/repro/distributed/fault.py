"""Fault tolerance: straggler detection, retry wrapper, failure simulation.

On a real cluster the runtime signals node loss via exceptions from the
collective layer; here the same control flow is exercised through injected
``FaultInjector`` failures (tests) so the recovery paths are real even if
the failures are synthetic.

* ``StragglerWatchdog`` — the wind-tunnel spans double as a straggler
  detector: a stage whose latest duration exceeds k x rolling-median is
  flagged (the paper's per-stage latency view, used operationally).
* ``retry_step`` — retries a step through transient faults with exponential
  backoff; unrecoverable faults propagate to the restart-from-checkpoint
  path in the train loop.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.spans import SpanCollector


class TransientFault(RuntimeError):
    """Recoverable in-process (preemption notice, timeout, flaky link)."""


class NodeLoss(RuntimeError):
    """Unrecoverable without re-meshing: restart from checkpoint."""


@dataclass
class FaultInjector:
    """Deterministic fault schedule for tests/examples."""
    transient_at: tuple = ()
    node_loss_at: tuple = ()
    step: int = 0
    fired: List[str] = field(default_factory=list)

    def check(self):
        s = self.step
        self.step += 1
        if s in self.node_loss_at:
            self.fired.append(f"node_loss@{s}")
            raise NodeLoss(f"injected node loss at step {s}")
        if s in self.transient_at:
            self.fired.append(f"transient@{s}")
            raise TransientFault(f"injected transient fault at step {s}")


class StragglerWatchdog:
    """Flags pipeline stages whose latest span blew past the rolling median."""

    def __init__(self, collector: SpanCollector, factor: float = 3.0,
                 window: int = 32, min_samples: int = 8):
        self.collector = collector
        self.factor = factor
        self.window = window
        self.min_samples = min_samples

    def stragglers(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for name in self.collector.stage_names():
            spans = self.collector.spans(name)[-self.window:]
            if len(spans) < self.min_samples:
                continue
            durs = sorted(s.duration for s in spans[:-1])
            med = durs[len(durs) // 2]
            last = spans[-1].duration
            if med > 0 and last > self.factor * med:
                out[name] = {"last_s": last, "median_s": med,
                             "ratio": last / med}
        return out


def retry_step(fn: Callable, *args, retries: int = 3, backoff_s: float = 0.05,
               injector: Optional[FaultInjector] = None, **kw):
    """Run fn, retrying TransientFault with exponential backoff + jitter.
    NodeLoss propagates (handled by the checkpoint-restart layer)."""
    attempt = 0
    while True:
        try:
            if injector is not None:
                injector.check()
            return fn(*args, **kw)
        except TransientFault:
            attempt += 1
            if attempt > retries:
                raise
            time.sleep(backoff_s * (2 ** (attempt - 1))
                       * (1.0 + 0.1 * random.random()))

"""Elastic scaling: resume a run on a different mesh/device count.

Checkpoints store global (host) arrays, so elasticity is a placement
problem: rebuild shardings for the new mesh and device_put the restored
tree. ``elastic_restore`` is the one-call path used after losing (or
gaining) a pod: train state, optimizer state and data position all carry
over; only the layout changes.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.ckpt import restore_checkpoint
from repro.config import ModelConfig, OptimizerConfig, ParallelConfig
from repro.distributed.sharding import build_rules, mesh_shape_dict
from repro.models import model as M
from repro.optim.adamw import abstract_opt_state, init_opt_state, opt_state_specs


def state_shardings(cfg: ModelConfig, ocfg: OptimizerConfig,
                    parallel: ParallelConfig, mesh: Mesh):
    rules = build_rules(parallel, mesh)
    mshape = mesh_shape_dict(mesh)
    pspecs = M.partition_specs(cfg, rules, mshape)
    ospecs = opt_state_specs(pspecs, ocfg, M.abstract_params(cfg),
                             parallel.fsdp_axis or "data", mshape)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,  # noqa: E731
                                is_leaf=lambda x: isinstance(x, P))
    return ns(pspecs), ns(ospecs)


def elastic_restore(ckpt_dir: str, cfg: ModelConfig, ocfg: OptimizerConfig,
                    parallel: ParallelConfig, new_mesh: Mesh,
                    step: Optional[int] = None):
    """Restore the latest checkpoint onto ``new_mesh`` (any device count
    whose axis sizes still divide the sharded dims — non-divisible dims
    fall back to replication automatically)."""
    params_like = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_like = init_opt_state(params_like, ocfg)
    pshard, oshard = state_shardings(cfg, ocfg, parallel, new_mesh)
    (params, opt_state), step_r, extra = restore_checkpoint(
        ckpt_dir, step, (params_like, opt_like), (pshard, oshard))
    return params, opt_state, step_r, extra

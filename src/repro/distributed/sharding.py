"""Logical-axis -> mesh-axis rule table (MaxText-style).

Every parameter/cache leaf declares logical axes in its schema; these rules
map them onto the production mesh. Rules fall back to replication when a
dim is not divisible by the mesh axis (specs_from_schema), so a single
rule table serves all ten architectures — the per-arch hillclimb
overrides live in ParallelConfig. Each distinct fall-back emits a
one-time ``RuntimeWarning`` naming the axis and sizes, so lost
parallelism is visible instead of silent.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ParallelConfig

# replication fall-backs already reported, keyed by (where, axis, dim,
# size) — falling back is the designed behavior (one rule table serves
# every architecture), but doing it SILENTLY hides lost parallelism, so
# each distinct fall-back warns exactly once per process
_REPLICATION_WARNED = set()


def _warn_replicated(where: str, axis, dim: int, size: int):
    # the obs counter bumps on EVERY fall-back (that's what a counter
    # is for); the Python warning below stays once-per-process
    from repro import obs
    obs.event("warn.replication_fallback", where=where, axis=str(axis))
    key = (where, str(axis), int(dim), int(size))
    if key in _REPLICATION_WARNED:
        return
    _REPLICATION_WARNED.add(key)
    warnings.warn(
        f"{where}: dim {dim} is not divisible by mesh axis {axis!r} "
        f"(size {size}); falling back to replication — this dimension "
        f"gets NO parallelism. Pad the dim to a multiple of {size} or "
        f"shrink the mesh axis to recover it.",
        RuntimeWarning, stacklevel=3)


def resolve_mesh_axis(devices, dim: int, where: str,
                      axis: str = "restart") -> Optional[int]:
    """Validate a user-facing ``devices=`` request and return the mesh
    size to build, or ``None`` to run unsharded.

    ``None``/1 asks for no mesh; non-positive or more-devices-than-
    visible raise (the latter naming the ``XLA_FLAGS`` host-device trick,
    same message family as ``simulate_grid``); a ``dim`` that doesn't
    divide the mesh falls back to the unsharded path with the same
    warn-once replication ``RuntimeWarning`` the rule table emits — lost
    parallelism is visible, never silent, and results are identical
    either way (the sharded kernels are bit-identical by construction).
    """
    if devices is None or devices == 1:
        return None
    devices = int(devices)
    if devices <= 0:
        raise ValueError(f"devices must be a positive mesh size, "
                         f"got {devices}")
    if devices > jax.device_count():
        raise ValueError(
            f"devices={devices} but only {jax.device_count()} "
            f"JAX device(s) are visible; on CPU export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{devices} before the first jax import")
    if dim % devices != 0:
        _warn_replicated(where, axis, dim, devices)
        return None
    return devices


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
              axis_names=None):
    """Version-compat ``shard_map``: the top-level ``jax.shard_map`` API
    (jax >= 0.6, with ``check_vma`` / ``axis_names``) when available, else
    ``jax.experimental.shard_map.shard_map`` with the kwargs translated
    (``check_vma`` -> ``check_rep``; ``axis_names`` -> the complement
    ``auto`` set). Use this everywhere instead of either spelling.

    Body contract: keep everything in-graph. Host callbacks
    (``io_callback`` / ``pure_callback``) inside a sharded body serialize
    multi-device dispatch and can deadlock it outright — the aggregate
    grid's round step (``core.simulate._sharded_agg_fn``) was once built
    AROUND that constraint, draining per-round latency panels to the host
    for binning; its histogram now accumulates in-body on device, so the
    constraint costs nothing. Device-wide reductions (f64 ``segment_sum``
    included) are fine in-body; only sharded-in/sharded-out data flow
    crosses the boundary."""
    if hasattr(jax, "shard_map"):
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def mesh_shape_dict(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def build_rules(parallel: ParallelConfig, mesh: Mesh) -> Dict[str, Optional[str]]:
    axes = set(mesh.axis_names)

    def ax(a):
        return a if a in axes else None

    batch = tuple(a for a in parallel.batch_axes if a in axes)
    seq_ax = parallel.seq_axis
    if isinstance(seq_ax, tuple):
        seq_ax = tuple(a for a in seq_ax if a in axes) or None
    else:
        seq_ax = ax(seq_ax)
    return {
        "vocab": ax(parallel.tp_axis),
        "embed": ax(parallel.fsdp_axis),
        "mlp": ax(parallel.tp_axis),
        "heads": ax(parallel.tp_axis),
        "kv": ax(parallel.tp_axis),
        "kv_heads": ax(parallel.tp_axis),
        "expert": ax(parallel.expert_axis),
        "batch": batch if batch else None,
        "cache_seq": seq_ax if parallel.shard_cache_seq else None,
        # activation-only logical axes (constrain() checks divisibility)
        "heads_act": ax(parallel.tp_axis),
        "kv_heads_act": ax(parallel.tp_axis),
        "vocab_act": ax(parallel.tp_axis),
        "mlp_act": ax(parallel.tp_axis),
        "expert_act": ax(parallel.expert_axis),
        "seq_act": None,   # sequence parallelism (hillclimb override)
        # small/replicated dims
        "rank": None, "state": None, "conv": None, "norm": None,
        "layers": None, "groups": None,
    }


def batch_partition(parallel: ParallelConfig, mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in parallel.batch_axes if a in set(mesh.axis_names))


# ---------------------------------------------------------------------------
# Activation sharding constraints
#
# GSPMD's propagation is a global solve: without anchors it may pick
# different activation layouts for near-identical programs (observed:
# 1-group vs 2-group probes sharding attention differently). Model code
# calls ``constrain(x, ...logical axes)``; the step factories install the
# mesh + rules here before tracing. No-op when nothing is installed, so
# model code stays mesh-free.
# ---------------------------------------------------------------------------

_ACT = {"mesh": None, "rules": None}


def set_activation_mesh(mesh: Optional[Mesh], rules: Optional[Dict] = None):
    _ACT["mesh"] = mesh
    _ACT["rules"] = rules


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical axis names (None = replicated).
    Drops any axis whose dim is not divisible by the mesh axis size."""
    mesh, rules = _ACT["mesh"], _ACT["rules"]
    if mesh is None or rules is None:
        return x
    import jax
    shape_d = mesh_shape_dict(mesh)
    spec, used = [], set()
    for dim, ax in zip(x.shape, logical_axes):
        mesh_ax = rules.get(ax) if ax is not None else None
        if mesh_ax is None:
            spec.append(None)
            continue
        axes_t = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
        if any(a in used for a in axes_t):
            spec.append(None)
            continue
        size = 1
        for a in axes_t:
            size *= shape_d.get(a, 1)
        if size > 1 and dim % size == 0:
            spec.append(mesh_ax)
            used.update(axes_t)
        else:
            if size > 1:
                _warn_replicated(f"constrain(logical axis {ax!r})",
                                 mesh_ax, dim, size)
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def input_batch_specs(batch_abstract: Dict, parallel: ParallelConfig,
                      mesh: Mesh) -> Dict:
    """PartitionSpecs for a model input batch: shard dim 0 (batch) over the
    dp axes when divisible; positions [3, b, s] shard dim 1."""
    dp = batch_partition(parallel, mesh)
    size = 1
    for a in dp:
        size *= mesh_shape_dict(mesh)[a]
    out = {}
    for k, v in batch_abstract.items():
        if k == "positions" and len(v.shape) == 3:
            if v.shape[1] % size == 0:
                out[k] = P(None, dp, None)
            else:
                if size > 1:
                    _warn_replicated(f"input_batch_specs({k!r})", dp,
                                     v.shape[1], size)
                out[k] = P()
        elif v.ndim >= 1 and v.shape[0] % size == 0 and size > 1:
            out[k] = P(dp, *([None] * (v.ndim - 1)))
        else:
            if size > 1 and v.ndim >= 1:
                _warn_replicated(f"input_batch_specs({k!r})", dp,
                                 v.shape[0], size)
            out[k] = P()
    return out

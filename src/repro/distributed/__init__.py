from repro.distributed.sharding import (  # noqa: F401
    build_rules, mesh_shape_dict, batch_partition,
)

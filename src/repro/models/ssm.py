"""Mamba-1 selective-SSM block (jamba's recurrent layer).

Structure per Gu & Dao 2023 / Jamba 2024: in_proj -> (x, z) gate split,
depthwise causal conv1d + silu on x, input-dependent (dt, B, C) via x_proj,
softplus dt with dt_proj, diagonal A = -exp(A_log), selective scan
(ops.ssm_scan -> Pallas kernel or jnp oracle), gated output, out_proj.

Serve state per layer: {conv: [b, d_conv-1, d_inner], ssm: [b, d_inner, n]}.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models.layers import ParamDef, Params, Schema

State = Dict[str, jnp.ndarray]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or max(1, math.ceil(cfg.d_model / 16))
    return d_inner, dt_rank, s.d_state


def mamba_schema(cfg: ModelConfig, name: str) -> Schema:
    s = cfg.ssm
    d = cfg.d_model
    di, dtr, n = _dims(cfg)
    return {
        f"{name}.in_proj": ParamDef((d, 2 * di), ("embed", "heads")),
        f"{name}.conv_w": ParamDef((s.d_conv, di), ("conv", "heads"), "small"),
        f"{name}.conv_b": ParamDef((di,), ("heads",), "zeros"),
        f"{name}.x_proj": ParamDef((di, dtr + 2 * n), ("heads", "rank")),
        f"{name}.dt_proj": ParamDef((dtr, di), ("rank", "heads"), "small"),
        f"{name}.dt_bias": ParamDef((di,), ("heads",), "zeros"),
        f"{name}.A_log": ParamDef((di, n), ("heads", "state"), "ones"),
        f"{name}.D": ParamDef((di,), ("heads",), "ones"),
        f"{name}.out_proj": ParamDef((di, d), ("heads", "embed")),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 prev: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x [b, s, di], w [k, di]. Returns (y, new_buffer)."""
    k = w.shape[0]
    if prev is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = prev.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                     # [b, s+k-1, di]
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    y = y + b[None, None]
    new_buf = xp[:, -(k - 1):] if k > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return y, new_buf


def apply_mamba(params: Params, name: str, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[State] = None) -> Tuple[jnp.ndarray, Optional[State]]:
    di, dtr, n = _dims(cfg)
    b, s, d = x.shape
    dt_ = x.dtype

    xz = jnp.einsum("bsd,de->bse", x, params[f"{name}.in_proj"].astype(dt_))
    xs, z = xz[..., :di], xz[..., di:]

    decode = state is not None and state.get("decode", False)
    conv_prev = state["conv"] if decode else None
    xs, conv_buf = _causal_conv(xs, params[f"{name}.conv_w"].astype(dt_),
                                params[f"{name}.conv_b"].astype(dt_), conv_prev)
    xs = jax.nn.silu(xs)

    proj = jnp.einsum("bsi,ir->bsr", xs, params[f"{name}.x_proj"].astype(dt_))
    dt_raw, B, C = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_raw, params[f"{name}.dt_proj"].astype(dt_))
        .astype(jnp.float32) + params[f"{name}.dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params[f"{name}.A_log"].astype(jnp.float32))   # [di, n]

    ssm_prev = state["ssm"] if decode else None
    y, new_ssm = ops.ssm_scan(xs, dt.astype(dt_), A, B, C,
                              params[f"{name}.D"].astype(jnp.float32), ssm_prev)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, params[f"{name}.out_proj"].astype(dt_))

    if state is not None:
        state = dict(state, conv=conv_buf, ssm=new_ssm)
    return out, state


def mamba_state_schema(cfg: ModelConfig, name: str, batch: int) -> Schema:
    s = cfg.ssm
    di, _, n = _dims(cfg)
    return {
        f"{name}.conv": ParamDef((batch, s.d_conv - 1, di),
                                 ("batch", None, "heads"), "zeros"),
        f"{name}.ssm": ParamDef((batch, di, n), ("batch", "heads", "state"), "zeros"),
    }

"""Attention blocks: GQA (MHA/MQA special cases), MLA, cross-attention.

Caches are dicts of arrays sized to the full serve context; decode writes
new K/V at per-sequence positions and masks by valid length. MLA caches the
*compressed latent* (kv_lora + rope dims) and uses the absorbed-matmul
formulation at decode so the per-step cost is O(S * (r + rope) * H).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import AttentionConfig, ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.models.layers import ParamDef, Params, Schema, apply_rope

Cache = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_schema(cfg: ModelConfig, name: str, cross: bool = False) -> Schema:
    a = cfg.attention
    d = cfg.d_model
    s: Schema = {
        f"{name}.wq": ParamDef((d, a.num_heads * a.head_dim), ("embed", "heads")),
        f"{name}.wk": ParamDef((d, a.num_kv_heads * a.head_dim), ("embed", "kv")),
        f"{name}.wv": ParamDef((d, a.num_kv_heads * a.head_dim), ("embed", "kv")),
        f"{name}.wo": ParamDef((a.num_heads * a.head_dim, d), ("heads", "embed")),
    }
    return s


def _write_kv(cache_k, cache_v, k_new, v_new, pos):
    """Write k_new [b, t, kh, hd] into cache at per-batch offsets pos [b]."""
    def upd(ck, cv, kn, vn, p):
        ck = jax.lax.dynamic_update_slice_in_dim(ck, kn.astype(ck.dtype), p, axis=0)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, vn.astype(cv.dtype), p, axis=0)
        return ck, cv
    return jax.vmap(upd)(cache_k, cache_v, k_new, v_new, pos)


def apply_gqa(params: Params, name: str, x: jnp.ndarray,
              positions: jnp.ndarray, cfg: ModelConfig,
              cache: Optional[Cache] = None,
              memory: Optional[jnp.ndarray] = None,
              causal: Optional[bool] = None,
              is_cross: bool = False) -> Tuple[jnp.ndarray, Optional[Cache]]:
    """x: [b, t, d]. Train/prefill: t == full seq, cache built if requested.
    Decode: t == 1 (or small), cache holds k/v + per-seq lengths.
    memory: encoder output for cross-attention (whisper)."""
    a = cfg.attention
    b, t, _ = x.shape
    dt = x.dtype
    q = jnp.einsum("btd,dk->btk", x, params[f"{name}.wq"].astype(dt))
    q = q.reshape(b, t, a.num_heads, a.head_dim)
    causal = a.causal if causal is None else causal

    if is_cross or memory is not None:
        # cross-attention: K/V from encoder memory; computed at prefill,
        # reused from the cache at decode (memory is None then).
        if memory is None:
            assert cache is not None and cache.get("decode", False)
            k, v = cache["k"].astype(dt), cache["v"].astype(dt)
        else:
            k = jnp.einsum("bsd,dk->bsk", memory, params[f"{name}.wk"].astype(dt))
            v = jnp.einsum("bsd,dk->bsk", memory, params[f"{name}.wv"].astype(dt))
            k = k.reshape(b, -1, a.num_kv_heads, a.head_dim)
            v = v.reshape(b, -1, a.num_kv_heads, a.head_dim)
            if cache is not None:
                cache = dict(cache)
                cache["k"], cache["v"] = k, v
        out = ops.sdpa(q, k, v, causal=False, logit_cap=a.logit_cap)
        out = out.reshape(b, t, -1)
        return jnp.einsum("btk,kd->btd", out, params[f"{name}.wo"].astype(dt)), cache

    k = jnp.einsum("btd,dk->btk", x, params[f"{name}.wk"].astype(dt))
    v = jnp.einsum("btd,dk->btk", x, params[f"{name}.wv"].astype(dt))
    k = k.reshape(b, t, a.num_kv_heads, a.head_dim)
    v = v.reshape(b, t, a.num_kv_heads, a.head_dim)
    if a.rope != "none":
        q = apply_rope(q, positions, a)
        k = apply_rope(k, positions, a)
    q = constrain(q, "batch", None, "heads_act", None)
    k = constrain(k, "batch", None, "kv_heads_act", None)
    v = constrain(v, "batch", None, "kv_heads_act", None)

    if cache is not None and cache.get("decode", False):
        pos = cache["length"]                                   # [b] int32
        ck, cv = _write_kv(cache["k"], cache["v"], k, v, pos)
        new_len = pos + t
        out = ops.sdpa(q, ck.astype(dt), cv.astype(dt), causal=False,
                       logit_cap=a.logit_cap, kv_len=new_len)
        cache = dict(cache, k=ck, v=cv, length=new_len)
    else:
        out = ops.sdpa(q, k, v, causal=causal, logit_cap=a.logit_cap)
        if cache is not None:                                   # prefill fill
            ck, cv = _write_kv(cache["k"], cache["v"], k, v,
                               jnp.zeros((b,), jnp.int32))
            cache = dict(cache, k=ck, v=cv,
                         length=jnp.full((b,), t, jnp.int32))
    out = out.reshape(b, t, -1)
    return jnp.einsum("btk,kd->btd", out, params[f"{name}.wo"].astype(dt)), cache


def gqa_cache_schema(cfg: ModelConfig, name: str, batch: int, max_len: int,
                     cross: bool = False) -> Schema:
    a = cfg.attention
    s_len = cfg.encoder_seq if cross else max_len
    return {
        f"{name}.k": ParamDef((batch, s_len, a.num_kv_heads, a.head_dim),
                              ("batch", "cache_seq", "kv_heads", None), "zeros"),
        f"{name}.v": ParamDef((batch, s_len, a.num_kv_heads, a.head_dim),
                              ("batch", "cache_seq", "kv_heads", None), "zeros"),
    }


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_schema(cfg: ModelConfig, name: str) -> Schema:
    a = cfg.attention
    d = cfg.d_model
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    s: Schema = {}
    if a.q_lora_rank > 0:
        s[f"{name}.wq_a"] = ParamDef((d, a.q_lora_rank), ("embed", "rank"))
        s[f"{name}.q_norm"] = ParamDef((a.q_lora_rank,), ("rank",), "ones")
        s[f"{name}.wq_b"] = ParamDef((a.q_lora_rank, a.num_heads * qk), ("rank", "heads"))
    else:
        s[f"{name}.wq"] = ParamDef((d, a.num_heads * qk), ("embed", "heads"))
    s[f"{name}.wkv_a"] = ParamDef((d, a.kv_lora_rank + a.qk_rope_head_dim),
                                  ("embed", "rank"))
    s[f"{name}.kv_norm"] = ParamDef((a.kv_lora_rank,), ("rank",), "ones")
    s[f"{name}.wkv_b"] = ParamDef(
        (a.kv_lora_rank, a.num_heads * (a.qk_nope_head_dim + a.v_head_dim)),
        ("rank", "heads"))
    s[f"{name}.wo"] = ParamDef((a.num_heads * a.v_head_dim, d), ("heads", "embed"))
    return s


def _rms(x, scale, eps):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _mla_q(params, name, x, positions, a: AttentionConfig, eps):
    b, t, _ = x.shape
    dt = x.dtype
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    if a.q_lora_rank > 0:
        ql = jnp.einsum("btd,dr->btr", x, params[f"{name}.wq_a"].astype(dt))
        ql = _rms(ql, params[f"{name}.q_norm"], eps)
        q = jnp.einsum("btr,rk->btk", ql, params[f"{name}.wq_b"].astype(dt))
    else:
        q = jnp.einsum("btd,dk->btk", x, params[f"{name}.wq"].astype(dt))
    q = q.reshape(b, t, a.num_heads, qk)
    q_nope, q_rope = q[..., :a.qk_nope_head_dim], q[..., a.qk_nope_head_dim:]
    rope_cfg = AttentionConfig(rope="standard", rope_theta=a.rope_theta)
    q_rope = apply_rope(q_rope, positions, rope_cfg)
    return q_nope, q_rope


def apply_mla(params: Params, name: str, x: jnp.ndarray,
              positions: jnp.ndarray, cfg: ModelConfig,
              cache: Optional[Cache] = None) -> Tuple[jnp.ndarray, Optional[Cache]]:
    a = cfg.attention
    b, t, _ = x.shape
    dt = x.dtype
    eps = cfg.norm_eps
    n_nope, n_rope, n_v = a.qk_nope_head_dim, a.qk_rope_head_dim, a.v_head_dim
    scale = (n_nope + n_rope) ** -0.5
    rope_cfg = AttentionConfig(rope="standard", rope_theta=a.rope_theta)

    kv_a = jnp.einsum("btd,dr->btr", x, params[f"{name}.wkv_a"].astype(dt))
    ckv = _rms(kv_a[..., :a.kv_lora_rank], params[f"{name}.kv_norm"], eps)
    k_rope = kv_a[..., None, a.kv_lora_rank:]                    # [b,t,1,rope]
    k_rope = apply_rope(k_rope, positions, rope_cfg)
    q_nope, q_rope = _mla_q(params, name, x, positions, a, eps)

    wkv_b = params[f"{name}.wkv_b"].astype(dt).reshape(
        a.kv_lora_rank, a.num_heads, n_nope + n_v)
    wk_b, wv_b = wkv_b[..., :n_nope], wkv_b[..., n_nope:]        # [r,h,n],[r,h,v]

    if cache is not None and cache.get("decode", False):
        pos = cache["length"]
        cckv, ckr = _write_kv(cache["ckv"][..., None], cache["k_rope"],
                              ckv[..., None], k_rope, pos)
        cckv = cckv[..., 0]
        new_len = pos + t
        # absorbed decode: scores over the latent directly
        q_abs = jnp.einsum("bthn,rhn->bthr", q_nope, wk_b)       # [b,t,h,r]
        logits = (jnp.einsum("bthr,bsr->bhts", q_abs.astype(jnp.float32),
                             cckv.astype(jnp.float32))
                  + jnp.einsum("bthn,bsn->bhts", q_rope.astype(jnp.float32),
                               ckr[:, :, 0].astype(jnp.float32))) * scale
        valid = jnp.arange(cckv.shape[1])[None, :] < new_len[:, None]
        logits = jnp.where(valid[:, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out_lat = jnp.einsum("bhts,bsr->bthr", probs.astype(dt), cckv.astype(dt))
        out = jnp.einsum("bthr,rhv->bthv", out_lat, wv_b)
        cache = dict(cache, ckv=cckv, k_rope=ckr, length=new_len)
    else:
        # expanded prefill
        kv = jnp.einsum("btr,rhn->bthn", ckv, wkv_b)             # [b,t,h,nope+v]
        k_nope, v = kv[..., :n_nope], kv[..., n_nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, t, a.num_heads, n_rope))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        out = ops.sdpa(q, k, v, causal=a.causal, scale=scale)
        if cache is not None:
            cckv, ckr = _write_kv(cache["ckv"][..., None], cache["k_rope"],
                                  ckv[..., None], k_rope,
                                  jnp.zeros((b,), jnp.int32))
            cache = dict(cache, ckv=cckv[..., 0], k_rope=ckr,
                         length=jnp.full((b,), t, jnp.int32))
    out = out.reshape(b, t, a.num_heads * n_v)
    return jnp.einsum("btk,kd->btd", out, params[f"{name}.wo"].astype(dt)), cache


def mla_cache_schema(cfg: ModelConfig, name: str, batch: int, max_len: int) -> Schema:
    a = cfg.attention
    return {
        f"{name}.ckv": ParamDef((batch, max_len, a.kv_lora_rank),
                                ("batch", "cache_seq", None), "zeros"),
        f"{name}.k_rope": ParamDef((batch, max_len, 1, a.qk_rope_head_dim),
                                   ("batch", "cache_seq", None, None), "zeros"),
    }

from repro.models.model import (  # noqa: F401
    abstract_params,
    init_params,
    loss_fn,
    partition_specs,
    prefill,
    decode_step,
    init_cache,
    abstract_cache,
    cache_partition_specs,
)

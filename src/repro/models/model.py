"""Top-level model API: schemas, init, loss, prefill, decode.

Uniform batch convention across all ten architectures:
  train/prefill: {"tokens": [b, s_text] i32, "positions": [b, s] or [3, b, s],
                  "loss_mask": [b, s] (train only),
                  "embeds":  [b, n_patch, d]  (vlm frontend stub, optional),
                  "frames":  [b, enc_seq, d]  (audio frontend stub, optional)}
  decode:        {"token": [b, 1] i32}  + cache (holds per-seq lengths)

The modality frontends are stubs per the assignment: ``input_specs`` provides
precomputed patch/frame embeddings at model width.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import AttentionConfig, ModelConfig
from repro.models import transformer as tf
from repro.models.layers import (ParamDef, Params, Schema,
                                 abstract_from_schema, apply_norm,
                                 embed_schema, embed_tokens,
                                 init_from_schema, norm_schema,
                                 sinusoidal_embed, specs_from_schema,
                                 unembed)


def encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg,
        num_layers=cfg.encoder_layers,
        block_pattern=("attn",),
        attention=dataclasses.replace(cfg.attention, causal=False, rope="none"),
        moe=None, moe_every=0)


def full_schema(cfg: ModelConfig) -> Schema:
    s: Schema = {}
    s.update(embed_schema(cfg))
    s.update(tf.stack_params_schema(cfg, "stack", cross=cfg.encdec))
    s.update(norm_schema(cfg, "final_norm"))
    if cfg.encdec:
        ecfg = encoder_cfg(cfg)
        s.update(tf.stack_params_schema(ecfg, "encoder"))
        s.update(norm_schema(ecfg, "encoder_norm"))
    return s


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    return init_from_schema(full_schema(cfg), key, jnp.dtype(cfg.param_dtype))


def abstract_params(cfg: ModelConfig) -> Params:
    return abstract_from_schema(full_schema(cfg), jnp.dtype(cfg.param_dtype))


def partition_specs(cfg: ModelConfig, rules: Dict[str, Optional[str]],
                    mesh_shape: Dict[str, int]):
    return specs_from_schema(full_schema(cfg), rules, mesh_shape)


def param_count(cfg: ModelConfig) -> int:
    total = 0
    for d in full_schema(cfg).values():
        n = 1
        for dim in d.shape:
            n *= dim
        total += n
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE counts top_k + shared experts)."""
    if cfg.moe is None:
        return param_count(cfg)
    total = 0
    m = cfg.moe
    for name, d in full_schema(cfg).items():
        n = 1
        for dim in d.shape:
            n *= dim
        if ".moe.w_" in name:          # routed expert weights
            n = n * (m.top_k / m.num_experts)
        total += int(n)
    return total


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _default_positions(cfg: ModelConfig, b: int, s: int, offset=0):
    pos = jnp.arange(s, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.attention.rope == "mrope":
        return jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def _encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    ecfg = encoder_cfg(cfg)
    b, s, _ = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoidal_embed(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    pos = _default_positions(ecfg, b, s)
    x, _, _ = tf.apply_stack(params, ecfg, x, pos, prefix="encoder")
    return apply_norm(params, "encoder_norm", x, ecfg)


def _embed_inputs(params: Params, cfg: ModelConfig, batch: Dict) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[jnp.ndarray]]:
    """Returns (x [b, s, d], positions, memory or None)."""
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    memory = None
    if cfg.frontend == "vision" and "embeds" in batch:
        emb = batch["embeds"].astype(x.dtype)
        x = jnp.concatenate([emb, x], axis=1)
    if cfg.encdec:
        memory = _encode(params, cfg, batch["frames"])
        # whisper-style absolute positions on decoder tokens
        x = x + sinusoidal_embed(jnp.arange(x.shape[1]), cfg.d_model)[None].astype(x.dtype)
    b, s, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, b, s)
    return x, positions, memory


def forward(params: Params, cfg: ModelConfig, batch: Dict,
            cache: Optional[Params] = None, decode: bool = False
            ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """Full forward. Returns (logits, new_cache, aux_loss)."""
    if decode:
        tokens = batch["token"]
        b, t = tokens.shape
        x = embed_tokens(params, tokens, cfg)
        if cfg.encdec:
            # absolute position at the current per-sequence length
            pos_emb = sinusoidal_embed(cache["length"].astype(jnp.float32),
                                       cfg.d_model)                 # [b, d]
            x = x + pos_emb[:, None].astype(x.dtype)
        lengths = cache["length"]
        positions = lengths[:, None].astype(jnp.int32)
        if cfg.attention.rope == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, b, t))
        stack_cache = {k[len("stack."):]: v for k, v in cache.items()
                       if k.startswith("stack.")}
        y, new_sc, aux = tf.apply_stack(params, cfg, x, positions,
                                        cache=stack_cache, decode=True,
                                        lengths=lengths, prefix="stack",
                                        cross=cfg.encdec)
        new_cache = {f"stack.{k}": v for k, v in new_sc.items()}
        new_cache["length"] = lengths + t
    else:
        x, positions, memory = _embed_inputs(params, cfg, batch)
        lengths = None
        stack_cache = None
        if cache is not None:
            stack_cache = {k[len("stack."):]: v for k, v in cache.items()
                           if k.startswith("stack.")}
            lengths = cache["length"]
        y, new_sc, aux = tf.apply_stack(params, cfg, x, positions,
                                        cache=stack_cache, decode=False,
                                        memory=memory, lengths=lengths,
                                        prefix="stack", cross=cfg.encdec)
        new_cache = None
        if new_sc is not None:
            new_cache = {f"stack.{k}": v for k, v in new_sc.items()}
            new_cache["length"] = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    y = apply_norm(params, "final_norm", y, cfg)
    logits = unembed(params, y, cfg)
    return logits, new_cache, aux


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
    logits, _, aux = forward(params, cfg, batch)
    # align: if vlm frontend prepended patches, only score token positions
    n_text = batch["tokens"].shape[1]
    logits = logits[:, -n_text:]
    targets = batch["tokens"][:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(targets, jnp.float32) if mask is None else \
        mask[:, -n_text:][:, 1:].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom + aux
    metrics = {"loss": loss, "nll": nll.sum() / denom, "aux": aux,
               "tokens": denom}
    return loss, metrics


# ---------------------------------------------------------------------------
# Serve caches
# ---------------------------------------------------------------------------

def cache_schema(cfg: ModelConfig, batch: int, max_len: int) -> Schema:
    s = tf.stack_cache_schema(cfg, batch, max_len, "stack", cross=cfg.encdec)
    s["length"] = ParamDef((batch,), ("batch",), "zeros")
    return s


def _cache_dtype(cfg: ModelConfig, name: str):
    if name == "length":
        return jnp.int32
    # recurrent states carry long-horizon accumulators -> fp32
    if name.endswith(".wkv") or name.endswith(".ssm"):
        return jnp.float32
    return jnp.dtype(cfg.dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    sch = cache_schema(cfg, batch, max_len)
    return {name: jnp.zeros(d.shape, _cache_dtype(cfg, name))
            for name, d in sch.items()}


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    sch = cache_schema(cfg, batch, max_len)
    return {name: jax.ShapeDtypeStruct(d.shape, _cache_dtype(cfg, name))
            for name, d in sch.items()}


def cache_partition_specs(cfg: ModelConfig, batch: int, max_len: int,
                          rules: Dict[str, Optional[str]],
                          mesh_shape: Dict[str, int]):
    return specs_from_schema(cache_schema(cfg, batch, max_len), rules, mesh_shape)


def prefill(params: Params, cfg: ModelConfig, batch: Dict, cache: Params
            ) -> Tuple[jnp.ndarray, Params]:
    logits, new_cache, _ = forward(params, cfg, batch, cache=cache, decode=False)
    return logits[:, -1:], new_cache


def decode_step(params: Params, cfg: ModelConfig, cache: Params, batch: Dict
                ) -> Tuple[jnp.ndarray, Params]:
    logits, new_cache, _ = forward(params, cfg, batch, cache=cache, decode=True)
    return logits, new_cache

"""RWKV-6 "Finch" blocks: time-mix with data-dependent decay + channel-mix.

Faithful structure per arXiv:2404.05892: token-shift interpolation with
data-dependent mix coefficients (LoRA-produced), per-channel decay
w_t = exp(-exp(w0 + lora(x))), bonus u, per-head groupnorm on the WKV
output, and squared-relu channel mix. The WKV recurrence itself lives in
kernels (ops.rwkv6_scan -> Pallas kernel or jnp oracle).

Serve state per layer: {x_att, x_ffn: [b, d] previous token activations;
wkv: [b, H, n, n] recurrent state}.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models.layers import ParamDef, Params, Schema

State = Dict[str, jnp.ndarray]
MIXES = 5  # r, w, k, v, g


def rwkv_schema(cfg: ModelConfig, name: str) -> Schema:
    r = cfg.rwkv
    d = cfg.d_model
    H = d // r.head_dim
    s: Schema = {
        # token-shift data-dependent mixing
        f"{name}.maa_x": ParamDef((d,), ("norm",), "zeros"),
        f"{name}.maa_base": ParamDef((MIXES, d), (None, "norm"), "zeros"),
        f"{name}.maa_w1": ParamDef((d, MIXES * r.mix_lora), ("embed", "rank"), "small"),
        f"{name}.maa_w2": ParamDef((MIXES, r.mix_lora, d), (None, "rank", "embed"), "small"),
        # data-dependent decay
        f"{name}.decay_base": ParamDef((d,), ("norm",), "zeros"),
        f"{name}.decay_w1": ParamDef((d, r.decay_lora), ("embed", "rank"), "small"),
        f"{name}.decay_w2": ParamDef((r.decay_lora, d), ("rank", "embed"), "small"),
        f"{name}.bonus": ParamDef((H, r.head_dim), ("kv_heads", None), "small"),
        # projections
        f"{name}.wr": ParamDef((d, d), ("embed", "heads")),
        f"{name}.wk": ParamDef((d, d), ("embed", "heads")),
        f"{name}.wv": ParamDef((d, d), ("embed", "heads")),
        f"{name}.wg": ParamDef((d, d), ("embed", "heads")),
        f"{name}.wo": ParamDef((d, d), ("heads", "embed")),
        # per-head groupnorm
        f"{name}.ln_x.scale": ParamDef((d,), ("norm",), "ones"),
        f"{name}.ln_x.bias": ParamDef((d,), ("norm",), "zeros"),
    }
    return s


def channel_mix_schema(cfg: ModelConfig, name: str) -> Schema:
    d, f = cfg.d_model, cfg.d_ff
    return {
        f"{name}.mix_k": ParamDef((d,), ("norm",), "zeros"),
        f"{name}.mix_r": ParamDef((d,), ("norm",), "zeros"),
        f"{name}.wk": ParamDef((d, f), ("embed", "mlp")),
        f"{name}.wr": ParamDef((d, d), ("embed", "heads")),
        f"{name}.wv": ParamDef((f, d), ("mlp", "embed")),
    }


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """[b, s, d] -> previous-token x; position 0 uses `prev` (or zeros)."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def apply_time_mix(params: Params, name: str, x: jnp.ndarray, cfg: ModelConfig,
                   state: Optional[State] = None) -> Tuple[jnp.ndarray, Optional[State]]:
    r_cfg = cfg.rwkv
    b, s, d = x.shape
    dt = x.dtype
    H, n = d // r_cfg.head_dim, r_cfg.head_dim

    prev = state["x_att"] if state is not None and state.get("decode", False) else None
    xs = _token_shift(x, prev)
    dx = xs - x
    # data-dependent mix coefficients
    xx = x + dx * params[f"{name}.maa_x"].astype(dt)
    lora = jnp.einsum("bsd,dr->bsr", xx, params[f"{name}.maa_w1"].astype(dt))
    lora = jnp.tanh(lora).reshape(b, s, MIXES, r_cfg.mix_lora)
    mix = params[f"{name}.maa_base"].astype(dt)[None, None] + jnp.einsum(
        "bsmr,mrd->bsmd", lora, params[f"{name}.maa_w2"].astype(dt))
    xr, xw, xk, xv, xg = [x + dx * mix[:, :, i] for i in range(MIXES)]

    rr = jnp.einsum("bsd,dk->bsk", xr, params[f"{name}.wr"].astype(dt)).reshape(b, s, H, n)
    kk = jnp.einsum("bsd,dk->bsk", xk, params[f"{name}.wk"].astype(dt)).reshape(b, s, H, n)
    vv = jnp.einsum("bsd,dk->bsk", xv, params[f"{name}.wv"].astype(dt)).reshape(b, s, H, n)
    gg = jnp.einsum("bsd,dk->bsk", xg, params[f"{name}.wg"].astype(dt))

    # data-dependent decay in (0, 1)
    dlora = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, params[f"{name}.decay_w1"].astype(dt)))
    decay_log = params[f"{name}.decay_base"].astype(jnp.float32) + jnp.einsum(
        "bsr,rd->bsd", dlora.astype(jnp.float32), params[f"{name}.decay_w2"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(decay_log)).reshape(b, s, H, n)

    wkv_state = state["wkv"] if state is not None and state.get("decode", False) else None
    u = params[f"{name}.bonus"].astype(jnp.float32)
    out, new_wkv = ops.rwkv6_scan(rr, kk, vv, w.astype(rr.dtype), u, wkv_state)

    # per-head groupnorm then gate
    o = out.reshape(b, s, H, n).astype(jnp.float32)
    mean = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = ((o - mean) * jax.lax.rsqrt(var + 64e-5)).reshape(b, s, d)
    o = o * params[f"{name}.ln_x.scale"].astype(jnp.float32) + \
        params[f"{name}.ln_x.bias"].astype(jnp.float32)
    o = o.astype(dt) * jax.nn.silu(gg)
    y = jnp.einsum("bsk,kd->bsd", o, params[f"{name}.wo"].astype(dt))

    if state is not None:
        state = dict(state, x_att=x[:, -1], wkv=new_wkv)
    return y, state


def apply_channel_mix(params: Params, name: str, x: jnp.ndarray, cfg: ModelConfig,
                      state: Optional[State] = None) -> Tuple[jnp.ndarray, Optional[State]]:
    dt = x.dtype
    prev = state["x_ffn"] if state is not None and state.get("decode", False) else None
    xs = _token_shift(x, prev)
    dx = xs - x
    xk = x + dx * params[f"{name}.mix_k"].astype(dt)
    xr = x + dx * params[f"{name}.mix_r"].astype(dt)
    k = jnp.einsum("bsd,df->bsf", xk, params[f"{name}.wk"].astype(dt))
    k = jnp.square(jax.nn.relu(k))
    r = jax.nn.sigmoid(jnp.einsum("bsd,dk->bsk", xr, params[f"{name}.wr"].astype(dt)))
    y = r * jnp.einsum("bsf,fd->bsd", k, params[f"{name}.wv"].astype(dt))
    if state is not None:
        state = dict(state, x_ffn=x[:, -1])
    return y, state


def rwkv_state_schema(cfg: ModelConfig, name: str, batch: int) -> Schema:
    r = cfg.rwkv
    d = cfg.d_model
    H = d // r.head_dim
    return {
        f"{name}.x_att": ParamDef((batch, d), ("batch", None), "zeros"),
        f"{name}.x_ffn": ParamDef((batch, d), ("batch", None), "zeros"),
        f"{name}.wkv": ParamDef((batch, H, r.head_dim, r.head_dim),
                                ("batch", "kv_heads", None, None), "zeros"),
    }

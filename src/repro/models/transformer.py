"""Stack assembly: repeating block groups scanned with lax.scan.

A model is ``num_layers`` layers arranged as ``n_groups`` repetitions of
``cfg.block_pattern`` (a tuple of block kinds). Parameters for one group are
a flat dict keyed ``blk{i}.<module>.<leaf>``; the whole stack stacks every
leaf along a leading ``groups`` axis and scans over it — one compiled group
body regardless of depth (compile-time and HLO size stay bounded for the
72-layer jamba as much as the 16-layer llama).

Block kinds:
  attn   — norm, GQA/MLA attention, norm, MLP or MoE
  mamba  — norm, selective SSM,     norm, MLP or MoE
  rwkv   — norm, RWKV6 time-mix,    norm, RWKV channel-mix
  xattn  — attn block + cross-attention sub-block (whisper decoder)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (ParamDef, Params, Schema, apply_mlp,
                                 apply_norm, mlp_schema, norm_schema,
                                 prefix_schema, stack_schema)

# scan unroll factor for the dry-run (see launch/dryrun.py): XLA's
# cost_analysis only counts a while-loop body ONCE, so the dry-run fully
# unrolls the group scan to get faithful FLOP counts. Runtime paths keep
# the rolled scan.
_SCAN_UNROLL = {"value": 1}
# activation-checkpoint policy applied to the scanned group body:
# none | full (save nothing) | dots (save matmul outputs)
_REMAT = {"policy": "none"}


def set_scan_unroll(n: int):
    _SCAN_UNROLL["value"] = n


def set_remat(policy: str):
    assert policy in ("none", "full", "dots"), policy
    _REMAT["policy"] = policy


def _maybe_remat(body):
    pol = _REMAT["policy"]
    if pol == "none":
        return body
    if pol == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(body)


def n_groups(cfg: ModelConfig) -> int:
    p = len(cfg.block_pattern)
    assert cfg.num_layers % p == 0, (cfg.num_layers, p)
    return cfg.num_layers // p


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------

def _block_schema(cfg: ModelConfig, kind: str, idx: int, cross: bool) -> Schema:
    """Schema for pattern position ``idx`` of one group."""
    pre = f"blk{idx}"
    s: Schema = {}
    s.update(norm_schema(cfg, f"{pre}.norm1"))
    if kind == "attn" or kind == "xattn":
        if cfg.attention.kind == "mla":
            s.update(attn_mod.mla_schema(cfg, f"{pre}.attn"))
        else:
            s.update(attn_mod.gqa_schema(cfg, f"{pre}.attn"))
    elif kind == "mamba":
        s.update(ssm_mod.mamba_schema(cfg, f"{pre}.mixer"))
    elif kind == "rwkv":
        s.update(rwkv_mod.rwkv_schema(cfg, f"{pre}.mixer"))
    else:
        raise ValueError(kind)
    if kind == "xattn":
        s.update(norm_schema(cfg, f"{pre}.norm_x"))
        s.update(attn_mod.gqa_schema(cfg, f"{pre}.cross", cross=True))
    s.update(norm_schema(cfg, f"{pre}.norm2"))
    if kind == "rwkv":
        s.update(rwkv_mod.channel_mix_schema(cfg, f"{pre}.cmix"))
    elif cfg.layer_uses_moe(idx):
        s.update(moe_mod.moe_schema(cfg, f"{pre}.moe"))
    else:
        s.update(mlp_schema(cfg, f"{pre}.mlp"))
    return s


def group_schema(cfg: ModelConfig, cross: bool = False) -> Schema:
    s: Schema = {}
    for i, kind in enumerate(cfg.block_pattern):
        k = "xattn" if (cross and kind == "attn") else kind
        s.update(_block_schema(cfg, k, i, cross))
    return s


def stack_params_schema(cfg: ModelConfig, prefix: str = "stack",
                        cross: bool = False) -> Schema:
    return prefix_schema(prefix, stack_schema(group_schema(cfg, cross),
                                              n_groups(cfg), "groups"))


def group_cache_schema(cfg: ModelConfig, batch: int, max_len: int,
                       cross: bool = False) -> Schema:
    """Serve-state schema for one group (stacked by caller)."""
    s: Schema = {}
    for i, kind in enumerate(cfg.block_pattern):
        pre = f"blk{i}"
        if kind == "attn":
            if cfg.attention.kind == "mla":
                s.update(attn_mod.mla_cache_schema(cfg, f"{pre}.attn", batch, max_len))
            else:
                s.update(attn_mod.gqa_cache_schema(cfg, f"{pre}.attn", batch, max_len))
            if cross:
                s.update(attn_mod.gqa_cache_schema(cfg, f"{pre}.cross", batch,
                                                   max_len, cross=True))
        elif kind == "mamba":
            s.update(ssm_mod.mamba_state_schema(cfg, f"{pre}.mixer", batch))
        elif kind == "rwkv":
            s.update(rwkv_mod.rwkv_state_schema(cfg, f"{pre}", batch))
    return s


def stack_cache_schema(cfg: ModelConfig, batch: int, max_len: int,
                       prefix: str = "stack", cross: bool = False) -> Schema:
    return prefix_schema(prefix, stack_schema(
        group_cache_schema(cfg, batch, max_len, cross), n_groups(cfg), "groups"))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _subcache(cache: Optional[Params], name: str, extra: dict) -> Optional[dict]:
    if cache is None:
        return None
    pre = f"{name}."
    sub = {k[len(pre):]: v for k, v in cache.items() if k.startswith(pre)}
    sub.update(extra)
    return sub


def _store(cache_out: dict, name: str, sub: Optional[dict], keys):
    if sub is None:
        return
    for k in keys:
        if k in sub:
            cache_out[f"{name}.{k}"] = sub[k]


def _apply_block(gp: Params, cfg: ModelConfig, idx: int, kind: str,
                 x: jnp.ndarray, positions, cache: Optional[Params],
                 cache_out: dict, decode: bool, memory, lengths):
    pre = f"blk{idx}"
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(gp, f"{pre}.norm1", x, cfg)
    extra = {"decode": decode, "length": lengths} if decode else (
        {"length": lengths} if lengths is not None else {})
    if kind in ("attn", "xattn"):
        name = f"{pre}.attn"
        sub = _subcache(cache, name, extra)
        if cfg.attention.kind == "mla":
            y, sub = attn_mod.apply_mla(gp, name, h, positions, cfg, sub)
            _store(cache_out, name, sub, ("ckv", "k_rope"))
        else:
            y, sub = attn_mod.apply_gqa(gp, name, h, positions, cfg, sub)
            _store(cache_out, name, sub, ("k", "v"))
    elif kind == "mamba":
        name = f"{pre}.mixer"
        sub = _subcache(cache, name, {"decode": decode}) if cache is not None else None
        y, sub = ssm_mod.apply_mamba(gp, name, h, cfg, sub)
        _store(cache_out, name, sub, ("conv", "ssm"))
    elif kind == "rwkv":
        name = f"{pre}.mixer"
        sub = _subcache(cache, pre, {"decode": decode}) if cache is not None else None
        y, sub = rwkv_mod.apply_time_mix(gp, name, h, cfg, sub)
        _store(cache_out, pre, sub, ("x_att", "wkv"))
    else:
        raise ValueError(kind)
    x = x + y

    if kind == "xattn":
        h = apply_norm(gp, f"{pre}.norm_x", x, cfg)
        name = f"{pre}.cross"
        sub = _subcache(cache, name, {"decode": decode}) if cache is not None else None
        y, sub = attn_mod.apply_gqa(gp, name, h, positions, cfg, sub,
                                    memory=memory, is_cross=True)
        _store(cache_out, name, sub, ("k", "v"))
        x = x + y

    h = apply_norm(gp, f"{pre}.norm2", x, cfg)
    if kind == "rwkv":
        sub = _subcache(cache, pre, {"decode": decode}) if cache is not None else None
        y, sub = rwkv_mod.apply_channel_mix(gp, f"{pre}.cmix", h, cfg, sub)
        _store(cache_out, pre, sub, ("x_ffn",))
    elif cfg.layer_uses_moe(idx):
        y, aux = moe_mod.apply_moe(gp, f"{pre}.moe", h, cfg)
    else:
        y = apply_mlp(gp, f"{pre}.mlp", h, cfg)
    return x + y, aux


def apply_stack(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                positions, cache: Optional[Params] = None,
                decode: bool = False, memory: Optional[jnp.ndarray] = None,
                lengths: Optional[jnp.ndarray] = None,
                prefix: str = "stack", cross: bool = False
                ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """Run the full stack. Returns (y, new_cache (stacked) or None, aux)."""
    pre = f"{prefix}."
    stacked = {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}
    pattern = cfg.block_pattern

    def body(carry, xs):
        x, aux = carry
        gp, gcache = xs
        x = constrain(x, "batch", None, None)
        cache_out: dict = {}
        for i, kind in enumerate(pattern):
            k = "xattn" if (cross and kind == "attn") else kind
            x, a = _apply_block(gp, cfg, i, k, x, positions, gcache,
                                cache_out, decode, memory, lengths)
            aux = aux + a
        return (x, aux), cache_out

    aux0 = jnp.zeros((), jnp.float32)
    if cache is not None:
        (x, aux), new_cache = jax.lax.scan(
            _maybe_remat(body), (x, aux0), (stacked, cache),
            unroll=_SCAN_UNROLL["value"])
    else:
        def body_nc(carry, gp):
            return body(carry, (gp, None))
        (x, aux), new_cache = jax.lax.scan(
            _maybe_remat(body_nc), (x, aux0), stacked,
            unroll=_SCAN_UNROLL["value"])
        new_cache = None
    return x, new_cache, aux

"""Shared layer primitives + the parameter-schema machinery.

Every module declares its parameters as a *schema*: a flat dict mapping
parameter path -> ParamDef(shape, logical_axes, init). From one schema we
derive:
  * concrete initialization  (init_from_schema)
  * abstract ShapeDtypeStructs for the dry-run  (abstract_from_schema)
  * PartitionSpecs under a rule table           (specs_from_schema)

Logical axes used throughout:
  layers / groups  — scan dimension, never sharded
  vocab            — vocabulary dim (TP over 'model' for embed/logits)
  embed            — d_model dim (FSDP over 'data')
  mlp              — FFN hidden (TP over 'model')
  heads            — fused attention head output dim (TP over 'model')
  kv               — fused KV head output dim (TP if divisible)
  expert           — MoE expert dim (EP over 'data')
  rank / state / conv / norm / inner — replicated small dims
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AttentionConfig, ModelConfig

Params = Dict[str, jnp.ndarray]


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | embed | small
    scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Schema = Dict[str, ParamDef]


def _fan_in(shape: Tuple[int, ...]) -> int:
    if len(shape) == 1:
        return shape[0]
    # convention: last dim is fan-out, second-to-last (or product of the
    # rest) is fan-in; good enough for init purposes.
    return int(np.prod(shape[:-1])) if len(shape) == 2 else shape[-2]


def init_from_schema(schema: Schema, key: jax.Array, dtype) -> Params:
    params = {}
    names = sorted(schema)
    keys = jax.random.split(key, max(len(names), 1))
    for k, name in zip(keys, names):
        d = schema[name]
        if d.init == "zeros":
            params[name] = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            params[name] = jnp.ones(d.shape, dtype)
        else:
            if d.scale is not None:
                std = d.scale
            elif d.init == "embed":
                std = 1.0
            elif d.init == "small":
                std = 0.02
            else:
                std = 1.0 / math.sqrt(max(_fan_in(d.shape), 1))
            params[name] = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)
    return params


def abstract_from_schema(schema: Schema, dtype) -> Params:
    return {
        name: jax.ShapeDtypeStruct(d.shape, dtype) for name, d in schema.items()
    }


def specs_from_schema(schema: Schema, rules: Dict[str, Optional[str]],
                      mesh_shape: Dict[str, int]):
    """Map logical axes to PartitionSpecs, dropping non-divisible shardings."""
    from jax.sharding import PartitionSpec as P

    specs = {}
    for name, d in schema.items():
        out = []
        used = set()
        for dim, ax in zip(d.shape, d.axes):
            mesh_ax = rules.get(ax) if ax is not None else None
            if mesh_ax is None or mesh_ax in used:
                out.append(None)
                continue
            size = mesh_shape.get(mesh_ax, 1) if not isinstance(mesh_ax, tuple) else int(
                np.prod([mesh_shape.get(a, 1) for a in mesh_ax]))
            if size > 1 and dim % size == 0:
                out.append(mesh_ax)
                used.add(mesh_ax)
            else:
                out.append(None)
        specs[name] = P(*out)
    return specs


def prefix_schema(prefix: str, schema: Schema) -> Schema:
    return {f"{prefix}.{k}": v for k, v in schema.items()}


def stack_schema(schema: Schema, n: int, axis_name: str = "layers") -> Schema:
    """Prepend a stacked (scan) dimension to every leaf."""
    return {
        k: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init, d.scale)
        for k, d in schema.items()
    }


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_schema(cfg: ModelConfig, name: str) -> Schema:
    s: Schema = {f"{name}.scale": ParamDef((cfg.d_model,), ("norm",), "ones")}
    if cfg.norm == "layernorm":
        s[f"{name}.bias"] = ParamDef((cfg.d_model,), ("norm",), "zeros")
    return s


def apply_norm(params: Params, name: str, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + cfg.norm_eps)
    else:
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * params[f"{name}.scale"].astype(jnp.float32)
    if cfg.norm == "layernorm":
        y = y + params[f"{name}.bias"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE (standard, partial, M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(att: AttentionConfig, rot_dim: int) -> jnp.ndarray:
    exponent = jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim
    return 1.0 / (att.rope_theta ** exponent)          # [rot_dim//2]


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, att: AttentionConfig) -> jnp.ndarray:
    """x: [b, s, h, hd]; positions: [b, s] or [rows, b, s] for M-RoPE."""
    if att.rope == "none":
        return x
    hd = x.shape[-1]
    rot_dim = int(hd * att.rotary_pct) // 2 * 2
    inv = rope_freqs(att, rot_dim)                     # [rot/2]
    if att.rope == "mrope":
        # positions [3, b, s]; head_dim halves split into sections (t, h, w)
        assert positions.ndim == 3, "M-RoPE needs [3, b, s] positions"
        sections = att.mrope_sections                  # sums to rot_dim//2
        parts = []
        start = 0
        for row, sec in enumerate(sections):
            pos = positions[row].astype(jnp.float32)   # [b, s]
            angles = pos[..., None] * inv[start:start + sec]  # [b, s, sec]
            parts.append(angles)
            start += sec
        angles = jnp.concatenate(parts, axis=-1)       # [b, s, rot/2]
    else:
        pos = positions.astype(jnp.float32)            # [b, s]
        angles = pos[..., None] * inv                  # [b, s, rot/2]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = jnp.split(x_rot, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, x_pass], axis=-1)


def sinusoidal_positions(seq: int, dim: int) -> jnp.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(emb, dtype=jnp.float32)


def sinusoidal_embed(positions: jnp.ndarray, dim: int) -> jnp.ndarray:
    """Sinusoidal embedding for arbitrary (possibly traced) positions [...]."""
    i = jnp.arange(dim // 2, dtype=jnp.float32)
    angle = positions.astype(jnp.float32)[..., None] / jnp.power(10000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------

def mlp_schema(cfg: ModelConfig, name: str, d_ff: Optional[int] = None) -> Schema:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s: Schema = {}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        s[f"{name}.w_gate"] = ParamDef((d, f), ("embed", "mlp"))
        s[f"{name}.w_up"] = ParamDef((d, f), ("embed", "mlp"))
    else:
        s[f"{name}.w_up"] = ParamDef((d, f), ("embed", "mlp"))
    s[f"{name}.w_down"] = ParamDef((f, d), ("mlp", "embed"))
    return s


def apply_mlp(params: Params, name: str, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    up = jnp.einsum("bsd,df->bsf", x, params[f"{name}.w_up"].astype(x.dtype))
    if cfg.mlp_kind == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, params[f"{name}.w_gate"].astype(x.dtype))
        h = jax.nn.silu(gate) * up
    elif cfg.mlp_kind == "geglu":
        gate = jnp.einsum("bsd,df->bsf", x, params[f"{name}.w_gate"].astype(x.dtype))
        h = jax.nn.gelu(gate, approximate=True) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    return jnp.einsum("bsf,fd->bsd", h, params[f"{name}.w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embed_schema(cfg: ModelConfig) -> Schema:
    s: Schema = {"embed.table": ParamDef((cfg.vocab_size, cfg.d_model),
                                         ("vocab", "embed"), "small")}
    if not cfg.tie_embeddings:
        s["unembed.w"] = ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return s


def embed_tokens(params: Params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    table = params["embed.table"]
    x = table.astype(jnp.dtype(cfg.dtype))[tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    from repro.distributed.sharding import constrain
    if cfg.tie_embeddings:
        w = params["embed.table"].astype(x.dtype)      # [v, d]
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed.w"].astype(x.dtype))
    return constrain(logits, "batch", None, "vocab_act")

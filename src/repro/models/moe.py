"""Mixture-of-Experts with grouped capacity dispatch (GShard-style limits,
gather/scatter implementation).

Tokens are split into groups; within each group every expert has capacity
C = ceil(group_size * top_k * capacity_factor / E); overflowing (token,
expert) pairs drop (residual passes through). Dispatch and combine are
index gathers/scatters — never materializing [tokens, E, C] one-hots — so
the only cross-device movement is the (k*cf)x token payload itself:
``constrain`` pins xe/ye to the expert axis and GSPMD emits the EP
all-to-alls there (observed: the one-hot einsum formulation made GSPMD
all-gather 20+ GB of dispatch masks per layer instead).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import ParamDef, Params, Schema

# tokens per dispatch group (capacity is per group-expert)
GROUP_SIZE = 256


def moe_schema(cfg: ModelConfig, name: str) -> Schema:
    m = cfg.moe
    d = cfg.d_model
    s: Schema = {
        f"{name}.router": ParamDef((d, m.num_experts), ("embed", None), "small"),
        f"{name}.w_gate": ParamDef((m.num_experts, d, m.d_ff), ("expert", "embed", "mlp")),
        f"{name}.w_up": ParamDef((m.num_experts, d, m.d_ff), ("expert", "embed", "mlp")),
        f"{name}.w_down": ParamDef((m.num_experts, m.d_ff, d), ("expert", "mlp", "embed")),
    }
    if m.num_shared_experts > 0:
        f_sh = m.d_ff * m.num_shared_experts
        s[f"{name}.shared.w_gate"] = ParamDef((d, f_sh), ("embed", "mlp"))
        s[f"{name}.shared.w_up"] = ParamDef((d, f_sh), ("embed", "mlp"))
        s[f"{name}.shared.w_down"] = ParamDef((f_sh, d), ("mlp", "embed"))
    return s


def _capacity(group: int, num_experts: int, top_k: int, factor: float) -> int:
    c = int(math.ceil(group * top_k * factor / num_experts))
    return max(c, 1)


def apply_moe(params: Params, name: str, x: jnp.ndarray,
              cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [b, s, d] -> (y [b, s, d], aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    dt = x.dtype
    tokens = b * s
    group = min(GROUP_SIZE, tokens)
    n_groups = tokens // group
    assert n_groups * group == tokens, (tokens, group)
    cap = _capacity(group, m.num_experts, m.top_k, m.capacity_factor)
    E, K = m.num_experts, m.top_k

    xg = constrain(x.reshape(n_groups, group, d), "batch", None, None)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params[f"{name}.router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # [g,t,E]

    topw, tope = jax.lax.top_k(probs, K)                        # [g,t,K]
    topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) pair within its expert's capacity buffer
    onehot = jax.nn.one_hot(tope.reshape(n_groups, group * K), E,
                            dtype=jnp.int32)                    # [g,tK,E]
    pos = (jnp.cumsum(onehot, axis=1) - onehot)
    pos = jnp.sum(pos * onehot, axis=-1).reshape(n_groups, group, K)
    keep = pos < cap
    weight = topw * keep

    # slot table: token filling expert-slot (e, c); dropped pairs -> sink
    slot_idx = jnp.where(keep, tope * cap + pos, E * cap)       # [g,t,K]
    token_ids = jnp.broadcast_to(jnp.arange(group)[None, :, None],
                                 (n_groups, group, K))
    slot_token = jnp.zeros((n_groups, E * cap + 1), jnp.int32)
    slot_token = jax.vmap(lambda st, si, ti: st.at[si.reshape(-1)]
                          .set(ti.reshape(-1), mode="drop"))(
        slot_token, slot_idx, token_ids)
    slot_filled = jnp.zeros((n_groups, E * cap + 1), dt)
    slot_filled = jax.vmap(lambda sf, si: sf.at[si.reshape(-1)]
                           .set(1.0, mode="drop"))(slot_filled, slot_idx)
    slot_token = slot_token[:, :E * cap]
    slot_filled = slot_filled[:, :E * cap]

    # gather token payloads into expert slots, a2a to the expert shard
    xe = jnp.take_along_axis(xg, slot_token[..., None], axis=1)  # [g,EC,d]
    xe = xe * slot_filled[..., None]
    xe = xe.reshape(n_groups, E, cap, d)
    xe = constrain(xe, None, "expert_act", None, None)

    gate = jnp.einsum("gecd,edf->gecf", xe, params[f"{name}.w_gate"].astype(dt))
    up = jnp.einsum("gecd,edf->gecf", xe, params[f"{name}.w_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("gecf,efd->gecd", h, params[f"{name}.w_down"].astype(dt))
    # w_down's contraction is TP-sharded: keep d sharded over 'model' here
    # so the psum becomes a reduce-scatter of the (k*cf)x capacity tensor,
    # and a2a back to the token shard in the same layout
    ye = constrain(ye, "batch", None, None, "mlp_act")
    ye = ye.reshape(n_groups, E * cap, d)
    picked = jnp.take_along_axis(
        ye, jnp.where(keep, tope * cap + pos, 0).reshape(
            n_groups, group * K)[..., None], axis=1)            # [g,tK,d]
    picked = picked.reshape(n_groups, group, K, d)
    picked = constrain(picked, "batch", None, None, "mlp_act")
    y = jnp.einsum("gtkd,gtk->gtd", picked.astype(jnp.float32),
                   weight).astype(dt)
    # gather the full hidden dim only on the token-sized output
    y = constrain(y, "batch", None, None).reshape(b, s, d)

    if m.num_shared_experts > 0:
        # shared experts run on the token-sharded view; keep the TP psum's
        # output token-sharded (reduce-scatter, not a replicated all-reduce)
        g2 = jnp.einsum("gtd,df->gtf", xg, params[f"{name}.shared.w_gate"].astype(dt))
        u2 = jnp.einsum("gtd,df->gtf", xg, params[f"{name}.shared.w_up"].astype(dt))
        ysh = jnp.einsum("gtf,fd->gtd", jax.nn.silu(g2) * u2,
                         params[f"{name}.shared.w_down"].astype(dt))
        y = y + constrain(ysh, "batch", None, None).reshape(b, s, d)

    # Switch aux load-balancing loss
    frac_tokens = jnp.mean(
        jax.nn.one_hot(tope[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs) * m.aux_loss_weight
    return y, aux

"""minicpm3-4b [dense]: 62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448.
MLA (multi-head latent attention). [hf:openbmb/MiniCPM3-4B; hf]"""
import dataclasses

from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    d_ff=6400,
    vocab_size=73448,
    attention=AttentionConfig(kind="mla", num_heads=40, num_kv_heads=40,
                              head_dim=64, rope="standard", rope_theta=10000.0,
                              q_lora_rank=768, kv_lora_rank=256,
                              qk_nope_head_dim=64, qk_rope_head_dim=32,
                              v_head_dim=64),
    mlp_kind="swiglu",
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="minicpm3-smoke", num_layers=2, d_model=64, d_ff=128,
        vocab_size=256,
        attention=dataclasses.replace(
            CONFIG.attention, num_heads=4, num_kv_heads=4, head_dim=16,
            q_lora_rank=24, kv_lora_rank=16, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16),
        max_seq_len=256)

"""stablelm-3b [dense]: 32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
Partial rotary (25%). [hf:stabilityai/stablelm-2-1_6b; unverified]"""
import dataclasses

from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    d_ff=6912,
    vocab_size=50304,
    attention=AttentionConfig(kind="gqa", num_heads=32, num_kv_heads=32,
                              head_dim=80, rope="standard",
                              rope_theta=10000.0, rotary_pct=0.25),
    mlp_kind="swiglu",
    norm="layernorm",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="stablelm-smoke", num_layers=2, d_model=64, d_ff=128,
        vocab_size=256,
        attention=dataclasses.replace(CONFIG.attention, num_heads=4,
                                      num_kv_heads=4, head_dim=16),
        max_seq_len=256)

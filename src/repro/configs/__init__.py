"""Architecture registry: ``get_config(arch)`` / ``get_smoke_config(arch)``.

One module per assigned architecture; each exports ``CONFIG`` (full size,
exercised only by the dry-run) and ``smoke_config()`` (reduced same-family
config runnable on CPU).
"""
from __future__ import annotations

import importlib

from repro.config import ModelConfig

ARCHS = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a66b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "rwkv6-7b": "rwkv6_7b",
    "jamba-1.5-large-398b": "jamba15_large_398b",
    "minicpm3-4b": "minicpm3_4b",
    "llama3.2-1b": "llama32_1b",
    "stablelm-3b": "stablelm_3b",
    "gemma-7b": "gemma_7b",
    "whisper-small": "whisper_small",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; options: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def all_archs():
    return list(ARCHS)

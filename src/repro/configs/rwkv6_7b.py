"""rwkv6-7b [ssm]: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.
Finch — data-dependent decay. [arXiv:2404.05892; hf]"""
import dataclasses

from repro.config import AttentionConfig, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    attention=AttentionConfig(kind="none", rope="none"),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    block_pattern=("rwkv",),
    norm="layernorm",     # rwkv uses LN
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="rwkv6-smoke", num_layers=2, d_model=64, d_ff=128,
        vocab_size=256, rwkv=RWKVConfig(head_dim=16, decay_lora=8, mix_lora=8),
        max_seq_len=256)

"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
M-RoPE, dynamic resolution (vision frontend is a stub: input_specs provides
precomputed patch embeddings at model width). [arXiv:2409.12191; hf]"""
import dataclasses

from repro.config import AttentionConfig, ModelConfig

# number of stub patch embeddings prepended to the text sequence
N_PATCHES = 256

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    d_ff=18944,
    vocab_size=152064,
    attention=AttentionConfig(kind="gqa", num_heads=28, num_kv_heads=4,
                              head_dim=128, rope="mrope", rope_theta=1000000.0,
                              mrope_sections=(16, 24, 24)),
    mlp_kind="swiglu",
    norm="rmsnorm",
    frontend="vision",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-vl-smoke", num_layers=2, d_model=64, d_ff=128,
        vocab_size=256,
        attention=dataclasses.replace(CONFIG.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=16,
                                      mrope_sections=(2, 3, 3)),
        max_seq_len=256)

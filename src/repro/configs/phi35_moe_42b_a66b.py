"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16e top-2. [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
import dataclasses

from repro.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    d_ff=6400,
    vocab_size=32064,
    attention=AttentionConfig(kind="gqa", num_heads=32, num_kv_heads=8,
                              head_dim=128, rope="standard", rope_theta=10000.0),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=6400),
    moe_every=1,
    mlp_kind="swiglu",
    norm="layernorm",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="phi3.5-smoke", num_layers=2, d_model=64, d_ff=96,
        vocab_size=256,
        attention=dataclasses.replace(CONFIG.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=96),
        max_seq_len=256)

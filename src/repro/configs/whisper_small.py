"""whisper-small [audio]: 12L d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865.
Encoder-decoder; conv frontend is a stub (input_specs provides precomputed
frame embeddings, 1500 frames). [arXiv:2212.04356; unverified]"""
import dataclasses

from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,           # decoder layers
    d_model=768,
    d_ff=3072,
    vocab_size=51865,
    attention=AttentionConfig(kind="gqa", num_heads=12, num_kv_heads=12,
                              head_dim=64, rope="none"),
    mlp_kind="gelu",
    norm="layernorm",
    encdec=True,
    encoder_layers=12,
    encoder_seq=1500,        # 30 s of audio at 50 Hz post-conv
    frontend="audio",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-smoke", num_layers=2, encoder_layers=2,
        encoder_seq=16, d_model=64, d_ff=128, vocab_size=256,
        attention=dataclasses.replace(CONFIG.attention, num_heads=4,
                                      num_kv_heads=4, head_dim=16),
        max_seq_len=256)

"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576,
MoE 16e top-2; Mamba:attention 7:1 interleave, MoE every other layer.
[arXiv:2403.19887; hf]"""
import dataclasses

from repro.config import AttentionConfig, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    d_ff=24576,
    vocab_size=65536,
    attention=AttentionConfig(kind="gqa", num_heads=64, num_kv_heads=8,
                              head_dim=128, rope="none"),  # jamba: no rope
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576),
    moe_every=2,          # MoE every other layer
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    block_pattern=("attn",) + ("mamba",) * 7,   # 7:1 mamba:attention
    mlp_kind="swiglu",
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="jamba-smoke", num_layers=8, d_model=64, d_ff=96,
        vocab_size=256,
        attention=dataclasses.replace(CONFIG.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=96),
        ssm=SSMConfig(d_state=4, d_conv=2, expand=2),
        max_seq_len=256)

"""gemma-7b [dense]: 28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000.
GeGLU, head_dim=256 (q dim 4096 != d_model), scaled embeddings, tied unembed.
[arXiv:2403.08295; hf]"""
import dataclasses

from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    d_ff=24576,
    vocab_size=256000,
    attention=AttentionConfig(kind="gqa", num_heads=16, num_kv_heads=16,
                              head_dim=256, rope="standard", rope_theta=10000.0),
    mlp_kind="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    scale_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="gemma-smoke", num_layers=2, d_model=64, d_ff=128,
        vocab_size=256,
        attention=dataclasses.replace(CONFIG.attention, num_heads=4,
                                      num_kv_heads=4, head_dim=32),
        max_seq_len=256)

"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 (+2 shared experts, moonlight/deepseek style).
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
import dataclasses

from repro.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    d_ff=1408,           # per-expert hidden
    vocab_size=163840,
    attention=AttentionConfig(kind="gqa", num_heads=16, num_kv_heads=16,
                              head_dim=128, rope="standard", rope_theta=50000.0),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408, num_shared_experts=2),
    moe_every=1,          # all layers MoE
    mlp_kind="swiglu",
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="moonshot-smoke", num_layers=2, d_model=64, d_ff=32,
        vocab_size=256,
        attention=dataclasses.replace(CONFIG.attention, num_heads=4,
                                      num_kv_heads=4, head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, num_shared_experts=1),
        max_seq_len=256)

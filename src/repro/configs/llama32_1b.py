"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-1B; unverified]"""
import dataclasses

from repro.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    d_ff=8192,
    vocab_size=128256,
    attention=AttentionConfig(kind="gqa", num_heads=32, num_kv_heads=8,
                              head_dim=64, rope="standard", rope_theta=500000.0),
    mlp_kind="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llama3.2-smoke", num_layers=2, d_model=64, d_ff=128,
        vocab_size=256,
        attention=dataclasses.replace(CONFIG.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=16),
        max_seq_len=256)

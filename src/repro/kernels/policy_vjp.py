"""Checkpointed custom VJP for the TwinPolicy lane scan.

``jax.grad`` of the plain reference scan (``ref.policy_grid_scan``)
stores every per-bin carry for the backward pass — O(T) residual memory
and, on the year horizon, a backward trace XLA re-materializes from the
full 8736-step forward. This module gives the same scan an explicit
``jax.custom_vjp`` with the classic O(√T) segment-checkpoint schedule:

* **forward** — the unmodified single ``lax.scan`` over all T bins (bit
  -identical primal values to ``ref.policy_grid_scan``; the custom rule
  changes nothing unless a gradient is actually requested);
* **backward** — the horizon is split into ~√T segments of ~√T bins.
  One cheap forward replay (carry only, no series) collects the segment
  -entry carries, then a ``reverse=True`` scan walks the segments back
  to front, rematerializing each segment with ``jax.vjp`` and chaining
  the carry cotangent through it. Live residuals are one segment's scan
  tape plus the [√T, N, CARRY_DIM] entry carries, never the full tape.

Cotangents flow to ``params``, ``loads`` and (on the mixed-grid path)
``onehot`` — everything calibrate/search differentiate and more; the
policy selector index is integer-typed and gets the mandatory ``float0``
zero. ``dt_hours`` / ``surrogate`` / the selector form are nondiff
trace constants, exactly as static as they are in the jitted fit/search
kernels that consume this through ``kernels.ops.policy_scan``.

``policy_grid_scan_fold`` is the streaming-aggregate sibling: instead of
returning five [N, T] series it folds per-bin outputs into a caller
-defined in-carry accumulator (compensated triples, running cumsums…)
and gives THAT scan the same O(√T) checkpointed VJP — the segment
replays carry the accumulators through, so neither direction ever holds
an [N, T] intermediate. It also speaks the fault layer (``caps=``),
which the plain ckpt path never did — chance-constrained search
gradients stream through here.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np


def _segment_plan(t_bins: int):
    """(seg_len, num_segments, tail_len) with seg_len ≈ √T — the classic
    even split; whatever T leaves over runs as one shorter tail segment."""
    seg = max(1, math.isqrt(max(t_bins, 1)))
    nseg = t_bins // seg
    return seg, nseg, t_bins - nseg * seg


def _bin_step(cfg, params, onehot, pidx):
    """The lane bin-step under ``cfg`` = (dt, surrogate, use_onehot) —
    the exact step ``ref.policy_grid_scan`` scans, so primal values (and
    therefore the rematerialized segments) match it bit for bit."""
    from repro.core.twin import (lane_branches, lane_policy_step,
                                 surrogate_lane_branches)
    dt_hours, surrogate, use_onehot = cfg
    branches = (surrogate_lane_branches() if surrogate
                else lane_branches())
    dt = jnp.asarray(dt_hours, jnp.float32)
    if use_onehot:
        def step(carry, arrive):
            return lane_policy_step(carry, arrive, params, onehot, dt,
                                    branches=branches)
    else:
        def step(carry, arrive):
            return jax.lax.switch(pidx, branches, carry, arrive, params,
                                  dt)
    return step


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ckpt_scan(cfg, params, loads_t, onehot, pidx):
    """Primal: ONE plain scan over all T bins (loads_t [T, N], scenario
    -minor). Returns (carry_end [N, CARRY_DIM], five [T, N] series)."""
    from repro.core.twin import CARRY_DIM
    step = _bin_step(cfg, params, onehot, pidx)
    n = loads_t.shape[1]
    return jax.lax.scan(step, jnp.zeros((n, CARRY_DIM), jnp.float32),
                        loads_t)


def _ckpt_fwd(cfg, params, loads_t, onehot, pidx):
    # residuals are just the primal inputs — segment-entry carries are
    # recomputed in bwd (one series-free replay), keeping fwd free
    return _ckpt_scan(cfg, params, loads_t, onehot, pidx), \
        (params, loads_t, onehot, pidx)


def _ckpt_bwd(cfg, res, cots):
    from repro.core.twin import CARRY_DIM
    params, loads_t, onehot, pidx = res
    g_carry, ct_outs = cots
    t_bins, n = loads_t.shape
    seg, nseg, tail = _segment_plan(t_bins)
    step = _bin_step(cfg, params, onehot, pidx)
    body = t_bins - tail

    def seg_scan(carry0, params_, onehot_, seg_loads):
        # the differentiable segment: same step, params/onehot rebound so
        # jax.vjp hands back their cotangents alongside carry and loads
        s = _bin_step(cfg, params_, onehot_, pidx)
        return jax.lax.scan(s, carry0, seg_loads)

    # forward replay, carry only: entry carries of the nseg body segments
    main = loads_t[:body].reshape(nseg, seg, n)

    def seg_fwd(carry, seg_loads):
        out, _ = jax.lax.scan(lambda c, a: (step(c, a)[0], None), carry,
                              seg_loads)
        return out, carry                       # ys = the ENTRY carry

    c_tail, entries = jax.lax.scan(seg_fwd, jnp.zeros((n, CARRY_DIM),
                                                      jnp.float32), main)

    g_params = jnp.zeros_like(params)
    g_onehot = jnp.zeros_like(onehot)
    g_loads = jnp.zeros_like(loads_t)
    if tail:
        _, tail_vjp = jax.vjp(seg_scan, c_tail, params, onehot,
                              loads_t[body:])
        g_carry, dp, doh, dl = tail_vjp(
            (g_carry, tuple(o[body:] for o in ct_outs)))
        g_params, g_onehot = g_params + dp, g_onehot + doh
        g_loads = g_loads.at[body:].set(dl)

    ct_main = tuple(o[:body].reshape(nseg, seg, n) for o in ct_outs)

    def seg_bwd(state, xs):
        g_c, g_p, g_oh = state
        entry, seg_loads, ct_seg = xs
        _, vjp_fn = jax.vjp(seg_scan, entry, params, onehot, seg_loads)
        dc, dp, doh, dl = vjp_fn((g_c, ct_seg))
        return (dc, g_p + dp, g_oh + doh), dl

    (g_carry, g_params, g_onehot), dls = jax.lax.scan(
        seg_bwd, (g_carry, g_params, g_onehot), (entries, main, ct_main),
        reverse=True)
    g_loads = g_loads.at[:body].set(dls.reshape(body, n))
    return (g_params, g_loads, g_onehot,
            np.zeros(np.shape(pidx), dtype=jax.dtypes.float0))


_ckpt_scan.defvjp(_ckpt_fwd, _ckpt_bwd)


def policy_grid_scan_ckpt(loads, params, onehot=None, dt_hours=1.0, *,
                          policy_index=None, surrogate: bool = False):
    """``ref.policy_grid_scan`` semantics + the O(√T) checkpointed VJP.

    Same operands, selector rule and return contract as the reference
    (loads [N, T] → carry_end [N, CARRY_DIM] + five [N, T] series);
    primal values are bit-identical — only the gradient schedule differs.
    ``dt_hours`` must be a static float here (it is a trace constant of
    the fit/search kernels); ``kernels.ops.policy_scan`` falls back to
    the plain reference when handed a traced bin width.
    """
    if (onehot is None) == (policy_index is None):
        raise ValueError("pass exactly one of onehot= (mixed grid) or "
                         "policy_index= (uniform lane block)")
    loads_t = jnp.asarray(loads, jnp.float32).T
    use_onehot = onehot is not None
    if use_onehot:
        onehot = jnp.asarray(onehot, jnp.float32)
        pidx = jnp.zeros((), jnp.int32)          # inert placeholder
    else:
        onehot = jnp.zeros((loads_t.shape[1], 0), jnp.float32)
        pidx = jnp.asarray(policy_index, jnp.int32)
    cfg = (float(dt_hours), bool(surrogate), use_onehot)
    carry_end, outs_t = _ckpt_scan(cfg, jnp.asarray(params, jnp.float32),
                                   loads_t, onehot, pidx)
    return carry_end, tuple(o.T for o in outs_t)


# ---------------------------------------------------------------------------
# Streaming fold scan — in-carry reductions, O(√T) checkpointed VJP
# ---------------------------------------------------------------------------

def _fold_bin_step(cfg, params, onehot, pidx, ops_lane):
    """The fold bin-step under ``cfg`` = (dt, surrogate, use_onehot,
    use_caps, fold_init, fold_step): advance the policy lanes one bin
    (optionally through the fault layer, same arithmetic as
    ``ref.policy_grid_scan``'s caps path) and fold the per-bin outputs
    into the caller's accumulator pytree instead of emitting them."""
    from repro.core.twin import (fault_lane_policy_step, lane_branches,
                                 lane_policy_step, surrogate_lane_branches)
    dt_hours, surrogate, use_onehot, use_caps = cfg[:4]
    fold_step = cfg[5]
    branches = (surrogate_lane_branches() if surrogate
                else lane_branches())
    dt = jnp.asarray(dt_hours, jnp.float32)
    if use_caps:
        if use_onehot:
            def pstep(state, arrive, capmul):
                return fault_lane_policy_step(state, arrive, capmul,
                                              params, onehot, dt,
                                              branches=branches)
        else:
            from repro.kernels.ref import _fault_switch_step
            pstep = _fault_switch_step(pidx, branches, params, dt)

        def step(state, row):
            carry, fq, acc = state
            arrive, capmul, xs_row = row
            (carry, fq), outs = pstep((carry, fq), arrive, capmul)
            return carry, fq, fold_step(acc, arrive, outs, ops_lane,
                                        xs_row)
    else:
        if use_onehot:
            def lstep(carry, arrive):
                return lane_policy_step(carry, arrive, params, onehot, dt,
                                        branches=branches)
        else:
            def lstep(carry, arrive):
                return jax.lax.switch(pidx, branches, carry, arrive,
                                      params, dt)

        def step(state, row):
            carry, fq, acc = state
            arrive, _, xs_row = row
            carry, outs = lstep(carry, arrive)
            return carry, fq, fold_step(acc, arrive, outs, ops_lane,
                                        xs_row)
    return step


def _fold_scan_impl(cfg, params, loads_t, onehot, pidx, caps_t, ops_lane,
                    xs):
    """ONE plain scan over all T bins carrying (policy carry [N,
    CARRY_DIM], fault backlog [N], fold accumulators) — ys=None, so
    nothing [T, N]-shaped ever leaves the scan."""
    from repro.core.twin import CARRY_DIM
    fold_init = cfg[4]
    n = loads_t.shape[1]
    step = _fold_bin_step(cfg, params, onehot, pidx, ops_lane)
    state0 = (jnp.zeros((n, CARRY_DIM), jnp.float32),
              jnp.zeros((n,), jnp.float32), fold_init(n))
    return jax.lax.scan(lambda s, r: (step(s, r), None), state0,
                        (loads_t, caps_t, xs))[0]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fold_scan(cfg, params, loads_t, onehot, pidx, caps_t, ops_lane, xs):
    return _fold_scan_impl(cfg, params, loads_t, onehot, pidx, caps_t,
                           ops_lane, xs)


def _fold_fwd(cfg, params, loads_t, onehot, pidx, caps_t, ops_lane, xs):
    # residuals are just the primal inputs — the backward rebuilds
    # segment-entry states with one carry-only replay, so the forward
    # tapes nothing beyond what the caller already holds
    return _fold_scan(cfg, params, loads_t, onehot, pidx, caps_t,
                      ops_lane, xs), \
        (params, loads_t, onehot, pidx, caps_t, ops_lane, xs)


def _fold_bwd(cfg, res, g_state):
    from repro.core.twin import CARRY_DIM
    params, loads_t, onehot, pidx, caps_t, ops_lane, xs = res
    fold_init = cfg[4]
    tmap = jax.tree_util.tree_map
    t_bins, n = loads_t.shape
    seg, nseg, tail = _segment_plan(t_bins)
    body = t_bins - tail
    rows = (loads_t, caps_t, xs)
    step = _fold_bin_step(cfg, params, onehot, pidx, ops_lane)

    def seg_scan(state0, params_, onehot_, ops_, seg_rows):
        # the differentiable segment: same step, operands rebound so
        # jax.vjp hands back their cotangents alongside the state chain
        s = _fold_bin_step(cfg, params_, onehot_, pidx, ops_)
        return jax.lax.scan(lambda st, r: (s(st, r), None), state0,
                            seg_rows)[0]

    # forward replay, state only: entry states of the nseg body segments
    # (policy carry + fault backlog + fold accumulators, all O(N))
    main = tmap(lambda a: a[:body].reshape((nseg, seg) + a.shape[1:]),
                rows)

    def seg_fwd(state, seg_rows):
        out = jax.lax.scan(lambda st, r: (step(st, r), None), state,
                           seg_rows)[0]
        return out, state                       # ys = the ENTRY state

    state0 = (jnp.zeros((n, CARRY_DIM), jnp.float32),
              jnp.zeros((n,), jnp.float32), fold_init(n))
    st_tail, entries = jax.lax.scan(seg_fwd, state0, main)

    g_params = jnp.zeros_like(params)
    g_onehot = jnp.zeros_like(onehot)
    g_ops = tmap(jnp.zeros_like, ops_lane)
    g_rows = tmap(jnp.zeros_like, rows)
    if tail:
        tail_rows = tmap(lambda a: a[body:], rows)
        _, tail_vjp = jax.vjp(seg_scan, st_tail, params, onehot, ops_lane,
                              tail_rows)
        g_state, dp, doh, dops, drows = tail_vjp(g_state)
        g_params, g_onehot = g_params + dp, g_onehot + doh
        g_ops = tmap(jnp.add, g_ops, dops)
        g_rows = tmap(lambda g, d: g.at[body:].set(d), g_rows, drows)

    def seg_bwd(state, seg_xs):
        g_st, g_p, g_oh, g_op = state
        entry, seg_rows = seg_xs
        _, vjp_fn = jax.vjp(seg_scan, entry, params, onehot, ops_lane,
                            seg_rows)
        d_st, dp, doh, dops, drows = vjp_fn(g_st)
        return (d_st, g_p + dp, g_oh + doh, tmap(jnp.add, g_op, dops)), \
            drows

    (g_state, g_params, g_onehot, g_ops), drows = jax.lax.scan(
        seg_bwd, (g_state, g_params, g_onehot, g_ops), (entries, main),
        reverse=True)
    g_rows = tmap(lambda g, d: g.at[:body].set(
        d.reshape((body,) + d.shape[2:])), g_rows, drows)
    g_loads, g_caps, g_xs = g_rows
    return (g_params, g_loads, g_onehot,
            np.zeros(np.shape(pidx), dtype=jax.dtypes.float0),
            g_caps, g_ops, g_xs)


_fold_scan.defvjp(_fold_fwd, _fold_bwd)


def policy_grid_scan_fold(loads=None, params=None, onehot=None,
                          dt_hours=1.0, *, policy_index=None,
                          surrogate: bool = False, caps=None,
                          loads_t=None, caps_t=None, fold_init,
                          fold_step, ops_lane=(), xs=()):
    """Streaming-aggregate lane scan: fold per-bin policy outputs into a
    caller-defined accumulator instead of materializing [N, T] series.

    ``fold_init(n)`` builds the accumulator pytree for ``n`` lanes and
    ``fold_step(acc, arrive, outs, ops_lane, xs_row)`` folds one bin's
    outputs ``outs = (processed, queue, latency, cost, dropped)`` (each
    [N]) into it. ``ops_lane`` is a pytree of differentiable per-lane
    operands (e.g. SLO limits); ``xs`` a pytree of per-bin operands with
    leading axis T (e.g. calibration targets). Both must be module-level
    functions — they ride in the nondiff config of a ``jax.custom_vjp``
    and key its (and the enclosing jit's) trace cache.

    The primal is one plain scan, per-bin arithmetic source-identical to
    ``ref.policy_grid_scan`` (+ the shared fold code), including the
    fault layer when ``caps``/``caps_t`` is given — backlog residue is
    folded into ``carry_end[:, 0]`` exactly like the reference. The
    benign and uniform-index fault forms come out bit-identical to
    materialize-then-fold; the mixed one-hot fault form may wobble a few
    ulps per bin (the masked blend's mul+add chain contracts to FMA
    differently across fusion contexts on CPU). The VJP
    is the O(√T) segment-checkpoint schedule of ``_ckpt_scan``, except
    the replayed state also carries the accumulators, so the backward
    tapes one √T-bin segment at a time and NO [N, T] residual — this is
    what lets chance-constrained search gradients stream.

    Operands may come scenario-minor (``loads_t``/``caps_t`` [T, N]) to
    keep lane-major [N, T] arrays out of the caller's jaxpr entirely.
    Returns (carry_end [N, CARRY_DIM], acc). A traced ``dt_hours`` falls
    back to one plain differentiable scan (O(T) tape), mirroring
    ``policy_scan``'s reference fallback.
    """
    if (onehot is None) == (policy_index is None):
        raise ValueError("pass exactly one of onehot= (mixed grid) or "
                         "policy_index= (uniform lane block)")
    if loads_t is None:
        loads_t = jnp.asarray(loads, jnp.float32).T
    use_caps = caps is not None or caps_t is not None
    if use_caps and caps_t is None:
        caps_t = jnp.asarray(caps, jnp.float32).T
    if not use_caps:
        caps_t = jnp.zeros((loads_t.shape[0], 0), jnp.float32)
    use_onehot = onehot is not None
    if use_onehot:
        onehot = jnp.asarray(onehot, jnp.float32)
        pidx = jnp.zeros((), jnp.int32)          # inert placeholder
    else:
        onehot = jnp.zeros((loads_t.shape[1], 0), jnp.float32)
        pidx = jnp.asarray(policy_index, jnp.int32)
    params = jnp.asarray(params, jnp.float32)
    try:
        dt_static = float(dt_hours)
    except (TypeError, jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        dt_static = None
    if dt_static is None:
        cfg = (dt_hours, bool(surrogate), use_onehot, use_caps,
               fold_init, fold_step)
        carry, fq, acc = _fold_scan_impl(cfg, params, loads_t, onehot,
                                         pidx, caps_t, ops_lane, xs)
    else:
        cfg = (dt_static, bool(surrogate), use_onehot, use_caps,
               fold_init, fold_step)
        carry, fq, acc = _fold_scan(cfg, params, loads_t, onehot, pidx,
                                    caps_t, ops_lane, xs)
    if use_caps:
        carry = carry.at[:, 0].add(fq)
    return carry, acc

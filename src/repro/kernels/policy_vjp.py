"""Checkpointed custom VJP for the TwinPolicy lane scan.

``jax.grad`` of the plain reference scan (``ref.policy_grid_scan``)
stores every per-bin carry for the backward pass — O(T) residual memory
and, on the year horizon, a backward trace XLA re-materializes from the
full 8736-step forward. This module gives the same scan an explicit
``jax.custom_vjp`` with the classic O(√T) segment-checkpoint schedule:

* **forward** — the unmodified single ``lax.scan`` over all T bins (bit
  -identical primal values to ``ref.policy_grid_scan``; the custom rule
  changes nothing unless a gradient is actually requested);
* **backward** — the horizon is split into ~√T segments of ~√T bins.
  One cheap forward replay (carry only, no series) collects the segment
  -entry carries, then a ``reverse=True`` scan walks the segments back
  to front, rematerializing each segment with ``jax.vjp`` and chaining
  the carry cotangent through it. Live residuals are one segment's scan
  tape plus the [√T, N, CARRY_DIM] entry carries, never the full tape.

Cotangents flow to ``params``, ``loads`` and (on the mixed-grid path)
``onehot`` — everything calibrate/search differentiate and more; the
policy selector index is integer-typed and gets the mandatory ``float0``
zero. ``dt_hours`` / ``surrogate`` / the selector form are nondiff
trace constants, exactly as static as they are in the jitted fit/search
kernels that consume this through ``kernels.ops.policy_scan``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np


def _segment_plan(t_bins: int):
    """(seg_len, num_segments, tail_len) with seg_len ≈ √T — the classic
    even split; whatever T leaves over runs as one shorter tail segment."""
    seg = max(1, math.isqrt(max(t_bins, 1)))
    nseg = t_bins // seg
    return seg, nseg, t_bins - nseg * seg


def _bin_step(cfg, params, onehot, pidx):
    """The lane bin-step under ``cfg`` = (dt, surrogate, use_onehot) —
    the exact step ``ref.policy_grid_scan`` scans, so primal values (and
    therefore the rematerialized segments) match it bit for bit."""
    from repro.core.twin import (lane_branches, lane_policy_step,
                                 surrogate_lane_branches)
    dt_hours, surrogate, use_onehot = cfg
    branches = (surrogate_lane_branches() if surrogate
                else lane_branches())
    dt = jnp.asarray(dt_hours, jnp.float32)
    if use_onehot:
        def step(carry, arrive):
            return lane_policy_step(carry, arrive, params, onehot, dt,
                                    branches=branches)
    else:
        def step(carry, arrive):
            return jax.lax.switch(pidx, branches, carry, arrive, params,
                                  dt)
    return step


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ckpt_scan(cfg, params, loads_t, onehot, pidx):
    """Primal: ONE plain scan over all T bins (loads_t [T, N], scenario
    -minor). Returns (carry_end [N, CARRY_DIM], five [T, N] series)."""
    from repro.core.twin import CARRY_DIM
    step = _bin_step(cfg, params, onehot, pidx)
    n = loads_t.shape[1]
    return jax.lax.scan(step, jnp.zeros((n, CARRY_DIM), jnp.float32),
                        loads_t)


def _ckpt_fwd(cfg, params, loads_t, onehot, pidx):
    # residuals are just the primal inputs — segment-entry carries are
    # recomputed in bwd (one series-free replay), keeping fwd free
    return _ckpt_scan(cfg, params, loads_t, onehot, pidx), \
        (params, loads_t, onehot, pidx)


def _ckpt_bwd(cfg, res, cots):
    from repro.core.twin import CARRY_DIM
    params, loads_t, onehot, pidx = res
    g_carry, ct_outs = cots
    t_bins, n = loads_t.shape
    seg, nseg, tail = _segment_plan(t_bins)
    step = _bin_step(cfg, params, onehot, pidx)
    body = t_bins - tail

    def seg_scan(carry0, params_, onehot_, seg_loads):
        # the differentiable segment: same step, params/onehot rebound so
        # jax.vjp hands back their cotangents alongside carry and loads
        s = _bin_step(cfg, params_, onehot_, pidx)
        return jax.lax.scan(s, carry0, seg_loads)

    # forward replay, carry only: entry carries of the nseg body segments
    main = loads_t[:body].reshape(nseg, seg, n)

    def seg_fwd(carry, seg_loads):
        out, _ = jax.lax.scan(lambda c, a: (step(c, a)[0], None), carry,
                              seg_loads)
        return out, carry                       # ys = the ENTRY carry

    c_tail, entries = jax.lax.scan(seg_fwd, jnp.zeros((n, CARRY_DIM),
                                                      jnp.float32), main)

    g_params = jnp.zeros_like(params)
    g_onehot = jnp.zeros_like(onehot)
    g_loads = jnp.zeros_like(loads_t)
    if tail:
        _, tail_vjp = jax.vjp(seg_scan, c_tail, params, onehot,
                              loads_t[body:])
        g_carry, dp, doh, dl = tail_vjp(
            (g_carry, tuple(o[body:] for o in ct_outs)))
        g_params, g_onehot = g_params + dp, g_onehot + doh
        g_loads = g_loads.at[body:].set(dl)

    ct_main = tuple(o[:body].reshape(nseg, seg, n) for o in ct_outs)

    def seg_bwd(state, xs):
        g_c, g_p, g_oh = state
        entry, seg_loads, ct_seg = xs
        _, vjp_fn = jax.vjp(seg_scan, entry, params, onehot, seg_loads)
        dc, dp, doh, dl = vjp_fn((g_c, ct_seg))
        return (dc, g_p + dp, g_oh + doh), dl

    (g_carry, g_params, g_onehot), dls = jax.lax.scan(
        seg_bwd, (g_carry, g_params, g_onehot), (entries, main, ct_main),
        reverse=True)
    g_loads = g_loads.at[:body].set(dls.reshape(body, n))
    return (g_params, g_loads, g_onehot,
            np.zeros(np.shape(pidx), dtype=jax.dtypes.float0))


_ckpt_scan.defvjp(_ckpt_fwd, _ckpt_bwd)


def policy_grid_scan_ckpt(loads, params, onehot=None, dt_hours=1.0, *,
                          policy_index=None, surrogate: bool = False):
    """``ref.policy_grid_scan`` semantics + the O(√T) checkpointed VJP.

    Same operands, selector rule and return contract as the reference
    (loads [N, T] → carry_end [N, CARRY_DIM] + five [N, T] series);
    primal values are bit-identical — only the gradient schedule differs.
    ``dt_hours`` must be a static float here (it is a trace constant of
    the fit/search kernels); ``kernels.ops.policy_scan`` falls back to
    the plain reference when handed a traced bin width.
    """
    if (onehot is None) == (policy_index is None):
        raise ValueError("pass exactly one of onehot= (mixed grid) or "
                         "policy_index= (uniform lane block)")
    loads_t = jnp.asarray(loads, jnp.float32).T
    use_onehot = onehot is not None
    if use_onehot:
        onehot = jnp.asarray(onehot, jnp.float32)
        pidx = jnp.zeros((), jnp.int32)          # inert placeholder
    else:
        onehot = jnp.zeros((loads_t.shape[1], 0), jnp.float32)
        pidx = jnp.asarray(policy_index, jnp.int32)
    cfg = (float(dt_hours), bool(surrogate), use_onehot)
    carry_end, outs_t = _ckpt_scan(cfg, jnp.asarray(params, jnp.float32),
                                   loads_t, onehot, pidx)
    return carry_end, tuple(o.T for o in outs_t)

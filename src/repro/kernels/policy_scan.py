"""Pallas kernel: the whole TwinPolicy (scenario x bin) grid in one scan.

The what-if engine's hot path is a tiny f32 bin-step scanned over T bins
for N scenarios (paper Sec. V-G: only the load *shape* is simulated, so
the grid engine bounds how many scenarios a sweep can afford). The XLA
path (``core.simulate._grid_scan``) runs it as vmap-of-scan with a
``lax.switch`` per scenario; this kernel fuses the whole grid into ONE
``pallas_call``:

* grid = (scenario blocks, time chunks), time minor — each kernel
  instance advances a block of LANES scenarios through one chunk of bins;
* scenarios live on the vector lanes: operand blocks are [chunk, LANES]
  with the scenario axis minor, so each bin-step is straight-line VPU
  vector math over the lane block (``core.twin.lane_policy_step`` — every
  registered policy evaluated and blended by the [LANES, P] one-hot mask,
  no control flow);
* the [LANES, CARRY_DIM] scan carry lives in VMEM scratch and persists
  across time chunks, so HBM sees each load bin exactly once and the
  carry never round-trips (the XLA scan materialises it per step).

On CPU this runs with ``interpret=True`` (tests, this container); the
grid/BlockSpec structure is the TPU layout. ``chunk`` bounds VMEM: a
(chunk x LANES) f32 block per operand/output — the default 546 splits the
8736-hour year into 16 chunks (~280 KB per array at 128 lanes). Horizons
the chunk doesn't divide fall back to a single chunk.

Dispatch through ``kernels.ops.policy_scan`` (the ``use_pallas`` /
``pallas_mode`` switch); the pure-jnp oracle is ``kernels.ref.
policy_grid_scan``. No VJP is defined — gradient users (twin calibration)
pin the reference path, which is the same branchless math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_LANES = 128   # scenario block on the vector lanes
DEFAULT_CHUNK = 546   # 8736-hour year -> 16 time chunks


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _policy_scan_kernel(loads_ref, params_ref, onehot_ref,
                        proc_ref, queue_ref, lat_ref, cost_ref, drop_ref,
                        carry_end_ref, carry_ref, *,
                        step, dt: float, chunk: int, num_chunks: int,
                        carry_dim: int):
    """Grid: (scenario blocks, time chunks) — time minor; carry in scratch."""
    c = pl.program_id(1)
    lanes = loads_ref.shape[1]

    @pl.when(c == 0)
    def _init():
        carry_ref[...] = jnp.zeros((lanes, carry_dim), jnp.float32)

    loads = loads_ref[...]            # [chunk, LANES]
    params = params_ref[...]          # [LANES, PARAM_DIM]
    onehot = onehot_ref[...]          # [LANES, P]
    dt_f = jnp.float32(dt)

    def bin_step(t, state):
        carry, proc, queue, lat, cost, drop = state
        carry, (p, q, l, co, dr) = step(carry, loads[t], params, onehot,
                                        dt_f)
        upd = functools.partial(jax.lax.dynamic_update_slice_in_dim,
                                start_index=t, axis=0)
        return (carry, upd(proc, p[None]), upd(queue, q[None]),
                upd(lat, l[None]), upd(cost, co[None]),
                upd(drop, dr[None]))

    zeros = lambda: jnp.zeros((chunk, lanes), jnp.float32)  # noqa: E731
    carry, proc, queue, lat, cost, drop = jax.lax.fori_loop(
        0, chunk, bin_step,
        (carry_ref[...], zeros(), zeros(), zeros(), zeros(), zeros()))
    carry_ref[...] = carry
    proc_ref[...] = proc
    queue_ref[...] = queue
    lat_ref[...] = lat
    cost_ref[...] = cost
    drop_ref[...] = drop

    @pl.when(c == num_chunks - 1)
    def _fin():
        carry_end_ref[...] = carry


@functools.partial(jax.jit,
                   static_argnames=("dt_hours", "version", "lanes", "chunk",
                                    "interpret"))
def _policy_scan(loads_t: jnp.ndarray, params: jnp.ndarray,
                 onehot: jnp.ndarray, *, dt_hours: float, version: int,
                 lanes: int, chunk: int, interpret: bool):
    """loads_t [T, Npad] (scenarios minor/padded), params [Npad, D],
    onehot [Npad, P]; ``version`` is the policy-registry version (static)
    so late policy registration retraces the branch blend."""
    from repro.core.twin import CARRY_DIM, lane_policy_step
    del version
    t_bins, npad = loads_t.shape
    nb, nc = npad // lanes, t_bins // chunk

    kernel = functools.partial(
        _policy_scan_kernel, step=lane_policy_step, dt=float(dt_hours),
        chunk=chunk, num_chunks=nc, carry_dim=CARRY_DIM)
    series = jax.ShapeDtypeStruct((t_bins, npad), jnp.float32)
    outs = pl.pallas_call(
        kernel,
        grid=(nb, nc),
        in_specs=[
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((lanes, params.shape[1]), lambda i, c: (i, 0)),
            pl.BlockSpec((lanes, onehot.shape[1]), lambda i, c: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((lanes, CARRY_DIM), lambda i, c: (i, 0)),
        ],
        out_shape=[series, series, series, series, series,
                   jax.ShapeDtypeStruct((npad, CARRY_DIM), jnp.float32)],
        scratch_shapes=[_vmem((lanes, CARRY_DIM), jnp.float32)],
        interpret=interpret,
    )(loads_t, params, onehot)
    return outs


def policy_grid_scan(loads: jnp.ndarray, params: jnp.ndarray,
                     onehot: jnp.ndarray, dt_hours: float = 1.0, *,
                     lanes: int = DEFAULT_LANES, chunk: int = DEFAULT_CHUNK,
                     interpret: bool = True):
    """Fused scenario-grid scan; same contract as ``ref.policy_grid_scan``.

    loads [N, T]; params [N, PARAM_DIM]; onehot [N, P]. The scenario axis
    is padded up to a LANES multiple (padded lanes carry an all-zero
    policy mask, so they blend to zeros) and transposed scenario-minor for
    the kernel; outputs come back truncated to N. Returns
    (carry_end [N, CARRY_DIM], (processed, queue, latency, cost, dropped))
    with each series [N, T].
    """
    from repro.core.twin import registry_version
    n, t_bins = loads.shape
    lanes = min(lanes, _round_up(max(n, 1), 8))
    npad = _round_up(max(n, 1), lanes)
    if t_bins % chunk:
        chunk = t_bins
    loads_t = jnp.zeros((t_bins, npad), jnp.float32)
    loads_t = loads_t.at[:, :n].set(jnp.asarray(loads, jnp.float32).T)
    pad = lambda a: jnp.zeros((npad, a.shape[1]), jnp.float32).at[:n].set(  # noqa: E731
        jnp.asarray(a, jnp.float32))
    proc, queue, lat, cost, drop, carry_end = _policy_scan(
        loads_t, pad(params), pad(onehot), dt_hours=float(dt_hours),
        version=registry_version(), lanes=lanes, chunk=chunk,
        interpret=interpret)
    series = tuple(o[:, :n].T for o in (proc, queue, lat, cost, drop))
    return carry_end[:n], series

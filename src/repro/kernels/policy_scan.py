"""Pallas kernel: the whole TwinPolicy (scenario x bin) grid in one scan.

The what-if engine's hot path is a tiny f32 bin-step scanned over T bins
for N scenarios (paper Sec. V-G: only the load *shape* is simulated, so
the grid engine bounds how many scenarios a sweep can afford). The XLA
path (``core.simulate._grid_scan``) runs it as vmap-of-scan with a
``lax.switch`` per scenario; this kernel fuses the whole grid into ONE
``pallas_call``:

* grid = (scenario blocks, time chunks), time minor — each kernel
  instance advances a block of LANES scenarios through one chunk of bins;
* scenarios live on the vector lanes: operand blocks are [chunk, LANES]
  with the scenario axis minor, so each bin-step is straight-line VPU
  vector math over the lane block (``core.twin.lane_policy_step`` — every
  registered policy evaluated and blended by the [LANES, P] one-hot mask,
  no control flow);
* the [LANES, CARRY_DIM] scan carry lives in VMEM scratch and persists
  across time chunks, so HBM sees each load bin exactly once and the
  carry never round-trips (the XLA scan materialises it per step).

On CPU this runs with ``interpret=True`` (tests, this container); the
grid/BlockSpec structure is the TPU layout. ``chunk`` bounds VMEM: a
(chunk x LANES) f32 block per operand/output. The (lanes, chunk) tile
is derived from the device's VMEM budget by ``tile_plan`` (lanes pinned
to the 128-wide VPU lane axis, chunk the largest divisor of the horizon
whose double-buffered operand blocks plus the per-lane resident state
fit the budget) rather than hard-coded year-shaped constants; the plan
is pure integer arithmetic, so interpret mode on CPU asserts the exact
tiles real silicon would get. Horizons an explicitly-passed chunk
doesn't divide fall back to a single chunk.

``policy_grid_agg`` is the STREAMING-AGGREGATE variant of the same
kernel (the O(N)-memory backend of ``simulate_grid(return_series=
False)``): the Table II statistics — twice-compensated sums, per-bin
max, SLO-ok counters and the quarter-octave load-weighted latency
histogram (``core.twin.lane_update_aggregate``, masked compare-adds on
the vector lanes, each bucket a compensated (sum, comp, comp2) triple)
— ride in a second VMEM scratch block across time chunks, and the only
HBM outputs are one [LANES, CARRY_DIM] carry row and one
[LANES, AGG_KDIM] kernel-row per scenario block (recombined to the
public [N, AGG_DIM] layout by ``core.twin.finalize_aggregate``). The
five [N, T] series are never allocated.

Dispatch through ``kernels.ops.policy_scan`` / ``ops.policy_scan_agg``
(the ``use_pallas`` / ``pallas_mode`` switch); the pure-jnp oracles are
``kernels.ref.policy_grid_scan`` / ``ref.policy_grid_agg``. No VJP is
defined — gradient users (twin calibration) pin the reference path,
which is the same branchless math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: VMEM budget the default tile plan targets: half of a 16 MB TPU core
#: VMEM, leaving headroom for compiler spills and semaphores
DEFAULT_VMEM_BYTES = 8 * 2**20
#: hardware vector-lane width the scenario axis is tiled to
LANE_WIDTH = 128
#: operand streams a kernel instance may double-buffer (loads + the two
#: fault streams, x2 for the pipelined next block)
_MAX_STREAM_BUFFERS = 6


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def tile_plan(t_bins: int, param_dim: int,
              vmem_bytes: int = DEFAULT_VMEM_BYTES):
    """(lanes, chunk) kernel tile for a ``t_bins``-hour horizon under a
    VMEM budget — the device-spec-driven replacement for the old
    year-shaped DEFAULT_LANES/DEFAULT_CHUNK constants.

    Lanes are pinned to the 128-wide VPU lane axis (``_stage_operands``
    still shrinks tiny grids below that); the time chunk is the largest
    divisor of ``t_bins`` whose double-buffered [chunk, lanes] operand
    blocks fit what the budget leaves after the per-lane resident state
    (params + one-hot rows, the fault-extended carry scratch, and the
    packed + unpacked aggregate kernel rows). Pure integer arithmetic —
    no device queries — so interpret mode on CPU asserts the exact tiles
    real silicon would get, and a chunk choice can never change results
    (the scan carry and aggregate scratch persist across chunks, so any
    divisor replays the identical per-bin op sequence)."""
    from repro.core.twin import AGG_KDIM, CARRY_DIM, num_policies
    t_bins = max(int(t_bins), 1)
    lanes = LANE_WIDTH
    slots = max(int(vmem_bytes), 0) // (4 * lanes)
    resident = param_dim + num_policies() + (CARRY_DIM + 1) + 2 * AGG_KDIM
    cap = max((slots - resident) // _MAX_STREAM_BUFFERS, 1)
    chunk = next(d for d in range(min(cap, t_bins), 0, -1)
                 if t_bins % d == 0)
    return lanes, chunk


def _policy_scan_kernel(loads_ref, params_ref, onehot_ref,
                        proc_ref, queue_ref, lat_ref, cost_ref, drop_ref,
                        carry_end_ref, carry_ref, *,
                        step, dt: float, chunk: int, num_chunks: int,
                        carry_dim: int):
    """Grid: (scenario blocks, time chunks) — time minor; carry in scratch."""
    c = pl.program_id(1)
    lanes = loads_ref.shape[1]

    @pl.when(c == 0)
    def _init():
        carry_ref[...] = jnp.zeros((lanes, carry_dim), jnp.float32)

    loads = loads_ref[...]            # [chunk, LANES]
    params = params_ref[...]          # [LANES, PARAM_DIM]
    onehot = onehot_ref[...]          # [LANES, P]
    dt_f = jnp.float32(dt)

    def bin_step(t, state):
        carry, proc, queue, lat, cost, drop = state
        carry, (p, q, l, co, dr) = step(carry, loads[t], params, onehot,
                                        dt_f)
        upd = functools.partial(jax.lax.dynamic_update_slice_in_dim,
                                start_index=t, axis=0)
        return (carry, upd(proc, p[None]), upd(queue, q[None]),
                upd(lat, l[None]), upd(cost, co[None]),
                upd(drop, dr[None]))

    zeros = lambda: jnp.zeros((chunk, lanes), jnp.float32)  # noqa: E731
    carry, proc, queue, lat, cost, drop = jax.lax.fori_loop(
        0, chunk, bin_step,
        (carry_ref[...], zeros(), zeros(), zeros(), zeros(), zeros()))
    carry_ref[...] = carry
    proc_ref[...] = proc
    queue_ref[...] = queue
    lat_ref[...] = lat
    cost_ref[...] = cost
    drop_ref[...] = drop

    @pl.when(c == num_chunks - 1)
    def _fin():
        carry_end_ref[...] = carry


@functools.partial(jax.jit,
                   static_argnames=("dt_hours", "version", "lanes", "chunk",
                                    "interpret"))
def _policy_scan(loads_t: jnp.ndarray, params: jnp.ndarray,
                 onehot: jnp.ndarray, *, dt_hours: float, version: int,
                 lanes: int, chunk: int, interpret: bool):
    """loads_t [T, Npad] (scenarios minor/padded), params [Npad, D],
    onehot [Npad, P]; ``version`` is the policy-registry version (static)
    so late policy registration retraces the branch blend."""
    from repro.core.twin import CARRY_DIM, lane_policy_step
    del version
    t_bins, npad = loads_t.shape
    nb, nc = npad // lanes, t_bins // chunk

    kernel = functools.partial(
        _policy_scan_kernel, step=lane_policy_step, dt=float(dt_hours),
        chunk=chunk, num_chunks=nc, carry_dim=CARRY_DIM)
    series = jax.ShapeDtypeStruct((t_bins, npad), jnp.float32)
    outs = pl.pallas_call(
        kernel,
        grid=(nb, nc),
        in_specs=[
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((lanes, params.shape[1]), lambda i, c: (i, 0)),
            pl.BlockSpec((lanes, onehot.shape[1]), lambda i, c: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((lanes, CARRY_DIM), lambda i, c: (i, 0)),
        ],
        out_shape=[series, series, series, series, series,
                   jax.ShapeDtypeStruct((npad, CARRY_DIM), jnp.float32)],
        scratch_shapes=[_vmem((lanes, CARRY_DIM), jnp.float32)],
        interpret=interpret,
    )(loads_t, params, onehot)
    return outs


def _policy_agg_kernel(loads_ref, params_ref, onehot_ref,
                       carry_end_ref, agg_out_ref, carry_ref, agg_ref, *,
                       step, update, pack, unpack, dt: float,
                       slo_limit: float, slo_mode: int, chunk: int,
                       num_chunks: int, carry_dim: int, agg_dim: int):
    """Streaming-aggregate variant: same (scenario blocks, time chunks)
    grid, but BOTH the policy carry and the Table II aggregate state live
    in VMEM scratch and persist across time chunks — no [chunk, LANES]
    output block exists at all, so HBM traffic is the loads in and one
    [LANES, AGG_KDIM] row out per scenario block. Inside the bin loop the
    aggregate state is the unpacked pytree (pure vector arithmetic); the
    packed [LANES, AGG_KDIM] form only exists at chunk boundaries, where
    it round-trips through the scratch block."""
    c = pl.program_id(1)
    lanes = loads_ref.shape[1]

    @pl.when(c == 0)
    def _init():
        carry_ref[...] = jnp.zeros((lanes, carry_dim), jnp.float32)
        agg_ref[...] = jnp.zeros((lanes, agg_dim), jnp.float32)

    loads = loads_ref[...]            # [chunk, LANES]
    params = params_ref[...]          # [LANES, PARAM_DIM]
    onehot = onehot_ref[...]          # [LANES, P]
    dt_f = jnp.float32(dt)

    def bin_step(t, state):
        carry, agg = state
        carry, outs = step(carry, loads[t], params, onehot, dt_f)
        agg = update(agg, loads[t], outs, slo_limit, slo_mode)
        return carry, agg

    carry, agg = jax.lax.fori_loop(0, chunk, bin_step,
                                   (carry_ref[...], unpack(agg_ref[...])))
    packed = pack(agg)
    carry_ref[...] = carry
    agg_ref[...] = packed

    @pl.when(c == num_chunks - 1)
    def _fin():
        carry_end_ref[...] = carry
        agg_out_ref[...] = packed


def _policy_agg_fault_kernel(loads_ref, caps_ref, fmask_ref, params_ref,
                             onehot_ref, carry_end_ref, agg_out_ref,
                             carry_ref, agg_ref, *, step, update, pack,
                             unpack, dt: float, slo_limit: float,
                             slo_mode: int, chunk: int, num_chunks: int,
                             carry_dim: int, agg_dim: int):
    """Fault-schedule variant of ``_policy_agg_kernel``: two extra
    scenario-minor input streams (capacity multipliers + in-fault masks,
    same [chunk, LANES] blocks as the loads) and the fault-layer backlog
    queue riding as one extra column of the VMEM carry scratch. Padded
    lanes stream zero capacity AND zero load, so the fault gate holds
    their backlog at exactly zero. The final carry row folds the backlog
    into the queue slot (records conservation: offered = processed +
    dropped + carry_end[:, 0])."""
    c = pl.program_id(1)
    lanes = loads_ref.shape[1]

    @pl.when(c == 0)
    def _init():
        carry_ref[...] = jnp.zeros((lanes, carry_dim + 1), jnp.float32)
        agg_ref[...] = jnp.zeros((lanes, agg_dim), jnp.float32)

    loads = loads_ref[...]            # [chunk, LANES]
    caps = caps_ref[...]              # [chunk, LANES]
    fmask = fmask_ref[...]            # [chunk, LANES]
    params = params_ref[...]          # [LANES, PARAM_DIM]
    onehot = onehot_ref[...]          # [LANES, P]
    dt_f = jnp.float32(dt)

    def bin_step(t, state):
        carry, fq, agg = state
        (carry, fq), outs = step((carry, fq), loads[t], caps[t], params,
                                 onehot, dt_f)
        agg = update(agg, loads[t], outs, slo_limit, slo_mode, fmask[t])
        return carry, fq, agg

    cf = carry_ref[...]
    carry, fq, agg = jax.lax.fori_loop(
        0, chunk, bin_step,
        (cf[:, :carry_dim], cf[:, carry_dim], unpack(agg_ref[...])))
    packed = pack(agg)
    carry_ref[...] = jnp.concatenate([carry, fq[:, None]], axis=1)
    agg_ref[...] = packed

    @pl.when(c == num_chunks - 1)
    def _fin():
        carry_end_ref[...] = jnp.concatenate(
            [(carry[:, 0] + fq)[:, None], carry[:, 1:]], axis=1)
        agg_out_ref[...] = packed


@functools.partial(jax.jit,
                   static_argnames=("dt_hours", "slo_limit", "slo_mode",
                                    "version", "lanes", "chunk",
                                    "interpret"))
def _policy_agg_fault(loads_t: jnp.ndarray, caps_t: jnp.ndarray,
                      fmask_t: jnp.ndarray, params: jnp.ndarray,
                      onehot: jnp.ndarray, *, dt_hours: float,
                      slo_limit: float, slo_mode: int, version: int,
                      lanes: int, chunk: int, interpret: bool):
    """Fault twin of ``_policy_agg``: identical grid and output layout,
    plus the two [T, Npad] fault operand streams."""
    from repro.core.twin import (AGG_KDIM, CARRY_DIM,
                                 fault_lane_policy_step,
                                 lane_update_aggregate, pack_aggregate,
                                 unpack_aggregate)
    del version
    t_bins, npad = loads_t.shape
    nb, nc = npad // lanes, t_bins // chunk

    kernel = functools.partial(
        _policy_agg_fault_kernel, step=fault_lane_policy_step,
        update=lane_update_aggregate, pack=pack_aggregate,
        unpack=unpack_aggregate, dt=float(dt_hours),
        slo_limit=float(slo_limit), slo_mode=int(slo_mode), chunk=chunk,
        num_chunks=nc, carry_dim=CARRY_DIM, agg_dim=AGG_KDIM)
    stream = pl.BlockSpec((chunk, lanes), lambda i, c: (c, i))
    return pl.pallas_call(
        kernel,
        grid=(nb, nc),
        in_specs=[
            stream, stream, stream,
            pl.BlockSpec((lanes, params.shape[1]), lambda i, c: (i, 0)),
            pl.BlockSpec((lanes, onehot.shape[1]), lambda i, c: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((lanes, CARRY_DIM), lambda i, c: (i, 0)),
            pl.BlockSpec((lanes, AGG_KDIM), lambda i, c: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((npad, CARRY_DIM), jnp.float32),
                   jax.ShapeDtypeStruct((npad, AGG_KDIM), jnp.float32)],
        scratch_shapes=[_vmem((lanes, CARRY_DIM + 1), jnp.float32),
                        _vmem((lanes, AGG_KDIM), jnp.float32)],
        interpret=interpret,
    )(loads_t, caps_t, fmask_t, params, onehot)


@functools.partial(jax.jit,
                   static_argnames=("dt_hours", "slo_limit", "slo_mode",
                                    "version", "lanes", "chunk",
                                    "interpret"))
def _policy_agg(loads_t: jnp.ndarray, params: jnp.ndarray,
                onehot: jnp.ndarray, *, dt_hours: float, slo_limit: float,
                slo_mode: int, version: int, lanes: int, chunk: int,
                interpret: bool):
    """Aggregate twin of ``_policy_scan``: same operand layout, O(N)
    outputs (carry_end [Npad, CARRY_DIM], agg [Npad, AGG_KDIM])."""
    from repro.core.twin import (AGG_KDIM, CARRY_DIM, lane_policy_step,
                                 lane_update_aggregate, pack_aggregate,
                                 unpack_aggregate)
    del version
    t_bins, npad = loads_t.shape
    nb, nc = npad // lanes, t_bins // chunk

    kernel = functools.partial(
        _policy_agg_kernel, step=lane_policy_step,
        update=lane_update_aggregate, pack=pack_aggregate,
        unpack=unpack_aggregate, dt=float(dt_hours),
        slo_limit=float(slo_limit), slo_mode=int(slo_mode), chunk=chunk,
        num_chunks=nc, carry_dim=CARRY_DIM, agg_dim=AGG_KDIM)
    return pl.pallas_call(
        kernel,
        grid=(nb, nc),
        in_specs=[
            pl.BlockSpec((chunk, lanes), lambda i, c: (c, i)),
            pl.BlockSpec((lanes, params.shape[1]), lambda i, c: (i, 0)),
            pl.BlockSpec((lanes, onehot.shape[1]), lambda i, c: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((lanes, CARRY_DIM), lambda i, c: (i, 0)),
            pl.BlockSpec((lanes, AGG_KDIM), lambda i, c: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((npad, CARRY_DIM), jnp.float32),
                   jax.ShapeDtypeStruct((npad, AGG_KDIM), jnp.float32)],
        scratch_shapes=[_vmem((lanes, CARRY_DIM), jnp.float32),
                        _vmem((lanes, AGG_KDIM), jnp.float32)],
        interpret=interpret,
    )(loads_t, params, onehot)


def _stage_operands(loads, loads_t, lanes, chunk, param_dim):
    """Common operand staging for both wrappers: accepts EXACTLY one of
    ``loads`` [N, T] (scenario-major, the historical API — transposed and
    zero-padded into the kernel layout) or ``loads_t`` [T, N] (already
    scenario-minor: the grid engine's block gathers produce this layout
    directly, so handing it over skips the [N, T] transpose copy that
    used to dominate per-block staging — the PR 3/4 layout follow-on).
    ``lanes`` / ``chunk`` = None resolve through ``tile_plan`` for the
    horizon at hand. Returns (n, t_bins, npad, lanes, chunk,
    staged_loads_t)."""
    if (loads is None) == (loads_t is None):
        raise ValueError("pass exactly one of loads= ([N, T]) or "
                         "loads_t= ([T, N] scenario-minor)")
    if loads_t is None:
        n, t_bins = loads.shape
    else:
        t_bins, n = loads_t.shape
    if lanes is None or chunk is None:
        plan = tile_plan(int(t_bins), int(param_dim))
        lanes = plan[0] if lanes is None else lanes
        chunk = plan[1] if chunk is None else chunk
    lanes = min(lanes, _round_up(max(n, 1), 8))
    npad = _round_up(max(n, 1), lanes)
    if t_bins % chunk:
        chunk = t_bins
    if loads_t is None:
        staged = jnp.zeros((t_bins, npad), jnp.float32)
        staged = staged.at[:, :n].set(jnp.asarray(loads, jnp.float32).T)
    else:
        staged = jnp.asarray(loads_t, jnp.float32)
        if npad != n:   # no-op (and no copy) when already lane-aligned
            staged = jnp.pad(staged, ((0, 0), (0, npad - n)))
    return n, t_bins, npad, lanes, chunk, staged


def _stage_aux(aux, aux_t, t_bins: int, n: int, npad: int, what: str):
    """Stage one optional per-bin fault stream into the kernel's [T, Npad]
    scenario-minor layout (zero-padded: a zero capacity multiplier on a
    zero-load padded lane keeps its fault backlog at exactly zero)."""
    if aux is None and aux_t is None:
        return None
    if (aux is None) == (aux_t is None):
        raise ValueError(f"pass exactly one of {what}= ([N, T]) or "
                         f"{what}_t= ([T, N] scenario-minor)")
    if aux_t is None:
        staged = jnp.zeros((t_bins, npad), jnp.float32)
        return staged.at[:, :n].set(jnp.asarray(aux, jnp.float32).T)
    staged = jnp.asarray(aux_t, jnp.float32)
    if npad != n:
        staged = jnp.pad(staged, ((0, 0), (0, npad - n)))
    return staged


def policy_grid_agg(loads: jnp.ndarray | None, params: jnp.ndarray,
                    onehot: jnp.ndarray, dt_hours: float = 1.0, *,
                    slo_limit: float = float("inf"), slo_mode: int = 0,
                    lanes: int = None, chunk: int = None,
                    interpret: bool = True, loads_t=None, caps=None,
                    fmask=None, caps_t=None, fmask_t=None,
                    finalize: bool = True):
    """Fused streaming-aggregate grid scan; semantics of
    ``ref.policy_grid_agg``. Same padding/transposition contract as
    ``policy_grid_scan``, but the only outputs are O(N): per-scenario
    final carries and the [AGG_DIM] aggregate rows — the five [N, T]
    series are never allocated, on HBM or anywhere else. ``slo_limit`` /
    ``slo_mode`` are static (see ``core.twin.AGG_SLO_*``). Pass
    ``loads_t=`` ([T, N], ``loads=None``) to hand over operands already
    in the kernel's scenario-minor layout. A fault schedule's capacity /
    in-fault streams ride along as ``caps``/``fmask`` (or the
    scenario-minor ``caps_t``/``fmask_t``) and select the fault kernel
    variant (``_policy_agg_fault_kernel``). ``finalize=False`` returns
    the raw [N, AGG_KDIM] kernel rows (per-bucket compensated triples)
    for drivers that recombine once at the end of a block loop
    (``core.twin.finalize_aggregate_x64``). Returns
    (carry_end [N, CARRY_DIM], agg [N, AGG_DIM]).
    """
    from repro.core.twin import finalize_aggregate_x64, registry_version
    n, t_bins, npad, lanes, chunk, loads_t = _stage_operands(
        loads, loads_t, lanes, chunk, params.shape[1])
    pad = lambda a: jnp.zeros((npad, a.shape[1]), jnp.float32).at[:n].set(  # noqa: E731
        jnp.asarray(a, jnp.float32))
    caps_t = _stage_aux(caps, caps_t, t_bins, n, npad, "caps")
    fmask_t = _stage_aux(fmask, fmask_t, t_bins, n, npad, "fmask")
    if (caps_t is None) != (fmask_t is None):
        raise ValueError("pass caps and fmask together (or neither)")
    if caps_t is not None:
        carry_end, agg = _policy_agg_fault(
            loads_t, caps_t, fmask_t, pad(params), pad(onehot),
            dt_hours=float(dt_hours), slo_limit=float(slo_limit),
            slo_mode=int(slo_mode), version=registry_version(),
            lanes=lanes, chunk=chunk, interpret=interpret)
    else:
        carry_end, agg = _policy_agg(
            loads_t, pad(params), pad(onehot), dt_hours=float(dt_hours),
            slo_limit=float(slo_limit), slo_mode=int(slo_mode),
            version=registry_version(), lanes=lanes, chunk=chunk,
            interpret=interpret)
    if finalize:
        agg = finalize_aggregate_x64(agg)
    return carry_end[:n], agg[:n]


def policy_grid_scan(loads: jnp.ndarray | None, params: jnp.ndarray,
                     onehot: jnp.ndarray, dt_hours: float = 1.0, *,
                     lanes: int = None, chunk: int = None,
                     interpret: bool = True, loads_t=None):
    """Fused scenario-grid scan; same contract as ``ref.policy_grid_scan``.

    loads [N, T]; params [N, PARAM_DIM]; onehot [N, P]. The scenario axis
    is padded up to a LANES multiple (padded lanes carry an all-zero
    policy mask, so they blend to zeros) and transposed scenario-minor for
    the kernel; outputs come back truncated to N. ``loads_t=`` ([T, N],
    with ``loads=None``) skips the transpose for callers that already
    hold the kernel layout. Returns
    (carry_end [N, CARRY_DIM], (processed, queue, latency, cost, dropped))
    with each series [N, T].
    """
    from repro.core.twin import registry_version
    n, t_bins, npad, lanes, chunk, loads_t = _stage_operands(
        loads, loads_t, lanes, chunk, params.shape[1])
    pad = lambda a: jnp.zeros((npad, a.shape[1]), jnp.float32).at[:n].set(  # noqa: E731
        jnp.asarray(a, jnp.float32))
    proc, queue, lat, cost, drop, carry_end = _policy_scan(
        loads_t, pad(params), pad(onehot), dt_hours=float(dt_hours),
        version=registry_version(), lanes=lanes, chunk=chunk,
        interpret=interpret)
    series = tuple(o[:, :n].T for o in (proc, queue, lat, cost, drop))
    return carry_end[:n], series

"""Pure-jnp reference oracles for every Pallas kernel.

These are the *semantics* of the kernels: small, obviously-correct jnp code.
Kernel tests sweep shapes/dtypes and assert_allclose against these. The
dry-run lowers these XLA paths (CPU container); on real TPU `ops.py` flips to
the Pallas implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
         causal: bool = True, scale: float | None = None,
         logit_cap: float = 0.0, kv_len: jnp.ndarray | None = None) -> jnp.ndarray:
    """Grouped-query scaled dot-product attention.

    q: [b, sq, h, dq]   k: [b, sk, kh, dq]   v: [b, sk, kh, dv]
    h must be a multiple of kh. kv_len: [b] optional valid KV prefix length
    (decode masking). Returns [b, sq, h, dv].
    """
    b, sq, h, dq = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    scale = scale if scale is not None else dq ** -0.5
    qg = q.reshape(b, sq, kh, g, dq)
    # operands stay in model dtype (a bf16 KV cache must cross the network
    # in bf16); accumulation is fp32 via preferred_element_type.
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if logit_cap > 0.0:
        logits = logit_cap * jnp.tanh(logits / logit_cap)
    mask = None
    if causal and sq > 1:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
    if kv_len is not None:
        valid = jnp.arange(sk)[None, :] < kv_len[:, None]          # [b, sk]
        vmask = valid[:, None, None, None, :]
        mask = vmask if mask is None else (mask[None, None, None] & vmask)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, -1)


def sdpa_blocked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                 causal: bool = True, scale: float | None = None,
                 chunk: int = 1024) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure jnp: O(s*chunk) memory
    instead of O(s^2). Same semantics as sdpa (no kv_len/logit_cap support).
    This is the XLA fallback of the Pallas flash kernel — the dry-run lowers
    this for long-sequence prefill so memory_analysis reflects the deployed
    algorithm."""
    b, sq, h, dq = q.shape
    _, sk, kh, dv = k.shape[0], k.shape[1], k.shape[2], v.shape[3]
    g = h // kh
    scale = scale if scale is not None else dq ** -0.5
    nq, nk = sq // chunk, sk // chunk
    assert nq * chunk == sq and nk * chunk == sk, (sq, sk, chunk)
    qg = q.reshape(b, nq, chunk, kh, g, dq)
    kc = k.reshape(b, nk, chunk, kh, dq)
    vc = v.reshape(b, nk, chunk, kh, dv)

    def q_block(qi, qb):
        # qb: [b, chunk, kh, g, dq]
        m0 = jnp.full((b, kh, g, chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kh, g, chunk), jnp.float32)
        a0 = jnp.zeros((b, kh, g, chunk, dv), jnp.float32)

        def kv_block(carry, ki):
            m, l, acc = carry
            logits = jnp.einsum("bckgd,bskd->bkgcs", qb.astype(jnp.float32),
                                kc[:, ki].astype(jnp.float32)) * scale
            if causal:
                qpos = qi * chunk + jnp.arange(chunk)
                kpos = ki * chunk + jnp.arange(chunk)
                mask = qpos[:, None] >= kpos[None, :]
                logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgcs,bskd->bkgcd", p, vc[:, ki].astype(jnp.float32))
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)        # [b, chunk, kh, g, dv]

    outs = jax.lax.map(lambda i: q_block(i, qg[:, i]), jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, kh, g, dv)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def _fault_switch_step(policy_index, branches, params, dt):
    """Fault-layer wrapper for the uniform-policy ``lax.switch`` step —
    same arithmetic (in the same order) as
    ``core.twin.fault_lane_policy_step``, with the single-branch switch
    in place of the masked blend."""
    def fbin_step(state, arrive, capmul):
        carry, fq = state
        gate = (capmul > 0).astype(jnp.float32)
        avail = fq + arrive
        a_eff = gate * avail
        new_fq = avail - a_eff
        p_eff = jnp.concatenate([(params[:, 0] * capmul)[:, None],
                                 params[:, 1:]], axis=1)
        carry, outs = jax.lax.switch(policy_index, branches, carry, a_eff,
                                     p_eff, dt)
        wait = new_fq / jnp.maximum(params[:, 0], jnp.float32(1e-9))
        outs = (outs[0], outs[1] + new_fq, outs[2] + wait, outs[3],
                outs[4])
        return (carry, new_fq), outs
    return fbin_step


def policy_grid_scan(loads: jnp.ndarray, params: jnp.ndarray,
                     onehot: jnp.ndarray = None, dt_hours=1.0,
                     policy_index=None, surrogate: bool = False,
                     caps: jnp.ndarray = None):
    """TwinPolicy scenario-grid scan, lane form — the semantics of the
    Pallas kernel (``kernels/policy_scan.py``).

    loads: [N, T] records/bin; params: [N, PARAM_DIM]. One ``lax.scan``
    over the T bins steps ALL N scenarios at once through the
    lane-vectorized policy steps. The branch selector is exactly one of:

    * ``onehot`` [N, P] (see ``core.twin.policy_onehot``) — mixed-policy
      grid: every registered policy evaluated on every lane and blended
      by the mask (``core.twin.lane_policy_step``), which is what
      ``vmap`` of the ``lax.switch`` step lowers to;
    * ``policy_index`` (scalar, may be traced) — a uniform-policy lane
      block (e.g. the K restarts of one calibration fit): a single
      ``lax.switch`` picks that policy's lane step per bin, so only one
      branch executes at runtime instead of all P.

    Pure jnp and differentiable w.r.t. ``params`` (the Pallas kernel has
    no VJP, so gradient users — twin calibration — pin this path).
    ``surrogate=True`` swaps in the smooth-surrogate lane branches
    (``core.twin.surrogate_lane_branches``) so hard-gated policy extras
    (quickscale/autoscale ceil, batch_window's flush comparison) carry
    gradients — the form ``repro.search`` differentiates.

    ``caps`` [N, T] (optional) is a per-bin capacity-multiplier series
    from a fault schedule (``repro.faults``): the scan steps through
    ``core.twin.fault_lane_policy_step`` instead, carrying a fault-layer
    backlog queue whose residue folds into ``carry_end[:, 0]``. Still
    differentiable w.r.t. ``params`` — the chance-constrained search
    grad path runs exactly this scan.

    Returns (carry_end [N, CARRY_DIM], (processed, queue, latency, cost,
    dropped)) with each series [N, T].
    """
    from repro.core.twin import (CARRY_DIM, fault_lane_policy_step,
                                 lane_branches,  # late: avoid a
                                 lane_policy_step,  # kernels<->core cycle
                                 surrogate_lane_branches)
    if (onehot is None) == (policy_index is None):
        raise ValueError("pass exactly one of onehot= (mixed grid) or "
                         "policy_index= (uniform lane block)")
    n = loads.shape[0]
    dt = jnp.asarray(dt_hours, jnp.float32)
    branches = surrogate_lane_branches() if surrogate else lane_branches()

    if caps is not None:
        if onehot is not None:
            def fbin_step(state, xs):
                arrive, capmul = xs
                return fault_lane_policy_step(state, arrive, capmul,
                                              params, onehot, dt,
                                              branches=branches)
        else:
            fstep = _fault_switch_step(policy_index, branches, params, dt)

            def fbin_step(state, xs):
                return fstep(state, xs[0], xs[1])

        (carry_end, fq_end), outs = jax.lax.scan(
            fbin_step, (jnp.zeros((n, CARRY_DIM), jnp.float32),
                        jnp.zeros((n,), jnp.float32)),
            (loads.T, caps.T))
        carry_end = carry_end.at[:, 0].add(fq_end)
        return carry_end, tuple(o.T for o in outs)

    if onehot is not None:
        def bin_step(carry, arrive):
            return lane_policy_step(carry, arrive, params, onehot, dt,
                                    branches=branches)
    else:
        def bin_step(carry, arrive):
            return jax.lax.switch(policy_index, branches, carry,
                                  arrive, params, dt)

    carry_end, outs = jax.lax.scan(
        bin_step, jnp.zeros((n, CARRY_DIM), jnp.float32), loads.T)
    return carry_end, tuple(o.T for o in outs)


def policy_grid_agg(loads: jnp.ndarray, params: jnp.ndarray,
                    onehot: jnp.ndarray = None, dt_hours=1.0, *,
                    policy_index=None, slo_limit: float = float("inf"),
                    slo_mode: int = 0, caps: jnp.ndarray = None,
                    fmask: jnp.ndarray = None):
    """Streaming-aggregate scenario-grid scan, lane form — the semantics
    of the Pallas aggregate kernel (``kernels/policy_scan.py``).

    Same operands and branch selection as ``policy_grid_scan``, but the
    Table II statistics are folded into the scan carry
    (``core.twin.lane_update_aggregate``) and NO per-bin series is kept:
    the scan emits nothing (``ys=None``), so memory is O(N) regardless of
    the horizon. ``slo_limit`` / ``slo_mode`` are static trace constants
    selecting which value stream feeds the SLO-ok counters
    (``core.twin.AGG_SLO_*``; ``inf`` when no SLO applies).

    ``caps`` / ``fmask`` [N, T] (optional, together) are the per-bin
    capacity-multiplier and in-fault-indicator series of a fault
    schedule: the policy steps through the fault layer
    (``core.twin.fault_lane_policy_step``), the SLO counters stay
    weighted by the OFFERED load (fault-layer backlog shows up as queue
    and latency, not as vanished records), ``fmask`` drives the
    A_FLTH/A_FOKH attribution counters, and the fault backlog residue
    folds into ``carry_end[:, 0]``.

    Returns (carry_end [N, CARRY_DIM], agg [N, AGG_DIM]) — the packed
    kernel rows (compensated per-bucket histogram triples) are
    recombined to the public AGG_DIM layout through
    ``core.twin.finalize_aggregate_x64`` before returning.
    """
    from repro.core.twin import (CARRY_DIM, fault_lane_policy_step,
                                 finalize_aggregate_x64,
                                 init_aggregate,  # late: avoid
                                 lane_branches, lane_policy_step,  # cycle
                                 lane_update_aggregate, pack_aggregate)
    if (onehot is None) == (policy_index is None):
        raise ValueError("pass exactly one of onehot= (mixed grid) or "
                         "policy_index= (uniform lane block)")
    if (caps is None) != (fmask is None):
        raise ValueError("pass caps= and fmask= together (or neither)")
    n = loads.shape[0]
    dt = jnp.asarray(dt_hours, jnp.float32)

    if caps is not None:
        if policy_index is not None:
            fstep = _fault_switch_step(policy_index, lane_branches(),
                                       params, dt)

        def fbin_step(state, xs):
            arrive, capmul, fm = xs
            (carry, fq), agg = state
            if onehot is not None:
                (carry, fq), outs = fault_lane_policy_step(
                    (carry, fq), arrive, capmul, params, onehot, dt)
            else:
                (carry, fq), outs = fstep((carry, fq), arrive, capmul)
            agg = lane_update_aggregate(agg, arrive, outs, slo_limit,
                                        slo_mode, fm)
            return ((carry, fq), agg), None

        (((carry_end, fq_end), agg), _) = jax.lax.scan(
            fbin_step, ((jnp.zeros((n, CARRY_DIM), jnp.float32),
                         jnp.zeros((n,), jnp.float32)),
                        init_aggregate((n,))),
            (loads.T, caps.T, fmask.T))
        carry_end = carry_end.at[:, 0].add(fq_end)
        return carry_end, finalize_aggregate_x64(pack_aggregate(agg))

    def bin_step(state, arrive):
        carry, agg = state
        if onehot is not None:
            carry, outs = lane_policy_step(carry, arrive, params, onehot,
                                           dt)
        else:
            carry, outs = jax.lax.switch(policy_index, lane_branches(),
                                         carry, arrive, params, dt)
        agg = lane_update_aggregate(agg, arrive, outs, slo_limit, slo_mode)
        return (carry, agg), None

    (carry_end, agg), _ = jax.lax.scan(
        bin_step, (jnp.zeros((n, CARRY_DIM), jnp.float32),
                   init_aggregate((n,))), loads.T)
    return carry_end, finalize_aggregate_x64(pack_aggregate(agg))


def rwkv6_scan(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               w: jnp.ndarray, u: jnp.ndarray,
               state: jnp.ndarray | None = None):
    """RWKV-6 linear-attention recurrence with data-dependent decay.

    r,k,v,w: [b, s, h, n] (w is the *decay*, already exp(-exp(.)) in (0,1));
    u: [h, n] bonus. state: [b, h, n, n] (key x value). Returns (out, state):
    out [b, s, h, n], final state.
      o_t = r_t . (S + u * k_t v_t^T);  S' = diag(w_t) S + k_t v_t^T
    """
    b, s, h, n = r.shape
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)
    state = state.astype(jnp.float32)

    def step(S, t):
        rt, kt, vt, wt = (x[:, t].astype(jnp.float32) for x in (r, k, v, w))
        kv = kt[..., :, None] * vt[..., None, :]                   # [b,h,n,n]
        ot = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, ot

    state, outs = jax.lax.scan(step, state, jnp.arange(s))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, n)             # [b,s,h,n]
    return out.astype(r.dtype), state


def ssm_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray,
             state: jnp.ndarray | None = None):
    """Mamba selective-scan.

    x, dt: [b, s, di]; A: [di, n]; B, C: [b, s, n]; D: [di].
    state: [b, di, n]. h' = exp(dt A) h + dt B x ; y = C.h + D x.
    Returns (y [b, s, di], final state).
    """
    b, s, di = x.shape
    n = A.shape[-1]
    if state is None:
        state = jnp.zeros((b, di, n), jnp.float32)
    state = state.astype(jnp.float32)

    def step(h, t):
        xt = x[:, t].astype(jnp.float32)                           # [b,di]
        dtt = dt[:, t].astype(jnp.float32)                         # [b,di]
        Bt = B[:, t].astype(jnp.float32)                           # [b,n]
        Ct = C[:, t].astype(jnp.float32)                           # [b,n]
        dA = jnp.exp(dtt[..., None] * A[None])                     # [b,di,n]
        dBx = (dtt * xt)[..., None] * Bt[:, None, :]               # [b,di,n]
        h = dA * h + dBx
        yt = jnp.einsum("bdn,bn->bd", h, Ct) + D[None] * xt
        return h, yt

    state, ys = jax.lax.scan(step, state, jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1)                                     # [b,s,di]
    return y.astype(x.dtype), state

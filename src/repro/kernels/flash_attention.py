"""Pallas TPU flash attention (forward) with GQA and causal masking.

TPU-native adaptation: online-softmax tiling where the KV loop is the minor
(sequential) grid dimension; m/l/acc accumulators live in VMEM scratch and
persist across KV steps, so HBM traffic is O(s*d) per head instead of
O(s^2). Block shapes are MXU-aligned (multiples of 128 on the contracting
and lane dims). Validated against ref.sdpa in interpret mode on CPU; on
real TPU the same pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  num_k_blocks: int):
    """Grid: (bh, nq, nk) — nk is minor/sequential; scratch persists."""
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: kv block strictly above the diagonal contributes nothing
    need = (j * block_k <= i * block_q + block_q - 1) if causal else True

    @pl.when(need if causal else (j >= 0))
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0].astype(jnp.float32)                  # [bk, dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                          (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                          (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = m_new

    @pl.when(j == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [b, sq, h, d]; k/v: [b, sk, kh, d]. Returns [b, sq, h, dv]."""
    b, sq, h, d = q.shape
    _, sk, kh, dv = v.shape
    g = h // kh
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k

    # layout: fold heads into the leading grid dim; kv head = head // g
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kh_ = k.transpose(0, 2, 1, 3).reshape(b * kh, sk, d)
    vh = v.transpose(0, 2, 1, 3).reshape(b * kh, sk, dv)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k,
                               num_k_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, i, j, g=g: (bh // g, j, 0)),
            pl.BlockSpec((1, block_k, dv),
                         lambda bh, i, j, g=g: (bh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, dv), q.dtype),
        scratch_shapes=[
            _vmem((block_q,), jnp.float32),        # m: running max
            _vmem((block_q,), jnp.float32),        # l: running denom
            _vmem((block_q, dv), jnp.float32),     # acc: running numerator
        ],
        interpret=interpret,
    )(qh, kh_, vh)
    return out.reshape(b, h, sq, dv).transpose(0, 2, 1, 3)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)

"""Pallas TPU kernel for the RWKV-6 WKV recurrence (chunked linear attention).

TPU-native adaptation of the CUDA wkv6 kernel: instead of a per-token
recurrence (serial, VPU-bound), the sequence is processed in chunks. Within
a chunk the contribution decomposes into MXU matmuls:

  A_t   = prod_{j<t} w_j                (cumulative decay inside the chunk)
  o_t   = (r_t*A_t) . S_chunk_start                      [carry term]
        + sum_{i<t} ((r_t*A_t).(k_i/A_{i+1})) v_i        [intra, strict tri]
        + ((r_t*u).k_t) v_t                              [bonus diagonal]
  S'    = diag(A_end) S + sum_i (A_end/A_{i+1}) k_i v_i^T

The chunk state S (head_dim x head_dim, fp32) lives in VMEM scratch and
persists across the chunk grid steps, so HBM traffic is O(s*n) instead of
the O(s*n^2) a naive XLA scan would incur. Chunks of 32 keep the decay
products in fp32 range for realistic decays.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                o_ref, sT_ref, state_ref, *, chunk: int, num_chunks: int):
    """Grid: (b*h, nc) — nc sequential; state scratch persists per (b,h)."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # [C, n]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)          # decay in (0,1)
    u = u_ref[0].astype(jnp.float32)          # [1, n] bonus

    # Exponent clamp: the factorized decay products exp(+cum) can overflow
    # for extreme data-dependent decays; +/-CLAMP keeps every representable
    # pair product exact (pairs beyond e^-CLAMP have decayed to zero).
    CLAMP = 80.0
    logw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(logw, axis=0)            # inclusive: log prod_{j<=t}
    A_t = jnp.exp(jnp.maximum(cum - logw, -2 * CLAMP))   # prod_{j<t}
    A_end = jnp.exp(jnp.maximum(cum[-1], -2 * CLAMP))    # whole chunk
    inv_Ai1 = jnp.exp(jnp.minimum(-cum, CLAMP))          # 1 / prod_{j<=i}

    rd = r * A_t                              # [C, n]
    kd = k * inv_Ai1                          # [C, n]

    S = state_ref[...]                        # [n, n]
    carry = jax.lax.dot_general(rd, S, (((1,), (0,)), ((), ())))   # [C, n]

    scores = jax.lax.dot_general(rd, kd, (((1,), (1,)), ((), ())))  # [C, C]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(ti > tj, scores, 0.0)                        # strict
    intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())))

    bonus = ((r * u) * k).sum(axis=1, keepdims=True) * v            # diag
    o_ref[0] = (carry + intra + bonus).astype(o_ref.dtype)

    kv = jax.lax.dot_general(k * (A_end[None] * inv_Ai1), v,
                             (((0,), (0,)), ((), ())))              # [n, n]
    state_ref[...] = A_end[:, None] * S + kv

    @pl.when(c == num_chunks - 1)
    def _fin():
        sT_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
          u: jnp.ndarray, state: Optional[jnp.ndarray] = None, *,
          chunk: int = 16, interpret: bool = True
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v,w: [b, s, h, n]; u: [h, n]; state: [b, h, n, n] or None."""
    b, s, h, n = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, x.shape[-1])

    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    uf = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, 1, n)
    s0 = state.reshape(b * h, n, n).astype(jnp.float32)

    kernel = functools.partial(_wkv_kernel, chunk=chunk, num_chunks=nc)
    o, sT = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, 1, n), lambda bh, c: (bh, 0, 0)),
            pl.BlockSpec((1, n, n), lambda bh, c: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, n, n), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, n), r.dtype),
            jax.ShapeDtypeStruct((b * h, n, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((n, n), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0)
    out = o.reshape(b, h, s, n).transpose(0, 2, 1, 3)
    return out, sT.reshape(b, h, n, n)

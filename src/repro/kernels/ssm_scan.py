"""Pallas TPU kernel for the Mamba-1 selective scan.

TPU adaptation: the CUDA kernel's warp-parallel scan becomes a
channel-blocked chunked scan — grid (batch, d_inner blocks, seq chunks),
seq minor/sequential; the per-channel SSM state [d_blk, n] lives in VMEM
scratch and persists across chunks, so HBM sees each input once and the
state never round-trips (the naive XLA scan writes [di, n] per step).
Inside a chunk the recurrence over time runs as a fori_loop on VMEM values
(elementwise VPU work; the heavy projections around the scan are MXU
matmuls that live OUTSIDE this kernel in the mamba block).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _ssm_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, s0_ref,
                y_ref, sT_ref, state_ref, *, chunk: int, num_chunks: int):
    """Grid: (b, d_blocks, nc) — nc minor; state scratch [d_blk, n]."""
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # [C, d_blk]
    dt = dt_ref[0].astype(jnp.float32)        # [C, d_blk]
    A = A_ref[...].astype(jnp.float32)        # [d_blk, n]
    B = B_ref[0].astype(jnp.float32)          # [C, n]
    Cm = C_ref[0].astype(jnp.float32)         # [C, n]
    D = D_ref[...].astype(jnp.float32)        # [1, d_blk]

    def step(t, carry):
        h, ys = carry
        dA = jnp.exp(dt[t][:, None] * A)                  # [d_blk, n]
        dBx = (dt[t] * x[t])[:, None] * B[t][None, :]     # [d_blk, n]
        h = dA * h + dBx
        yt = (h * Cm[t][None, :]).sum(axis=1) + D[0] * x[t]
        ys = jax.lax.dynamic_update_slice_in_dim(ys, yt[None], t, axis=0)
        return h, ys

    ys0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (state_ref[...], ys0))
    state_ref[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(c == num_chunks - 1)
    def _fin():
        sT_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "d_block", "interpret"))
def ssm(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
        C: jnp.ndarray, D: jnp.ndarray, state: Optional[jnp.ndarray] = None,
        *, chunk: int = 64, d_block: int = 128, interpret: bool = True
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x, dt: [b, s, di]; A: [di, n]; B, C: [b, s, n]; D: [di]."""
    b, s, di = x.shape
    n = A.shape[-1]
    chunk = min(chunk, s)
    d_block = min(d_block, di)
    assert s % chunk == 0 and di % d_block == 0, (s, chunk, di, d_block)
    nc, nd = s // chunk, di // d_block
    if state is None:
        state = jnp.zeros((b, di, n), jnp.float32)

    kernel = functools.partial(_ssm_kernel, chunk=chunk, num_chunks=nc)
    y, sT = pl.pallas_call(
        kernel,
        grid=(b, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda bi, d, c: (bi, c, d)),
            pl.BlockSpec((1, chunk, d_block), lambda bi, d, c: (bi, c, d)),
            pl.BlockSpec((d_block, n), lambda bi, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, d, c: (bi, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, d, c: (bi, c, 0)),
            pl.BlockSpec((1, d_block), lambda bi, d, c: (0, d)),
            pl.BlockSpec((1, d_block, n), lambda bi, d, c: (bi, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda bi, d, c: (bi, c, d)),
            pl.BlockSpec((1, d_block, n), lambda bi, d, c: (bi, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, di), x.dtype),
            jax.ShapeDtypeStruct((b, di, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((d_block, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D.reshape(1, di), state.astype(jnp.float32))
    return y, sT

"""jit'd dispatch layer between Pallas kernels and jnp references.

``use_pallas(True)`` flips attention / rwkv6 / ssm / policy-grid hot
paths to their Pallas implementations (TPU target; ``interpret=True`` on
CPU for tests). The default is the XLA reference path so the 512-device
dry-run lowers on the CPU container. Model code imports ONLY from this
module; the what-if grid backend (``core.simulate._grid_scan``) selects
through ``policy_scan`` here.
"""
from __future__ import annotations

import contextlib
import threading

import jax.numpy as jnp

from repro.kernels import ref

_state = threading.local()
_state.pallas = False
_state.interpret = True
# XLA-path attention chunking: 0 = exact quadratic einsum; >0 = flash-style
# blocked online softmax with this chunk (dry-run memfit mode; ref of the
# Pallas kernel). Applies when seq_len is a multiple of the chunk.
_state.attn_chunk = 0


def use_pallas(enable: bool = True, interpret: bool = True):
    _state.pallas = enable
    _state.interpret = interpret


def set_attn_chunk(chunk: int):
    _state.attn_chunk = chunk


def get_attn_chunk() -> int:
    return getattr(_state, "attn_chunk", 0)


def pallas_enabled() -> bool:
    return getattr(_state, "pallas", False)


def interpret_enabled() -> bool:
    """Whether Pallas kernels run in interpret mode (CPU) — the public
    accessor callers outside this module must use."""
    return getattr(_state, "interpret", True)


@contextlib.contextmanager
def pallas_mode(enable: bool = True, interpret: bool = True):
    prev = (getattr(_state, "pallas", False), getattr(_state, "interpret", True))
    use_pallas(enable, interpret)
    try:
        yield
    finally:
        use_pallas(*prev)


def sdpa(q, k, v, *, causal=True, scale=None, logit_cap=0.0, kv_len=None):
    if pallas_enabled() and kv_len is None and logit_cap == 0.0 and q.shape[1] > 1:
        from repro.kernels import flash_attention
        return flash_attention.flash_attention(
            q, k, v, causal=causal, scale=scale,
            interpret=getattr(_state, "interpret", True))
    chunk = get_attn_chunk()
    if (chunk > 0 and kv_len is None and logit_cap == 0.0
            and q.shape[1] > chunk and q.shape[1] % chunk == 0
            and k.shape[1] % chunk == 0):
        return ref.sdpa_blocked(q, k, v, causal=causal, scale=scale,
                                chunk=chunk)
    return ref.sdpa(q, k, v, causal=causal, scale=scale,
                    logit_cap=logit_cap, kv_len=kv_len)


def rwkv6_scan(r, k, v, w, u, state=None):
    if pallas_enabled():
        from repro.kernels import rwkv6_kernel
        return rwkv6_kernel.rwkv6(r, k, v, w, u, state,
                                  interpret=getattr(_state, "interpret", True))
    return ref.rwkv6_scan(r, k, v, w, u, state)


def ssm_scan(x, dt, A, B, C, D, state=None):
    if pallas_enabled():
        from repro.kernels import ssm_scan as ssm_kernel
        return ssm_kernel.ssm(x, dt, A, B, C, D, state,
                              interpret=getattr(_state, "interpret", True))
    return ref.ssm_scan(x, dt, A, B, C, D, state)


def policy_scan(loads, params, onehot=None, dt_hours=1.0, *,
                policy_index=None, differentiable=False, surrogate=False,
                caps=None):
    """TwinPolicy scenario-grid scan: loads [N, T], params [N, PARAM_DIM]
    -> (carry_end [N, CARRY_DIM], five [N, T] series).

    Exactly one of ``onehot`` [N, P] (mixed-policy grid, masked-blend
    lane step) or ``policy_index`` (scalar, possibly traced — a
    uniform-policy lane block such as K calibration restarts; a single
    lane branch executes via ``lax.switch`` instead of all P) selects
    the policies; see ``ref.policy_grid_scan``.

    ``differentiable=True`` pins the pure-jnp lane path regardless of the
    Pallas switch — the kernel has no VJP, and twin calibration takes
    ``jax.grad`` through this scan. Both paths run the same
    lane-vectorized math, so the choice never changes the numbers. When
    the bin width is a static float, the differentiable path carries the
    checkpointed O(√T) custom VJP (``kernels.policy_vjp``), so fit/search
    backward passes rematerialize √T-bin segments instead of taping the
    whole horizon; a traced ``dt_hours`` falls back to the plain
    reference scan (autodiff-through-scan), same numbers either way.

    ``surrogate=True`` (implies the differentiable path) additionally
    swaps in the smooth-surrogate lane branches so hard-gated policy
    extras carry gradients — the policy-search inner loop
    (``repro.search``). Surrogate numbers are a gradient guide only;
    exact results always come from the non-surrogate forms.

    ``caps`` [N, T] (optional) threads a fault schedule's capacity
    multipliers through the scan (``repro.faults``): the step runs in
    the fault-layer wrapper (backlog queue, reconnect flood — see
    ``core.twin.fault_lane_policy_step``). The fault SERIES path always
    takes the reference lane scan (plain autodiff when differentiated;
    the Pallas series kernel covers the benign non-diff fast path).
    Gradient users who don't need the series should go through
    ``policy_scan_fold`` instead — its in-carry reductions stream on
    both the benign AND the fault path with the O(√T) backward, which
    is how the search/calibrate kernels dispatch since the streaming
    -objective rework.
    """
    if (onehot is None) == (policy_index is None):   # before dispatch, so
        # both backends reject the ambiguity identically (one_hot(None)
        # would otherwise make the Pallas path return silent zeros)
        raise ValueError("pass exactly one of onehot= (mixed grid) or "
                         "policy_index= (uniform lane block)")
    if caps is not None:
        return ref.policy_grid_scan(loads, params, onehot, dt_hours,
                                    policy_index=policy_index,
                                    surrogate=surrogate, caps=caps)
    if pallas_enabled() and not differentiable and not surrogate:
        from repro.kernels import policy_scan as policy_kernel
        if onehot is None:
            # the kernel's branch selector is the mask form; a traced
            # uniform index lowers to its one-hot row broadcast over lanes
            import jax

            from repro.core.twin import num_policies
            onehot = jnp.broadcast_to(
                jax.nn.one_hot(policy_index, num_policies(),
                               dtype=jnp.float32),
                (loads.shape[0], num_policies()))
        return policy_kernel.policy_grid_scan(
            loads, params, onehot, dt_hours,
            interpret=getattr(_state, "interpret", True))
    if differentiable or surrogate:
        try:
            dt_static = float(dt_hours)   # tracers raise TypeError
        except TypeError:
            dt_static = None
        if dt_static is not None:
            from repro.kernels import policy_vjp
            return policy_vjp.policy_grid_scan_ckpt(
                loads, params, onehot, dt_static,
                policy_index=policy_index, surrogate=surrogate)
    return ref.policy_grid_scan(loads, params, onehot, dt_hours,
                                policy_index=policy_index,
                                surrogate=surrogate)


def policy_scan_fold(loads=None, params=None, onehot=None, dt_hours=1.0,
                     *, policy_index=None, surrogate=False, caps=None,
                     loads_t=None, caps_t=None, fold_init, fold_step,
                     ops_lane=(), xs=()):
    """Streaming-aggregate GRADIENT scan: fold per-bin policy outputs
    into a caller-defined accumulator inside the scan carry instead of
    materializing five [N, T] series — the gradient-path sibling of
    ``policy_scan_agg``, always the pure-jnp lane path (the Pallas
    kernels have no VJP).

    ``fold_init(n)`` / ``fold_step(acc, arrive, outs, ops_lane, xs_row)``
    must be module-level functions (they key trace caches); ``ops_lane``
    is a pytree of differentiable per-lane operands, ``xs`` a pytree of
    per-bin operands with leading axis T. Operands may come scenario
    -minor (``loads_t``/``caps_t`` [T, N]). With a static ``dt_hours``
    the scan carries the checkpointed O(√T) custom VJP — including the
    fault layer (``caps=``), which the series path above never streams —
    so neither direction holds an [N, T] intermediate; a traced bin
    width falls back to one plain differentiable scan, same numbers.
    Returns (carry_end [N, CARRY_DIM], acc); fault-backlog residue is
    folded into ``carry_end[:, 0]`` exactly like ``ref.policy_grid_scan``.
    """
    from repro.kernels import policy_vjp
    return policy_vjp.policy_grid_scan_fold(
        loads, params, onehot, dt_hours, policy_index=policy_index,
        surrogate=surrogate, caps=caps, loads_t=loads_t, caps_t=caps_t,
        fold_init=fold_init, fold_step=fold_step, ops_lane=ops_lane,
        xs=xs)


def policy_scan_agg(loads, params, onehot, dt_hours=1.0, *,
                    slo_limit=float("inf"), slo_mode=0, caps=None,
                    fmask=None):
    """Streaming-aggregate TwinPolicy grid scan: loads [N, T], params
    [N, PARAM_DIM], onehot [N, P] -> (carry_end [N, CARRY_DIM],
    agg [N, AGG_DIM]) — Table II statistics folded into the scan carry,
    NO [N, T] series materialized on either backend.

    Under ``use_pallas(True)`` this is the fused Pallas aggregate kernel
    (``kernels/policy_scan.policy_grid_agg``: carry + aggregates —
    load-weighted latency histogram included, as compensated in-kernel
    triples — resident in VMEM scratch across time chunks, tiled by
    ``tile_plan``); otherwise the pure-jnp lane oracle
    ``ref.policy_grid_agg``. Both return FINALIZED AGG_DIM rows
    (histogram triples recombined in f64 by
    ``core.twin.finalize_aggregate_x64``), bit-identical to the host
    ``np_latency_histogram`` oracle — no host binning round-trip exists
    on either path. ``slo_limit`` / ``slo_mode`` are static trace
    constants (``core.twin.AGG_SLO_*``; ``inf`` = no SLO).
    Not differentiable on either path — calibration differentiates the
    series scan, which keeps the full trace a loss needs anyway.

    ``caps`` / ``fmask`` [N, T] (together) thread a fault schedule
    through the scan on BOTH backends: the Pallas aggregate kernel has a
    native fault variant (two extra scenario-minor input streams, the
    backlog queue as one more VMEM scratch column), the reference path
    scans ``core.twin.fault_lane_policy_step``.
    """
    if (caps is None) != (fmask is None):
        raise ValueError("pass caps= and fmask= together (or neither)")
    if pallas_enabled():
        from repro.kernels import policy_scan as policy_kernel
        return policy_kernel.policy_grid_agg(
            loads, params, onehot, dt_hours, slo_limit=float(slo_limit),
            slo_mode=int(slo_mode), caps=caps, fmask=fmask,
            interpret=getattr(_state, "interpret", True))
    return ref.policy_grid_agg(loads, params, onehot, dt_hours,
                               slo_limit=float(slo_limit),
                               slo_mode=int(slo_mode), caps=caps,
                               fmask=fmask)

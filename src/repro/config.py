"""Configuration system for the repro framework.

Dataclass-based, frozen, hashable configs. Every assigned architecture gets a
module under ``repro.configs`` exporting ``CONFIG`` (full size, dry-run only)
and ``smoke_config()`` (reduced, runnable on CPU). ``repro.configs.get_config``
is the registry entry point used by ``--arch`` on every launcher CLI.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttentionConfig:
    """Attention family configuration.

    kind:
      gqa   — grouped-query attention (MHA/MQA are special cases)
      mla   — multi-head latent attention (DeepSeek/MiniCPM3 style)
      none  — attention-free (RWKV/SSM layers)
    """
    kind: str = "gqa"
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    # rope: standard | mrope | none
    rope: str = "standard"
    rope_theta: float = 10000.0
    # fraction of head_dim that is rotated (stablelm uses 0.25)
    rotary_pct: float = 1.0
    # M-RoPE section split of head_dim//2 (temporal, height, width)
    mrope_sections: Tuple[int, ...] = ()
    # MLA-specific
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    causal: bool = True
    # logit soft-capping (gemma-2 style); 0 disables
    logit_cap: float = 0.0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim


# ---------------------------------------------------------------------------
# MoE / SSM / RWKV
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    capacity_factor: float = 1.25
    num_shared_experts: int = 0    # moonlight-style always-on shared experts
    router_noise: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 style selective SSM."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64    # lora rank for data-dependent decay (w)
    mix_lora: int = 32      # lora rank for token-shift mixes
    gate_lora: int = 64


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | vlm | ssm | hybrid | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # layer pattern within one repeating group; e.g. jamba:
    # ("attn", "mamba", ..., "mamba") with moe_every=2.
    # Default: ("attn",) * 1 — homogeneous attention stack.
    block_pattern: Tuple[str, ...] = ("attn",)
    # every Nth layer uses MoE for its MLP (0 = all-MoE if moe set, else dense)
    moe_every: int = 0
    mlp_kind: str = "swiglu"          # swiglu | geglu | gelu
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # encoder-decoder (whisper)
    encdec: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0              # encoder memory length (stub frames)
    frontend: str = "none"            # none | audio | vision
    # M-RoPE needs 3-row positions
    position_rows: int = 1
    # numerics
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    # embedding scale (gemma multiplies by sqrt(d_model))
    scale_embeddings: bool = False
    # attention-free pure-recurrent model (no kv cache at all)
    max_seq_len: int = 524288

    @property
    def layer_types(self) -> Tuple[str, ...]:
        """Expanded per-layer block kinds of length num_layers."""
        pat = self.block_pattern
        reps = (self.num_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.num_layers])

    def layer_uses_moe(self, idx: int) -> bool:
        if self.moe is None:
            return False
        if self.moe_every <= 1:
            return True
        return (idx % self.moe_every) == (self.moe_every - 1)

    @property
    def sub_quadratic(self) -> bool:
        """True if serve-state is O(1)/linear in context (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")


# ---------------------------------------------------------------------------
# Parallelism / run configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    """Sharding rules: map logical axes to mesh axes (None = replicate).

    Mesh axes: pod (multi-pod DP), data (FSDP/DP/EP/SP), model (TP).
    """
    batch_axes: Tuple[str, ...] = ("pod", "data")
    fsdp_axis: Optional[str] = "data"
    tp_axis: Optional[str] = "model"
    expert_axis: Optional[str] = "data"
    # sequence-parallel axis for the KV-cache seq dim (str or tuple of axes)
    seq_axis: Any = "data"
    # shard KV-cache sequence dim over seq_axis when batch < data axis
    shard_cache_seq: bool = False
    remat: str = "none"                  # none | full | dots
    # gradient all-reduce compression: none | int8
    grad_compression: str = "none"
    # microbatches for grad accumulation (1 = off)
    microbatches: int = 1


@dataclass(frozen=True)
class ShapeConfig:
    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; options: {[s.name for s in SHAPES]}")


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    # optimizer state dtype: float32 | bfloat16 | int8 (block-quantized)
    state_dtype: str = "float32"
    state_block: int = 256            # quantization block for int8 state


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    seq_len: int = 512
    global_batch: int = 8
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    async_checkpoint: bool = True
    log_every: int = 10
    seed: int = 0


def replace(cfg, **kw):
    """dataclasses.replace that tolerates nested dotted keys."""
    return dataclasses.replace(cfg, **kw)

"""The paper's pipeline-under-test: automotive telemetry, three variants.

Mirrors Sec. VI-A with real compute on CPU:

  unzipper_phase — receives one zip blob per car transmission (five binary
                   subsystem files), decompresses, forwards the binaries.
  v2x_phase      — parses the custom binary telematics format into columnar
                   ("parquet-like") arrays; the ``blocking-write`` variant
                   synchronously backs every file up to a blob store
                   (tempdir + fsync), the paper's deliberate design flaw.
  etl_phase      — scrubs records with missing/bad data and inserts the
                   clean rows into an in-memory SQLite database (the RDS
                   analogue).

Variants (paper Sec. VII-A):
  blocking-write    — synchronous blob backup inside v2x_phase
  no-blocking-write — backup handed to a background writer thread
  cpu-limited       — no-blocking, with v2x_phase CPU-throttled (cgroup-style)
"""
from __future__ import annotations

import io
import os
import queue
import sqlite3
import struct
import tempfile
import time
import threading
import zipfile
import zlib
from typing import Dict, List, Optional

import numpy as np

from repro.core.datagen import DataSet
from repro.core.pipeline import Pipeline, PipelineStage, Resources
from repro.core.schema import Schema, FieldSpec

SUBSYSTEMS = ("engine", "location", "speed", "battery", "adas")
CHANNELS = 12
SAMPLES = 64          # samples per channel per transmission
MAGIC = 0x56325821    # 'V2X!'
# blob-store PUT round-trip (the S3 latency the paper's blocking write
# paid inline; local fsync alone is instant on this container's FS)
BLOB_RTT_S = 0.002

TELEMETRY_VARIANTS = ("blocking-write", "no-blocking-write", "cpu-limited")


# ---------------------------------------------------------------------------
# Synthetic raw data: one zip per car transmission
# ---------------------------------------------------------------------------

def _binary_subsystem(rng: np.random.Generator, vehicle: int, name: str) -> bytes:
    """Custom binary format: header + float32 channel block (with a few NaNs
    so etl has real scrubbing work)."""
    data = rng.normal(0, 100, (CHANNELS, SAMPLES)).astype(np.float32)
    bad = rng.random((CHANNELS, SAMPLES)) < 0.01
    data[bad] = np.nan
    head = struct.pack("<IIH6sII", MAGIC, vehicle, len(name),
                       name.encode()[:6].ljust(6), CHANNELS, SAMPLES)
    return head + data.tobytes()


def make_telemetry_dataset(num_records: int, seed: int = 0) -> DataSet:
    """num_records zip transmissions (the DataSet fed to the load generator)."""
    rng = np.random.default_rng(seed)
    blobs: List[bytes] = []
    for i in range(num_records):
        buf = io.BytesIO()
        with zipfile.ZipFile(buf, "w", zipfile.ZIP_DEFLATED) as z:
            for sub in SUBSYSTEMS:
                z.writestr(f"{sub}.bin", _binary_subsystem(rng, i, sub))
        blobs.append(buf.getvalue())
    mean_bytes = int(np.mean([len(b) for b in blobs]))
    schema = Schema("vehicle-zip", (FieldSpec("zip", "bytes", length=mean_bytes),))
    cols = {"zip": np.array(blobs, dtype=object)}
    return DataSet(schema, cols, num_records)


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------

def _unzip(batch: Dict) -> List[bytes]:
    out: List[bytes] = []
    for blob in batch["zip"]:
        with zipfile.ZipFile(io.BytesIO(blob)) as z:
            for name in z.namelist():
                out.append(z.read(name))
    return out


class _V2XParser:
    def __init__(self, blob_dir: Optional[str], blocking: bool):
        self.blob_dir = blob_dir
        self.blocking = blocking
        self._bg_queue: "queue.Queue[bytes]" = queue.Queue()
        self._bg: Optional[threading.Thread] = None
        if blob_dir and not blocking:
            self._bg = threading.Thread(target=self._bg_writer, daemon=True)
            self._bg.start()
        self._counter = 0

    def _write_blob(self, payload: bytes):
        path = os.path.join(self.blob_dir, f"blob_{os.getpid()}_{id(self)}_"
                            f"{self._counter}.bin")
        self._counter += 1
        with open(path, "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())       # the blocking S3 PUT analogue
        time.sleep(BLOB_RTT_S)         # network round-trip to the blob store

    def _bg_writer(self):
        while True:
            payload = self._bg_queue.get()
            if payload is None:
                return
            try:
                self._write_blob(payload)
            except OSError:
                pass

    def __call__(self, binaries: List[bytes]) -> List[Dict]:
        tables: List[Dict] = []
        for raw in binaries:
            magic, vehicle, nlen, name, ch, ns = struct.unpack_from(
                "<IIH6sII", raw, 0)
            assert magic == MAGIC, "corrupt subsystem file"
            off = struct.calcsize("<IIH6sII")
            arr = np.frombuffer(raw, np.float32, ch * ns, off).reshape(ch, ns)
            # "parquet conversion": columnar dict + checksum pass
            table = {"vehicle": vehicle, "subsystem": name[:nlen].decode(),
                     "data": arr, "crc": zlib.crc32(raw)}
            if self.blob_dir is not None:
                payload = zlib.compress(raw, 1)
                if self.blocking:
                    self._write_blob(payload)
                else:
                    self._bg_queue.put(payload)
            tables.append(table)
        return tables


class _ETL:
    def __init__(self):
        self.db = sqlite3.connect(":memory:", check_same_thread=False)
        self.db.execute("CREATE TABLE telemetry (vehicle INT, subsystem TEXT,"
                        " channel INT, mean REAL, mn REAL, mx REAL, n INT)")
        self.rows = 0
        self.scrubbed = 0

    def __call__(self, tables: List[Dict]) -> None:
        rows = []
        for t in tables:
            data = t["data"]
            good = np.isfinite(data)
            self.scrubbed += int((~good).sum())
            for c in range(data.shape[0]):
                col = data[c][good[c]]
                if col.size == 0:
                    continue
                rows.append((int(t["vehicle"]), t["subsystem"], c,
                             float(col.mean()), float(col.min()),
                             float(col.max()), int(col.size)))
        with self.db:
            self.db.executemany("INSERT INTO telemetry VALUES (?,?,?,?,?,?,?)",
                                rows)
        self.rows += len(rows)
        return None


# ---------------------------------------------------------------------------
# Pipeline factory
# ---------------------------------------------------------------------------

def make_telemetry_pipeline(variant: str, blob_dir: Optional[str] = None
                            ) -> Pipeline:
    assert variant in TELEMETRY_VARIANTS, variant
    if blob_dir is None:
        blob_dir = tempfile.mkdtemp(prefix=f"plantd_blob_{variant.replace('-','_')}_")
    os.makedirs(blob_dir, exist_ok=True)
    blocking = variant == "blocking-write"
    v2x = _V2XParser(blob_dir, blocking=blocking)
    etl = _ETL()
    # cpu-limited throttles v2x below even the blocking variant's capacity
    # (paper Sec. VII-A: "deliberately throttle the CPU of the second stage
    # ... verify it has a similar effect as the blocking write did")
    quota = 0.02 if variant == "cpu-limited" else 1.0
    stages = [
        PipelineStage("unzipper_phase", _unzip),
        PipelineStage("v2x_phase", v2x, cpu_quota=quota),
        PipelineStage("etl_phase", etl),
    ]
    # resource declarations drive the cost model (vCPUs sized per variant:
    # the non-blocking variant provisions bigger nodes, as in the paper where
    # it cost ~8x more per hour)
    res = {"blocking-write": Resources(vcpus=2, ram_gb=4),
           "no-blocking-write": Resources(vcpus=16, ram_gb=32),
           "cpu-limited": Resources(vcpus=0.5, ram_gb=2)}[variant]
    p = Pipeline(f"telemetry-{variant}", stages, resources=res)
    p.etl = etl          # expose for result validation
    return p

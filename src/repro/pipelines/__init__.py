from repro.pipelines.telemetry import (  # noqa: F401
    make_telemetry_dataset,
    make_telemetry_pipeline,
    TELEMETRY_VARIANTS,
)

"""Digital twins (paper Sec. V-G) as a unified TwinPolicy architecture.

A twin is explainable pipeline model fit from wind-tunnel experiments and
applied to traffic projections by the simulator. Where the paper ships two
hard-coded models (fixed-capacity FIFO and optimal quickscaling), here a
twin is a ``Twin`` record carrying a *policy name* plus a *flat parameter
vector*, and every policy is a pure hour-step function

    step(carry, arrive, params) -> (carry, (processed, queue, latency,
                                            cost, dropped))

registered in a module-level table. The simulator selects the step inside
its ``jax.lax.scan`` with ``jax.lax.switch``, so every (twin x traffic)
scenario of a what-if grid — regardless of policy mix — runs through ONE
vmapped scan kernel (see core/simulate.py). New scaling/queueing policies
are added by registering a step function; the kernel never changes.

Steps are *bin-width aware*: the canonical signature is

    step(carry, arrive, params, dt) -> (carry, (processed, queue, latency,
                                                cost, dropped))

where ``dt`` is the bin width in hours (1.0 for the year simulation;
sub-hour for calibration traces). Legacy three-argument steps registered
before the dt generalization are wrapped automatically and simply ignore
``dt`` — at dt=1.0 every built-in reduces bit-identically to its PR 1 form.

Every policy exists in TWO interchangeable step forms:

* the scalar form above — dispatched per scenario with ``jax.lax.switch``
  inside the XLA grid kernel (``core/simulate.py``), the parity anchor;
* a *branchless, lane-vectorized* form

      lane_step(carry [LANES, CARRY_DIM], arrive [LANES],
                params [LANES, PARAM_DIM], dt) -> (carry, outs)

  — pure masked ``jnp`` math over a block of LANES scenarios at once,
  with each of the five outputs shaped [LANES]. The built-ins hand-write
  this form (so it lowers to straight-line VPU vector code inside the
  Pallas scenario-grid kernel, ``kernels/policy_scan.py``); policies
  registered without one get it derived automatically via ``jax.vmap`` of
  their scalar step. At registration the registry *asserts both forms
  agree* on a random block, so the two backends cannot drift.

``lane_policy_step(carry, arrive, params, policy_onehot, dt)`` is the
combined branchless step over a mixed-policy lane block: every registered
policy is evaluated on every lane and the results blended with the
[LANES, P] one-hot policy mask — exactly what ``vmap`` of ``lax.switch``
lowers to, and the form the Pallas kernel scans over all T bins with
scenarios on the vector lanes.

Next to the policy steps live the *streaming-aggregate hooks* (AGG_*
constants, ``update_agg_scalars`` / ``lane_update_aggregate`` /
``np_latency_histogram``): the carry extension that lets the grid
backends fold the Table II summary statistics into the scan instead of
materializing [N, T] series — see the section comment below and
``core/simulate.py``.

Each registered policy also declares *calibration metadata*: a per-parameter
``bounds`` box, the subset optimized in log-space (``log_params``), and the
params ``frozen`` by default during gradient fitting (operator-chosen knobs
like instance bounds). ``repro.calibrate`` uses this to reparameterize the
flat vector onto the bounds with a sigmoid/softplus bijection and fit it to
an observed trace by differentiating through the simulation scan.

Shared convention: ``params[0:3] = (max_rps, usd_per_hour, base_latency_s)``
for every policy; extra parameters follow, zero-padded to ``PARAM_DIM``.
The scan carry is a ``CARRY_DIM``-vector: slot 0 holds queued/accumulated
records, slot 1 holds policy state (autoscale's live instance count,
batch_window's hours-since-flush).

Built-in policies
-----------------
fifo          — fixed capacity, fixed $/hr, FIFO infinite queue (the
                paper's proof-of-concept model, Table I).
quickscale    — optimal horizontal scaling: no queueing; cost scales with
                ceil(load / capacity) instances.
autoscale     — beyond-paper: horizontal scaling with a scale-up delay and
                min/max instance bounds — the autoscaling-delay /
                overprovisioning cost levers of Jablonski & Heltweg.
shed          — beyond-paper: bounded queue with load shedding; excess
                records are dropped and reported per hour.
batch_window  — beyond-paper: accumulate-then-flush batching; pay mostly
                for compute actually used (plus a keep-warm fraction) at
                the price of half-a-window average latency.

``SimpleTwin`` / ``QuickscalingTwin`` remain as constructor aliases that
build the equivalent ``Twin``, and ``roofline_twin`` still derives capacity
analytically from compiled dry-run roofline terms (launch/roofline.py), so
cost/performance can be forecast before a pipeline is ever run at scale.
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.experiment import ExperimentResult

CARRY_DIM = 2     # [queued/accumulated records, policy state]
PARAM_DIM = 6     # flat parameter vector, zero-padded per policy

# calibration boxes for the shared triple; extras declare their own via
# register_policy(bounds=...) or inherit the generic positive box below
SHARED_BOUNDS: Dict[str, Tuple[float, float]] = {
    "max_rps": (1e-2, 1e3),
    "usd_per_hour": (1e-4, 10.0),
    "base_latency_s": (1e-2, 100.0),
}
GENERIC_BOUNDS: Tuple[float, float] = (1e-3, 1e3)
SHARED_LOG = ("max_rps", "usd_per_hour", "base_latency_s")


@dataclass(frozen=True)
class PolicySpec:
    """One registered scaling/queueing policy."""
    name: str
    index: int                       # lax.switch branch index (stable)
    step: Callable                   # (carry, arrive, params, dt) -> (carry, out)
    param_names: Tuple[str, ...]     # layout of the flat param vector
    defaults: Dict[str, float]
    doc: str
    # calibration metadata (repro.calibrate)
    bounds: Dict[str, Tuple[float, float]] = None
    log_params: Tuple[str, ...] = ()
    frozen: Tuple[str, ...] = ()
    # branchless lane-vectorized form of ``step`` (see module docstring):
    # (carry [L, CARRY_DIM], arrive [L], params [L, PARAM_DIM], dt)
    lane_step: Callable = None
    # --- differentiability audit (repro.search) ---------------------------
    # parameters the EXACT step hard-gates on (ceil / >= comparisons whose
    # gradient is zero or undefined): a gradient-based policy search cannot
    # move these through ``lane_step``. Policies flagging any must supply a
    # ``surrogate_lane_step`` — same signature and lane semantics as
    # ``lane_step`` but with the hard gates smoothed (fluid instance
    # counts, sigmoid flush gates), so ``d(output)/d(param)`` is nonzero.
    # The surrogate is ONLY used for gradients (repro.search's inner loop);
    # every reported number still comes from the exact step.
    nondiff_params: Tuple[str, ...] = ()
    surrogate_lane_step: Callable = None

    def bound(self, pname: str) -> Tuple[float, float]:
        return (self.bounds or {}).get(pname, GENERIC_BOUNDS)


_REGISTRY: Dict[str, PolicySpec] = {}
_VERSION = 0    # bumped on registration; a static jit arg, so the grid
                # kernel retraces when a new policy is registered late


def _accepts_dt(fn: Callable) -> bool:
    """True if ``fn`` already takes the (carry, arrive, params, dt) form."""
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):       # builtins etc. — assume modern
        return True
    kinds = [p.kind for p in sig.parameters.values()]
    if any(k == inspect.Parameter.VAR_POSITIONAL for k in kinds):
        return True
    pos = [k for k in kinds if k in (inspect.Parameter.POSITIONAL_ONLY,
                                     inspect.Parameter.POSITIONAL_OR_KEYWORD)]
    return len(pos) >= 4


def _derived_lane_step(step: Callable) -> Callable:
    """Lane-vectorize a scalar step with ``jax.vmap`` (the fallback for
    policies registered without a hand-written lane form)."""
    import jax
    return jax.vmap(step, in_axes=(0, 0, 0, None))


def _assert_lane_parity(name: str, step: Callable, lane_step: Callable,
                        lanes: int = 4, seed: int = 0):
    """Registry invariant: the scalar and lane-vectorized forms of a policy
    agree on a random block of scenarios. Runs eagerly at registration so a
    hand-written lane step cannot drift from the ``lax.switch`` form."""
    rng = np.random.default_rng(seed)
    carry = jnp.asarray(rng.uniform(0.0, 50.0, (lanes, CARRY_DIM)),
                        jnp.float32)
    arrive = jnp.asarray(rng.uniform(0.0, 2e4, (lanes,)), jnp.float32)
    params = jnp.asarray(rng.uniform(0.05, 8.0, (lanes, PARAM_DIM)),
                         jnp.float32)
    for dt in (1.0, 1.0 / 60.0):
        dt = jnp.float32(dt)
        c_lane, o_lane = lane_step(carry, arrive, params, dt)
        for lane in range(lanes):
            c_s, o_s = step(carry[lane], arrive[lane], params[lane], dt)
            np.testing.assert_allclose(
                np.asarray(c_lane[lane]), np.asarray(c_s), rtol=1e-5,
                atol=1e-5, err_msg=f"{name}: lane/scalar carry mismatch")
            for k, (ol, os_) in enumerate(zip(o_lane, o_s)):
                np.testing.assert_allclose(
                    np.asarray(ol[lane]), np.asarray(os_), rtol=1e-5,
                    atol=1e-5,
                    err_msg=f"{name}: lane/scalar output {k} mismatch")


def _assert_surrogate_sane(name: str, surrogate: Callable, lanes: int = 4,
                           seed: int = 1):
    """Registry invariant for surrogate steps: finite outputs and finite
    parameter gradients on a random lane block (both bin widths). The
    surrogate is a gradient guide, not a parity target, so closeness to
    the exact step is NOT asserted — only that grads exist to follow."""
    rng = np.random.default_rng(seed)
    carry = jnp.asarray(rng.uniform(0.0, 50.0, (lanes, CARRY_DIM)),
                        jnp.float32)
    arrive = jnp.asarray(rng.uniform(0.0, 2e4, (lanes,)), jnp.float32)
    params = jnp.asarray(rng.uniform(0.05, 8.0, (lanes, PARAM_DIM)),
                         jnp.float32)

    def total(p, dt):
        c, outs = surrogate(carry, arrive, p, dt)
        return sum(jnp.sum(o) for o in outs) + jnp.sum(c)

    for dt in (1.0, 1.0 / 60.0):
        val = total(params, jnp.float32(dt))
        g = jax.grad(total)(params, jnp.float32(dt))
        if not (np.isfinite(float(val)) and np.all(np.isfinite(g))):
            raise AssertionError(
                f"{name}: surrogate step produced non-finite output or "
                f"gradient at dt={dt}")


def register_policy(name: str, param_names: Tuple[str, ...],
                    defaults: Optional[Dict[str, float]] = None,
                    doc: str = "",
                    bounds: Optional[Dict[str, Tuple[float, float]]] = None,
                    log_params: Optional[Tuple[str, ...]] = None,
                    frozen: Tuple[str, ...] = (),
                    lane_step: Optional[Callable] = None,
                    nondiff_params: Tuple[str, ...] = (),
                    surrogate_lane_step: Optional[Callable] = None):
    """Decorator: register ``fn(carry, arrive, params, dt)`` as ``name``.

    ``param_names`` must start with the shared triple
    (max_rps, usd_per_hour, base_latency_s) and fit within PARAM_DIM.
    Legacy ``fn(carry, arrive, params)`` steps are wrapped to ignore the
    bin width ``dt`` (they then only simulate correctly at dt=1 hour).

    ``bounds`` / ``log_params`` / ``frozen`` declare calibration metadata:
    the fit box per parameter (shared-triple boxes are filled in), which
    parameters are fit in log-space, and which are held fixed by default.

    ``lane_step`` optionally supplies the branchless lane-vectorized form
    (see module docstring); omitted, it is derived with ``jax.vmap``.
    Either way the registry asserts the two forms agree on a random block
    before the policy becomes visible.

    ``nondiff_params`` flags parameters the exact step hard-gates on
    (zero-gradient through ceil / comparisons); flagging any requires a
    ``surrogate_lane_step`` whose gates are smoothed so ``repro.search``
    can take gradients w.r.t. them. Policies with no hard gates leave both
    unset and the exact lane step doubles as its own surrogate.
    """
    if len(param_names) > PARAM_DIM:
        raise ValueError(f"{name}: {len(param_names)} params > {PARAM_DIM}")
    if tuple(param_names[:3]) != ("max_rps", "usd_per_hour",
                                  "base_latency_s"):
        raise ValueError(f"{name}: params must start with the shared triple")
    full_bounds = dict(SHARED_BOUNDS)
    full_bounds.update(bounds or {})
    logp = tuple(log_params) if log_params is not None else tuple(
        p for p in param_names if p in SHARED_LOG)

    def deco(fn):
        global _VERSION
        step = fn if _accepts_dt(fn) else (
            lambda carry, arrive, p, dt, _fn=fn: _fn(carry, arrive, p))
        lstep = lane_step or _derived_lane_step(step)
        _assert_lane_parity(name, step, lstep)
        unknown_nd = set(nondiff_params) - set(param_names)
        if unknown_nd:
            raise ValueError(f"{name}: nondiff_params {sorted(unknown_nd)} "
                             f"not in param_names")
        if nondiff_params and surrogate_lane_step is None:
            raise ValueError(
                f"{name}: flags hard-gated params {list(nondiff_params)} "
                f"but supplies no surrogate_lane_step — gradient search "
                f"over them would silently see zero gradients")
        sstep = surrogate_lane_step or lstep
        _assert_surrogate_sane(name, sstep)
        # overriding an existing policy keeps its switch index so twins
        # built earlier still dispatch to the right branch slot
        prev = _REGISTRY.get(name)
        spec = PolicySpec(name=name,
                          index=prev.index if prev else len(_REGISTRY),
                          step=step,
                          param_names=tuple(param_names),
                          defaults=dict(defaults or {}),
                          doc=doc or (fn.__doc__ or "").strip(),
                          bounds=full_bounds,
                          log_params=logp,
                          frozen=tuple(frozen),
                          lane_step=lstep,
                          nondiff_params=tuple(nondiff_params),
                          surrogate_lane_step=sstep)
        _REGISTRY[name] = spec
        _VERSION += 1
        return fn
    return deco


def policy_spec(name: str) -> PolicySpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown twin policy {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def policy_names() -> List[str]:
    return [s.name for s in sorted(_REGISTRY.values(), key=lambda s: s.index)]


def policy_branches() -> Tuple[Callable, ...]:
    """Step functions ordered by switch index (the kernel's branch table)."""
    return tuple(s.step for s in
                 sorted(_REGISTRY.values(), key=lambda s: s.index))


def lane_branches() -> Tuple[Callable, ...]:
    """Lane-vectorized step functions ordered by switch index."""
    return tuple(s.lane_step for s in
                 sorted(_REGISTRY.values(), key=lambda s: s.index))


def surrogate_lane_branches() -> Tuple[Callable, ...]:
    """Smooth-surrogate lane steps ordered by switch index — the branch
    table gradient-based policy search scans (``repro.search``). Policies
    without hard gates reuse their exact lane step here."""
    return tuple(s.surrogate_lane_step for s in
                 sorted(_REGISTRY.values(), key=lambda s: s.index))


def num_policies() -> int:
    return len(_REGISTRY)


def policy_onehot(policy_idx) -> np.ndarray:
    """[N, P] f32 one-hot mask from [N] switch indices — the lane form's
    branch selector (P = number of registered policies)."""
    idx = np.asarray(policy_idx, np.int32)
    return (idx[:, None] == np.arange(num_policies())[None, :]).astype(
        np.float32)


def lane_policy_step(carry, arrive, params, onehot, dt, branches=None):
    """The combined branchless bin-step over a mixed-policy lane block.

    carry [L, CARRY_DIM]; arrive [L]; params [L, PARAM_DIM];
    onehot [L, P] selects each lane's policy. Every registered policy is
    evaluated on every lane (pure vector math, no control flow) and the
    results blended with the one-hot mask — a masked sum is exact in f32
    (1*x + 0*y == x), so this matches the ``lax.switch`` form bit for bit
    as long as every branch stays finite on foreign parameter vectors
    (a registry invariant checked at registration). This is the step the
    Pallas scenario-grid kernel scans over all T bins with scenarios on
    the vector lanes (``kernels/policy_scan.py``).

    ``branches`` overrides the branch table (default: the exact lane
    steps) — ``repro.search`` passes ``surrogate_lane_branches()``.
    """
    new_carry = jnp.zeros_like(carry)
    outs = [jnp.zeros_like(arrive) for _ in range(5)]
    for j, lstep in enumerate(branches or lane_branches()):
        c_j, o_j = lstep(carry, arrive, params, dt)
        m = onehot[:, j]
        new_carry = new_carry + m[:, None] * c_j
        outs = [acc + m * o for acc, o in zip(outs, o_j)]
    return new_carry, tuple(outs)


def fault_lane_policy_step(state, arrive, capmul, params, onehot, dt,
                           branches=None):
    """``lane_policy_step`` wrapped in the fault perturbation layer.

    ``state`` = (policy carry [L, CARRY_DIM], fault backlog ``fq`` [L]);
    ``capmul`` [L] is this bin's capacity multiplier from the fault
    schedule. The layer sits *outside* the policy step, so every
    registered policy composes with every fault kind unchanged:

    * capacity scales: the policy sees ``max_rps * capmul`` (brownouts);
    * hard outage (``capmul == 0``) gates arrivals into a fault-layer
      backlog queue instead of the policy — the policy drains its own
      queue and then idles, rather than autoscaling against a dead
      pipeline. When capacity returns the whole backlog re-enters in one
      bin: the reconnect flood, conserving every record;
    * backlog waiting time is priced into reported latency at NOMINAL
      capacity (``fq / max_rps``) — a deliberate lower bound that keeps
      outage latencies finite instead of dividing by a zeroed rate.

    The benign bin (``capmul == 1``, ``fq == 0``) is IEEE-exact identity:
    ``1 * (0 + arrive) == arrive``, ``max_rps * 1.0 == max_rps``, and
    ``lat + 0.0 == lat``, so an all-ones capacity series is bit-identical
    to the unwrapped step — the structural guarantee behind the
    empty-schedule parity tests.
    """
    carry, fq = state
    gate = (capmul > 0).astype(jnp.float32)
    avail = fq + arrive
    a_eff = gate * avail
    new_fq = avail - a_eff
    p_eff = jnp.concatenate([(params[:, 0] * capmul)[:, None],
                             params[:, 1:]], axis=1)
    carry, outs = lane_policy_step(carry, a_eff, p_eff, onehot, dt,
                                   branches=branches)
    wait = new_fq / jnp.maximum(params[:, 0], jnp.float32(1e-9))
    outs = (outs[0], outs[1] + new_fq, outs[2] + wait, outs[3], outs[4])
    return (carry, new_fq), outs


def registry_version() -> int:
    return _VERSION


# ---------------------------------------------------------------------------
# Streaming aggregates (the O(N)-memory grid backend's carry extension)
# ---------------------------------------------------------------------------
#
# The what-if tables (core/whatif.table2_rows) consume only per-scenario
# *scalars*, so the streaming grid backend folds the Table II statistics
# into the scan carry instead of materializing five [N, T] series:
#
# * running sums of processed / cost / dropped / latency*load / load and
#   the load-weighted SLO-ok mass, each carried as a twice-compensated
#   (sum, comp, comp2) f32 triple (cascaded Neumaier: the exact two-sum
#   residual stream is itself compensated) — recombined in f64 on the
#   host the triple reproduces numpy's f64 series sum bit for bit at
#   year-grid magnitudes, so aggregate totals match the series-path
#   ``_summarise`` exactly;
# * the per-bin max throughput and the count of SLO-ok bins (exact in f32);
# * a fixed-width load-weighted latency histogram over AGG_HIST_BINS
#   quarter-octave buckets — the device-side replacement for the numpy
#   sort/cumsum median in ``_summarise`` (quantiles read off the bucket
#   CDF are exact to one bucket width, ``AGG_HIST_W`` decades). Bucket
#   keys come straight from the f32 exponent + top mantissa bits
#   (``_hist_bucket`` / ``np_hist_bucket``: bitcast, shift, clip — no
#   transcendentals), so every backend computes the identical integer
#   bucket for every latency value.
#
# In the scan the aggregate state is an UNPACKED pytree (a tuple of
# per-statistic arrays, ``init_aggregate``) rather than one packed
# [AGG_DIM] vector: per-bin updates are then pure elementwise arithmetic
# with no gather/stack/update-slice in the hot loop (~5x on the CPU
# backend). ``pack_aggregate`` flattens the state into the [.., AGG_DIM]
# slot layout once per scan (or per Pallas time chunk, where the packed
# form is what persists in VMEM scratch — ``unpack_aggregate`` restores
# the pytree at chunk entry).
#
# The histogram has two backend-appropriate DEVICE-RESIDENT
# realizations, both bit-identical to the host reference
# (``np_latency_histogram``, kept as the parity oracle):
#
# * ``lane_update_aggregate`` — the branchless lane form the Pallas
#   kernel (and the jnp lane oracle) runs: a masked compare-add over the
#   bucket axis, resident in VMEM scratch, O(N) end to end. Each bucket
#   column is a twice-compensated (sum, comp, comp2) triple — the same
#   scheme the scalar sums use — recombined in f64 once per scan
#   (``finalize_aggregate``), which reproduces numpy's per-row f64
#   ``np.bincount`` bit for bit;
# * the XLA switch-scan backend keeps only the scalar statistics in the
#   scan carry and folds each staged time-chunk of latencies through
#   ``device_latency_histogram`` — a flat f64 ``segment_sum`` over
#   (scenario, bucket) ids *outside* the scan carry (a per-step
#   [N, BINS] carry costs ~0.5 s per 1k scenarios in scan
#   double-buffering alone). The f64 adds are exact at year-grid
#   magnitudes, so the chunked accumulation is order-independent and
#   matches ``np.bincount`` bitwise with no host round-trip.

AGG_HIST_BINS = 152            # quarter-octave latency buckets
#: smallest resolvable latency: 2^-10 s ~ 0.98 ms (bucket 0 clips below)
AGG_HIST_MIN_EXP = -10
AGG_HIST_MIN = float(2.0 ** AGG_HIST_MIN_EXP)
#: (biased exponent | 2-bit mantissa) key of AGG_HIST_MIN — bucket 0
_AGG_HIST_KEY0 = (127 + AGG_HIST_MIN_EXP) << 2
#: bucket width in decades: a quarter octave (top edge 2^28 s ~ 8.5 yr)
AGG_HIST_W = float(np.log10(2.0) / 4.0)

# scalar slot layout: (sum, comp, comp2) triples first, then exact slots
A_PROC = 0                     # sum of processed records
A_COST = 3                     # sum of cost_usd
A_DROP = 6                     # sum of dropped records
A_LATW = 9                     # sum of latency * load (record-weighted)
A_LOAD = 12                    # sum of load
A_OKW = 15                     # sum of load in SLO-ok bins
A_OKH = 18                     # count of SLO-ok bins
A_MAXP = 19                    # max processed per bin
A_FLTH = 20                    # count of bins inside a fault window
A_FOKH = 21                    # count of SLO-ok bins inside fault windows
AGG_SCALARS = 22
AGG_DIM = AGG_SCALARS + AGG_HIST_BINS
#: kernel-internal packed width: each histogram bucket is a
#: twice-compensated (sum, comp, comp2) triple until ``finalize_aggregate``
AGG_KDIM = AGG_SCALARS + 3 * AGG_HIST_BINS

#: SLO metric selector for the aggregate scan (a static trace argument)
AGG_SLO_LATENCY, AGG_SLO_DROP_RATE = 0, 1


def aggregate_hist_edges() -> np.ndarray:
    """[AGG_HIST_BINS + 1] bucket edges in seconds (quarter-octave)."""
    return np.power(2.0, AGG_HIST_MIN_EXP
                    + np.arange(AGG_HIST_BINS + 1) / 4.0)


def aggregate_hist_centers() -> np.ndarray:
    """[AGG_HIST_BINS] geometric bucket centers in seconds — the
    representative values quantiles read off the histogram CDF."""
    return np.power(2.0, AGG_HIST_MIN_EXP
                    + (np.arange(AGG_HIST_BINS) + 0.5) / 4.0)


def _two_sum(a, b):
    """Branch-free Knuth two-sum: (fl(a+b), exact residual)."""
    s = a + b
    bb = s - a
    return s, (a - (s - bb)) + (b - bb)


def _neumaier2(s, c, cc, x):
    """One twice-compensated summation step: (sum, comp, comp2) += x.
    The per-step two-sum residual is EXACT; its running sum is itself
    compensated into (c, cc), so ``s + c + cc`` recombined in f64 on the
    host matches numpy's f64 sum of the same f32 terms bit for bit at
    the magnitudes the year grids produce (verified against the series
    path in tests/test_grid_aggregate.py)."""
    s, e = _two_sum(s, x)
    c, ee = _two_sum(c, e)
    return s, c, cc + ee


def fold_triple_init(shape) -> tuple:
    """Fresh twice-compensated accumulator triple ``(sum, comp, comp2)``
    of f32 zeros — the differentiable AGG hook the streaming gradient
    objectives fold into their scan carry (search cost / compliance /
    violation sums, calibrate residual sums)."""
    z = jnp.zeros(shape, jnp.float32)
    return z, z, z


@jax.custom_jvp
def fold_triple_add(triple: tuple, x) -> tuple:
    """One differentiable compensated accumulation step: triple += x.

    Every two-sum residual channel is *symbolically* zero in exact
    arithmetic (``e = (a - (s - bb)) + (b - bb)`` with ``s = a + b``,
    ``bb = s - a`` has ``de/da = de/db = 0``), and because autodiff's
    chain coefficients through those wires are exact 0/1 constants, the
    gradient of a compensated fold is BITWISE the gradient of the plain
    sum. The custom JVP states that directly — tangents ride the plain
    ``s + x`` channel — so the O(sqrt(T)) segment replays of the
    streaming objectives don't drag three dead two-sum transposes per
    accumulator per bin through the backward (measurably faster at the
    search kernel's small lane counts, identical numbers)."""
    s, c, cc = triple
    return _neumaier2(s, c, cc, x)


@fold_triple_add.defjvp
def _fold_triple_add_jvp(primals, tangents):
    triple, x = primals
    (ds, dc, dcc), dx = tangents
    return fold_triple_add(triple, x), (ds + dx, dc, dcc)


def fold_triple_finalize(triple: tuple) -> jnp.ndarray:
    """Recombine ``(sum, comp, comp2) -> sum + comp + comp2`` in f64,
    cast back to f32 — the PR 4 trick that makes the streamed value
    match an f64 accumulation of the same f32 terms. Under a plain-f32
    trace (the search/fit kernels) the f64 cast is a no-op and the
    recombination is a deterministic pair of f32 adds; either way the
    result is bit-identical between any two paths that share this code."""
    s, c, cc = triple
    # canonicalize: f64 only when x64 is enabled (avoids the truncation
    # UserWarning on every plain-f32 trace; the numbers are identical)
    acc_t = jax.dtypes.canonicalize_dtype(jnp.float64)
    return (s.astype(acc_t) + c + cc).astype(jnp.float32)


def _hist_bucket(latency):
    """Bucket index on the fixed quarter-octave grid, from the f32 bit
    pattern: (exponent | top 2 mantissa bits) rebased to AGG_HIST_MIN.
    Integer-exact and backend-independent (``np_hist_bucket`` is the
    bit-identical numpy twin)."""
    lat = jnp.maximum(latency, jnp.float32(AGG_HIST_MIN))
    bits = jax.lax.bitcast_convert_type(lat, jnp.int32)
    return jnp.clip((bits >> 21) - _AGG_HIST_KEY0, 0, AGG_HIST_BINS - 1)


def np_hist_bucket(latency: np.ndarray) -> np.ndarray:
    """Numpy twin of ``_hist_bucket`` — same bits, same buckets (one
    temporary, then in-place int ops: this sits on the streaming grid's
    per-block hot path)."""
    buf = np.maximum(np.ascontiguousarray(latency, np.float32),
                     np.float32(AGG_HIST_MIN))
    bits = buf.view(np.int32)
    np.right_shift(bits, 21, out=bits)
    bits -= _AGG_HIST_KEY0
    np.clip(bits, 0, AGG_HIST_BINS - 1, out=bits)
    return bits


def np_latency_histogram(latency: np.ndarray, weights: np.ndarray,
                         weight_rows: np.ndarray = None) -> np.ndarray:
    """[N, T] latencies + [N, T] weights -> [N, AGG_HIST_BINS] f32
    load-weighted histogram (one ``np.bincount`` per scenario, f64
    accumulation per row). The host half of the XLA aggregate backend.

    With ``weight_rows`` [N], ``weights`` is instead the [K, T] distinct
    load matrix and row i weighs by ``weights[weight_rows[i]]`` — the
    grid engine's blocks repeat a few matrix rows thousands of times, so
    this form skips the [N, T] gather AND hands bincount pre-converted
    f64 row views instead of a fresh f32->f64 copy per scenario.
    Bit-identical to the gathered form (the f64 conversion is exact and
    the accumulation order is unchanged)."""
    buckets = np_hist_bucket(latency)
    n = buckets.shape[0]
    out = np.empty((n, AGG_HIST_BINS), np.float32)
    if weight_rows is None:
        for i in range(n):
            out[i] = np.bincount(buckets[i], weights=weights[i],
                                 minlength=AGG_HIST_BINS)
    else:
        w64 = np.ascontiguousarray(weights, np.float64)
        for i in range(n):
            out[i] = np.bincount(buckets[i],
                                 weights=w64[weight_rows[i]],
                                 minlength=AGG_HIST_BINS)
    return out


def device_latency_histogram(latency, weights):
    """[N, C] latencies + [N, C] weights -> [N, AGG_HIST_BINS] f64
    load-weighted histogram, entirely on device: bucket ids from the f32
    bit pattern (``_hist_bucket``), then ONE flat ``segment_sum`` over
    (scenario * AGG_HIST_BINS + bucket) ids in f64.

    MUST be traced under ``jax.experimental.enable_x64()`` — outside it
    the f64 cast silently truncates to f32 and bit-parity with
    ``np_latency_histogram`` is lost. The f64 adds are exact at the
    magnitudes year grids produce (bucket sums need ~35-51 bits < 53),
    so the result is order-independent: accumulating per time chunk and
    adding the chunk histograms reproduces numpy's per-row f64
    ``np.bincount`` of the full series bit for bit."""
    n = latency.shape[0]
    seg = (jax.lax.broadcasted_iota(jnp.int32, latency.shape, 0)
           * AGG_HIST_BINS + _hist_bucket(latency))
    return jax.ops.segment_sum(
        weights.astype(jnp.float64).reshape(-1), seg.reshape(-1),
        num_segments=n * AGG_HIST_BINS).reshape(n, AGG_HIST_BINS)


def init_agg_scalars(shape=()):
    """Zeroed scalar-statistic state: (sums tuple[18], okh, maxp, flth,
    fokh), every leaf ``shape``-shaped (scalar under the vmapped switch
    path, [L] for a lane block)."""
    z = jnp.zeros(shape, jnp.float32)
    return ((z,) * 18, z, z, z, z)


def update_agg_scalars(state, arrive, outs, slo_limit, slo_mode,
                       fmask=None):
    """Fold one bin's step outputs into the scalar statistics (shared by
    every backend; elementwise, shape-polymorphic). ``slo_limit`` (float)
    and ``slo_mode`` (AGG_SLO_*) are static trace constants — pass
    ``inf`` / latency when no SLO applies.

    ``fmask`` (0/1, same shape as ``arrive``) marks bins inside a fault
    window; it drives the exact fault-attribution counters (A_FLTH /
    A_FOKH — integer counts, exact in f32). ``None`` (the benign path)
    leaves both counters untouched, so faulted and benign traces share
    one code path bit for bit."""
    sums, okh, maxp, flth, fokh = state
    processed, _queue, latency, cost, dropped = outs
    if slo_mode == AGG_SLO_DROP_RATE:
        val = dropped / jnp.maximum(arrive, jnp.float32(1e-9))
    else:
        val = latency
    ok = (val <= jnp.float32(slo_limit)).astype(jnp.float32)
    new = []
    # term order IS the slot order: A_PROC, A_COST, A_DROP, A_LATW,
    # A_LOAD, A_OKW (each a (sum, comp, comp2) triple)
    for j, x in enumerate((processed, cost, dropped, latency * arrive,
                           arrive, arrive * ok)):
        new += _neumaier2(sums[3 * j], sums[3 * j + 1], sums[3 * j + 2], x)
    if fmask is not None:
        flth = flth + fmask
        fokh = fokh + fmask * ok
    return (tuple(new), okh + ok, jnp.maximum(maxp, processed), flth, fokh)


def pack_agg_scalars(state) -> jnp.ndarray:
    """[..., AGG_SCALARS] slot layout of a scalar-statistic state."""
    sums, okh, maxp, flth, fokh = state
    return jnp.stack(tuple(sums) + (okh, maxp, flth, fokh), axis=-1)


def init_aggregate(shape=()):
    """Zeroed FULL aggregate state (scalars + histogram) for the lane
    backends: (scalar state, hist triple of [*shape, AGG_HIST_BINS] —
    per-bucket (sum, comp, comp2) compensated columns)."""
    z = jnp.zeros(tuple(shape) + (AGG_HIST_BINS,), jnp.float32)
    return (init_agg_scalars(shape), (z, z, z))


def lane_update_aggregate(state, arrive, outs, slo_limit, slo_mode,
                          fmask=None):
    """Fold one bin into the full aggregate state — branchless lane form.

    ``state`` = (scalar state with [L] leaves, hist triple of
    [L, AGG_HIST_BINS]); arrive [L]; outs five [L] vectors. Scalars via
    the shared ``update_agg_scalars``; the histogram is a masked
    compare-add over the bucket axis (no scatter) folded through the
    same twice-compensated ``_neumaier2`` step the scalar sums use, so
    the Pallas kernel runs it as straight-line VPU vector math with
    everything resident in VMEM and ``finalize_aggregate`` recovers the
    exact f64 bucket sums. ``fmask`` [L] (optional) feeds the
    fault-attribution counters."""
    scal, (hs, hc, hcc) = state
    scal = update_agg_scalars(scal, arrive, outs, slo_limit, slo_mode,
                              fmask)
    bucket = _hist_bucket(outs[2])
    lanes = bucket.shape[0]
    buckets = jax.lax.broadcasted_iota(jnp.int32, (lanes, AGG_HIST_BINS), 1)
    x = jnp.where(bucket[:, None] == buckets, arrive[:, None],
                  jnp.float32(0.0))
    return (scal, _neumaier2(hs, hc, hcc, x))


def pack_aggregate(state) -> jnp.ndarray:
    """Flatten a full aggregate state into the [..., AGG_KDIM] slot
    layout (scalars, then the three histogram planes; done once per scan
    / per Pallas time chunk, never in the bin loop)."""
    scal, hist = state
    return jnp.concatenate([pack_agg_scalars(scal)] + list(hist), axis=-1)


def unpack_aggregate(packed: jnp.ndarray):
    """Inverse of ``pack_aggregate`` — restores the pytree a Pallas
    kernel's VMEM-resident [L, AGG_KDIM] block carries between chunks."""
    b = AGG_HIST_BINS
    return ((tuple(packed[..., i] for i in range(18)),
             packed[..., A_OKH], packed[..., A_MAXP],
             packed[..., A_FLTH], packed[..., A_FOKH]),
            (packed[..., AGG_SCALARS:AGG_SCALARS + b],
             packed[..., AGG_SCALARS + b:AGG_SCALARS + 2 * b],
             packed[..., AGG_SCALARS + 2 * b:]))


def finalize_aggregate(packed: jnp.ndarray) -> jnp.ndarray:
    """[..., AGG_KDIM] kernel rows -> [..., AGG_DIM] public rows: each
    bucket's (sum, comp, comp2) triple recombined in f64 then cast f32.

    MUST be traced under ``jax.experimental.enable_x64()`` (like
    ``device_latency_histogram``): the f64 recombination of the
    twice-compensated triple is exact, so the result equals numpy's f64
    ``np.bincount`` rounded once — an f32-only recombination double-
    rounds at tie boundaries and loses bit-parity."""
    b = AGG_HIST_BINS
    hs = packed[..., AGG_SCALARS:AGG_SCALARS + b].astype(jnp.float64)
    hc = packed[..., AGG_SCALARS + b:AGG_SCALARS + 2 * b]
    hcc = packed[..., AGG_SCALARS + 2 * b:]
    hist = (hs + hc + hcc).astype(jnp.float32)
    return jnp.concatenate([packed[..., :AGG_SCALARS], hist], axis=-1)


_finalize_aggregate_jit = jax.jit(finalize_aggregate)


def finalize_aggregate_x64(packed: jnp.ndarray) -> jnp.ndarray:
    """Eager entry point for ``finalize_aggregate``: enters
    ``enable_x64`` around a module-level jit, so the compiled cache only
    ever holds the f64-correct variant (calling the same jit outside the
    ctx would silently re-trace a truncated-f32 one)."""
    from jax.experimental import enable_x64
    with enable_x64():
        return _finalize_aggregate_jit(packed)


def policy_table_rows() -> List[Dict]:
    """Catalog rows for report.render_table (docs / examples)."""
    rows = []
    for s in sorted(_REGISTRY.values(), key=lambda s: s.index):
        extras = ", ".join(p for p in s.param_names[3:]) or "-"
        rows.append({"policy": s.name, "extra_params": extras,
                     "behaviour": s.doc.split("\n")[0]})
    return rows


# ---------------------------------------------------------------------------
# The Twin record
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Twin:
    """A fitted pipeline model: policy name + flat parameter vector.

    ``params`` is laid out per ``policy_spec(policy).param_names``; the
    first three entries are always (max_rps, usd_per_hour, base_latency_s).
    """
    name: str
    policy: str = "fifo"
    params: Tuple[float, ...] = ()
    kind: str = "fit"

    # shared-triple accessors (every policy's params start with these)
    @property
    def max_rps(self) -> float:
        return self.params[0]

    @property
    def usd_per_hour(self) -> float:
        return self.params[1]

    @property
    def base_latency_s(self) -> float:
        return self.params[2]

    def param(self, pname: str) -> float:
        """Named lookup into the flat vector (falls back to the default)."""
        spec = policy_spec(self.policy)
        i = spec.param_names.index(pname)
        if i < len(self.params):
            return self.params[i]
        return float(spec.defaults[pname])

    def with_params(self, **updates) -> "Twin":
        """A copy with named parameters changed."""
        spec = policy_spec(self.policy)
        vals = dict(zip(spec.param_names, self.padded_params()))
        unknown = set(updates) - set(spec.param_names)
        if unknown:
            raise KeyError(f"{self.policy} has no params {sorted(unknown)}")
        vals.update(updates)
        return replace(self, params=tuple(float(vals[p])
                                          for p in spec.param_names))

    def padded_params(self) -> np.ndarray:
        """[PARAM_DIM] f32 vector: params, then defaults, then zeros."""
        spec = policy_spec(self.policy)
        vals = [float(v) for v in self.params[:len(spec.param_names)]]
        for pname in spec.param_names[len(vals):]:
            vals.append(float(spec.defaults.get(pname, 0.0)))
        vals += [0.0] * (PARAM_DIM - len(vals))
        return np.asarray(vals, np.float32)

    @property
    def policy_index(self) -> int:
        return policy_spec(self.policy).index


def make_twin(name: str, policy: str, *, kind: str = "fit",
              **params: float) -> Twin:
    """Build a Twin by named parameters, filling registered defaults."""
    spec = policy_spec(policy)
    vals = dict(spec.defaults)
    unknown = set(params) - set(spec.param_names)
    if unknown:
        raise KeyError(f"{policy} has no params {sorted(unknown)}; "
                       f"expects {spec.param_names}")
    vals.update(params)
    missing = [p for p in spec.param_names if p not in vals]
    if missing:
        raise KeyError(f"{policy} missing params {missing}")
    return Twin(name=name, policy=policy, kind=kind,
                params=tuple(float(vals[p]) for p in spec.param_names))


# ---------------------------------------------------------------------------
# Built-in policy bin-steps. Pure f32 math, identical output avals across
# branches (lax.switch requirement): carry [CARRY_DIM] and five scalars
# (processed, queue, latency, cost, dropped). ``dt`` is the bin width in
# hours; every formula reduces bit-identically to the hour-step at dt=1
# (multiplying by a literal 1.0 is exact in IEEE f32).
#
# Each built-in also hand-writes its lane-vectorized form (``_*_lane``):
# the same formulas over [L]-vectors with carry [L, CARRY_DIM] — the op
# sequence is kept identical to the scalar step so the two forms agree to
# f32 exactness (asserted at registration). Lane forms must stay finite on
# ANY lane's parameter vector (other policies' params occupy the same
# slots), which every division below guards with ``jnp.maximum(.., 1e-9)``.
# ---------------------------------------------------------------------------

def _fifo_lane(carry, arrive, p, dt):
    max_rps, usd_hr, base_lat = p[:, 0], p[:, 1], p[:, 2]
    cap_bin = max_rps * 3600.0 * dt
    queue = carry[:, 0]
    avail = queue + arrive
    processed = jnp.minimum(avail, cap_bin)
    new_q = avail - processed
    avg_q = 0.5 * (queue + new_q)
    latency = base_lat + avg_q / jnp.maximum(max_rps, 1e-9)
    return (jnp.stack([new_q, carry[:, 1]], axis=1),
            (processed, new_q, latency, usd_hr * dt,
             jnp.zeros_like(arrive)))


@register_policy("fifo", ("max_rps", "usd_per_hour", "base_latency_s"),
                 lane_step=_fifo_lane)
def _fifo_step(carry, arrive, p, dt):
    """Fixed capacity, fixed $/hr, FIFO infinite queue (paper Table I)."""
    max_rps, usd_hr, base_lat = p[0], p[1], p[2]
    cap_bin = max_rps * 3600.0 * dt
    queue = carry[0]
    avail = queue + arrive
    processed = jnp.minimum(avail, cap_bin)
    new_q = avail - processed
    # a record arriving this bin waits behind ~the average queue
    avg_q = 0.5 * (queue + new_q)
    latency = base_lat + avg_q / jnp.maximum(max_rps, 1e-9)
    return (carry.at[0].set(new_q),
            (processed, new_q, latency, usd_hr * dt, jnp.zeros((), jnp.float32)))


def _quickscale_lane(carry, arrive, p, dt):
    max_rps, usd_hr, base_lat = p[:, 0], p[:, 1], p[:, 2]
    cap_bin = max_rps * 3600.0 * dt
    queue = carry[:, 0]
    instances = jnp.maximum(jnp.ceil(arrive / jnp.maximum(cap_bin, 1e-9)),
                            1.0)
    processed = arrive
    new_q = queue * 0.0
    cost = usd_hr * instances * dt
    return (jnp.stack([new_q, carry[:, 1]], axis=1),
            (processed, new_q, base_lat, cost, jnp.zeros_like(arrive)))


def _quickscale_lane_smooth(carry, arrive, p, dt):
    # fluid instance count: ceil() has zero gradient w.r.t. max_rps, so
    # the surrogate pays for fractional instances instead — cost varies
    # smoothly with capacity while latency/throughput stay exact
    max_rps, usd_hr, base_lat = p[:, 0], p[:, 1], p[:, 2]
    cap_bin = max_rps * 3600.0 * dt
    queue = carry[:, 0]
    instances = jnp.maximum(arrive / jnp.maximum(cap_bin, 1e-9), 1.0)
    processed = arrive
    new_q = queue * 0.0
    cost = usd_hr * instances * dt
    return (jnp.stack([new_q, carry[:, 1]], axis=1),
            (processed, new_q, base_lat, cost, jnp.zeros_like(arrive)))


@register_policy("quickscale", ("max_rps", "usd_per_hour",
                                "base_latency_s"),
                 lane_step=_quickscale_lane,
                 nondiff_params=("max_rps",),
                 surrogate_lane_step=_quickscale_lane_smooth)
def _quickscale_step(carry, arrive, p, dt):
    """Optimal scaling: never queues; pay ceil(load/capacity) instances."""
    max_rps, usd_hr, base_lat = p[0], p[1], p[2]
    cap_bin = max_rps * 3600.0 * dt
    queue = carry[0]
    instances = jnp.maximum(jnp.ceil(arrive / jnp.maximum(cap_bin, 1e-9)), 1.0)
    processed = arrive
    new_q = queue * 0.0
    cost = usd_hr * instances * dt
    return (carry.at[0].set(new_q),
            (processed, new_q, base_lat, cost, jnp.zeros((), jnp.float32)))


def _autoscale_lane(carry, arrive, p, dt):
    max_rps, usd_hr, base_lat = p[:, 0], p[:, 1], p[:, 2]
    min_i, max_i, delay = p[:, 3], p[:, 4], p[:, 5]
    cap1 = max_rps * 3600.0 * dt
    queue, prev = carry[:, 0], carry[:, 1]
    prev = jnp.clip(prev, min_i, max_i)
    avail = queue + arrive
    target = jnp.clip(jnp.ceil(avail / jnp.maximum(cap1, 1e-9)),
                      min_i, max_i)
    booting = prev + (target - prev) * dt / jnp.maximum(delay, dt)
    inst = jnp.where(target > prev, booting, target)
    processed = jnp.minimum(avail, inst * cap1)
    new_q = avail - processed
    avg_q = 0.5 * (queue + new_q)
    latency = base_lat + avg_q / jnp.maximum(inst * max_rps, 1e-9)
    cost = usd_hr * inst * dt
    return (jnp.stack([new_q, inst], axis=1),
            (processed, new_q, latency, cost, jnp.zeros_like(arrive)))


def _autoscale_lane_smooth(carry, arrive, p, dt):
    # fluid scaling target: drop the ceil() (zero gradient w.r.t.
    # max_rps); clip keeps exact subgradients w.r.t. min/max_instances,
    # and the first-order boot dynamics already differentiate cleanly
    # w.r.t. scale_up_hours
    max_rps, usd_hr, base_lat = p[:, 0], p[:, 1], p[:, 2]
    min_i, max_i, delay = p[:, 3], p[:, 4], p[:, 5]
    cap1 = max_rps * 3600.0 * dt
    queue, prev = carry[:, 0], carry[:, 1]
    prev = jnp.clip(prev, min_i, max_i)
    avail = queue + arrive
    target = jnp.clip(avail / jnp.maximum(cap1, 1e-9), min_i, max_i)
    booting = prev + (target - prev) * dt / jnp.maximum(delay, dt)
    inst = jnp.where(target > prev, booting, target)
    processed = jnp.minimum(avail, inst * cap1)
    new_q = avail - processed
    avg_q = 0.5 * (queue + new_q)
    latency = base_lat + avg_q / jnp.maximum(inst * max_rps, 1e-9)
    cost = usd_hr * inst * dt
    return (jnp.stack([new_q, inst], axis=1),
            (processed, new_q, latency, cost, jnp.zeros_like(arrive)))


@register_policy("autoscale",
                 ("max_rps", "usd_per_hour", "base_latency_s",
                  "min_instances", "max_instances", "scale_up_hours"),
                 defaults={"min_instances": 1.0, "max_instances": 64.0,
                           "scale_up_hours": 1.0},
                 bounds={"min_instances": (1.0, 4096.0),
                         "max_instances": (1.0, 4096.0),
                         "scale_up_hours": (0.1, 48.0)},
                 log_params=("max_rps", "usd_per_hour", "base_latency_s",
                             "scale_up_hours"),
                 frozen=("min_instances", "max_instances"),
                 lane_step=_autoscale_lane,
                 nondiff_params=("max_rps",),
                 surrogate_lane_step=_autoscale_lane_smooth)
def _autoscale_step(carry, arrive, p, dt):
    """Horizontal scaling with scale-up delay and min/max instance bounds.

    Demand (queue + arrivals) sets a target instance count; booting is
    first-order with time constant ``scale_up_hours`` (teardown is
    immediate), so a slow autoscaler under-provisions during ramps — the
    queueing/latency vs cost lever of cloud-pipeline autoscaling studies.
    params[0:2] are per-instance capacity and per-instance $/hr.
    """
    max_rps, usd_hr, base_lat = p[0], p[1], p[2]
    min_i, max_i, delay = p[3], p[4], p[5]
    cap1 = max_rps * 3600.0 * dt
    queue, prev = carry[0], carry[1]
    prev = jnp.clip(prev, min_i, max_i)   # bin 0: carry starts at min_i
    avail = queue + arrive
    target = jnp.clip(jnp.ceil(avail / jnp.maximum(cap1, 1e-9)),
                      min_i, max_i)
    booting = prev + (target - prev) * dt / jnp.maximum(delay, dt)
    inst = jnp.where(target > prev, booting, target)
    processed = jnp.minimum(avail, inst * cap1)
    new_q = avail - processed
    avg_q = 0.5 * (queue + new_q)
    latency = base_lat + avg_q / jnp.maximum(inst * max_rps, 1e-9)
    cost = usd_hr * inst * dt
    return (jnp.stack([new_q, inst]),
            (processed, new_q, latency, cost, jnp.zeros((), jnp.float32)))


def _shed_lane(carry, arrive, p, dt):
    max_rps, usd_hr, base_lat, qcap_h = p[:, 0], p[:, 1], p[:, 2], p[:, 3]
    cap_hour = max_rps * 3600.0
    cap_bin = cap_hour * dt
    qmax = qcap_h * cap_hour
    queue = carry[:, 0]
    avail = queue + arrive
    processed = jnp.minimum(avail, cap_bin)
    backlog = avail - processed
    dropped = jnp.maximum(backlog - qmax, 0.0)
    new_q = backlog - dropped
    avg_q = 0.5 * (queue + new_q)
    latency = base_lat + avg_q / jnp.maximum(max_rps, 1e-9)
    return (jnp.stack([new_q, carry[:, 1]], axis=1),
            (processed, new_q, latency, usd_hr * dt, dropped))


@register_policy("shed",
                 ("max_rps", "usd_per_hour", "base_latency_s",
                  "queue_cap_hours"),
                 defaults={"queue_cap_hours": 4.0},
                 bounds={"queue_cap_hours": (0.05, 168.0)},
                 log_params=("max_rps", "usd_per_hour", "base_latency_s",
                             "queue_cap_hours"),
                 lane_step=_shed_lane)
def _shed_step(carry, arrive, p, dt):
    """Bounded queue with load shedding: overflow beyond the cap is dropped.

    The queue holds at most ``queue_cap_hours`` hours of capacity worth of
    records; anything beyond is shed and reported in the dropped series, so
    latency stays bounded at the price of completeness.
    """
    max_rps, usd_hr, base_lat, qcap_h = p[0], p[1], p[2], p[3]
    cap_hour = max_rps * 3600.0
    cap_bin = cap_hour * dt
    qmax = qcap_h * cap_hour          # hours-of-capacity, not bins
    queue = carry[0]
    avail = queue + arrive
    processed = jnp.minimum(avail, cap_bin)
    backlog = avail - processed
    dropped = jnp.maximum(backlog - qmax, 0.0)
    new_q = backlog - dropped
    avg_q = 0.5 * (queue + new_q)
    latency = base_lat + avg_q / jnp.maximum(max_rps, 1e-9)
    return (carry.at[0].set(new_q),
            (processed, new_q, latency, usd_hr * dt, dropped))


def _batch_window_lane(carry, arrive, p, dt):
    max_rps, usd_hr, base_lat = p[:, 0], p[:, 1], p[:, 2]
    window, idle_frac = p[:, 3], p[:, 4]
    cap_hour = max_rps * 3600.0
    acc, timer = carry[:, 0], carry[:, 1]
    timer = timer + dt
    flush = timer >= window
    avail = acc + arrive
    processed = jnp.where(flush, jnp.minimum(avail, cap_hour * window), 0.0)
    new_acc = avail - processed
    latency = (base_lat + 0.5 * window * 3600.0
               + new_acc / jnp.maximum(max_rps, 1e-9))
    cost = (usd_hr * idle_frac * dt
            + usd_hr * processed / jnp.maximum(cap_hour, 1e-9))
    new_timer = jnp.where(flush, 0.0, timer)
    return (jnp.stack([new_acc, new_timer], axis=1),
            (processed, new_acc, latency, cost, jnp.zeros_like(arrive)))


def _batch_window_lane_smooth(carry, arrive, p, dt):
    # soft flush gate: the exact step's ``timer >= window`` comparison has
    # zero gradient w.r.t. window_hours, so the surrogate flushes a
    # sigmoid fraction of the accumulator as the timer crosses the
    # window — flush timing (and hence cost/latency) varies smoothly.
    # The TIMER update uses a detached gate: differentiating the soft
    # reset would multiply a ~|d new_timer/d timer| > 1 factor per flush
    # into the scan's backward chain (exponential blowup to inf over a
    # year of flushes); dropping that one term keeps per-bin window
    # sensitivity while the recurrence stays contraction-stable.
    max_rps, usd_hr, base_lat = p[:, 0], p[:, 1], p[:, 2]
    window, idle_frac = p[:, 3], p[:, 4]
    cap_hour = max_rps * 3600.0
    acc, timer = carry[:, 0], carry[:, 1]
    timer = timer + dt
    gate = jax.nn.sigmoid((timer - window) / (0.25 * dt))
    avail = acc + arrive
    processed = gate * jnp.minimum(avail, cap_hour * window)
    new_acc = avail - processed
    latency = (base_lat + 0.5 * window * 3600.0
               + new_acc / jnp.maximum(max_rps, 1e-9))
    cost = (usd_hr * idle_frac * dt
            + usd_hr * processed / jnp.maximum(cap_hour, 1e-9))
    new_timer = (1.0 - jax.lax.stop_gradient(gate)) * timer
    return (jnp.stack([new_acc, new_timer], axis=1),
            (processed, new_acc, latency, cost, jnp.zeros_like(arrive)))


@register_policy("batch_window",
                 ("max_rps", "usd_per_hour", "base_latency_s",
                  "window_hours", "idle_cost_fraction"),
                 defaults={"window_hours": 6.0, "idle_cost_fraction": 0.1},
                 bounds={"window_hours": (0.25, 48.0),
                         "idle_cost_fraction": (0.0, 1.0)},
                 log_params=("max_rps", "usd_per_hour", "base_latency_s",
                             "window_hours"),
                 lane_step=_batch_window_lane,
                 nondiff_params=("window_hours",),
                 surrogate_lane_step=_batch_window_lane_smooth)
def _batch_window_step(carry, arrive, p, dt):
    """Accumulate-then-flush batching: cheap hours, half-a-window latency.

    Records accumulate for ``window_hours``; a flush burst then processes up
    to a full window of capacity at once. Cost is pay-per-use (pipeline
    hours actually consumed) plus an ``idle_cost_fraction`` keep-warm charge
    every hour — bigger windows amortise the idle cost but add ~window/2 of
    batching latency.
    """
    max_rps, usd_hr, base_lat = p[0], p[1], p[2]
    window, idle_frac = p[3], p[4]
    cap_hour = max_rps * 3600.0
    acc, timer = carry[0], carry[1]
    timer = timer + dt                 # hours since last flush
    flush = timer >= window
    avail = acc + arrive
    processed = jnp.where(flush, jnp.minimum(avail, cap_hour * window), 0.0)
    new_acc = avail - processed
    latency = (base_lat + 0.5 * window * 3600.0
               + new_acc / jnp.maximum(max_rps, 1e-9))
    cost = (usd_hr * idle_frac * dt
            + usd_hr * processed / jnp.maximum(cap_hour, 1e-9))
    new_timer = jnp.where(flush, 0.0, timer)
    return (jnp.stack([new_acc, new_timer]),
            (processed, new_acc, latency, cost, jnp.zeros((), jnp.float32)))


# ---------------------------------------------------------------------------
# Constructor aliases (seed API) and fitting from wind-tunnel experiments
# ---------------------------------------------------------------------------

def SimpleTwin(name: str, max_rps: float, usd_per_hour: float,
               base_latency_s: float, policy: str = "fifo",
               kind: str = "simple") -> Twin:
    """Seed-compatible alias: fixed-capacity FIFO twin (paper Table I)."""
    return Twin(name=name, policy=policy, kind=kind,
                params=(float(max_rps), float(usd_per_hour),
                        float(base_latency_s)))


def QuickscalingTwin(name: str, max_rps: float, usd_per_hour: float,
                     base_latency_s: float, policy: str = "quickscale",
                     kind: str = "quickscaling") -> Twin:
    """Seed-compatible alias: optimal horizontal-scaling twin."""
    return Twin(name=name, policy=policy, kind=kind,
                params=(float(max_rps), float(usd_per_hour),
                        float(base_latency_s)))


def fit_twin(result: ExperimentResult, policy: str = "fifo",
             name: Optional[str] = None, **extra_params: float) -> Twin:
    """The paper's fit, generalised to any registered policy: apparent
    sustained throughput over the whole experiment, measured hourly cost,
    no-queue latency from stage medians; policy extras via kwargs."""
    return make_twin(name or result.pipeline_name, policy,
                     max_rps=result.sustained_rps,
                     usd_per_hour=result.cost["usd_per_hour"],
                     base_latency_s=result.base_latency_s,
                     **extra_params)


def fit_simple_twin(result: ExperimentResult,
                    name: Optional[str] = None) -> Twin:
    return fit_twin(result, "fifo", name)


def fit_quickscaling_twin(result: ExperimentResult,
                          name: Optional[str] = None) -> Twin:
    return fit_twin(result, "quickscale", name)


def roofline_twin(name: str, *, step_seconds: float, records_per_step: float,
                  chips: int, chip_usd_per_hour: float = 1.20,
                  base_latency_s: Optional[float] = None) -> Twin:
    """Capacity from the dry-run roofline bound: one serving step processes
    ``records_per_step`` requests in ``step_seconds`` (max of the three
    roofline terms). See launch/roofline.py for the term derivation."""
    cap = records_per_step / step_seconds
    return SimpleTwin(name=name, max_rps=cap,
                      usd_per_hour=chips * chip_usd_per_hour,
                      base_latency_s=base_latency_s or step_seconds,
                      kind="roofline")

"""Digital twins (paper Sec. V-G) as a unified TwinPolicy architecture.

A twin is explainable pipeline model fit from wind-tunnel experiments and
applied to traffic projections by the simulator. Where the paper ships two
hard-coded models (fixed-capacity FIFO and optimal quickscaling), here a
twin is a ``Twin`` record carrying a *policy name* plus a *flat parameter
vector*, and every policy is a pure hour-step function

    step(carry, arrive, params) -> (carry, (processed, queue, latency,
                                            cost, dropped))

registered in a module-level table. The simulator selects the step inside
its ``jax.lax.scan`` with ``jax.lax.switch``, so every (twin x traffic)
scenario of a what-if grid — regardless of policy mix — runs through ONE
vmapped scan kernel (see core/simulate.py). New scaling/queueing policies
are added by registering a step function; the kernel never changes.

Shared convention: ``params[0:3] = (max_rps, usd_per_hour, base_latency_s)``
for every policy; extra parameters follow, zero-padded to ``PARAM_DIM``.
The scan carry is a ``CARRY_DIM``-vector: slot 0 holds queued/accumulated
records, slot 1 holds policy state (autoscale's live instance count,
batch_window's hours-since-flush).

Built-in policies
-----------------
fifo          — fixed capacity, fixed $/hr, FIFO infinite queue (the
                paper's proof-of-concept model, Table I).
quickscale    — optimal horizontal scaling: no queueing; cost scales with
                ceil(load / capacity) instances.
autoscale     — beyond-paper: horizontal scaling with a scale-up delay and
                min/max instance bounds — the autoscaling-delay /
                overprovisioning cost levers of Jablonski & Heltweg.
shed          — beyond-paper: bounded queue with load shedding; excess
                records are dropped and reported per hour.
batch_window  — beyond-paper: accumulate-then-flush batching; pay mostly
                for compute actually used (plus a keep-warm fraction) at
                the price of half-a-window average latency.

``SimpleTwin`` / ``QuickscalingTwin`` remain as constructor aliases that
build the equivalent ``Twin``, and ``roofline_twin`` still derives capacity
analytically from compiled dry-run roofline terms (launch/roofline.py), so
cost/performance can be forecast before a pipeline is ever run at scale.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.experiment import ExperimentResult

CARRY_DIM = 2     # [queued/accumulated records, policy state]
PARAM_DIM = 6     # flat parameter vector, zero-padded per policy


@dataclass(frozen=True)
class PolicySpec:
    """One registered scaling/queueing policy."""
    name: str
    index: int                       # lax.switch branch index (stable)
    step: Callable                   # (carry, arrive, params) -> (carry, out)
    param_names: Tuple[str, ...]     # layout of the flat param vector
    defaults: Dict[str, float]
    doc: str


_REGISTRY: Dict[str, PolicySpec] = {}
_VERSION = 0    # bumped on registration; a static jit arg, so the grid
                # kernel retraces when a new policy is registered late


def register_policy(name: str, param_names: Tuple[str, ...],
                    defaults: Optional[Dict[str, float]] = None,
                    doc: str = ""):
    """Decorator: register ``fn(carry, arrive, params)`` as policy ``name``.

    ``param_names`` must start with the shared triple
    (max_rps, usd_per_hour, base_latency_s) and fit within PARAM_DIM.
    """
    if len(param_names) > PARAM_DIM:
        raise ValueError(f"{name}: {len(param_names)} params > {PARAM_DIM}")
    if tuple(param_names[:3]) != ("max_rps", "usd_per_hour",
                                  "base_latency_s"):
        raise ValueError(f"{name}: params must start with the shared triple")

    def deco(fn):
        global _VERSION
        # overriding an existing policy keeps its switch index so twins
        # built earlier still dispatch to the right branch slot
        prev = _REGISTRY.get(name)
        spec = PolicySpec(name=name,
                          index=prev.index if prev else len(_REGISTRY),
                          step=fn,
                          param_names=tuple(param_names),
                          defaults=dict(defaults or {}),
                          doc=doc or (fn.__doc__ or "").strip())
        _REGISTRY[name] = spec
        _VERSION += 1
        return fn
    return deco


def policy_spec(name: str) -> PolicySpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown twin policy {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def policy_names() -> List[str]:
    return [s.name for s in sorted(_REGISTRY.values(), key=lambda s: s.index)]


def policy_branches() -> Tuple[Callable, ...]:
    """Step functions ordered by switch index (the kernel's branch table)."""
    return tuple(s.step for s in
                 sorted(_REGISTRY.values(), key=lambda s: s.index))


def registry_version() -> int:
    return _VERSION


def policy_table_rows() -> List[Dict]:
    """Catalog rows for report.render_table (docs / examples)."""
    rows = []
    for s in sorted(_REGISTRY.values(), key=lambda s: s.index):
        extras = ", ".join(p for p in s.param_names[3:]) or "-"
        rows.append({"policy": s.name, "extra_params": extras,
                     "behaviour": s.doc.split("\n")[0]})
    return rows


# ---------------------------------------------------------------------------
# The Twin record
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Twin:
    """A fitted pipeline model: policy name + flat parameter vector.

    ``params`` is laid out per ``policy_spec(policy).param_names``; the
    first three entries are always (max_rps, usd_per_hour, base_latency_s).
    """
    name: str
    policy: str = "fifo"
    params: Tuple[float, ...] = ()
    kind: str = "fit"

    # shared-triple accessors (every policy's params start with these)
    @property
    def max_rps(self) -> float:
        return self.params[0]

    @property
    def usd_per_hour(self) -> float:
        return self.params[1]

    @property
    def base_latency_s(self) -> float:
        return self.params[2]

    def param(self, pname: str) -> float:
        """Named lookup into the flat vector (falls back to the default)."""
        spec = policy_spec(self.policy)
        i = spec.param_names.index(pname)
        if i < len(self.params):
            return self.params[i]
        return float(spec.defaults[pname])

    def with_params(self, **updates) -> "Twin":
        """A copy with named parameters changed."""
        spec = policy_spec(self.policy)
        vals = dict(zip(spec.param_names, self.padded_params()))
        unknown = set(updates) - set(spec.param_names)
        if unknown:
            raise KeyError(f"{self.policy} has no params {sorted(unknown)}")
        vals.update(updates)
        return replace(self, params=tuple(float(vals[p])
                                          for p in spec.param_names))

    def padded_params(self) -> np.ndarray:
        """[PARAM_DIM] f32 vector: params, then defaults, then zeros."""
        spec = policy_spec(self.policy)
        vals = [float(v) for v in self.params[:len(spec.param_names)]]
        for pname in spec.param_names[len(vals):]:
            vals.append(float(spec.defaults.get(pname, 0.0)))
        vals += [0.0] * (PARAM_DIM - len(vals))
        return np.asarray(vals, np.float32)

    @property
    def policy_index(self) -> int:
        return policy_spec(self.policy).index


def make_twin(name: str, policy: str, *, kind: str = "fit",
              **params: float) -> Twin:
    """Build a Twin by named parameters, filling registered defaults."""
    spec = policy_spec(policy)
    vals = dict(spec.defaults)
    unknown = set(params) - set(spec.param_names)
    if unknown:
        raise KeyError(f"{policy} has no params {sorted(unknown)}; "
                       f"expects {spec.param_names}")
    vals.update(params)
    missing = [p for p in spec.param_names if p not in vals]
    if missing:
        raise KeyError(f"{policy} missing params {missing}")
    return Twin(name=name, policy=policy, kind=kind,
                params=tuple(float(vals[p]) for p in spec.param_names))


# ---------------------------------------------------------------------------
# Built-in policy hour-steps. Pure f32 math, identical output avals across
# branches (lax.switch requirement): carry [CARRY_DIM] and five scalars
# (processed, queue, latency, cost, dropped).
# ---------------------------------------------------------------------------

@register_policy("fifo", ("max_rps", "usd_per_hour", "base_latency_s"))
def _fifo_step(carry, arrive, p):
    """Fixed capacity, fixed $/hr, FIFO infinite queue (paper Table I)."""
    max_rps, usd_hr, base_lat = p[0], p[1], p[2]
    cap_h = max_rps * 3600.0
    queue = carry[0]
    avail = queue + arrive
    processed = jnp.minimum(avail, cap_h)
    new_q = avail - processed
    # a record arriving this hour waits behind ~the average queue
    avg_q = 0.5 * (queue + new_q)
    latency = base_lat + avg_q / jnp.maximum(max_rps, 1e-9)
    return (carry.at[0].set(new_q),
            (processed, new_q, latency, usd_hr, jnp.zeros(())))


@register_policy("quickscale", ("max_rps", "usd_per_hour",
                                "base_latency_s"))
def _quickscale_step(carry, arrive, p):
    """Optimal scaling: never queues; pay ceil(load/capacity) instances."""
    max_rps, usd_hr, base_lat = p[0], p[1], p[2]
    cap_h = max_rps * 3600.0
    queue = carry[0]
    instances = jnp.maximum(jnp.ceil(arrive / jnp.maximum(cap_h, 1e-9)), 1.0)
    processed = arrive
    new_q = queue * 0.0
    cost = usd_hr * instances
    return (carry.at[0].set(new_q),
            (processed, new_q, base_lat, cost, jnp.zeros(())))


@register_policy("autoscale",
                 ("max_rps", "usd_per_hour", "base_latency_s",
                  "min_instances", "max_instances", "scale_up_hours"),
                 defaults={"min_instances": 1.0, "max_instances": 64.0,
                           "scale_up_hours": 1.0})
def _autoscale_step(carry, arrive, p):
    """Horizontal scaling with scale-up delay and min/max instance bounds.

    Demand (queue + arrivals) sets a target instance count; booting is
    first-order with time constant ``scale_up_hours`` (teardown is
    immediate), so a slow autoscaler under-provisions during ramps — the
    queueing/latency vs cost lever of cloud-pipeline autoscaling studies.
    params[0:2] are per-instance capacity and per-instance $/hr.
    """
    max_rps, usd_hr, base_lat = p[0], p[1], p[2]
    min_i, max_i, delay = p[3], p[4], p[5]
    cap1 = max_rps * 3600.0
    queue, prev = carry[0], carry[1]
    prev = jnp.clip(prev, min_i, max_i)   # hour 0: carry starts at min_i
    avail = queue + arrive
    target = jnp.clip(jnp.ceil(avail / jnp.maximum(cap1, 1e-9)),
                      min_i, max_i)
    booting = prev + (target - prev) / jnp.maximum(delay, 1.0)
    inst = jnp.where(target > prev, booting, target)
    processed = jnp.minimum(avail, inst * cap1)
    new_q = avail - processed
    avg_q = 0.5 * (queue + new_q)
    latency = base_lat + avg_q / jnp.maximum(inst * max_rps, 1e-9)
    cost = usd_hr * inst
    return (jnp.stack([new_q, inst]),
            (processed, new_q, latency, cost, jnp.zeros(())))


@register_policy("shed",
                 ("max_rps", "usd_per_hour", "base_latency_s",
                  "queue_cap_hours"),
                 defaults={"queue_cap_hours": 4.0})
def _shed_step(carry, arrive, p):
    """Bounded queue with load shedding: overflow beyond the cap is dropped.

    The queue holds at most ``queue_cap_hours`` hours of capacity worth of
    records; anything beyond is shed and reported in the dropped series, so
    latency stays bounded at the price of completeness.
    """
    max_rps, usd_hr, base_lat, qcap_h = p[0], p[1], p[2], p[3]
    cap_h = max_rps * 3600.0
    qmax = qcap_h * cap_h
    queue = carry[0]
    avail = queue + arrive
    processed = jnp.minimum(avail, cap_h)
    backlog = avail - processed
    dropped = jnp.maximum(backlog - qmax, 0.0)
    new_q = backlog - dropped
    avg_q = 0.5 * (queue + new_q)
    latency = base_lat + avg_q / jnp.maximum(max_rps, 1e-9)
    return (carry.at[0].set(new_q),
            (processed, new_q, latency, usd_hr, dropped))


@register_policy("batch_window",
                 ("max_rps", "usd_per_hour", "base_latency_s",
                  "window_hours", "idle_cost_fraction"),
                 defaults={"window_hours": 6.0, "idle_cost_fraction": 0.1})
def _batch_window_step(carry, arrive, p):
    """Accumulate-then-flush batching: cheap hours, half-a-window latency.

    Records accumulate for ``window_hours``; a flush burst then processes up
    to a full window of capacity at once. Cost is pay-per-use (pipeline
    hours actually consumed) plus an ``idle_cost_fraction`` keep-warm charge
    every hour — bigger windows amortise the idle cost but add ~window/2 of
    batching latency.
    """
    max_rps, usd_hr, base_lat = p[0], p[1], p[2]
    window, idle_frac = p[3], p[4]
    cap_h = max_rps * 3600.0
    acc, timer = carry[0], carry[1]
    timer = timer + 1.0
    flush = timer >= window
    avail = acc + arrive
    processed = jnp.where(flush, jnp.minimum(avail, cap_h * window), 0.0)
    new_acc = avail - processed
    latency = (base_lat + 0.5 * window * 3600.0
               + new_acc / jnp.maximum(max_rps, 1e-9))
    cost = (usd_hr * idle_frac
            + usd_hr * processed / jnp.maximum(cap_h, 1e-9))
    new_timer = jnp.where(flush, 0.0, timer)
    return (jnp.stack([new_acc, new_timer]),
            (processed, new_acc, latency, cost, jnp.zeros(())))


# ---------------------------------------------------------------------------
# Constructor aliases (seed API) and fitting from wind-tunnel experiments
# ---------------------------------------------------------------------------

def SimpleTwin(name: str, max_rps: float, usd_per_hour: float,
               base_latency_s: float, policy: str = "fifo",
               kind: str = "simple") -> Twin:
    """Seed-compatible alias: fixed-capacity FIFO twin (paper Table I)."""
    return Twin(name=name, policy=policy, kind=kind,
                params=(float(max_rps), float(usd_per_hour),
                        float(base_latency_s)))


def QuickscalingTwin(name: str, max_rps: float, usd_per_hour: float,
                     base_latency_s: float, policy: str = "quickscale",
                     kind: str = "quickscaling") -> Twin:
    """Seed-compatible alias: optimal horizontal-scaling twin."""
    return Twin(name=name, policy=policy, kind=kind,
                params=(float(max_rps), float(usd_per_hour),
                        float(base_latency_s)))


def fit_twin(result: ExperimentResult, policy: str = "fifo",
             name: Optional[str] = None, **extra_params: float) -> Twin:
    """The paper's fit, generalised to any registered policy: apparent
    sustained throughput over the whole experiment, measured hourly cost,
    no-queue latency from stage medians; policy extras via kwargs."""
    return make_twin(name or result.pipeline_name, policy,
                     max_rps=result.sustained_rps,
                     usd_per_hour=result.cost["usd_per_hour"],
                     base_latency_s=result.base_latency_s,
                     **extra_params)


def fit_simple_twin(result: ExperimentResult,
                    name: Optional[str] = None) -> Twin:
    return fit_twin(result, "fifo", name)


def fit_quickscaling_twin(result: ExperimentResult,
                          name: Optional[str] = None) -> Twin:
    return fit_twin(result, "quickscale", name)


def roofline_twin(name: str, *, step_seconds: float, records_per_step: float,
                  chips: int, chip_usd_per_hour: float = 1.20,
                  base_latency_s: Optional[float] = None) -> Twin:
    """Capacity from the dry-run roofline bound: one serving step processes
    ``records_per_step`` requests in ``step_seconds`` (max of the three
    roofline terms). See launch/roofline.py for the term derivation."""
    cap = records_per_step / step_seconds
    return SimpleTwin(name=name, max_rps=cap,
                      usd_per_hour=chips * chip_usd_per_hour,
                      base_latency_s=base_latency_s or step_seconds,
                      kind="roofline")

"""Digital twins (paper Sec. V-G): explainable pipeline models fit from
experiments, applied to traffic projections by the simulator.

SimpleTwin      — fixed capacity, fixed $/hr, FIFO infinite queue (the
                  paper's proof-of-concept model, Table I).
QuickscalingTwin— optimal horizontal scaling: no queueing; cost scales with
                  ceil(load / capacity) instances.
RooflineTwin    — beyond-paper: capacity derived *analytically* from the
                  compiled dry-run roofline terms of a JAX serving pipeline,
                  so cost/performance can be forecast before the pipeline is
                  ever run at scale.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.experiment import ExperimentResult


@dataclass(frozen=True)
class SimpleTwin:
    name: str
    max_rps: float               # sustained capacity, records/s
    usd_per_hour: float          # fixed resource cost
    base_latency_s: float        # per-record latency with no queueing
    policy: str = "fifo"
    kind: str = "simple"


@dataclass(frozen=True)
class QuickscalingTwin:
    name: str
    max_rps: float               # capacity of ONE instance
    usd_per_hour: float          # cost of ONE instance
    base_latency_s: float
    policy: str = "scale"
    kind: str = "quickscaling"


def fit_simple_twin(result: ExperimentResult, name: Optional[str] = None
                    ) -> SimpleTwin:
    """The paper's fit: apparent sustained throughput over the whole
    experiment, fixed hourly cost, no-queue latency from stage medians."""
    return SimpleTwin(
        name=name or result.pipeline_name,
        max_rps=result.sustained_rps,
        usd_per_hour=result.cost["usd_per_hour"],
        base_latency_s=result.base_latency_s)


def fit_quickscaling_twin(result: ExperimentResult, name: Optional[str] = None
                          ) -> QuickscalingTwin:
    return QuickscalingTwin(
        name=name or result.pipeline_name,
        max_rps=result.sustained_rps,
        usd_per_hour=result.cost["usd_per_hour"],
        base_latency_s=result.base_latency_s)


def roofline_twin(name: str, *, step_seconds: float, records_per_step: float,
                  chips: int, chip_usd_per_hour: float = 1.20,
                  base_latency_s: Optional[float] = None) -> SimpleTwin:
    """Capacity from the dry-run roofline bound: one serving step processes
    ``records_per_step`` requests in ``step_seconds`` (max of the three
    roofline terms). See launch/roofline.py for the term derivation."""
    cap = records_per_step / step_seconds
    return SimpleTwin(name=name, max_rps=cap,
                      usd_per_hour=chips * chip_usd_per_hour,
                      base_latency_s=base_latency_s or step_seconds,
                      kind="roofline")

"""Experiment management (the paper's Experiment custom resource).

An Experiment ties a DataSet, a LoadPattern and a Pipeline together, runs
the load at the requested rates, waits for the pipeline to finish, and
packages spans + metrics + cost into an ExperimentResult. Only one
experiment is "engaged" at a time (module-level lock), exactly as PlantD
serializes experiments against a pipeline.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.cost import CostModel
from repro.core.datagen import DataSet
from repro.core.loadpattern import LoadPattern
from repro.core.metrics import MetricStore
from repro.core.pipeline import Pipeline
from repro.core.spans import SpanCollector

_ENGAGED = threading.Lock()


@dataclass
class ExperimentResult:
    name: str
    pipeline_name: str
    started: float
    duration_s: float
    records_sent: int
    records_done: int
    ingest_mb: float
    stage_summary: Dict[str, Dict[str, float]]
    cost: Dict[str, float]
    collector: SpanCollector
    metrics: MetricStore
    drained: bool
    # dilation the experiment ran under; trace binning (repro.calibrate)
    # uses it to convert real span timestamps back to virtual time
    time_scale: float = 1.0

    @property
    def sustained_rps(self) -> float:
        """Apparent sustained throughput: records fully processed / total
        time to process them (the paper's simple-twin capacity estimate)."""
        return self.records_done / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def base_latency_s(self) -> float:
        """End-to-end per-record latency with no queueing: sum of stage
        median service times."""
        return sum(v["p50_latency_s"] for v in self.stage_summary.values())


@dataclass
class Experiment:
    name: str
    pipeline: Pipeline
    load: LoadPattern
    dataset: DataSet
    cost_model: CostModel = field(default_factory=CostModel)
    batch_records: int = 1          # records per submitted batch
    tick_s: float = 0.02
    drain_timeout_s: float = 600.0
    # time dilation for tests: 2.0 runs the pattern twice as fast while
    # reporting undialted rates (keeps CI quick without changing semantics)
    time_scale: float = 1.0
    status: str = "pending"

    def run(self) -> ExperimentResult:
        with _ENGAGED:          # one engaged experiment at a time
            return self._run()

    def _run(self) -> ExperimentResult:
        self.status = "engaged"
        pipe = self.pipeline
        metrics = MetricStore()
        pipe.start()
        sent = 0
        carry = 0.0
        t_start = time.perf_counter()
        virt_total = self.load.total_duration
        try:
            virt_prev = 0.0
            while virt_prev < virt_total:
                time.sleep(self.tick_s)
                virt_now = min((time.perf_counter() - t_start) * self.time_scale,
                               virt_total)
                due = self.load.records_between(virt_prev, virt_now) + carry
                n = int(due)
                carry = due - n
                virt_prev = virt_now
                while n > 0:
                    take = min(n, self.batch_records)
                    batch = self.dataset.record_batch(sent, take)
                    pipe.submit(batch, take)
                    sent += take
                    n -= take
                # every experiment series shares the virtual (undilated)
                # clock, so time_scale'd runs export one coherent time base
                # and calibration can bin records_sent into an ObservedTrace
                metrics.observe("load_rps", self.load.rate_at(virt_now),
                                t=virt_now)
                metrics.observe("queued_records", pipe.inflight, t=virt_now)
                metrics.observe("records_sent", sent, t=virt_now)
            drained = pipe.drain(self.drain_timeout_s)
        finally:
            pipe.stop()
        t_end = time.perf_counter()
        # report in *virtual* (undilated) time so time_scale is transparent
        duration = (t_end - t_start) * self.time_scale
        summary = pipe.collector.summary()
        if self.time_scale != 1.0:
            for v in summary.values():
                v["throughput_rps"] = v["throughput_rps"] / self.time_scale
        ingest_mb = sent * self.dataset.schema.record_bytes() / 1e6
        cost = self.cost_model.experiment_cost(pipe.resources, duration, ingest_mb)
        self.status = "completed"
        return ExperimentResult(
            name=self.name, pipeline_name=pipe.name, started=t_start,
            duration_s=duration, records_sent=sent,
            records_done=sent - max(pipe.inflight, 0), ingest_mb=ingest_mb,
            stage_summary=summary, cost=cost, collector=pipe.collector,
            metrics=metrics, drained=drained, time_scale=self.time_scale)

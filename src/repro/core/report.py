"""Result rendering (PlantD-Studio's tables, as text/CSV)."""
from __future__ import annotations

import csv
import io
from typing import Dict, List, Sequence


def render_table(rows: Sequence[Dict], title: str = "") -> str:
    if not rows:
        return f"{title}\n(no rows)\n"
    cols = list(rows[0].keys())
    widths = {c: max(len(str(c)), *(len(_fmt(r.get(c))) for r in rows))
              for c in cols}
    out = io.StringIO()
    if title:
        out.write(title + "\n")
    out.write(" | ".join(str(c).ljust(widths[c]) for c in cols) + "\n")
    out.write("-+-".join("-" * widths[c] for c in cols) + "\n")
    for r in rows:
        out.write(" | ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols) + "\n")
    return out.getvalue()


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e6 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:,.2f}"
    return str(v)


def write_csv(rows: Sequence[Dict], path: str):
    if not rows:
        return
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        for r in rows:
            w.writerow(r)


def bench_csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    """Benchmark harness line format: ``name,us_per_call,derived``."""
    return f"{name},{us_per_call:.2f},{derived}"

"""Pipeline-under-test abstraction.

A Pipeline is a chain of named stages connected by bounded queues, each
stage running on its own worker thread (the in-process analogue of the
paper's Kafka-decoupled stages). Every stage execution is wrapped in a span,
so the collector sees per-stage latency/throughput exactly like the paper's
OpenTelemetry instrumentation. Ingestion happens by ``submit``-ing record
batches; ``drain`` waits until all queues are empty (the paper's "can't even
tell when the pipeline is done without instrumentation" — here the harness
owns the queues, so it can).

Resources (vCPU/RAM) are declared per pipeline for cost allocation — the
OpenCost analogue prorates their price over the experiment window.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.spans import SpanCollector, span


@dataclass
class PipelineStage:
    name: str
    fn: Callable[[Any], Any]          # batch -> batch (None output = sink)
    # simulated cgroup CPU quota: fraction of a core this stage may use
    # (1.0 = unthrottled). Implements the paper's `cpu-limited` variant.
    cpu_quota: float = 1.0


@dataclass
class Resources:
    vcpus: float = 2.0
    ram_gb: float = 4.0
    chips: int = 0                    # TPU chips (serving/training pipelines)


class Pipeline:
    def __init__(self, name: str, stages: Sequence[PipelineStage],
                 resources: Resources = Resources(),
                 collector: Optional[SpanCollector] = None,
                 queue_depth: int = 100000):
        self.name = name
        self.stages = list(stages)
        self.resources = resources
        self.collector = collector or SpanCollector()
        self._queues: List[queue.Queue] = [queue.Queue(queue_depth)
                                           for _ in self.stages]
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        self.errors: List[Exception] = []

    # -- lifecycle -----------------------------------------------------------
    def start(self):
        self._stop.clear()
        for i, stage in enumerate(self.stages):
            t = threading.Thread(target=self._worker, args=(i, stage),
                                 daemon=True, name=f"{self.name}.{stage.name}")
            t.start()
            self._threads.append(t)

    def stop(self):
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads.clear()

    def _worker(self, idx: int, stage: PipelineStage):
        while not self._stop.is_set():
            try:
                batch, records = self._queues[idx].get(timeout=0.05)
            except queue.Empty:
                continue
            t0 = time.perf_counter()
            try:
                with span(stage.name, self.collector, records=records):
                    out = stage.fn(batch)
            except Exception as e:   # noqa: BLE001 — stage fault isolation
                self.errors.append(e)
                out = None
            busy = time.perf_counter() - t0
            if stage.cpu_quota < 1.0 and busy > 0:
                # cgroup-style throttle: a quota q stretches wall time by 1/q
                time.sleep(busy * (1.0 / stage.cpu_quota - 1.0))
            if out is not None and idx + 1 < len(self.stages):
                self._queues[idx + 1].put((out, records))
            else:
                with self._inflight_lock:
                    self._inflight -= records
            self._queues[idx].task_done()

    # -- ingestion ------------------------------------------------------------
    def submit(self, batch: Any, records: int = 1):
        with self._inflight_lock:
            self._inflight += records
        self._queues[0].put((batch, records))

    def queue_depths(self) -> Dict[str, int]:
        return {s.name: q.qsize() for s, q in zip(self.stages, self._queues)}

    @property
    def inflight(self) -> int:
        with self._inflight_lock:
            return self._inflight

    def drain(self, timeout: float = 600.0) -> bool:
        """Wait until every submitted record has left the last stage."""
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < timeout:
            if self.inflight <= 0:
                return True
            time.sleep(0.01)
        return False

"""Span instrumentation (OpenTelemetry analogue) + collector.

Pipeline stages are wrapped in ``with span("stage", collector, records=n):``
blocks. The collector converts finished spans into time-series metrics
(throughput, latency per stage) — the paper's OTel-collector -> Prometheus
path, in-process. Span overhead is a few microseconds, honoring the paper's
"minimal instrumentation burden" design goal.
"""
from __future__ import annotations

import contextlib
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

from repro import obs


@dataclass
class Span:
    name: str
    start: float
    duration: float
    records: int = 1
    attrs: Dict[str, float] = field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.start + self.duration


class SpanCollector:
    """Accumulates spans; converts them to per-stage metrics on demand."""

    def __init__(self, clock=time.perf_counter):
        self._spans: List[Span] = []
        self._lock = threading.Lock()
        self.clock = clock

    def add(self, s: Span):
        with self._lock:
            self._spans.append(s)

    def spans(self, name: Optional[str] = None) -> List[Span]:
        with self._lock:
            out = list(self._spans)
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def stage_names(self) -> List[str]:
        seen: Dict[str, None] = {}
        for s in self.spans():
            seen.setdefault(s.name, None)
        return list(seen)

    def clear(self):
        with self._lock:
            self._spans.clear()

    # -- metric conversions (the "collector module") ------------------------

    def stage_latency(self, name: str) -> List[float]:
        """Per-record latency estimates of one stage (duration/records)."""
        return [s.duration / max(s.records, 1) for s in self.spans(name)]

    def stage_throughput(self, name: str, bucket_s: float = 1.0) -> List[tuple]:
        """(bucket_time, records/s) series for one stage."""
        spans = self.spans(name)
        if not spans:
            return []
        t0 = min(s.start for s in spans)
        t1 = max(s.end for s in spans)
        n_buckets = max(1, int((t1 - t0) / bucket_s) + 1)
        counts = [0.0] * n_buckets
        for s in spans:
            b = int((s.end - t0) / bucket_s)
            counts[min(b, n_buckets - 1)] += s.records
        return [(t0 + (i + 0.5) * bucket_s, c / bucket_s)
                for i, c in enumerate(counts)]

    def summary(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for name in self.stage_names():
            lats = self.stage_latency(name)
            spans = self.spans(name)
            recs = sum(s.records for s in spans)
            wall = (max(s.end for s in spans) - min(s.start for s in spans)
                    ) if spans else 0.0
            out[name] = {
                "records": recs,
                "mean_latency_s": sum(lats) / max(len(lats), 1),
                "p50_latency_s": sorted(lats)[len(lats) // 2] if lats else 0.0,
                "throughput_rps": recs / wall if wall > 0 else 0.0,
                "busy_s": sum(s.duration for s in spans),
            }
        return out


@contextlib.contextmanager
def span(name: str, collector: Optional[SpanCollector],
         records: int = 1, **attrs) -> Iterator[None]:
    """Wrap one stage invocation. Spans land in the pipeline's own
    ``collector`` as always; when run-telemetry is on they ALSO mirror
    into ``repro.obs`` as ``stage.{name}`` spans with a ``records``
    attr — which is what lets ``obs.to_otel_spans(prefix="stage.")``
    export an instrumented experiment straight into
    ``ObservedTrace.from_otel_spans`` (the round-trip into calibrate)."""
    if collector is None:
        with obs.span(f"stage.{name}", records=records, **attrs):
            yield
        return
    t0 = collector.clock()
    try:
        with obs.span(f"stage.{name}", records=records, **attrs):
            yield
    finally:
        collector.add(Span(name, t0, collector.clock() - t0, records,
                           {k: float(v) for k, v in attrs.items()}))

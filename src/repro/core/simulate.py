"""Year-long pipeline simulation (paper Sec. V-G / Tables II & IV).

``simulate_year`` plays an hourly load projection through a digital twin:
FIFO queueing when load exceeds capacity (SimpleTwin) or elastic scaling
(QuickscalingTwin). Implemented as a jitted ``jax.lax.scan`` over the 8736
hours — "no synthetic data is actually processed; only the load shape is
used, so the simulation is quite fast" (paper) — here a full year simulates
in ~1 ms, so what-if grids over many scenarios are interactive.

End-of-year backlog is priced the paper's way: queue_length / capacity
hours of extra pipeline time at the twin's hourly rate ("the cost of, for
example, spinning up duplicate pipelines to process the backlog").

``storage_costs`` runs the daily rolling-retention accumulation (Table IV):
data builds up day by day and ages out after the retention window.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import CostModel
from repro.core.slo import SLO
from repro.core.traffic import DAYS_PER_YEAR, HOURS_PER_YEAR, MONTH_DAYS
from repro.core.twin import QuickscalingTwin, SimpleTwin

Twin = Union[SimpleTwin, QuickscalingTwin]


@dataclass
class SimulationResult:
    name: str
    twin: Twin
    # hourly arrays [8736]
    load: np.ndarray
    processed: np.ndarray
    queue: np.ndarray
    latency_s: np.ndarray
    cost_usd: np.ndarray
    # scalars
    total_cost_usd: float
    backlog_s: float
    backlog_cost_usd: float
    mean_throughput_rph: float
    max_throughput_rph: float
    median_latency_s: float
    mean_latency_s: float
    pct_latency_met: float          # record-weighted, vs slo.limit
    pct_hours_met: float            # hour-weighted
    slo_met: Optional[bool]
    network_cost_usd: float = 0.0
    storage_cost_usd: float = 0.0

    @property
    def grand_total_usd(self) -> float:
        return self.total_cost_usd + self.network_cost_usd + self.storage_cost_usd


@functools.partial(jax.jit, static_argnums=(2,))
def _fifo_scan(load: jnp.ndarray, params: jnp.ndarray, quickscale: bool):
    """load [H] records/hour; params = (max_rps, usd_per_hour, base_lat)."""
    max_rps, usd_hr, base_lat = params
    cap_h = max_rps * 3600.0

    def hour(queue, arrive):
        if quickscale:
            instances = jnp.maximum(jnp.ceil(arrive / jnp.maximum(cap_h, 1e-9)), 1.0)
            processed = arrive
            new_q = queue * 0.0
            latency = base_lat
            cost = usd_hr * instances
        else:
            avail = queue + arrive
            processed = jnp.minimum(avail, cap_h)
            new_q = avail - processed
            # a record arriving this hour waits behind ~the average queue
            avg_q = 0.5 * (queue + new_q)
            latency = base_lat + avg_q / jnp.maximum(max_rps, 1e-9)
            cost = usd_hr
        return new_q, (processed, new_q, latency, cost)

    q_end, (processed, queue, latency, cost) = jax.lax.scan(
        hour, jnp.zeros(()), load)
    return q_end, processed, queue, latency, cost


def simulate_year(twin: Twin, hourly_load: np.ndarray,
                  slo: Optional[SLO] = None,
                  cost_model: Optional[CostModel] = None,
                  record_mb: float = 0.0,
                  name: Optional[str] = None) -> SimulationResult:
    load = jnp.asarray(hourly_load, jnp.float32)
    assert load.shape == (HOURS_PER_YEAR,), load.shape
    params = jnp.array([twin.max_rps, twin.usd_per_hour, twin.base_latency_s],
                       jnp.float32)
    quick = isinstance(twin, QuickscalingTwin) or twin.kind == "quickscaling"
    q_end, processed, queue, latency, cost = _fifo_scan(load, params, quick)

    load_np = np.asarray(load, np.float64)
    lat_np = np.asarray(latency, np.float64)
    cost_np = np.asarray(cost, np.float64)
    backlog_s = float(q_end) / max(twin.max_rps, 1e-9)
    backlog_cost = backlog_s / 3600.0 * twin.usd_per_hour

    # record-weighted latency stats (records arriving each hour share the
    # hour's latency estimate)
    w = load_np / max(load_np.sum(), 1e-9)
    order = np.argsort(lat_np)
    cdf = np.cumsum(w[order])
    median_lat = float(lat_np[order][np.searchsorted(cdf, 0.5)])
    mean_lat = float((lat_np * w).sum())

    pct_rec_met = pct_hours_met = 100.0
    slo_met = None
    if slo is not None:
        ok = lat_np <= slo.limit_s
        pct_rec_met = float((w * ok).sum() * 100.0)
        pct_hours_met = float(ok.mean() * 100.0)
        slo_met = bool(pct_rec_met >= slo.met_fraction * 100.0)

    net_cost = stor_cost = 0.0
    if cost_model is not None and record_mb > 0.0:
        daily = storage_costs(load_np, cost_model, record_mb)
        net_cost = float(daily["network_usd"].sum())
        stor_cost = float(daily["storage_usd"].sum())

    return SimulationResult(
        name=name or f"{twin.name}", twin=twin, load=load_np,
        processed=np.asarray(processed, np.float64),
        queue=np.asarray(queue, np.float64), latency_s=lat_np,
        cost_usd=cost_np,
        total_cost_usd=float(cost_np.sum() + backlog_cost),
        backlog_s=backlog_s, backlog_cost_usd=backlog_cost,
        mean_throughput_rph=float(np.asarray(processed).mean()),
        max_throughput_rph=float(np.asarray(processed).max()),
        median_latency_s=median_lat, mean_latency_s=mean_lat,
        pct_latency_met=pct_rec_met, pct_hours_met=pct_hours_met,
        slo_met=slo_met, network_cost_usd=net_cost,
        storage_cost_usd=stor_cost)


def storage_costs(hourly_load: np.ndarray, cost_model: CostModel,
                  record_mb: float) -> Dict[str, np.ndarray]:
    """Daily rolling-retention storage + network costs (Table IV)."""
    daily_records = hourly_load.reshape(DAYS_PER_YEAR, 24).sum(axis=1)
    ingest_mb = daily_records * record_mb
    ret = cost_model.retention_days
    # stored_mb[d] = sum of ingest over the trailing retention window
    csum = np.concatenate([[0.0], np.cumsum(ingest_mb)])
    lo = np.maximum(np.arange(DAYS_PER_YEAR) + 1 - ret, 0)
    stored_mb = csum[1:] - csum[lo]
    return {
        "ingest_mb": ingest_mb,
        "stored_gb": stored_mb / 1024.0,
        "network_usd": ingest_mb * cost_model.network_usd_per_mb,
        "storage_usd": stored_mb / 1024.0 * cost_model.storage_usd_per_gb_day,
    }


def monthly_table(sim: SimulationResult, cost_model: CostModel,
                  record_mb: float) -> List[Dict[str, float]]:
    """Monthly cloud/network/storage breakdown (Table IV rows)."""
    daily = storage_costs(sim.load, cost_model, record_mb)
    rows = []
    day0 = 0
    hourly_cost = sim.cost_usd
    for m, nd in enumerate(MONTH_DAYS):
        days = slice(day0, day0 + nd)
        hours = slice(day0 * 24, (day0 + nd) * 24)
        cloud = float(hourly_cost[hours].sum())
        net = float(daily["network_usd"][days].sum())
        stor = float(daily["storage_usd"][days].sum())
        rows.append({"month": m + 1, "cloud_usd": cloud, "network_usd": net,
                     "storage_usd": stor, "total_usd": cloud + net + stor})
        day0 += nd
    return rows

"""Year-long pipeline simulation (paper Sec. V-G / Tables II & IV) on the
unified TwinPolicy engine.

``simulate_grid`` plays hourly load projections through digital twins: the
whole batch of (twin x traffic) scenarios is stacked into [N, H] load and
[N, PARAM_DIM] parameter arrays and executed as ONE ``jax.vmap`` over a
jitted ``jax.lax.scan`` of the 8736 hours. Each hour step dispatches to the
twin's registered policy with ``jax.lax.switch`` (see core/twin.py), so a
grid mixing fifo / quickscale / autoscale / shed / batch_window twins is a
single device dispatch — "no synthetic data is actually processed; only the
load shape is used, so the simulation is quite fast" (paper); here a full
64-scenario grid simulates in about the time the seed took for one.

``simulate_year`` is the batch-of-one convenience wrapper and keeps the
seed's exact semantics: legacy SimpleTwin/QuickscalingTwin results are
numerically identical to the old hard-coded scan.

The scan is generalized to arbitrary horizon and bin width: policy steps
take the bin width ``dt`` (hours), so the same kernel that plays 8736
one-hour bins for the year tables also replays a sub-hour calibration
trace (``repro.calibrate``). ``scan_trace`` is the unbatched, *unjitted*
core — differentiable w.r.t. the parameter vector, which is what twin
calibration differentiates through. The year path pins dt=1.0 (a static
jit arg) and stays bit-identical to the PR 1 kernel.

The grid runs on either of two interchangeable backends, selected by
``_grid_scan`` through the ``kernels.ops`` Pallas switch:

* **XLA** (default) — ``_grid_scan_xla``: vmap over per-scenario scans of
  the scalar ``lax.switch`` policy step. The parity anchor; hourly
  full-year results are bit-identical to the pre-Pallas kernel.
* **Pallas** (``kernels.ops.use_pallas(True)`` or the ``pallas_mode()``
  context) — the fused scenario-grid kernel of
  ``kernels/policy_scan.py``: one ``pallas_call`` scans all T bins for
  LANES scenarios at a time using the branchless lane-vectorized policy
  steps (``core.twin.lane_policy_step``), scenarios on the vector lanes,
  ``interpret=True`` on CPU. Grids and K-restart calibration fits
  (restarts are just more lanes) both route through this selection.

Each backend additionally exists in a **streaming-aggregate** variant
(``simulate_grid(return_series=False)`` -> ``_grid_scan_agg``): the
Table II statistics — twice-compensated running sums, per-bin max,
end-of-scan queue, SLO-ok counters and a quarter-octave load-weighted
latency histogram (``core.twin`` AGG_* hooks) — come back as O(N)
aggregate rows and the five [N, T] series are never returned. Grids
beyond ``AGG_AUTO_BLOCK`` scenarios (or any grid given an explicit
``scenario_block``) stream through the device as ``lax.map`` blocks
gathered from a [K, T] load matrix + [N] index map, so 100k+-scenario
full-year sweeps complete in one call on hardware that could never hold
the series. ``GridSummary`` rows are produced by one vectorized numpy
pass (``_summarise_aggregates``); sums/max/queue/SLO percentages match
the series path's ``_summarise`` bit for bit, the histogram median to
one bucket width. ``whatif.run_grid`` uses this mode by default.

End-of-year backlog is priced the paper's way: queue_length / capacity
hours of extra pipeline time at the twin's hourly rate ("the cost of, for
example, spinning up duplicate pipelines to process the backlog"). Policies
with a bounded queue additionally report a ``dropped`` hourly series
(records shed), which SLOs can target via ``metric="drop_rate"``.

``storage_costs`` runs the daily rolling-retention accumulation (Table IV):
data builds up day by day and ages out after the retention window.
"""
from __future__ import annotations

import contextlib
import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.cost import CostModel
from repro.core.slo import SLO
from repro.core.traffic import DAYS_PER_YEAR, HOURS_PER_YEAR, MONTH_DAYS
from jax.experimental import enable_x64

from repro.core.twin import (A_COST, A_DROP, A_FLTH, A_FOKH, A_LATW, A_LOAD,
                             A_MAXP, A_OKH, A_OKW, A_PROC, AGG_DIM,
                             AGG_HIST_BINS, AGG_KDIM, AGG_SCALARS,
                             AGG_SLO_DROP_RATE, AGG_SLO_LATENCY, CARRY_DIM,
                             Twin, aggregate_hist_centers,
                             device_latency_histogram,
                             finalize_aggregate_x64, init_agg_scalars,
                             pack_agg_scalars, policy_branches,
                             registry_version, update_agg_scalars)


@dataclass
class SimulationResult:
    name: str
    twin: Twin
    # hourly arrays [8736]
    load: np.ndarray
    processed: np.ndarray
    queue: np.ndarray
    latency_s: np.ndarray
    cost_usd: np.ndarray
    # scalars
    total_cost_usd: float
    backlog_s: float
    backlog_cost_usd: float
    mean_throughput_rph: float
    max_throughput_rph: float
    median_latency_s: float
    mean_latency_s: float
    pct_latency_met: float          # record-weighted, vs slo.limit
    pct_hours_met: float            # hour-weighted
    slo_met: Optional[bool]
    network_cost_usd: float = 0.0
    storage_cost_usd: float = 0.0
    # hourly records shed by bounded-queue policies (zeros otherwise)
    dropped: np.ndarray = field(default_factory=lambda: np.zeros(0))
    dropped_records: float = 0.0
    # record-weighted tail latencies (same CDF the median is read from)
    p95_latency_s: float = 0.0
    p99_latency_s: float = 0.0

    def __post_init__(self):
        # a defaulted ``dropped`` must still match the horizon — a bare
        # shape-(0,) array silently broadcasts to nonsense (or raises)
        # against the other hourly series in elementwise use
        if self.dropped.shape != self.load.shape:
            if self.dropped.size == 0:
                self.dropped = np.zeros_like(self.load)
            else:
                raise ValueError(
                    f"dropped has shape {self.dropped.shape}, want "
                    f"{self.load.shape} to match the hourly series")

    @property
    def grand_total_usd(self) -> float:
        return self.total_cost_usd + self.network_cost_usd + self.storage_cost_usd


@dataclass
class GridSummary:
    """One scenario of an aggregate-mode grid: Table II scalars, no series.

    The streaming backend (``simulate_grid(return_series=False)``) folds
    the summary statistics into the scan carry, so this is all that comes
    back — every scalar a ``SimulationResult`` carries, plus the
    load-weighted latency histogram the median was read from
    (``latency_hist`` over ``core.twin.aggregate_hist_centers()`` buckets).
    Sums, maxima, end-of-scan queue and the SLO percentages match the
    series-path ``_summarise`` exactly; ``median_latency_s`` is the
    histogram-CDF quantile, exact to one log-spaced bucket width
    (``core.twin.AGG_HIST_W`` decades).
    """
    name: str
    twin: Twin
    # scalars (same meanings as SimulationResult)
    total_cost_usd: float
    backlog_s: float
    backlog_cost_usd: float
    mean_throughput_rph: float
    max_throughput_rph: float
    median_latency_s: float
    mean_latency_s: float
    pct_latency_met: float
    pct_hours_met: float
    slo_met: Optional[bool]
    network_cost_usd: float = 0.0
    storage_cost_usd: float = 0.0
    dropped_records: float = 0.0
    # load-weighted tail latencies read off the histogram CDF, exact to
    # one quarter-octave bucket like the median
    p95_latency_s: float = 0.0
    p99_latency_s: float = 0.0
    # aggregate extras the series path derives from the full arrays
    processed_records: float = 0.0
    arrived_records: float = 0.0
    queue_end: float = 0.0
    latency_hist: np.ndarray = field(default_factory=lambda: np.zeros(0))
    # fault attribution (``simulate_grid(faults=...)``), read from the
    # in-carry A_FLTH/A_FOKH counters — zero / 100% on benign grids
    fault_hours: float = 0.0
    pct_hours_met_in_fault: float = 100.0
    pct_hours_met_outside_fault: float = 100.0

    @property
    def grand_total_usd(self) -> float:
        return self.total_cost_usd + self.network_cost_usd + self.storage_cost_usd


def scan_trace(load: jnp.ndarray, params: jnp.ndarray, policy_index,
               dt_hours=1.0):
    """One scenario's scan over arbitrary bins — the differentiable core.

    load [T] records/bin; params [PARAM_DIM]; ``dt_hours`` is the bin width.
    Unjitted on purpose: ``repro.calibrate`` takes ``jax.grad`` of a loss
    through this scan (wrapping it in its own jit), and ``_grid_scan`` wraps
    it in vmap+jit for the what-if grids. Returns (carry_end, (processed,
    queue, latency, cost, dropped)) with each series shaped [T].
    """
    branches = policy_branches()
    dt = jnp.asarray(dt_hours, jnp.float32)

    def bin_step(carry, arrive):
        return jax.lax.switch(policy_index, branches, carry, arrive,
                              params, dt)

    return jax.lax.scan(bin_step, jnp.zeros((CARRY_DIM,), jnp.float32), load)


@functools.partial(jax.jit, static_argnums=(3, 4))
def _grid_scan_xla(loads: jnp.ndarray, params: jnp.ndarray,
                   policy_idx: jnp.ndarray, version: int,
                   dt_hours: float = 1.0):
    """The XLA grid backend: vmap over per-scenario ``lax.switch`` scans.

    loads [N, T] records/bin; params [N, PARAM_DIM] per twin.padded_params;
    policy_idx [N] int32 switch indices; ``version`` is the policy-registry
    version (static) so late policy registration forces a retrace;
    ``dt_hours`` (static) is the bin width — 1.0 for the year tables.
    This path is the parity anchor: the hourly full-year numbers stay
    bit-identical to the pre-Pallas kernel.
    """
    def one(load, p, idx):
        carry_end, outs = scan_trace(load, p, idx, dt_hours)
        return carry_end[0], outs

    return jax.vmap(one)(loads, params, policy_idx)


def _grid_scan(loads: jnp.ndarray, params: jnp.ndarray,
               policy_idx: jnp.ndarray, version: int, dt_hours: float = 1.0):
    """The whole grid in one dispatch — backend-selecting entry point.

    Default: the XLA vmapped switch-scan above. Under ``kernels.ops.
    use_pallas(True)`` / ``pallas_mode()``: the fused Pallas scenario-grid
    kernel (``kernels/policy_scan.py``), scenarios on the vector lanes,
    ``interpret=True`` on CPU. Same operands, same (q_end [N], five
    [N, T] series) contract either way; selection happens OUTSIDE jit, so
    flipping the switch between calls never stales a trace cache.
    """
    from repro.kernels import ops
    if ops.pallas_enabled():
        from repro.core.twin import policy_onehot
        onehot = jnp.asarray(policy_onehot(np.asarray(policy_idx)))
        carry_end, outs = ops.policy_scan(loads, params, onehot, dt_hours)
        return carry_end[:, 0], outs
    return _grid_scan_xla(loads, params, policy_idx, version, dt_hours)


def _agg_time_chunk(t_bins: int, cap: int = 1024) -> int:
    """Time-chunk width the device-resident histogram accumulates over:
    the largest divisor of ``t_bins`` at most ``cap`` (the 8736-hour
    year -> 728, 12 chunks). The chunking can never change results —
    the scan carry threads through every chunk unchanged and the f64
    per-chunk histogram adds are exact, hence order-independent — so the
    cap is purely a working-set bound on the [B, chunk] latency/load
    transients each chunk step stages."""
    t_bins = max(int(t_bins), 1)
    return next(d for d in range(min(cap, t_bins), 0, -1)
                if t_bins % d == 0)


def _branches_f32():
    """``policy_branches()`` with every step output pinned to f32.

    The aggregate XLA jits trace under ``enable_x64()`` (the histogram's
    exactness contract), where a registered policy step that builds
    dtype-less literals (e.g. ``jnp.zeros(())``) silently emits f64 —
    breaking ``lax.switch`` branch-type agreement and flipping scan-carry
    dtypes mid-trace. Registry steps are f32-in/f32-out by contract;
    this enforces the contract at the trace boundary instead of trusting
    every (possibly user-registered) step. The cast is a no-op for
    conforming branches and exact for dtype-less zeros, so numbers never
    change."""
    def pin(step):
        def wrapped(carry, arrive, p, dt):
            carry, outs = step(carry, arrive, p, dt)
            f32 = lambda x: jnp.asarray(x, jnp.float32)  # noqa: E731
            return f32(carry), tuple(f32(o) for o in outs)
        return wrapped
    return [pin(s) for s in policy_branches()]


def _agg_scan_vmap(loads: jnp.ndarray, params: jnp.ndarray,
                   policy_idx: jnp.ndarray, dt_hours: float,
                   slo_limit: float, slo_mode: int):
    """Unjitted core of the XLA streaming-aggregate backend: an outer
    ``lax.scan`` over time chunks of vmapped per-scenario ``lax.switch``
    scans whose carry is (policy carry, scalar aggregate state). The
    policy-step op sequence is IDENTICAL to ``scan_trace`` (chaining the
    chunk scans replays the same per-bin sequence), so per-scenario
    carries (and thus the end-of-scan queue) match the series path bit
    for bit.

    The latency histogram is the one statistic not folded into the
    per-step carry on THIS backend: a per-step [BINS]-wide carry burns
    ~0.5 s per 1k scenarios in scan double-buffering on CPU. Instead
    each chunk step emits its [N, chunk] latencies and folds them
    through ``core.twin.device_latency_histogram`` — an exact f64
    ``segment_sum`` accumulated OUTSIDE the scan carry, entirely on
    device, bit-identical to host ``np.bincount``. No [N, T] panel is
    ever staged and nothing round-trips to the host. MUST be traced
    under ``jax.experimental.enable_x64()`` (``_grid_scan_agg`` wraps
    its call sites). Returns (carry_end [N, CARRY_DIM],
    agg [N, AGG_DIM] f32)."""
    branches = _branches_f32()
    dt = jnp.asarray(dt_hours, jnp.float32)
    n, t_bins = loads.shape
    chunk = _agg_time_chunk(t_bins)
    nc = t_bins // chunk

    def one(carry_i, agg_i, load_i, p, idx):
        def bin_step(state, arrive):
            carry, agg = state
            carry, outs = jax.lax.switch(idx, branches, carry, arrive, p,
                                         dt)
            agg = update_agg_scalars(agg, arrive, outs, slo_limit,
                                     slo_mode)
            return (carry, agg), outs[2]          # chunk-local latency

        (carry, agg), latency = jax.lax.scan(bin_step, (carry_i, agg_i),
                                             load_i)
        return carry, agg, latency

    def chunk_step(state, loads_c):
        carry, agg, hist = state
        carry, agg, lat = jax.vmap(one)(carry, agg, loads_c, params,
                                        policy_idx)
        hist = hist + device_latency_histogram(lat, loads_c)
        return (carry, agg, hist), None

    state0 = (jnp.zeros((n, CARRY_DIM), jnp.float32),
              init_agg_scalars((n,)),
              jnp.zeros((n, AGG_HIST_BINS), jnp.float64))
    (carry, agg, hist), _ = jax.lax.scan(
        chunk_step, state0,
        loads.reshape(n, nc, chunk).transpose(1, 0, 2))
    return carry, jnp.concatenate(
        [pack_agg_scalars(agg), hist.astype(jnp.float32)], axis=-1)


@functools.partial(jax.jit, static_argnums=(3, 4, 5, 6))
def _grid_scan_agg_xla(loads: jnp.ndarray, params: jnp.ndarray,
                       policy_idx: jnp.ndarray, version: int,
                       dt_hours: float, slo_limit: float, slo_mode: int):
    """The XLA aggregate backend (jitted). ``slo_limit`` / ``slo_mode``
    are static like ``dt_hours`` — a grid sweep reuses one SLO, so the
    retrace per distinct objective is paid once. Call under
    ``enable_x64()`` (see ``_agg_scan_vmap``). Returns (carry_end
    [N, CARRY_DIM], agg [N, AGG_DIM])."""
    return _agg_scan_vmap(loads, params, policy_idx, dt_hours, slo_limit,
                          slo_mode)


def _fault_scalar_step(branches, dt):
    """Scalar (per-scenario) form of the fault perturbation layer — the
    same arithmetic, in the same order, as ``core.twin.
    fault_lane_policy_step``, over one scenario's CARRY_DIM carry."""
    def fstep(state, arrive, capmul, p, idx):
        carry, fq = state
        gate = (capmul > 0).astype(jnp.float32)
        avail = fq + arrive
        a_eff = gate * avail
        new_fq = avail - a_eff
        p_eff = p.at[0].set(p[0] * capmul)
        carry, outs = jax.lax.switch(idx, branches, carry, a_eff, p_eff,
                                     dt)
        wait = new_fq / jnp.maximum(p[0], jnp.float32(1e-9))
        outs = (outs[0], outs[1] + new_fq, outs[2] + wait, outs[3],
                outs[4])
        return (carry, new_fq), outs
    return fstep


@functools.partial(jax.jit, static_argnums=(4, 5))
def _grid_scan_fault_xla(loads: jnp.ndarray, caps: jnp.ndarray,
                         params: jnp.ndarray, policy_idx: jnp.ndarray,
                         version: int, dt_hours: float = 1.0):
    """Fault sibling of ``_grid_scan_xla`` (series mode): per-scenario
    switch-scans through the fault perturbation layer. The fault SERIES
    path is XLA-only regardless of the Pallas switch — the fused series
    kernel covers benign grids; chaos grids lean on the aggregate
    backend (``return_series=False``), where the Pallas fault kernel
    lives. Returns (q_end [N] with the fault backlog folded in, five
    [N, T] series)."""
    branches = policy_branches()
    dt = jnp.asarray(dt_hours, jnp.float32)
    fstep = _fault_scalar_step(branches, dt)

    def one(load, cap, p, idx):
        def bin_step(state, xs):
            arrive, capmul = xs
            return fstep(state, arrive, capmul, p, idx)

        (carry, fq), outs = jax.lax.scan(
            bin_step, (jnp.zeros((CARRY_DIM,), jnp.float32),
                       jnp.float32(0.0)), (load, cap))
        return carry[0] + fq, outs

    return jax.vmap(one)(loads, caps, params, policy_idx)


@functools.partial(jax.jit, static_argnums=(5, 6, 7, 8))
def _grid_scan_agg_fault_xla(loads: jnp.ndarray, caps: jnp.ndarray,
                             fmask: jnp.ndarray, params: jnp.ndarray,
                             policy_idx: jnp.ndarray, version: int,
                             dt_hours: float, slo_limit: float,
                             slo_mode: int):
    """Fault sibling of ``_grid_scan_agg_xla``: the vmapped switch-scan
    steps through the fault layer (``caps``/``fmask`` [N, T] per-bin
    series), the in-carry counters gain the A_FLTH/A_FOKH attribution
    slots, and the fault backlog residue folds into ``carry_end[:, 0]``.
    Same chunked device-resident histogram contract as the benign path
    (call under ``enable_x64()``); returns (carry_end [N, CARRY_DIM],
    agg [N, AGG_DIM])."""
    branches = _branches_f32()
    dt = jnp.asarray(dt_hours, jnp.float32)
    fstep = _fault_scalar_step(branches, dt)
    n, t_bins = loads.shape
    chunk = _agg_time_chunk(t_bins)
    nc = t_bins // chunk
    cs = lambda a: a.reshape(n, nc, chunk).transpose(1, 0, 2)  # noqa: E731

    def one(carry_i, fq_i, agg_i, load_i, cap_i, fm_i, p, idx):
        def bin_step(state, xs):
            arrive, capmul, fmk = xs
            (carry, fq), agg = state
            (carry, fq), outs = fstep((carry, fq), arrive, capmul, p, idx)
            agg = update_agg_scalars(agg, arrive, outs, slo_limit,
                                     slo_mode, fmk)
            return ((carry, fq), agg), outs[2]    # chunk-local latency

        ((carry, fq), agg), latency = jax.lax.scan(
            bin_step, ((carry_i, fq_i), agg_i), (load_i, cap_i, fm_i))
        return carry, fq, agg, latency

    def chunk_step(state, xs):
        carry, fq, agg, hist = state
        loads_c, caps_c, fmask_c = xs
        carry, fq, agg, lat = jax.vmap(one)(carry, fq, agg, loads_c,
                                            caps_c, fmask_c, params,
                                            policy_idx)
        hist = hist + device_latency_histogram(lat, loads_c)
        return (carry, fq, agg, hist), None

    state0 = (jnp.zeros((n, CARRY_DIM), jnp.float32),
              jnp.zeros((n,), jnp.float32),
              init_agg_scalars((n,)),
              jnp.zeros((n, AGG_HIST_BINS), jnp.float64))
    (carry, fq, agg, hist), _ = jax.lax.scan(
        chunk_step, state0, (cs(loads), cs(caps), cs(fmask)))
    carry = carry.at[:, 0].add(fq)
    return carry, jnp.concatenate(
        [pack_agg_scalars(agg), hist.astype(jnp.float32)], axis=-1)


def _grid_scan_agg(loads: jnp.ndarray, params: jnp.ndarray,
                   policy_idx: jnp.ndarray, version: int, dt_hours: float,
                   slo_limit: float, slo_mode: int,
                   caps=None, fmask=None):
    """Backend-selecting entry point of the streaming-aggregate scan —
    the O(N)-memory sibling of ``_grid_scan``. Same selection rule:
    XLA vmapped switch-scan by default, the fused Pallas aggregate kernel
    under ``kernels.ops.pallas_mode()`` (aggregates fully resident in
    VMEM scratch), decided OUTSIDE jit. Either way the result is O(N)
    and fully device-resident — histogram included, no host binning
    round-trip on any backend: (carry_end [N, CARRY_DIM],
    agg [N, AGG_DIM]). The XLA jits are always entered under
    ``enable_x64()`` so their exact-f64 histogram accumulation never
    silently re-traces truncated. ``caps``/``fmask`` [N, T] (together)
    thread a fault schedule through either backend."""
    from repro.kernels import ops
    if ops.pallas_enabled():
        from repro.core.twin import policy_onehot
        onehot = jnp.asarray(policy_onehot(np.asarray(policy_idx)))
        return ops.policy_scan_agg(loads, params, onehot, dt_hours,
                                   slo_limit=slo_limit, slo_mode=slo_mode,
                                   caps=caps, fmask=fmask)
    with enable_x64():
        if caps is not None:
            return _grid_scan_agg_fault_xla(
                loads, caps, fmask, params, policy_idx, version, dt_hours,
                slo_limit, slo_mode)
        return _grid_scan_agg_xla(
            loads, params, policy_idx, version, dt_hours, slo_limit,
            slo_mode)


def _agg_scan_uniform(load_matrix: jnp.ndarray, lidx: jnp.ndarray,
                      params: jnp.ndarray, policy_index: jnp.ndarray,
                      dt_hours: float, slo_limit: float, slo_mode: int):
    """Single-policy sibling of ``_agg_scan_vmap``: ``policy_index`` is a
    SCALAR (possibly traced), so the ``lax.switch`` hoists OUTSIDE the
    vmapped scan and the block executes exactly one policy branch — on a
    mixed five-policy grid that is ~5x less per-bin work than the vmapped
    switch (which a batched index lowers to evaluate-all-and-select).
    The per-scenario op sequence inside the selected branch is IDENTICAL
    to ``_agg_scan_vmap``'s, so results stay bit-for-bit equal; the block
    planner (``_agg_block_plan``) guarantees every chunked block is
    single-policy.

    Takes the [K, T] distinct-row matrix + the block's [B] row index and
    gathers ONE [B, chunk] slice per time chunk in-graph — the block's
    full [B, T] loads never exist, on device or host, and the histogram
    accumulates on device (``device_latency_histogram``; call under
    ``enable_x64()``). Returns (carry_end [B, CARRY_DIM],
    agg [B, AGG_DIM])."""
    branches = _branches_f32()
    dt = jnp.asarray(dt_hours, jnp.float32)
    b = lidx.shape[0]
    k, t_bins = load_matrix.shape
    chunk = _agg_time_chunk(t_bins)
    nc = t_bins // chunk
    mx = load_matrix.reshape(k, nc, chunk).transpose(1, 0, 2)

    def uniform(j):
        def run(mx, lidx, params):
            def one(carry_i, agg_i, load_i, p):
                def bin_step(state, arrive):
                    carry, agg = state
                    carry, outs = branches[j](carry, arrive, p, dt)
                    agg = update_agg_scalars(agg, arrive, outs, slo_limit,
                                             slo_mode)
                    return (carry, agg), outs[2]

                (carry, agg), latency = jax.lax.scan(
                    bin_step, (carry_i, agg_i), load_i)
                return carry, agg, latency

            def chunk_step(state, m_c):
                carry, agg, hist = state
                loads_c = jnp.take(m_c, lidx, axis=0)
                carry, agg, lat = jax.vmap(one)(carry, agg, loads_c,
                                                params)
                hist = hist + device_latency_histogram(lat, loads_c)
                return (carry, agg, hist), None

            state0 = (jnp.zeros((b, CARRY_DIM), jnp.float32),
                      init_agg_scalars((b,)),
                      jnp.zeros((b, AGG_HIST_BINS), jnp.float64))
            (carry, agg, hist), _ = jax.lax.scan(chunk_step, state0, mx)
            return carry, jnp.concatenate(
                [pack_agg_scalars(agg), hist.astype(jnp.float32)],
                axis=-1)

        return run

    return jax.lax.switch(policy_index,
                          [uniform(j) for j in range(len(branches))],
                          mx, lidx, params)


def _agg_scan_uniform_fault(load_matrix: jnp.ndarray, lidx: jnp.ndarray,
                            cap_matrix: jnp.ndarray,
                            fmask_matrix: jnp.ndarray, fidx: jnp.ndarray,
                            params: jnp.ndarray,
                            policy_index: jnp.ndarray, dt_hours: float,
                            slo_limit: float, slo_mode: int):
    """Fault sibling of ``_agg_scan_uniform``: the single hoisted
    ``lax.switch`` picks the policy branch, every scenario of the block
    steps through the scalar fault layer, and the A_FLTH/A_FOKH counters
    ride the scalar aggregate state. The [F, T] capacity/mask matrices
    gather through ``fidx`` one [B, chunk] slice per time chunk, exactly
    like the loads through ``lidx`` — no [B, T] fault panels are staged
    either. Same returns plus the backlog folded into the carry's queue
    slot."""
    branches = _branches_f32()
    dt = jnp.asarray(dt_hours, jnp.float32)
    b = lidx.shape[0]
    k, t_bins = load_matrix.shape
    chunk = _agg_time_chunk(t_bins)
    nc = t_bins // chunk
    cs = lambda a: a.reshape(a.shape[0], nc, chunk).transpose(1, 0, 2)  # noqa: E731

    def uniform(j):
        def run(mx, cx, fx, lidx, fidx, params):
            def one(carry_i, fq_i, agg_i, load_i, cap_i, fm_i, p):
                def bin_step(state, xs):
                    arrive, capmul, fmk = xs
                    (carry, fq), agg = state
                    gate = (capmul > 0).astype(jnp.float32)
                    avail = fq + arrive
                    a_eff = gate * avail
                    new_fq = avail - a_eff
                    p_eff = p.at[0].set(p[0] * capmul)
                    carry, outs = branches[j](carry, a_eff, p_eff, dt)
                    wait = new_fq / jnp.maximum(p[0], jnp.float32(1e-9))
                    outs = (outs[0], outs[1] + new_fq, outs[2] + wait,
                            outs[3], outs[4])
                    agg = update_agg_scalars(agg, arrive, outs, slo_limit,
                                             slo_mode, fmk)
                    return ((carry, new_fq), agg), outs[2]

                ((carry, fq), agg), latency = jax.lax.scan(
                    bin_step, ((carry_i, fq_i), agg_i),
                    (load_i, cap_i, fm_i))
                return carry, fq, agg, latency

            def chunk_step(state, xs):
                carry, fq, agg, hist = state
                m_c, c_c, f_c = xs
                loads_c = jnp.take(m_c, lidx, axis=0)
                caps_c = jnp.take(c_c, fidx, axis=0)
                fmask_c = jnp.take(f_c, fidx, axis=0)
                carry, fq, agg, lat = jax.vmap(one)(
                    carry, fq, agg, loads_c, caps_c, fmask_c, params)
                hist = hist + device_latency_histogram(lat, loads_c)
                return (carry, fq, agg, hist), None

            state0 = (jnp.zeros((b, CARRY_DIM), jnp.float32),
                      jnp.zeros((b,), jnp.float32),
                      init_agg_scalars((b,)),
                      jnp.zeros((b, AGG_HIST_BINS), jnp.float64))
            (carry, fq, agg, hist), _ = jax.lax.scan(
                chunk_step, state0, (mx, cx, fx))
            carry = carry.at[:, 0].add(fq)
            return carry, jnp.concatenate(
                [pack_agg_scalars(agg), hist.astype(jnp.float32)],
                axis=-1)

        return run

    return jax.lax.switch(policy_index,
                          [uniform(j) for j in range(len(branches))],
                          cs(load_matrix), cs(cap_matrix),
                          cs(fmask_matrix), lidx, fidx, params)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3),
                   donate_argnums=(8, 9))
def _agg_block_step_xla(version: int, dt_hours: float, slo_limit: float,
                        slo_mode: int, load_matrix: jnp.ndarray,
                        lidx: jnp.ndarray, params: jnp.ndarray,
                        policy_index: jnp.ndarray, carry_acc: jnp.ndarray,
                        agg_acc: jnp.ndarray, offset,
                        cap_matrix=None, fmask_matrix=None, fidx=None):
    """One donated block step of the device-resident XLA engine: run the
    uniform-branch aggregate scan — which gathers the block's loads one
    [B, chunk] time chunk at a time from the replicated [K, T] matrix and
    accumulates the histogram on device — and write the O(B·AGG_DIM)
    result into the donated [Npad, *] accumulators at ``offset``.
    ``donate_argnums`` hands the accumulator buffers back to XLA, so
    device memory stays at ONE chunk's gathered loads + the O(N)
    aggregates no matter how many blocks stream through; no [B, T] panel
    ever exists and nothing returns to the host until the last block.
    Traces f64 (the histogram segment_sum) — call under ``enable_x64()``.
    Fault grids add the replicated [F, T] capacity/mask matrices + the
    block's [B] ``fidx`` gather map (appended AFTER ``offset`` so the
    donated accumulator positions never move)."""
    del version
    if cap_matrix is None:
        carry, agg = _agg_scan_uniform(
            load_matrix, lidx, params, policy_index, dt_hours,
            slo_limit, slo_mode)
    else:
        carry, agg = _agg_scan_uniform_fault(
            load_matrix, lidx, cap_matrix, fmask_matrix, fidx, params,
            policy_index, dt_hours, slo_limit, slo_mode)
    carry_acc = jax.lax.dynamic_update_slice(carry_acc, carry, (offset, 0))
    agg_acc = jax.lax.dynamic_update_slice(agg_acc, agg, (offset, 0))
    return carry_acc, agg_acc


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4),
                   donate_argnums=(9, 10))
def _agg_block_step_pallas(version: int, dt_hours: float, slo_limit: float,
                           slo_mode: int, interpret: bool,
                           matrix_t: jnp.ndarray, lidx: jnp.ndarray,
                           params: jnp.ndarray, policy_index: jnp.ndarray,
                           carry_acc: jnp.ndarray, agg_acc: jnp.ndarray,
                           offset, cap_mt=None, fmask_mt=None, fidx=None):
    """Pallas twin of ``_agg_block_step_xla``: gathers the block directly
    in the kernel's scenario-minor layout (``matrix_t`` [T, K] staged once,
    columns gathered per block — the PR 3/4 layout follow-on: no [B, T]
    intermediate or per-block transpose copy exists anymore) and runs the
    fused aggregate kernel, histogram and all on-device. The kernel's RAW
    [B, AGG_KDIM] rows (compensated histogram triples unrecombined) are
    accumulated — the driver recombines once at the very end
    (``finalize_aggregate_x64``), keeping this jit pure f32. Accumulators
    are donated exactly as on the XLA path. Fault grids gather the [T, F]
    ``cap_mt``/``fmask_mt`` columns through ``fidx`` the same way and run
    the kernel's fault variant."""
    del version
    from repro.core.twin import num_policies
    from repro.kernels.policy_scan import policy_grid_agg
    loads_t = jnp.take(matrix_t, lidx, axis=1)
    caps_t = fmask_t = None
    if cap_mt is not None:
        caps_t = jnp.take(cap_mt, fidx, axis=1)
        fmask_t = jnp.take(fmask_mt, fidx, axis=1)
    onehot = jnp.broadcast_to(
        jax.nn.one_hot(policy_index, num_policies(), dtype=jnp.float32),
        (lidx.shape[0], num_policies()))
    carry, agg = policy_grid_agg(
        None, params, onehot, dt_hours, slo_limit=slo_limit,
        slo_mode=slo_mode, interpret=interpret, loads_t=loads_t,
        caps_t=caps_t, fmask_t=fmask_t, finalize=False)
    carry_acc = jax.lax.dynamic_update_slice(carry_acc, carry, (offset, 0))
    agg_acc = jax.lax.dynamic_update_slice(agg_acc, agg, (offset, 0))
    return carry_acc, agg_acc


#: device-memory budget a streamed block may spend on its per-block
#: working set — the block size every horizon auto-chunks to derives
#: from this, see ``agg_auto_block``
AGG_BLOCK_BUDGET_BYTES = 150 * 2**20


def agg_auto_block(t_bins: int, dtype_bytes: int = 4,
                   panels: int = 0) -> int:
    """Auto-chunk block size for a ``t_bins``-bin horizon: the largest
    lane-aligned scenario count whose per-block working set fits the
    ~150 MB ``AGG_BLOCK_BUDGET_BYTES``.

    ``panels`` counts the [B, T] (or [T, B]) full-horizon arrays the
    block actually stages — the historical under-budgeting bug was
    declaring a budget for ONE panel while fault dispatch gathered
    ``caps_t``/``fmask_t`` alongside ``loads_t`` (~3x the declared
    budget). The Pallas path still gathers per-block column panels, so
    it passes ``panels=1`` (benign) or ``panels=3`` (fault grids); the
    device-resident XLA path stages NO full-horizon panel at all
    (``panels=0``) — its footprint is the [B, chunk] time-chunk gathers
    (up to 6 buffered by the scan pipeline) plus the O(B·AGG_DIM)
    aggregate rows, so year grids get ~7k-scenario blocks instead of
    ~4k and short horizons no longer over-chunk.

    A fixed scenario count would over-chunk short calibration horizons
    (thousands of tiny dispatches) and under-chunk long sub-hour ones
    (working sets far past the budget); deriving from the horizon keeps
    every grid at the same working set. Clamped to [128, 65536] and
    rounded down to a 128-lane multiple."""
    t_bins = max(int(t_bins), 1)
    if panels:
        per_row = t_bins * dtype_bytes * panels
    else:
        per_row = (6 * _agg_time_chunk(t_bins) + 4 * AGG_DIM) * dtype_bytes
    block = AGG_BLOCK_BUDGET_BYTES // per_row
    return int(min(max(block // 128 * 128, 128), 65536))


#: aggregate YEAR grids beyond this many scenarios auto-chunk; kept as a
#: constant for back-compat — non-year horizons use ``agg_auto_block``
AGG_AUTO_BLOCK = agg_auto_block(HOURS_PER_YEAR)


def _agg_block_plan(policy_idx: np.ndarray, block: int):
    """Group scenarios into single-policy blocks of ``block``.

    Returns (positions [NB, block] int64, block_policy [NB] int32):
    ``positions[b, i]`` is the scenario index occupying slot i of block b,
    or -1 for a pad slot (each policy's run is padded up to a block
    multiple independently, so every block is policy-uniform — tail pads
    are per policy, not one global tail). Grouping is a STABLE sort by
    policy, so scenarios of one policy keep their grid order; results are
    scattered back through ``positions``, making the regrouping invisible
    to callers."""
    policy_idx = np.asarray(policy_idx)
    order = np.argsort(policy_idx, kind="stable")
    positions, block_policy = [], []
    for p in np.unique(policy_idx):
        pos = order[policy_idx[order] == p]
        nb = -(-len(pos) // block)
        padded = np.full(nb * block, -1, np.int64)
        padded[:len(pos)] = pos
        positions.append(padded.reshape(nb, block))
        block_policy.extend([int(p)] * nb)
    if positions:
        positions = np.concatenate(positions)
    else:
        positions = np.zeros((0, block), np.int64)
    return positions, np.asarray(block_policy, np.int32)


@functools.lru_cache(maxsize=16)
def _sharded_agg_fn(devices: int, version: int, dt_hours: float,
                    slo_limit: float, slo_mode: int, backend: str,
                    interpret: bool, block: int, faulted: bool = False):
    """Build (and cache) the jitted ``shard_map`` ROUND step for a
    ``devices``-wide 1-D scenario mesh: the [K, T] load matrix is
    replicated, and one round feeds each device exactly one
    single-policy block — lidx [D, B] / params [D, B, PARAM_DIM] /
    block_policy [D] sharded on the leading axis, so every shard runs
    the same uniform-branch aggregate scan the one-device engine runs
    and results are bit-identical to unsharded by construction. Both
    backends keep the histogram INSIDE the ``shard_map`` body — the XLA
    branch accumulates it on device with ``device_latency_histogram``
    (scenarios are disjoint across shards, so a plain sharded gather
    returns the per-row histograms; no psum needed) and returns finished
    [D, B, AGG_DIM] rows; the Pallas branch returns the kernel's raw
    [D, B, AGG_KDIM] rows for one end-of-grid recombination. The old
    per-round host drain — and the pure_callback-deadlock constraint it
    was built around — is gone: the XLA round traces f64, so CALL IT
    UNDER ``enable_x64()``. ``faulted`` builds the fault-grid variant:
    the [F, T] capacity/mask matrices replicate like the load matrix and
    a sharded [D, B] fault index gathers each block's per-bin fault
    series."""
    del version
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.distributed.sharding import shard_map

    mesh = Mesh(np.asarray(jax.devices()[:devices]), ("scenario",))

    def body(load_matrix, lidx, params, block_policy, cap_matrix=None,
             fmask_matrix=None, fidx=None):
        lidx_b, p_b = lidx[0], params[0]          # the shard's one block
        pidx_b = block_policy[0]
        if backend == "pallas":
            from repro.core.twin import num_policies
            from repro.kernels.policy_scan import policy_grid_agg
            loads_t = jnp.take(load_matrix.T, lidx_b, axis=1)
            caps_t = fmask_t = None
            if faulted:
                caps_t = jnp.take(cap_matrix.T, fidx[0], axis=1)
                fmask_t = jnp.take(fmask_matrix.T, fidx[0], axis=1)
            onehot = jnp.broadcast_to(
                jax.nn.one_hot(pidx_b, num_policies(),
                               dtype=jnp.float32),
                (block, num_policies()))
            carry, agg = policy_grid_agg(
                None, p_b, onehot, dt_hours, slo_limit=slo_limit,
                slo_mode=slo_mode, interpret=interpret, loads_t=loads_t,
                caps_t=caps_t, fmask_t=fmask_t, finalize=False)
            return carry[None], agg[None]
        if faulted:
            carry, agg = _agg_scan_uniform_fault(
                load_matrix, lidx_b, cap_matrix, fmask_matrix, fidx[0],
                p_b, pidx_b, dt_hours, slo_limit, slo_mode)
        else:
            carry, agg = _agg_scan_uniform(
                load_matrix, lidx_b, p_b, pidx_b, dt_hours, slo_limit,
                slo_mode)
        return carry[None], agg[None]

    out_specs = (P("scenario"), P("scenario"))
    in_specs = (P(), P("scenario"), P("scenario"), P("scenario"))
    if faulted:
        in_specs = in_specs + (P(), P(), P("scenario"))
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_vma=False)
    return jax.jit(sharded)


def _run_blocks_sharded(load_matrix: np.ndarray, lidx: np.ndarray,
                        params: np.ndarray, block_policy: np.ndarray,
                        devices: int, version: int, dt_hours: float,
                        slo_limit: float, slo_mode: int, backend: str,
                        interpret: bool, fault=None):
    """Drive the sharded round step over all blocks: rounds of one block
    per device, every round fully device-resident — the old overlap
    machinery (host binning of round r-1's panels while round r runs)
    is gone because there is no host binning left to overlap. ``lidx``
    arrives padded to a ``devices`` multiple of blocks (dummy all-pad
    blocks). ``fault`` = (cap [F, T], fmask [F, T], fidx [NB, B])
    threads a fault grid through every round. Returns host (carry
    [NB*B, CARRY_DIM], agg [NB*B, AGG_DIM]) — Pallas rounds return raw
    AGG_KDIM rows, recombined ONCE here at the end of the grid."""
    nb, block = lidx.shape
    d = devices
    rounds = nb // d
    npad = nb * block
    fn = _sharded_agg_fn(d, version, dt_hours, slo_limit, slo_mode,
                         backend, interpret, block,
                         faulted=fault is not None)
    matrix_dev = jnp.asarray(load_matrix)
    agg_width = AGG_KDIM if backend == "pallas" else AGG_DIM
    carry_out = np.empty((npad, CARRY_DIM), np.float32)
    agg_out = np.empty((npad, agg_width), np.float32)

    def rnd(a, r):
        return jnp.asarray(a[r * d:(r + 1) * d])

    if fault is not None:
        cap_dev = jnp.asarray(fault[0])
        fmask_dev = jnp.asarray(fault[1])
        fidx_blocks = fault[2]
        fargs = lambda r: (cap_dev, fmask_dev, rnd(fidx_blocks, r))  # noqa: E731
    else:
        fargs = lambda r: ()  # noqa: E731

    # the XLA round jit traces f64 (in-graph histogram segment_sum) —
    # every call must sit inside enable_x64 or jit re-traces a truncated
    # f32 variant; the Pallas round jit is pure f32 and stays outside
    ctx = (contextlib.nullcontext() if backend == "pallas"
           else enable_x64())
    with ctx:
        for r in range(rounds):
            cache0 = obs.jit_cache_size(fn) if obs.enabled() else 0
            with obs.span("grid.round", round=r, devices=d, block=block,
                          backend=backend,
                          scenarios=d * block) as sp:
                carry, agg = fn(matrix_dev, rnd(lidx, r), rnd(params, r),
                                rnd(block_policy, r), *fargs(r))
                jax.block_until_ready(agg)
            if obs.enabled():
                sp.attrs["compiled"] = float(
                    obs.jit_cache_grew(fn, cache0))
            sl = slice(r * d * block, (r + 1) * d * block)
            carry_out[sl] = np.asarray(carry).reshape(-1, CARRY_DIM)
            agg_out[sl] = np.asarray(agg).reshape(-1, agg.shape[-1])
    if backend == "pallas":
        agg_out = np.asarray(finalize_aggregate_x64(agg_out))
    return carry_out, agg_out


def _run_blocks_single(load_matrix: np.ndarray, lidx: np.ndarray,
                       params: np.ndarray, block_policy: np.ndarray,
                       version: int, dt_hours: float, slo_limit: float,
                       slo_mode: int, backend: str, interpret: bool,
                       fault=None):
    """The one-device streaming engine: every block runs fully
    device-resident — no latency panel ever crosses to the host and the
    old dispatch/bin overlap machinery is gone because there is no host
    binning left to overlap. Accumulators are donated across steps (see
    ``_agg_block_step_*``), so device memory stays at one block's
    working set + the O(N) aggregate rows; nothing copies back until
    the final ``np.asarray``. ``fault`` = (cap [F, T], fmask [F, T],
    fidx [NB, B]) threads a fault grid through every block. Returns host
    (carry [NB*B, CARRY_DIM], agg [NB*B, AGG_DIM]) — Pallas blocks
    accumulate raw AGG_KDIM rows, recombined ONCE at the end of the
    grid."""
    nb, block = lidx.shape
    npad = nb * block
    carry_acc = jnp.zeros((npad, CARRY_DIM), jnp.float32)
    if backend == "pallas":
        matrix_t = jnp.asarray(load_matrix.T)
        if fault is not None:
            cap_mt = jnp.asarray(np.asarray(fault[0]).T)
            fmask_mt = jnp.asarray(np.asarray(fault[1]).T)
            fidx_blocks = fault[2]
            fargs = lambda b: (cap_mt, fmask_mt,  # noqa: E731
                               jnp.asarray(fidx_blocks[b]))
        else:
            fargs = lambda b: ()  # noqa: E731
        agg_acc = jnp.zeros((npad, AGG_KDIM), jnp.float32)
        for b in range(nb):
            cache0 = (obs.jit_cache_size(_agg_block_step_pallas)
                      if obs.enabled() else 0)
            with obs.span("grid.block", block=b, size=block,
                          policy=int(block_policy[b]),
                          backend="pallas") as sp:
                carry_acc, agg_acc = _agg_block_step_pallas(
                    version, dt_hours, slo_limit, slo_mode, interpret,
                    matrix_t, jnp.asarray(lidx[b]),
                    jnp.asarray(params[b]),
                    jnp.asarray(block_policy[b]), carry_acc, agg_acc,
                    b * block, *fargs(b))
                if obs.enabled():
                    jax.block_until_ready(agg_acc)
                    sp.attrs["compiled"] = float(obs.jit_cache_grew(
                        _agg_block_step_pallas, cache0))
        return (np.asarray(carry_acc),
                np.asarray(finalize_aggregate_x64(agg_acc)))
    matrix_dev = jnp.asarray(load_matrix)
    if fault is not None:
        cap_dev = jnp.asarray(fault[0])
        fmask_dev = jnp.asarray(fault[1])
        fidx_blocks = fault[2]
        fargs = lambda b: (cap_dev, fmask_dev,  # noqa: E731
                           jnp.asarray(fidx_blocks[b]))
    else:
        fargs = lambda b: ()  # noqa: E731
    agg_acc = jnp.zeros((npad, AGG_DIM), jnp.float32)
    with enable_x64():      # the block step traces f64 — see its docstring
        for b in range(nb):
            cache0 = (obs.jit_cache_size(_agg_block_step_xla)
                      if obs.enabled() else 0)
            with obs.span("grid.block", block=b, size=block,
                          policy=int(block_policy[b]),
                          backend="xla") as sp:
                carry_acc, agg_acc = _agg_block_step_xla(
                    version, dt_hours, slo_limit, slo_mode, matrix_dev,
                    jnp.asarray(lidx[b]), jnp.asarray(params[b]),
                    jnp.asarray(block_policy[b]), carry_acc, agg_acc,
                    b * block, *fargs(b))
                if obs.enabled():
                    jax.block_until_ready(agg_acc)
                    sp.attrs["compiled"] = float(obs.jit_cache_grew(
                        _agg_block_step_xla, cache0))
        return np.asarray(carry_acc), np.asarray(agg_acc)


def _dedup_rows(load_index: np.ndarray, params: np.ndarray,
                policy_idx: np.ndarray, fault=None):
    """Exact duplicate-scenario detection for the aggregate dispatch.

    Two scenario rows are duplicates when their (load row, param vector,
    policy index, fault row) are BITWISE identical — they play the same
    deterministic year, so one simulation serves all of them. Fault rows
    are canonicalized first (bitwise-equal [F, T] cap+fmask rows map to
    one id), which is what collapses benign futures: ``expand_grid``
    aliases their load rows to the originals and every benign future's
    cap/fmask row is the same all-ones/all-zeros pair, so the N*F chaos
    grid keeps one benign row per base scenario. Tiled grids (policy
    tournaments re-running a baseline, twin x traffic sweeps cycling a
    twin list) collapse the same way. Returns (keep [U], inv [N],
    fidx_canon [N]) with ``keep`` the first-occurrence row of each
    distinct scenario and ``inv`` the expansion map back to grid order —
    or None when every row is already distinct. f32 bit-equality is
    conservative: NaN != NaN and -0.0 != 0.0 never merge rows that could
    differ."""
    lidx = np.ascontiguousarray(load_index, np.int32)
    n = lidx.shape[0]
    pp = np.ascontiguousarray(params, np.float32)
    key = [lidx[:, None].view(np.uint32),
           np.ascontiguousarray(policy_idx, np.int32)[:, None]
           .view(np.uint32), pp.view(np.uint32)]
    fidx_canon = None
    if fault is not None:
        frows = np.concatenate(
            [np.ascontiguousarray(fault[0], np.float32).view(np.uint32),
             np.ascontiguousarray(fault[1], np.float32).view(np.uint32)],
            axis=1)
        _, ffirst, finv = np.unique(frows, axis=0, return_index=True,
                                    return_inverse=True)
        fidx_canon = ffirst[finv.reshape(-1)][np.asarray(fault[2])] \
            .astype(np.int32)
        key.append(fidx_canon[:, None].view(np.uint32))
    keep, inv = np.unique(np.concatenate(key, axis=1), axis=0,
                          return_index=True, return_inverse=True)[1:]
    if keep.shape[0] == n:
        return None
    return keep, inv.reshape(-1), fidx_canon


def _grid_agg_dispatch(load_matrix: np.ndarray, load_index: np.ndarray,
                       params: np.ndarray, policy_idx: np.ndarray,
                       dt_hours: float, slo_limit: float, slo_mode: int,
                       scenario_block: Optional[int],
                       devices: Optional[int] = None, fault=None):
    """Run the aggregate scan over (matrix, index)-encoded scenarios,
    chunked into ``scenario_block``-sized blocks when asked — or when the
    grid exceeds the horizon's auto-chunk threshold (``agg_auto_block``).
    Chunked grids are regrouped into single-policy blocks
    (``_agg_block_plan``) and streamed through the donated async block
    engine; ``devices`` > 1 instead shards the blocked grid over a 1-D
    scenario mesh (``_sharded_agg_fn``). ``fault`` = (cap [F, T],
    fmask [F, T], fault_index [N]) threads a fault grid through every
    path — fault rows gather through ``fault_index`` exactly like load
    rows through ``load_index``, so a 65k chaos grid ships F fault rows,
    not 65k. Bitwise-duplicate scenario rows (``_dedup_rows``) are
    simulated once and their summary rows replicated on the way out —
    exact, because scenarios are independent and deterministic. All
    paths return the same host numpy (carry_end [N, CARRY_DIM], agg
    [N, AGG_DIM]), bit-identical to one another."""
    from repro.kernels import ops
    n = len(load_index)
    dd = _dedup_rows(load_index, params, policy_idx, fault)
    if dd is not None:
        keep, inv, fidx_canon = dd
        # counters bump ONLY here: the recursive call below sees an
        # already-distinct grid (dd None) and never double-counts
        obs.count("grid.dedup.total", n)
        obs.count("grid.dedup.kept", len(keep))
        fault_k = None
        if fault is not None:
            fault_k = (fault[0], fault[1], fidx_canon[keep])
        carry_u, agg_u = _grid_agg_dispatch(
            load_matrix, np.asarray(load_index)[keep],
            np.asarray(params)[keep], np.asarray(policy_idx)[keep],
            dt_hours, slo_limit, slo_mode, scenario_block, devices,
            fault_k)
        return carry_u[inv], agg_u[inv]
    backend = "pallas" if ops.pallas_enabled() else "xla"
    interpret = ops.interpret_enabled()
    # the Pallas path still stages per-block [T, B] column panels (one
    # for loads, +2 for a fault grid's caps/fmask); the device-resident
    # XLA path stages none — derive the auto-block from what the chosen
    # backend actually allocates
    panels = (3 if fault is not None else 1) if backend == "pallas" else 0
    auto_block = agg_auto_block(load_matrix.shape[1], panels=panels)
    if scenario_block is None and (n > auto_block
                                   or (devices or 1) > 1):
        scenario_block = auto_block
    version = registry_version()
    if scenario_block is None or (scenario_block >= n
                                  and (devices or 1) <= 1):
        if (load_matrix.shape[0] == n
                and np.array_equal(load_index, np.arange(n))):
            loads_np = load_matrix      # identity map: the rows ARE the grid
        else:
            loads_np = np.ascontiguousarray(load_matrix[load_index])
        caps = fmask = None
        if fault is not None:
            cap_m, fmask_m, fidx = fault
            caps = jnp.asarray(np.asarray(cap_m)[fidx])
            fmask = jnp.asarray(np.asarray(fmask_m)[fidx])
        carry_end, agg = _grid_scan_agg(jnp.asarray(loads_np),
                                        jnp.asarray(params),
                                        jnp.asarray(policy_idx), version,
                                        dt_hours, slo_limit, slo_mode,
                                        caps=caps, fmask=fmask)
        return (np.asarray(carry_end, np.float64),
                np.asarray(agg, np.float64))

    block = int(min(scenario_block, max(n, 1)))
    positions, block_policy = _agg_block_plan(policy_idx, block)
    obs.gauge("grid.block_size", block)
    obs.count("grid.blocks", positions.shape[0],
              backend=backend, devices=int(devices or 1))

    # stage the per-block host operands through the position map: pad
    # slots (-1) read row 0 with zero params — discarded on scatter
    valid = positions >= 0
    safe = np.where(valid, positions, 0)
    lidx = np.where(valid, np.asarray(load_index)[safe], 0) \
        .astype(np.int32)
    params_b = np.where(valid[..., None], np.asarray(params)[safe],
                        0).astype(np.float32)
    block_fault = None
    if fault is not None:
        cap_m, fmask_m, fidx_all = fault
        fidx_b = np.where(valid, np.asarray(fidx_all)[safe], 0) \
            .astype(np.int32)
        block_fault = (np.asarray(cap_m, np.float32),
                       np.asarray(fmask_m, np.float32), fidx_b)

    d = int(devices or 1)
    if d > 1:
        nb = positions.shape[0]
        pad_blocks = (-nb) % d
        if pad_blocks:      # dummy all-pad blocks so every round is full
            lidx = np.concatenate(
                [lidx, np.zeros((pad_blocks, block), np.int32)])
            params_b = np.concatenate(
                [params_b,
                 np.zeros((pad_blocks, block) + params_b.shape[2:],
                          np.float32)])
            block_policy = np.concatenate(
                [block_policy, np.zeros(pad_blocks, np.int32)])
            if block_fault is not None:
                block_fault = (block_fault[0], block_fault[1],
                               np.concatenate(
                                   [block_fault[2],
                                    np.zeros((pad_blocks, block),
                                             np.int32)]))
        carry, agg = _run_blocks_sharded(
            np.asarray(load_matrix), lidx, params_b, block_policy, d,
            version, float(dt_hours), float(slo_limit), int(slo_mode),
            backend, interpret, fault=block_fault)
        carry = carry[:nb * block]
        agg = agg[:nb * block]
    else:
        carry, agg = _run_blocks_single(
            np.asarray(load_matrix), lidx, params_b, block_policy,
            version, float(dt_hours), float(slo_limit), int(slo_mode),
            backend, interpret, fault=block_fault)

    # scatter block results back to grid order through the position map
    flat_pos = positions.reshape(-1)
    vmask = flat_pos >= 0
    carry_end = np.zeros((n, carry.shape[-1]), np.float64)
    out_agg = np.zeros((n, agg.shape[-1]), np.float64)
    carry_end[flat_pos[vmask]] = carry[vmask]
    out_agg[flat_pos[vmask]] = agg[vmask]
    return carry_end, out_agg


# the jit-cache introspection the tests (and benchmarks) use lives on the
# XLA paths (series + aggregate); expose it on the selector so callers
# keep one import — "compiled exactly once" holds whichever mode ran
def _clear_grid_caches():
    _grid_scan_xla.clear_cache()
    _grid_scan_agg_xla.clear_cache()
    _agg_block_step_xla.clear_cache()
    _agg_block_step_pallas.clear_cache()
    _sharded_agg_fn.cache_clear()


def _grid_cache_size():
    return (_grid_scan_xla._cache_size() + _grid_scan_agg_xla._cache_size()
            + _agg_block_step_xla._cache_size()
            + _agg_block_step_pallas._cache_size())


_grid_scan.clear_cache = _clear_grid_caches
_grid_scan._cache_size = _grid_cache_size


def simulate_grid(twins: Sequence[Twin], loads: Optional[np.ndarray] = None,
                  names: Optional[Sequence[str]] = None,
                  slo: Optional[SLO] = None,
                  cost_model: Optional[CostModel] = None,
                  record_mb: float = 0.0,
                  bin_hours: Optional[float] = None, *,
                  return_series: bool = True,
                  load_matrix: Optional[np.ndarray] = None,
                  load_index: Optional[np.ndarray] = None,
                  scenario_block: Optional[int] = None,
                  devices: Optional[int] = None,
                  faults=None):
    """Simulate N scenarios — twins[i] against loads[i] — in one vmapped
    scan. ``loads`` is [N, T] records per bin of ``bin_hours`` (the year
    tables use [N, HOURS_PER_YEAR] hourly bins).

    Two result modes:

    * ``return_series=True`` (default) — the seed contract, bit-identical:
      five [N, T] hourly series come back from the device and each
      scenario is summarised into a full ``SimulationResult``. Plots,
      ``monthly_table`` and calibration traces need this mode.
    * ``return_series=False`` — the streaming-aggregate backend: the
      Table II statistics (compensated sums, per-bin max, end-of-scan
      queue, SLO-ok counters and a load-weighted latency histogram) are
      folded into the scan carry, NO [N, T] output series is ever
      materialized, and one vectorized numpy pass over the O(N)
      aggregates returns ``GridSummary`` rows. Sums / maxima / queue /
      SLO percentages match the series path exactly; the median is
      histogram-exact (one log bucket). This is the mode 100k+-scenario
      what-if sweeps should use (and ``whatif.run_grid`` defaults to).

    Instead of a stacked ``loads`` grid, pass ``load_matrix`` [K, T] (each
    distinct load row once) + ``load_index`` [N] (scenario i plays row
    ``load_matrix[load_index[i]]``) so host memory stays O(K*T + N);
    ``whatif.run_grid`` builds its (traffic x twin) grids this way.
    ``scenario_block`` (aggregate mode only) streams the grid through
    the device in blocks of that many scenarios via ``lax.map`` — with
    the matrix+index encoding, grids larger than device memory complete
    in one call (a stacked ``loads=`` grid still lands on the device
    whole as the gather source; chunking then bounds only the
    per-block panel and outputs).

    Omitting ``bin_hours`` keeps the seed contract: hourly bins over the
    full year, any other horizon rejected. Passing it (any value,
    including an explicit 1.0) unlocks arbitrary horizons — but storage/
    network accounting (Table IV) is daily-rolling over the year, so a
    cost model + record_mb on a non-year grid is an error, not a silent
    zero.

    **Scaling the grid** (aggregate mode). The whole engine is
    device-resident: the quarter-octave latency histogram accumulates
    on device next to the scan (an exact f64 ``segment_sum`` per time
    chunk on the XLA path, compensated in-kernel triples on Pallas), so
    no ``[B, T]`` latency panel is ever staged, copied to the host, or
    binned there — only O(N·AGG_DIM) aggregate rows leave the device,
    once, at the end of the grid. Three independent levers:

    * ``scenario_block`` — scenarios per streamed device block. The
      default (``agg_auto_block(t_bins, panels=...)``) sizes blocks so
      one block's working set fits a ~150 MB budget, derived from what
      the chosen backend actually allocates: the XLA path stages only
      [B, chunk] time-chunk gathers plus the aggregate rows (so year
      grids get ~7.6k-scenario blocks), while the Pallas path still
      gathers one [T, B] column panel per block (three on chaos grids —
      counted, not under-budgeted). Shrink it if a block plus the O(N)
      aggregates exceeds device memory; growing it buys little —
      per-block overhead is one dispatch.
    * Chunked blocks are regrouped to be *policy-uniform* (stable order,
      results scattered back), so each block runs exactly one policy
      branch instead of an evaluate-all-branches select — on a mixed
      five-policy grid that alone is most of the engine's speedup, at
      identical bits.
    * ``devices=D`` — shard the blocked grid over a 1-D ``D``-device
      scenario mesh (load matrix replicated, scenario blocks sharded).
      The histogram stays inside the ``shard_map`` body, so rounds no
      longer serialize on a host drain. Results are bit-identical to
      ``devices=None``. On a multi-core CPU host, export
      ``XLA_FLAGS=--xla_force_host_platform_device_count=D``
      *before the first jax import* to expose D host devices; on real
      accelerators each device is one shard. Million-scenario full-year
      sweeps complete either way — memory stays at one block per device
      — sharding just divides the wall clock.

    **Chaos suites** (``faults=``). Pass a ``repro.faults.FaultSchedule``
    (sampled here, seeded and deterministic) or a pre-sampled
    ``repro.faults.SampledFaults`` to play every scenario against F
    fault futures: outage windows zero the twin's capacity, brownouts
    scale it down, correlated device disconnects strip a load fraction
    and replay it as a reconnect flood right after the window, bursts
    multiply the load. The grid expands in place to N*F scenarios named
    ``"{name}/f{f}"``, ordered scenario-major / future-minor (row
    ``i*F + f``), with ``twins[i]`` repeated across its futures. Load
    perturbations are baked into extra load-matrix rows (futures that
    don't touch the load alias the ORIGINAL rows — an empty or benign
    schedule is bit-identical to the fault-free grid on both backends,
    including under ``devices=D``); capacity perturbations stream
    through the scan as [F, T] fault rows gathered per scenario, so a
    65k-scenario full-year chaos grid ships F rows, not 65k. Aggregate
    mode additionally reports fault attribution per scenario
    (``GridSummary.fault_hours`` / ``pct_hours_met_in_fault`` /
    ``pct_hours_met_outside_fault``) from in-carry counters — no [N, T]
    series materialized. Sampled series are validated before any device
    work: a negative or non-finite capacity/load multiplier raises
    ``ValueError`` naming the fault spec and bin index. Chance-
    constrained search over the same futures lives in
    ``repro.search.search(faults=..., quantile=...)``.

    **Observing the wind tunnel** (``repro.obs``). With telemetry on
    (``REPRO_OBS=1`` or inside ``obs.capture()``) every grid emits a
    ``grid.simulate`` root span (attrs: ``n``, ``t_bins``, ``mode``,
    ``devices``, ``faulted``); the blocked aggregate engine nests a
    ``grid.block`` span per device block (``grid.round`` per sharded
    round) tagged with block index, size, policy, backend and a
    ``compiled`` flag read off the jit trace cache, so re-trace storms
    are visible per block. Counters: ``grid.scenarios``,
    ``grid.blocks{backend,devices}``, ``grid.dedup.total`` /
    ``grid.dedup.kept`` (how much of the grid bitwise-dedup collapsed).
    All instrumentation sits at dispatch boundaries — never inside
    jitted code — so simulated numbers are bit-identical with telemetry
    on or off, and the disabled path costs one attribute check per
    site. ``obs.render()`` prints the consolidated table;
    ``obs.prometheus_exposition(rows)`` serves the returned
    ``GridSummary`` rows as a scrape-able exposition.
    """
    if (loads is None) == (load_matrix is None):
        raise ValueError("pass exactly one of loads= (stacked [N, T] grid) "
                         "or load_matrix= [K, T] + load_index= [N]")
    if load_matrix is not None:
        load_matrix = np.asarray(load_matrix, np.float32)
        if load_matrix.ndim != 2:
            raise ValueError(f"load_matrix must be [K, T], got shape "
                             f"{load_matrix.shape}")
        if load_index is None:
            raise ValueError("load_matrix= needs load_index= mapping each "
                             "scenario to a matrix row")
        load_index = np.asarray(load_index, np.int32)
        if load_index.ndim != 1:
            raise ValueError(f"load_index must be [N], got shape "
                             f"{load_index.shape}")
        if load_index.size and (load_index.min() < 0
                                or load_index.max() >= load_matrix.shape[0]):
            raise ValueError(f"load_index out of range for "
                             f"{load_matrix.shape[0]} load_matrix rows")
        n, t_bins = len(load_index), load_matrix.shape[1]
    else:
        loads = np.asarray(loads, np.float32)
        if loads.ndim != 2:
            raise ValueError(f"loads must be a [N, T] scenario grid, got "
                             f"shape {loads.shape}")
        n, t_bins = loads.shape
    if bin_hours is None:
        if t_bins != HOURS_PER_YEAR:
            raise ValueError(
                f"hourly grids must cover the {HOURS_PER_YEAR}-hour year, "
                f"got {t_bins} bins; pass bin_hours= for sub-hour "
                f"or short-horizon traces")
        bin_hours = 1.0
    year_grid = t_bins == HOURS_PER_YEAR and bin_hours == 1.0
    if cost_model is not None and record_mb > 0.0 and not year_grid:
        raise ValueError("storage/network costs need the hourly full-year "
                         "grid (daily rolling retention); drop the cost "
                         "model or simulate the full year")
    if len(twins) != n:
        raise ValueError(f"{len(twins)} twins for {n} load "
                         f"rows — the grid pairs twins[i] with loads[i]")
    if scenario_block is not None and scenario_block <= 0:
        raise ValueError(f"scenario_block must be a positive block size, "
                         f"got {scenario_block}")
    if scenario_block is not None and return_series:
        raise ValueError("scenario_block chunks the streaming-aggregate "
                         "backend only; series mode materializes all "
                         "[N, T] series regardless, so the memory bound "
                         "you asked for cannot be honored — drop "
                         "scenario_block or pass return_series=False")
    if devices is not None:
        if return_series:
            raise ValueError("devices= shards the streaming-aggregate "
                             "backend only; pass return_series=False")
        if devices <= 0:
            raise ValueError(f"devices must be a positive mesh size, "
                             f"got {devices}")
        if devices > jax.device_count():
            raise ValueError(
                f"devices={devices} but only {jax.device_count()} "
                f"JAX device(s) are visible; on CPU export "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{devices} before the first jax import")
    params = np.stack([tw.padded_params() for tw in twins])
    idx = np.asarray([tw.policy_index for tw in twins], np.int32)
    names = list(names) if names is not None else [tw.name for tw in twins]

    fault = None
    if faults is not None:
        from repro.faults import (FaultSchedule, SampledFaults,
                                  expand_grid, sample_futures,
                                  validate_sampled)
        if isinstance(faults, FaultSchedule):
            sampled = sample_futures(faults, t_bins, float(bin_hours))
        elif isinstance(faults, SampledFaults):
            if faults.t_bins != t_bins:
                raise ValueError(
                    f"SampledFaults covers {faults.t_bins} bins but the "
                    f"grid has {t_bins}; resample with sample_futures("
                    f"schedule, {t_bins}, bin_hours={bin_hours})")
            sampled = faults
        else:
            raise TypeError(
                f"faults= must be a repro.faults.FaultSchedule or "
                f"SampledFaults, got {type(faults).__name__}")
        validate_sampled(sampled)
        if load_matrix is None:    # expansion needs the matrix encoding
            load_matrix = loads
            load_index = np.arange(n, dtype=np.int32)
            loads = None
        fg = expand_grid(sampled, load_matrix, load_index)
        nf = fg.n_futures
        load_matrix, load_index = fg.load_matrix, fg.load_index
        params = np.repeat(params, nf, axis=0)
        idx = np.repeat(idx, nf)
        twins = [tw for tw in twins for _ in range(nf)]
        names = [f"{nm}/f{f}" for nm in names for f in range(nf)]
        n = n * nf
        fault = (fg.cap, fg.fmask, fg.fault_index)

    if not return_series:
        slo_mode = (AGG_SLO_DROP_RATE
                    if slo is not None and slo.metric == "drop_rate"
                    else AGG_SLO_LATENCY)
        slo_limit = float(slo.limit_s) if slo is not None else float("inf")
        if load_matrix is None:        # chunk/gather via an identity map
            load_matrix, load_index = loads, np.arange(n, dtype=np.int32)
        # duplicate-scenario dedup (benign futures, tiled tournaments)
        # happens inside the dispatch — see _dedup_rows
        obs.count("grid.scenarios", n)
        with obs.span("grid.simulate", n=n, t_bins=t_bins, mode="agg",
                      devices=int(devices or 1),
                      faulted=fault is not None):
            carry_end, agg = _grid_agg_dispatch(
                load_matrix, load_index, params, idx, float(bin_hours),
                slo_limit, slo_mode, scenario_block, devices=devices,
                fault=fault)
        return _summarise_aggregates(
            names, twins, carry_end[:, 0], agg, slo, cost_model, record_mb,
            float(bin_hours), t_bins, load_matrix, load_index)

    if loads is None:
        # series mode needs the full grid — the O(N*T) stack is the cost
        # of asking for per-bin series; aggregate mode never builds it
        loads = load_matrix[load_index]
    obs.count("grid.scenarios", n)
    with obs.span("grid.simulate", n=n, t_bins=t_bins, mode="series",
                  faulted=fault is not None):
        if fault is not None:
            caps_np = np.asarray(fault[0])[fault[2]]
            q_end, (processed, queue, latency, cost, dropped) = \
                _grid_scan_fault_xla(
                    jnp.asarray(loads), jnp.asarray(caps_np),
                    jnp.asarray(params), jnp.asarray(idx),
                    registry_version(), float(bin_hours))
        else:
            q_end, (processed, queue, latency, cost, dropped) = _grid_scan(
                jnp.asarray(loads), jnp.asarray(params), jnp.asarray(idx),
                registry_version(), float(bin_hours))
        jax.block_until_ready(q_end)
    q_end = np.asarray(q_end, np.float64)
    processed = np.asarray(processed, np.float64)
    queue = np.asarray(queue, np.float64)
    latency = np.asarray(latency, np.float64)
    cost = np.asarray(cost, np.float64)
    dropped = np.asarray(dropped, np.float64)
    return [
        _summarise(names[i], twins[i], np.asarray(loads[i], np.float64),
                   processed[i], queue[i], latency[i], cost[i], dropped[i],
                   float(q_end[i]), slo, cost_model, record_mb, bin_hours)
        for i in range(len(twins))
    ]


def simulate_year(twin: Twin, hourly_load: np.ndarray,
                  slo: Optional[SLO] = None,
                  cost_model: Optional[CostModel] = None,
                  record_mb: float = 0.0,
                  name: Optional[str] = None) -> SimulationResult:
    """Batch-of-one wrapper over ``simulate_grid`` (the seed's API)."""
    load = np.asarray(hourly_load, np.float32)
    if load.shape != (HOURS_PER_YEAR,):
        raise ValueError(f"hourly_load must cover the {HOURS_PER_YEAR}-hour "
                         f"year, got shape {load.shape}; use simulate_grid "
                         f"with bin_hours= for other horizons")
    return simulate_grid([twin], load[None], names=[name or twin.name],
                         slo=slo, cost_model=cost_model,
                         record_mb=record_mb)[0]


def _summarise(name: str, twin: Twin, load_np: np.ndarray,
               processed: np.ndarray, queue: np.ndarray, lat_np: np.ndarray,
               cost_np: np.ndarray, dropped: np.ndarray, q_end: float,
               slo: Optional[SLO], cost_model: Optional[CostModel],
               record_mb: float, bin_hours: float = 1.0) -> SimulationResult:
    backlog_s = q_end / max(twin.max_rps, 1e-9)
    backlog_cost = backlog_s / 3600.0 * twin.usd_per_hour

    # record-weighted latency stats (records arriving each hour share the
    # hour's latency estimate); p95/p99 read off the same CDF as the
    # median — the tail targets p-latency SLOs constrain
    w = load_np / max(load_np.sum(), 1e-9)
    order = np.argsort(lat_np)
    sorted_lat = lat_np[order]
    cdf = np.cumsum(w[order])
    qidx = np.minimum(np.searchsorted(cdf, (0.5, 0.95, 0.99)),
                      len(sorted_lat) - 1)
    median_lat, p95_lat, p99_lat = (float(v) for v in sorted_lat[qidx])
    mean_lat = float((lat_np * w).sum())

    pct_rec_met = pct_hours_met = 100.0
    slo_met = None
    if slo is not None:
        if slo.metric == "drop_rate":
            # hourly shed fraction vs the allowed fraction
            vals = dropped / np.maximum(load_np, 1e-9)
        else:
            vals = lat_np
        pct_rec_met, slo_met = slo.evaluate(vals, weights=load_np)
        pct_hours_met = slo.evaluate(vals)[0]

    net_cost = stor_cost = 0.0
    if cost_model is not None and record_mb > 0.0:
        # simulate_grid guarantees the hourly full-year grid here
        daily = storage_costs(load_np, cost_model, record_mb)
        net_cost = float(daily["network_usd"].sum())
        stor_cost = float(daily["storage_usd"].sum())

    return SimulationResult(
        name=name, twin=twin, load=load_np,
        processed=processed, queue=queue, latency_s=lat_np, cost_usd=cost_np,
        total_cost_usd=float(cost_np.sum() + backlog_cost),
        backlog_s=backlog_s, backlog_cost_usd=backlog_cost,
        mean_throughput_rph=float(processed.mean() / bin_hours),
        max_throughput_rph=float(processed.max() / bin_hours),
        median_latency_s=median_lat, mean_latency_s=mean_lat,
        pct_latency_met=pct_rec_met, pct_hours_met=pct_hours_met,
        slo_met=slo_met, network_cost_usd=net_cost,
        storage_cost_usd=stor_cost, dropped=dropped,
        dropped_records=float(dropped.sum()),
        p95_latency_s=p95_lat, p99_latency_s=p99_lat)


def _summarise_aggregates(names: Sequence[str], twins: Sequence[Twin],
                          q_end: np.ndarray, agg: np.ndarray,
                          slo: Optional[SLO],
                          cost_model: Optional[CostModel], record_mb: float,
                          bin_hours: float, t_bins: int,
                          load_matrix: np.ndarray,
                          load_index: np.ndarray) -> List["GridSummary"]:
    """ONE vectorized numpy pass over the [N, AGG_DIM] aggregate rows —
    the streaming replacement for the per-scenario ``_summarise`` loop.

    Twice-compensated (sum, comp, comp2) triples are recombined in f64,
    which reproduces the series path's f64 sums bit for bit at year-grid
    magnitudes; the median is read off the load-weighted latency
    histogram CDF (bucket-center representative, exact to one
    ``AGG_HIST_W``-decade bucket)."""
    n = agg.shape[0]
    tri = lambda i: agg[:, i] + agg[:, i + 1] + agg[:, i + 2]  # noqa: E731
    sum_proc, sum_cost = tri(A_PROC), tri(A_COST)
    sum_drop, sum_latw = tri(A_DROP), tri(A_LATW)
    sum_load, sum_okw = tri(A_LOAD), tri(A_OKW)
    okh, maxp = agg[:, A_OKH], agg[:, A_MAXP]
    flth, fokh = agg[:, A_FLTH], agg[:, A_FOKH]

    max_rps = np.array([tw.max_rps for tw in twins], np.float64)
    usd_hr = np.array([tw.usd_per_hour for tw in twins], np.float64)
    backlog_s = q_end / np.maximum(max_rps, 1e-9)
    backlog_cost = backlog_s / 3600.0 * usd_hr

    # device-side quantiles: first histogram bucket whose load-weighted
    # CDF crosses each target (the sort/cumsum quantiles of
    # ``_summarise``, exact to one log-spaced bucket). p95/p99 feed
    # p-latency SLO checks (repro.search) and the Table II tail columns.
    hist = agg[:, AGG_SCALARS:]
    cdf = np.cumsum(hist, axis=1)
    centers = aggregate_hist_centers()
    median, p95, p99 = (
        centers[np.argmax(cdf >= q * cdf[:, -1:], axis=1)]
        for q in (0.5, 0.95, 0.99))
    mean_lat = sum_latw / np.maximum(sum_load, 1e-9)

    if slo is not None:
        pct_rec = sum_okw / np.maximum(sum_load, 1e-12) * 100.0
        pct_hours = okh / t_bins * 100.0
        met = pct_rec >= slo.met_fraction * 100.0
    else:
        pct_rec = pct_hours = np.full(n, 100.0)
        met = None

    # fault attribution (repro.faults): in-carry counters split the
    # SLO-ok bins inside vs outside fault windows — no [N, T] series.
    # Benign grids carry flth == 0 everywhere, so both splits read 100.
    fault_hours = flth * bin_hours
    pct_in = np.where(flth > 0, fokh / np.maximum(flth, 1.0) * 100.0,
                      100.0)
    out_bins = t_bins - flth
    pct_out = np.where(out_bins > 0,
                       (okh - fokh) / np.maximum(out_bins, 1.0) * 100.0,
                       100.0)

    net = stor = np.zeros(n)
    if cost_model is not None and record_mb > 0.0:
        # per distinct load row (simulate_grid guarantees the hourly
        # full-year grid here), then spread by the index map
        daily = np.asarray(load_matrix, np.float64).reshape(
            -1, DAYS_PER_YEAR, 24).sum(axis=2)
        ingest_mb = daily * record_mb
        ret = cost_model.retention_days
        csum = np.concatenate(
            [np.zeros((len(ingest_mb), 1)), np.cumsum(ingest_mb, axis=1)],
            axis=1)
        lo = np.maximum(np.arange(DAYS_PER_YEAR) + 1 - ret, 0)
        stored_mb = csum[:, 1:] - csum[:, lo]
        net_k = ingest_mb.sum(axis=1) * cost_model.network_usd_per_mb
        stor_k = (stored_mb / 1024.0).sum(axis=1) \
            * cost_model.storage_usd_per_gb_day
        net, stor = net_k[load_index], stor_k[load_index]

    return [
        GridSummary(
            name=names[i], twin=twins[i],
            total_cost_usd=float(sum_cost[i] + backlog_cost[i]),
            backlog_s=float(backlog_s[i]),
            backlog_cost_usd=float(backlog_cost[i]),
            mean_throughput_rph=float(sum_proc[i] / t_bins / bin_hours),
            max_throughput_rph=float(maxp[i] / bin_hours),
            median_latency_s=float(median[i]),
            mean_latency_s=float(mean_lat[i]),
            pct_latency_met=float(pct_rec[i]),
            pct_hours_met=float(pct_hours[i]),
            slo_met=None if met is None else bool(met[i]),
            network_cost_usd=float(net[i]),
            storage_cost_usd=float(stor[i]),
            dropped_records=float(sum_drop[i]),
            p95_latency_s=float(p95[i]),
            p99_latency_s=float(p99[i]),
            processed_records=float(sum_proc[i]),
            arrived_records=float(sum_load[i]),
            queue_end=float(q_end[i]),
            latency_hist=hist[i],
            fault_hours=float(fault_hours[i]),
            pct_hours_met_in_fault=float(pct_in[i]),
            pct_hours_met_outside_fault=float(pct_out[i]))
        for i in range(n)
    ]


def storage_costs(hourly_load: np.ndarray, cost_model: CostModel,
                  record_mb: float) -> Dict[str, np.ndarray]:
    """Daily rolling-retention storage + network costs (Table IV)."""
    daily_records = hourly_load.reshape(DAYS_PER_YEAR, 24).sum(axis=1)
    ingest_mb = daily_records * record_mb
    ret = cost_model.retention_days
    # stored_mb[d] = sum of ingest over the trailing retention window
    csum = np.concatenate([[0.0], np.cumsum(ingest_mb)])
    lo = np.maximum(np.arange(DAYS_PER_YEAR) + 1 - ret, 0)
    stored_mb = csum[1:] - csum[lo]
    return {
        "ingest_mb": ingest_mb,
        "stored_gb": stored_mb / 1024.0,
        "network_usd": ingest_mb * cost_model.network_usd_per_mb,
        "storage_usd": stored_mb / 1024.0 * cost_model.storage_usd_per_gb_day,
    }


def monthly_table(sim: SimulationResult, cost_model: CostModel,
                  record_mb: float) -> List[Dict[str, float]]:
    """Monthly cloud/network/storage breakdown (Table IV rows)."""
    daily = storage_costs(sim.load, cost_model, record_mb)
    rows = []
    day0 = 0
    hourly_cost = sim.cost_usd
    for m, nd in enumerate(MONTH_DAYS):
        days = slice(day0, day0 + nd)
        hours = slice(day0 * 24, (day0 + nd) * 24)
        cloud = float(hourly_cost[hours].sum())
        net = float(daily["network_usd"][days].sum())
        stor = float(daily["storage_usd"][days].sum())
        rows.append({"month": m + 1, "cloud_usd": cloud, "network_usd": net,
                     "storage_usd": stor, "total_usd": cloud + net + stor})
        day0 += nd
    return rows

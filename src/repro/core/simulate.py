"""Year-long pipeline simulation (paper Sec. V-G / Tables II & IV) on the
unified TwinPolicy engine.

``simulate_grid`` plays hourly load projections through digital twins: the
whole batch of (twin x traffic) scenarios is stacked into [N, H] load and
[N, PARAM_DIM] parameter arrays and executed as ONE ``jax.vmap`` over a
jitted ``jax.lax.scan`` of the 8736 hours. Each hour step dispatches to the
twin's registered policy with ``jax.lax.switch`` (see core/twin.py), so a
grid mixing fifo / quickscale / autoscale / shed / batch_window twins is a
single device dispatch — "no synthetic data is actually processed; only the
load shape is used, so the simulation is quite fast" (paper); here a full
64-scenario grid simulates in about the time the seed took for one.

``simulate_year`` is the batch-of-one convenience wrapper and keeps the
seed's exact semantics: legacy SimpleTwin/QuickscalingTwin results are
numerically identical to the old hard-coded scan.

The scan is generalized to arbitrary horizon and bin width: policy steps
take the bin width ``dt`` (hours), so the same kernel that plays 8736
one-hour bins for the year tables also replays a sub-hour calibration
trace (``repro.calibrate``). ``scan_trace`` is the unbatched, *unjitted*
core — differentiable w.r.t. the parameter vector, which is what twin
calibration differentiates through. The year path pins dt=1.0 (a static
jit arg) and stays bit-identical to the PR 1 kernel.

The grid runs on either of two interchangeable backends, selected by
``_grid_scan`` through the ``kernels.ops`` Pallas switch:

* **XLA** (default) — ``_grid_scan_xla``: vmap over per-scenario scans of
  the scalar ``lax.switch`` policy step. The parity anchor; hourly
  full-year results are bit-identical to the pre-Pallas kernel.
* **Pallas** (``kernels.ops.use_pallas(True)`` or the ``pallas_mode()``
  context) — the fused scenario-grid kernel of
  ``kernels/policy_scan.py``: one ``pallas_call`` scans all T bins for
  LANES scenarios at a time using the branchless lane-vectorized policy
  steps (``core.twin.lane_policy_step``), scenarios on the vector lanes,
  ``interpret=True`` on CPU. Grids and K-restart calibration fits
  (restarts are just more lanes) both route through this selection.

End-of-year backlog is priced the paper's way: queue_length / capacity
hours of extra pipeline time at the twin's hourly rate ("the cost of, for
example, spinning up duplicate pipelines to process the backlog"). Policies
with a bounded queue additionally report a ``dropped`` hourly series
(records shed), which SLOs can target via ``metric="drop_rate"``.

``storage_costs`` runs the daily rolling-retention accumulation (Table IV):
data builds up day by day and ages out after the retention window.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import CostModel
from repro.core.slo import SLO
from repro.core.traffic import DAYS_PER_YEAR, HOURS_PER_YEAR, MONTH_DAYS
from repro.core.twin import (CARRY_DIM, Twin, policy_branches,
                             registry_version)


@dataclass
class SimulationResult:
    name: str
    twin: Twin
    # hourly arrays [8736]
    load: np.ndarray
    processed: np.ndarray
    queue: np.ndarray
    latency_s: np.ndarray
    cost_usd: np.ndarray
    # scalars
    total_cost_usd: float
    backlog_s: float
    backlog_cost_usd: float
    mean_throughput_rph: float
    max_throughput_rph: float
    median_latency_s: float
    mean_latency_s: float
    pct_latency_met: float          # record-weighted, vs slo.limit
    pct_hours_met: float            # hour-weighted
    slo_met: Optional[bool]
    network_cost_usd: float = 0.0
    storage_cost_usd: float = 0.0
    # hourly records shed by bounded-queue policies (zeros otherwise)
    dropped: np.ndarray = field(default_factory=lambda: np.zeros(0))
    dropped_records: float = 0.0

    def __post_init__(self):
        # a defaulted ``dropped`` must still match the horizon — a bare
        # shape-(0,) array silently broadcasts to nonsense (or raises)
        # against the other hourly series in elementwise use
        if self.dropped.shape != self.load.shape:
            if self.dropped.size == 0:
                self.dropped = np.zeros_like(self.load)
            else:
                raise ValueError(
                    f"dropped has shape {self.dropped.shape}, want "
                    f"{self.load.shape} to match the hourly series")

    @property
    def grand_total_usd(self) -> float:
        return self.total_cost_usd + self.network_cost_usd + self.storage_cost_usd


def scan_trace(load: jnp.ndarray, params: jnp.ndarray, policy_index,
               dt_hours=1.0):
    """One scenario's scan over arbitrary bins — the differentiable core.

    load [T] records/bin; params [PARAM_DIM]; ``dt_hours`` is the bin width.
    Unjitted on purpose: ``repro.calibrate`` takes ``jax.grad`` of a loss
    through this scan (wrapping it in its own jit), and ``_grid_scan`` wraps
    it in vmap+jit for the what-if grids. Returns (carry_end, (processed,
    queue, latency, cost, dropped)) with each series shaped [T].
    """
    branches = policy_branches()
    dt = jnp.asarray(dt_hours, jnp.float32)

    def bin_step(carry, arrive):
        return jax.lax.switch(policy_index, branches, carry, arrive,
                              params, dt)

    return jax.lax.scan(bin_step, jnp.zeros((CARRY_DIM,), jnp.float32), load)


@functools.partial(jax.jit, static_argnums=(3, 4))
def _grid_scan_xla(loads: jnp.ndarray, params: jnp.ndarray,
                   policy_idx: jnp.ndarray, version: int,
                   dt_hours: float = 1.0):
    """The XLA grid backend: vmap over per-scenario ``lax.switch`` scans.

    loads [N, T] records/bin; params [N, PARAM_DIM] per twin.padded_params;
    policy_idx [N] int32 switch indices; ``version`` is the policy-registry
    version (static) so late policy registration forces a retrace;
    ``dt_hours`` (static) is the bin width — 1.0 for the year tables.
    This path is the parity anchor: the hourly full-year numbers stay
    bit-identical to the pre-Pallas kernel.
    """
    def one(load, p, idx):
        carry_end, outs = scan_trace(load, p, idx, dt_hours)
        return carry_end[0], outs

    return jax.vmap(one)(loads, params, policy_idx)


def _grid_scan(loads: jnp.ndarray, params: jnp.ndarray,
               policy_idx: jnp.ndarray, version: int, dt_hours: float = 1.0):
    """The whole grid in one dispatch — backend-selecting entry point.

    Default: the XLA vmapped switch-scan above. Under ``kernels.ops.
    use_pallas(True)`` / ``pallas_mode()``: the fused Pallas scenario-grid
    kernel (``kernels/policy_scan.py``), scenarios on the vector lanes,
    ``interpret=True`` on CPU. Same operands, same (q_end [N], five
    [N, T] series) contract either way; selection happens OUTSIDE jit, so
    flipping the switch between calls never stales a trace cache.
    """
    from repro.kernels import ops
    if ops.pallas_enabled():
        from repro.core.twin import policy_onehot
        onehot = jnp.asarray(policy_onehot(np.asarray(policy_idx)))
        carry_end, outs = ops.policy_scan(loads, params, onehot, dt_hours)
        return carry_end[:, 0], outs
    return _grid_scan_xla(loads, params, policy_idx, version, dt_hours)


# the jit-cache introspection the tests (and benchmarks) use lives on the
# XLA path; expose it on the selector so callers keep one import
_grid_scan.clear_cache = _grid_scan_xla.clear_cache
_grid_scan._cache_size = _grid_scan_xla._cache_size


def simulate_grid(twins: Sequence[Twin], loads: np.ndarray,
                  names: Optional[Sequence[str]] = None,
                  slo: Optional[SLO] = None,
                  cost_model: Optional[CostModel] = None,
                  record_mb: float = 0.0,
                  bin_hours: Optional[float] = None) -> List[SimulationResult]:
    """Simulate N scenarios — twins[i] against loads[i] — in one vmapped
    scan. ``loads`` is [N, T] records per bin of ``bin_hours`` (the year
    tables use [N, HOURS_PER_YEAR] hourly bins); stats are summarised per
    scenario afterwards in numpy.

    Omitting ``bin_hours`` keeps the seed contract: hourly bins over the
    full year, any other horizon rejected. Passing it (any value,
    including an explicit 1.0) unlocks arbitrary horizons — but storage/
    network accounting (Table IV) is daily-rolling over the year, so a
    cost model + record_mb on a non-year grid is an error, not a silent
    zero."""
    loads = np.asarray(loads, np.float32)
    if loads.ndim != 2:
        raise ValueError(f"loads must be a [N, T] scenario grid, got shape "
                         f"{loads.shape}")
    if bin_hours is None:
        if loads.shape[1] != HOURS_PER_YEAR:
            raise ValueError(
                f"hourly grids must cover the {HOURS_PER_YEAR}-hour year, "
                f"got {loads.shape[1]} bins; pass bin_hours= for sub-hour "
                f"or short-horizon traces")
        bin_hours = 1.0
    year_grid = loads.shape[1] == HOURS_PER_YEAR and bin_hours == 1.0
    if cost_model is not None and record_mb > 0.0 and not year_grid:
        raise ValueError("storage/network costs need the hourly full-year "
                         "grid (daily rolling retention); drop the cost "
                         "model or simulate the full year")
    if len(twins) != loads.shape[0]:
        raise ValueError(f"{len(twins)} twins for {loads.shape[0]} load "
                         f"rows — the grid pairs twins[i] with loads[i]")
    params = np.stack([tw.padded_params() for tw in twins])
    idx = np.asarray([tw.policy_index for tw in twins], np.int32)
    q_end, (processed, queue, latency, cost, dropped) = _grid_scan(
        jnp.asarray(loads), jnp.asarray(params), jnp.asarray(idx),
        registry_version(), float(bin_hours))
    q_end = np.asarray(q_end, np.float64)
    processed = np.asarray(processed, np.float64)
    queue = np.asarray(queue, np.float64)
    latency = np.asarray(latency, np.float64)
    cost = np.asarray(cost, np.float64)
    dropped = np.asarray(dropped, np.float64)
    names = list(names) if names is not None else [tw.name for tw in twins]
    return [
        _summarise(names[i], twins[i], np.asarray(loads[i], np.float64),
                   processed[i], queue[i], latency[i], cost[i], dropped[i],
                   float(q_end[i]), slo, cost_model, record_mb, bin_hours)
        for i in range(len(twins))
    ]


def simulate_year(twin: Twin, hourly_load: np.ndarray,
                  slo: Optional[SLO] = None,
                  cost_model: Optional[CostModel] = None,
                  record_mb: float = 0.0,
                  name: Optional[str] = None) -> SimulationResult:
    """Batch-of-one wrapper over ``simulate_grid`` (the seed's API)."""
    load = np.asarray(hourly_load, np.float32)
    if load.shape != (HOURS_PER_YEAR,):
        raise ValueError(f"hourly_load must cover the {HOURS_PER_YEAR}-hour "
                         f"year, got shape {load.shape}; use simulate_grid "
                         f"with bin_hours= for other horizons")
    return simulate_grid([twin], load[None], names=[name or twin.name],
                         slo=slo, cost_model=cost_model,
                         record_mb=record_mb)[0]


def _summarise(name: str, twin: Twin, load_np: np.ndarray,
               processed: np.ndarray, queue: np.ndarray, lat_np: np.ndarray,
               cost_np: np.ndarray, dropped: np.ndarray, q_end: float,
               slo: Optional[SLO], cost_model: Optional[CostModel],
               record_mb: float, bin_hours: float = 1.0) -> SimulationResult:
    backlog_s = q_end / max(twin.max_rps, 1e-9)
    backlog_cost = backlog_s / 3600.0 * twin.usd_per_hour

    # record-weighted latency stats (records arriving each hour share the
    # hour's latency estimate)
    w = load_np / max(load_np.sum(), 1e-9)
    order = np.argsort(lat_np)
    cdf = np.cumsum(w[order])
    median_lat = float(lat_np[order][np.searchsorted(cdf, 0.5)])
    mean_lat = float((lat_np * w).sum())

    pct_rec_met = pct_hours_met = 100.0
    slo_met = None
    if slo is not None:
        if slo.metric == "drop_rate":
            # hourly shed fraction vs the allowed fraction
            vals = dropped / np.maximum(load_np, 1e-9)
        else:
            vals = lat_np
        pct_rec_met, slo_met = slo.evaluate(vals, weights=load_np)
        pct_hours_met = slo.evaluate(vals)[0]

    net_cost = stor_cost = 0.0
    if cost_model is not None and record_mb > 0.0:
        # simulate_grid guarantees the hourly full-year grid here
        daily = storage_costs(load_np, cost_model, record_mb)
        net_cost = float(daily["network_usd"].sum())
        stor_cost = float(daily["storage_usd"].sum())

    return SimulationResult(
        name=name, twin=twin, load=load_np,
        processed=processed, queue=queue, latency_s=lat_np, cost_usd=cost_np,
        total_cost_usd=float(cost_np.sum() + backlog_cost),
        backlog_s=backlog_s, backlog_cost_usd=backlog_cost,
        mean_throughput_rph=float(processed.mean() / bin_hours),
        max_throughput_rph=float(processed.max() / bin_hours),
        median_latency_s=median_lat, mean_latency_s=mean_lat,
        pct_latency_met=pct_rec_met, pct_hours_met=pct_hours_met,
        slo_met=slo_met, network_cost_usd=net_cost,
        storage_cost_usd=stor_cost, dropped=dropped,
        dropped_records=float(dropped.sum()))


def storage_costs(hourly_load: np.ndarray, cost_model: CostModel,
                  record_mb: float) -> Dict[str, np.ndarray]:
    """Daily rolling-retention storage + network costs (Table IV)."""
    daily_records = hourly_load.reshape(DAYS_PER_YEAR, 24).sum(axis=1)
    ingest_mb = daily_records * record_mb
    ret = cost_model.retention_days
    # stored_mb[d] = sum of ingest over the trailing retention window
    csum = np.concatenate([[0.0], np.cumsum(ingest_mb)])
    lo = np.maximum(np.arange(DAYS_PER_YEAR) + 1 - ret, 0)
    stored_mb = csum[1:] - csum[lo]
    return {
        "ingest_mb": ingest_mb,
        "stored_gb": stored_mb / 1024.0,
        "network_usd": ingest_mb * cost_model.network_usd_per_mb,
        "storage_usd": stored_mb / 1024.0 * cost_model.storage_usd_per_gb_day,
    }


def monthly_table(sim: SimulationResult, cost_model: CostModel,
                  record_mb: float) -> List[Dict[str, float]]:
    """Monthly cloud/network/storage breakdown (Table IV rows)."""
    daily = storage_costs(sim.load, cost_model, record_mb)
    rows = []
    day0 = 0
    hourly_cost = sim.cost_usd
    for m, nd in enumerate(MONTH_DAYS):
        days = slice(day0, day0 + nd)
        hours = slice(day0 * 24, (day0 + nd) * 24)
        cloud = float(hourly_cost[hours].sum())
        net = float(daily["network_usd"][days].sum())
        stor = float(daily["storage_usd"][days].sum())
        rows.append({"month": m + 1, "cloud_usd": cloud, "network_usd": net,
                     "storage_usd": stor, "total_usd": cloud + net + stor})
        day0 += nd
    return rows

"""LoadPattern (the paper's K6 load generator config): piecewise-linear
records/second over named segments. ``rate_at(t)`` linearly interpolates
within a segment; ``records_between(t0, t1)`` integrates the trapezoid so
callers can drive discrete steps at exact record counts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

# numpy < 2.0 ships the integrator as np.trapz (same compat-shim precedent
# as the jax-version shims in distributed/sharding.py)
_trapezoid = getattr(np, "trapezoid", None) or np.trapz


@dataclass(frozen=True)
class Segment:
    duration_s: float
    start_rate: float        # records/s at segment start
    end_rate: float          # records/s at segment end


@dataclass(frozen=True)
class LoadPattern:
    name: str
    segments: Tuple[Segment, ...]

    @property
    def total_duration(self) -> float:
        return sum(s.duration_s for s in self.segments)

    @property
    def total_records(self) -> float:
        return sum(0.5 * (s.start_rate + s.end_rate) * s.duration_s
                   for s in self.segments)

    def rate_at(self, t: float) -> float:
        off = 0.0
        for s in self.segments:
            if t <= off + s.duration_s:
                frac = (t - off) / max(s.duration_s, 1e-9)
                return s.start_rate + frac * (s.end_rate - s.start_rate)
            off += s.duration_s
        return 0.0

    def records_between(self, t0: float, t1: float, n: int = 32) -> float:
        """Trapezoidal integral of rate over [t0, t1]."""
        ts = np.linspace(t0, t1, n)
        rs = np.array([self.rate_at(float(t)) for t in ts])
        return float(_trapezoid(rs, ts))

    @staticmethod
    def ramp(name: str, duration_s: float, peak_rate: float) -> "LoadPattern":
        """The paper's canonical pattern: ramp 0 -> above-capacity peak to
        find nominal throughput and overload behaviour."""
        return LoadPattern(name, (Segment(duration_s, 0.0, peak_rate),))

    @staticmethod
    def steady(name: str, duration_s: float, rate: float) -> "LoadPattern":
        return LoadPattern(name, (Segment(duration_s, rate, rate),))

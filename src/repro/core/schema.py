"""Data schemas (GoFakeIt analogue): field specs with constraints.

A Schema describes one record type the pipeline-under-test ingests. Fields
carry type + range/choice constraints; the DataGenerator synthesizes records
matching them. For LM pipelines a Schema can also describe a token stream
(field kind "tokens" with a vocab size and length distribution) — the
JAX-pipeline equivalent of the paper's zipped telemetry files.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple


@dataclass(frozen=True)
class FieldSpec:
    name: str
    kind: str                      # float | int | choice | latlon | timestamp | tokens | bytes
    low: float = 0.0
    high: float = 1.0
    choices: Tuple[str, ...] = ()
    vocab_size: int = 0            # kind == tokens
    length: int = 0                # kind in (tokens, bytes)

    def byte_size(self) -> int:
        """Approximate on-the-wire size of one field value (CSV-ish)."""
        if self.kind == "float":
            return 12
        if self.kind == "int":
            return 8
        if self.kind == "timestamp":
            return 20
        if self.kind == "choice":
            return max((len(c) for c in self.choices), default=4)
        if self.kind == "latlon":
            return 24
        if self.kind == "tokens":
            return 4 * self.length
        if self.kind == "bytes":
            return self.length
        raise ValueError(self.kind)


@dataclass(frozen=True)
class Schema:
    name: str
    fields: Tuple[FieldSpec, ...]

    def record_bytes(self) -> int:
        return sum(f.byte_size() for f in self.fields) + len(self.fields)

    def field(self, name: str) -> FieldSpec:
        for f in self.fields:
            if f.name == name:
                return f
        raise KeyError(name)


def telemetry_schema(subsystems: int = 5, floats_per_subsystem: int = 12) -> Schema:
    """The Honda-style automotive telemetry record: one zip per car
    transmission containing ``subsystems`` binary channel files."""
    fields = [
        FieldSpec("vehicle_id", "int", 0, 2 ** 31),
        FieldSpec("ts", "timestamp"),
        FieldSpec("location", "latlon", low=-84.8, high=41.5),  # Ohio-ish box
        FieldSpec("speed_kph", "float", 0, 200),
    ]
    for s in range(subsystems):
        for i in range(floats_per_subsystem):
            fields.append(FieldSpec(f"sub{s}_ch{i}", "float", -1e3, 1e3))
    return Schema("automotive-telemetry", tuple(fields))


def token_stream_schema(vocab_size: int, seq_len: int) -> Schema:
    """LM pipeline ingest: one record == one sequence of token ids."""
    return Schema(f"tokens-v{vocab_size}-s{seq_len}",
                  (FieldSpec("tokens", "tokens", vocab_size=vocab_size,
                             length=seq_len),))

"""Traffic models (paper Sec. V-G): projected hourly load over a year.

Load_h = R * growth(dayofyear) * H[hour, dow] * M[month]

R — records/s at the start of the year; G — annual growth factor (1.0 = flat,
1.5 = +50 % by year end; the paper's formula reads `1 + doy*G/365` but its
own Nominal case uses G=1.0 with *no* growth, so the intended multiplier is
`1 + doy*(G-1)/365`, which we use and note in EXPERIMENTS.md); M — monthly
seasonal factors; H — 168 hour-of-week factors.

The paper's exact 168-entry H table is unpublished; ``honda_default``
synthesizes factors matching every published constraint: month range
0.84 (Jan) … 1.14 (Aug), hour-of-week range 0.04 (Wed 6am) … 2.26 (Fri 8pm),
and the Table II mean load of 5035.8 records/hour at R = 3.5 rec/s.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import numpy as np

HOURS_PER_YEAR = 8736            # 52 weeks, the paper's year (cost tables)
DAYS_PER_YEAR = 364
# calendar months over a 364-day year (Dec truncated to 30 days)
MONTH_DAYS = (31, 28, 31, 30, 31, 30, 31, 31, 30, 31, 30, 30)
START_DOW = 3                    # Jan 1 is a Thursday (2026); 0 = Monday

# Published anchor points. The paper's hour-of-week pins (2.26 Fri 8pm,
# 0.04 Wed 6am) are on a mean-normalized scale: Table II's peak nominal load
# (13191.79 rec/h = max non-block throughput) / mean (5035.8) = 2.62 =
# 2.26 * maxM/meanM — i.e. mean(H_rel) == 1 and the absolute multiplier is
# folded into the calibration constant alpha below.
M_MONTH = np.array([0.84, 0.86, 0.92, 0.98, 1.04, 1.09, 1.12, 1.14,
                    1.08, 1.00, 0.92, 0.87])
PIN_FRI20 = 2.26                 # Friday 20:00 (relative, mean(H_rel)=1)
PIN_WED06 = 0.04                 # Wednesday 06:00 (relative)
TARGET_MEAN_RPH = 5035.8         # Table II mean throughput @ R=3.5 rec/s


def _base_hour_curve() -> np.ndarray:
    """One weekday's 24-hour shape (relative; normalized later)."""
    return np.array([
        0.30, 0.18, 0.10, 0.07, 0.05, 0.045, 0.05, 0.30,   # 00-07
        0.70, 0.95, 1.05, 1.10, 1.15, 1.10, 1.05, 1.10,    # 08-15
        1.25, 1.50, 1.75, 1.95, 2.05, 1.55, 0.95, 0.55])   # 16-23


def _dow_scale() -> np.ndarray:
    # Mon..Sun; Friday evening spike, quieter Sunday
    return np.array([0.97, 0.99, 1.01, 1.03, 1.10, 1.05, 0.85])


@dataclass(frozen=True)
class TrafficModel:
    name: str
    R: float                          # records/s at year start
    G: float = 1.0                    # annual growth factor
    M: Tuple[float, ...] = tuple(M_MONTH)
    H: Tuple[float, ...] = ()         # 168 entries, Mon 00:00 first

    def month_of_day(self, day: int) -> int:
        acc = 0
        for m, nd in enumerate(MONTH_DAYS):
            acc += nd
            if day < acc:
                return m
        return 11

    def hourly_loads(self) -> np.ndarray:
        """Records per hour for each of the 8736 hours."""
        H = np.asarray(self.H, float)
        M = np.asarray(self.M, float)
        hours = np.arange(HOURS_PER_YEAR)
        day = hours // 24
        hod = hours % 24
        dow = (START_DOW + day) % 7
        how = dow * 24 + hod
        months = np.array([self.month_of_day(int(d)) for d in range(DAYS_PER_YEAR)])
        growth = 1.0 + day * (self.G - 1.0) / 365.0
        return (self.R * 3600.0) * growth * H[how] * M[months[day]]

    @staticmethod
    def honda_default(name: str = "nominal", R: float = 3.5,
                      G: float = 1.0) -> "TrafficModel":
        """Synthesized Honda-like factors calibrated to published anchors."""
        base = np.outer(_dow_scale(), _base_hour_curve()).reshape(168)
        # relative curve with mean 1 and the published pins
        H_rel = base / base.mean()
        fri20, wed06 = 4 * 24 + 20, 2 * 24 + 6
        for _ in range(4):
            H_rel[fri20], H_rel[wed06] = PIN_FRI20, PIN_WED06
            free = np.ones(168, bool)
            free[[fri20, wed06]] = False
            H_rel[free] *= (168 - PIN_FRI20 - PIN_WED06) / H_rel[free].sum()
        # absolute calibration to the published mean load at R=3.5
        tm = TrafficModel(name, R=R, G=1.0, H=tuple(H_rel))
        alpha = TARGET_MEAN_RPH * (R / 3.5) / tm.hourly_loads().mean()
        return TrafficModel(name, R=R, G=G, H=tuple(H_rel * alpha))

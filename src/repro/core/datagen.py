"""Synthetic data generation (the paper's GoFakeIt-based data service).

Generates a DataSet ahead of an experiment (the paper stores generated data
before the run so generation never throttles the load generator). Generation
is numpy-based and deterministic per (schema, seed).

For LM pipelines the interesting structure is token statistics: uniform
token ids exercise an LM pipeline the way mid-ocean lat/lons exercise a
map-matching stage (the paper's own example of unrealistic synthetic data) —
so token streams use a Zipfian distribution by default, which matches the
rank-frequency profile of real text corpora.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.schema import FieldSpec, Schema


@dataclass
class DataSet:
    """Pre-generated records for an experiment (Kubernetes DataSet CRD)."""
    schema: Schema
    columns: Dict[str, np.ndarray]
    num_records: int

    def record_batch(self, start: int, count: int) -> Dict[str, np.ndarray]:
        idx = (np.arange(start, start + count)) % self.num_records
        return {k: v[idx] for k, v in self.columns.items()}

    @property
    def total_bytes(self) -> int:
        return self.num_records * self.schema.record_bytes()


class DataGenerator:
    def __init__(self, seed: int = 0, zipf_a: float = 1.2):
        self.seed = seed
        self.zipf_a = zipf_a

    def generate(self, schema: Schema, num_records: int) -> DataSet:
        # stable across processes: Python's str hash is salted by
        # PYTHONHASHSEED, so hash((name, seed)) would break "deterministic
        # per (schema, seed)" between runs; crc32 is not
        rng = np.random.default_rng(
            zlib.crc32(f"{schema.name}:{self.seed}".encode()) % (2 ** 31))
        cols: Dict[str, np.ndarray] = {}
        for f in schema.fields:
            cols[f.name] = self._field(rng, f, num_records)
        return DataSet(schema, cols, num_records)

    def _field(self, rng, f: FieldSpec, n: int) -> np.ndarray:
        if f.kind == "float":
            return rng.uniform(f.low, f.high, n).astype(np.float32)
        if f.kind == "int":
            return rng.integers(int(f.low), int(f.high), n, dtype=np.int64)
        if f.kind == "timestamp":
            base = np.datetime64("2026-01-01").astype("datetime64[s]").astype(np.int64)
            return base + rng.integers(0, 86400 * 364, n)
        if f.kind == "choice":
            return rng.choice(np.array(f.choices), n)
        if f.kind == "latlon":
            # constrained land box (avoids the paper's mid-ocean pitfall)
            lat = rng.uniform(38.4, 41.9, n)
            lon = rng.uniform(-84.8, -80.5, n)
            return np.stack([lat, lon], -1).astype(np.float32)
        if f.kind == "tokens":
            # Zipfian token ids folded into the vocab
            z = rng.zipf(self.zipf_a, size=(n, f.length))
            return ((z - 1) % f.vocab_size).astype(np.int32)
        if f.kind == "bytes":
            return rng.integers(0, 256, (n, f.length), dtype=np.uint8)
        raise ValueError(f.kind)

"""PlantD core — the paper's contribution, adapted to JAX pipelines.

The "data pipeline wind tunnel": schema-driven synthetic data, shaped load
generation, span instrumentation, a time-series metric store, experiment
management, cost capture, and the business-analysis layer (traffic models,
digital twins, year-long simulation, SLOs, what-if comparison).
"""
from repro.core.schema import Schema, FieldSpec                    # noqa: F401
from repro.core.datagen import DataGenerator, DataSet              # noqa: F401
from repro.core.loadpattern import LoadPattern, Segment            # noqa: F401
from repro.core.spans import Span, SpanCollector, span             # noqa: F401
from repro.core.metrics import MetricStore                         # noqa: F401
from repro.core.pipeline import Pipeline, PipelineStage            # noqa: F401
from repro.core.experiment import Experiment, ExperimentResult     # noqa: F401
from repro.core.traffic import TrafficModel                        # noqa: F401
from repro.core.twin import (Twin, SimpleTwin, QuickscalingTwin,   # noqa: F401
                             make_twin, register_policy, policy_names,
                             fit_twin, fit_simple_twin,
                             fit_quickscaling_twin, roofline_twin)
from repro.core.simulate import (simulate_year, simulate_grid,     # noqa: F401
                                 SimulationResult)
from repro.core.slo import SLO                                     # noqa: F401
from repro.core.cost import CostModel, TPU_V5E_USD_PER_CHIP_HOUR   # noqa: F401

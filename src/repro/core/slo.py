"""Service-level objectives (paper Sec. V-G): a measurement type, a limit,
and the required fraction of compliance. Example from the paper: processing
latency may not exceed 4 hours more than 5% of the time.

Beyond-paper: ``metric="drop_rate"`` targets the hourly shed fraction of
bounded-queue twin policies (core/twin.py ``shed``) instead of latency —
``limit_s`` is then a dimensionless fraction (``SLO.for_drop_rate``)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SLO:
    metric: str = "latency"        # latency | drop_rate | error_rate
    limit_s: float = 4 * 3600.0    # seconds (latency) or fraction (rates)
    met_fraction: float = 0.95     # required proportion within the limit

    @property
    def limit(self) -> float:
        """Metric-agnostic alias for ``limit_s``."""
        return self.limit_s

    @classmethod
    def for_drop_rate(cls, max_fraction: float = 0.0,
                      met_fraction: float = 0.95) -> "SLO":
        """E.g. "no more than 1% of records shed in 95% of hours"."""
        return cls(metric="drop_rate", limit_s=max_fraction,
                   met_fraction=met_fraction)

    def evaluate(self, values: np.ndarray, weights: np.ndarray | None = None):
        """Returns (pct_met, met_bool); weights for record-weighted checks."""
        values = np.asarray(values, float)
        ok = values <= self.limit_s
        if weights is None:
            pct = float(ok.mean() * 100.0)
        else:
            w = np.asarray(weights, float)
            pct = float((ok * w).sum() / max(w.sum(), 1e-12) * 100.0)
        return pct, bool(pct >= self.met_fraction * 100.0)

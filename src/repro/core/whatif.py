"""What-if scenario engine (paper Sec. VII): run (twin x traffic) grids,
compare retention policies, and render Table II / Table IV style results.

``run_grid`` stacks every (traffic x twin) combination into one batch and
executes it as a single scan dispatch via ``simulate_grid`` — policies may
be mixed freely in one grid. The scan runs on whichever backend
``core.simulate._grid_scan`` selects: the XLA vmapped ``lax.switch`` scan
(default), or — under ``kernels.ops.pallas_mode()`` — the fused Pallas
scenario-grid kernel with scenarios on the vector lanes, so 1k+-scenario
sweeps of the Jablonski & Heltweg cost levers (autoscaling delay,
overprovisioning, queue caps) stay one device program.

``calibrated_grid`` closes the paper's loop end to end: it gradient-fits
one twin per requested policy to a measured ``ExperimentResult`` (or a
prebuilt ``ObservedTrace``) via ``repro.calibrate`` and plays the fitted
twins through the Table II grid — measurement in, scenario table out."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cost import CostModel
from repro.core.simulate import (SimulationResult, monthly_table,
                                 simulate_grid, simulate_year)
from repro.core.slo import SLO
from repro.core.traffic import TrafficModel
from repro.core.twin import Twin


@dataclass(frozen=True)
class Scenario:
    name: str
    twin: Twin
    traffic: TrafficModel


def run_grid(twins: Sequence[Twin], traffics: Sequence[TrafficModel],
             slo: Optional[SLO] = None,
             cost_model: Optional[CostModel] = None,
             record_mb: float = 0.0) -> List[SimulationResult]:
    """Every (traffic x twin) combination — the paper's Table II grid —
    simulated in one vmapped scan over the stacked scenario batch."""
    grid_twins: List[Twin] = []
    grid_loads: List[np.ndarray] = []
    names: List[str] = []
    for tr in traffics:
        loads = tr.hourly_loads()
        for tw in twins:
            grid_twins.append(tw)
            grid_loads.append(loads)
            names.append(f"{tr.name} {tw.name}")
    if not grid_twins:
        return []
    return simulate_grid(grid_twins, np.stack(grid_loads), names=names,
                         slo=slo, cost_model=cost_model, record_mb=record_mb)


def calibrated_grid(source, policies: Sequence[str],
                    traffics: Sequence[TrafficModel],
                    slo: Optional[SLO] = None,
                    cost_model: Optional[CostModel] = None,
                    record_mb: float = 0.0,
                    bin_s: float = 1.0,
                    **fit_kwargs) -> List[SimulationResult]:
    """Measured pipeline -> fitted twins -> Table II grid, in one call.

    ``source`` is an ``ExperimentResult`` or an
    ``repro.calibrate.ObservedTrace``; one twin is calibrated per entry of
    ``policies`` (extra kwargs forward to ``repro.calibrate.fit``), then
    the whole (traffic x fitted twin) grid runs as a single vmapped scan.
    """
    from repro.calibrate import calibrated_twin   # late: calibrate sits
    twins = [calibrated_twin(source, policy, bin_s=bin_s,  # above core
                             name=f"{policy}-cal", **fit_kwargs)
             for policy in policies]
    return run_grid(twins, traffics, slo=slo, cost_model=cost_model,
                    record_mb=record_mb)


def run_scenarios(scenarios: Sequence[Scenario],
                  slo: Optional[SLO] = None,
                  cost_model: Optional[CostModel] = None,
                  record_mb: float = 0.0) -> List[SimulationResult]:
    """Arbitrary named (twin, traffic) pairs, batched like ``run_grid``."""
    if not scenarios:
        return []
    loads = np.stack([s.traffic.hourly_loads() for s in scenarios])
    return simulate_grid([s.twin for s in scenarios], loads,
                         names=[s.name for s in scenarios], slo=slo,
                         cost_model=cost_model, record_mb=record_mb)


def table2_rows(sims: Sequence[SimulationResult]) -> List[Dict]:
    rows = []
    for s in sims:
        rows.append({
            "run": s.name,
            "policy": s.twin.policy,
            "cost_usd": round(s.total_cost_usd, 2),
            "latency_median_s": round(s.median_latency_s, 2),
            "latency_mean_s": round(s.mean_latency_s, 2),
            "latency_backlog_s": round(s.backlog_s, 2),
            "thruput_mean_rph": round(s.mean_throughput_rph, 2),
            "thruput_max_rph": round(s.max_throughput_rph, 2),
            "dropped": round(s.dropped_records, 1),
            "pct_latency_met": round(s.pct_latency_met, 2),
            "slo_met": s.slo_met,
        })
    return rows


def retention_whatif(twin: Twin, traffic: TrafficModel, record_mb: float,
                     retentions_days: Sequence[int] = (91, 182),
                     cost_model: Optional[CostModel] = None,
                     slo: Optional[SLO] = None) -> Dict[int, List[Dict]]:
    """The paper's 3-month vs 6-month retention comparison (Table IV)."""
    cm = cost_model or CostModel()
    loads = traffic.hourly_loads()
    out = {}
    for ret in retentions_days:
        cmr = replace(cm, retention_days=ret)
        sim = simulate_year(twin, loads, slo=slo, cost_model=cmr,
                            record_mb=record_mb,
                            name=f"{traffic.name} {twin.name} ret{ret}")
        out[ret] = monthly_table(sim, cmr, record_mb)
    return out

"""What-if scenario engine (paper Sec. VII): run (twin x traffic) grids,
compare retention policies, and render Table II / Table IV style results.

``run_grid`` pairs every (traffic x twin) combination and executes the
whole batch as a single scan dispatch via ``simulate_grid`` — policies may
be mixed freely in one grid. Each traffic's [8736] load row is held ONCE
in a [K, T] load matrix with an [N] index map (never duplicated per twin),
so host memory is O(traffics*T + N), and by default the grid runs in
**streaming-aggregate mode**: the Table II statistics come back as O(N)
``GridSummary`` rows with no [N, T] series ever materialized —
``table2_rows`` only consumes scalars, so 100k+-scenario sweeps of the
Jablonski & Heltweg cost levers (autoscaling delay, overprovisioning,
queue caps) cost O(N) memory. Pass ``return_series=True`` for the full
per-bin ``SimulationResult`` series (plots, ``monthly_table``), and
``scenario_block=`` to stream grids larger than device memory through in
blocks. The scan runs on whichever backend ``core.simulate`` selects: the
XLA vmapped ``lax.switch`` scan (default), or — under
``kernels.ops.pallas_mode()`` — the fused Pallas scenario-grid kernels
with scenarios on the vector lanes.

``calibrated_grid`` closes the paper's loop end to end: it gradient-fits
one twin per requested policy to a measured ``ExperimentResult`` (or a
prebuilt ``ObservedTrace``) via ``repro.calibrate`` and plays the fitted
twins through the Table II grid — measurement in, scenario table out."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.cost import CostModel
from repro.core.simulate import (GridSummary, SimulationResult,
                                 monthly_table, simulate_grid, simulate_year)
from repro.core.slo import SLO
from repro.core.traffic import TrafficModel
from repro.core.twin import Twin

#: what grid runners return: per-bin series or streaming-aggregate scalars
GridResult = Union[SimulationResult, GridSummary]


@dataclass(frozen=True)
class Scenario:
    name: str
    twin: Twin
    traffic: TrafficModel


def run_grid(twins: Sequence[Twin], traffics: Sequence[TrafficModel],
             slo: Optional[SLO] = None,
             cost_model: Optional[CostModel] = None,
             record_mb: float = 0.0, *,
             return_series: bool = False,
             scenario_block: Optional[int] = None,
             devices: Optional[int] = None,
             faults=None) -> List[GridResult]:
    """Every (traffic x twin) combination — the paper's Table II grid —
    simulated in one dispatch over the (load matrix, index map) batch.

    Aggregate mode by default (``GridSummary`` rows, O(N) memory end to
    end); ``return_series=True`` restores the full ``SimulationResult``
    series, bit-identical to the pre-streaming engine. ``scenario_block``
    streams huge aggregate grids through the device in policy-uniform
    blocks, and ``devices=D`` shards those blocks over a D-device
    scenario mesh (see ``simulate_grid``'s "Scaling the grid").
    ``faults=`` (a ``repro.faults.FaultSchedule`` or ``SampledFaults``)
    crosses the grid with F fault futures — chaos-suite Table II, rows
    named ``"{traffic} {twin}/f{f}"`` (see ``simulate_grid``'s "Chaos
    suites"); ``table2_rows`` then adds the fault-attribution columns."""
    if not twins or not traffics:
        return []
    load_matrix = np.stack([tr.hourly_loads() for tr in traffics])
    load_index = np.repeat(np.arange(len(traffics), dtype=np.int32),
                           len(twins))
    grid_twins = [tw for _ in traffics for tw in twins]
    names = [f"{tr.name} {tw.name}" for tr in traffics for tw in twins]
    return simulate_grid(grid_twins, names=names, slo=slo,
                         cost_model=cost_model, record_mb=record_mb,
                         return_series=return_series,
                         load_matrix=load_matrix, load_index=load_index,
                         scenario_block=scenario_block, devices=devices,
                         faults=faults)


def calibrated_grid(source, policies: Sequence[str],
                    traffics: Sequence[TrafficModel],
                    slo: Optional[SLO] = None,
                    cost_model: Optional[CostModel] = None,
                    record_mb: float = 0.0,
                    bin_s: float = 1.0,
                    **fit_kwargs) -> List[GridResult]:
    """Measured pipeline -> fitted twins -> Table II grid, in one call.

    ``source`` is an ``ExperimentResult`` or an
    ``repro.calibrate.ObservedTrace``; one twin is calibrated per entry of
    ``policies`` (extra kwargs forward to ``repro.calibrate.fit`` —
    ``devices=D`` shards each fit's restarts over a device mesh), then
    the whole (traffic x fitted twin) grid runs as a single vmapped scan.
    """
    from repro.calibrate import calibrated_twin   # late: calibrate sits
    twins = [calibrated_twin(source, policy, bin_s=bin_s,  # above core
                             name=f"{policy}-cal", **fit_kwargs)
             for policy in policies]
    return run_grid(twins, traffics, slo=slo, cost_model=cost_model,
                    record_mb=record_mb)


def optimize_scenario(base: Twin, traffics, slo: Optional[SLO] = None,
                      *, search: Optional[Sequence[str]] = None,
                      bounds: Optional[Dict] = None,
                      tie: Optional[Dict] = None,
                      **search_kwargs):
    """The inverse of ``run_grid``: cheapest configuration, not a table.

    Searches ``base``'s policy for the cheapest parameter setting that
    meets ``slo`` on every traffic scenario — gradient descent on the
    smooth annual-cost objective (``repro.search``), all restarts x
    scenarios as one vmapped grad-of-scan dispatch, feasibility
    re-checked through the bit-exact streaming-aggregate grid. ``search``
    names the free parameters (default: the policy's extras, or priced
    capacity for extra-less policies); ``bounds``/``tie`` refine the
    space; remaining kwargs forward to ``repro.search.search`` (restarts,
    steps, coarsen, ..., and ``devices=D`` to shard the restart axis over
    a device mesh — see "Scaling the search" there). Returns a
    ``repro.search.SearchResult`` whose ``.twin`` drops straight into
    ``run_grid`` / ``table2_rows``.

    Pass ``faults=`` (a ``repro.faults.FaultSchedule``) and
    ``quantile=`` for the chance-constrained resilience variant: the
    cheapest configuration meeting ``slo`` in at least that fraction of
    the schedule's fault futures on every traffic scenario, with the
    achieved empirical quantile re-checked bit-exactly
    (``SearchResult.achieved_quantile``).
    """
    from repro.search import search as _search          # late: search
    from repro.search import search_space               # sits above core
    space = search_space(base, search, bounds=bounds, tie=tie)
    return _search(space, traffics, slo, **search_kwargs)


def run_scenarios(scenarios: Sequence[Scenario],
                  slo: Optional[SLO] = None,
                  cost_model: Optional[CostModel] = None,
                  record_mb: float = 0.0, *,
                  return_series: bool = False,
                  scenario_block: Optional[int] = None,
                  devices: Optional[int] = None) -> List[GridResult]:
    """Arbitrary named (twin, traffic) pairs, batched like ``run_grid``
    (aggregate mode by default; each scenario brings its own traffic, so
    the load matrix deduplicates repeated traffic objects only).
    ``scenario_block`` / ``devices`` stream and shard exactly as in
    ``run_grid``."""
    if not scenarios:
        return []
    row_of: Dict[int, int] = {}
    rows: List[np.ndarray] = []
    load_index = np.empty(len(scenarios), np.int32)
    for i, s in enumerate(scenarios):
        key = id(s.traffic)
        if key not in row_of:
            row_of[key] = len(rows)
            rows.append(s.traffic.hourly_loads())
        load_index[i] = row_of[key]
    return simulate_grid([s.twin for s in scenarios],
                         names=[s.name for s in scenarios], slo=slo,
                         cost_model=cost_model, record_mb=record_mb,
                         return_series=return_series,
                         load_matrix=np.stack(rows), load_index=load_index,
                         scenario_block=scenario_block, devices=devices)


def table2_rows(sims: Sequence[GridResult]) -> List[Dict]:
    # chaos-suite grids (any row simulated through fault windows) grow
    # three attribution columns; benign tables keep the seed's exact
    # column set
    fault_cols = any(getattr(s, "fault_hours", 0.0) > 0.0 for s in sims)
    rows = []
    for s in sims:
        row = {
            "run": s.name,
            "policy": s.twin.policy,
            "cost_usd": round(s.total_cost_usd, 2),
            "latency_median_s": round(s.median_latency_s, 2),
            "latency_p95_s": round(s.p95_latency_s, 2),
            "latency_p99_s": round(s.p99_latency_s, 2),
            "latency_mean_s": round(s.mean_latency_s, 2),
            "latency_backlog_s": round(s.backlog_s, 2),
            "thruput_mean_rph": round(s.mean_throughput_rph, 2),
            "thruput_max_rph": round(s.max_throughput_rph, 2),
            "dropped": round(s.dropped_records, 1),
            "pct_latency_met": round(s.pct_latency_met, 2),
            "slo_met": s.slo_met,
        }
        if fault_cols:
            row["fault_hours"] = round(getattr(s, "fault_hours", 0.0), 1)
            row["pct_hours_met_in_fault"] = round(
                getattr(s, "pct_hours_met_in_fault", 100.0), 2)
            row["pct_hours_met_outside_fault"] = round(
                getattr(s, "pct_hours_met_outside_fault", 100.0), 2)
        rows.append(row)
    return rows


def retention_whatif(twin: Twin, traffic: TrafficModel, record_mb: float,
                     retentions_days: Sequence[int] = (91, 182),
                     cost_model: Optional[CostModel] = None,
                     slo: Optional[SLO] = None) -> Dict[int, List[Dict]]:
    """The paper's 3-month vs 6-month retention comparison (Table IV)."""
    cm = cost_model or CostModel()
    loads = traffic.hourly_loads()
    out = {}
    for ret in retentions_days:
        cmr = replace(cm, retention_days=ret)
        sim = simulate_year(twin, loads, slo=slo, cost_model=cmr,
                            record_mb=record_mb,
                            name=f"{traffic.name} {twin.name} ret{ret}")
        out[ret] = monthly_table(sim, cmr, record_mb)
    return out

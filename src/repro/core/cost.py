"""Cost capture + modeling (the paper's OpenCost / billing-log analogue).

On a cloud, PlantD prorates hourly billing records over the experiment
window and allocates shared-cluster cost by container utilisation. Here the
"cluster" is this process plus (virtually) the TPU slice the pipeline
targets, so the price book is explicit and the allocation exact — we keep
the same prorating API so the business layer is unchanged.

Rates are public on-demand list prices (July 2025-ish): TPU v5e $1.20 per
chip-hour; generic vCPU $0.0425/hr; RAM $0.0057/GB-hr. Network and storage
rates default to the paper's business-analysis assumptions: 0.02 cents/MB
network, 1 cent/GB/day storage, 3-month retention.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

TPU_V5E_USD_PER_CHIP_HOUR = 1.20
VCPU_USD_PER_HOUR = 0.0425
RAM_USD_PER_GB_HOUR = 0.0057


@dataclass(frozen=True)
class CostModel:
    """Business cost assumptions (paper Sec. VI-B defaults)."""
    network_usd_per_mb: float = 0.0002          # 0.02 cents / MB
    storage_usd_per_gb_day: float = 0.01        # 1 cent / GB / day
    retention_days: int = 91                    # 3 months
    chip_usd_per_hour: float = TPU_V5E_USD_PER_CHIP_HOUR
    vcpu_usd_per_hour: float = VCPU_USD_PER_HOUR
    ram_usd_per_gb_hour: float = RAM_USD_PER_GB_HOUR

    def pipeline_usd_per_hour(self, resources) -> float:
        return (resources.chips * self.chip_usd_per_hour
                + resources.vcpus * self.vcpu_usd_per_hour
                + resources.ram_gb * self.ram_usd_per_gb_hour)

    def experiment_cost(self, resources, duration_s: float,
                        ingest_mb: float = 0.0) -> Dict[str, float]:
        """Prorated cost of one experiment window (the paper prorates the
        provider's hourly billing granularity over the run length)."""
        hourly = self.pipeline_usd_per_hour(resources)
        compute = hourly * duration_s / 3600.0
        network = ingest_mb * self.network_usd_per_mb
        return {"compute_usd": compute, "network_usd": network,
                "total_usd": compute + network, "usd_per_hour": hourly}

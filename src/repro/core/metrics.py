"""In-process time-series store (Prometheus analogue).

Counters, gauges and histograms with timestamped samples; rate/mean/quantile
queries over time windows. JSONL export for post-hoc analysis (the paper's
"review later / compare experiments" workflow).
"""
from __future__ import annotations

import bisect
import json
import threading
import time
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass
class Sample:
    t: float
    value: float


class MetricStore:
    def __init__(self, clock=time.perf_counter):
        self._series: Dict[str, List[Sample]] = defaultdict(list)
        self._lock = threading.Lock()
        self.clock = clock

    # -- writers ------------------------------------------------------------
    def observe(self, name: str, value: float, t: Optional[float] = None):
        with self._lock:
            self._series[name].append(Sample(self.clock() if t is None else t,
                                             float(value)))

    def inc(self, name: str, delta: float = 1.0, t: Optional[float] = None):
        with self._lock:
            prev = self._series[name][-1].value if self._series[name] else 0.0
            self._series[name].append(
                Sample(self.clock() if t is None else t, prev + delta))

    # -- readers ------------------------------------------------------------
    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._series)

    def series(self, name: str) -> List[Sample]:
        with self._lock:
            return list(self._series.get(name, []))

    def values(self, name: str) -> List[float]:
        return [s.value for s in self.series(name)]

    def window(self, name: str, t0: float, t1: float) -> List[Sample]:
        ss = self.series(name)
        ts = [s.t for s in ss]
        i0 = bisect.bisect_left(ts, t0)
        i1 = bisect.bisect_right(ts, t1)
        return ss[i0:i1]

    def mean(self, name: str) -> float:
        v = self.values(name)
        return sum(v) / len(v) if v else 0.0

    def quantile(self, name: str, q: float) -> float:
        v = sorted(self.values(name))
        if not v:
            return 0.0
        return v[min(int(q * len(v)), len(v) - 1)]

    def rate(self, name: str, window_s: float = 10.0) -> float:
        """Per-second increase of a counter over the trailing window."""
        ss = self.series(name)
        if len(ss) < 2:
            return 0.0
        t1 = ss[-1].t
        w = self.window(name, t1 - window_s, t1)
        if len(w) < 2:
            return 0.0
        dt = w[-1].t - w[0].t
        return (w[-1].value - w[0].value) / dt if dt > 0 else 0.0

    # -- export -------------------------------------------------------------
    def dump_jsonl(self, path: str):
        with open(path, "w") as f:
            for name in self.names():
                for s in self.series(name):
                    f.write(json.dumps({"name": name, "t": s.t, "v": s.value}) + "\n")

    @staticmethod
    def load_jsonl(path: str) -> "MetricStore":
        ms = MetricStore()
        with open(path) as f:
            for line in f:
                d = json.loads(line)
                ms.observe(d["name"], d["v"], t=d["t"])
        return ms

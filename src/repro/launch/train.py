"""CLI train driver: ``python -m repro.launch.train --arch llama3.2-1b
--steps 200 --smoke`` (CPU) — the end-to-end training pipeline under the
wind tunnel. On a real slice, drop --smoke and point --mesh at the pod."""
from __future__ import annotations

import argparse

import jax

from repro.config import OptimizerConfig, ParallelConfig, TrainConfig
from repro.configs import all_archs, get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=all_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config runnable on CPU")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(args.data, args.model)
    tcfg = TrainConfig(steps=args.steps, seq_len=args.seq_len,
                       global_batch=args.batch, checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir)
    ocfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                           warmup_steps=max(args.steps // 10, 1))
    parallel = ParallelConfig(batch_axes=("data",), remat=args.remat,
                              microbatches=args.microbatches)
    res = train(cfg, tcfg, ocfg, parallel, mesh)
    print(f"done: {res.steps_done} steps, loss {res.losses[0]:.4f} -> "
          f"{res.final_loss:.4f}, restarts={res.restarts}")
    print("stage summary:")
    for name, v in res.collector.summary().items():
        print(f"  {name:14s} mean={v['mean_latency_s']*1e3:8.2f} ms/rec "
              f"thr={v['throughput_rps']:8.1f} rec/s")


if __name__ == "__main__":
    main()

"""CLI serve driver: batched serving of a smoke model under a LoadPattern,
measured by the wind tunnel (TTFT / latency / throughput per stage)."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.config import ParallelConfig
from repro.configs import all_archs, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=all_archs())
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0, help="requests/s")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    mesh = make_host_mesh(1, 1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, mesh, ParallelConfig(batch_axes=("data",)), params,
                      slots=args.slots, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=list(rng.integers(0, cfg.vocab_size, 8)),
                    max_new=args.max_new, submitted=i / args.rate)
            for i in range(args.requests)]
    done = eng.serve(reqs)
    ttfts = [r.ttft_s for r in done]
    lats = [r.latency_s for r in done]
    print(f"served {len(done)} requests")
    print(f"TTFT   p50={np.median(ttfts)*1e3:8.1f} ms  p95={np.percentile(ttfts,95)*1e3:8.1f} ms")
    print(f"E2E    p50={np.median(lats)*1e3:8.1f} ms  p95={np.percentile(lats,95)*1e3:8.1f} ms")
    for name, v in eng.collector.summary().items():
        print(f"  {name:12s} mean={v['mean_latency_s']*1e3:8.2f} ms "
              f"thr={v['throughput_rps']:8.1f}/s busy={v['busy_s']:.2f}s")


if __name__ == "__main__":
    main()

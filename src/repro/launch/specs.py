"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` builds the abstract batch for a (arch x shape)
cell; ``abstract_cache`` lives in models.model. Modality frontends are
stubs: for [vlm] the batch carries precomputed patch embeddings, for
[audio] precomputed frames, both at model width.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    """Abstract inputs for train/prefill; decode uses decode_input_specs."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch: Dict[str, SDS] = {}
    if cfg.frontend == "vision":
        from repro.configs.qwen2_vl_7b import N_PATCHES
        n_patch = min(N_PATCHES, s // 2)
        batch["tokens"] = SDS((b, s - n_patch), jnp.int32)
        batch["embeds"] = SDS((b, n_patch, cfg.d_model), dt)
        batch["positions"] = SDS((3, b, s), jnp.int32)
    elif cfg.encdec:
        batch["tokens"] = SDS((b, s), jnp.int32)
        batch["frames"] = SDS((b, cfg.encoder_seq, cfg.d_model), dt)
    else:
        batch["tokens"] = SDS((b, s), jnp.int32)
    if shape.kind == "train":
        batch["loss_mask"] = SDS(batch["tokens"].shape, jnp.float32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    return {"token": SDS((shape.global_batch, 1), jnp.int32)}


def concrete_batch(cfg: ModelConfig, shape: ShapeConfig, key=None):
    """Materialize a real batch matching input_specs (smoke tests/examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32 and k == "tokens":
            out[k] = jax.random.randint(key, v.shape, 0, cfg.vocab_size,
                                        dtype=jnp.int32)
        elif k == "positions":
            pos = jnp.broadcast_to(jnp.arange(v.shape[-1], dtype=jnp.int32),
                                   v.shape)
            out[k] = pos
        elif v.dtype == jnp.int32:
            out[k] = jnp.zeros(v.shape, jnp.int32)
        elif k == "loss_mask":
            out[k] = jnp.ones(v.shape, jnp.float32)
        else:
            out[k] = jax.random.normal(key, v.shape, jnp.float32).astype(v.dtype)
    return out

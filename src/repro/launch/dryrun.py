"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM and unsupported collectives all fail here.

Per cell we produce up to five compiles:
  memfit  — FULL config, rolled layer scan, flash-blocked attention:
            memory_analysis (fits 16 GB/chip?) + collective schedule.
  probe1/probe2 (exact)  — 1-group / 2-group model, scan fully unrolled,
            exact quadratic attention: faithful HLO FLOPs + collective bytes
            (XLA's cost_analysis counts a while-loop body once, so the
            dry-run unrolls; stack totals extrapolate linearly:
            total = B + (n_groups-1) * (C - B)).
  probe1/probe2 (chunked) — same, flash-blocked attention: faithful HBM
            bytes for the deployed (VMEM-resident) attention algorithm.

Results are cached as JSON under experiments/dryrun/ for the roofline layer.

NOTE the XLA_FLAGS line below MUST run before any jax import anywhere in
the process — run this module as a fresh `python -m repro.launch.dryrun`.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.config import (OptimizerConfig, ParallelConfig, ShapeConfig,  # noqa: E402
                          get_shape, SHAPES)
from repro.configs import all_archs, get_config  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import decode_input_specs, input_specs  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.optim.adamw import abstract_opt_state  # noqa: E402
from repro.serve.steps import make_decode_step, make_prefill_step  # noqa: E402
from repro.train.steps import make_train_step  # noqa: E402

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
               "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
               "c128": 16, "token": 0}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Sum result-operand bytes of every collective in the optimized HLO.
    Async pairs count the -start only. Returns {kind: {bytes, count}}."""
    out = {k: {"bytes": 0, "count": 0} for k in COLLECTIVES}
    for line in hlo.splitlines():
        if "=" not in line:
            continue
        for kind in COLLECTIVES:
            tok = f" {kind}("
            tok_start = f" {kind}-start("
            if tok in line or tok_start in line:
                lhs = line.split("=", 1)[1]
                op_pos = lhs.find(kind)
                type_part = lhs[:op_pos]
                b = _type_bytes(type_part)
                out[kind]["bytes"] += b
                out[kind]["count"] += 1
                break
        else:
            continue
    return out


def default_parallel(shape: ShapeConfig) -> ParallelConfig:
    """Production-default layouts per workload kind.

    train:   FSDP over 'data' + TP over 'model', full remat (activation
             memory at 1M-token global batches would blow HBM otherwise).
    serve:   TP over 'model' + EP over 'data' for experts; NO FSDP — weights
             replicated over 'data' (bf16) so decode never all-gathers
             parameters. Long-context batch-1 cells shard the cache seq dim
             over 'data' (sequence parallelism).
    """
    if shape.kind == "train":
        return ParallelConfig(remat="full", microbatches=4)
    if shape.kind == "decode":
        # flash-decoding layout: KV cache 2D-sharded (batch over dp, seq
        # over 'model' — or over everything when batch=1); the softmax
        # reduction distributes instead of gathering the cache.
        seq_axis = "model" if shape.global_batch >= 16 else ("data", "model")
        return ParallelConfig(fsdp_axis=None, shard_cache_seq=True,
                              seq_axis=seq_axis)
    return ParallelConfig(fsdp_axis=None)


def skip_reason(arch: str, shape: ShapeConfig) -> Optional[str]:
    cfg = get_config(arch)
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: 500k-token decode needs "
                "sub-quadratic attention (run only for ssm/hybrid)")
    return None


def reduced_cfg(cfg, groups: int):
    return dataclasses.replace(cfg,
                               num_layers=groups * len(cfg.block_pattern))


def build_lowered(cfg, shape: ShapeConfig, parallel: ParallelConfig, mesh,
                  ocfg: OptimizerConfig):
    """Lower the right step for the cell; returns jax.stages.Lowered."""
    if shape.kind == "train":
        batch_abs = input_specs(cfg, shape)
        step, _ = make_train_step(cfg, ocfg, parallel, mesh, batch_abs,
                                  donate=True)
        params_abs = M.abstract_params(cfg)
        opt_abs = abstract_opt_state(params_abs, ocfg)
        return step.lower(params_abs, opt_abs, batch_abs)
    if shape.kind == "prefill":
        batch_abs = input_specs(cfg, shape)
        step, _ = make_prefill_step(cfg, parallel, mesh, batch_abs,
                                    shape.global_batch, shape.seq_len)
        params_abs = M.abstract_params(cfg)
        cache_abs = M.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        return step.lower(params_abs, batch_abs, cache_abs)
    # decode
    batch_abs = decode_input_specs(cfg, shape)
    step, _ = make_decode_step(cfg, parallel, mesh, batch_abs,
                               shape.global_batch, shape.seq_len)
    params_abs = M.abstract_params(cfg)
    cache_abs = M.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    return step.lower(params_abs, cache_abs, batch_abs)


def memory_dict(compiled) -> Dict[str, float]:
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception as e:   # noqa: BLE001
        return {"error": str(e)}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes",
              "host_argument_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_mode(arch: str, shape: ShapeConfig, mesh_kind: str, mode: str,
             remat: str = "none", parallel_over: Optional[dict] = None
             ) -> Dict:
    cfg = get_config(arch)
    if shape.kind != "train":
        # serving runs bf16 weights (production inference numerics);
        # perf iterations may override (e.g. float8_e4m3fn W8 serving)
        cfg = dataclasses.replace(
            cfg, param_dtype=os.environ.get("REPRO_SERVE_PARAM_DTYPE",
                                            "bfloat16"))
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    parallel = default_parallel(shape)
    if remat != "none":
        parallel = dataclasses.replace(parallel, remat=remat)
    if parallel_over:
        parallel = dataclasses.replace(parallel, **parallel_over)
    ocfg = OptimizerConfig()

    if mode == "memfit":
        run_cfg = cfg
        tf.set_scan_unroll(1)
        ops.set_attn_chunk(1024 if shape.seq_len >= 4096 else 0)
    else:
        groups = 1 if mode.startswith("probe1") else 2
        run_cfg = reduced_cfg(cfg, groups)
        tf.set_scan_unroll(groups)
        ops.set_attn_chunk(1024 if mode.endswith("chunked") else 0)
        # probes must not hide per-step cost inside the microbatch scan;
        # the roofline layer re-adds per-microbatch weight traffic.
        parallel = dataclasses.replace(parallel, microbatches=1)

    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_kind, "mode": mode,
           "n_groups_full": tf.n_groups(cfg),
           "pattern_len": len(cfg.block_pattern), "status": "ok",
           "microbatches": parallel.microbatches, "remat": parallel.remat,
           "fsdp": parallel.fsdp_axis,
           "param_dtype": cfg.param_dtype}
    t0 = time.time()
    try:
        with mesh:
            lowered = build_lowered(run_cfg, shape, parallel, mesh, ocfg)
            t_lower = time.time()
            compiled = lowered.compile()
            t_comp = time.time()
            ca = compiled.cost_analysis() or {}
            rec["cost"] = {"flops": float(ca.get("flops", 0.0)),
                           "bytes": float(ca.get("bytes accessed", 0.0))}
            rec["memory"] = memory_dict(compiled)
            hlo = compiled.as_text()
            rec["collectives"] = parse_collectives(hlo)
            rec["hlo_has_while"] = " while(" in hlo
            rec["lower_s"] = round(t_lower - t0, 2)
            rec["compile_s"] = round(t_comp - t_lower, 2)
    except Exception as e:   # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        tf.set_scan_unroll(1)
        ops.set_attn_chunk(0)
        tf.set_remat("none")
    return rec


MODES = ("memfit", "probe1_exact", "probe2_exact", "probe1_chunked",
         "probe2_chunked")


def cell_path(out_dir: str, arch: str, shape: str, mesh_kind: str,
              mode: str, tag: str = "") -> str:
    t = f".{tag}" if tag else ""
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}__{mode}{t}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--mode", default=None, choices=MODES)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--tag", default="", help="variant tag for perf iterations")
    ap.add_argument("--parallel-json", default="",
                    help="JSON dict of ParallelConfig overrides")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else all_archs()
    shapes = [get_shape(args.shape)] if args.shape else list(SHAPES)
    modes = [args.mode] if args.mode else list(MODES)
    overrides = json.loads(args.parallel_json) if args.parallel_json else None

    for arch in archs:
        for shape in shapes:
            reason = skip_reason(arch, shape)
            if reason:
                rec = {"arch": arch, "shape": shape.name, "mesh": args.mesh,
                       "mode": "memfit", "status": "skipped",
                       "skip_reason": reason}
                with open(cell_path(args.out, arch, shape.name, args.mesh,
                                    "memfit", args.tag), "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"SKIP {arch} {shape.name}: {reason}")
                continue
            for mode in modes:
                path = cell_path(args.out, arch, shape.name, args.mesh, mode,
                                 args.tag)
                if os.path.exists(path) and not args.force:
                    print(f"cached {path}")
                    continue
                rec = run_mode(arch, shape, args.mesh, mode,
                               remat=args.remat, parallel_over=overrides)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                ok = rec["status"]
                extra = (f" flops={rec.get('cost', {}).get('flops', 0):.3e}"
                         f" lower={rec.get('lower_s')}s"
                         f" compile={rec.get('compile_s')}s"
                         if ok == "ok" else f" {rec.get('error', '')[:200]}")
                print(f"{ok:7s} {arch} {shape.name} {args.mesh} {mode}{extra}",
                      flush=True)


if __name__ == "__main__":
    main()

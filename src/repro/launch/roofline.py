"""Roofline analysis from the dry-run JSON cache.

Per (arch x shape) cell on the single-pod mesh:

  flops/bytes/collective-bytes per device are extrapolated from the probe
  compiles:   total = B + (n_groups - 1) * (C - B)
  where B/C are the 1-group/2-group fully-unrolled compiles (exact-attention
  probes feed the FLOP/collective terms; flash-chunked probes feed the HBM
  byte term, matching the deployed VMEM-resident attention algorithm).

  compute term    = flops_dev / PEAK_BF16
  memory term     = bytes_dev / HBM_BW
  collective term = coll_bytes_dev / ICI_BW
  bound           = max of the three;  roofline fraction = compute/bound

  MODEL_FLOPS = 6 * N(_active) * D (global; reported per device for the
  ratio against HLO flops — catches remat/redundant compute).

Caveats (documented in EXPERIMENTS.md §Roofline): recurrence steps inside
rwkv/ssm sequence scans are counted once by XLA — their FLOP share is <1%
of the projections (measured), and their once-counted state traffic matches
the VMEM-resident kernel rather than the XLA scan, which is the deployed
path.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.config import SHAPES, get_shape
from repro.configs import all_archs, get_config
from repro.models.model import active_param_count, param_count

PEAK_BF16 = 197e12          # TPU v5e peak bf16 FLOP/s per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per chip (per-link figure from spec)
HBM_BYTES = 16e9            # capacity per chip
CHIPS_SINGLE = 256
TP = 16                     # model-axis width on the single-pod mesh
DP = 16                     # data-axis width


def analytic_bytes(arch: str, shape_name: str, memfit: Dict) -> float:
    """Kernel-level HBM traffic model per device per step (bytes).

    The CPU backend's HLO `bytes accessed` counts every HLO op's operands —
    including tile/attention buffers that live in VMEM on the TPU target
    (CPU XLA fuses far less than TPU XLA + our Pallas kernels). This model
    counts only true HBM traffic: weights, residual/activation streams (per
    pass), logits, KV/recurrent caches and optimizer state. Constants are
    deliberately simple and documented; HLO bytes stay in the JSON as a
    diagnostic.
    """
    from repro.config import get_shape
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mb = memfit.get("microbatches", 1)
    pdt = {"float32": 4, "bfloat16": 2}.get(
        memfit.get("param_dtype", "float32"), 1)
    act_b = 2                                  # bf16 activations
    p_local = param_count(cfg) * pdt / CHIPS_SINGLE
    d = cfg.d_model
    L = cfg.num_layers
    kinds = cfg.layer_types

    if shape.kind == "train":
        tok_l = shape.global_batch * shape.seq_len / DP
        passes = 3 if memfit.get("remat", "none") != "none" else 2
        # weights: read per pass per microbatch; grad accum rw; opt m/v rw +
        # master update (fp32)
        w = p_local * (passes * mb + 1) + p_local / pdt * (2 * 4 + 2 * 8)
        # activations: residual + block internals per layer per pass
        act = 0.0
        for i, kind in enumerate(kinds):
            if kind == "attn":
                per_tok = (12 * d + 3 * (cfg.d_ff / TP)) if not cfg.layer_uses_moe(i) else 12 * d
            elif kind == "mamba":
                di = cfg.ssm.expand * d
                per_tok = 8 * d + 6 * di / TP
            else:                               # rwkv
                per_tok = (14 * d + 3 * (cfg.d_ff / TP))
            if cfg.layer_uses_moe(i):
                m = cfg.moe
                per_tok += m.top_k * m.capacity_factor * (2 * d + 3 * m.d_ff / TP)
                if m.num_shared_experts:
                    per_tok += 3 * m.num_shared_experts * m.d_ff / TP
            act += per_tok * tok_l * act_b
        act *= passes
        logits = tok_l * (cfg.vocab_size / TP) * 4 * 2      # fwd + grad, fp32
        return w + act + logits

    if shape.kind == "prefill":
        tok_l = shape.global_batch * shape.seq_len / DP
        w = p_local
        act = 0.0
        cache = 0.0
        for i, kind in enumerate(kinds):
            if kind == "attn":
                per_tok = 10 * d + 3 * (cfg.d_ff / TP if not cfg.layer_uses_moe(i) else 0)
                a = cfg.attention
                kvh = a.num_kv_heads if a.kind == "gqa" else 1
                kv_dim = (2 * kvh * a.head_dim if a.kind == "gqa"
                          else a.kv_lora_rank + a.qk_rope_head_dim)
                cache += tok_l * kv_dim * act_b
            elif kind == "mamba":
                per_tok = 8 * d + 6 * (cfg.ssm.expand * d) / TP
            else:
                per_tok = 14 * d + 3 * (cfg.d_ff / TP)
            if cfg.layer_uses_moe(i):
                m = cfg.moe
                per_tok += m.top_k * m.capacity_factor * (2 * d + 3 * m.d_ff / TP)
            act += per_tok * tok_l * act_b
        logits = shape.global_batch / DP * (cfg.vocab_size / TP) * 4
        return w + act + cache + logits

    # decode: weights once + full local cache read + small activations
    w = p_local
    a = cfg.attention
    cache = 0.0
    seq_shard = DP * TP if shape.global_batch < 16 else TP
    batch_shard = 1 if shape.global_batch < 16 else DP
    b_l = shape.global_batch / batch_shard
    for i, kind in enumerate(kinds):
        if kind == "attn":
            if a.kind == "mla":
                kv_dim = a.kv_lora_rank + a.qk_rope_head_dim
            else:
                kv_dim = 2 * a.num_kv_heads * a.head_dim
            cache += b_l * (shape.seq_len / seq_shard) * kv_dim * act_b
        elif kind == "mamba":
            cache += b_l * cfg.ssm.expand * d * cfg.ssm.d_state * 4
        elif kind == "rwkv":
            H = d // cfg.rwkv.head_dim
            cache += b_l * H * cfg.rwkv.head_dim ** 2 * 4
    act = b_l * L * 20 * d * act_b
    logits = b_l * (cfg.vocab_size / TP) * 4
    return w + cache * 2 + act + logits        # cache read + update write


def _load(out_dir: str, arch: str, shape: str, mesh: str, mode: str,
          tag: str = "") -> Optional[Dict]:
    t = f".{tag}" if tag else ""
    p = os.path.join(out_dir, f"{arch}__{shape}__{mesh}__{mode}{t}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def _coll_bytes(rec: Dict) -> float:
    return sum(v["bytes"] for v in rec.get("collectives", {}).values())


def _extrapolate(b: float, c: float, groups: int) -> float:
    return max(b + (groups - 1) * (c - b), 0.0)


@dataclass
class CellRoofline:
    arch: str
    shape: str
    status: str
    flops_dev: float = 0.0
    bytes_dev: float = 0.0          # analytic kernel-level HBM traffic
    hlo_bytes_dev: float = 0.0      # diagnostic: XLA HLO bytes accessed
    coll_bytes_dev: float = 0.0
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bound: str = ""
    bound_s: float = 0.0
    roofline_fraction: float = 0.0   # compute_s / bound_s
    model_flops_dev: float = 0.0
    useful_ratio: float = 0.0        # MODEL_FLOPS / HLO_FLOPS
    mem_gb_dev: float = 0.0          # argument+temp from memfit
    fits_hbm: Optional[bool] = None
    note: str = ""
    skip_reason: str = ""


def analyze_cell(out_dir: str, arch: str, shape_name: str,
                 mesh: str = "single", tag: str = "") -> CellRoofline:
    shape = get_shape(shape_name)
    memfit = _load(out_dir, arch, shape_name, mesh, "memfit", tag)
    if memfit is None:
        return CellRoofline(arch, shape_name, "missing")
    if memfit.get("status") == "skipped":
        return CellRoofline(arch, shape_name, "skipped",
                            skip_reason=memfit.get("skip_reason", ""))
    recs = {m: _load(out_dir, arch, shape_name, mesh, m, tag)
            for m in ("probe1_exact", "probe2_exact",
                      "probe1_chunked", "probe2_chunked")}
    if any(r is None or r.get("status") != "ok" for r in recs.values()):
        bad = [m for m, r in recs.items()
               if r is None or r.get("status") != "ok"]
        return CellRoofline(arch, shape_name, f"probe-missing:{bad}")
    groups = memfit.get("n_groups_full") or recs["probe1_exact"]["n_groups_full"]

    flops = _extrapolate(recs["probe1_exact"]["cost"]["flops"],
                         recs["probe2_exact"]["cost"]["flops"], groups)
    bytes_ = _extrapolate(recs["probe1_chunked"]["cost"]["bytes"],
                          recs["probe2_chunked"]["cost"]["bytes"], groups)
    coll = _extrapolate(_coll_bytes(recs["probe1_exact"]),
                        _coll_bytes(recs["probe2_exact"]), groups)

    # probes run at microbatches=1; production train steps use gradient
    # accumulation (memfit's count) which re-gathers the FSDP weight shards
    # once per extra microbatch (fwd+bwd).
    mb = memfit.get("microbatches", 1)
    if mb > 1 and memfit.get("fsdp"):
        cfgx = get_config(arch)
        pbytes = param_count(cfgx) * 4 / CHIPS_SINGLE     # fp32 train master
        coll += (mb - 1) * 2 * pbytes
    hlo_bytes = bytes_
    bytes_ = analytic_bytes(arch, shape_name, memfit)

    cell = CellRoofline(arch, shape_name,
                        memfit.get("status", "ok"))
    cell.flops_dev, cell.bytes_dev, cell.coll_bytes_dev = flops, bytes_, coll
    cell.hlo_bytes_dev = hlo_bytes
    cell.compute_s = flops / PEAK_BF16
    cell.memory_s = bytes_ / HBM_BW
    cell.collective_s = coll / ICI_BW
    terms = {"compute": cell.compute_s, "memory": cell.memory_s,
             "collective": cell.collective_s}
    cell.bound = max(terms, key=terms.get)
    cell.bound_s = terms[cell.bound]
    cell.roofline_fraction = (cell.compute_s / cell.bound_s
                              if cell.bound_s > 0 else 0.0)

    cfg = get_config(arch)
    n = active_param_count(cfg) if cfg.moe is not None else param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n * tokens
    else:                      # decode: one token per sequence
        tokens = shape.global_batch
        model_flops = 2.0 * n * tokens
    cell.model_flops_dev = model_flops / CHIPS_SINGLE
    cell.useful_ratio = (cell.model_flops_dev / flops) if flops > 0 else 0.0

    mem = memfit.get("memory", {})
    arg = mem.get("argument_size_in_bytes", 0)
    tmp = mem.get("temp_size_in_bytes", 0)
    cell.mem_gb_dev = (arg + tmp) / 1e9
    if arg + tmp > 0:
        cell.fits_hbm = (arg + tmp) <= HBM_BYTES
    cell.note = _advice(cell)
    return cell


def _advice(c: CellRoofline) -> str:
    if c.bound == "compute":
        return ("compute-bound: raise MFU via kernel fusion/larger per-chip "
                "batch; already at the right roofline corner")
    if c.bound == "memory":
        return ("memory-bound: cut HBM traffic (fuse elementwise chains, "
                "bf16/int8 states, larger arithmetic intensity per pass)")
    return ("collective-bound: reshard to cut all-gather/all-reduce volume "
            "(FSDP prefetch, TP only where heads divide, int8 grad "
            "compression, overlap with compute)")


def analyze_all(out_dir: str, mesh: str = "single", tag: str = ""
                ) -> List[CellRoofline]:
    cells = []
    for arch in all_archs():
        for shape in SHAPES:
            cells.append(analyze_cell(out_dir, arch, shape.name, mesh, tag))
    return cells


def rows(cells: List[CellRoofline]) -> List[Dict]:
    out = []
    for c in cells:
        if c.status in ("skipped",):
            out.append({"arch": c.arch, "shape": c.shape, "status": "skipped",
                        "bound": "-", "compute_ms": "-", "memory_ms": "-",
                        "collective_ms": "-", "roofline_frac": "-",
                        "useful_ratio": "-", "mem_gb": "-", "fits": "-"})
            continue
        out.append({
            "arch": c.arch, "shape": c.shape, "status": c.status,
            "bound": c.bound,
            "compute_ms": round(c.compute_s * 1e3, 3),
            "memory_ms": round(c.memory_s * 1e3, 3),
            "collective_ms": round(c.collective_s * 1e3, 3),
            "roofline_frac": round(c.roofline_fraction, 3),
            "useful_ratio": round(c.useful_ratio, 3),
            "mem_gb": round(c.mem_gb_dev, 2),
            "fits": c.fits_hbm,
        })
    return out


def main():
    import argparse
    from repro.core.report import render_table, write_csv
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--csv", default="experiments/roofline.csv")
    args = ap.parse_args()
    cells = analyze_all(args.out, args.mesh, args.tag)
    r = rows(cells)
    print(render_table(r, f"Roofline ({args.mesh} pod, 256 chips)"))
    write_csv(r, args.csv)
    print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()

"""Batched serving engine — the inference pipeline-under-test.

Grouped batching: requests queue up, groups of up to ``slots`` are prefilled
together (prompts right-padded to the group max), then decoded step-by-step
until every member finishes. Stages (queue wait / prefill / decode) are
wind-tunnel spans, so PlantD experiments measure TTFT, per-token latency and
throughput for a serving pipeline exactly like the paper's telemetry
pipeline — and the business layer can simulate a year of request traffic
against the fitted twin.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.config import ModelConfig, ParallelConfig
from repro.core.pipeline import Pipeline, PipelineStage, Resources
from repro.core.spans import SpanCollector, span
from repro.launch.specs import SDS
from repro.models import model as M
from repro.serve.steps import make_decode_step, make_prefill_step


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    submitted: float = 0.0
    first_token: Optional[float] = None
    completed: Optional[float] = None
    output: List[int] = field(default_factory=list)

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.first_token is None else self.first_token - self.submitted

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.completed is None else self.completed - self.submitted


class ServeEngine:
    def __init__(self, cfg: ModelConfig, mesh, parallel: ParallelConfig,
                 params, *, slots: int = 4, max_len: int = 256,
                 collector: Optional[SpanCollector] = None, chips: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.parallel = parallel
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.collector = collector or SpanCollector()
        self.chips = chips
        batch_abs = {"tokens": SDS((slots, max_len // 2), jnp.int32)}
        self._prefill, _ = make_prefill_step(cfg, parallel, mesh, batch_abs,
                                             slots, max_len)
        self._decode, _ = make_decode_step(
            cfg, parallel, mesh, {"token": SDS((slots, 1), jnp.int32)},
            slots, max_len)
        self._prefill_len = max_len // 2

    # -- one group ------------------------------------------------------------
    def process_group(self, group: Sequence[Request]) -> None:
        now = self.collector.clock
        g = len(group)
        assert g <= self.slots
        obs.count("serve.requests", g)
        plen = self._prefill_len
        toks = np.zeros((self.slots, plen), np.int32)
        for i, r in enumerate(group):
            p = r.prompt[-plen:]
            toks[i, :len(p)] = p        # left-aligned, right-padded
        with span("prefill", self.collector, records=g):
            cache = M.init_cache(self.cfg, self.slots, self.max_len)
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)}, cache)
            jax.block_until_ready(logits)
        tok = np.asarray(jnp.argmax(logits[:, -1], -1)).astype(np.int32)
        t = now()
        for i, r in enumerate(group):
            r.first_token = t
            r.output.append(int(tok[i]))
        max_new = max(r.max_new for r in group)
        cur = jnp.asarray(tok)[:, None]
        for step_i in range(1, max_new):
            with span("decode", self.collector, records=g):
                logits, cache = self._decode(self.params, cache,
                                             {"token": cur})
                jax.block_until_ready(logits)
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1)).astype(np.int32)
            t = now()
            for i, r in enumerate(group):
                if len(r.output) < r.max_new:
                    r.output.append(int(nxt[i]))
                    if len(r.output) == r.max_new:
                        r.completed = t
            cur = jnp.asarray(nxt)[:, None]
        t = now()
        for r in group:
            if r.completed is None:
                r.completed = t

    # -- request-loop driver ---------------------------------------------------
    def serve(self, requests: List[Request], duration_s: float = 10.0
              ) -> List[Request]:
        """FIFO grouped batching over a pre-timestamped request list
        (timestamps relative to start). The stage spans (queue_wait /
        prefill / decode) land in the engine's collector as always and
        mirror into ``repro.obs`` as ``stage.*`` spans when telemetry
        is on; the request loop itself records a ``serve.loop`` span
        with the request count and bumps ``serve.requests`` /
        ``serve.groups`` counters."""
        with obs.span("serve.loop", requests=len(requests),
                      slots=self.slots):
            start = self.collector.clock()
            pending = sorted(requests, key=lambda r: r.submitted)
            for r in pending:
                r.submitted += start
            done: List[Request] = []
            i = 0
            while i < len(pending):
                nowt = self.collector.clock()
                group = []
                while (i < len(pending) and len(group) < self.slots
                       and pending[i].submitted <= nowt):
                    group.append(pending[i])
                    i += 1
                if not group:
                    nxt = pending[i].submitted
                    time.sleep(max(0.0, min(nxt - nowt, 0.01)))
                    continue
                with span("queue_wait", self.collector,
                          records=len(group)):
                    pass
                obs.count("serve.groups")
                self.process_group(group)
                done.extend(group)
            return done

    def as_pipeline(self, name: str = "serve") -> Pipeline:
        """Wind-tunnel adapter: one stage that serves a group per record
        batch (records are token-id arrays from a DataSet)."""
        def stage(batch: Dict) -> None:
            toks = batch["tokens"]
            reqs = [Request(rid=i, prompt=list(map(int, row[:8])), max_new=4)
                    for i, row in enumerate(np.atleast_2d(toks)[: self.slots])]
            self.process_group(reqs)
            return None
        return Pipeline(name, [PipelineStage("serve_group", stage)],
                        resources=Resources(vcpus=2, ram_gb=4,
                                            chips=self.chips),
                        collector=self.collector)

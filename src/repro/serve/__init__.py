from repro.serve.steps import make_prefill_step, make_decode_step, serve_shardings  # noqa: F401

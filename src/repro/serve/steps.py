"""Serve-step factories: pjit'd prefill and single-token decode.

Decode shapes per the assignment: ``decode_32k``/``long_500k`` lower
``serve_step`` — one new token against a KV cache (or recurrent state) of
seq_len. The cache is an explicit sharded input/output; for long-context
cells the KV sequence dim is sharded over the 'data' axis (sequence
parallelism) and GSPMD inserts the distributed softmax reductions.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ParallelConfig
from repro.distributed.sharding import (build_rules, input_batch_specs,
                                        mesh_shape_dict, set_activation_mesh)
from repro.models import model as M


def _tree_ns(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def serve_shardings(cfg: ModelConfig, parallel: ParallelConfig, mesh: Mesh,
                    batch_abstract: Dict, batch: int, max_len: int):
    rules = build_rules(parallel, mesh)
    mshape = mesh_shape_dict(mesh)
    pspecs = M.partition_specs(cfg, rules, mshape)
    cspecs = M.cache_partition_specs(cfg, batch, max_len, rules, mshape)
    bspecs = input_batch_specs(batch_abstract, parallel, mesh)
    return pspecs, cspecs, bspecs


def make_prefill_step(cfg: ModelConfig, parallel: ParallelConfig, mesh: Mesh,
                      batch_abstract: Dict, batch: int, max_len: int):
    pspecs, cspecs, bspecs = serve_shardings(cfg, parallel, mesh,
                                             batch_abstract, batch, max_len)
    set_activation_mesh(mesh, build_rules(parallel, mesh))

    def step(params, batch_in, cache):
        return M.prefill(params, cfg, batch_in, cache)

    ns = functools.partial(_tree_ns, mesh)
    jitted = jax.jit(step,
                     in_shardings=(ns(pspecs), ns(bspecs), ns(cspecs)),
                     out_shardings=(None, ns(cspecs)),
                     donate_argnums=(2,))
    return jitted, (pspecs, cspecs, bspecs)


def make_decode_step(cfg: ModelConfig, parallel: ParallelConfig, mesh: Mesh,
                     batch_abstract: Dict, batch: int, max_len: int):
    pspecs, cspecs, bspecs = serve_shardings(cfg, parallel, mesh,
                                             batch_abstract, batch, max_len)
    set_activation_mesh(mesh, build_rules(parallel, mesh))

    def step(params, cache, batch_in):
        return M.decode_step(params, cfg, cache, batch_in)

    ns = functools.partial(_tree_ns, mesh)
    jitted = jax.jit(step,
                     in_shardings=(ns(pspecs), ns(cspecs), ns(bspecs)),
                     out_shardings=(None, ns(cspecs)),
                     donate_argnums=(1,))
    return jitted, (pspecs, cspecs, bspecs)

# Convenience targets for the tier-1 verify and the benchmark harness.
#
#   make test            tier-1 test suite (ROADMAP.md's verify command)
#   make test-deps       install the test requirements
#   make bench           full benchmark harness (all paper tables + grid)
#   make bench-grid      looped-vs-vmapped what-if grid microbenchmark only
#   make calibrate-bench multi-start twin-fit wall-clock vs K
#                        (writes BENCH_calibrate.json)

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-deps bench bench-grid calibrate-bench

test:
	$(PYTHON) -m pytest -x -q

test-deps:
	$(PYTHON) -m pip install -r tests/requirements.txt

bench:
	$(PYTHON) -m benchmarks.run

bench-grid:
	$(PYTHON) benchmarks/grid_bench.py

calibrate-bench:
	$(PYTHON) -m benchmarks.run calibrate

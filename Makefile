# Convenience targets for the tier-1 verify and the benchmark harness.
#
#   make test              tier-1 test suite (ROADMAP.md's verify command)
#   make test-deps         install the test requirements
#   make bench             full benchmark harness (all paper tables + grid)
#   make bench-grid        looped-vs-vmapped what-if grid microbenchmark only
#   make grid-bench-pallas XLA vs Pallas grid backends at 64/256/1024
#                          scenarios (writes BENCH_grid_pallas.json)
#   make grid-bench-stream series vs streaming-aggregate simulate_grid at
#                          1024/8192/65536 full-year scenarios
#                          (writes BENCH_grid_stream.json)
#   make grid-bench-shard  sharded block engine at 65536/262144/1048576
#                          full-year scenarios over a 1/2/4-device
#                          scenario mesh (writes BENCH_grid_shard.json)
#   make grid-bench-device device-resident histogram engine at 1024/65536
#                          full-year scenarios, single-device + 1/2/4
#                          mesh, vs the PR 6 host-binned baseline
#                          (writes BENCH_grid_device.json)
#   make calibrate-bench   multi-start twin-fit wall-clock vs K
#                          (writes BENCH_calibrate.json)
#   make search-bench      one-dispatch K-restart policy search vs serial
#                          loop + vs exhaustive 4096-point grid
#                          (writes BENCH_search.json)
#   make search-bench-stream  streamed vs materialized chance-constrained
#                          grad step at 1024 lanes x 8736 bins — wall
#                          clock + peak temp bytes (merges a "stream"
#                          key into BENCH_search.json)
#   make faults-bench      chaos-suite overhead — fault-perturbed vs
#                          benign aggregate grids at 1024/65536 full-year
#                          rows, 4 futures/base (writes BENCH_faults.json)
#   make obs-report        run-telemetry console report: instrumented demo
#                          workload (grid + fit + search) through
#                          repro.obs — spans, dispatch profiles, counters

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-deps bench bench-grid grid-bench-pallas \
        grid-bench-stream grid-bench-shard grid-bench-device \
        calibrate-bench search-bench search-bench-stream faults-bench \
        obs-report

test:
	$(PYTHON) -m pytest -x -q

test-deps:
	$(PYTHON) -m pip install -r tests/requirements.txt

bench:
	$(PYTHON) -m benchmarks.run

bench-grid:
	$(PYTHON) benchmarks/grid_bench.py

grid-bench-pallas:
	$(PYTHON) -m benchmarks.run grid-pallas

grid-bench-stream:
	$(PYTHON) -m benchmarks.run grid-stream

grid-bench-shard:
	$(PYTHON) -m benchmarks.run grid-shard

grid-bench-device:
	$(PYTHON) -m benchmarks.run grid-device

calibrate-bench:
	$(PYTHON) -m benchmarks.run calibrate

search-bench:
	$(PYTHON) -m benchmarks.run search

search-bench-stream:
	$(PYTHON) -m benchmarks.run search-stream

faults-bench:
	$(PYTHON) -m benchmarks.run faults

obs-report:
	$(PYTHON) -m repro.obs

"""Paper Table III / Fig. 8: per-variant engineering comparison under an
identical above-capacity ramp — per-stage throughput/latency + experiment
cost. Demonstrates the paper's central finding (blocking write inflates
v2x_phase) with real measured spans."""
from __future__ import annotations

import tempfile
import time
from typing import Dict, List

from repro.core.experiment import Experiment
from repro.core.loadpattern import LoadPattern
from repro.pipelines.telemetry import (TELEMETRY_VARIANTS,
                                       make_telemetry_dataset,
                                       make_telemetry_pipeline)


def run(records: int = 40, peak_rate: float = 120.0,
        duration_s: float = 3.0) -> List[Dict]:
    ds = make_telemetry_dataset(records, seed=23)
    rows = []
    for variant in TELEMETRY_VARIANTS:
        pipe = make_telemetry_pipeline(variant, blob_dir=tempfile.mkdtemp())
        load = LoadPattern.ramp("ramp", duration_s, peak_rate)
        res = Experiment(f"t3-{variant}", pipe, load, ds,
                         drain_timeout_s=120).run()
        row = {"experiment": variant,
               "mean_throughput_rps": round(res.sustained_rps, 2),
               "mean_latency_ms": round(res.base_latency_s * 1e3, 3),
               "exp_length_s": round(res.duration_s, 2),
               "total_cost_usd": round(res.cost["total_usd"], 6),
               "cost_per_hr_usd": round(res.cost["usd_per_hour"], 4),
               "drained": res.drained}
        for st, v in res.stage_summary.items():
            row[f"{st}_p50_ms"] = round(v["p50_latency_s"] * 1e3, 3)
        rows.append(row)
    return rows


def main() -> List[str]:
    t0 = time.perf_counter()
    rows = run()
    wall = (time.perf_counter() - t0) / len(rows) * 1e6
    lines = []
    for r in rows:
        lines.append(
            f"table3/{r['experiment']},{wall:.0f},"
            f"thr={r['mean_throughput_rps']};v2x_p50_ms="
            f"{r.get('v2x_phase_p50_ms')};cost_hr={r['cost_per_hr_usd']}")
    return lines


if __name__ == "__main__":
    from repro.core.report import render_table
    print(render_table(run(), "Table III (engineering comparison)"))
